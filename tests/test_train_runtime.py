"""Training-loop substrate: microbatch-accumulation equivalence, grad
clipping, warmup schedule, and the attention-decode oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import lm
from repro.models.layers import AttnSpec, attention_decode
from repro.kernels.flash_attention.ref import attention_ref
from repro.runtime import train as train_lib


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(ARCHS["llama3.2-1b"])
    params = lm.init_params(cfg, jax.random.PRNGKey(0), max_pos=64)
    batch = {"tokens": jnp.arange(128, dtype=jnp.int32).reshape(8, 16) % cfg.vocab_size}
    return cfg, params, batch


def test_microbatched_grads_match_full(setup):
    cfg, params, batch = setup

    def loss_of(p, b):
        return lm.loss_fn(cfg, p, b)

    (_, _), g_full = jax.value_and_grad(loss_of, has_aux=True)(params, batch)
    g_micro, _ = train_lib._accumulated_grads(loss_of, params, batch, micro=2)
    for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_micro)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=3e-2, rtol=3e-2,
        )


def test_grad_clip_bounds_update(setup):
    cfg, params, batch = setup
    opt = train_lib.OptConfig(lr=1.0, grad_clip=1e-9, weight_decay=0.0, warmup_steps=1)
    state = train_lib.init_state(cfg, params)
    step = train_lib.make_train_step(cfg, opt)
    new_state, _ = step(state, batch)
    # with a tiny clip, params barely move
    for a, b in zip(jax.tree.leaves(state["params"]), jax.tree.leaves(new_state["params"])):
        assert float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))) < 1e-2


def test_warmup_schedule():
    opt = train_lib.OptConfig(lr=1e-3, warmup_steps=10)
    assert float(train_lib._lr_at(opt, jnp.int32(1))) == pytest.approx(1e-4)
    assert float(train_lib._lr_at(opt, jnp.int32(10))) == pytest.approx(1e-3)
    assert float(train_lib._lr_at(opt, jnp.int32(100))) == pytest.approx(1e-3)


def test_attention_decode_matches_ref():
    """One-token decode vs full attention at the same position."""
    b, s, h, kh, hd = 2, 12, 4, 2, 16
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, 1, h, hd))
    kc = jax.random.normal(k2, (b, 16, kh, hd))  # cache with 16 slots
    vc = jax.random.normal(k3, (b, 16, kh, hd))
    out = attention_decode(q, kc, vc, jnp.int32(s), AttnSpec(causal=True))
    # reference: attend over the first s cache entries, query at position s-1
    ref = attention_ref(q, kc[:, :s], vc[:, :s], causal=True, q_offset=s - 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)


def test_opt_state_dtype_honored():
    cfg = reduced(ARCHS["kimi-k2-1t-a32b"])  # opt_state_dtype = bfloat16
    params = lm.init_params(cfg, jax.random.PRNGKey(0), max_pos=64)
    state = train_lib.init_state(cfg, params)
    assert all(x.dtype == jnp.bfloat16 for x in jax.tree.leaves(state["m"]))
