"""Partitioner unit + property tests (DP vs exhaustive oracle)."""

import pytest

from _hypothesis_compat import given, settings, st

from repro.core import (
    chain,
    make_partitions,
    partition_exact_k,
    partition_exhaustive,
    partition_min_bottleneck,
    partition_min_sum,
    partition_paper_greedy,
)
from repro.core.graph import Layer, LayerGraph


def toy(sizes):
    return chain("toy", sizes)


class TestMakePartitions:
    def test_no_cuts(self):
        g = toy([(10, 5), (10, 5), (10, 5)])
        parts = make_partitions(g, [])
        assert len(parts) == 1
        assert parts[0].param_bytes == 30
        assert parts[0].out_bytes == 0

    def test_cuts(self):
        g = toy([(1, 100), (2, 200), (3, 300), (4, 400)])
        parts = make_partitions(g, [0, 2])
        assert [p.param_bytes for p in parts] == [1, 5, 4]
        assert [p.out_bytes for p in parts] == [100, 300, 0]

    def test_bad_cuts(self):
        g = toy([(1, 1), (1, 1)])
        with pytest.raises(ValueError):
            make_partitions(g, [5])
        with pytest.raises(ValueError):
            make_partitions(g, [0, 0])


class TestMinBottleneck:
    def test_trivial_fit(self):
        g = toy([(10, 99), (10, 99)])
        r = partition_min_bottleneck(g, 100)
        assert r.feasible and r.n_parts == 1 and r.max_cut_bytes == 0

    def test_single_layer_too_big(self):
        g = toy([(1000, 1), (10, 1)])
        assert not partition_min_bottleneck(g, 100).feasible

    def test_picks_cheap_edges(self):
        # capacity forces >= 2 parts; edge 1 is the cheap cut
        g = toy([(40, 100), (40, 1), (40, 100)])
        r = partition_min_bottleneck(g, 80)
        assert r.feasible and r.cuts == (1,) and r.max_cut_bytes == 1

    def test_max_parts_respected(self):
        g = toy([(50, 1)] * 6)
        r = partition_min_bottleneck(g, 100, max_parts=3)
        assert r.feasible and r.n_parts == 3
        assert not partition_min_bottleneck(g, 100, max_parts=2).feasible

    def test_capacity_exact_boundary(self):
        g = toy([(50, 7), (50, 3)])
        r = partition_min_bottleneck(g, 100)
        assert r.feasible and r.n_parts == 1
        r = partition_min_bottleneck(g, 99)
        assert r.feasible and r.n_parts == 2 and r.max_cut_bytes == 7


class TestExactK:
    def test_matches_min_bottleneck_at_kmin(self):
        g = toy([(30, 9), (30, 2), (30, 8), (30, 1), (30, 5)])
        base = partition_min_bottleneck(g, 70)
        r = partition_exact_k(g, 70, base.n_parts)
        assert r.feasible and r.max_cut_bytes == base.max_cut_bytes

    def test_infeasible_k(self):
        g = toy([(10, 1)] * 3)
        assert not partition_exact_k(g, 100, 5).feasible
        assert not partition_exact_k(g, 100, 0).feasible


SIZES = st.lists(
    st.tuples(st.integers(1, 50), st.integers(1, 1000)), min_size=2, max_size=9
)


@settings(max_examples=120, deadline=None)
@given(sizes=SIZES, cap=st.integers(10, 200))
def test_min_bottleneck_matches_exhaustive(sizes, cap):
    """The binary-search partitioner is exact: same min-max-cut as oracle."""
    g = toy(sizes)
    opt = partition_min_bottleneck(g, cap)
    oracle = partition_exhaustive(g, cap)
    assert opt.feasible == oracle.feasible
    if opt.feasible:
        assert opt.max_cut_bytes == oracle.max_cut_bytes
        # every segment fits
        assert all(p.param_bytes <= cap for p in opt.partitions)


@settings(max_examples=120, deadline=None)
@given(sizes=SIZES, cap=st.integers(10, 200))
def test_greedy_never_beats_optimal_and_is_valid(sizes, cap):
    g = toy(sizes)
    greedy = partition_paper_greedy(g, cap)
    opt = partition_min_bottleneck(g, cap)
    if greedy.feasible:
        assert all(p.param_bytes <= cap for p in greedy.partitions)
        # partitions reconstruct the chain
        assert greedy.partitions[0].start == 0
        assert greedy.partitions[-1].stop == len(g)
        for a, b in zip(greedy.partitions, greedy.partitions[1:]):
            assert a.stop == b.start
    if greedy.feasible and opt.feasible:
        assert opt.max_cut_bytes <= greedy.max_cut_bytes


@settings(max_examples=100, deadline=None)
@given(sizes=SIZES, cap=st.integers(10, 200))
def test_min_sum_bounded_by_minmax_total(sizes, cap):
    """min_sum total <= min_bottleneck total (it optimizes the sum)."""
    g = toy(sizes)
    ms = partition_min_sum(g, cap)
    mb = partition_min_bottleneck(g, cap)
    assert ms.feasible == mb.feasible
    if ms.feasible:
        assert ms.total_cut_bytes <= mb.total_cut_bytes
        assert all(p.param_bytes <= cap for p in ms.partitions)


@settings(max_examples=60, deadline=None)
@given(sizes=SIZES, cap=st.integers(20, 200), k=st.integers(1, 6))
def test_exact_k_is_optimal_for_its_k(sizes, cap, k):
    g = toy(sizes)
    r = partition_exact_k(g, cap, k)
    oracle = partition_exhaustive(g, cap, max_parts=k)
    if r.feasible:
        assert r.n_parts == k
        # oracle minimizes over <= k parts, so oracle <= exact_k
        assert oracle.feasible and oracle.max_cut_bytes <= r.max_cut_bytes


def test_layer_validation():
    with pytest.raises(ValueError):
        Layer("x", -1, 0)
    with pytest.raises(ValueError):
        LayerGraph("empty", ())
