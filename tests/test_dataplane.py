"""Data-plane coverage: codec registry, transforms, byte/cost models, the
joint codec x placement assignment, and the engine's end-to-end pinning.

The anchor tests are the last two groups: every registered codec (plus
``"auto"``) deployed on a bandwidth-constrained cluster must measure within
5% of ``Plan.predicted_throughput`` (the engine and the planner share
``core.bottleneck.service_times``), and a lossy codec must *really* alter
the activations crossing links -- the transform runs in the serving path,
not just in the byte model.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ClusterSpec, DeploymentSpec, deploy
from repro.cluster import NodeFailed
from repro.core.bottleneck import service_times
from repro.core.graph import chain, make_partitions
from repro.core.model_zoo import demo_mlp
from repro.core.placement import CommGraph
from repro.dataplane import (
    UnknownCodecError,
    assign_link_codecs,
    codec_table,
    default_codec,
    get_codec,
    link_charge_s,
    list_codecs,
    register_codec,
    select_codec,
)

WIDTH = 32


def _star_cluster(mesh_bw: float, hosting: int = 4, dispatcher_bw: float = 1e9):
    """Fast dispatcher links, ``mesh_bw`` across the hosting mesh -- the
    constrained resource is exactly the inter-stage activation path."""
    n = hosting + 1
    bw = np.full((n, n), float(mesh_bw))
    bw[0, :] = bw[:, 0] = dispatcher_bw
    np.fill_diagonal(bw, 0.0)
    graph, _ = demo_mlp(d=WIDTH)
    cap = np.full(n, graph.total_param_bytes / 3.0)
    cap[0] = -1.0
    return CommGraph(bw=bw, node_capacity=cap)


def _deploy(codec, mesh_bw=1e4, **kw):
    graph, executor_for_version = demo_mlp(d=WIDTH)
    return deploy(DeploymentSpec(
        model=graph,
        executor_for_version=executor_for_version,
        cluster=ClusterSpec(comm=_star_cluster(mesh_bw)),
        codec=codec,
        microbatch=1,
        **kw,
    ))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_registry_contains_the_required_codecs():
    names = set(list_codecs())
    assert names >= {"identity", "fp16", "int8", "topk-sparse"}
    assert default_codec() == "identity"
    assert list_codecs()[0] == "identity"  # default listed first


def test_unknown_codec_raises_with_suggestions():
    with pytest.raises(UnknownCodecError) as ei:
        get_codec("int-8")
    assert "int8" in str(ei.value)  # did-you-mean
    assert "identity" in str(ei.value)  # registered names listed


def test_duplicate_codec_registration_rejected():
    with pytest.raises(ValueError, match="duplicate"):
        register_codec("identity")(type("Dup", (), {}))


def test_codec_table_reports_every_codec():
    rows = codec_table()
    assert {r["name"] for r in rows} == set(list_codecs())
    by = {r["name"]: r for r in rows}
    assert by["identity"]["default"] == "yes"
    assert float(by["int8"]["wire_ratio_f32"]) < 0.5


# ---------------------------------------------------------------------------
# Transforms + byte model
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("asarray", [np.asarray, jnp.asarray],
                         ids=["numpy", "jax"])
def test_roundtrip_error_within_reported_bound(asarray):
    """decode(encode(x)) stays within each codec's reported error bound,
    on both the jax path (what the engine feeds) and the numpy fallback."""
    x = asarray(np.random.default_rng(0).normal(
        size=(4, 37)).astype(np.float32))
    scale = float(np.max(np.abs(np.asarray(x))))
    for name in list_codecs():
        codec = get_codec(name)
        y = codec.transcode(x)
        assert y.shape == x.shape
        err = float(np.max(np.abs(np.asarray(y) - np.asarray(x)))) / scale
        assert err <= codec.error_bound * (1 + 1e-4) + 1e-9, name


def test_identity_is_exact_and_free():
    codec = get_codec("identity")
    x = jnp.ones((3, 5))
    assert codec.transcode(x) is x
    assert codec.wire_bytes(1000.0) == 1000.0
    assert codec.encode_cost_s(1e9, 1e9) == 0.0
    assert codec.error_bound == 0.0


def test_topk_keeps_the_largest_magnitudes_exactly():
    codec = get_codec("topk-sparse")
    x = np.arange(1, 17, dtype=np.float32).reshape(4, 4)  # all distinct
    y = codec.transcode(x)
    k = codec._k(x.size)
    top = np.sort(np.abs(x).ravel())[-k:]
    kept = np.abs(y[y != 0])
    np.testing.assert_array_equal(np.sort(kept), top)  # survivors exact
    assert np.count_nonzero(y) == k


def test_compressed_bytes_layouts():
    """Exact on-wire sizes: identity = raw, fp16 = half, int8 = 1 B/elem +
    one f32 scale per (ragged) block, topk = kept * (value + int32 index)."""
    shape = (4, 300)  # ragged over int8's 256-wide blocks
    n = 4 * 300
    assert get_codec("identity").compressed_bytes(shape) == n * 4
    assert get_codec("fp16").compressed_bytes(shape) == n * 2
    assert get_codec("int8").compressed_bytes(shape) == n + 4 * (4 * 2)
    topk = get_codec("topk-sparse")
    assert topk.compressed_bytes(shape) == topk._k(n) * 8
    # the analytic wire ratio agrees with the exact layout on block-aligned
    # shapes (what the byte-counted simulator charges)
    aligned = (4, 512)
    for name in list_codecs():
        codec = get_codec(name)
        exact = codec.compressed_bytes(aligned)
        assert codec.wire_bytes(4 * 512 * 4) == pytest.approx(exact, rel=0.01)


def test_fp16_clamps_out_of_range_instead_of_overflowing():
    """Values past float16's finite range must degrade to the range edge,
    never become inf and poison downstream stages."""
    codec = get_codec("fp16")
    x = np.array([[1e6, -1e6, 3.5]], np.float32)
    for y in (codec.transcode(x), codec.transcode(jnp.asarray(x))):
        y = np.asarray(y, np.float32)
        assert np.all(np.isfinite(y))
        np.testing.assert_allclose(y, [[65504.0, -65504.0, 3.5]], rtol=1e-3)


def test_int8_numpy_fallback_matches_the_jax_ref_exactly():
    """The codec's numpy fallback and kernels/quantize/ref.py implement one
    algorithm twice (ref must stay jnp to lower under jit); this pin makes
    any drift -- scale rule, epsilon, clip range, ragged padding -- fail
    loudly instead of silently forking the wire format."""
    from repro.dataplane.codecs import _np_dequantize, _np_quantize
    from repro.kernels.quantize.ref import dequantize_ref, quantize_ref

    for shape in ((4, 512), (3, 300), (2, 37)):  # aligned + ragged
        x = np.random.default_rng(sum(shape)).normal(
            size=shape).astype(np.float32)
        block = 256
        qn, sn = _np_quantize(x, block)
        qj, sj = quantize_ref(jnp.asarray(x), block)
        np.testing.assert_array_equal(qn, np.asarray(qj))
        np.testing.assert_allclose(sn, np.asarray(sj), rtol=1e-7)
        yn = _np_dequantize(qn, sn, block)
        yj = dequantize_ref(qj, sj, dtype=jnp.float32, block=block)
        np.testing.assert_allclose(yn, np.asarray(yj), rtol=1e-6, atol=1e-8)


def test_int8_codec_reports_the_kernel_error_bound():
    """One number, two consumers: the quantize kernel's tested bound IS the
    figure the planner's accuracy_tolerance check uses."""
    from repro.kernels.quantize import INT8_MAX_REL_ERROR

    assert get_codec("int8").error_bound == INT8_MAX_REL_ERROR


# ---------------------------------------------------------------------------
# Selection + assignment
# ---------------------------------------------------------------------------

def test_select_codec_compresses_slow_links_and_leaves_fast_ones_raw():
    # slow link: wire time dominates -> densest admissible codec
    assert select_codec(1e6, 1e3, src_flops=1e9, dst_flops=1e9) == "int8"
    # fast link: codec compute dominates -> identity (zero-cost) wins
    assert select_codec(1e3, 1e12, src_flops=1e9, dst_flops=1e9) == "identity"


def test_select_codec_respects_the_tolerance():
    assert select_codec(1e6, 1e3, tolerance=0.0) == "identity"
    assert select_codec(1e6, 1e3, tolerance=1e-3) == "fp16"
    assert select_codec(1e6, 1e3, tolerance=0.004) == "int8"


def test_link_charge_is_encode_plus_transfer_plus_decode():
    codec = get_codec("int8")
    nbytes, bw, f = 1e6, 1e4, 1e9
    expect = (codec.encode_cost_s(nbytes, f)
              + codec.wire_bytes(nbytes) / bw
              + codec.decode_cost_s(nbytes, f))
    assert link_charge_s(codec, nbytes, bw, src_flops=f, dst_flops=f) == expect
    assert link_charge_s(codec, nbytes, 0.0) == float("inf")


def test_assignment_keeps_dispatcher_hops_raw():
    bw = np.full((4, 4), 1e3)
    codecs = assign_link_codecs([100, 200, 200, 100], [1, 2, 3], bw,
                                codec="int8", dispatcher=0)
    assert codecs == ("identity", "int8", "int8", "identity")
    auto = assign_link_codecs([100, 200, 200, 100], [1, 2, 3], bw,
                              codec="auto", dispatcher=0)
    assert auto[0] == auto[-1] == "identity"
    assert all(c == "int8" for c in auto[1:-1])


def test_assignment_skips_colocated_hops():
    bw = np.full((3, 3), 1e3)
    codecs = assign_link_codecs([0, 200, 0], [1, 1], bw,
                                codec="auto", dispatcher=0)
    assert codecs == ("identity", "identity", "identity")


def test_service_times_charges_the_codec_window():
    graph = chain("c", [(100, 1000)] * 2, in_bytes=0)
    parts = make_partitions(graph, [0])
    bw = np.full((3, 3), 1e3)
    codec = get_codec("int8")
    base_compute, base_links = service_times(parts, [1, 2], bw,
                                             flops_per_node=1e9)
    compute, links = service_times(
        parts, [1, 2], bw, flops_per_node=1e9,
        codecs=["identity", "int8", "identity"])
    assert compute == base_compute  # codec work rides the link window
    assert links[1] == pytest.approx(
        link_charge_s(codec, 1000.0, 1e3, src_flops=1e9, dst_flops=1e9))
    assert links[1] < base_links[1]  # compression shrank the wire time


# ---------------------------------------------------------------------------
# Spec validation
# ---------------------------------------------------------------------------

def _spec(**kw):
    graph, _ = demo_mlp(d=WIDTH)
    kw.setdefault("model", graph)
    kw.setdefault("cluster", ClusterSpec(comm=_star_cluster(1e4)))
    return DeploymentSpec(**kw)


def test_spec_rejects_unknown_codec_with_suggestions():
    issues = _spec(codec="int-8").validate()
    assert [i.code for i in issues] == ["unknown_codec"]
    assert "int8" in issues[0].message  # did-you-mean rides the issue


def test_spec_rejects_negative_tolerance():
    issues = _spec(codec="auto", accuracy_tolerance=-0.5).validate()
    assert [i.code for i in issues] == ["bad_tolerance"]


def test_spec_rejects_named_codec_over_tolerance():
    issues = _spec(codec="topk-sparse", accuracy_tolerance=0.01).validate()
    assert [i.code for i in issues] == ["codec_exceeds_tolerance"]
    # auto under the same tolerance is fine: it picks within the budget
    assert _spec(codec="auto", accuracy_tolerance=0.01).validate() == ()
    # and a lossless codec trivially fits a zero tolerance
    assert _spec(codec="identity", accuracy_tolerance=0.0).validate() == ()


# ---------------------------------------------------------------------------
# End to end: deploy -> serve -> measure
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec", [*list_codecs(), "auto"])
def test_engine_measures_the_plan_prediction_per_codec(codec):
    """Measured steady-state rate == predicted (shared service_times model,
    codec windows included) within 5%, for every codec on a link-bound
    cluster."""
    d = _deploy(codec)
    for _ in range(24):
        d.submit(jnp.ones((WIDTH,)) * 0.1)
    d.drain()
    assert len(d.loop.failed) == 0 and len(d.loop.completed) == 24
    measured = d.loop.steady_state_throughput()
    assert measured == pytest.approx(d.plan.predicted_throughput, rel=0.05)


def test_auto_beats_identity_on_a_link_bound_cluster():
    """The acceptance criterion: link time >> compute time under identity,
    so auto must pick a compressing codec and improve >= 1.5x."""
    rates = {}
    for codec in ("identity", "auto"):
        d = _deploy(codec)
        for _ in range(24):
            d.submit(jnp.ones((WIDTH,)) * 0.1)
        d.drain()
        rates[codec] = d.loop.steady_state_throughput()
        if codec == "auto":
            interior = d.plan.codecs[1:-1]
            assert any(c != "identity" for c in interior), d.plan.codecs
    assert rates["auto"] >= 1.5 * rates["identity"]


def test_tolerance_zero_forces_lossless_links():
    d = _deploy("auto", accuracy_tolerance=0.0)
    assert set(d.plan.codecs) == {"identity"}


def test_lossy_codec_really_transforms_the_activations():
    """int8 runs decode(encode(x)) on every link crossing: outputs differ
    from the identity deployment but stay within a few quantization steps
    through the whole tanh chain."""
    outs = {}
    for codec in ("identity", "int8"):
        d = _deploy(codec)
        d.submit(jnp.ones((WIDTH,)) * 0.1)
        (req,) = d.drain()
        outs[codec] = np.asarray(req.result, np.float32)
    assert not np.array_equal(outs["identity"], outs["int8"])
    assert np.max(np.abs(outs["identity"] - outs["int8"])) < 0.05


def test_engine_reports_per_link_compression_and_utilization():
    d = _deploy("int8")
    for _ in range(8):
        d.submit(jnp.ones((WIDTH,)) * 0.1)
    d.drain()
    links = d.loop.metrics()["links"]
    assert len(links) == len(d.plan.path) + 1
    interior = [ln for ln in links if 0 < ln["hop"] < len(d.plan.path)]
    for ln in interior:
        assert ln["codec"] == "int8"
        assert ln["compression_x"] == pytest.approx(2048 / 520, rel=1e-6)
        assert ln["transfers"] == 8
        assert 0.0 < ln["utilization"] <= 1.0
    # dispatcher round-trip hops stay raw
    assert links[0]["codec"] == links[-1]["codec"] == "identity"


def test_replan_keeps_the_codec_config():
    """Swapping a strategy on a live deployment must not silently drop the
    data-plane config: the new planner inherits codec + tolerance."""
    d = _deploy("auto")
    d.replan(placer="greedy")
    assert d.control.planner.codec == "auto"
    assert any(c != "identity" for c in d.plan.codecs[1:-1])
    assert d.plan.codecs == tuple(d.control.pipeline.link_codecs)


def test_recovery_reassigns_codecs_for_the_new_path():
    """Joint codec x placement survives churn: a NodeFailed re-placement
    re-solves the per-link assignment and the plan/pipeline/engine agree."""
    graph, executor_for_version = demo_mlp(d=WIDTH)
    d = deploy(DeploymentSpec(
        model=graph,
        executor_for_version=executor_for_version,
        # a spare hosting node, so the 4-partition pipeline survives a kill
        cluster=ClusterSpec(comm=_star_cluster(1e4, hosting=5)),
        codec="auto",
        microbatch=1,
    ))
    for _ in range(16):
        d.submit(jnp.ones((WIDTH,)) * 0.1)
    d.step()
    victim = d.control.pipeline.pods[1].node_id
    d.inject(NodeFailed(victim))
    d.drain()
    assert len(d.loop.completed) == 16
    plan = d.plan
    assert len(plan.codecs) == len(plan.path) + 1
    assert plan.codecs == tuple(d.control.pipeline.link_codecs)
    assert any(c != "identity" for c in plan.codecs[1:-1])
    measured = d.loop.steady_state_throughput()
    assert measured > 0
