"""Property-based tests over EVERY registered partitioner strategy.

Driven through the ``_hypothesis_compat`` shim (real hypothesis when
installed, a deterministic seeded fallback otherwise), so the same
invariants run in both CI legs:

  * **structural**: a feasible result's parts are contiguous, exhaustive
    (they reconstruct the whole chain), and non-empty;
  * **capacity**: every part fits the per-node cap; boundary weights match
    the graph's cut edges;
  * **ordering oracle**: ``exact_k`` is the optimal min-max cut among
    k-part partitions, so at ``uniform``'s own part count it can never be
    beaten by the uniform (equal-layer-count) baseline;
  * **feasibility consistency**: whenever the exact ``min_bottleneck``
    solver finds a partition, the baselines that report feasible agree on
    capacity, and infeasibility of the exact solver implies the heuristics
    cannot do better at the same part budget.
"""

import pytest

from _hypothesis_compat import given, settings, st

from repro.api import get_strategy, list_strategies
from repro.core.graph import chain
from repro.core.partitioner import (
    partition_exact_k,
    partition_min_bottleneck,
    partition_uniform,
)

SIZES = st.lists(
    st.tuples(st.integers(1, 50), st.integers(1, 1000)), min_size=2, max_size=9
)

ALL_PARTITIONERS = sorted(list_strategies("partitioner"))


def _graph(sizes):
    return chain("prop", sizes)


@pytest.mark.parametrize("name", ALL_PARTITIONERS)
def test_parts_contiguous_exhaustive_and_within_capacity(name):
    fn = get_strategy("partitioner", name).fn

    @settings(max_examples=60, deadline=None)
    @given(sizes=SIZES, cap=st.integers(10, 300))
    def prop(sizes, cap):
        g = _graph(sizes)
        r = fn(g, cap)
        if not r.feasible:
            return
        parts = r.partitions
        assert parts, f"{name}: feasible result with no parts"
        # contiguous + exhaustive: the parts tile [0, n) in order
        assert parts[0].start == 0
        assert parts[-1].stop == len(g)
        for a, b in zip(parts, parts[1:]):
            assert a.stop == b.start, f"{name}: gap/overlap at {a.stop}"
        assert all(p.stop > p.start for p in parts), f"{name}: empty part"
        # capacity respected, and recorded sizes match the graph
        for p in parts:
            assert p.param_bytes == g.segment_param_bytes(p.start, p.stop)
            assert p.param_bytes <= cap, f"{name}: part over capacity"
        # boundaries are exactly the cut edges' weights
        assert r.boundaries == tuple(
            g.edge_bytes(p.stop - 1) for p in parts[:-1]
        )
        assert r.max_cut_bytes == max(r.boundaries, default=0)

    prop()


@pytest.mark.parametrize("name", ALL_PARTITIONERS)
def test_max_parts_budget_respected(name):
    fn = get_strategy("partitioner", name).fn

    @settings(max_examples=40, deadline=None)
    @given(sizes=SIZES, cap=st.integers(10, 300), budget=st.integers(1, 6))
    def prop(sizes, cap, budget):
        g = _graph(sizes)
        r = fn(g, cap, max_parts=budget)
        if r.feasible:
            assert r.n_parts <= budget, f"{name}: exceeded max_parts"

    prop()


@settings(max_examples=80, deadline=None)
@given(sizes=SIZES, cap=st.integers(10, 300))
def test_exact_k_min_max_never_worse_than_uniform(sizes, cap):
    """The exact-k DP is optimal among k-part partitions, so at uniform's
    own k it must meet or beat the equal-layer-count baseline's max cut."""
    g = _graph(sizes)
    uni = partition_uniform(g, cap)
    if not uni.feasible:
        return
    opt = partition_exact_k(g, cap, uni.n_parts)
    assert opt.feasible  # uniform exhibits a feasible k-part witness
    assert opt.max_cut_bytes <= uni.max_cut_bytes


@settings(max_examples=60, deadline=None)
@given(sizes=SIZES, cap=st.integers(10, 300))
def test_min_bottleneck_lower_bounds_every_strategy(sizes, cap):
    """min_bottleneck is the exact min-max optimum over ALL part counts:
    no registered strategy may report a smaller max cut."""
    g = _graph(sizes)
    best = partition_min_bottleneck(g, cap)
    for name in ALL_PARTITIONERS:
        r = get_strategy("partitioner", name).fn(g, cap)
        if r.feasible:
            assert best.feasible, f"{name} feasible but exact solver is not"
            assert best.max_cut_bytes <= r.max_cut_bytes, name


@settings(max_examples=40, deadline=None)
@given(sizes=SIZES, cap=st.integers(10, 300))
def test_infeasibility_agrees_on_oversized_layers(sizes, cap):
    """A single layer over capacity defeats every contiguous partitioner."""
    g = _graph(sizes)
    if all(l.param_bytes <= cap for l in g.layers):
        return
    for name in ALL_PARTITIONERS:
        assert not get_strategy("partitioner", name).fn(g, cap).feasible, name
