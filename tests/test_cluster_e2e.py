"""Cluster-layer integration: the full SEIFER lifecycle, in-process.

init -> leader election -> bandwidth probe -> partition+place -> deploy ->
inference -> node failure -> recovery -> inference again -> model-version
update -> redeploy.  The executor is a real jnp MLP so outputs are checked
end-to-end, not just orchestration state."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import ArtifactStore, Dispatcher, EdgeCluster, ModelWatcher
from repro.core.graph import chain
from repro.core.placement import CommGraph
from repro.core.simulate import random_cluster


def _mlp_setup(n_layers=8, d=16, seed=0):
    ws = np.asarray(
        jax.random.normal(jax.random.PRNGKey(seed), (n_layers, d, d)) * 0.3
    )

    def executor(start, stop, x):
        for i in range(start, stop):  # partition [start, stop) == ws rows
            x = jnp.tanh(x @ ws[i])
        return x

    # layer graph: embed-like first node + n_layers + head handled as chain
    g = chain("mlp", [(d * d * 4, 4 * d * 4)] * n_layers, in_bytes=4 * d * 4)

    def reference(x):
        for i in range(n_layers):
            x = jnp.tanh(x @ ws[i])
        return x

    return g, executor, reference


def _cluster(n_nodes=8, capacity=3 * 16 * 16 * 4, seed=3):
    comm = random_cluster(n_nodes, capacity, seed=seed)
    return EdgeCluster(comm, flops_per_s=1e9)


def test_full_lifecycle_with_failure():
    g, executor, reference = _mlp_setup()
    cluster = _cluster()
    store = ArtifactStore_tmp()
    disp = Dispatcher(cluster, store, seed=0)

    leader = disp.elect_leader()
    assert leader == 0
    probed = disp.probe_bandwidths()
    assert probed.bw.shape == cluster.comm.bw.shape

    plan = disp.configure(g, version=0, capacity=3 * 16 * 16 * 4)
    assert plan.feasible
    assert plan.partition.n_parts >= 2  # model does not fit one node
    pipe = disp.deploy(plan, executor)

    x = jnp.ones((4, 16)) * 0.2
    y0, trace = pipe.run(x)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(reference(x)), rtol=1e-6)
    assert trace.bottleneck_s > 0

    # --- kill a node hosting a partition ---
    victim = pipe.pods[1].node_id
    cluster.fail(victim)
    pipe.mark_node_failed(victim)
    assert not pipe.healthy()
    with pytest.raises(RuntimeError):
        pipe.run(x)

    pipe = disp.recover(pipe, g, version=0)
    assert pipe.healthy()
    assert victim not in pipe.path()
    y1, _ = pipe.run(x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), rtol=1e-6)
    assert any(p.restarts > 0 for p in pipe.pods)


def test_compression_reduces_bottleneck():
    g, executor, _ = _mlp_setup()
    cluster = _cluster()
    disp = Dispatcher(cluster, ArtifactStore_tmp(), seed=1)
    plan = disp.configure(g, version=0, capacity=3 * 16 * 16 * 4)
    plain = disp.deploy(plan, executor)
    comp = disp.deploy(plan, executor, compression_ratio=2.0)
    x = jnp.ones((4, 16))
    _, t0 = plain.run(x)
    _, t1 = comp.run(x)
    assert t1.bottleneck_s == pytest.approx(t0.bottleneck_s / 2.0)


def test_model_watch_redeploys():
    g, executor, reference = _mlp_setup()
    cluster = _cluster()
    store = ArtifactStore_tmp()
    disp = Dispatcher(cluster, store, seed=2)
    plan = disp.configure(g, version=0, capacity=3 * 16 * 16 * 4)
    pipe = disp.deploy(plan, executor)
    store.publish(0)

    watcher = ModelWatcher(store, disp, graph_for_version=lambda v: g)
    same = watcher.poll(pipe, executor)
    assert same is pipe  # no new version -> untouched

    store.publish(1)  # external repo pushes a new model version
    new_pipe = watcher.poll(pipe, executor)
    assert new_pipe is not pipe
    assert all(not p.alive for p in pipe.pods)  # old pods stopped
    y, _ = new_pipe.run(jnp.ones((2, 16)))
    assert y.shape == (2, 16)


def test_leader_reelection_on_leader_death():
    g, executor, _ = _mlp_setup()
    cluster = _cluster()
    disp = Dispatcher(cluster, ArtifactStore_tmp(), seed=4)
    plan = disp.configure(g, version=0, capacity=3 * 16 * 16 * 4)
    pipe = disp.deploy(plan, executor)
    cluster.fail(0)  # dispatcher node dies
    pipe.mark_node_failed(0)
    disp.recover(pipe, g, version=0)
    assert disp.leader != 0
    assert disp.leader in cluster.healthy_ids()


def ArtifactStore_tmp():
    import tempfile

    return ArtifactStore(tempfile.mkdtemp(prefix="seifer-store-"))
