"""Sharding-rule unit tests on a stubbed (16, 16) production mesh.

The rules only read axis names/sizes, so a stub mesh exercises the exact
divisibility logic the 256-chip dry run uses, without faking 256 devices."""

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS
from repro.sharding import policy as pol


class StubMesh:
    def __init__(self, shape=(16, 16), axes=("data", "model")):
        self.axis_names = axes
        self.devices = np.empty(shape)


MESH = StubMesh()
MESH3 = StubMesh((2, 16, 16), ("pod", "data", "model"))


def _spec(cfg, path, shape, mesh=MESH):
    return pol._mk_rules(cfg, mesh).spec(cfg, path, shape)


def test_attention_heads_shard_when_divisible():
    cfg = ARCHS["llama3.2-1b"]  # 32 heads / 16 = 2
    s = _spec(cfg, "blocks/attn/wq", (16, 2048, 32, 64))
    assert s == P(None, None, "model", None)


def test_qwen_padded_heads_shard():
    cfg = ARCHS["qwen2-7b"]  # 28 -> padded 32
    assert cfg.padded_heads == 32
    s = _spec(cfg, "blocks/attn/wq", (28, 3584, 32, 128))
    assert s == P(None, None, "model", None)


def test_indivisible_heads_fall_to_head_dim():
    cfg = ARCHS["llama3.2-1b"]  # kv heads 8: not divisible by 16
    s = _spec(cfg, "blocks/attn/wk", (16, 2048, 8, 64))
    assert s == P(None, None, None, "model")  # hd = 64 = 16*4


def test_moe_experts_shard_over_model():
    cfg = ARCHS["kimi-k2-1t-a32b"]  # 384 experts, fsdp_full
    s = _spec(cfg, "blocks/mlp/w_gate", (61, 384, 7168, 2048))
    assert s[1] == "model" and s[2] == "data"  # E over model, d over data
    s3 = _spec(cfg, "blocks/mlp/w_gate", (61, 384, 7168, 2048), MESH3)
    assert s3[3] == "pod"  # f over pod on the multi-pod mesh


def test_embed_vocab_shards():
    cfg = ARCHS["gemma-2b"]  # vocab 256000 % 16 == 0
    s = _spec(cfg, "embed", (256000, 2048))
    assert s[0] == "model"


def test_cache_split_kv_when_heads_indivisible():
    cfg = ARCHS["llama3.2-1b"]
    s = pol._cache_spec(cfg, MESH, "blocks/kv/k", (16, 128, 32768, 8, 64), P("data"))
    assert s == P(None, "data", "model", None, None)  # seq over model


def test_cache_heads_shard_when_divisible():
    cfg = ARCHS["gemma2-27b"]  # 16 kv heads
    s = pol._cache_spec(cfg, MESH, "blocks/global/k", (23, 128, 32768, 16, 128), P("data"))
    assert s == P(None, "data", None, "model", None)


def test_cache_batch_one_uses_data_axis_for_seq():
    cfg = ARCHS["gemma2-27b"]
    s = pol._cache_spec(cfg, MESH, "blocks/global/k", (23, 1, 524288, 16, 128), P(None))
    assert s == P(None, None, "data", "model", None)  # SPerf D1


def test_single_device_mesh_replicates_everything():
    cfg = ARCHS["qwen2-7b"]
    one = StubMesh((1, 1))
    s = _spec(cfg, "blocks/attn/wq", (28, 3584, 32, 128), one)
    assert all(ax in (None, "model", "data") for ax in s)
    # axis size 1 divides everything; NamedSharding on 1 device is trivial
