"""Chaos scenarios: randomized churn interleavings against a live deployment.

Drives a ``ControlPlane`` (through the ``deploy()`` facade and the pipelined
engine) with randomized sequences of NodeFailed / NodeJoined / VersionBumped
/ LinkDegraded fired *while serving*, and asserts the control plane's
contract:

  * **convergence** -- after the stream drains, observed == desired: the
    deployed version matches the desired version, the path uses only
    distinct healthy nodes, and the pipeline is healthy with a finite
    bottleneck;
  * **generation monotonicity** -- the full-restart counter never goes
    backwards, and only NodeJoined restarts advance it;
  * **liveness** -- every submitted request eventually completes (none
    lost, none duplicated, none failed).

The seed matrix is CI-controllable: ``SEIFER_CHAOS_SEEDS=3,4`` runs seeds
3 and 4 (tier-2 fans the matrix out across jobs); the default is 0,1,2.
"""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from _router_helpers import assert_router_conserved

from repro.api import ClusterSpec, DeploymentSpec, deploy
from repro.cluster import LinkDegraded, NodeFailed, NodeJoined
from repro.core.model_zoo import demo_mlp

SEEDS = [int(s) for s in os.environ.get("SEIFER_CHAOS_SEEDS", "0,1,2").split(",")]

D = 16
N_NODES = 8
N_REQUESTS = 60
MAX_EVENTS = 12


def _deployment(seed):
    graph, executor_for_version = demo_mlp(d=D)
    spec = DeploymentSpec(
        model=graph,
        executor_for_version=executor_for_version,
        cluster=ClusterSpec(
            n_nodes=N_NODES, capacity_bytes=graph.total_param_bytes / 3,
            seed=seed + 3,
        ),
        seed=seed,
        microbatch=2,
    )
    return deploy(spec)


def _conserved(dep, submitted_ids):
    loop = dep.loop
    everywhere = (
        [r.req_id for r in loop.completed]
        + [r.req_id for r in loop.failed]
        + [r.req_id for r in loop.queue]
        + [r.req_id for mb in loop._inflight for r in mb.requests]
    )
    assert len(everywhere) == len(set(everywhere)), "request duplicated"
    assert sorted(everywhere) == sorted(submitted_ids), "request lost"


def _inject_random_event(dep, rng, state):
    """Fire one random disturbance; returns its label (or None if skipped)."""
    cluster = dep.cluster
    pods = dep.control.pipeline.pods
    hosting = sum(1 for nd in cluster.nodes if nd.healthy and nd.capacity_bytes > 0)
    roll = rng.random()
    if roll < 0.30:
        # keep enough healthy hosting nodes that recovery stays feasible
        if hosting <= len(pods) + 1:
            return None
        victim = int(pods[rng.integers(len(pods))].node_id)
        dep.inject(NodeFailed(victim))
        state["failed"].add(victim)
        return f"NodeFailed({victim})"
    if roll < 0.50:
        if state["failed"]:
            node = state["failed"].pop()
            dep.inject(NodeJoined(node_id=node))
            return f"NodeJoined(heal {node})"
        dep.grow_cluster(seed=int(rng.integers(1 << 16)))
        return "NodeJoined(grow)"
    if roll < 0.75:
        a, b = (int(x) for x in rng.choice(cluster.n, size=2, replace=False))
        factor = float(rng.uniform(0.05, 0.8))
        dep.inject(LinkDegraded(a, b, factor))
        return f"LinkDegraded({a},{b})"
    version = dep.observed().version + 1
    dep.store.publish(version)
    dep.poll_model_updates()
    return f"VersionBumped({version})"


@pytest.mark.parametrize("seed", SEEDS)
def test_chaos_converges_and_loses_nothing(seed):
    dep = _deployment(seed)
    rng = np.random.default_rng(seed * 7919 + 1)
    ids = [dep.submit(jnp.ones((D,)) * 0.1).req_id for _ in range(N_REQUESTS)]

    fired = []
    state = {"failed": set()}
    last_gen = dep.observed().generation
    restarts = 0
    steps = 0
    while dep.loop.backlog or dep.control.pending:
        steps += 1
        assert steps < 10_000, "scenario did not drain"
        if len(fired) < MAX_EVENTS and rng.random() < 0.2:
            label = _inject_random_event(dep, rng, state)
            if label:
                fired.append(label)
        dep.step()
        gen = dep.observed().generation
        assert gen >= last_gen, "generation went backwards"
        restarts += gen - last_gen
        last_gen = gen
        _conserved(dep, ids)

    assert fired, "no disturbance was injected"
    # liveness: everything completed, nothing failed
    assert len(dep.loop.completed) == N_REQUESTS
    assert not dep.loop.failed

    # convergence: observed == desired
    obs = dep.observed()
    assert obs.healthy
    assert obs.version == dep.control.desired.version
    assert np.isfinite(obs.bottleneck_latency)
    path = list(obs.path)
    assert len(path) == len(set(path)), "placement reuses a node"
    healthy = set(dep.cluster.healthy_ids())
    assert set(path) <= healthy, "a pod sits on an unhealthy node"
    # generation advanced exactly once per full-restart action
    restart_actions = sum(
        1 for a in dep.control.history if a.kind == "restart")
    assert restarts == restart_actions

    # the served math matches the FINAL version's reference on a fresh probe
    x = jnp.ones((D,)) * 0.1
    req = dep.submit(x)
    dep.drain()
    import jax

    ws = np.asarray(
        jax.random.normal(jax.random.PRNGKey(obs.version), (8, D, D)) * 0.3
    )
    ref = x
    for w in ws:
        ref = jnp.tanh(ref @ w)
    np.testing.assert_allclose(np.asarray(req.result), np.asarray(ref), rtol=1e-5)


# ---------------------------------------------------------------------------
# Replica isolation: churn in one replica must not touch the others
# ---------------------------------------------------------------------------

R_REPLICAS = 3


def _replicated_deployment(seed, *, group_size=4, replicas=R_REPLICAS,
                           microbatch=2):
    """R pipeline replicas on a symmetric cluster.

    ``capacity = 0.4 x model`` packs demo_mlp's 8 layers into 3-part
    pipelines, so a ``group_size=4`` replica keeps one spare node (in-group
    re-place possible) while ``group_size=3`` has none (a kill retires it).
    """
    graph, executor_for_version = demo_mlp(d=D)
    capacity = graph.total_param_bytes * 0.4
    n_hosting = replicas * group_size
    bw = np.full((n_hosting + 1, n_hosting + 1), 4e5)
    np.fill_diagonal(bw, 0.0)
    caps = np.full(n_hosting + 1, capacity)
    caps[0] = -1.0  # dispatcher hosts no partition
    from repro.core.placement import CommGraph

    spec = DeploymentSpec(
        model=graph,
        executor_for_version=executor_for_version,
        cluster=ClusterSpec(comm=CommGraph(bw=bw, node_capacity=caps)),
        capacity=capacity,
        seed=seed,
        microbatch=microbatch,
        replicas=replicas,
    )
    return deploy(spec)


def _window_rate(reqs, lo, hi):
    """Completions/s inside (lo, hi], from the MEDIAN positive
    inter-completion gap -- the steady cadence, robust to microbatch
    same-timestamp pairs and to idle gaps while the stream drains."""
    ts = sorted(r.completed_s for r in reqs if lo < r.completed_s <= hi)
    gaps = [b - a for a, b in zip(ts, ts[1:]) if b > a]
    if len(gaps) < 3:
        return None
    return 1.0 / float(np.median(gaps))


@pytest.mark.parametrize("seed", SEEDS)
def test_replica_isolation_node_kill_touches_only_its_replica(seed):
    """Kill one replica's node mid-serve: the touched replica re-places
    inside its own group; the survivors' pipelines, timings, and measured
    cadence are bit-for-bit untouched."""
    dep = _replicated_deployment(seed)
    rset = dep.replicaset
    n = 90
    ids = [dep.submit(jnp.ones((D,)) * 0.1).req_id for _ in range(n)]
    while len(dep.loop.completed) < n // 3:
        dep.step()

    victim_replica = 0
    victim = rset.controls[victim_replica].pipeline.pods[1].node_id
    survivors = [r for r in range(rset.n_replicas) if r != victim_replica]
    pre_pipes = [dep.loop.loops[r]._bound_pipeline for r in survivors]
    pre_link_s = [list(dep.loop.loops[r]._link_s) for r in survivors]
    kill_clock = {r: dep.loop.loops[r].clock_s for r in survivors}
    dep.inject(NodeFailed(victim))

    while dep.loop.backlog or dep.pending:
        dep.step()
        assert_router_conserved(dep, ids)
    assert len(dep.loop.completed) == n and not dep.loop.failed

    # the touched replica recovered inside its own group, and ONLY its
    # resident microbatches were requeued
    assert not rset.retired[victim_replica]
    obs = rset.controls[victim_replica].observed()
    assert obs.healthy and victim not in obs.path
    assert set(obs.path) <= rset.groups[victim_replica]
    # the re-solve itself was scoped to the failure neighborhood inside the
    # victim's group -- not a full-cluster solve that happened to land there
    rec = rset.recovery_log()[victim_replica]
    assert rec is not None and rec["scoped"], rec
    assert rec["scope_size"] <= len(rset.groups[victim_replica])
    for r in survivors:
        assert rset.recovery_log()[r] is None, "a survivor ran a recovery"
    for i, r in enumerate(survivors):
        loop = dep.loop.loops[r]
        assert loop._requeues == 0, "a survivor requeued microbatches"
        assert loop._bound_pipeline is pre_pipes[i], "a survivor was rebound"
        assert list(loop._link_s) == pre_link_s[i], "survivor timings changed"
        assert all(a.kind == "noop" for a in rset.controls[r].history)
    # every retried request belongs to the victim replica
    for req in dep.loop.completed:
        if req.attempts > 0:
            assert req.replica == victim_replica

    # survivors' measured cadence is unchanged across the kill (within 5%)
    for r in survivors:
        reqs = dep.loop.loops[r].completed
        pre = _window_rate(reqs, 0.0, kill_clock[r])
        post = _window_rate(reqs, kill_clock[r], float("inf"))
        if pre is not None and post is not None:
            assert post == pytest.approx(pre, rel=0.05)


@pytest.mark.parametrize("seed", SEEDS)
def test_replica_retirement_redistributes_to_survivors(seed):
    """With no spare node in the group, a kill retires the replica: its
    resident requests are reclaimed and completed by the survivors, which
    themselves stay untouched."""
    dep = _replicated_deployment(seed, group_size=3)
    rset = dep.replicaset
    n = 60
    ids = [dep.submit(jnp.ones((D,)) * 0.1).req_id for _ in range(n)]
    while len(dep.loop.completed) < n // 4:
        dep.step()
    victim = rset.controls[0].pipeline.pods[1].node_id
    dep.inject(NodeFailed(victim))
    while dep.loop.backlog or dep.pending:
        dep.step()
        assert_router_conserved(dep, ids)
    assert rset.retired[0]
    assert len(dep.loop.completed) == n and not dep.loop.failed
    dispatched_at_retirement = dep.loop.dispatched[0]
    # redistributed requests finished on a survivor with a charged attempt
    moved = [r for r in dep.loop.completed if r.attempts > 0]
    assert moved and all(r.replica in (1, 2) for r in moved)
    for r in (1, 2):
        assert dep.loop.loops[r]._requeues == 0
        assert all(a.kind == "noop" for a in rset.controls[r].history)
    # the router never dispatched to the corpse again
    assert dep.loop.dispatched[0] == dispatched_at_retirement


@pytest.mark.parametrize("seed", SEEDS)
def test_replica_rolling_version_bump_keeps_serving(seed):
    """A version bump rolls the replicas one at a time: versions advance
    monotonically one replica per transition, and aggregate serving never
    stops (completions strictly increase across every transition)."""
    dep = _replicated_deployment(seed)
    rset = dep.replicaset
    n = 90
    ids = [dep.submit(jnp.ones((D,)) * 0.1).req_id for _ in range(n)]
    while len(dep.loop.completed) < n // 4:
        dep.step()
    dep.store.publish(1)
    assert dep.poll_model_updates()
    transitions = []  # (version tuple, completions at the moment of change)
    last = tuple(c.desired.version for c in rset.controls)
    while dep.loop.backlog or dep.pending:
        dep.step()
        assert_router_conserved(dep, ids)
        now = tuple(c.desired.version for c in rset.controls)
        if now != last:
            changed = sum(a != b for a, b in zip(now, last))
            assert changed == 1, "two replicas bumped in one step"
            transitions.append((now, len(dep.loop.completed)))
            last = now
    assert last == (1,) * rset.n_replicas
    assert len(transitions) == rset.n_replicas
    # zero-downtime: the set kept completing requests between transitions
    counts = [c for _, c in transitions]
    assert all(b > a for a, b in zip(counts, counts[1:])), counts
    assert len(dep.loop.completed) == n and not dep.loop.failed

    # post-roll requests carry v1 math
    import jax

    req = dep.submit(jnp.ones((D,)) * 0.1)
    dep.drain()
    ws = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (8, D, D)) * 0.3)
    ref = jnp.ones((D,)) * 0.1
    for w in ws:
        ref = jnp.tanh(ref @ w)
    np.testing.assert_allclose(np.asarray(req.result), np.asarray(ref), rtol=1e-5)


@pytest.mark.parametrize("seed", SEEDS)
def test_chaos_event_burst_between_quiet_phases(seed):
    """A quiet phase, then a burst of back-to-back events reconciled in one
    go, then another quiet phase: the control plane applies the whole batch
    and still converges."""
    dep = _deployment(seed + 100)
    rng = np.random.default_rng(seed * 104729 + 7)
    ids = [dep.submit(jnp.ones((D,)) * 0.1).req_id for _ in range(20)]
    while dep.loop.backlog:
        dep.step()

    state = {"failed": set()}
    burst = [lbl for _ in range(5)
             if (lbl := _inject_random_event(dep, rng, state))]
    assert dep.control.pending == len(
        [b for b in burst if not b.startswith("VersionBumped")]
    ) + sum(b.startswith("VersionBumped") for b in burst)

    ids += [dep.submit(jnp.ones((D,)) * 0.1).req_id for _ in range(20)]
    while dep.loop.backlog or dep.control.pending:
        dep.step()
        _conserved(dep, ids)
    assert len(dep.loop.completed) == 40 and not dep.loop.failed
    obs = dep.observed()
    assert obs.healthy and obs.version == dep.control.desired.version


@pytest.mark.parametrize("seed", SEEDS)
def test_node_fail_recovery_is_scoped_to_failure_neighborhood(seed):
    """A ``NodeFailed`` re-solve runs on the failure neighborhood (surviving
    path + best-connected spares), not the whole cluster: the recovery
    record says so, the action log says so, and the replacement path stays
    inside the recorded scope."""
    dep = _deployment(seed)
    control = dep.control
    assert control.scoped_recovery  # the default
    victim = int(control.pipeline.pods[1].node_id)
    pre_path = list(control.pipeline.path())
    dep.inject(NodeFailed(victim))
    while dep.pending:
        dep.step()
    rec = control.dispatcher.last_recovery
    assert rec is not None and rec["scoped"], rec
    assert rec["fallback"] == "none"
    # neighborhood = surviving path + max(4, k) spares, strictly < cluster
    surviving = [p for p in pre_path if p != victim]
    width = max(4, len(pre_path))
    assert rec["scope_size"] <= len(surviving) + width
    assert rec["scope_size"] < control.cluster.n
    action = next(a for a in control.history
                  if a.event is not None and isinstance(a.event, NodeFailed))
    assert "scoped to" in action.detail, action.detail
    # the deployed path honors the scope: every node is in the neighborhood
    scope = set(control._failure_neighborhood(victim))
    obs = control.observed()
    assert obs.healthy and victim not in obs.path
    assert set(obs.path) <= scope | {control.dispatcher.leader}


@pytest.mark.parametrize("seed", SEEDS)
def test_scoped_recovery_falls_back_to_full_solve_when_infeasible(seed):
    """With ``recovery_width=0`` the neighborhood is just the surviving path
    -- too few nodes to host k partitions -- so the scoped solve must fall
    back to the full graph and still converge."""
    dep = _deployment(seed)
    control = dep.control
    control.recovery_width = 0
    victim = int(control.pipeline.pods[1].node_id)
    dep.inject(NodeFailed(victim))
    while dep.pending:
        dep.step()
    rec = control.dispatcher.last_recovery
    assert rec is not None and not rec["scoped"], rec
    assert rec["fallback"] in ("full", "reconfigure")
    obs = control.observed()
    assert obs.healthy and victim not in obs.path


# ---------------------------------------------------------------------------
# Saturation: open-loop overload + node kill (load shedding + autoscaling)
# ---------------------------------------------------------------------------

SAT_HOSTING = 8
SAT_CAPACITY = 1.05e6  # 2 layers/node -> 4-stage pipelines, 2 feasible splits
SAT_ADMISSION = 32


def _saturation_deployment(seed):
    """Autoscaled open-loop deployment on a synthetic symmetric cluster
    (passthrough math: saturation behavior is a pure timing-model property)."""
    from repro.api import ArrivalSpec, AutoscaleSpec
    from repro.core.graph import Layer, LayerGraph
    from repro.core.placement import CommGraph

    layers = tuple(
        Layer(f"l{i}", param_bytes=500_000, out_bytes=100_000, flops=5_000_000)
        for i in range(8)
    )
    graph = LayerGraph("synth8", layers, in_bytes=50_000)
    bw = np.full((SAT_HOSTING + 1, SAT_HOSTING + 1), 20e6)
    np.fill_diagonal(bw, 0.0)
    caps = np.full(SAT_HOSTING + 1, SAT_CAPACITY)
    caps[0] = -1.0

    def spec(**kw):
        return DeploymentSpec(
            model=graph, cluster=ClusterSpec(comm=CommGraph(bw=bw, node_capacity=caps)),
            capacity=SAT_CAPACITY, seed=seed, microbatch=1, max_batch=8,
            admission_depth=SAT_ADMISSION, **kw)

    # calibrate: closed-loop saturation throughput of one pipeline
    probe = deploy(spec())
    for _ in range(40):
        probe.submit(jnp.ones((4,)))
    probe.drain()
    capacity = 40 / probe.loop.clock_s

    dep = deploy(spec(
        arrival=ArrivalSpec(trace="bursty", rate=3.0 * capacity,
                            duration_s=1.0, seed=seed),
        autoscale=AutoscaleSpec(min_replicas=1, backlog_high=6.0,
                                backlog_low=1.0, cooldown_s=0.05)))
    return dep, capacity


@pytest.mark.parametrize("seed", SEEDS)
def test_saturation_kill_under_overload(seed):
    """Kill a serving node while the cluster is past saturation: the
    overflow is rejected (never silently lost), the tail stays bounded by
    the admission queue, and completions keep strictly increasing through
    the kill and every scale event."""
    dep, capacity = _saturation_deployment(seed)
    reqs = dep.submit_trace(make_input=lambda i, a: jnp.ones((4,)))
    ids = [r.req_id for r in reqs]

    killed = False
    progress = [0]
    steps = 0
    while dep.loop.backlog or dep.loop.pending_arrivals or dep.pending:
        steps += 1
        assert steps < 50_000, "saturation scenario did not drain"
        if not killed and len(dep.loop.completed) >= len(reqs) // 4:
            live = dep.replicaset.live_indices()
            victim = sorted(dep.replicaset.groups[live[0]])[0]
            dep.inject(NodeFailed(victim))
            killed = True
        progressed = bool(dep.step()) or dep.pending
        if steps % 40 == 0:
            progress.append(len(dep.loop.completed))
            assert_router_conserved(dep, ids)
        if (not progressed and not dep.loop.pending_arrivals
                and not dep.loop.backlog):
            break
    progress.append(len(dep.loop.completed))

    m = dep.metrics()["serving"]
    # conservation: admitted = completed + failed + rejected, none lost
    assert m["completed"] + m["failed"] + m["rejected"] == len(reqs)
    assert_router_conserved(dep, ids)
    # the overload was shed, not queued without bound or dropped silently
    assert m["rejected"] > 0, "3x overload must trigger load shedding"
    # tail bounded by the admission queue, not by the trace length
    p99 = m["latency"]["overall"]["p99_s"]
    assert p99 <= 4.0 * SAT_ADMISSION / capacity, (p99, capacity)
    # serving never stalled: completions strictly increase across windows
    assert killed
    deltas = [b - a for a, b in zip(progress, progress[1:])]
    assert all(d > 0 for d in deltas), progress
    # the kill was absorbed: the set still has live replicas and the
    # autoscaler record explains every capacity move
    assert dep.replicaset.live_indices()
    assert m["autoscaler"]["grows"] >= 1


# ---------------------------------------------------------------------------
# Tenant isolation: churn on one tenant's slice must not touch the others
# ---------------------------------------------------------------------------

TEN_HOSTING = 12
TEN_CAPACITY = 1.05e6  # alpha: 2 layers/node (4 stages), beta: 1/node (6)


def _tenant_deployment(seed, *, policy="partition", explicit_fractions=True):
    """Two heterogeneous synthetic tenants on one symmetric shared cluster
    (passthrough math: isolation is a pure control/timing-model property)."""
    from repro.api import TenantSpec
    from repro.core.graph import Layer, LayerGraph
    from repro.core.placement import CommGraph

    def graph(name, n_layers, param_bytes):
        layers = tuple(
            Layer(f"{name}{i}", param_bytes=param_bytes, out_bytes=100_000,
                  flops=5_000_000)
            for i in range(n_layers)
        )
        return LayerGraph(name, layers, in_bytes=50_000)

    bw = np.full((TEN_HOSTING + 1, TEN_HOSTING + 1), 20e6)
    np.fill_diagonal(bw, 0.0)
    caps = np.full(TEN_HOSTING + 1, TEN_CAPACITY)
    caps[0] = -1.0  # dispatcher hosts no partition
    comm = CommGraph(bw=bw, node_capacity=caps)

    def spec(g):
        return DeploymentSpec(
            model=g, cluster=ClusterSpec(comm=comm), capacity=TEN_CAPACITY,
            seed=seed, microbatch=1)

    frac = 0.5 if explicit_fractions else None
    return deploy([
        TenantSpec("alpha", spec(graph("a", 8, 500_000)),
                   capacity_fraction=frac),
        TenantSpec("beta", spec(graph("b", 6, 700_000)),
                   capacity_fraction=frac),
    ], policy=policy)


def _loop_conserved(loop, submitted_ids):
    everywhere = (
        [r.req_id for r in loop.completed]
        + [r.req_id for r in loop.failed]
        + [r.req_id for r in loop.queue]
        + [r.req_id for mb in loop._inflight for r in mb.requests]
    )
    assert len(everywhere) == len(set(everywhere)), "request duplicated"
    assert sorted(everywhere) == sorted(submitted_ids), "request lost"


@pytest.mark.parametrize("seed", SEEDS)
def test_tenant_isolation_churn_on_one_slice(seed):
    """Randomized fail/heal churn confined to tenant alpha's slice: beta's
    pipeline identity, link timings, and completion cadence are untouched,
    and per-tenant request conservation holds throughout."""
    d = _tenant_deployment(seed)
    rng = np.random.default_rng(seed * 6151 + 11)
    n = 48
    ids = {name: [d.submit(name, i).req_id for i in range(n)]
           for name in ("alpha", "beta")}

    beta = d.router.loop("beta")
    pre_pipe = beta._bound_pipeline
    pre_link = list(beta._link_s)
    alpha_nodes = set(d.nodes_for("alpha"))

    fired = []
    failed = set()
    churn_clock = None
    steps = 0
    while d.router.backlog:
        steps += 1
        assert steps < 20_000, "tenant scenario did not drain"
        if len(fired) < 6 and rng.random() < 0.15:
            if failed and rng.random() < 0.5:
                node = failed.pop()
                d.inject(NodeJoined(node_id=node))
                fired.append(f"heal {node}")
            else:
                full_path = d.deployment("alpha").observed().path
                path = [p for p in full_path if p not in failed]
                # events reconcile lazily (FIFO, each against the state the
                # previous one left), so bound concurrent failures by the
                # healthy-node count against the FULL stage count -- the
                # filtered path understates how many survivors the pipeline
                # needs when the observed path is stale
                if path and len(alpha_nodes - failed) - 1 >= len(full_path):
                    victim = int(path[int(rng.integers(len(path)))])
                    d.inject(NodeFailed(victim))
                    failed.add(victim)
                    fired.append(f"fail {victim}")
            if fired and churn_clock is None:
                churn_clock = beta.clock_s
        d.step()
        for name in ("alpha", "beta"):
            _loop_conserved(d.router.loop(name), ids[name])
    d.reconcile()
    assert fired, "no churn was injected on alpha's slice"

    # every event was routed to alpha alone -- beta never heard a thing
    assert {t for t, _ in d.controlplane.routed} == {"alpha"}
    assert beta._requeues == 0
    assert beta._bound_pipeline is pre_pipe, "beta was rebound"
    assert list(beta._link_s) == pre_link, "beta timings changed"
    assert d.deployment("beta").control.history == []

    # both tenants completed everything; alpha stayed inside its slice
    for name in ("alpha", "beta"):
        loop = d.router.loop(name)
        assert len(loop.completed) == n and not loop.failed
    obs_a = d.deployment("alpha").observed()
    assert obs_a.healthy and set(obs_a.path) <= alpha_nodes

    # beta's measured cadence is unchanged across the churn (within 5%)
    pre = _window_rate(beta.completed, 0.0, churn_clock)
    post = _window_rate(beta.completed, churn_clock, float("inf"))
    if pre is not None and post is not None:
        assert post == pytest.approx(pre, rel=0.05)


@pytest.mark.parametrize("seed", SEEDS)
def test_tenant_shared_policy_churn_reaches_every_cohost(seed):
    """Under the ``shared`` policy tenants co-reside on the same nodes, so a
    node kill must reach EVERY tenant hosting it -- both re-plan, and
    per-tenant conservation still holds through the disturbance."""
    d = _tenant_deployment(seed, policy="shared", explicit_fractions=False)
    n = 32
    ids = {name: [d.submit(name, i).req_id for i in range(n)]
           for name in ("alpha", "beta")}
    while len(d.completed()) < n // 2:
        d.step()

    victim = int(d.deployment("alpha").observed().path[0])
    d.inject(NodeFailed(victim))
    steps = 0
    while d.router.backlog or d.pending:
        steps += 1
        assert steps < 20_000, "shared scenario did not drain"
        if not d.step() and d.pending:
            d.reconcile()
        for name in ("alpha", "beta"):
            _loop_conserved(d.router.loop(name), ids[name])

    # the event fanned out to every co-hosting tenant
    assert set(d.controlplane.routed) >= {
        ("alpha", "NodeFailed"), ("beta", "NodeFailed")}
    for name in ("alpha", "beta"):
        loop = d.router.loop(name)
        assert len(loop.completed) == n and not loop.failed
        obs = d.deployment(name).observed()
        assert obs.healthy and victim not in obs.path
        assert d.deployment(name).control.history, (
            f"tenant {name} never reconciled the shared-node kill")
