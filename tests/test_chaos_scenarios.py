"""Chaos scenarios: randomized churn interleavings against a live deployment.

Drives a ``ControlPlane`` (through the ``deploy()`` facade and the pipelined
engine) with randomized sequences of NodeFailed / NodeJoined / VersionBumped
/ LinkDegraded fired *while serving*, and asserts the control plane's
contract:

  * **convergence** -- after the stream drains, observed == desired: the
    deployed version matches the desired version, the path uses only
    distinct healthy nodes, and the pipeline is healthy with a finite
    bottleneck;
  * **generation monotonicity** -- the full-restart counter never goes
    backwards, and only NodeJoined restarts advance it;
  * **liveness** -- every submitted request eventually completes (none
    lost, none duplicated, none failed).

The seed matrix is CI-controllable: ``SEIFER_CHAOS_SEEDS=3,4`` runs seeds
3 and 4 (tier-2 fans the matrix out across jobs); the default is 0,1,2.
"""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ClusterSpec, DeploymentSpec, deploy
from repro.cluster import LinkDegraded, NodeFailed, NodeJoined
from repro.core.model_zoo import demo_mlp

SEEDS = [int(s) for s in os.environ.get("SEIFER_CHAOS_SEEDS", "0,1,2").split(",")]

D = 16
N_NODES = 8
N_REQUESTS = 60
MAX_EVENTS = 12


def _deployment(seed):
    graph, executor_for_version = demo_mlp(d=D)
    spec = DeploymentSpec(
        model=graph,
        executor_for_version=executor_for_version,
        cluster=ClusterSpec(
            n_nodes=N_NODES, capacity_bytes=graph.total_param_bytes / 3,
            seed=seed + 3,
        ),
        seed=seed,
        microbatch=2,
    )
    return deploy(spec)


def _conserved(dep, submitted_ids):
    loop = dep.loop
    everywhere = (
        [r.req_id for r in loop.completed]
        + [r.req_id for r in loop.failed]
        + [r.req_id for r in loop.queue]
        + [r.req_id for mb in loop._inflight for r in mb.requests]
    )
    assert len(everywhere) == len(set(everywhere)), "request duplicated"
    assert sorted(everywhere) == sorted(submitted_ids), "request lost"


def _inject_random_event(dep, rng, state):
    """Fire one random disturbance; returns its label (or None if skipped)."""
    cluster = dep.cluster
    pods = dep.control.pipeline.pods
    hosting = sum(1 for nd in cluster.nodes if nd.healthy and nd.capacity_bytes > 0)
    roll = rng.random()
    if roll < 0.30:
        # keep enough healthy hosting nodes that recovery stays feasible
        if hosting <= len(pods) + 1:
            return None
        victim = int(pods[rng.integers(len(pods))].node_id)
        dep.inject(NodeFailed(victim))
        state["failed"].add(victim)
        return f"NodeFailed({victim})"
    if roll < 0.50:
        if state["failed"]:
            node = state["failed"].pop()
            dep.inject(NodeJoined(node_id=node))
            return f"NodeJoined(heal {node})"
        dep.grow_cluster(seed=int(rng.integers(1 << 16)))
        return "NodeJoined(grow)"
    if roll < 0.75:
        a, b = (int(x) for x in rng.choice(cluster.n, size=2, replace=False))
        factor = float(rng.uniform(0.05, 0.8))
        dep.inject(LinkDegraded(a, b, factor))
        return f"LinkDegraded({a},{b})"
    version = dep.observed().version + 1
    dep.store.publish(version)
    dep.poll_model_updates()
    return f"VersionBumped({version})"


@pytest.mark.parametrize("seed", SEEDS)
def test_chaos_converges_and_loses_nothing(seed):
    dep = _deployment(seed)
    rng = np.random.default_rng(seed * 7919 + 1)
    ids = [dep.submit(jnp.ones((D,)) * 0.1).req_id for _ in range(N_REQUESTS)]

    fired = []
    state = {"failed": set()}
    last_gen = dep.observed().generation
    restarts = 0
    steps = 0
    while dep.loop.backlog or dep.control.pending:
        steps += 1
        assert steps < 10_000, "scenario did not drain"
        if len(fired) < MAX_EVENTS and rng.random() < 0.2:
            label = _inject_random_event(dep, rng, state)
            if label:
                fired.append(label)
        dep.step()
        gen = dep.observed().generation
        assert gen >= last_gen, "generation went backwards"
        restarts += gen - last_gen
        last_gen = gen
        _conserved(dep, ids)

    assert fired, "no disturbance was injected"
    # liveness: everything completed, nothing failed
    assert len(dep.loop.completed) == N_REQUESTS
    assert not dep.loop.failed

    # convergence: observed == desired
    obs = dep.observed()
    assert obs.healthy
    assert obs.version == dep.control.desired.version
    assert np.isfinite(obs.bottleneck_latency)
    path = list(obs.path)
    assert len(path) == len(set(path)), "placement reuses a node"
    healthy = set(dep.cluster.healthy_ids())
    assert set(path) <= healthy, "a pod sits on an unhealthy node"
    # generation advanced exactly once per full-restart action
    restart_actions = sum(
        1 for a in dep.control.history if a.kind == "restart")
    assert restarts == restart_actions

    # the served math matches the FINAL version's reference on a fresh probe
    x = jnp.ones((D,)) * 0.1
    req = dep.submit(x)
    dep.drain()
    import jax

    ws = np.asarray(
        jax.random.normal(jax.random.PRNGKey(obs.version), (8, D, D)) * 0.3
    )
    ref = x
    for w in ws:
        ref = jnp.tanh(ref @ w)
    np.testing.assert_allclose(np.asarray(req.result), np.asarray(ref), rtol=1e-5)


@pytest.mark.parametrize("seed", SEEDS)
def test_chaos_event_burst_between_quiet_phases(seed):
    """A quiet phase, then a burst of back-to-back events reconciled in one
    go, then another quiet phase: the control plane applies the whole batch
    and still converges."""
    dep = _deployment(seed + 100)
    rng = np.random.default_rng(seed * 104729 + 7)
    ids = [dep.submit(jnp.ones((D,)) * 0.1).req_id for _ in range(20)]
    while dep.loop.backlog:
        dep.step()

    state = {"failed": set()}
    burst = [lbl for _ in range(5)
             if (lbl := _inject_random_event(dep, rng, state))]
    assert dep.control.pending == len(
        [b for b in burst if not b.startswith("VersionBumped")]
    ) + sum(b.startswith("VersionBumped") for b in burst)

    ids += [dep.submit(jnp.ones((D,)) * 0.1).req_id for _ in range(20)]
    while dep.loop.backlog or dep.control.pending:
        dep.step()
        _conserved(dep, ids)
    assert len(dep.loop.completed) == 40 and not dep.loop.failed
    obs = dep.observed()
    assert obs.healthy and obs.version == dep.control.desired.version
