"""Tests for the unified observability plane (``repro.obs``).

Covers the four components end to end on real deployments:

  * span tracer -- timelines tile each completed request's life exactly,
    sampling is deterministic, disabled tracing leaves no trace surface,
    churn (node kills mid-serve) never produces malformed timelines
    (property-tested over random kill schedules);
  * control-plane journal -- monotone stamps, recovery/reconcile records
    that agree with ``Dispatcher.last_recovery``;
  * metrics registry -- schema-valid snapshots embedded in
    ``Deployment.metrics()`` without disturbing the legacy shape;
  * critical-path analyzer -- fractions sum to one, bottleneck agreement.

Determinism is pinned hard: same-seed runs must serialize byte-identically
(timelines, Chrome traces, and journal dumps).
"""

from __future__ import annotations

import json
import math

import jax.numpy as jnp
import pytest

from repro.api import ClusterSpec, DeploymentSpec, TraceConfig, deploy
from repro.cluster import NodeFailed
from repro.cluster.autoscale import ScaleEvent
from repro.core.model_zoo import demo_mlp
from repro.obs import Journal, analyze_spans, percentile
from repro.obs.critical_path import request_attribution
from repro.obs.metrics import MetricsRegistry, validate_snapshot
from repro.obs.trace import SpanTracer, split_hop, split_window

from tests._hypothesis_compat import given, settings, st

D = 32


def _deploy(sample=1.0, seed=0, **kw):
    graph, executor_for_version = demo_mlp(d=D)
    trace = TraceConfig(sample=sample) if sample is not None else None
    return deploy(DeploymentSpec(
        model=graph,
        executor_for_version=executor_for_version,
        cluster=ClusterSpec(n_nodes=8,
                            capacity_bytes=graph.total_param_bytes / 2.5,
                            seed=seed + 3),
        seed=seed,
        trace=trace,
        **kw,
    ))


def _serve(d, n, kill_node=None, kill_after=0):
    x = jnp.ones((D,)) * 0.1
    for _ in range(n):
        d.submit(x)
    killed = kill_node is None
    for _ in range(100_000):
        if not killed and len(d.loop.completed) >= kill_after:
            d.inject(NodeFailed(kill_node))
            killed = True
        if not d.loop.backlog and not d.pending:
            break
        d.step()
    assert not d.loop.backlog and not d.pending, "serve loop did not drain"
    return d


def _assert_contiguous(spans):
    """One request's retained spans form a gapless, overlap-free chain."""
    spans = sorted(spans, key=lambda s: s.t0_s)
    for s in spans:
        assert s.t1_s > s.t0_s
    for a, b in zip(spans, spans[1:]):
        assert abs(b.t0_s - a.t1_s) <= 1e-9, (a, b)


# -- span tracer ------------------------------------------------------------

def test_spans_tile_each_completed_request_exactly():
    d = _serve(_deploy(), 12)
    assert d.loop.completed
    for req in d.loop.completed:
        spans = d.tracer.spans_for(req.req_id)
        assert spans, req.req_id
        _assert_contiguous(spans)
        first = min(s.t0_s for s in spans)
        last = max(s.t1_s for s in spans)
        assert abs(first - req.submitted_s) <= 1e-9
        assert abs(last - req.completed_s) <= 1e-9
        covered = sum(s.duration_s for s in spans)
        assert abs(covered - req.latency_s) <= 1e-9


def test_sampling_is_deterministic_and_partial():
    d1 = _serve(_deploy(sample=0.5), 32)
    d2 = _serve(_deploy(sample=0.5), 32)
    traced1 = {s.req_id for s in d1.tracer.spans}
    traced2 = {s.req_id for s in d2.tracer.spans}
    assert traced1 == traced2  # hash-based, not RNG-state-based
    assert 0 < len(traced1) < 32  # partial sampling really is partial
    for req in d1.loop.completed:
        if req.req_id not in traced1:
            assert d1.tracer.spans_for(req.req_id) == []


def test_disabled_tracing_leaves_no_surface():
    d = _serve(_deploy(sample=None), 8)
    assert d.tracer is None
    assert d.trace_timeline() == []
    assert d.chrome_trace() is None
    assert d.attribution() is None
    assert d.metrics()["observability"]["trace"] is None


def test_sync_loop_emits_tiling_spans():
    d = _serve(_deploy(serving="sync"), 8)
    for req in d.loop.completed:
        spans = d.tracer.spans_for(req.req_id)
        assert spans
        _assert_contiguous(spans)
        covered = sum(s.duration_s for s in spans)
        assert abs(covered - req.latency_s) <= 1e-8


def _replicated(sample=1.0, seed=0):
    graph, executor_for_version = demo_mlp(d=D)
    return deploy(DeploymentSpec(
        model=graph,
        executor_for_version=executor_for_version,
        cluster=ClusterSpec(n_nodes=16,
                            capacity_bytes=graph.total_param_bytes / 2.5,
                            seed=seed + 3),
        seed=seed,
        replicas=2,
        trace=TraceConfig(sample=sample),
    ))


def test_replicated_loop_attributes_spans_to_replicas():
    d = _serve(_replicated(), 16)
    replicas = {s.replica for s in d.tracer.spans}
    assert replicas and replicas <= {0, 1}
    assert len(replicas) == 2  # both replicas carried sampled requests


def test_max_spans_cap_counts_drops():
    d = _serve(_deploy(), 24)
    full = len(d.tracer.spans)
    assert full > 10
    graph, executor_for_version = demo_mlp(d=D)
    capped = deploy(DeploymentSpec(
        model=graph, executor_for_version=executor_for_version,
        cluster=ClusterSpec(n_nodes=8,
                            capacity_bytes=graph.total_param_bytes / 2.5,
                            seed=3),
        trace=TraceConfig(max_spans=10),
    ))
    _serve(capped, 24)
    assert len(capped.tracer.spans) == 10
    assert capped.tracer.dropped == full - 10
    assert capped.tracer.summary()["dropped"] == full - 10


@settings(max_examples=12, deadline=None)
@given(kill_stage=st.integers(min_value=0, max_value=7),
       kill_after=st.integers(min_value=0, max_value=10))
def test_timelines_stay_well_formed_under_random_node_kills(
        kill_stage, kill_after):
    """Property: whatever node dies whenever, every retained span timeline
    is positive-length, contiguous, and ends at the request's completion;
    journal stamps stay monotone."""
    d = _deploy()
    pods = d.control.pipeline.pods
    node = pods[kill_stage % len(pods)].node_id
    _serve(d, 12, kill_node=node, kill_after=kill_after)
    assert len(d.loop.completed) == 12
    by_req = {}
    for s in d.tracer.spans:
        by_req.setdefault(s.req_id, []).append(s)
    completed = {r.req_id: r for r in d.loop.completed}
    for rid, spans in by_req.items():
        _assert_contiguous(spans)
        req = completed[rid]
        assert abs(max(s.t1_s for s in spans) - req.completed_s) <= 1e-9
    stamps = [r.t_s for r in d.journal.records]
    assert stamps == sorted(stamps)
    assert [r.seq for r in d.journal.records] == list(range(len(stamps)))


def test_same_seed_runs_serialize_byte_identically():
    a = _serve(_deploy(), 16)
    b = _serve(_deploy(), 16)
    assert json.dumps(a.trace_timeline()) == json.dumps(b.trace_timeline())
    assert json.dumps(a.chrome_trace()) == json.dumps(b.chrome_trace())
    assert (json.dumps(a.journal.as_dicts())
            == json.dumps(b.journal.as_dicts()))


def test_chrome_trace_is_structurally_valid():
    d = _serve(_deploy(), 12)
    trace = d.chrome_trace()
    json.dumps(trace)  # serializable as-is
    events = trace["traceEvents"]
    assert any(ev["ph"] == "M" and ev["name"] == "process_name"
               for ev in events)
    tracks = {}
    for ev in events:
        assert {"ph", "pid", "tid"} <= set(ev)
        if ev["ph"] == "M":
            continue
        assert ev["ph"] == "X" and ev["dur"] >= 0
        tracks.setdefault((ev["pid"], ev["tid"]), []).append(
            (ev["ts"], ev["dur"]))
    for spans in tracks.values():
        spans.sort()
        for (t0, dur), (t1, _) in zip(spans, spans[1:]):
            assert t1 >= t0 + dur - 1e-6  # per-request tracks never overlap


# -- control-plane journal --------------------------------------------------

def test_journal_monotone_with_skewed_clocks_and_stamp_overrides():
    j = Journal()
    j.bind_clock(lambda: 5.0)
    j.bind_clock(lambda: 3.0)
    r1 = j.append("reconcile", "control", {"action": "noop"})
    assert r1.t_s == 5.0  # max across providers
    r2 = j.append("scale", "autoscaler", {}, t_s=1.0)
    assert r2.t_s == 5.0  # explicit stamps are clamped monotone
    r3 = j.append("scale", "autoscaler", {}, t_s=9.0)
    assert r3.t_s == 9.0
    assert [r.seq for r in j.records] == [0, 1, 2]
    assert j.summary()["kinds"] == {"reconcile": 1, "scale": 2}
    assert j.select(kind="scale") == [r2, r3]
    assert j.select(source="control") == [r1]


def test_node_kill_journals_recovery_matching_dispatcher():
    d = _deploy()
    node = d.control.pipeline.pods[1].node_id
    _serve(d, 16, kill_node=node, kill_after=4)
    recoveries = d.journal.select(kind="recovery")
    assert recoveries
    last = d.control.dispatcher.last_recovery
    rec = recoveries[-1].detail
    assert rec["affected_stages"] == list(last["affected_stages"])
    assert rec["scoped"] == last["scoped"]
    assert rec["fallback"] == last["fallback"]
    assert d.journal.select(kind="reconcile")  # the replace was journaled
    # the dispatcher's own log mirrors what the journal saw
    assert d.control.dispatcher.recovery_log
    assert d.control.dispatcher.recovery_log[-1] == last


def test_metrics_surfaces_recovery_log_and_journal():
    d = _deploy()
    node = d.control.pipeline.pods[1].node_id
    _serve(d, 16, kill_node=node, kill_after=4)
    out = d.metrics()
    assert out["recovery"]["last"] == d.control.dispatcher.last_recovery
    assert out["recovery"]["log"] == d.control.dispatcher.recovery_log
    assert out["journal"]["records"] == len(d.journal)
    assert out["journal"]["kinds"].get("recovery", 0) >= 1


# -- metrics registry -------------------------------------------------------

def test_registry_snapshot_validates_and_counts_requests():
    d = _serve(_deploy(), 12)
    out = d.metrics()
    snap = out["observability"]["metrics"]
    validate_snapshot(snap)
    counters = {c["name"]: c["value"] for c in snap["counters"]}
    assert counters["requests_completed"] == 12
    # legacy metrics keys survive (the registry view is additive)
    assert "serving" in out or "requests" in out or "backlog" in out


def test_registry_rejects_malformed_snapshots():
    from repro.obs.metrics import SnapshotSchemaError

    reg = MetricsRegistry()
    reg.counter("ok").inc()
    snap = reg.snapshot()
    validate_snapshot(snap)
    snap["counters"][0]["value"] = float("nan")
    with pytest.raises(SnapshotSchemaError):
        validate_snapshot(snap)


def test_scale_event_carries_its_measurement():
    ev = ScaleEvent(t_s=1.0, action="grow", replica=2,
                    reason="backlog_per_replica>16", live_after=3,
                    measurement=24.5)
    assert ev.summary()["measurement"] == 24.5
    restore = ScaleEvent(t_s=2.0, action="restore", replica=0,
                         reason="no live replicas", live_after=1)
    assert restore.summary()["measurement"] is None


# -- critical-path analyzer -------------------------------------------------

def test_attribution_fractions_sum_to_one():
    d = _serve(_deploy(), 12)
    att = analyze_spans(d.tracer.spans)
    assert abs(sum(att["fractions"].values()) - 1.0) <= 1e-6
    assert att["requests"] == 12
    assert att["bottleneck"]["kind"] in ("stage", "link")
    for spans_of_req in (d.tracer.spans_for(r.req_id)
                         for r in d.loop.completed[:3]):
        per = request_attribution(spans_of_req)
        groups = ("queue", "compute", "wire", "transcode")
        assert abs(sum(per[g] for g in groups) - 1.0) <= 1e-6
        assert per["total_s"] > 0


def test_split_window_tiles_exactly_and_handles_dead_links():
    segs = split_window(1.0, 2.0, (0.25, 0.5, 0.25))
    assert [p for p, _, _ in segs] == ["encode", "wire", "decode"]
    assert abs(sum(b - a for _, a, b in segs) - 1.0) <= 1e-12
    for (_, _, b), (_, a, _) in zip(segs, segs[1:]):
        assert a == b  # shared boundaries: telescoping by construction
    assert split_window(1.0, 2.0, (0.0, float("inf"), 0.0)) == [
        ("wire", 1.0, 2.0)]
    assert split_window(2.0, 2.0, (0.1, 0.1, 0.1)) == []
    enc, wire, dec = split_hop(float("inf"), None, 1024)
    assert (enc, dec) == (0.0, 0.0) and math.isinf(wire)


# -- spec validation --------------------------------------------------------

def test_trace_config_validation():
    assert TraceConfig().issues() == []
    assert TraceConfig(sample=2.0).issues()
    assert TraceConfig(sample=-0.1).issues()
    assert TraceConfig(max_spans=0).issues()
    graph, executor_for_version = demo_mlp(d=D)
    spec = DeploymentSpec(
        model=graph, executor_for_version=executor_for_version,
        cluster=ClusterSpec(n_nodes=8, capacity_bytes=1e9),
        trace=TraceConfig(sample=7.0))
    assert any("trace" in i.message for i in spec.validate())


# -- shared stats helper ----------------------------------------------------

def test_percentile_has_one_nearest_rank_implementation():
    from repro.cluster.serving import percentile as served
    assert served is percentile
    vals = sorted(float(v) for v in range(1, 101))
    assert percentile(vals, 0.50) == 50.0
    assert percentile(vals, 0.99) == 99.0
    assert percentile(vals, 1.00) == 100.0
