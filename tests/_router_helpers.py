"""Shared assertions for replicated-serving tests.

The replica router's conservation invariant -- every admitted request lives
in exactly one of {completed, failed, rejected, router queue, router arrival
heap, a replica's admission queue, a replica's arrival heap, an in-flight
microbatch} -- is asserted by the router property suite, the workload
property suite, and the replica chaos scenarios; one walker keeps them in
lockstep when the router grows a new holding location.
"""


def assert_router_conserved(dep, submitted_ids):
    """Walk every place a request can live in a replicated deployment."""
    loop = dep.loop
    everywhere = (
        [r.req_id for r in loop.completed]
        + [r.req_id for r in loop.failed]
        + [r.req_id for r in loop.rejected]
        + [r.req_id for r in loop.queue]
        + [r.req_id for r in loop.arrivals]
        + [r.req_id for sub in loop.loops for r in sub.queue]
        + [r.req_id for sub in loop.loops for r in sub.arrivals]
        + [r.req_id for sub in loop.loops for r in sub.rejected]
        + [r.req_id for sub in loop.loops for mb in sub._inflight
           for r in mb.requests]
    )
    assert len(everywhere) == len(set(everywhere)), "request duplicated"
    assert sorted(everywhere) == sorted(submitted_ids), "request lost"


def assert_engine_conserved(loop, submitted_ids):
    """Same walk for a single (non-replicated) pipelined engine."""
    everywhere = (
        [r.req_id for r in loop.completed]
        + [r.req_id for r in loop.failed]
        + [r.req_id for r in loop.rejected]
        + [r.req_id for r in loop.queue]
        + [r.req_id for r in loop.arrivals]
        + [r.req_id for mb in loop._inflight for r in mb.requests]
    )
    assert len(everywhere) == len(set(everywhere)), "request duplicated"
    assert sorted(everywhere) == sorted(submitted_ids), "request lost"
