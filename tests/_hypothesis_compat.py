"""Hypothesis shim: use the real library when installed, else a deterministic
fallback so the property tests still exercise a fixed sample of inputs.

``hypothesis`` is an *optional* test dependency (see requirements.txt).  The
fallback implements exactly the strategy surface these tests use --
``integers``, ``tuples``, ``lists`` -- and replays a fixed number of examples
drawn from a per-test seeded PRNG, so runs are reproducible and the suite
collects (and passes) on a bare interpreter.
"""

from __future__ import annotations

import random
import zlib

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings
    import hypothesis.strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    _FALLBACK_MAX_EXAMPLES = 15  # cap: fallback trades coverage for speed

    class _Strategy:
        """A sampler: draw(rng) -> value.  Mirrors the hypothesis API shape."""

        def __init__(self, draw):
            self.draw = draw

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def tuples(*strats):
            return _Strategy(lambda rng: tuple(s.draw(rng) for s in strats))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            return _Strategy(
                lambda rng: [
                    elements.draw(rng)
                    for _ in range(rng.randint(min_size, max_size))
                ]
            )

    st = _Strategies()

    def settings(max_examples=20, **_kwargs):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strats):
        def deco(fn):
            def wrapper(*args, **kwargs):
                limit = getattr(
                    wrapper, "_max_examples", getattr(fn, "_max_examples", 20)
                )
                n = min(limit, _FALLBACK_MAX_EXAMPLES)
                base = zlib.crc32(fn.__qualname__.encode())
                for example in range(n):
                    rng = random.Random(base + example)
                    drawn = {k: s.draw(rng) for k, s in strats.items()}
                    try:
                        fn(*args, **kwargs, **drawn)
                    except Exception as e:  # noqa: BLE001 - re-raise with context
                        raise AssertionError(
                            f"falsifying example #{example}: {drawn!r}"
                        ) from e

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco


__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
