"""Property + regression tests for open-loop serving under trace-driven load.

Properties (hypothesis when installed, deterministic fallback otherwise):

  * generated traces are sorted, in-range, and a pure function of
    ``(name, rate, duration, seed)``;
  * latency percentiles are ordered (p50 <= p95 <= p99 <= max) for every
    trace shape and seed;
  * request conservation -- admitted = completed + rejected + failed, and
    mid-flight every request lives in exactly one holding location -- holds
    under random churn;
  * continuous batching never coalesces past ``max_batch``.

Plus the determinism regression: one (spec, trace seed) pair must produce an
identical serving-metrics payload across two full runs -- the virtual clock
has no hidden wall-clock or ordering nondeterminism.
"""

from __future__ import annotations

import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    ArrivalSpec,
    AutoscaleSpec,
    ClusterSpec,
    DeploymentSpec,
    deploy,
)
from repro.cluster import NodeFailed
from repro.core.graph import Layer, LayerGraph
from repro.core.placement import CommGraph
from repro.workload import UnknownTraceError, list_traces, make_trace

from tests._hypothesis_compat import given, settings, st
from tests._router_helpers import assert_engine_conserved, assert_router_conserved

N_HOSTING = 8
PARAM_BYTES = 500_000
CAPACITY = 1.05e6  # 2 layers/node -> 4-stage pipelines, 2 feasible replicas


def _graph() -> LayerGraph:
    layers = tuple(
        Layer(f"l{i}", param_bytes=PARAM_BYTES, out_bytes=100_000,
              flops=5_000_000)
        for i in range(8)
    )
    return LayerGraph("synth8", layers, in_bytes=50_000)


def _comm() -> CommGraph:
    bw = np.full((N_HOSTING + 1, N_HOSTING + 1), 20e6)
    np.fill_diagonal(bw, 0.0)
    cap = np.full(N_HOSTING + 1, CAPACITY)
    cap[0] = -1.0
    return CommGraph(bw=bw, node_capacity=cap)


def _spec(seed=0, **kw) -> DeploymentSpec:
    return DeploymentSpec(
        model=_graph(), cluster=ClusterSpec(comm=_comm()), capacity=CAPACITY,
        seed=seed, microbatch=1, **kw)


def _drive(dep, *, kill=None, kill_after=0, conserve_every=None, ids=None):
    """Serve everything; optionally kill a node after N completions and
    assert conservation at every M-th step."""
    killed = kill is None
    steps = 0
    while dep.loop.backlog or dep.loop.pending_arrivals or dep.pending:
        if not killed and len(dep.loop.completed) >= kill_after:
            dep.inject(NodeFailed(kill))
            killed = True
        progressed = bool(dep.step()) or dep.pending
        steps += 1
        if conserve_every and steps % conserve_every == 0:
            assert_router_conserved(dep, ids)
        if (not progressed and not dep.loop.pending_arrivals
                and not dep.loop.backlog):
            break


# ---------------------------------------------------------------------------
# Trace generation
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(tr=st.integers(0, 3), seed=st.integers(0, 10_000),
       rate=st.integers(50, 400))
def test_traces_sorted_in_range_deterministic(tr, seed, rate):
    name = list_traces()[tr % len(list_traces())]
    t1 = make_trace(name, rate=float(rate), duration_s=1.5, seed=seed,
                    classes={"gold": 1.0, "std": 3.0})
    t2 = make_trace(name, rate=float(rate), duration_s=1.5, seed=seed,
                    classes={"gold": 1.0, "std": 3.0})
    times = [a.t_s for a in t1.arrivals]
    assert times == sorted(times)
    assert all(0.0 <= t < 1.5 for t in times)
    assert [(a.t_s, a.slo_class) for a in t1.arrivals] == \
        [(a.t_s, a.slo_class) for a in t2.arrivals]
    assert {a.slo_class for a in t1.arrivals} <= {"gold", "std"}


def test_unknown_trace_suggests():
    with pytest.raises(UnknownTraceError) as ei:
        make_trace("poison", rate=10.0, duration_s=1.0)
    assert "poisson" in str(ei.value)


def test_trace_rejects_bad_params():
    with pytest.raises(ValueError):
        make_trace("poisson", rate=0.0, duration_s=1.0)
    with pytest.raises(ValueError):
        make_trace("poisson", rate=10.0, duration_s=0.0)


# ---------------------------------------------------------------------------
# Latency percentiles + batching bound (single pipeline)
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(tr=st.integers(0, 3), seed=st.integers(0, 1000),
       mb=st.integers(2, 8))
def test_percentiles_ordered_and_batch_bounded(tr, seed, mb):
    name = list_traces()[tr % len(list_traces())]
    dep = deploy(_spec(
        seed=seed, max_batch=mb, admission_depth=24,
        arrival=ArrivalSpec(trace=name, rate=120.0, duration_s=1.0,
                            seed=seed)))
    reqs = dep.submit_trace(make_input=lambda i, a: jnp.ones((4,)))
    _drive(dep)
    m = dep.metrics()["serving"]
    lat = m["latency"]["overall"]
    assert lat["p50_s"] <= lat["p95_s"] <= lat["p99_s"] <= lat["max_s"]
    assert m["batching"]["max_batch_seen"] <= mb
    assert all(len(mb_.requests) <= mb for mb_ in dep.loop._inflight)
    assert m["completed"] + m["failed"] + m["rejected"] == len(reqs)
    assert_engine_conserved(dep.loop, [r.req_id for r in reqs])
    assert all(r.latency_s >= 0 for r in dep.loop.completed)


# ---------------------------------------------------------------------------
# Conservation under churn (replicated + autoscaled)
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 1000), victim=st.integers(1, N_HOSTING),
       kill_after=st.integers(3, 40))
def test_conservation_under_churn(seed, victim, kill_after):
    dep = deploy(_spec(
        seed=seed, max_batch=4, admission_depth=64,
        arrival=ArrivalSpec(trace="bursty", rate=250.0, duration_s=1.0,
                            seed=seed),
        autoscale=AutoscaleSpec(min_replicas=1, backlog_high=6.0,
                                backlog_low=1.0, cooldown_s=0.05)))
    reqs = dep.submit_trace(make_input=lambda i, a: jnp.ones((4,)))
    ids = [r.req_id for r in reqs]
    _drive(dep, kill=victim, kill_after=kill_after, conserve_every=7, ids=ids)
    m = dep.metrics()["serving"]
    assert m["completed"] + m["failed"] + m["rejected"] == len(reqs)
    assert_router_conserved(dep, ids)


# ---------------------------------------------------------------------------
# Determinism regression
# ---------------------------------------------------------------------------

def _run_once(autoscale: bool) -> dict:
    kw = dict(
        seed=3, max_batch=4, admission_depth=48,
        arrival=ArrivalSpec(trace="heavy-tailed", rate=200.0, duration_s=1.0,
                            seed=11))
    if autoscale:
        kw["autoscale"] = AutoscaleSpec(min_replicas=1, backlog_high=6.0,
                                        backlog_low=1.0, cooldown_s=0.05)
    dep = deploy(_spec(**kw))
    dep.submit_trace(make_input=lambda i, a: jnp.ones((4,)))
    _drive(dep, kill=2, kill_after=25)
    return dep.metrics()["serving"]


@pytest.mark.parametrize("autoscale", [False, True],
                         ids=["single", "autoscaled"])
def test_same_seed_same_metrics(autoscale):
    """Same trace seed + spec -> byte-identical serving metrics payload."""
    a = json.dumps(_run_once(autoscale), sort_keys=True, default=str)
    b = json.dumps(_run_once(autoscale), sort_keys=True, default=str)
    assert a == b
