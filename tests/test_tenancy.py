"""Multi-tenant serving: spec validation, scheduler carve, quota admission,
weighted-fair service, and tenant-scoped churn routing.

The isolation *scenarios* (randomized churn on one slice, shared-node kills)
live in ``test_chaos_scenarios.py``; these tests pin the tenancy layer's
unit-level contracts.
"""

import numpy as np
import pytest

from repro.api import (
    ClusterSpec,
    DeploymentSpec,
    InfeasibleSpecError,
    TenantSpec,
    deploy,
)
from repro.api.spec import as_tenants, validate_tenants
from repro.cluster import LinkDegraded, NodeFailed, NodeJoined, VersionBumped
from repro.core.graph import Layer, LayerGraph
from repro.core.placement import CommGraph
from repro.tenancy import TenantScheduler, resolve_fractions

N_HOSTING = 12
CAPACITY = 1.05e6


def _comm(n_hosting=N_HOSTING, cap=CAPACITY):
    bw = np.full((n_hosting + 1, n_hosting + 1), 20e6)
    np.fill_diagonal(bw, 0.0)
    caps = np.full(n_hosting + 1, cap)
    caps[0] = -1.0
    return CommGraph(bw=bw, node_capacity=caps)


def _graph(name, n_layers=8, param_bytes=500_000):
    layers = tuple(
        Layer(f"{name}{i}", param_bytes=param_bytes, out_bytes=100_000,
              flops=5_000_000)
        for i in range(n_layers)
    )
    return LayerGraph(name, layers, in_bytes=50_000)


def _spec(name, comm, **kw):
    kw.setdefault("microbatch", 1)
    kw.setdefault("capacity", CAPACITY)
    return DeploymentSpec(model=_graph(name),
                          cluster=ClusterSpec(comm=comm), **kw)


def _two_tenants(comm=None, **tenant_kw):
    comm = comm if comm is not None else _comm()
    return [
        TenantSpec("alpha", _spec("a", comm), **tenant_kw),
        TenantSpec("beta", _spec("b", comm), **tenant_kw),
    ]


# ---------------------------------------------------------------------------
# TenantSpec validation
# ---------------------------------------------------------------------------

def test_as_tenants_wraps_bare_specs_with_generated_names():
    comm = _comm()
    ts = as_tenants([_spec("a", comm), TenantSpec("named", _spec("b", comm))])
    assert [t.name for t in ts] == ["tenant0", "named"]
    with pytest.raises(TypeError):
        as_tenants([42])


def test_validate_tenants_flags_quota_and_name_problems():
    comm = _comm()
    issues = validate_tenants(as_tenants([
        TenantSpec("a", _spec("a", comm), capacity_fraction=0.8),
        TenantSpec("a", _spec("b", comm), capacity_fraction=0.5),
        TenantSpec("c", _spec("c", comm), weight=-1.0),
    ]))
    codes = {i.code for i in issues}
    assert "duplicate_tenant" in codes
    assert "quota_exceeded" in codes  # 0.8 + 0.5 > 1
    assert "bad_quota" in codes  # weight <= 0


def test_validate_tenants_rejects_mismatched_clusters():
    issues = validate_tenants(as_tenants([
        TenantSpec("a", _spec("a", _comm())),
        TenantSpec("b", _spec("b", _comm())),  # a DIFFERENT CommGraph object
    ]))
    assert "tenant_cluster_mismatch" in {i.code for i in issues}


def test_tenant_quota_falls_back_to_the_spec():
    comm = _comm()
    t = TenantSpec("a", _spec("a", comm, admission_depth=16))
    assert t.quota() == 16
    t2 = TenantSpec("a", _spec("a", comm, admission_depth=16),
                    admission_depth=4)
    assert t2.quota() == 4


# ---------------------------------------------------------------------------
# Scheduler carve
# ---------------------------------------------------------------------------

def test_resolve_fractions_splits_the_remainder_equally():
    comm = _comm()
    ts = [TenantSpec("a", _spec("a", comm), capacity_fraction=0.5),
          TenantSpec("b", _spec("b", comm)),
          TenantSpec("c", _spec("c", comm))]
    assert resolve_fractions(ts) == [0.5, 0.25, 0.25]


def test_partition_carve_is_disjoint_and_quota_proportional():
    comm = _comm()
    ts = [TenantSpec("a", _spec("a", comm), capacity_fraction=0.75),
          TenantSpec("b", _spec("b", comm), capacity_fraction=0.25)]
    plan = TenantScheduler().carve(comm, ts)
    a, b = (set(p.nodes) for p in plan.placements)
    assert not a & b, "slices must be disjoint"
    assert 0 not in a | b, "the dispatcher is never carved"
    assert len(a) == 9 and len(b) == 3  # 0.75/0.25 of 12 hosting nodes
    assert plan.spare == ()


def test_partition_carve_leaves_unclaimed_nodes_spare():
    comm = _comm()
    ts = [TenantSpec("a", _spec("a", comm), capacity_fraction=0.25),
          TenantSpec("b", _spec("b", comm), capacity_fraction=0.25)]
    plan = TenantScheduler().carve(comm, ts)
    taken = {i for p in plan.placements for i in p.nodes}
    assert len(taken) == 6 and len(plan.spare) == 6
    assert taken | set(plan.spare) == set(range(1, N_HOSTING + 1))


def test_shared_policy_gives_every_tenant_every_hosting_node():
    comm = _comm()
    plan = TenantScheduler(policy="shared").carve(comm, _two_tenants(comm))
    for p in plan.placements:
        assert set(p.nodes) == set(range(1, N_HOSTING + 1))
    assert plan.spare == ()


def test_more_tenants_than_hosting_nodes_is_infeasible():
    # roomy nodes: each spec fits the cluster fine on its own, so the only
    # infeasibility is the carve (3 tenants, 2 hosting nodes)
    comm = _comm(n_hosting=2, cap=4.2e6)
    ts = [TenantSpec(f"t{i}", _spec(f"t{i}", comm, capacity=4.2e6))
          for i in range(3)]
    with pytest.raises(ValueError, match="hosting node"):
        TenantScheduler().carve(comm, ts)
    with pytest.raises(InfeasibleSpecError) as ei:
        deploy(ts)
    assert {i.code for i in ei.value.issues} == {"infeasible_tenancy"}


def test_scheduler_rejects_unknown_policy():
    with pytest.raises(ValueError, match="policy"):
        TenantScheduler(policy="round-robin")


# ---------------------------------------------------------------------------
# deploy() list entry + serving
# ---------------------------------------------------------------------------

def test_deploy_list_builds_a_multi_tenant_deployment():
    d = deploy(_two_tenants())
    assert d.names() == ("alpha", "beta")
    assert set(d.nodes_for("alpha")) | set(d.nodes_for("beta")) <= set(
        range(1, N_HOSTING + 1))
    # each tenant planned strictly inside its slice
    for name in d.names():
        path = set(d.deployment(name).observed().path)
        assert path <= set(d.nodes_for(name))
    for i in range(8):
        d.submit("alpha", i)
        d.submit("beta", i)
    done = d.drain()
    assert len(done) == 16
    assert {r.tenant for r in done} == {"alpha", "beta"}
    # merged completion stream is time-ordered
    times = [r.completed_s for r in d.completed()]
    assert times == sorted(times)


def test_deploy_rejects_tenancy_kwargs_on_a_single_spec():
    with pytest.raises(TypeError, match="tenancy"):
        deploy(_spec("a", _comm()), policy="partition")


def test_tenant_quota_sheds_only_that_tenants_overload():
    """Admission quotas are open-loop load shedding: a burst of timestamped
    arrivals past one tenant's ``admission_depth`` is rejected from THAT
    tenant's queue while the co-located tenant admits everything."""
    comm = _comm()
    tenants = [
        TenantSpec("greedy", _spec("a", comm), capacity_fraction=0.5,
                   admission_depth=2),
        TenantSpec("modest", _spec("b", comm), capacity_fraction=0.5),
    ]
    d = deploy(tenants)
    for i in range(20):  # a same-instant burst: 2 fit the queue, 18 shed
        d.schedule("greedy", i, 0.0)
        d.schedule("modest", i, 0.0)
    d.drain()
    greedy = d.router.loop("greedy")
    modest = d.router.loop("modest")
    assert greedy.metrics()["rejected"] > 0, "quota must shed the overload"
    assert modest.metrics()["rejected"] == 0, "quota is per-tenant"
    assert len(modest.completed) == 20
    assert len(greedy.completed) + greedy.metrics()["rejected"] == 20


def test_weighted_fair_deficit_tracks_completions_over_weight():
    comm = _comm()
    d = deploy([
        TenantSpec("heavy", _spec("a", comm), capacity_fraction=0.5,
                   weight=3.0),
        TenantSpec("light", _spec("b", comm), capacity_fraction=0.5,
                   weight=1.0),
    ])
    for i in range(12):
        d.submit("heavy", i)
        d.submit("light", i)
    d.drain()
    fair = d.router.metrics()["fairness"]
    assert fair["heavy"]["served"] == fair["light"]["served"] == 12
    # every completion charges 1/weight: the heavier tenant accrues less
    assert fair["heavy"]["deficit"] == pytest.approx(12 / 3.0)
    assert fair["light"]["deficit"] == pytest.approx(12 / 1.0)


def test_router_tie_break_rotates_across_equally_lagging_tenants():
    d = deploy(_two_tenants())
    for i in range(6):
        d.submit("alpha", i)
        d.submit("beta", i)
    # identical engines, equal clocks: the deficit tie-break must rotate
    # instead of starving one side
    first = d.step()
    second = d.step()
    assert {r.tenant for r in first + second} == {"alpha", "beta"}


def test_metrics_are_tenant_keyed_and_json_clean():
    import json

    d = deploy(_two_tenants())
    for i in range(4):
        d.submit("alpha", i)
        d.submit("beta", i)
    d.drain()
    m = d.metrics()
    assert m["mode"] == "multi-tenant"
    assert set(m["tenants"]) == {"alpha", "beta"}
    assert set(m["serving"]["fairness"]) == {"alpha", "beta"}
    json.dumps(m, allow_nan=False)  # normalized: strict JSON round trip
    rep = d.latency_report()
    assert set(rep) == {"alpha", "beta"}
    assert rep["alpha"]["overall"]["count"] == 4


# ---------------------------------------------------------------------------
# Tenant-scoped control plane
# ---------------------------------------------------------------------------

def test_node_failure_routes_only_to_the_owning_tenant():
    d = deploy(_two_tenants())
    victim = d.deployment("alpha").control.pipeline.pods[0].node_id
    d.inject(NodeFailed(victim))
    acts = d.reconcile()
    assert d.controlplane.routed == [("alpha", "NodeFailed")]
    assert [a.kind for a in acts["alpha"]] == ["replace"]
    assert acts["beta"] == []


def test_spare_node_failure_touches_no_tenant():
    comm = _comm()
    tenants = [TenantSpec("a", _spec("a", comm), capacity_fraction=0.4),
               TenantSpec("b", _spec("b", comm), capacity_fraction=0.4)]
    d = deploy(tenants)
    assert d.plan.spare, "this carve must leave spares"
    spare = d.plan.spare[0]
    d.inject(NodeFailed(spare))
    acts = d.reconcile()
    assert d.controlplane.routed == [(None, "NodeFailed")]
    assert all(v == [] for v in acts.values())
    assert not d.cluster.nodes[spare].healthy  # shared state stayed honest


def test_version_bump_requires_a_tenant_scope():
    d = deploy(_two_tenants())
    with pytest.raises(ValueError, match="tenant-scoped"):
        d.inject(VersionBumped(1))
    # scoped: only the named tenant rolls
    d.deployment("alpha").store.publish(1)
    d.inject(VersionBumped(1), tenant="alpha")
    d.reconcile()
    assert d.deployment("alpha").observed().version == 1
    assert d.deployment("beta").observed().version == 0


def test_tenant_stores_are_isolated(tmp_path):
    d = deploy(_two_tenants(), store_root=str(tmp_path))
    sa = d.deployment("alpha").store
    sb = d.deployment("beta").store
    assert sa.root != sb.root
    sa.publish(5)
    assert sb.current_version() != 5


def test_link_degraded_on_a_cross_slice_link_touches_no_tenant():
    d = deploy(_two_tenants())
    a = d.nodes_for("alpha")[0]
    b = d.nodes_for("beta")[0]
    before = float(d.cluster.comm.bw[a, b])
    d.inject(LinkDegraded(a, b, 0.5))
    acts = d.reconcile()
    assert d.controlplane.routed == [(None, "LinkDegraded")]
    assert all(v == [] for v in acts.values())
    assert float(d.cluster.comm.bw[a, b]) == pytest.approx(0.5 * before)


def test_grown_node_is_adopted_by_the_weakest_tenant():
    comm = _comm()
    # symmetric pipelines tie on raw throughput, so "weakest" is decided by
    # throughput PER UNIT WEIGHT: beta's weight 3 marks it furthest below
    # its fair share and the grown node must land in beta's slice
    tenants = [TenantSpec("alpha", _spec("a", comm), weight=1.0),
               TenantSpec("beta", _spec("b", comm), weight=3.0)]
    d = deploy(tenants)
    n = d.cluster.n
    grown = np.full((n + 1, n + 1), 20e6)
    np.fill_diagonal(grown, 0.0)
    grown_caps = np.append(np.asarray(d.cluster.comm.node_capacity), CAPACITY)
    d.inject(NodeJoined(comm=CommGraph(bw=grown, node_capacity=grown_caps)))
    d.reconcile()
    assert d.cluster.n == n + 1
    new_id = n
    owners = d.controlplane.owners_of_node(new_id)
    assert owners == ["beta"], owners
    assert ("beta", "NodeJoined") in d.controlplane.routed


def test_unknown_tenant_scope_raises():
    d = deploy(_two_tenants())
    with pytest.raises(KeyError):
        d.inject(NodeFailed(1), tenant="nope")
