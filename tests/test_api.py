"""Declarative API coverage: registry, spec validation, planner parity, deploy.

The parity test is the PR's regression anchor: a default-strategy ``Planner``
driven through ``Dispatcher.configure`` must reproduce the pre-refactor
hardcoded pipeline (``partition_min_bottleneck`` + ``place_color_coding`` on
the dispatcher's RNG stream) *exactly* -- same cuts, same node path, same
bottleneck latency -- on several seeded clusters.
"""

import tempfile

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    ClusterSpec,
    DeploymentSpec,
    InfeasibleSpecError,
    Planner,
    UnknownStrategyError,
    default_strategy,
    deploy,
    get_strategy,
    list_strategies,
    register_strategy,
    strategy_table,
)
from repro.cluster import ArtifactStore, Dispatcher, EdgeCluster, NodeFailed
from repro.core.graph import chain
from repro.core.partitioner import partition_min_bottleneck
from repro.core.placement import CommGraph, place_color_coding
from repro.core.simulate import random_cluster

D, LAYERS = 16, 8
CAPACITY = 3 * D * D * 4


def _graph():
    return chain("mlp", [(D * D * 4, 4 * D * 4)] * LAYERS, in_bytes=4 * D * 4)


def _demo_spec(seed=3, **kw):
    from repro.core.model_zoo import demo_mlp

    graph, _ = demo_mlp(d=32)
    kw.setdefault("model", "demo_mlp")
    kw.setdefault("cluster", ClusterSpec(
        n_nodes=8, capacity_bytes=graph.total_param_bytes / 3, seed=seed))
    return DeploymentSpec(**kw)


# ---------------------------------------------------------------------------
# Registry round-trip
# ---------------------------------------------------------------------------

def test_registry_contains_every_algorithm():
    assert set(list_strategies("partitioner")) == {
        "min_bottleneck", "paper_greedy", "min_sum", "exact_k", "uniform",
        "exhaustive",
    }
    assert set(list_strategies("placer")) == {
        "color_coding", "greedy", "random", "optimal", "hierarchical",
    }
    assert set(list_strategies("joint")) == {"sequential", "joint"}
    # defaults are the paper pipeline, listed first
    assert default_strategy("partitioner") == "min_bottleneck"
    assert default_strategy("placer") == "color_coding"
    assert default_strategy("joint") == "sequential"
    assert list_strategies("partitioner")[0] == "min_bottleneck"


def test_registry_resolves_the_actual_functions():
    assert get_strategy("partitioner", "min_bottleneck").fn is partition_min_bottleneck
    assert get_strategy("placer", "color_coding").fn is place_color_coding
    for kind in ("partitioner", "placer", "joint"):
        for name in list_strategies(kind):
            s = get_strategy(kind, name)
            assert s.name == name and s.kind == kind and callable(s.fn)


def test_unknown_strategy_raises_with_suggestions():
    with pytest.raises(UnknownStrategyError) as ei:
        get_strategy("placer", "color_codng")
    assert "color_coding" in str(ei.value)  # did-you-mean
    assert "greedy" in str(ei.value)  # registered names listed
    with pytest.raises(ValueError, match="kind"):
        get_strategy("scheduler", "foo")


def test_unknown_codec_raises_with_suggestions():
    """The codec registry mirrors the strategy registry's ergonomics: a typo
    fails with did-you-mean suggestions, and a spec naming it surfaces the
    same message as a structured issue instead of deploying."""
    from repro.dataplane import UnknownCodecError, get_codec, list_codecs

    with pytest.raises(UnknownCodecError) as ei:
        get_codec("identty")
    assert "identity" in str(ei.value)  # did-you-mean
    assert "int8" in str(ei.value)  # registered names listed
    assert list_codecs()[0] == "identity"  # default first

    issues = _demo_spec(codec="identty").validate()
    assert [i.code for i in issues] == ["unknown_codec"]
    assert "identity" in issues[0].message
    with pytest.raises(InfeasibleSpecError, match="unknown_codec"):
        deploy(_demo_spec(codec="identty"))


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError, match="duplicate"):
        register_strategy("placer", "color_coding")(lambda: None)


def test_strategy_table_covers_all_kinds():
    rows = strategy_table()
    kinds = {r["kind"] for r in rows}
    assert kinds == {"partitioner", "placer", "joint"}
    assert sum(1 for r in rows if r["default"] == "yes") == 3


# ---------------------------------------------------------------------------
# Planner parity with the pre-refactor Dispatcher.configure
# ---------------------------------------------------------------------------

def _old_configure(comm_graph, graph, capacity, n_classes, seed, probe_noise=0.05):
    """The pre-API Dispatcher.configure, inlined verbatim as the oracle."""
    cluster = EdgeCluster(comm_graph)
    rng = np.random.default_rng(seed)
    leader = min(cluster.healthy_ids())
    true = cluster.degraded_comm()
    n = true.n
    noise = rng.lognormal(0.0, probe_noise, size=(n, n))
    noise = np.tril(noise) + np.tril(noise, -1).T
    comm = CommGraph(bw=true.bw * noise, node_capacity=true.node_capacity)
    part = partition_min_bottleneck(
        graph, int(capacity), max_parts=len(cluster.healthy_ids())
    )
    assert part.feasible
    place = place_color_coding(
        part.boundaries,
        [p.param_bytes for p in part.partitions],
        comm,
        n_classes=n_classes,
        seed=int(rng.integers(1 << 31)),
        in_bytes=graph.in_bytes,
        out_bytes=graph.layers[-1].out_bytes,
        dispatcher=leader,
    )
    return part, place


@pytest.mark.parametrize("seed", [3, 7, 11, 23])
def test_planner_default_matches_prerefactor_configure(seed):
    graph = _graph()
    comm = random_cluster(8, CAPACITY, seed=seed)
    part0, place0 = _old_configure(comm, graph, CAPACITY, n_classes=4, seed=seed)

    disp = Dispatcher(
        EdgeCluster(comm), ArtifactStore(tempfile.mkdtemp(prefix="seifer-api-")),
        seed=seed,
    )
    plan = disp.configure(graph, version=0, capacity=CAPACITY)

    assert plan.feasible
    assert plan.partition.cuts == part0.cuts  # exact partition boundaries
    assert plan.partition.boundaries == part0.boundaries
    assert plan.placement.path == place0.path  # exact node path
    assert plan.placement.bottleneck_latency == place0.bottleneck_latency
    assert dict(plan.strategies) == {
        "partitioner": "min_bottleneck", "placer": "color_coding",
    }


def test_explicit_default_names_equal_implicit_defaults():
    graph = _graph()
    comm = random_cluster(8, CAPACITY, seed=5)
    implicit = Planner().plan(graph, comm, capacity=CAPACITY, seed=0)
    explicit = Planner("min_bottleneck", "color_coding").plan(
        graph, comm, capacity=CAPACITY, seed=0
    )
    assert implicit.partition.cuts == explicit.partition.cuts
    assert implicit.placement.path == explicit.placement.path


def test_every_registered_pair_plans_the_demo_model():
    from repro.core.model_zoo import demo_mlp

    graph, _ = demo_mlp(d=32)
    cap = graph.total_param_bytes / 3
    comm = random_cluster(8, cap, seed=3)
    for pname in list_strategies("partitioner"):
        for plname in list_strategies("placer"):
            plan = Planner(pname, plname).plan(
                graph, comm, capacity=cap, max_parts=8, seed=1, dispatcher=0,
            )
            assert plan.feasible, (pname, plname)
            assert np.isfinite(plan.predicted_bottleneck_s), (pname, plname)


def test_joint_strategy_never_worse_than_sequential():
    graph = _graph()
    comm = random_cluster(8, CAPACITY, seed=9)
    seq = Planner(joint="sequential").plan(graph, comm, capacity=CAPACITY, seed=2)
    jnt = Planner(joint="joint").plan(graph, comm, capacity=CAPACITY, seed=2)
    assert seq.feasible and jnt.feasible
    assert (jnt.placement.bottleneck_latency
            <= seq.placement.bottleneck_latency + 1e-12)
    # a joint optimizer REPLACES the pipeline: only it is reported
    assert dict(seq.strategies) == {"joint": "sequential"}


def test_joint_path_honors_max_parts():
    graph = _graph()
    comm = random_cluster(8, CAPACITY, seed=9)
    for name in ("sequential", "joint"):
        plan = Planner(joint=name).plan(
            graph, comm, capacity=CAPACITY, max_parts=3, seed=2
        )
        assert plan.feasible and plan.n_parts <= 3, name


def test_compression_reaches_predicted_throughput():
    """configure() threads the desired compression into the plan, so
    SLO checks and metrics() agree with Planner.compile()."""
    spec1 = _demo_spec()
    spec2 = _demo_spec(compression_ratio=4.0)
    d1, d2 = deploy(spec1), deploy(spec2)
    assert d2.plan.predicted_throughput > d1.plan.predicted_throughput
    # same partition/placement: compression only shrinks wire bytes
    assert d2.plan.partition.cuts == d1.plan.partition.cuts


# ---------------------------------------------------------------------------
# Spec validation: structured infeasibility reasons
# ---------------------------------------------------------------------------

def test_layer_over_capacity_reports_structured_reason():
    huge = chain("huge", [(100 * CAPACITY, 4)] * 4)
    spec = DeploymentSpec(
        model=huge, cluster=ClusterSpec(n_nodes=4, capacity_bytes=CAPACITY),
    )
    issues = spec.validate()
    codes = {i.code for i in issues}
    assert "layer_exceeds_capacity" in codes
    msg = next(i.message for i in issues if i.code == "layer_exceeds_capacity")
    assert "huge.0" in msg and str(100 * CAPACITY) in msg  # names the layer
    with pytest.raises(InfeasibleSpecError, match="layer_exceeds_capacity"):
        deploy(spec)  # the facade refuses up front, no deep stack trace


def test_unknown_strategy_name_fails_validation_with_suggestion():
    spec = _demo_spec(placer="color_codng")
    issues = spec.validate()
    assert any(i.code == "unknown_strategy" for i in issues)
    with pytest.raises(InfeasibleSpecError, match="color_coding"):
        spec.check()


def test_ambiguous_cluster_description_rejected():
    issues = ClusterSpec().validate()  # neither comm nor (n_nodes, capacity)
    assert any(i.code == "ambiguous_cluster" for i in issues)
    comm = random_cluster(4, CAPACITY, seed=0)
    both = ClusterSpec(n_nodes=4, capacity_bytes=CAPACITY, comm=comm)
    assert any(i.code == "ambiguous_cluster" for i in both.validate())
    # partial overlap: comm plus a random-cluster arg that would be ignored
    partial = ClusterSpec(comm=comm, n_nodes=16)
    assert any(i.code == "ambiguous_cluster" for i in partial.validate())
    half = ClusterSpec(n_nodes=4)  # incomplete random description
    assert any(i.code == "ambiguous_cluster" for i in half.validate())
    assert ClusterSpec(comm=comm).validate() == ()


def test_deploy_callable_under_either_import_order():
    """``repro.api.deploy`` names both the facade function and its module;
    whichever object an import order yields must deploy the spec."""
    import repro.api.deploy as deploy_module

    d = deploy_module(_demo_spec())  # the module itself is callable
    assert d.observed().healthy
    from repro.api import deploy as deploy_fn

    assert callable(deploy_fn)


def test_unmeetable_slo_raises_before_deploy():
    spec = _demo_spec(max_bottleneck_s=1e-12)
    with pytest.raises(InfeasibleSpecError, match="slo_bottleneck"):
        Planner.from_spec(spec).compile(spec)


def test_commgraph_shorthand_wraps_into_cluster_spec():
    comm = random_cluster(4, CAPACITY, seed=0)
    spec = DeploymentSpec(model=_graph(), cluster=comm)
    assert isinstance(spec.cluster, ClusterSpec)
    assert spec.validate() == ()


# ---------------------------------------------------------------------------
# deploy(spec): the facade end to end
# ---------------------------------------------------------------------------

def test_deploy_survives_churn_with_same_action_classes():
    """The acceptance scenario: node kill + version bump through the facade
    produce the same reconcile action classes the control-plane tests pin
    (``replace`` for NodeFailed, ``redeploy`` for VersionBumped)."""
    d = deploy(_demo_spec())
    n = 20
    for _ in range(n):
        d.submit(jnp.ones((32,)) * 0.1)
    killed = False
    while d.loop.backlog or d.control.pending:
        if not killed and len(d.loop.completed) >= n // 2:
            d.inject(NodeFailed(d.control.pipeline.pods[1].node_id))
            killed = True
        d.step()
    assert killed
    assert len(d.loop.completed) == n and len(d.loop.failed) == 0

    d.store.publish(1)
    assert d.poll_model_updates()
    for _ in range(4):
        d.submit(jnp.ones((32,)) * 0.1)
    d.drain()
    assert len(d.loop.completed) == n + 4 and len(d.loop.failed) == 0

    kinds = [a.kind for a in d.control.history]
    assert "replace" in kinds and "redeploy" in kinds
    m = d.metrics()
    assert m["version"] == 1 and m["generation"] == 0 and m["healthy"]


def test_deploy_metrics_reports_predicted_and_observed():
    d = deploy(_demo_spec())
    m = d.metrics()
    assert m["strategies"] == {
        "partitioner": "min_bottleneck", "placer": "color_coding",
    }
    assert m["predicted_bottleneck_s"] > 0
    assert np.isfinite(m["bottleneck_latency_s"])
    assert m["serving"]["completed"] == 0


def test_replan_swaps_strategy_on_live_deployment():
    d = deploy(_demo_spec())
    gen0 = d.observed().generation
    plan = d.replan(placer="greedy")
    assert dict(plan.strategies)["placer"] == "greedy"
    assert d.observed().generation == gen0  # no cluster restart
    assert d.observed().healthy
    d.submit(jnp.ones((32,)) * 0.1)
    assert len(d.drain()) == 1


def test_replan_pipeline_strategy_drops_joint():
    """Naming a placer on a joint-optimized deployment must actually swap
    the placement algorithm, not silently keep the joint optimizer."""
    d = deploy(_demo_spec(joint="sequential"))
    assert dict(d.plan.strategies) == {"joint": "sequential"}
    plan = d.replan(placer="greedy")
    assert dict(plan.strategies) == {
        "partitioner": "min_bottleneck", "placer": "greedy",
    }
    assert d.control.planner.joint is None
    # and back to a joint optimizer by naming one
    plan = d.replan(joint="joint")
    assert dict(plan.strategies) == {"joint": "joint"}


def test_infeasible_replan_keeps_running_pipeline_and_planner():
    d = deploy(_demo_spec())
    path0 = list(d.observed().path)
    placer0 = d.control.planner.placer.name
    # an unsatisfiable strategy: exhaustive partitioner is fine, but force
    # infeasibility by shrinking the desired capacity below any single layer
    d.control.desired.capacity = 1.0
    with pytest.raises(RuntimeError):
        d.replan(placer="greedy")
    assert d.control.planner.placer.name == placer0  # planner rolled back
    assert list(d.observed().path) == path0  # pipeline untouched
    assert d.observed().healthy


def test_plan_tracks_replacement_after_node_failure():
    """d.plan must describe what is DEPLOYED: after a NodeFailed recovery
    the recorded path excludes the dead node and matches observed state."""
    d = deploy(_demo_spec())
    victim = d.control.pipeline.pods[1].node_id
    d.inject(NodeFailed(victim))
    d.reconcile()
    assert victim not in d.plan.path
    assert list(d.plan.path) == list(d.observed().path)
    assert np.isfinite(d.plan.predicted_bottleneck_s)


def test_planner_with_explicit_n_classes_conflict_raises():
    cluster = EdgeCluster(random_cluster(4, CAPACITY, seed=0))
    store = ArtifactStore(tempfile.mkdtemp(prefix="seifer-api-"))
    with pytest.raises(ValueError, match="n_classes"):
        Dispatcher(cluster, store, planner=Planner(), n_classes=8)
    # planner alone, or n_classes alone, are both fine
    Dispatcher(cluster, store, planner=Planner(n_classes=8))
    Dispatcher(cluster, store, n_classes=8)


def test_wrong_model_type_gets_bad_model_issue():
    spec = DeploymentSpec(
        model=123, cluster=ClusterSpec(n_nodes=4, capacity_bytes=CAPACITY),
    )
    issues = spec.validate()
    assert any(i.code == "bad_model" for i in issues)
    assert not any(i.code == "unknown_model" for i in issues)


def test_passthrough_executor_for_zoo_models():
    """CNN zoo graphs have no executable weights: serving still works in
    timing-only mode via the pass-through executor."""
    graph = chain("toy", [(CAPACITY // 2, 64)] * 4, in_bytes=64)
    spec = DeploymentSpec(
        model=graph, cluster=ClusterSpec(n_nodes=6, capacity_bytes=CAPACITY),
    )
    d = deploy(spec)
    d.submit(jnp.ones((4,)))
    (req,) = d.drain()
    assert req.done
    assert d.loop.clock_s > 0  # simulated link time still advances


def test_demo_transformer_kernel_path_e2e():
    """Real compute through the serving engine: demo_transformer stages run
    flash attention, int8 hops hand EncodedActivations to the fused
    dequant-matmul handler, and the Pallas (interpret) deployment reproduces
    the reference deployment's outputs."""
    from repro.core.model_zoo import demo_transformer

    x = np.asarray(jnp.ones((256, 32)) * 0.1)
    results = {}
    for use_pallas in (False, True):
        graph, executor_for_version = demo_transformer(
            use_pallas=use_pallas, interpret=use_pallas)
        spec = DeploymentSpec(
            model=graph,
            executor_for_version=executor_for_version,
            cluster=ClusterSpec(n_nodes=6,
                                capacity_bytes=graph.total_param_bytes / 2.5,
                                seed=5),
            codec="int8",
            seed=3,
            use_pallas=use_pallas,
            interpret=use_pallas,
        )
        d = deploy(spec)
        # the fused fast path is live: the executor advertises the int8
        # handler and the planner put int8 on the wire
        assert "int8" in d.control.pipeline.executor.fused_codecs
        assert "int8" in d.plan.codecs
        assert len(d.control.pipeline.pods) >= 2  # a real multi-stage pipe
        d.submit(jnp.asarray(x))
        (req,) = d.drain()
        assert req.done
        results[use_pallas] = np.asarray(req.result)
    assert results[False].shape == (256, 32)
    np.testing.assert_allclose(results[True], results[False],
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# Open-loop serving spec surface (traces, SLO classes, batching, autoscale)
# ---------------------------------------------------------------------------

def _codes(spec):
    return {i.code for i in spec.validate()}


def test_bad_batching_and_admission_codes():
    from repro.api import ArrivalSpec  # noqa: F401  (surface check)

    assert "bad_batching" in _codes(_demo_spec(max_batch=0))
    assert "bad_batching" in _codes(_demo_spec(admission_depth=-1))
    assert "bad_batching" not in _codes(_demo_spec(max_batch=8,
                                                   admission_depth=64))


def test_slo_class_validation_codes():
    from repro.api import SLOClass

    bad = _demo_spec(slo_classes=(SLOClass("gold", target_latency_s=-1.0),))
    assert "bad_slo_class" in _codes(bad)
    dup = _demo_spec(slo_classes=(SLOClass("a"), SLOClass("a")))
    assert "bad_slo_class" in _codes(dup)
    ok = _demo_spec(slo_classes=(SLOClass("gold", priority=1,
                                          target_latency_s=0.5),
                                 SLOClass("std")))
    assert "bad_slo_class" not in _codes(ok)
    assert ok.class_priority() == {"gold": 1, "std": 0}
    assert ok.class_targets() == {"gold": 0.5, "std": None}


def test_arrival_spec_validation_codes():
    from repro.api import ArrivalSpec

    unknown = _demo_spec(arrival=ArrivalSpec(trace="poison"))
    assert "unknown_trace" in _codes(unknown)
    assert "bad_arrival" in _codes(_demo_spec(arrival=ArrivalSpec(rate=0.0)))
    assert "bad_arrival" in _codes(
        _demo_spec(arrival=ArrivalSpec(duration_s=-1.0)))
    # open-loop arrivals need the pipelined engine
    sync = _demo_spec(serving="sync", arrival=ArrivalSpec())
    assert "bad_serving" in _codes(sync)
    assert not {"unknown_trace", "bad_arrival", "bad_serving"} & _codes(
        _demo_spec(arrival=ArrivalSpec(trace="bursty", rate=50.0,
                                       duration_s=2.0)))


def test_autoscale_spec_validation_codes():
    from repro.api import AutoscaleSpec

    assert "bad_autoscale" in _codes(
        _demo_spec(autoscale=AutoscaleSpec(min_replicas=0)))
    assert "bad_autoscale" in _codes(
        _demo_spec(autoscale=AutoscaleSpec(backlog_high=2.0, backlog_low=4.0)))
    # autoscaling owns the replica count: an explicit replicas=N conflicts
    assert "bad_autoscale" in _codes(
        _demo_spec(replicas=2, autoscale=AutoscaleSpec()))
    # ``autoscale=True`` sugar coerces to the default policy
    sugar = _demo_spec(autoscale=True)
    assert isinstance(sugar.autoscale, AutoscaleSpec)
    assert "bad_autoscale" not in _codes(sugar)


def test_autoscale_min_replicas_infeasible_at_deploy():
    from repro.api import AutoscaleSpec

    spec = _demo_spec(autoscale=AutoscaleSpec(min_replicas=64))
    with pytest.raises(InfeasibleSpecError) as ei:
        deploy(spec)
    assert any(i.code == "infeasible_replicas" for i in ei.value.issues)
