"""Benchmark artifact hygiene: one canonical casing + a validated schema.

The ``results/`` directory used to accumulate duplicated artifacts --
``BENCH_churn_throughput.json`` (written by the driver) next to a legacy
lowercase ``bench_churn_throughput.json`` (written by the module).  Now
``benchmarks.common.save`` is the single writer, always emitting the
canonical ``BENCH_<name>.json`` and schema-validating the payload first.
These tests pin the casing, the validator, and every committed artifact.
"""

import json
import math
from pathlib import Path

import pytest

from benchmarks import common
from benchmarks.common import (
    ARTIFACT_PREFIX,
    PayloadSchemaError,
    save,
    validate_payload,
)

# ---------------------------------------------------------------------------
# Canonical casing
# ---------------------------------------------------------------------------

def test_save_writes_single_canonical_casing(tmp_path, monkeypatch):
    monkeypatch.setattr(common, "RESULTS_DIR", tmp_path)
    path = save("demo", {"rows": [{"a": 1}], "meta": "x"})
    assert path.name == f"{ARTIFACT_PREFIX}demo.json"
    assert [p.name for p in tmp_path.iterdir()] == [f"{ARTIFACT_PREFIX}demo.json"]
    assert json.loads(path.read_text())["rows"] == [{"a": 1}]


def test_no_code_path_writes_legacy_lowercase_artifacts():
    """The duplicated lowercase twins (``bench_*.json`` next to
    ``BENCH_*.json``) are gone and must stay gone: no benchmark passes a
    lowercase prefix to ``save()`` and the default is the canonical one.
    (Deliberately checks the *code*, not the gitignored results/ dir, so a
    developer's stale local artifacts cannot fail tier-1.)"""
    assert ARTIFACT_PREFIX == "BENCH_"
    bench_dir = Path(__file__).resolve().parent.parent / "benchmarks"
    offenders = [
        p.name for p in bench_dir.glob("*.py")
        if 'prefix="bench_"' in p.read_text() or "prefix='bench_'" in p.read_text()
    ]
    assert offenders == [], f"lowercase artifact prefix reintroduced: {offenders}"


def test_churn_benchmark_emits_a_valid_canonical_artifact(tmp_path, monkeypatch):
    """End to end: a real benchmark run writes exactly one BENCH_ artifact
    that round-trips through strict JSON and the schema."""
    from benchmarks import churn_throughput

    monkeypatch.setattr(common, "RESULTS_DIR", tmp_path)
    churn_throughput.run(per_phase=4)
    (path,) = tmp_path.iterdir()
    assert path.name == f"{ARTIFACT_PREFIX}churn_throughput.json"
    payload = json.loads(path.read_text())
    validate_payload(path.stem, payload)
    assert payload["serving_mode"] == "pipelined"
    assert payload["lost_requests"] == 0


def test_replica_scaling_benchmark_emits_a_valid_canonical_artifact(
        tmp_path, monkeypatch):
    """End to end: the replica-scaling benchmark writes one schema-valid
    BENCH_ artifact whose rows pin measurement to the summed prediction."""
    from benchmarks import replica_scaling

    monkeypatch.setattr(common, "RESULTS_DIR", tmp_path)
    replica_scaling.run(requests=16, r_values=(1, 2))
    (path,) = tmp_path.iterdir()
    assert path.name == f"{ARTIFACT_PREFIX}replica_scaling.json"
    payload = json.loads(path.read_text())
    validate_payload(path.stem, payload)
    assert {r["pipelines"] for r in payload["rows"]} >= {1, 2}
    assert 0.95 <= payload["claims"]["worst_vs_predicted"]
    assert payload["claims"]["best_vs_predicted"] <= 1.05
    router = payload["serving"]["engine"]
    assert "replicated" in router


def test_bandwidth_sweep_benchmark_emits_a_valid_canonical_artifact(
        tmp_path, monkeypatch):
    """End to end: the codec bandwidth sweep writes one schema-valid BENCH_
    artifact whose claims pin the data plane's acceptance criteria -- int8
    >= identity and auto >= 1.5x identity on the constrained mesh, and the
    engine within 5% of the plan's prediction for every codec."""
    from benchmarks import bandwidth_sweep

    monkeypatch.setattr(common, "RESULTS_DIR", tmp_path)
    bandwidth_sweep.run(requests=16)
    (path,) = tmp_path.iterdir()
    assert path.name == f"{ARTIFACT_PREFIX}bandwidth_sweep.json"
    payload = json.loads(path.read_text())
    validate_payload(path.stem, payload)
    codecs = {r["codec"] for r in payload["rows"]}
    assert codecs >= {"identity", "int8", "topk-sparse", "auto"}
    assert payload["claims"]["int8_vs_identity_at_min_bw"] >= 1.0
    assert payload["claims"]["auto_vs_identity_at_min_bw"] >= 1.5
    assert 0.95 <= payload["claims"]["worst_vs_predicted"]
    assert payload["claims"]["best_vs_predicted"] <= 1.05


def test_algo_scaling_benchmark_emits_a_valid_canonical_artifact(
        tmp_path, monkeypatch):
    """End to end (shrunk sweep): the algo-scaling benchmark writes one
    schema-valid BENCH_ artifact with flat AND hierarchical placement rows,
    and its claims pin near-linear hierarchical scaling."""
    from benchmarks import algo_scaling

    monkeypatch.setattr(common, "RESULTS_DIR", tmp_path)
    algo_scaling.run(partition_layers=(64,),
                     placement_nodes=(16, 64, 128), flat_cap=16)
    (path,) = tmp_path.iterdir()
    assert path.name == f"{ARTIFACT_PREFIX}algo_scaling.json"
    payload = json.loads(path.read_text())
    validate_payload(path.stem, payload)
    algos = {r["algo"] for r in payload["rows"] if r["stage"] == "placement"}
    assert algos == {"flat", "hierarchical"}
    assert all(r["feasible"] for r in payload["rows"])
    claims = payload["claims"]
    assert claims["hier_nodes_hi"] == 128
    assert claims["hier_ratio"] <= claims["scaling_ratio_max"]


def test_approx_ratio_hierarchical_rows_pin_quality(tmp_path, monkeypatch):
    """End to end (shrunk trials): the approx-ratio harness emits
    hierarchical rows measured against the exact subset-DP oracle, with
    claims bounding the degradation."""
    from benchmarks import approx_ratio

    monkeypatch.setattr(common, "RESULTS_DIR", tmp_path)
    payload = approx_ratio.run(trials=4)
    validate_payload("approx_ratio", payload)
    hier = [r for r in payload["rows"] if r["algo"].startswith("hierarchical")]
    assert hier, "no hierarchical rows emitted"
    claims = payload["claims"]
    assert claims["hier_mean_ratio"] <= claims["hier_mean_ratio_max"]
    assert claims["hier_worst_ratio"] <= claims["hier_worst_ratio_max"]
    # every hierarchical row is oracle-bounded: ratio >= 1 by optimality
    assert all(r["mean_ratio"] >= 1.0 - 1e-9 for r in hier)


def test_latency_pareto_benchmark_emits_a_valid_canonical_artifact(
        tmp_path, monkeypatch):
    """End to end: the open-loop latency pareto writes one schema-valid
    BENCH_ artifact whose claims pin the saturation behavior -- bounded
    p99 with rejected overflow past capacity, and the autoscaler beating
    the fixed single replica by >= 1.5x on the bursty trace."""
    from benchmarks import latency_pareto

    monkeypatch.setattr(common, "RESULTS_DIR", tmp_path)
    latency_pareto.run(duration_s=1.0)
    (path,) = tmp_path.iterdir()
    assert path.name == f"{ARTIFACT_PREFIX}latency_pareto.json"
    payload = json.loads(path.read_text())
    validate_payload(path.stem, payload)
    loads = [r["load_x"] for r in payload["rows"]]
    assert min(loads) < 1.0 < max(loads), "sweep must straddle saturation"
    assert payload["claims"]["overload_rejects"] > 0
    assert payload["claims"]["underload_rejects"] == 0
    assert payload["claims"]["worst_p99_ms"] <= payload["claims"]["p99_bound_ms"]
    assert payload["claims"]["autoscale_gain"] >= 1.5
    assert payload["serving"]["max_batch"] >= 1


def test_multi_tenant_benchmark_emits_a_valid_canonical_artifact(
        tmp_path, monkeypatch):
    """End to end: the multi-tenant benchmark writes one schema-valid BENCH_
    artifact whose claims pin the tenancy acceptance criteria -- each
    co-located tenant >= 70% of its solo throughput, and churn on one
    tenant's slice moving the other's completion cadence < 5%."""
    from benchmarks import multi_tenant

    monkeypatch.setattr(common, "RESULTS_DIR", tmp_path)
    multi_tenant.run(requests=24)
    (path,) = tmp_path.iterdir()
    assert path.name == f"{ARTIFACT_PREFIX}multi_tenant.json"
    payload = json.loads(path.read_text())
    validate_payload(path.stem, payload)
    assert {r["tenant"] for r in payload["rows"]} == {"alpha", "beta"}
    assert payload["claims"]["min_retention"] >= 0.70
    assert payload["claims"]["beta_cadence_drift"] <= 0.05
    assert payload["claims"]["alpha_replanned"] is True
    assert payload["claims"]["beta_untouched"] is True
    assert payload["cluster"]["policy"] == "partition"


def test_kernel_path_benchmark_emits_a_valid_canonical_artifact(
        tmp_path, monkeypatch):
    """End to end: the kernel fast-path gate writes one schema-valid BENCH_
    artifact whose rows pin kernel-vs-ref parity (int8 round-trip within
    INT8_MAX_REL_ERROR, flash within its documented bound, fused == unfused
    dequant-matmul) and fused <= unfused service time.  run() raises on any
    violated pin, so a green artifact IS the acceptance evidence."""
    from benchmarks import kernel_path

    monkeypatch.setattr(common, "RESULTS_DIR", tmp_path)
    # loose timing slack: tier-1 pins schema + numerics; the tight 1.05
    # timing gate runs in CI's dedicated benchmark step with full reps
    payload = kernel_path.run(reps=3, timing_slack=2.0)
    (path,) = tmp_path.iterdir()
    assert path.name == f"{ARTIFACT_PREFIX}kernel_path.json"
    disk = json.loads(path.read_text())
    validate_payload(path.stem, disk)
    checks = {r["check"]: r for r in disk["rows"]}
    assert set(checks) == {
        "int8_roundtrip_rel_err", "flash_interpret_max_abs_err",
        "fused_vs_unfused_rel_err", "fused_pallas_interpret_rel_err",
        "e2e_pallas_vs_ref_rel_err", "fused_over_unfused_time_ratio",
    }
    assert all(r["ok"] for r in disk["rows"])
    assert checks["int8_roundtrip_rel_err"]["bound"] == payload[
        "int8_max_rel_error"]
    assert disk["fused_ms"] > 0 and disk["unfused_ms"] > 0


def test_deployment_metrics_are_normalized_json(tmp_path):
    """The metrics facades run through ``normalize_metrics``: every dict key
    is a str and the whole payload survives a strict-JSON round trip
    unchanged -- pinned here so artifact consumers can rely on the schema."""
    from repro.api import ClusterSpec, DeploymentSpec, deploy
    from repro.cluster.serving import normalize_metrics

    spec = DeploymentSpec(
        model="demo_mlp",
        cluster=ClusterSpec(n_nodes=8, capacity_bytes=11_000, seed=0),
    )
    d = deploy(spec, store_root=str(tmp_path))
    import jax.numpy as jnp

    for _ in range(4):
        d.submit(jnp.ones((32,)) * 0.1)
    d.drain()
    m = d.metrics()

    def walk(value, where="$"):
        if isinstance(value, dict):
            for k, v in value.items():
                assert isinstance(k, str), f"non-str key {k!r} at {where}"
                walk(v, f"{where}.{k}")
        elif isinstance(value, list):
            for i, v in enumerate(value):
                walk(v, f"{where}[{i}]")
        else:
            assert isinstance(value, (str, int, float, bool, type(None))), (
                f"non-JSON leaf {type(value).__name__} at {where}")

    walk(m)
    # strict JSON round trip is the identity on a normalized payload
    assert json.loads(json.dumps(m, allow_nan=False)) == m
    # normalization is idempotent
    assert normalize_metrics(m) == m


def test_every_benchmark_declares_its_artifact_name():
    """run.py (and the CI upload step) resolve artifact paths through each
    module's ARTIFACT constant -- the single source of the basename."""
    import importlib

    for mod in ("algo_scaling", "approx_ratio", "bandwidth_sweep",
                "churn_throughput", "fig3_bottleneck", "joint_opt",
                "kernel_bench", "kernel_path", "latency_pareto",
                "multi_tenant", "observability", "replica_scaling",
                "throughput_scaling"):
        m = importlib.import_module(f"benchmarks.{mod}")
        assert isinstance(m.ARTIFACT, str) and m.ARTIFACT, mod


def test_every_artifact_module_is_registered_in_the_driver():
    """Every benchmarks/*.py that declares an ARTIFACT must be wired into
    run.py's registry -- a benchmark that exists but never runs is a silent
    coverage hole (and its artifact silently goes stale)."""
    import importlib

    from benchmarks.run import bench_registry

    bench_dir = Path(__file__).resolve().parent.parent / "benchmarks"
    declared = set()
    for p in sorted(bench_dir.glob("*.py")):
        if p.stem in ("common", "run", "__init__"):
            continue
        if "ARTIFACT = " not in p.read_text():
            continue
        declared.add(importlib.import_module(f"benchmarks.{p.stem}").ARTIFACT)
    registered = {module.ARTIFACT for module, _ in bench_registry().values()}
    missing = declared - registered
    assert not missing, f"benchmarks not wired into run.py: {sorted(missing)}"


# ---------------------------------------------------------------------------
# Schema validator
# ---------------------------------------------------------------------------

def test_validator_accepts_a_typical_payload():
    validate_payload("ok", {
        "rows": [{"nodes": 3, "tp": 1.5, "label": "a", "ok": True},
                 {"nodes": 4, "tp": 2.5, "label": "b", "ok": False}],
        "claims": {"max": 2.5},
        "nested": {"list": [1, 2, [3, 4]], "none": None},
    })


def test_validator_rejects_non_finite_numbers():
    with pytest.raises(PayloadSchemaError, match="non-finite"):
        validate_payload("bad", {"x": float("nan")})
    with pytest.raises(PayloadSchemaError, match="non-finite"):
        validate_payload("bad", {"rows": [{"v": math.inf}]})


def test_validator_rejects_ragged_rows():
    with pytest.raises(PayloadSchemaError, match="ragged"):
        validate_payload("bad", {"rows": [{"a": 1}, {"a": 1, "b": 2}]})


def test_validator_rejects_non_json_leaves_and_non_dict_payloads():
    with pytest.raises(PayloadSchemaError, match="non-JSON leaf"):
        validate_payload("bad", {"x": object()})
    with pytest.raises(PayloadSchemaError, match="must be a dict"):
        validate_payload("bad", [1, 2, 3])
    with pytest.raises(PayloadSchemaError, match="non-empty"):
        validate_payload("bad", {"rows": []})


def test_save_refuses_invalid_payloads(tmp_path, monkeypatch):
    monkeypatch.setattr(common, "RESULTS_DIR", tmp_path)
    with pytest.raises(PayloadSchemaError):
        save("bad", {"x": float("inf")})
    assert list(tmp_path.iterdir()) == []  # nothing half-written


def test_save_coerces_numpy_scalars(tmp_path, monkeypatch):
    np = pytest.importorskip("numpy")
    monkeypatch.setattr(common, "RESULTS_DIR", tmp_path)
    path = save("np", {
        "rows": [{"n": np.int64(3), "v": np.float64(1.5)}],
        "arr": np.arange(3),
    })
    data = json.loads(path.read_text())
    assert data["rows"] == [{"n": 3, "v": 1.5}] and data["arr"] == [0, 1, 2]
