"""Replica-set planner properties: the split, the plans, and ``"auto"``.

Driven through the ``_hypothesis_compat`` shim over randomly generated
chains and wireless clusters:

  * ``split_cluster`` partitions exactly the hosting nodes into R disjoint,
    balanced groups (the dispatcher never joins a group);
  * every feasible per-replica plan obeys the same structural invariants the
    single-pipeline property suite pins (contiguous, exhaustive, within
    capacity) and places strictly inside its own group -- paths are pairwise
    node-disjoint across replicas;
  * ``replicas="auto"`` never predicts less aggregate throughput than
    ``replicas=1`` on any cluster where the single pipeline is feasible
    (R=1 is always in auto's candidate set, so width only ever helps).
"""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.api import Planner, split_cluster, subcluster
from repro.core.graph import chain
from repro.core.simulate import random_cluster

SIZES = st.lists(
    st.tuples(st.integers(1, 50), st.integers(1, 1000)), min_size=2, max_size=8
)


def _planner():
    return Planner()  # registry defaults: min_bottleneck + color_coding


# ---------------------------------------------------------------------------
# The split itself
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(n_nodes=st.integers(2, 14), replicas=st.integers(1, 5),
       seed=st.integers(0, 10_000))
def test_split_cluster_partitions_hosting_nodes(n_nodes, replicas, seed):
    comm = random_cluster(n_nodes, 1000.0, seed=seed)
    hosting = [i for i in range(comm.n) if comm.node_capacity[i] > 0]
    if replicas > len(hosting):
        with pytest.raises(ValueError):
            split_cluster(comm, replicas, dispatcher=0)
        return
    groups = split_cluster(comm, replicas, dispatcher=0)
    assert len(groups) == replicas
    flat = [node for g in groups for node in g]
    assert sorted(flat) == sorted(hosting), "groups must tile the hosting nodes"
    assert 0 not in flat, "the dispatcher never joins a group"
    sizes = [len(g) for g in groups]
    assert max(sizes) - min(sizes) <= 1, "groups must stay balanced"
    # the masked view really is the group: no capacity, no links outside
    for g in groups:
        sub = subcluster(comm, g, keep=(0,))
        outside = set(range(comm.n)) - set(g) - {0}
        for i in outside:
            assert sub.node_capacity[i] == 0.0
            assert not np.any(sub.bw[i, :]) and not np.any(sub.bw[:, i])
        assert sub.node_capacity[0] <= 0.0, "dispatcher may not host"


# ---------------------------------------------------------------------------
# Per-replica plans: same invariants as the single-pipeline property suite
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(sizes=SIZES, n_nodes=st.integers(4, 10), replicas=st.integers(2, 3),
       seed=st.integers(0, 10_000), cap_scale=st.integers(2, 5))
def test_replica_plans_pass_partition_and_placement_invariants(
        sizes, n_nodes, replicas, seed, cap_scale):
    g = chain("prop", sizes)
    cap = max(l.param_bytes for l in g.layers) * cap_scale
    comm = random_cluster(n_nodes, float(cap), seed=seed)
    rp = _planner().plan_replicated(
        g, comm, replicas=replicas, dispatcher=0, device_flops=1e9,
    )
    if not rp.feasible:
        return
    assert rp.n_replicas == replicas
    seen_nodes = set()
    for plan, group in zip(rp.replicas, rp.groups):
        parts = plan.partition.partitions
        # contiguous + exhaustive + within capacity (the single-pipeline
        # invariants from test_partitioner_properties, per replica)
        assert parts[0].start == 0 and parts[-1].stop == len(g)
        for a, b in zip(parts, parts[1:]):
            assert a.stop == b.start
        for p in parts:
            assert p.stop > p.start
            assert p.param_bytes == g.segment_param_bytes(p.start, p.stop)
            assert p.param_bytes <= cap
        # placement stays inside the replica's own group, injectively
        path = list(plan.path)
        assert len(path) == len(set(path))
        assert set(path) <= set(group), "placed outside the replica's group"
        assert seen_nodes.isdisjoint(path), "replicas share a node"
        seen_nodes.update(path)


# ---------------------------------------------------------------------------
# "auto" never loses to a single pipeline
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(sizes=SIZES, n_nodes=st.integers(3, 10), seed=st.integers(0, 10_000),
       cap_scale=st.integers(2, 6))
def test_auto_replicas_never_below_single_pipeline(sizes, n_nodes, seed,
                                                   cap_scale):
    g = chain("prop", sizes)
    cap = max(l.param_bytes for l in g.layers) * cap_scale
    comm = random_cluster(n_nodes, float(cap), seed=seed)
    planner = _planner()
    single = planner.plan_replicated(
        g, comm, replicas=1, dispatcher=0, device_flops=1e9,
    )
    if not single.feasible:
        return
    auto = planner.plan_replicated(
        g, comm, replicas="auto", dispatcher=0, device_flops=1e9,
    )
    assert auto.feasible, "R=1 is a feasible candidate, auto may not fail"
    assert auto.predicted_throughput >= single.predicted_throughput * (1 - 1e-9)
