"""Cluster-wide replica router: conservation, fairness, and queue bounds.

Property suite (via the ``_hypothesis_compat`` shim, so it runs with real
hypothesis or the deterministic fallback) over the ``ReplicatedServingLoop``:

  * **request conservation** -- across random replica counts and random
    churn (node kills incl. whole-replica retirement, link degradations,
    rolling version bumps), every admitted request is in exactly one place
    at every step and eventually completes or is failed with its attempt
    budget exhausted;
  * **no starvation** -- on a healthy symmetric cluster every replica
    receives dispatches and completes requests (shortest-expected-wait must
    not fixate);
  * **bounded queues** -- each replica's undelivered backlog never exceeds
    ``replica_backlog`` and each stage's in-queue never exceeds
    ``queue_depth``; overflow waits in the cluster-wide queue
    (backpressure), it is never dropped;
  * **routing policy** -- on an asymmetric cluster the faster replica gets
    more work.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from _router_helpers import assert_router_conserved

from repro.api import ClusterSpec, DeploymentSpec, deploy
from repro.cluster import LinkDegraded, NodeFailed
from repro.cluster.engine import ReplicatedServingLoop
from repro.core.graph import Layer, LayerGraph
from repro.core.placement import CommGraph

N_LAYERS = 6
PARAM = 1_000_000
ACT = 120_000
FLOPS = 8_000_000
CAPACITY = 2 * PARAM * 1.05  # 2 layers per node -> 3-part pipelines


def _graph(flops=FLOPS):
    layers = tuple(
        Layer(f"l{i}", param_bytes=PARAM, out_bytes=ACT, flops=flops)
        for i in range(N_LAYERS)
    )
    return LayerGraph("router6", layers, in_bytes=ACT // 2)


def _symmetric_comm(n_hosting, bw=15e6):
    mat = np.full((n_hosting + 1, n_hosting + 1), float(bw))
    np.fill_diagonal(mat, 0.0)
    cap = np.full(n_hosting + 1, CAPACITY)
    cap[0] = -1.0  # dispatcher hosts nothing
    return CommGraph(bw=mat, node_capacity=cap)


def _deploy(replicas, group_size, *, seed=0, microbatch=1, flops=FLOPS):
    spec = DeploymentSpec(
        model=_graph(flops),
        cluster=ClusterSpec(comm=_symmetric_comm(replicas * group_size)),
        capacity=CAPACITY,
        seed=seed,
        microbatch=microbatch,
        replicas=replicas,
    )
    return deploy(spec)


# ---------------------------------------------------------------------------
# Conservation under random replica counts + churn
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000), replicas=st.integers(2, 3))
def test_request_conservation_under_random_replica_churn(seed, replicas):
    d = _deploy(replicas, group_size=4, seed=seed % 97, microbatch=2)
    rset = d.replicaset
    rng = np.random.default_rng(seed)
    n = 40
    ids = [d.submit(jnp.ones((4,))).req_id for _ in range(n)]
    events = 0
    steps = 0
    while d.loop.backlog or d.pending:
        steps += 1
        assert steps < 10_000, "router did not drain"
        if events < 6 and rng.random() < 0.2:
            events += 1
            roll = rng.random()
            if roll < 0.5:
                # kill anywhere except the last group (liveness floor): this
                # may retire whole replicas, which must also conserve
                victims = [
                    node for g in rset.groups[:-1] for node in g
                    if d.cluster.nodes[node].healthy
                ]
                if victims:
                    d.inject(NodeFailed(int(rng.choice(victims))))
            elif roll < 0.8:
                a, b = (int(x) for x in rng.choice(d.cluster.n, 2, replace=False))
                d.inject(LinkDegraded(a, b, float(rng.uniform(0.3, 0.8))))
            else:
                latest = max(c.desired.version for c in rset.controls)
                d.store.publish(latest + 1)
                d.poll_model_updates()
        d.step()
        assert_router_conserved(d, ids)
    assert events > 0 or not rset.retired[0]  # scenario sanity
    assert len(d.loop.completed) + len(d.loop.failed) == n
    # the only way out without completing is an exhausted attempt budget
    for req in d.loop.failed:
        assert req.attempts >= d.loop.max_attempts
    # the protected last replica never retired, so the set stayed live
    assert not rset.retired[-1]


# ---------------------------------------------------------------------------
# No starvation of any healthy replica
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 1_000), replicas=st.integers(2, 4))
def test_no_starvation_of_any_healthy_replica(seed, replicas):
    d = _deploy(replicas, group_size=3, seed=seed % 13)
    n = 30 * replicas
    for _ in range(n):
        d.submit(jnp.ones((4,)))
    d.drain()
    assert len(d.loop.completed) == n and not d.loop.failed
    assert all(count > 0 for count in d.loop.dispatched)
    for sub in d.loop.loops:
        # symmetric cluster: every replica carries a fair share of the load
        assert len(sub.completed) >= n // (4 * replicas)
    assert all(r.replica is not None for r in d.loop.completed)


# ---------------------------------------------------------------------------
# Bounded per-replica queues + backpressure
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("replica_backlog", [2, 5])
def test_replica_backlog_and_stage_queues_bounded(replica_backlog):
    d = _deploy(2, group_size=3)
    d.loop = ReplicatedServingLoop(
        d.replicaset, microbatch=1, queue_depth=2,
        replica_backlog=replica_backlog,
    )
    n = 40
    for _ in range(n):
        d.submit(jnp.ones((4,)))
    saw_backpressure = False
    while d.loop.backlog:
        d.step()
        for sub in d.loop.loops:
            assert sub.backlog <= replica_backlog
            for stage in sub._stages:
                assert len(stage.queue) + stage.reserved <= 2
        if d.loop.queue:
            saw_backpressure = True  # overflow held centrally, not dropped
    assert saw_backpressure
    assert len(d.loop.completed) == n and not d.loop.failed


# ---------------------------------------------------------------------------
# Shortest-expected-wait routing
# ---------------------------------------------------------------------------

def test_router_prefers_the_faster_replica():
    """Two replicas, one with 8x slower links on a link-bound model: the
    shortest-expected-wait policy must route the slow replica less work."""
    n_hosting = 6
    fast, slow = {1, 2, 3}, {4, 5, 6}
    bw = np.full((n_hosting + 1, n_hosting + 1), 16e6)
    for i in range(n_hosting + 1):
        for j in range(n_hosting + 1):
            if i in slow or j in slow:
                bw[i, j] = 2e6
    np.fill_diagonal(bw, 0.0)
    cap = np.full(n_hosting + 1, CAPACITY)
    cap[0] = -1.0
    spec = DeploymentSpec(
        model=_graph(flops=0),  # link-bound: stage compute is free
        cluster=ClusterSpec(comm=CommGraph(bw=bw, node_capacity=cap)),
        capacity=CAPACITY,
        microbatch=1,
        replicas=2,
    )
    d = deploy(spec)
    groups = [set(g) for g in d.replicaset.groups]
    assert sorted(map(sorted, groups)) == [sorted(fast), sorted(slow)], (
        "bandwidth-aware split should separate the cliques"
    )
    fast_idx = next(i for i, g in enumerate(groups) if g == fast)
    n = 80
    for _ in range(n):
        d.submit(jnp.ones((4,)))
    d.drain()
    assert len(d.loop.completed) == n and not d.loop.failed
    counts = d.loop.dispatched
    assert counts[fast_idx] > counts[1 - fast_idx], counts
