"""Discrete-event pipelined serving engine: virtual-clock invariants.

  * steady-state throughput pins to the Planner's bottleneck prediction
    (within 5%), for both link-bound and compute-bound pipelines;
  * no request is lost or duplicated under arbitrary event sequences
    (node kills, version bumps, link degradations, unannounced failures);
  * backpressure bounds every stage queue at ``queue_depth``;
  * in-flight requeue hits exactly the batches resident on affected stages;
  * the pipelined engine beats the synchronous baseline by >= 2x at >= 8
    partitions (the paper's 200% claim, pinned as a test).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ClusterSpec, DeploymentSpec, deploy
from repro.cluster import LinkDegraded, NodeFailed
from repro.cluster.engine import PipelinedServingLoop
from repro.core.graph import Layer, LayerGraph
from repro.core.model_zoo import demo_mlp


def _synth_graph(n_layers=16, param=1_000_000, act=200_000, flops=50_000_000):
    layers = tuple(
        Layer(f"l{i}", param_bytes=param, out_bytes=act, flops=flops)
        for i in range(n_layers)
    )
    return LayerGraph(f"synth{n_layers}", layers, in_bytes=act // 2)


def _deploy(graph, *, n_nodes=10, parts_cap_frac=None, seed=0, serving="pipelined",
            microbatch=1, queue_depth=2, **kw):
    capacity = (
        graph.total_param_bytes * parts_cap_frac
        if parts_cap_frac is not None
        else graph.total_param_bytes / 6
    )
    spec = DeploymentSpec(
        model=graph,
        cluster=ClusterSpec(n_nodes=n_nodes, capacity_bytes=capacity, seed=seed + 3),
        capacity=capacity,
        seed=seed,
        microbatch=microbatch,
        serving=serving,
        queue_depth=queue_depth,
        **kw,
    )
    return deploy(spec)


# ---------------------------------------------------------------------------
# Throughput pins to the Planner's prediction
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_steady_state_throughput_matches_planner_prediction(seed):
    """Measured steady-state rate == 1/bottleneck predicted by the Planner
    (same service_times model, same probed bandwidths) within 5%."""
    d = _deploy(_synth_graph(), seed=seed)
    for _ in range(150):
        d.submit(jnp.ones((4,)))
    d.drain()
    assert not d.loop.failed
    measured = d.loop.steady_state_throughput()
    predicted = d.plan.predicted_throughput  # microbatch==1: same units
    assert measured == pytest.approx(predicted, rel=0.05)


def test_link_bound_pipeline_also_pins_to_prediction():
    """flops=0 makes every stage free: the bottleneck is a link."""
    d = _deploy(_synth_graph(flops=0), seed=1)
    for _ in range(150):
        d.submit(jnp.ones((4,)))
    d.drain()
    measured = d.loop.steady_state_throughput()
    assert measured == pytest.approx(d.plan.predicted_throughput, rel=0.05)
    # sanity: the prediction really is the bottleneck-hop rate
    m = d.loop.metrics()
    bottleneck = max(max(m["link_s"]), max(s["compute_s"] for s in m["stages"]))
    assert measured == pytest.approx(1.0 / bottleneck, rel=0.05)


# ---------------------------------------------------------------------------
# Conservation: no request lost or duplicated
# ---------------------------------------------------------------------------

def _conservation(loop, submitted):
    done_ids = [r.req_id for r in loop.completed]
    failed_ids = [r.req_id for r in loop.failed]
    queued_ids = [r.req_id for r in loop.queue]
    inflight_ids = [r.req_id for mb in loop._inflight for r in mb.requests]
    everything = done_ids + failed_ids + queued_ids + inflight_ids
    assert len(everything) == len(set(everything)), "request duplicated"
    assert sorted(everything) == sorted(submitted), "request lost"


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_no_request_lost_or_duplicated_under_random_events(seed):
    """Arbitrary interleavings of kills/degradations/version bumps while
    the pipe is full: every admitted request stays accounted for, and all
    of them eventually complete."""
    graph, executor_for_version = demo_mlp(d=16)
    d = _deploy(graph, n_nodes=8, parts_cap_frac=1 / 3, seed=seed,
                microbatch=2, executor_for_version=executor_for_version)
    rng = np.random.default_rng(seed)
    n = 60
    ids = [d.submit(jnp.ones((16,)) * 0.1).req_id for _ in range(n)]
    events = 0
    while d.loop.backlog or d.control.pending:
        if rng.random() < 0.15 and events < 8:
            events += 1
            roll = rng.random()
            pods = d.control.pipeline.pods
            if roll < 0.4:
                d.inject(NodeFailed(pods[rng.integers(len(pods))].node_id))
            elif roll < 0.6:
                victim = pods[rng.integers(len(pods))].node_id
                d.control.cluster.fail(victim)  # unannounced: no event
                d.control.pipeline.mark_node_failed(victim)
            elif roll < 0.8:
                a, b = rng.choice(d.cluster.n, size=2, replace=False)
                d.inject(LinkDegraded(int(a), int(b), 0.5))
            else:
                d.store.publish(d.observed().version + 1)
                d.poll_model_updates()
        d.step()
        _conservation(d.loop, ids)
    assert events > 0
    assert len(d.loop.completed) == n
    assert not d.loop.failed
    # completions carry the CURRENT version's math at completion time: check
    # the last request against the final deployed version's reference
    version = d.observed().version
    x = jnp.ones((16,)) * 0.1
    ws = np.asarray(jax.random.normal(jax.random.PRNGKey(version), (8, 16, 16)) * 0.3)
    for w in ws:
        x = jnp.tanh(x @ w)
    np.testing.assert_allclose(
        np.asarray(d.loop.completed[-1].result), np.asarray(x), rtol=1e-5
    )


# ---------------------------------------------------------------------------
# Backpressure
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("queue_depth", [1, 2, 4])
def test_backpressure_bounds_every_queue(queue_depth):
    """With a slow bottleneck stage and a deep backlog, no stage's in-queue
    (incl. reserved in-transit slots) ever exceeds queue_depth."""
    # last stage is the bottleneck: cheap links, one expensive compute
    layers = [Layer(f"l{i}", 1_000_000, 10_000, flops=1_000_000) for i in range(11)]
    layers.append(Layer("heavy", 1_000_000, 10_000, flops=500_000_000))
    graph = LayerGraph("skewed", tuple(layers), in_bytes=10_000)
    d = _deploy(graph, n_nodes=8, parts_cap_frac=1 / 4, seed=2,
                queue_depth=queue_depth)
    for _ in range(80):
        d.submit(jnp.ones((4,)))
    while d.loop.backlog:
        d.step()
        for st in d.loop._stages:
            assert len(st.queue) + st.reserved <= queue_depth
    m = d.loop.metrics()
    assert all(s["max_queue"] <= queue_depth for s in m["stages"])
    # the bottleneck stage saturates; everyone upstream is throttled to it
    occ = [s["occupancy"] for s in m["stages"]]
    assert max(occ) > 0.9


# ---------------------------------------------------------------------------
# Requeue granularity: exactly the affected stages
# ---------------------------------------------------------------------------

def test_requeue_hits_only_batches_on_affected_stages():
    graph, executor_for_version = demo_mlp(d=16)
    d = _deploy(graph, n_nodes=8, parts_cap_frac=1 / 3, seed=0,
                microbatch=1, executor_for_version=executor_for_version)
    loop = d.loop
    n = 30
    for _ in range(n):
        d.submit(jnp.ones((16,)) * 0.1)
    # fill the pipe, then kill the node hosting stage 1 mid-flight
    while len(loop.completed) < n // 3:
        d.step()
    pods = d.control.pipeline.pods
    victim_stage = 1
    victim = pods[victim_stage].node_id
    k = len(pods)
    resident = set()
    for mb in loop._inflight:
        kind, idx = mb.location
        if kind == "link":
            # hop 0 is a free retransmission (dispatcher still holds the
            # input), so only hops adjacent to the victim stage count
            touches = idx > 0 and (
                (idx - 1) == victim_stage or (idx < k and idx == victim_stage)
            )
        else:
            touches = idx == victim_stage
        if touches:
            resident.update(r.req_id for r in mb.requests)
    spared = {
        r.req_id for mb in loop._inflight for r in mb.requests
        if r.req_id not in resident
    }
    d.inject(NodeFailed(victim))
    d.step()
    everywhere = (
        list(loop.queue) + loop.completed
        + [r for mb in loop._inflight for r in mb.requests]
    )
    retried = {r.req_id for r in everywhere if r.attempts > 0}
    assert retried == resident  # exactly the affected batches, no others
    assert all(r.attempts == 0 for r in everywhere if r.req_id in spared)
    d.drain()
    assert len(loop.completed) == n and not loop.failed


def test_version_bump_requeues_everything_in_flight():
    """A version bump replaces weights everywhere: every stage is affected,
    so every in-flight batch restarts and is recomputed with v1 math."""
    graph, executor_for_version = demo_mlp(d=16)
    d = _deploy(graph, n_nodes=8, parts_cap_frac=1 / 3, seed=0,
                microbatch=1, executor_for_version=executor_for_version)
    n = 24
    for _ in range(n):
        d.submit(jnp.ones((16,)) * 0.1)
    while len(d.loop.completed) < n // 2:
        d.step()
    # batches on the input hop are free retransmissions, not retries
    inflight = [
        r.req_id for mb in d.loop._inflight for r in mb.requests
        if mb.location != ("link", 0)
    ]
    assert inflight  # the pipe is genuinely full mid-bump
    d.store.publish(1)
    d.poll_model_updates()
    d.drain()
    assert len(d.loop.completed) == n and not d.loop.failed
    by_id = {r.req_id: r for r in d.loop.completed}
    assert all(by_id[i].attempts >= 1 for i in inflight)
    # everything completed after the bump used the v1 weights
    x = jnp.ones((16,)) * 0.1
    ws = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (8, 16, 16)) * 0.3)
    for w in ws:
        x = jnp.tanh(x @ w)
    for i in inflight:
        np.testing.assert_allclose(
            np.asarray(by_id[i].result), np.asarray(x), rtol=1e-5
        )


# ---------------------------------------------------------------------------
# The paper's claim: pipelining vs synchronous execution
# ---------------------------------------------------------------------------

def test_pipelined_at_least_2x_sync_at_8_partitions():
    graph = _synth_graph(n_layers=16, act=1_000_000, flops=2_000_000)
    rates = {}
    for serving in ("pipelined", "sync"):
        d = _deploy(graph, n_nodes=10, parts_cap_frac=2.1 / 16, seed=0,
                    serving=serving)
        assert d.plan.n_parts >= 8
        for _ in range(96):
            d.submit(jnp.ones((4,)))
        d.drain()
        assert not d.loop.failed
        loop = d.loop
        rates[serving] = (
            loop.steady_state_throughput()
            if isinstance(loop, PipelinedServingLoop)
            else loop.metrics()["throughput"]
        )
    assert rates["pipelined"] >= 2.0 * rates["sync"]


def test_out_of_band_reconcile_requeues_restarted_stages():
    """Calling Deployment.reconcile() directly (not via step) must still
    requeue the batches resident on pods that were restarted, at the next
    step -- the engine detects the pod-signature change."""
    graph, executor_for_version = demo_mlp(d=16)
    d = _deploy(graph, n_nodes=8, parts_cap_frac=1 / 3, seed=0,
                microbatch=1, executor_for_version=executor_for_version)
    n = 24
    ids = [d.submit(jnp.ones((16,)) * 0.1).req_id for _ in range(n)]
    while len(d.loop.completed) < n // 3:
        d.step()
    victim = d.control.pipeline.pods[1].node_id
    d.inject(NodeFailed(victim))
    d.reconcile()  # out of band: the serving loop is not in this call path
    assert any(p.restarts > 0 for p in d.control.pipeline.pods)
    d.drain()
    assert len(d.loop.completed) == n and not d.loop.failed
    assert sorted(r.req_id for r in d.loop.completed) == sorted(ids)
    assert d.loop._requeues >= 1  # the restarted stage's batch went back


def test_dead_link_bounds_retries_instead_of_hanging():
    """A transfer stuck on a zero-bandwidth hop can never finish; the engine
    must retry its riders (attempts -> failed) rather than stall a
    ``while backlog: step()`` loop forever."""
    graph, executor_for_version = demo_mlp(d=16)
    d = _deploy(graph, n_nodes=8, parts_cap_frac=1 / 3, seed=0,
                microbatch=1, executor_for_version=executor_for_version)
    loop = d.loop
    n = 12
    for _ in range(n):
        d.submit(jnp.ones((16,)) * 0.1)
    d.step()
    # the wire between stages 1 and 2 goes dark without any event or any
    # node becoming unhealthy -- the worst case for liveness
    loop._link_s[2] = float("inf")
    steps = 0
    while loop.backlog:
        steps += 1
        assert steps < 5_000, "engine hung on a dead link"
        d.step()
    assert len(loop.completed) + len(loop.failed) == n
    assert loop.failed  # the stalled riders were failed, not leaked
    assert all(r.attempts >= loop.max_attempts for r in loop.failed)


def test_engine_is_the_default_serving_mode():
    graph, executor_for_version = demo_mlp(d=16)
    d = _deploy(graph, n_nodes=8, parts_cap_frac=1 / 3,
                executor_for_version=executor_for_version)
    assert isinstance(d.loop, PipelinedServingLoop)
    assert d.metrics()["serving"]["mode"] == "pipelined"
    d2 = _deploy(graph, n_nodes=8, parts_cap_frac=1 / 3, serving="sync",
                 executor_for_version=executor_for_version)
    assert d2.metrics()["serving"]["mode"] == "sync"
