"""Property suite for the joint codec x placement planner (hypothesis shim).

Two guarantees the data plane's timing model was *designed* to make provable,
checked over every registered partitioner and every registered codec (plus
``"auto"``) on randomized link-bound clusters:

  * **bandwidth monotonicity** -- scaling every link bandwidth up never
    decreases ``Plan.predicted_throughput``: each hop's charged window
    (``encode + wire/bw + decode``) is non-increasing in bandwidth, stage
    computes are bandwidth-independent, and ``auto`` takes a per-hop min of
    non-increasing functions;
  * **auto never loses** -- enabling ``codec="auto"`` never predicts worse
    than ``identity`` (or any fixed codec): every fixed assignment is in
    auto's per-hop candidate set, and hop charges are independent, so the
    per-hop argmin dominates every uniform choice.

Runs through ``tests/_hypothesis_compat.py`` -- hypothesis itself is not
installed here, so the deterministic fallback replays a fixed sample.
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.api import Planner, list_strategies
from repro.core.model_zoo import demo_mlp
from repro.core.placement import CommGraph
from repro.dataplane import list_codecs

FLOPS = 1e9  # per node: codec compute participates in every charge


def _mesh(hosting: int, mesh_bw: float, rng: np.random.Generator) -> CommGraph:
    """Link-bound star+mesh with mild per-link jitter (still symmetric)."""
    n = hosting + 1
    jitter = rng.uniform(0.6, 1.4, size=(n, n))
    jitter = np.tril(jitter) + np.tril(jitter, -1).T
    bw = np.full((n, n), float(mesh_bw)) * jitter
    bw[0, :] = bw[:, 0] = 1e9
    np.fill_diagonal(bw, 0.0)
    graph, _ = demo_mlp()
    # 0.4 * total leaves packing slack so EVERY registered partitioner
    # (incl. paper_greedy's first-fit) finds a feasible multi-part split
    cap = np.full(n, 0.4 * graph.total_param_bytes)
    cap[0] = -1.0
    return CommGraph(bw=bw, node_capacity=cap)


def _throughput(partitioner: str, codec: str, comm: CommGraph) -> float:
    graph, _ = demo_mlp()
    planner = Planner(partitioner=partitioner, placer="greedy", codec=codec)
    plan = planner.plan(
        graph, comm, capacity=float(np.max(comm.node_capacity)),
        max_parts=comm.n, dispatcher=0, device_flops=FLOPS,
    )
    assert plan.feasible, (partitioner, codec)
    return plan.predicted_throughput


@pytest.mark.parametrize("partitioner", list_strategies("partitioner"))
def test_predicted_throughput_monotone_in_bandwidth(partitioner):
    """For every partitioner x codec pair: uniformly faster links never
    predict lower throughput."""

    @given(
        bw_exp=st.integers(3, 7),
        factor=st.integers(2, 16),
        seed=st.integers(0, 2**16),
    )
    @settings(max_examples=6, deadline=None)
    def check(bw_exp, factor, seed):
        rng = np.random.default_rng(seed)
        hosting = int(rng.integers(6, 9))
        lo = _mesh(hosting, 10.0 ** bw_exp, np.random.default_rng(seed))
        hi = CommGraph(bw=lo.bw * factor,
                       node_capacity=lo.node_capacity.copy())
        for codec in (*list_codecs(), "auto"):
            tp_lo = _throughput(partitioner, codec, lo)
            tp_hi = _throughput(partitioner, codec, hi)
            assert tp_hi >= tp_lo * (1 - 1e-9), (codec, tp_lo, tp_hi)

    check()


@pytest.mark.parametrize("partitioner", list_strategies("partitioner"))
def test_auto_never_predicts_worse_than_any_fixed_codec(partitioner):
    """Enabling codec="auto" never decreases predicted throughput relative
    to identity -- or to any other registered fixed codec."""

    @given(bw_exp=st.integers(3, 7), seed=st.integers(0, 2**16))
    @settings(max_examples=6, deadline=None)
    def check(bw_exp, seed):
        rng = np.random.default_rng(seed)
        hosting = int(rng.integers(6, 9))
        comm = _mesh(hosting, 10.0 ** bw_exp, np.random.default_rng(seed))
        tp_auto = _throughput(partitioner, "auto", comm)
        for codec in list_codecs():
            tp_fixed = _throughput(partitioner, codec, comm)
            assert tp_auto >= tp_fixed * (1 - 1e-9), (codec, tp_fixed, tp_auto)

    check()
