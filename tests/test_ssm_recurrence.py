"""Chunked-parallel vs step-recurrent consistency for the SSM/xLSTM towers.

``*_forward`` (chunked scan, used for train/prefill) and ``*_step`` (O(1)
decode) are independent implementations of the same recurrence; agreement
over a token-by-token replay validates both (this is also exactly the
prefill->decode handoff invariant the serving path relies on)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models import ssm as ssm_lib
from repro.models import xlstm as xlstm_lib


def test_mamba_forward_matches_steps():
    cfg = reduced(ARCHS["zamba2-2.7b"])
    p = ssm_lib.init_mamba(cfg, jax.random.PRNGKey(0))
    b, s = 2, 8
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model), jnp.float32).astype(jnp.bfloat16) * 0.5
    y_par = ssm_lib.mamba_forward(cfg, p, x, chunk=4)
    cache = ssm_lib.mamba_init_cache(cfg, b)
    outs = []
    for t in range(s):
        cache, y = ssm_lib.mamba_step(cfg, p, cache, x[:, t : t + 1])
        outs.append(y)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_par, np.float32), np.asarray(y_seq, np.float32),
        atol=3e-2, rtol=3e-2,
    )


def test_mamba_chunk_size_invariance():
    cfg = reduced(ARCHS["zamba2-2.7b"])
    p = ssm_lib.init_mamba(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 16, cfg.d_model)).astype(jnp.bfloat16)
    y1 = ssm_lib.mamba_forward(cfg, p, x, chunk=4)
    y2 = ssm_lib.mamba_forward(cfg, p, x, chunk=16)
    np.testing.assert_allclose(
        np.asarray(y1, np.float32), np.asarray(y2, np.float32), atol=2e-2, rtol=2e-2
    )


def test_mlstm_forward_matches_steps():
    cfg = reduced(ARCHS["xlstm-125m"])
    p = xlstm_lib.init_mlstm(cfg, jax.random.PRNGKey(0))
    b, s = 2, 8
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model)).astype(jnp.bfloat16) * 0.5
    y_par = xlstm_lib.mlstm_forward(cfg, p, x, chunk=4)
    cache = xlstm_lib.mlstm_init_cache(cfg, b)
    outs = []
    for t in range(s):
        cache, y = xlstm_lib.mlstm_step(cfg, p, cache, x[:, t : t + 1])
        outs.append(y)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_par, np.float32), np.asarray(y_seq, np.float32),
        atol=3e-2, rtol=3e-2,
    )


def test_slstm_forward_matches_steps():
    cfg = reduced(ARCHS["xlstm-125m"])
    p = xlstm_lib.init_slstm(cfg, jax.random.PRNGKey(0))
    b, s = 2, 6
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model)).astype(jnp.bfloat16) * 0.5
    y_fwd = xlstm_lib.slstm_forward(cfg, p, x)
    state = xlstm_lib.slstm_init_state(cfg, b)
    outs = []
    for t in range(s):
        state, y = xlstm_lib.slstm_step(cfg, p, state, x[:, t : t + 1])
        outs.append(y)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_fwd, np.float32), np.asarray(y_seq, np.float32),
        atol=3e-2, rtol=3e-2,
    )


def test_mamba_state_decay_property():
    """With zero input, the SSM state decays monotonically (A < 0)."""
    cfg = reduced(ARCHS["zamba2-2.7b"])
    p = ssm_lib.init_mamba(cfg, jax.random.PRNGKey(0))
    cache = ssm_lib.mamba_init_cache(cfg, 1)
    cache = dict(cache, ssm=jnp.ones_like(cache["ssm"]))
    x = jnp.zeros((1, 1, cfg.d_model), jnp.bfloat16)
    norms = []
    for _ in range(4):
        cache, _ = ssm_lib.mamba_step(cfg, p, cache, x)
        norms.append(float(jnp.sum(jnp.abs(cache["ssm"]))))
    assert norms[0] >= norms[-1]
