"""The roofline analyzer vs XLA's own cost analysis (oracle where valid).

XLA counts while bodies once; our analyzer multiplies by known_trip_count.
On scan-free programs the two must agree (bytes exactly; flops up to the
elementwise ops we deliberately exclude)."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze_hlo


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def _cost(compiled):
    """cost_analysis() returns a dict in newer jax, [dict] in older."""
    c = compiled.cost_analysis()
    return c[0] if isinstance(c, (list, tuple)) else c


def test_matches_xla_on_scan_free():
    def g(a, b):
        return (jnp.tanh(a @ b) @ b).sum()

    spec = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = _compile(g, spec, spec)
    ours = analyze_hlo(c.as_text())
    xla = _cost(c)
    # bytes agreement is fusion-dependent: our analyzer charges operands +
    # outputs per top-level instruction, so a more aggressively fusing XLA
    # build reports fewer bytes accessed than we do (same order, not equal)
    assert ours.bytes == pytest.approx(xla["bytes accessed"], rel=0.3)
    # ours counts MXU flops only; XLA adds elementwise -> ours <= xla, close
    assert ours.flops <= xla["flops"]
    assert ours.flops == pytest.approx(2 * 2 * 256**3, rel=0.01)


def test_scan_trip_count_multiplies():
    def f(x, w):
        def step(c, _):
            return jnp.tanh(c @ w), None

        y, _ = jax.lax.scan(step, x, None, length=12)
        return y.sum()

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = _compile(f, x, w)
    ours = analyze_hlo(c.as_text())
    expected = 12 * 2 * 64 * 128 * 128
    assert ours.flops == pytest.approx(expected, rel=0.02)
    # XLA's own count misses the trip multiplier
    assert _cost(c)["flops"] < expected / 4


def test_nested_scans_multiply():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None

            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None

        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y.sum()

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = _compile(f, x, w)
    ours = analyze_hlo(c.as_text())
    assert ours.flops == pytest.approx(15 * 2 * 32 * 64 * 64, rel=0.05)


def test_collectives_counted_with_trips():
    import os

    # needs >1 device; run only when the host is faking devices
    if jax.device_count() < 2:
        pytest.skip("single-device host")
    from jax.sharding import NamedSharding, PartitionSpec as P

    n = jax.device_count()
    mesh = jax.make_mesh((n,), ("m",))

    def f(x, w):
        def step(c, _):
            y = jax.lax.with_sharding_constraint(
                c @ w, NamedSharding(mesh, P(None, None))
            )
            return y, None

        y, _ = jax.lax.scan(step, x, None, length=4)
        return y

    xs = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    with mesh:
        c = (
            jax.jit(
                f,
                in_shardings=(
                    NamedSharding(mesh, P(None, None)),
                    NamedSharding(mesh, P(None, "m")),
                ),
            )
            .lower(xs, ws)
            .compile()
        )
    ours = analyze_hlo(c.as_text())
    assert ours.total_collective_bytes > 0
