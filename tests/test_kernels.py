"""Kernel correctness: shape/dtype sweeps against the pure-jnp oracles.

Covers the jnp blockwise flash attention (fwd + custom VJP), the Pallas TPU
kernel in interpret mode, and the int8 quantize/dequantize pair."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.flash_attention.kernel import flash_attention_tpu
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.quantize.kernel import dequantize_int8_tpu, quantize_int8_tpu
from repro.kernels.quantize.ref import dequantize_ref, quantize_ref


def _qkv(b, sq, skv, h, kh, hd, dtype, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (
        jax.random.normal(k1, (b, sq, h, hd), dtype),
        jax.random.normal(k2, (b, skv, kh, hd), dtype),
        jax.random.normal(k3, (b, skv, kh, hd), dtype),
    )


SWEEP = [
    # (b, sq, skv, h, kh, hd, causal, window, softcap, block, dtype)
    (2, 512, 512, 4, 2, 64, True, 0, 0.0, 128, jnp.float32),
    (1, 1024, 1024, 4, 4, 32, True, 0, 50.0, 256, jnp.float32),
    (2, 512, 512, 4, 1, 64, True, 200, 0.0, 128, jnp.float32),
    (2, 512, 512, 2, 2, 64, False, 0, 0.0, 128, jnp.float32),
    (1, 256, 768, 2, 2, 64, False, 0, 0.0, 128, jnp.float32),
    (1, 512, 512, 8, 2, 128, True, 0, 0.0, 128, jnp.bfloat16),
]


@pytest.mark.parametrize("layout", ["blocked", "grouped"])
@pytest.mark.parametrize("case", SWEEP)
def test_flash_forward_matches_ref(case, layout):
    b, sq, skv, h, kh, hd, causal, window, softcap, block, dtype = case
    q, k, v = _qkv(b, sq, skv, h, kh, hd, dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, block=block, layout=layout)
    ref = attention_ref(q, k, v, causal=causal, window=window, softcap=softcap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("layout", ["blocked", "grouped"])
@pytest.mark.parametrize("case", SWEEP[:5])
def test_flash_grads_match_ref(case, layout):
    """Gradient parity vs attention_ref for BOTH layouts.  The grouped leg
    pins the custom-VJP backward on grouped-layout residuals -- the path the
    dead identical-branch staging in ``bwd`` used to (not) special-case."""
    b, sq, skv, h, kh, hd, causal, window, softcap, block, dtype = case
    q, k, v = _qkv(b, sq, skv, h, kh, hd, jnp.float32)
    kw = dict(causal=causal, window=window, softcap=softcap)
    gf = jax.grad(lambda *a: (flash_attention(*a, block=block, layout=layout,
                                              **kw) ** 2).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(lambda *a: (attention_ref(*a, **kw) ** 2).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gf, gr):
        scale = max(1e-6, float(jnp.max(jnp.abs(b_))))
        assert float(jnp.max(jnp.abs(a - b_))) / scale < 1e-4


@pytest.mark.parametrize("case", SWEEP)
def test_flash_use_pallas_dispatch_matches_ref(case):
    """The ops-level ``use_pallas`` knob (interpret mode) stays within the
    documented forward tolerance vs attention_ref.  Cross-length shapes
    (sq != skv) silently take the jnp path -- the result must be equally
    correct either way, which is exactly what serving executors rely on."""
    b, sq, skv, h, kh, hd, causal, window, softcap, block, dtype = case
    q, k, v = _qkv(b, sq, skv, h, kh, hd, dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, block=block,
                          use_pallas=True, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window, softcap=softcap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("case", SWEEP)
def test_pallas_kernel_interpret_matches_ref(case):
    b, sq, skv, h, kh, hd, causal, window, softcap, block, dtype = case
    if sq != skv:
        pytest.skip("TPU kernel grid assumes aligned q/kv blocks")
    q, k, v = _qkv(b, sq, skv, h, kh, hd, dtype)
    out = flash_attention_tpu(q, k, v, causal=causal, window=window,
                              softcap=softcap, block_q=block, block_k=block,
                              interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window, softcap=softcap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# quantize
# ---------------------------------------------------------------------------

@given(
    rows=st.integers(1, 8),
    dblocks=st.integers(1, 6),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=25, deadline=None)
def test_quantize_roundtrip_bounded(rows, dblocks, seed):
    block = 128
    x = jax.random.normal(jax.random.PRNGKey(seed), (rows, dblocks * block), jnp.float32)
    q, s = quantize_ref(x, block)
    y = dequantize_ref(q, s, dtype=jnp.float32)
    # symmetric int8: error <= scale/2 per element (small f32 rounding slack:
    # the exact bound can overshoot by ~3e-6 relative on unlucky draws)
    bound = np.repeat(np.asarray(s), block, axis=-1) * 0.5 * (1 + 1e-4) + 1e-9
    assert np.all(np.abs(np.asarray(y - x)) <= bound)


QUANT_SWEEP = [
    # (rows, d, block, dtype) -- incl. ragged last blocks (d % block != 0)
    (4, 512, 128, jnp.float32),
    (4, 512, 128, jnp.bfloat16),
    (3, 300, 128, jnp.float32),  # ragged: last block 44 wide
    (3, 300, 128, jnp.bfloat16),
    (2, 37, 256, jnp.float32),  # ragged: d < block entirely
    (1, 129, 128, jnp.bfloat16),  # ragged: one element past the boundary
]


@pytest.mark.parametrize("case", QUANT_SWEEP)
def test_quantize_error_bound_matches_reported(case):
    """Round-trip error <= INT8_MAX_REL_ERROR * per-block max -- the SAME
    constant the data plane's int8 codec reports to the planner's
    accuracy_tolerance check, across dtypes and ragged last-block shapes."""
    from repro.kernels.quantize import INT8_MAX_REL_ERROR

    rows, d, block, dtype = case
    x = jax.random.normal(jax.random.PRNGKey(rows * d), (rows, d), dtype)
    q, s = quantize_ref(x, block)
    assert q.shape == x.shape and s.shape == (rows, -(-d // block))
    y = dequantize_ref(q, s, dtype=jnp.float32, block=block)
    xf = np.asarray(x, np.float32)
    # per-element bound: rel error wrt the element's own block max (small
    # f32 rounding slack, as in the scale/2 bound above)
    per_block_max = np.repeat(np.asarray(s) * 127.0, block, axis=-1)[:, :d]
    bound = INT8_MAX_REL_ERROR * per_block_max * (1 + 1e-4) + 1e-9
    assert np.all(np.abs(np.asarray(y) - xf) <= bound)
    # the data plane reports exactly this constant as the codec error bound
    from repro.dataplane import get_codec

    assert get_codec("int8").error_bound == INT8_MAX_REL_ERROR


@pytest.mark.parametrize("case", QUANT_SWEEP)
def test_quantize_pallas_interpret_matches_ref_sweep(case):
    """The Pallas kernel (interpret mode) agrees with the jnp oracle on the
    same dtype/ragged sweep: identical codes, identical scales."""
    rows, d, block, dtype = case
    x = jax.random.normal(jax.random.PRNGKey(7 + rows + d), (rows, d), dtype)
    q1, s1 = quantize_ref(x, block)
    q2, s2 = quantize_int8_tpu(x, block=block, interpret=True)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)
    y1 = dequantize_ref(q1, s1, dtype=jnp.float32, block=block)
    y2 = dequantize_int8_tpu(q2, s2, dtype=jnp.float32, block=block,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-6, atol=1e-8)


def test_dequantize_ragged_requires_block():
    """When the trailing dim does not divide the scale count, no block can
    be inferred -- refuse instead of silently misassigning scales.  (An
    evenly-dividing ragged shape is indistinguishable from a smaller-block
    legacy layout, which is why every codec caller passes block= always.)"""
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 301), jnp.float32)
    q, s = quantize_ref(x, 128)
    assert s.shape[-1] == 3  # ragged: 301 over 128-wide blocks
    with pytest.raises(ValueError, match="ragged"):
        dequantize_ref(q, s)
    assert dequantize_ref(q, s, block=128).shape == x.shape


def test_quantize_pallas_matches_ref():
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 64, 512), jnp.bfloat16)
    q1, s1 = quantize_ref(x, 128)
    q2, s2 = quantize_int8_tpu(x, block=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)
    y1 = dequantize_ref(q1, s1)
    y2 = dequantize_int8_tpu(q2, s2, interpret=True)
    np.testing.assert_allclose(
        np.asarray(y1, np.float32), np.asarray(y2, np.float32), rtol=1e-2, atol=1e-2
    )


# ---------------------------------------------------------------------------
# fused dequant-matmul
# ---------------------------------------------------------------------------

DQMM_SWEEP = [
    # (rows, d, dout, block, wdtype) -- incl. ragged trailing dims
    (16, 512, 64, 128, jnp.float32),
    (8, 300, 32, 128, jnp.float32),  # ragged: q cols + w rows get padded
    (4, 96, 48, 256, jnp.float32),  # ragged: d < block entirely
    (16, 512, 64, 128, jnp.bfloat16),
]


@pytest.mark.parametrize("case", DQMM_SWEEP)
def test_dequant_matmul_fused_matches_unfused(case):
    """The fused op computes EXACTLY dequantize-then-matmul (both f32): the
    fusion saves a materialized activation + dispatch, never accuracy."""
    from repro.kernels.quantize import dequant_matmul, dequantize_int8

    rows, d, dout, block, wdtype = case
    k1, k2 = jax.random.split(jax.random.PRNGKey(d + dout))
    x = jax.random.normal(k1, (rows, d), jnp.float32)
    w = jax.random.normal(k2, (d, dout), wdtype)
    q, s = quantize_ref(x, block)
    unfused = dequantize_int8(q, s, dtype=jnp.float32, block=block) @ w.astype(
        jnp.float32)
    fused = dequant_matmul(q, s, w, dtype=jnp.float32, block=block)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("case", DQMM_SWEEP)
def test_dequant_matmul_pallas_interpret_matches_ref(case):
    """Pallas dequant-matmul (interpret) vs the jnp oracle on the same
    shapes, including ragged trailing dims (zero-padded q cols keep the
    padded w rows inert)."""
    from repro.kernels.quantize import dequant_matmul

    rows, d, dout, block, wdtype = case
    k1, k2 = jax.random.split(jax.random.PRNGKey(3 * d + dout))
    x = jax.random.normal(k1, (rows, d), jnp.float32)
    w = jax.random.normal(k2, (d, dout), wdtype)
    q, s = quantize_ref(x, block)
    ref = dequant_matmul(q, s, w, dtype=jnp.float32, block=block)
    pal = dequant_matmul(q, s, w, dtype=jnp.float32, block=block,
                         use_pallas=True, interpret=True)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_dequant_matmul_leading_dims_and_default_dtype():
    """Leading batch dims flatten through the matmul; dtype defaults to w's."""
    from repro.kernels.quantize import dequant_matmul

    x = jax.random.normal(jax.random.PRNGKey(5), (3, 4, 256), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(6), (256, 32), jnp.bfloat16)
    q, s = quantize_ref(x, 128)
    out = dequant_matmul(q, s, w, block=128)
    assert out.shape == (3, 4, 32) and out.dtype == jnp.bfloat16
    pal = dequant_matmul(q, s, w, block=128, use_pallas=True, interpret=True)
    assert pal.shape == out.shape and pal.dtype == out.dtype
    np.testing.assert_allclose(np.asarray(pal, np.float32),
                               np.asarray(out, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_quantize_scale_equivariance():
    """quantize(a*x) has scales a*scale(x) and identical codes (property)."""
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 256), jnp.float32)
    q1, s1 = quantize_ref(x, 128)
    q2, s2 = quantize_ref(4.0 * x, 128)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_allclose(np.asarray(s2), 4.0 * np.asarray(s1), rtol=1e-6)


# ---------------------------------------------------------------------------
# ssm_scan (chunked SSD)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dims", [(2, 256, 4, 64, 32, 64), (1, 512, 8, 64, 64, 128)])
def test_ssd_pallas_matches_ref(dims):
    from repro.kernels.ssm_scan.ops import ssd_chunked
    from repro.kernels.ssm_scan.ref import ssd_ref

    b, s, h, hd, n, q = dims
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    xs = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32) * 0.5
    bm = jax.random.normal(ks[1], (b, s, n)) * 0.5
    cm = jax.random.normal(ks[2], (b, s, n)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[4], (h,)) * 0.3)
    y_ref, _ = ssd_ref(xs, bm, cm, dt, a, chunk=q)
    y_pal = ssd_chunked(xs, bm, cm, dt, a, chunk=q, use_pallas=True, interpret=True)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_pal), atol=1e-5, rtol=1e-5)


def test_ssd_chunk_invariance():
    from repro.kernels.ssm_scan.ref import ssd_ref

    b, s, h, hd, n = 1, 256, 2, 32, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    xs = jax.random.normal(ks[0], (b, s, h, hd)) * 0.5
    bm = jax.random.normal(ks[1], (b, s, n)) * 0.5
    cm = jax.random.normal(ks[2], (b, s, n)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[4], (h,)) * 0.3)
    y1, _ = ssd_ref(xs, bm, cm, dt, a, chunk=32)
    y2, _ = ssd_ref(xs, bm, cm, dt, a, chunk=256)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# model-zoo executors on the kernel path
# ---------------------------------------------------------------------------

def _run_executor(factory, x, **knob):
    graph, executor_for_version = factory(**knob)
    return executor_for_version(0)(0, len(graph.layers), x)


@pytest.mark.parametrize("factory_name,shape", [
    ("demo_transformer", (256, 32)),
    ("demo_ssm", (8, 24)),
])
def test_zoo_executor_pallas_interpret_matches_ref(factory_name, shape):
    """demo_transformer/demo_ssm executors produce the same activations with
    the execution knob on (Pallas interpret) as on the jnp reference path --
    the whole point of the knob: same math, kernel-backed."""
    from repro.core import model_zoo

    factory = getattr(model_zoo, factory_name)
    x = jax.random.normal(jax.random.PRNGKey(9), shape, jnp.float32) * 0.5
    y_ref = _run_executor(factory, x)
    y_pal = _run_executor(factory, x, use_pallas=True, interpret=True)
    assert y_pal.shape == x.shape
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               atol=1e-5, rtol=1e-5)


def test_demo_transformer_fused_int8_stage_matches_decode():
    """A stage handed an int8 EncodedActivation via the fused dequant-matmul
    handler computes the same thing as decode-then-run, from any cut."""
    from repro.core.model_zoo import demo_transformer
    from repro.dataplane import get_codec
    from repro.dataplane.base import EncodedActivation

    graph, executor_for_version = demo_transformer()
    ex = executor_for_version(0)
    n = len(graph.layers)
    assert "int8" in ex.fused_codecs
    codec = get_codec("int8")
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(11), (256, 32))) * 0.5
    x = ex(0, 2, x)  # realistic mid-pipeline activation
    enc = EncodedActivation(codec, codec.encode(np.asarray(x)))
    for start in (2, n - 1):
        fused = ex(start, n, enc)
        decoded = ex(start, n, enc.decode())
        np.testing.assert_allclose(np.asarray(fused), np.asarray(decoded),
                                   atol=1e-5, rtol=1e-5)


def test_demo_mlp_has_no_fused_codecs():
    """Executors without per-layer fused handlers advertise none, so the
    serving engines keep transcoding on the wire for them."""
    from repro.core.model_zoo import demo_mlp

    _, executor_for_version = demo_mlp()
    assert executor_for_version(0).fused_codecs == frozenset()
