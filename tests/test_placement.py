"""Placement tests: exact DP vs brute force, color coding, invariants."""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import (
    CommGraph,
    place_brute_force,
    place_color_coding,
    place_greedy,
    place_optimal,
    place_random,
    quantize_bandwidths,
)


def rand_comm(n, seed, capacity=100.0, p_drop=0.0):
    rng = np.random.default_rng(seed)
    bw = rng.uniform(0.5, 20.0, (n, n))
    bw = (bw + bw.T) / 2
    if p_drop:
        drop = rng.random((n, n)) < p_drop
        drop = drop | drop.T
        bw = np.where(drop, 0.0, bw)
    np.fill_diagonal(bw, 0.0)
    return CommGraph.uniform(bw, capacity)


class TestQuantize:
    def test_one_class_flattens(self):
        comm = rand_comm(5, 0)
        q, vals = quantize_bandwidths(comm.bw, 1)
        pos = q[comm.bw > 0]
        assert len(vals) == 1
        assert np.all(pos == pos[0])

    def test_conservative(self):
        comm = rand_comm(6, 1)
        for c in (1, 2, 4, 8):
            q, _ = quantize_bandwidths(comm.bw, c)
            assert np.all(q <= comm.bw + 1e-12)
            assert np.all((q > 0) == (comm.bw > 0))

    def test_none_is_identity(self):
        comm = rand_comm(4, 2)
        q, _ = quantize_bandwidths(comm.bw, None)
        np.testing.assert_array_equal(q, comm.bw)

    def test_more_classes_tighter(self):
        comm = rand_comm(8, 3)
        q2, _ = quantize_bandwidths(comm.bw, 2)
        q8, _ = quantize_bandwidths(comm.bw, 8)
        # 8-class floors are >= 2-class floors on average (finer = tighter)
        assert q8[comm.bw > 0].mean() >= q2[comm.bw > 0].mean() - 1e-9


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(3, 6),
    k=st.integers(2, 4),
    seed=st.integers(0, 10_000),
)
def test_optimal_matches_brute_force(n, k, seed):
    if k > n:
        return
    comm = rand_comm(n, seed)
    rng = np.random.default_rng(seed + 1)
    bounds = list(rng.uniform(1.0, 100.0, k - 1))
    pb = [1.0] * k
    opt = place_optimal(bounds, pb, comm)
    bf = place_brute_force(bounds, pb, comm)
    assert opt.feasible == bf.feasible
    if opt.feasible:
        assert opt.bottleneck_latency == pytest.approx(bf.bottleneck_latency)
        assert len(set(opt.path)) == k  # simple path


@settings(max_examples=30, deadline=None)
@given(n=st.integers(4, 7), seed=st.integers(0, 10_000))
def test_heuristics_never_beat_optimal(n, seed):
    comm = rand_comm(n, seed)
    rng = np.random.default_rng(seed)
    k = int(rng.integers(2, n))
    bounds = list(rng.uniform(1.0, 100.0, k - 1))
    pb = [1.0] * k
    opt = place_optimal(bounds, pb, comm)
    for placer in (place_greedy, place_random):
        h = placer(bounds, pb, comm)
        if h.feasible and opt.feasible:
            assert h.bottleneck_latency >= opt.bottleneck_latency - 1e-12


@settings(max_examples=25, deadline=None)
@given(n=st.integers(4, 7), seed=st.integers(0, 10_000))
def test_color_coding_unquantized_equals_optimal_small_n(n, seed):
    """With n <= exact_limit and no quantization, cc == optimal."""
    comm = rand_comm(n, seed)
    rng = np.random.default_rng(seed)
    k = int(rng.integers(2, n))
    bounds = list(rng.uniform(1.0, 100.0, k - 1))
    pb = [1.0] * k
    cc = place_color_coding(bounds, pb, comm, n_classes=None)
    opt = place_optimal(bounds, pb, comm)
    assert cc.feasible == opt.feasible
    if cc.feasible:
        assert cc.bottleneck_latency == pytest.approx(opt.bottleneck_latency)


def test_quantization_only_hurts_or_ties():
    """Solving on the quantized graph can't beat the unquantized optimum
    (true-latency is reported either way)."""
    for seed in range(8):
        comm = rand_comm(7, seed)
        bounds = [50.0, 20.0, 5.0]
        pb = [1.0] * 4
        opt = place_optimal(bounds, pb, comm)
        for c in (1, 2, 4):
            cc = place_color_coding(bounds, pb, comm, n_classes=c)
            assert cc.feasible
            assert cc.bottleneck_latency >= opt.bottleneck_latency - 1e-12


def test_more_classes_monotone_on_average():
    """The paper's Fig.3 trend: more bandwidth classes -> better placement."""
    lats = {c: [] for c in (1, 2, 4, 8)}
    for seed in range(20):
        comm = rand_comm(9, seed)
        rng = np.random.default_rng(seed)
        bounds = list(rng.uniform(1.0, 100.0, 4))
        pb = [1.0] * 5
        for c in lats:
            r = place_color_coding(bounds, pb, comm, n_classes=c)
            assert r.feasible
            lats[c].append(r.bottleneck_latency)
    means = {c: np.mean(v) for c, v in lats.items()}
    assert means[8] <= means[1] + 1e-12


class TestColorCodingLargeN:
    def test_finds_known_path(self):
        # ring of 20 nodes with one golden high-bw path
        n = 20
        bw = np.full((n, n), 1.0)
        np.fill_diagonal(bw, 0.0)
        golden = [3, 7, 11, 15, 19]
        for a, b in zip(golden, golden[1:]):
            bw[a, b] = bw[b, a] = 100.0
        comm = CommGraph.uniform(bw, 10.0)
        bounds = [100.0] * 4
        pb = [1.0] * 5
        r = place_color_coding(
            bounds, pb, comm, n_classes=None, exact_limit=4, trials=80, seed=0
        )
        assert r.feasible
        assert r.bottleneck_latency == pytest.approx(1.0)  # golden path found

    def test_capacity_constraints_respected(self):
        n = 18
        rng = np.random.default_rng(0)
        bw = rng.uniform(1, 10, (n, n))
        bw = (bw + bw.T) / 2
        np.fill_diagonal(bw, 0)
        cap = np.full(n, 0.5)
        cap[[2, 5, 8, 11]] = 10.0  # only these can host
        comm = CommGraph(bw=bw, node_capacity=cap)
        r = place_color_coding(
            [5.0, 3.0], [1.0] * 3, comm, n_classes=4, exact_limit=4, trials=60
        )
        assert r.feasible
        assert set(r.path) <= {2, 5, 8, 11}


class TestEdgeCases:
    def test_k_greater_than_n(self):
        comm = rand_comm(3, 0)
        assert not place_optimal([1.0] * 4, [1.0] * 5, comm).feasible

    def test_single_partition(self):
        comm = rand_comm(4, 0)
        r = place_optimal([], [1.0], comm)
        assert r.feasible and len(r.path) == 1 and r.bottleneck_latency == 0.0
        assert r.throughput == float("inf")

    def test_disconnected_graph_infeasible(self):
        bw = np.zeros((4, 4))
        bw[0, 1] = bw[1, 0] = 5.0  # only one link
        comm = CommGraph.uniform(bw, 10.0)
        assert place_optimal([1.0, 1.0], [1.0] * 3, comm).feasible is False

    def test_capacity_blocks_placement(self):
        comm = rand_comm(4, 0, capacity=0.5)
        assert not place_optimal([1.0], [1.0, 1.0], comm).feasible

    def test_dispatcher_edges_counted(self):
        bw = np.full((3, 3), 10.0)
        np.fill_diagonal(bw, 0)
        cap = np.array([-1.0, 10.0, 10.0])  # node 0 = dispatcher
        comm = CommGraph(bw=bw, node_capacity=cap)
        r = place_color_coding(
            [10.0], [1.0, 1.0], comm, n_classes=None,
            in_bytes=1000.0, dispatcher=0,
        )
        assert r.feasible
        assert r.bottleneck_latency == pytest.approx(100.0)  # input edge dominates

    def test_asymmetric_bw_rejected(self):
        bw = np.array([[0.0, 1.0], [2.0, 0.0]])
        with pytest.raises(ValueError):
            CommGraph.uniform(bw, 1.0)


# ---------------------------------------------------------------------------
# Hierarchical large-n placement
# ---------------------------------------------------------------------------

from repro.core import place_hierarchical  # noqa: E402
from repro.core.placement import HIERARCHICAL_NODE_LIMIT  # noqa: E402


class TestHierarchical:
    def test_feasible_and_valid_on_large_cluster(self):
        comm = rand_comm(200, 0)
        r = place_hierarchical([5.0] * 4, [1.0] * 5, comm, seed=1)
        assert r.feasible
        assert len(r.path) == 5 and len(set(r.path)) == 5
        assert all(0 <= i < comm.n for i in r.path)
        # reported bottleneck is the true one for the returned path
        worst = max(
            5.0 / comm.bw[a, b] for a, b in zip(r.path, r.path[1:])
        )
        assert r.bottleneck_latency == pytest.approx(worst)
        assert r.algorithm.startswith("hierarchical(")

    def test_never_beats_optimal_small_n(self):
        for seed in range(8):
            comm = rand_comm(8, seed)
            opt = place_optimal([3.0] * 3, [1.0] * 4, comm)
            # tiny groups force the coarse-DP path even at n=8
            hier = place_hierarchical(
                [3.0] * 3, [1.0] * 4, comm, seed=seed, group_size=3
            )
            assert opt.feasible and hier.feasible
            assert hier.bottleneck_latency >= opt.bottleneck_latency - 1e-12

    def test_small_clusters_fall_back_to_flat(self):
        comm = rand_comm(6, 4)
        r = place_hierarchical([2.0] * 2, [1.0] * 3, comm, seed=0)
        assert r.feasible
        assert "flat_fallback" in r.algorithm

    def test_color_coding_delegates_above_limit(self):
        comm = rand_comm(HIERARCHICAL_NODE_LIMIT + 8, 5)
        r = place_color_coding([4.0] * 3, [1.0] * 4, comm, seed=0)
        assert r.feasible
        assert r.algorithm.startswith("hierarchical(")
        # and the flat path is still reachable explicitly
        flat = place_color_coding(
            [4.0] * 3, [1.0] * 4, comm, seed=0, hierarchical_limit=None
        )
        assert flat.feasible and not flat.algorithm.startswith("hierarchical(")

    def test_respects_capacity_and_dispatcher(self):
        rng = np.random.default_rng(7)
        n = 96
        bw = rng.uniform(1.0, 30.0, (n, n))
        bw = (bw + bw.T) / 2
        np.fill_diagonal(bw, 0.0)
        cap = np.full(n, 10.0)
        cap[0] = -1.0  # dispatcher hosts nothing
        cap[1::2] = 0.5  # odd nodes cannot host any partition
        comm = CommGraph(bw=bw, node_capacity=cap)
        r = place_hierarchical(
            [2.0] * 3, [1.0] * 4, comm, seed=0,
            in_bytes=1.0, out_bytes=1.0, dispatcher=0,
        )
        assert r.feasible
        assert 0 not in r.path
        assert all(i % 2 == 0 for i in r.path), r.path

    def test_deterministic_for_fixed_seed(self):
        comm = rand_comm(150, 9)
        a = place_hierarchical([3.0] * 4, [1.0] * 5, comm, seed=3)
        b = place_hierarchical([3.0] * 4, [1.0] * 5, comm, seed=3)
        assert a.path == b.path
        assert a.bottleneck_latency == b.bottleneck_latency
