"""Per-arch smoke tests: reduced config, one forward/train step + one decode
step on CPU; asserts output shapes and no NaNs (assignment requirement)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SHAPES, reduced, shape_cells
from repro.models import lm
from repro.models.graph_export import export_graph
from repro.runtime import train as train_lib


def _batch(cfg, b=2, s=16):
    batch = {"tokens": jnp.arange(b * s, dtype=jnp.int32).reshape(b, s) % cfg.vocab_size}
    if cfg.family == "audio":
        batch["frames"] = jnp.ones((b, s, cfg.d_model), jnp.bfloat16) * 0.1
    if cfg.family == "vlm":
        batch["patches"] = jnp.ones((b, 4, lm.PATCH_DIM), jnp.bfloat16) * 0.1
    return batch


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_forward_and_loss(name):
    cfg = reduced(ARCHS[name])
    params = lm.init_params(cfg, jax.random.PRNGKey(0), max_pos=64)
    batch = _batch(cfg)
    loss, metrics = lm.loss_fn(cfg, params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{name}: NaN/inf loss"
    assert float(loss) > 0


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_decode_step(name):
    cfg = reduced(ARCHS[name])
    params = lm.init_params(cfg, jax.random.PRNGKey(0), max_pos=64)
    b, max_len = 2, 32
    caches = lm.init_caches(cfg, b, max_len, enc_len=16)
    tok = jnp.zeros((b, 1), jnp.int32)
    for _ in range(3):
        logits, caches = lm.decode_step(cfg, params, caches, tok, enc_len=16)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{name}: NaN decode logits"
    assert int(caches["pos"]) == 3


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_train_step_improves(name):
    cfg = reduced(ARCHS[name])
    params = lm.init_params(cfg, jax.random.PRNGKey(0), max_pos=64)
    state = train_lib.init_state(cfg, params)
    step = jax.jit(train_lib.make_train_step(cfg, train_lib.OptConfig(lr=1e-2, warmup_steps=1)))
    batch = _batch(cfg)
    losses = []
    for _ in range(4):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
        assert jnp.isfinite(metrics["grad_norm"])
    assert losses[-1] < losses[0], f"{name}: loss did not decrease: {losses}"


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_graph_export_cells(name):
    cfg = ARCHS[name]
    for cell in shape_cells(cfg):
        g = export_graph(cfg, SHAPES[cell])
        assert g.total_param_bytes > 0
        assert all(l.out_bytes >= 0 for l in g.layers)
        assert g.total_flops > 0
    # long_500k only for sub-quadratic archs
    assert ("long_500k" in shape_cells(cfg)) == cfg.subquadratic
