"""Reconciler coverage: the Sec. 2.3 convergence rules, event by event.

  * version bump  -> in-place redeploy, NO full cluster restart
  * node failure  -> re-place onto healthy nodes only
  * node join     -> full restart (generation bump, re-probe, re-partition)
  * link degraded -> re-place only when the bottleneck actually worsens
  * serving loop  -> in-flight requests complete or are retried, never lost
"""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import (
    ArtifactStore,
    ControlPlane,
    EdgeCluster,
    LinkDegraded,
    ModelWatcher,
    NodeFailed,
    NodeJoined,
    ServingLoop,
    VersionBumped,
)
from repro.core.graph import chain
from repro.core.simulate import expand_cluster, random_cluster
from repro.runtime.pipeline import make_layer_executor

D, LAYERS = 16, 8
CAPACITY = 3 * D * D * 4


def _weights(version, n_layers=LAYERS, d=D):
    return np.asarray(
        jax.random.normal(jax.random.PRNGKey(version), (n_layers, d, d)) * 0.3
    )


def _executor_for_version(version):
    ws = _weights(version)
    return make_layer_executor(
        [lambda x, w=ws[i]: jnp.tanh(x @ w) for i in range(LAYERS)]
    )


def _reference(version, x):
    for w in _weights(version):
        x = jnp.tanh(x @ w)
    return x


def _control(seed=0, n_nodes=8, with_positions=False):
    graph = chain("mlp", [(D * D * 4, 4 * D * 4)] * LAYERS, in_bytes=4 * D * 4)
    comm, pos = random_cluster(n_nodes, CAPACITY, seed=3, with_positions=True)
    cluster = EdgeCluster(comm, flops_per_s=1e9)
    store = ArtifactStore(tempfile.mkdtemp(prefix="seifer-cp-"))
    control = ControlPlane(
        cluster, store, lambda v: graph, _executor_for_version,
        capacity=CAPACITY, seed=seed,
    )
    control.bootstrap(0)  # constructor capacity/compression are the defaults
    return (control, pos) if with_positions else control


def test_version_bump_redeploys_in_place():
    control = _control()
    old_pods = list(control.pipeline.pods)
    gen0 = control.generation
    leader0 = control.dispatcher.leader
    probed0 = control.dispatcher.probed

    control.store.publish(1)
    watcher = ModelWatcher(control.store)
    assert watcher.poll_events(control)
    (action,) = control.reconcile()

    assert action.kind == "redeploy"
    obs = control.observed()
    assert obs.version == 1
    # in-place: no full cluster restart -- same generation, same leader,
    # and the probed bandwidths were NOT re-measured
    assert control.generation == gen0
    assert control.dispatcher.leader == leader0
    assert control.dispatcher.probed is probed0
    assert all(not p.alive for p in old_pods)  # old pods stopped
    # new pipeline really computes the NEW version's weights
    x = jnp.ones((2, D)) * 0.1
    y, _ = control.pipeline.run(x)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(_reference(1, x)), rtol=1e-6
    )


def test_infeasible_version_bump_keeps_old_deployment():
    """An infeasible new version must not take down the healthy pipeline."""
    graph_v0 = chain("mlp", [(D * D * 4, 4 * D * 4)] * LAYERS, in_bytes=4 * D * 4)
    too_big = chain("huge", [(100 * CAPACITY, 4)] * LAYERS)
    comm = random_cluster(8, CAPACITY, seed=3)
    store = ArtifactStore(tempfile.mkdtemp(prefix="seifer-cp-"))
    control = ControlPlane(
        EdgeCluster(comm, flops_per_s=1e9), store,
        lambda v: too_big if v > 0 else graph_v0, _executor_for_version,
        capacity=CAPACITY,
    )
    control.bootstrap(0)
    control.submit(VersionBumped(1))
    (action,) = control.reconcile()
    assert action.kind == "noop" and "rejected" in action.detail
    obs = control.observed()
    assert obs.version == 0 and obs.healthy  # v0 still serving
    y, _ = control.pipeline.run(jnp.ones((2, D)))
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(_reference(0, jnp.ones((2, D)))), rtol=1e-6
    )


def test_stale_version_bump_is_noop():
    control = _control()
    control.submit(VersionBumped(0))
    (action,) = control.reconcile()
    assert action.kind == "noop"
    assert control.observed().version == 0


def test_node_failure_replaces_onto_healthy_nodes():
    control = _control()
    x = jnp.ones((2, D)) * 0.2
    y0, _ = control.pipeline.run(x)
    victim = control.pipeline.pods[1].node_id

    control.submit(NodeFailed(victim))
    (action,) = control.reconcile()

    assert action.kind == "replace"
    obs = control.observed()
    assert obs.healthy
    assert victim not in obs.path
    assert set(obs.path) <= set(control.cluster.healthy_ids())
    assert control.generation == 0  # failure never forces a full restart
    y1, _ = control.pipeline.run(x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), rtol=1e-6)


def test_node_failure_on_idle_node_is_noop():
    control = _control()
    idle = next(
        i for i in control.cluster.healthy_ids()
        if i not in control.pipeline.path() and i != control.dispatcher.leader
    )
    path0 = control.pipeline.path()
    control.submit(NodeFailed(idle))
    (action,) = control.reconcile()
    assert action.kind == "noop"
    assert control.pipeline.path() == path0


def test_node_join_triggers_full_restart():
    control, pos = _control(with_positions=True)
    gen0 = control.generation
    n0 = control.cluster.n
    probed0 = control.dispatcher.probed

    comm2, _ = expand_cluster(pos, CAPACITY, seed=11)
    control.submit(NodeJoined(comm=comm2))
    (action,) = control.reconcile()

    assert action.kind == "restart"
    assert control.generation == gen0 + 1
    assert control.cluster.n == n0 + 1
    assert control.dispatcher.probed is not probed0  # re-probed from scratch
    obs = control.observed()
    assert obs.healthy
    y, _ = control.pipeline.run(jnp.ones((2, D)))
    assert y.shape == (2, D)


def test_constructor_compression_reaches_deployment():
    graph = chain("mlp", [(D * D * 4, 4 * D * 4)] * LAYERS, in_bytes=4 * D * 4)
    cluster = EdgeCluster(random_cluster(8, CAPACITY, seed=3), flops_per_s=1e9)
    control = ControlPlane(
        cluster, ArtifactStore(tempfile.mkdtemp(prefix="seifer-cp-")),
        lambda v: graph, _executor_for_version,
        capacity=CAPACITY, compression_ratio=2.0,
    )
    control.bootstrap(0)  # no kwargs: constructor values must take effect
    assert control.desired.capacity == CAPACITY
    assert control.pipeline.compression_ratio == 2.0


def test_legacy_poll_without_dispatcher_raises_clearly():
    control = _control()
    watcher = ModelWatcher(control.store)  # control-plane-style construction
    control.store.publish(99)
    with pytest.raises(RuntimeError, match="poll_events"):
        watcher.poll(control.pipeline, _executor_for_version(0))


def test_infeasible_node_join_keeps_old_deployment():
    """A join whose post-restart configure fails must not kill serving."""
    control, pos = _control(with_positions=True)
    y0, _ = control.pipeline.run(jnp.ones((2, D)) * 0.2)
    # make the desired graph impossible to place from now on
    control.desired = __import__("dataclasses").replace(
        control.desired,
        graph=chain("huge", [(100 * CAPACITY, 4)] * LAYERS),
    )
    comm2, _ = expand_cluster(pos, CAPACITY, seed=11)
    control.submit(NodeJoined(comm=comm2))
    (action,) = control.reconcile()
    assert action.kind == "noop" and "rejected" in action.detail
    assert control.generation == 0  # no restart happened
    assert control.observed().healthy  # old pipeline still serving
    y1, _ = control.pipeline.run(jnp.ones((2, D)) * 0.2)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), rtol=1e-6)


def test_failed_node_rejoin_triggers_full_restart():
    control = _control()
    victim = control.pipeline.pods[1].node_id
    control.submit(NodeFailed(victim))
    control.reconcile()
    control.submit(NodeJoined(node_id=victim))
    (action,) = control.reconcile()
    assert action.kind == "restart"
    assert control.generation == 1
    assert control.cluster.nodes[victim].healthy


def test_link_degraded_within_tolerance_is_noop():
    control = _control()
    # a link between two nodes NOT adjacent on the path: harmless
    path = control.pipeline.path()
    others = [i for i in range(control.cluster.n) if i not in path]
    control.submit(LinkDegraded(others[0], others[1], 0.01))
    (action,) = control.reconcile()
    assert action.kind == "noop"


def test_link_degraded_on_path_replaces():
    control = _control()
    a, b = control.pipeline.path()[:2]
    before = control.observed().bottleneck_latency
    control.submit(LinkDegraded(a, b, 1e-4))
    (action,) = control.reconcile()
    assert action.kind == "replace"
    assert control.observed().bottleneck_latency < before * 1e3  # not stuck on dead link
    assert control.observed().healthy


def test_event_validation():
    with pytest.raises(ValueError):
        NodeJoined()  # neither node_id nor comm
    with pytest.raises(ValueError):
        LinkDegraded(0, 1, -0.5)


# ---------------------------------------------------------------------------
# Serving loop across recovery
# ---------------------------------------------------------------------------

def test_inflight_requests_survive_node_kill():
    control = _control()
    loop = ServingLoop(control, microbatch=4)
    n = 20
    for _ in range(n):
        loop.submit(jnp.ones((D,)) * 0.1)
    killed = False
    while loop.backlog or control.pending:
        if not killed and len(loop.completed) >= n // 2:
            control.submit(NodeFailed(control.pipeline.pods[1].node_id))
            killed = True
        loop.step()
    assert killed
    assert len(loop.completed) == n
    assert len(loop.failed) == 0
    expected = _reference(0, jnp.ones((D,)) * 0.1)
    for req in loop.completed:
        np.testing.assert_allclose(
            np.asarray(req.result), np.asarray(expected), rtol=1e-5
        )


def test_inflight_requests_retried_on_unannounced_failure():
    """Infra-level failure (no event): pipeline raises mid-batch, the loop
    re-queues, and the drift check repairs the pipeline."""
    control = _control()
    loop = ServingLoop(control, microbatch=4)
    for _ in range(8):
        loop.submit(jnp.ones((D,)) * 0.1)
    loop.step()
    # the node dies WITHOUT an event: only the cluster + pods know
    victim = control.pipeline.pods[1].node_id
    control.cluster.fail(victim)
    control.pipeline.mark_node_failed(victim)
    before_attempts = max(r.attempts for r in loop.queue)
    loop.drain()
    assert len(loop.completed) == 8
    assert len(loop.failed) == 0
    assert any(r.attempts > before_attempts for r in loop.completed)
    assert any(
        a.kind == "replace" and a.event is None for a in control.history
    )  # drift-check repair, not event-driven


def test_serving_across_version_bump_switches_weights():
    control = _control()
    loop = ServingLoop(control, microbatch=4)
    for _ in range(4):
        loop.submit(jnp.ones((D,)) * 0.1)
    loop.drain()
    control.store.publish(1)
    ModelWatcher(control.store).poll_events(control)
    for _ in range(4):
        loop.submit(jnp.ones((D,)) * 0.1)
    loop.drain()
    assert len(loop.completed) == 8
    ref0 = _reference(0, jnp.ones((D,)) * 0.1)
    ref1 = _reference(1, jnp.ones((D,)) * 0.1)
    np.testing.assert_allclose(
        np.asarray(loop.completed[3].result), np.asarray(ref0), rtol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(loop.completed[-1].result), np.asarray(ref1), rtol=1e-5
    )
