"""GPipe pipeline correctness on a faked 4-device host (subprocess, so the
main test process keeps its single-device view)."""

import subprocess
import sys
import textwrap
from pathlib import Path

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.runtime.pipeline import make_gpipe, plan_pipeline, reorder_stage_params
    from repro.core.graph import chain

    mesh = jax.make_mesh((4,), ("stage",))
    d, n_micro = 32, 8
    ws = jax.random.normal(jax.random.PRNGKey(0), (8, d, d), jnp.float32) * 0.1
    stage_ws = ws.reshape(4, 2, d, d)

    def stage_fn(local_w, x):
        for i in range(2):
            x = jnp.tanh(x @ local_w[i])
        return x

    x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, 16, d), jnp.float32)
    ref = x
    for i in range(8):
        ref = jnp.tanh(ref @ ws[i])

    g = chain("mlp", [(d * d * 4, 16 * d * 4)] * 8)
    pod_bw = np.array(
        [[0, 10e9, 1e9, 1e9], [10e9, 0, 5e9, 1e9],
         [1e9, 5e9, 0, 2e9], [1e9, 1e9, 2e9, 0]], float)
    plan = plan_pipeline(g, 4, stage_capacity=2 * d * d * 4, pod_bw=pod_bw)
    assert plan.cuts == (1, 3, 5), plan.cuts  # balanced SEIFER cuts

    # identity placement, exact
    pipe = make_gpipe(stage_fn, mesh, axis="stage", n_micro=n_micro)
    with mesh:
        y = pipe(stage_ws, x)
    assert float(jnp.max(jnp.abs(y - ref))) < 1e-6, "identity placement"

    # SEIFER placement, exact
    pipe = make_gpipe(stage_fn, mesh, axis="stage", n_micro=n_micro,
                      stage_order=plan.stage_order)
    with mesh:
        y = pipe(reorder_stage_params(stage_ws, plan), x)
    assert float(jnp.max(jnp.abs(y - ref))) < 1e-6, "seifer placement"

    # int8-compressed boundaries: small bounded error
    pipe = make_gpipe(stage_fn, mesh, axis="stage", n_micro=n_micro,
                      compress=True, quant_block=32,
                      stage_order=plan.stage_order)
    with mesh:
        y = pipe(reorder_stage_params(stage_ws, plan), x)
    err = float(jnp.max(jnp.abs(y - ref)))
    assert 0 < err < 0.05, f"compressed pipeline err {err}"

    # same int8 boundaries through the Pallas kernels (interpret mode): the
    # execution knob reaches the quantized send path, and the kernel emits
    # the same codes as the jnp oracle, so the outputs agree to fp noise
    from repro.core.execution import PALLAS_INTERPRET
    pipe = make_gpipe(stage_fn, mesh, axis="stage", n_micro=n_micro,
                      compress=True, quant_block=32,
                      stage_order=plan.stage_order, execution=PALLAS_INTERPRET)
    with mesh:
        y2 = pipe(reorder_stage_params(stage_ws, plan), x)
    knob_err = float(jnp.max(jnp.abs(y2 - y)))
    assert knob_err < 1e-6, f"pallas-interpret knob diverged: {knob_err}"
    print("PIPELINE_OK")
    """
)


def test_gpipe_four_stages():
    repo = Path(__file__).resolve().parents[1]
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": str(repo / "src"), "PATH": "/usr/bin:/bin"},
        cwd=repo,
    )
    assert "PIPELINE_OK" in proc.stdout, proc.stdout + proc.stderr


def test_plan_period_is_bottleneck_pipeline_period():
    """plan_pipeline.est_period_s IS core.bottleneck's pipeline_period on the
    same partitions/path/comm -- ONE steady-state definition shared with the
    edge serving engine, pinned here so the two cannot drift apart."""
    import numpy as np

    from repro.core.bottleneck import evaluate_pipeline
    from repro.core.graph import chain
    from repro.core.partitioner import partition_exact_k
    from repro.core.placement import CommGraph
    from repro.runtime.pipeline import plan_pipeline

    d = 32
    g = chain("mlp", [(d * d * 4, 16 * d * 4)] * 8)
    pod_bw = np.array(
        [[0, 10e9, 1e9, 1e9], [10e9, 0, 5e9, 1e9],
         [1e9, 5e9, 0, 2e9], [1e9, 1e9, 2e9, 0]], float)
    cap = 2 * d * d * 4
    plan = plan_pipeline(g, 4, stage_capacity=cap, pod_bw=pod_bw,
                         device_flops=1e9)
    part = partition_exact_k(g, cap, 4)
    comm = CommGraph(bw=pod_bw, node_capacity=np.full(4, float(cap)))
    metrics = evaluate_pipeline(part.partitions, list(plan.stage_order), comm,
                                device_flops=1e9)
    assert plan.est_period_s == float(metrics.pipeline_period)
    assert plan.est_period_s > 0.0
    # the period dominates the pure link bottleneck (it maxes over links AND
    # stage compute), never undercuts it
    assert plan.est_period_s >= plan.est_bottleneck_s
