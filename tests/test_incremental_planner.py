"""Incremental planner: plan caches, trials accounting, scoped recovery.

Covers the hierarchical/incremental planner work: ``PlanCache`` semantics,
the ``trials_used`` accounting fix, per-level feasibility seeding, probe
caching by cluster generation, and the property tests (via the hypothesis
shim) that a scoped churn re-plan leaves untouched replicas byte-identical
and lands within a bounded ratio of a full re-solve.
"""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.api import ClusterSpec, DeploymentSpec, deploy
from repro.api.planner import PlanCache, Planner
from repro.cluster import NodeFailed
from repro.core import CommGraph, place_color_coding
from repro.core.model_zoo import demo_mlp

D = 16

# scoped recovery may only use the failure neighborhood, so it can be worse
# than a full re-solve -- but never by more than the spare-selection bound
SCOPED_VS_FULL_BOUND = 4.0


def rand_comm(n, seed, capacity=100.0):
    rng = np.random.default_rng(seed)
    bw = rng.uniform(0.5, 20.0, (n, n))
    bw = (bw + bw.T) / 2
    np.fill_diagonal(bw, 0.0)
    return CommGraph.uniform(bw, capacity)


# ---------------------------------------------------------------------------
# trials accounting + per-level seeding (satellite bugfixes)
# ---------------------------------------------------------------------------

def test_trials_used_reports_actual_colorings_drawn():
    """A first-trial hit must not charge the full budget (the old code added
    ``trials`` per successful level, over-reporting by ~the whole budget)."""
    comm = rand_comm(8, 0)
    r = place_color_coding([0.0, 0.0], [1.0] * 3, comm,
                           seed=0, exact_limit=0, trials=40)
    assert r.feasible
    # one candidate level (all-zero boundaries), dense graph: a feasible
    # coloring lands within a handful of draws, nowhere near the budget
    assert 1 <= r.trials_used < 40


def test_trials_used_counts_full_budget_on_infeasible():
    bw = np.zeros((4, 4))
    bw[0, 1] = bw[1, 0] = 5.0  # only one link: no 3-path exists
    comm = CommGraph.uniform(bw, 10.0)
    r = place_color_coding([1.0, 1.0], [1.0] * 3, comm,
                           seed=0, exact_limit=0, trials=7)
    assert not r.feasible
    assert r.trials_used >= 7  # every visited level burned its full budget


def test_same_seed_same_result():
    """Per-level ``(seed, candidate_index)`` RNG seeding: the returned path
    is a pure function of the instance + seed, so repeat calls agree."""
    comm = rand_comm(12, 3)
    a = place_color_coding([4.0] * 3, [1.0] * 4, comm, seed=5, exact_limit=0)
    b = place_color_coding([4.0] * 3, [1.0] * 4, comm, seed=5, exact_limit=0)
    assert a.path == b.path
    assert a.trials_used == b.trials_used


@given(seed=st.integers(min_value=0, max_value=9))
@settings(max_examples=10, deadline=None)
def test_confirmation_pass_matches_exact_dp_under_quantization(seed):
    """With unquantized classes the Monte-Carlo search (+ confirmation pass)
    should land on the exact optimum on small instances -- a false-negative
    prune would show up here as a worse bottleneck."""
    from repro.core import place_optimal

    comm = rand_comm(9, seed)
    opt = place_optimal([3.0, 2.0, 4.0], [1.0] * 4, comm)
    cc = place_color_coding([3.0, 2.0, 4.0], [1.0] * 4, comm,
                            n_classes=None, seed=seed, exact_limit=0,
                            trials=80)
    assert opt.feasible and cc.feasible
    assert cc.bottleneck_latency == pytest.approx(opt.bottleneck_latency)


# ---------------------------------------------------------------------------
# PlanCache
# ---------------------------------------------------------------------------

class TestPlanCache:
    def test_hit_miss_accounting(self):
        cache = PlanCache()
        calls = []
        assert cache.lookup("a", lambda: calls.append(1) or 10) == 10
        assert cache.lookup("a", lambda: calls.append(1) or 99) == 10
        assert calls == [1]
        assert cache.stats() == {"hits": 1, "misses": 1, "entries": 1}

    def test_fifo_eviction(self):
        cache = PlanCache(max_entries=2)
        cache.lookup("a", lambda: 1)
        cache.lookup("b", lambda: 2)
        cache.lookup("c", lambda: 3)  # evicts "a"
        assert cache.lookup("a", lambda: 111) == 111  # rebuilt
        assert cache.stats()["entries"] == 2

    def test_raising_build_caches_nothing(self):
        cache = PlanCache()
        with pytest.raises(ValueError):
            cache.lookup("bad", lambda: (_ for _ in ()).throw(ValueError()))
        assert cache.stats()["entries"] == 0
        assert cache.lookup("bad", lambda: 7) == 7

    def test_invalidate(self):
        cache = PlanCache()
        cache.lookup("a", lambda: 1)
        cache.invalidate()
        assert cache.lookup("a", lambda: 2) == 2

    def test_planner_shares_quantization_across_places(self):
        """Repeat placements on an unchanged comm hit the quantize cache --
        the ``replicas='auto'`` R-candidate search and every recovery
        re-solve stop recomputing the class sublattice."""
        planner = Planner(placer="color_coding", n_classes=4)
        comm = rand_comm(10, 0, capacity=3.0)
        for _ in range(3):
            planner.place([2.0] * 2, [1.0] * 3, comm, seed=1)
        stats = planner.cache.stats()
        assert stats["misses"] == 1 and stats["hits"] == 2

    def test_comm_key_tracks_content(self):
        a = rand_comm(6, 0)
        same = CommGraph(bw=a.bw.copy(), node_capacity=a.node_capacity.copy())
        other = rand_comm(6, 1)
        assert a.key() == same.key()
        assert a.key() != other.key()


# ---------------------------------------------------------------------------
# probe caching by cluster generation
# ---------------------------------------------------------------------------

def _single_deployment(seed):
    graph, executor_for_version = demo_mlp(d=D)
    return deploy(DeploymentSpec(
        model=graph,
        executor_for_version=executor_for_version,
        cluster=ClusterSpec(
            n_nodes=8, capacity_bytes=graph.total_param_bytes / 3,
            seed=seed + 3,
        ),
        seed=seed,
        microbatch=2,
    ))


def test_probe_cache_keyed_on_generation():
    dep = _single_deployment(0)
    disp = dep.control.dispatcher
    probed = disp.probe_bandwidths()
    assert disp.probe_bandwidths() is probed  # same generation: cache hit
    dep.cluster.fail(dep.cluster.n - 1)  # generation bump
    reprobed = disp.probe_bandwidths()
    assert reprobed is not probed
    assert reprobed.bw[dep.cluster.n - 1].max() == 0.0


def test_node_flops_cache_keyed_on_generation():
    dep = _single_deployment(1)
    disp = dep.control.dispatcher
    flops = disp.node_flops()
    assert disp.node_flops() is flops
    dep.cluster.fail(0)
    assert disp.node_flops() is not flops


# ---------------------------------------------------------------------------
# property tests: scoped churn re-plan vs full re-solve
# ---------------------------------------------------------------------------

R = 3
GROUP = 4


def _replicated_deployment(seed):
    """R replicas over heterogeneous (but well-connected) links, one spare
    node per group so in-group scoped recovery is always possible."""
    graph, executor_for_version = demo_mlp(d=D)
    capacity = graph.total_param_bytes * 0.4
    n = R * GROUP + 1
    rng = np.random.default_rng(seed + 17)
    bw = rng.uniform(2e5, 6e5, (n, n))
    bw = (bw + bw.T) / 2
    np.fill_diagonal(bw, 0.0)
    caps = np.full(n, capacity)
    caps[0] = -1.0  # dispatcher hosts no partition
    return deploy(DeploymentSpec(
        model=graph,
        executor_for_version=executor_for_version,
        cluster=ClusterSpec(comm=CommGraph(bw=bw, node_capacity=caps)),
        capacity=capacity,
        seed=seed,
        microbatch=2,
        replicas=R,
    ))


@given(seed=st.integers(min_value=0, max_value=4))
@settings(max_examples=5, deadline=None)
def test_scoped_churn_leaves_untouched_replicas_byte_identical(seed):
    dep = _replicated_deployment(seed)
    rset = dep.replicaset
    victim = int(rset.controls[0].pipeline.pods[1].node_id)
    pre = [
        (tuple(c.pipeline.path()), tuple(c.pipeline.link_codecs or ()),
         tuple(c.pipeline.boundary_bytes))
        for c in rset.controls[1:]
    ]
    dep.inject(NodeFailed(victim))
    while dep.pending:
        dep.step()
    post = [
        (tuple(c.pipeline.path()), tuple(c.pipeline.link_codecs or ()),
         tuple(c.pipeline.boundary_bytes))
        for c in rset.controls[1:]
    ]
    assert post == pre, "an untouched replica's path/codecs changed"
    assert rset.recovery_log()[1:] == [None, None]
    rec = rset.recovery_log()[0]
    assert rec is not None and rec["scoped"], rec


@given(seed=st.integers(min_value=0, max_value=4))
@settings(max_examples=5, deadline=None)
def test_scoped_recovery_within_bound_of_full_resolve(seed):
    dep = _replicated_deployment(seed)
    rset = dep.replicaset
    control = rset.controls[0]
    victim = int(control.pipeline.pods[1].node_id)
    dep.inject(NodeFailed(victim))
    while dep.pending:
        dep.step()
    rec = rset.recovery_log()[0]
    assert rec is not None and rec["scoped"], rec
    scoped_bn = control.last_plan.placement.bottleneck_latency
    # full re-solve over the replica's whole (masked) probed view, same
    # partitions -- the scoped answer may not beat it by construction, and
    # must not trail it past the spare-selection bound
    disp = control.dispatcher
    graph = control.desired.graph
    full = control.planner.place(
        control.pipeline.boundary_bytes,
        [p.partition.param_bytes for p in control.pipeline.pods],
        disp.probed, seed=123,
        in_bytes=graph.in_bytes, out_bytes=graph.layers[-1].out_bytes,
        dispatcher=disp.leader,
    )
    assert full.feasible
    assert scoped_bn <= SCOPED_VS_FULL_BOUND * full.bottleneck_latency + 1e-12
