"""Make ``src/`` and the tests dir importable regardless of invocation.

The canonical tier-1 command sets ``PYTHONPATH=src`` explicitly; this keeps a
bare ``python -m pytest`` working too, and lets test modules import the
``_hypothesis_compat`` shim without a package layout.
"""

import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
# repo root is needed for the ``benchmarks`` package (artifact schema tests)
for p in (str(_ROOT / "src"), str(_ROOT / "tests"), str(_ROOT)):
    if p not in sys.path:
        sys.path.insert(0, p)
