"""Checkpoint/restore: bit-exactness, latest-pointer semantics, GC, and
resume-equivalence of a training run (fault-tolerance requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import lm
from repro.runtime import train as train_lib
from repro.runtime.checkpoint import Checkpointer


@pytest.fixture()
def tiny_state():
    cfg = reduced(ARCHS["llama3.2-1b"])
    params = lm.init_params(cfg, jax.random.PRNGKey(0), max_pos=64)
    return cfg, train_lib.init_state(cfg, params)


def test_save_restore_bit_exact(tmp_path, tiny_state):
    cfg, state = tiny_state
    ck = Checkpointer(tmp_path)
    ck.save(7, state)
    step, restored = ck.restore(state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_latest_pointer_and_gc(tmp_path, tiny_state):
    _, state = tiny_state
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, state)
    assert ck.latest_step() == 4
    dirs = sorted(d.name for d in ck.store.root.iterdir() if d.name.startswith("v"))
    assert dirs == ["v000003", "v000004"]  # older checkpoints GC'd
    with pytest.raises(Exception):
        ck.restore(state, step=1)  # collected


def test_restore_empty_raises(tmp_path, tiny_state):
    _, state = tiny_state
    with pytest.raises(FileNotFoundError):
        Checkpointer(tmp_path).restore(state)


def test_resume_equals_uninterrupted(tmp_path, tiny_state):
    """Train 4 steps straight == train 2, checkpoint, restore, train 2."""
    cfg, state0 = tiny_state
    step_fn = jax.jit(train_lib.make_train_step(cfg, train_lib.OptConfig(lr=1e-3)))
    batch = {"tokens": jnp.arange(32, dtype=jnp.int32).reshape(2, 16)}

    s = state0
    for _ in range(4):
        s, _ = step_fn(s, batch)
    straight = s

    s = state0
    for _ in range(2):
        s, _ = step_fn(s, batch)
    ck = Checkpointer(tmp_path)
    ck.save(2, s)
    _, s = ck.restore(s)
    for _ in range(2):
        s, _ = step_fn(s, batch)

    for a, b in zip(jax.tree.leaves(straight), jax.tree.leaves(s)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
