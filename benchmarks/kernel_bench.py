"""Kernel microbenchmarks (CPU wall-time is indicative only; the structural
numbers -- FLOPs per variant and HBM-traffic model -- are the TPU-relevant
output and feed the EXPERIMENTS.md kernel table)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.quantize.ops import dequantize_int8, quantize_int8

from benchmarks.common import save, table

ARTIFACT = "kernels"  # results/BENCH_kernels.json


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def run() -> dict:
    rows = []
    b, h, kh, hd = 1, 8, 2, 64
    for s, block in ((1024, 256), (4096, 512)):
        q = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, hd), jnp.float32)
        k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kh, hd), jnp.float32)
        v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kh, hd), jnp.float32)
        flash = jax.jit(lambda q, k, v: flash_attention(q, k, v, block=block))
        naive = jax.jit(lambda q, k, v: attention_ref(q, k, v))
        t_f = _time(flash, q, k, v)
        t_n = _time(naive, q, k, v)
        # structural numbers (per device, causal):
        flops = 4 * b * h * hd * (s * s // 2)
        naive_hbm = b * h * s * s * 4 * 2  # logits + probs materialized
        flash_hbm = 3 * b * s * h * hd * 4 + b * s * h * hd * 4
        rows.append({
            "kernel": "flash_attention", "seq": s,
            "cpu_ms": t_f * 1e3, "naive_cpu_ms": t_n * 1e3,
            "gflops": flops / 1e9,
            "hbm_traffic_ratio_naive/flash": naive_hbm / flash_hbm,
        })
    x = jax.random.normal(jax.random.PRNGKey(3), (64, 4096), jnp.bfloat16)
    t_q = _time(lambda x: quantize_int8(x, 256), x)
    q8, sc = quantize_int8(x, 256)
    t_d = _time(lambda q, s: dequantize_int8(q, s), q8, sc)
    rows.append({
        "kernel": "quantize_int8", "seq": 4096, "cpu_ms": t_q * 1e3,
        "naive_cpu_ms": t_d * 1e3,
        "gflops": 0.0,
        "hbm_traffic_ratio_naive/flash": 2.0 * x.dtype.itemsize / (1 + 4 / 256),
    })
    payload = {"rows": rows}
    save(ARTIFACT, payload)
    print(table(rows, ["kernel", "seq", "cpu_ms", "naive_cpu_ms",
                       "hbm_traffic_ratio_naive/flash"], "Kernel microbench"))
    return payload


if __name__ == "__main__":
    run()
