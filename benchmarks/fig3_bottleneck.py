"""Paper Fig. 3: bottleneck latency vs model x capacity x nodes x classes.

Reproduces the color-map experiment: randomly placed edge devices, distance-
derived wireless bandwidths, ``trials`` seeds per cell (paper: 50), mean
bottleneck latency per cell.  The paper's qualitative claims checked here:
  * more nodes / higher capacity / more bandwidth classes => lower latency,
  * improvement reaches ~2x (200% throughput) across the sweep.
"""

from __future__ import annotations

import numpy as np

from repro.core.model_zoo import PAPER_MODELS
from repro.core.simulate import aggregate, sweep

from benchmarks.common import save, table

ARTIFACT = "fig3"  # results/BENCH_fig3.json

CAPACITY_FRACS = [0.15, 0.3, 0.6]  # node capacity as a fraction of model size
NODE_COUNTS = [4, 8, 12]
CLASS_COUNTS = [1, 2, 4, 8]


def _capacities(graph) -> list[float]:
    """Per-model node capacities; always >= the largest single layer."""
    biggest = max(l.param_bytes for l in graph.layers)
    return [
        max(f * graph.total_param_bytes, 1.05 * biggest) for f in CAPACITY_FRACS
    ]


def run(trials: int = 12, seed: int = 0) -> dict:
    results = []
    for name, fn in PAPER_MODELS.items():
        graph = fn()
        results += sweep(
            {name: fn},
            capacities=_capacities(graph),
            node_counts=NODE_COUNTS,
            class_counts=CLASS_COUNTS,
            trials=trials,
            base_seed=seed,
        )
    cells = aggregate(results)
    rows = [
        {
            "model": k[0], "capacity_mb": k[1] / 1e6, "nodes": k[2],
            "classes": k[3],
            # cells with zero feasible trials aggregate to inf/0; encode the
            # missing mean as None so the artifact stays valid JSON
            **{
                m: (None if not np.isfinite(v) else round(v, 6))
                for m, v in vals.items()
            },
        }
        for k, vals in cells.items()
    ]

    # paper claim: best cell vs worst feasible cell per model -> up to ~2x
    claims = {}
    for model in PAPER_MODELS:
        feas = [r for r in rows if r["model"] == model and r["feasible_frac"] > 0.5]
        if not feas:
            continue
        lats = [r["mean_bottleneck_s"] for r in feas]
        claims[model] = {
            "worst_s": max(lats), "best_s": min(lats),
            "improvement_x": max(lats) / min(lats),
        }
    payload = {"rows": rows, "claims": claims, "trials": trials}
    save(ARTIFACT, payload)
    print(table(
        [dict(model=m, **c) for m, c in claims.items()],
        ["model", "worst_s", "best_s", "improvement_x"],
        "Fig.3 sweep: bottleneck-latency improvement (best vs worst cell)",
    ))
    return payload


if __name__ == "__main__":
    run()
