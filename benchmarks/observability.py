"""Observability-plane gate: tracer overhead, attribution exactness, pins.

Five properties of the ``repro.obs`` plane, each persisted as a pinned row
(the same ``{check, value, bound, ok}`` shape the kernel gates use):

  * **overhead** -- the tracer's marginal cost as a fraction of untraced
    serving time.  The pinned number is a *decomposition*: the per-span /
    per-request hot-path costs are microbenchmarked on the real tracer
    code (tight loops, best-of minima -- stable to a few percent), scaled
    by the span counts the workload actually emits, doubled to cover the
    engine-side call-site bookkeeping, and divided by the measured
    untraced drain time.  A direct A/B wall-clock ratio is *also* recorded
    (``ab_overhead_*``) but not pinned: on shared runners two back-to-back
    40 ms drains jitter by +-5-10%, far above the ~1% effect under test,
    so pinning the A/B number would gate merges on scheduler luck.
  * **attribution exactness** -- a traced request's critical-path fractions
    (queue/compute/wire/transcode) sum to 1 within 1e-6: spans tile the
    request's life contiguously, by construction.
  * **service-time pin** -- observed per-stage exec medians on a churn-free
    run sit within 5% of the plan's ``core.bottleneck.service_times``
    prediction, and the observed bottleneck resource is the plan's.
  * **journal recovery record** -- a mid-stream node kill leaves a
    ``kind="recovery"`` record in the control-plane journal whose
    affected-stage set matches ``Dispatcher.last_recovery``.
  * **export validity + determinism** -- the Chrome trace export is
    structurally valid (ph/ts/pid/tid on every event, per-request tracks
    non-overlapping) and a same-seed rerun is byte-identical.

The node-kill run's Chrome trace is also written next to the artifact
(``results/BENCH_observability.trace.json``) as a loadable sample.

  PYTHONPATH=src python -m benchmarks.observability [--smoke]
"""

from __future__ import annotations

import argparse
import gc
import json
import statistics
import time

import jax.numpy as jnp

from repro.api import ClusterSpec, DeploymentSpec, TraceConfig, deploy
from repro.cluster import NodeFailed
from repro.core.model_zoo import demo_mlp
from repro.obs import analyze_spans
from repro.obs.critical_path import pin_service_times, predicted_times

from benchmarks.common import RESULTS_DIR, save, table

ARTIFACT = "observability"  # results/BENCH_observability.json

D = 32

# wall-clock noise floor on the measured pieces (untraced drain minimum,
# microbench minima); the bounds below carry this much *additive* slack
# (documented, not hidden)
_TIMING_SLACK = 0.01

# the decomposition doubles the microbenched per-span cost to cover the
# engine-side call sites (_trace_open/_trace_close dispatch, link-window
# tiling) that the tight loop does not exercise
_CALLSITE_FACTOR = 2.0

OVERHEAD_FULL_BOUND = 0.03    # sample=1.0: <= 3% serving overhead
OVERHEAD_SPARSE_BOUND = 0.005  # sample=0.01: <= 0.5%


def _deploy(sample: float | None, *, model="demo_mlp", seed: int = 0,
            n_nodes: int = 8):
    if model == "demo_mlp":
        graph, executor_for_version = demo_mlp(d=D)
    else:  # timing-only zoo model (pass-through executor, real flops)
        from repro.core.model_zoo import PAPER_MODELS

        graph, executor_for_version = PAPER_MODELS[model](), None
    return deploy(DeploymentSpec(
        model=graph,
        executor_for_version=executor_for_version,
        cluster=ClusterSpec(n_nodes=n_nodes,
                            capacity_bytes=graph.total_param_bytes / 2.5,
                            seed=seed + 3),
        seed=seed,
        trace=None if sample is None else TraceConfig(sample=sample),
    ))


def _serve_once(sample: float | None, requests: int) -> tuple[float, object]:
    """One fresh deployment served to empty; returns (drain wall s, dep)."""
    d = _deploy(sample)
    x = jnp.ones((D,)) * 0.1
    for _ in range(requests):
        d.submit(x)
    t0 = time.perf_counter()
    d.drain()
    return time.perf_counter() - t0, d


class _BenchReq:
    """Minimal request stand-in for the tracer microbench (same attribute
    shape the fan-out path reads)."""

    __slots__ = ("req_id", "replica", "tenant", "attempts")

    def __init__(self, i: int):
        self.req_id = i
        self.replica = 0
        self.tenant = None
        self.attempts = 0


def _hot_path_costs(inner: int = 5000, reps: int = 5) -> dict:
    """Best-of minima of the tracer hot-path primitives, in seconds."""
    from repro.obs.trace import SpanTracer

    batch = [_BenchReq(i) for i in range(4)]
    span_cost = sample_cost = queue_cost = float("inf")
    for _ in range(reps):
        tr = SpanTracer(TraceConfig())
        t0 = time.perf_counter()
        for i in range(inner):
            tr.record_many(batch, "exec", float(i), i + 0.5,
                           stage=1, generation=0)
        span_cost = min(span_cost,
                        (time.perf_counter() - t0) / (inner * len(batch)))
        tr2 = SpanTracer(TraceConfig(sample=0.01))
        t0 = time.perf_counter()
        for i in range(4 * inner):
            tr2.sampled(i)
        sample_cost = min(sample_cost,
                          (time.perf_counter() - t0) / (4 * inner))
        tr3 = SpanTracer(TraceConfig())
        t0 = time.perf_counter()
        for i in range(inner):
            tr3.queue_open(i, float(i))
            tr3.queue_since.pop(i)
        queue_cost = min(queue_cost, (time.perf_counter() - t0) / inner)
    return {"span_s": span_cost, "sample_s": sample_cost,
            "queue_s": queue_cost}


def _overhead(requests: int, reps: int) -> dict:
    """Tracer cost share of one serving run (see module docstring).

    Pinned: the decomposed estimate (microbenched per-span/per-request
    costs x the workload's real span counts / untraced drain minimum).
    Context only: the direct A/B medians, order-rotated per rep.
    """
    _serve_once(None, requests)  # warm the jax dispatch caches
    _serve_once(1.0, requests)
    configs = [("off", None), ("full", 1.0), ("sparse", 0.01)]
    times = {"off": [], "sparse": [], "full": []}
    spans = {"full": 0, "sparse": 0}
    ratios = {"sparse": [], "full": []}
    for rep in range(reps):
        # rotate the in-rep order so monotone machine drift biases every
        # config equally across reps instead of always taxing the last one
        order = configs[rep % 3:] + configs[:rep % 3]
        t = {}
        gc.collect()
        gc.disable()
        try:
            for key, sample in order:
                t[key], dep = _serve_once(sample, requests)
                if key in spans:
                    spans[key] = len(dep.tracer.spans)
        finally:
            gc.enable()
        for key in times:
            times[key].append(t[key])
        ratios["full"].append(t["full"] / t["off"])
        ratios["sparse"].append(t["sparse"] / t["off"])
    costs = _hot_path_costs()
    off_s = min(times["off"])
    # every submitted request pays one sampling decision + the admission
    # queue bookkeeping; every emitted span pays the record fan-out, with
    # the call-site factor covering the engine-side transition code
    per_req = costs["sample_s"] + costs["queue_s"]
    estimate = {
        key: (spans[key] * costs["span_s"] * _CALLSITE_FACTOR
              + requests * per_req) / off_s
        for key in spans
    }
    med = statistics.median
    return {
        "off_s": off_s,
        "sparse_s": min(times["sparse"]),
        "full_s": min(times["full"]),
        "spans_full": spans["full"],
        "spans_sparse": spans["sparse"],
        "span_cost_ns": costs["span_s"] * 1e9,
        "sample_cost_ns": costs["sample_s"] * 1e9,
        "queue_cost_ns": costs["queue_s"] * 1e9,
        "overhead_full": estimate["full"],
        "overhead_sparse": estimate["sparse"],
        "ab_overhead_full": med(ratios["full"]) - 1.0,
        "ab_overhead_sparse": med(ratios["sparse"]) - 1.0,
    }


def _chrome_valid(trace: dict) -> bool:
    """Structural validity: required fields on every event, X events with
    non-negative durations, per-(pid, tid) tracks non-overlapping."""
    tracks: dict[tuple, list[tuple[float, float]]] = {}
    for ev in trace["traceEvents"]:
        if not all(k in ev for k in ("ph", "pid", "tid")):
            return False
        if ev["ph"] == "M":
            continue
        if ev["ph"] != "X" or "ts" not in ev or "dur" not in ev:
            return False
        if ev["dur"] < 0:
            return False
        tracks.setdefault((ev["pid"], ev["tid"]), []).append(
            (ev["ts"], ev["dur"]))
    for spans in tracks.values():
        spans.sort()
        for (t0, d0), (t1, _) in zip(spans, spans[1:]):
            if t1 < t0 + d0 - 1e-6:  # overlap beyond float slop (us)
                return False
    return True


def run(requests: int = 192, reps: int = 6,
        timing_slack: float = _TIMING_SLACK) -> dict:
    rows = []

    def pin(check: str, value: float, bound: float) -> None:
        rows.append({"check": check, "value": float(value),
                     "bound": float(bound), "ok": bool(value <= bound)})

    # --- tracer overhead ----------------------------------------------------
    ov = _overhead(requests, reps)
    pin("overhead_at_sample_1.0", ov["overhead_full"],
        OVERHEAD_FULL_BOUND + timing_slack)
    pin("overhead_at_sample_0.01", ov["overhead_sparse"],
        OVERHEAD_SPARSE_BOUND + timing_slack)

    # --- attribution exactness (spans tile each request's life) -------------
    _, d = _serve_once(1.0, requests=24)
    att = analyze_spans(d.tracer.spans)
    pin("fraction_sum_abs_err",
        abs(sum(att["fractions"][g] for g in att["fractions"]) - 1.0), 1e-6)
    worst = 0.0
    for req in d.loop.completed:
        spans = d.tracer.spans_for(req.req_id)
        worst = max(worst, abs(sum(s.duration_s for s in spans)
                               - req.latency_s))
    pin("span_coverage_vs_latency_abs_err_s", worst, 1e-9)

    # --- per-stage service times vs. the plan (churn-free, real flops) ------
    ds = _deploy(1.0, model="mobilenetv2", seed=1)
    x = jnp.ones((8, 8)) * 0.1
    for _ in range(12):
        ds.submit(x)
    ds.drain()
    analysis = analyze_spans(ds.tracer.spans)
    times = predicted_times(ds.control)
    pin_report = pin_service_times(analysis, *times, rel_tol=0.05)
    pin("stage_service_max_rel_err", pin_report["max_rel_err"],
        pin_report["rel_tol"])
    pin("bottleneck_agrees_with_plan",
        0.0 if pin_report["bottleneck_agrees"] else 1.0, 0.0)

    # --- node-kill journal + exported sample trace --------------------------
    dk = _deploy(1.0, seed=2)
    xk = jnp.ones((D,)) * 0.1
    for _ in range(32):
        dk.submit(xk)
    killed = False
    while dk.loop.backlog or dk.pending:
        if not killed and len(dk.loop.completed) >= 16:
            dk.inject(NodeFailed(dk.control.pipeline.pods[1].node_id))
            killed = True
        if not dk.step() and not dk.pending and not dk.loop.backlog:
            break
    recoveries = dk.journal.select(kind="recovery")
    last = dk.control.dispatcher.last_recovery
    journal_ok = bool(
        recoveries and last is not None
        and recoveries[-1].detail["affected_stages"]
        == list(last["affected_stages"])
        and recoveries[-1].detail["scoped"] == last["scoped"])
    pin("journal_recovery_matches_dispatcher",
        0.0 if journal_ok else 1.0, 0.0)

    chrome = dk.chrome_trace()
    pin("chrome_trace_valid", 0.0 if _chrome_valid(chrome) else 1.0, 0.0)
    trace_path = RESULTS_DIR / "BENCH_observability.trace.json"
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    trace_path.write_text(json.dumps(chrome))

    # --- same-seed determinism (byte-identical timelines) -------------------
    _, d1 = _serve_once(1.0, requests=16)
    _, d2 = _serve_once(1.0, requests=16)
    identical = (json.dumps(d1.trace_timeline())
                 == json.dumps(d2.trace_timeline()))
    pin("same_seed_trace_identical", 0.0 if identical else 1.0, 0.0)

    payload = {
        "rows": rows,
        "requests": requests,
        "reps": reps,
        "timing_slack": timing_slack,
        "callsite_factor": _CALLSITE_FACTOR,
        "drain_off_ms": ov["off_s"] * 1e3,
        "drain_sparse_ms": ov["sparse_s"] * 1e3,
        "drain_full_ms": ov["full_s"] * 1e3,
        "spans_full": ov["spans_full"],
        "spans_sparse": ov["spans_sparse"],
        "span_cost_ns": ov["span_cost_ns"],
        "sample_cost_ns": ov["sample_cost_ns"],
        "queue_cost_ns": ov["queue_cost_ns"],
        "ab_overhead_full": ov["ab_overhead_full"],
        "ab_overhead_sparse": ov["ab_overhead_sparse"],
        "fractions": att["fractions"],
        "observed_bottleneck": analysis["bottleneck"],
        "predicted_bottleneck": pin_report["predicted_bottleneck"],
        "journal_records": len(dk.journal),
        "journal_kinds": dk.journal.summary()["kinds"],
        "chrome_events": len(chrome["traceEvents"]),
    }
    save(ARTIFACT, payload)
    print(table(rows, ["check", "value", "bound", "ok"],
                "Observability plane"))
    print(f"sample Chrome trace: {trace_path}")
    bad = [r["check"] for r in rows if not r["ok"]]
    if bad:
        raise RuntimeError(f"observability pins violated: {bad}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="fewer requests/reps")
    args = ap.parse_args()
    run(requests=64 if args.smoke else 192, reps=3 if args.smoke else 6)
