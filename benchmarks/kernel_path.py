"""Kernel fast-path gate: parity pins + fused dequant-matmul timing.

This is the acceptance gate for serving real models through the Pallas
kernels.  It runs the SAME code the serving engines run -- a deployed
``demo_transformer`` pipeline whose stages execute flash attention and whose
int8-coded hops decode inside the receiving stage's first matmul (the fused
dequant-matmul handler) -- and pins:

  * int8 round-trip relative error      <= INT8_MAX_REL_ERROR (the constant
    the data plane reports to the planner's accuracy check);
  * flash kernel (interpret) vs ref     <= 2e-5 f32 (the documented forward
    tolerance from tests/test_kernels.py);
  * fused vs unfused dequant-matmul     <= 1e-5 (same math, one dispatch);
  * Pallas e2e deployment vs reference  <= INT8_MAX_REL_ERROR relative;
  * fused one-dispatch service time     <= unfused two-dispatch (dequantize
    then matmul) -- the whole point of fusing the data plane into compute.

Any violated pin raises, so CI fails loudly instead of shipping a fast path
that silently drifts from the reference numerics.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.kernel import flash_attention_tpu
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.quantize import (
    INT8_MAX_REL_ERROR,
    dequant_matmul,
    dequantize_int8,
    quantize_int8,
)

from benchmarks.common import save, table

ARTIFACT = "kernel_path"  # results/BENCH_kernel_path.json

# timing noise floor: best-of-N minima still jitter a few percent on shared
# CI runners, so the <= gate carries this much slack (documented, not hidden)
_TIMING_SLACK = 1.05


def _best_interleaved(fns, args, reps: int = 15) -> list[float]:
    """Best-of-``reps`` wall time per fn, measured round-robin so slow drift
    on a shared runner hits every candidate equally."""
    for fn in fns:
        jax.block_until_ready(fn(*args))  # compile + warm caches
    best = [float("inf")] * len(fns)
    for _ in range(reps):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            best[i] = min(best[i], time.perf_counter() - t0)
    return best


def _rel_err(got, want) -> float:
    got = np.asarray(got, np.float32)
    want = np.asarray(want, np.float32)
    scale = max(1e-9, float(np.max(np.abs(want))))
    return float(np.max(np.abs(got - want))) / scale


def _e2e_outputs() -> dict[bool, np.ndarray]:
    """Deploy demo_transformer twice (reference / Pallas-interpret), int8 on
    the wire, and return each deployment's output for the same input."""
    from repro.api import ClusterSpec, DeploymentSpec, deploy
    from repro.core.model_zoo import demo_transformer

    x = jnp.ones((256, 32)) * 0.1
    outs = {}
    for use_pallas in (False, True):
        graph, executor_for_version = demo_transformer(
            use_pallas=use_pallas, interpret=use_pallas)
        d = deploy(DeploymentSpec(
            model=graph,
            executor_for_version=executor_for_version,
            cluster=ClusterSpec(n_nodes=6,
                                capacity_bytes=graph.total_param_bytes / 2.5,
                                seed=5),
            codec="int8",
            seed=3,
            use_pallas=use_pallas,
            interpret=use_pallas,
        ))
        if "int8" not in d.control.pipeline.executor.fused_codecs:
            raise RuntimeError("demo_transformer lost its fused int8 handler")
        if "int8" not in d.plan.codecs:
            raise RuntimeError("planner put no int8 hop on the wire")
        d.submit(x)
        (req,) = d.drain()
        outs[use_pallas] = np.asarray(req.result)
    return outs


def run(reps: int = 15, timing_slack: float = _TIMING_SLACK) -> dict:
    rows = []

    def pin(check: str, value: float, bound: float) -> None:
        rows.append({"check": check, "value": float(value),
                     "bound": float(bound), "ok": bool(value <= bound)})

    # --- int8 hop round-trip, kernel (interpret) path -----------------------
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 2048), jnp.float32)
    q, s = quantize_int8(x, 256, use_pallas=True, interpret=True)
    y = dequantize_int8(q, s, dtype=jnp.float32, block=256,
                        use_pallas=True, interpret=True)
    scale = float(jnp.max(jnp.abs(x)))
    pin("int8_roundtrip_rel_err", float(jnp.max(jnp.abs(y - x))) / scale,
        INT8_MAX_REL_ERROR)

    # --- flash attention kernel (interpret) vs ref --------------------------
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(1), 3)
    b, sq, h, kh, hd = 1, 512, 4, 2, 64
    fq = jax.random.normal(kq, (b, sq, h, hd), jnp.float32)
    fk = jax.random.normal(kk, (b, sq, kh, hd), jnp.float32)
    fv = jax.random.normal(kv, (b, sq, kh, hd), jnp.float32)
    out = flash_attention_tpu(fq, fk, fv, causal=True, window=128,
                              softcap=50.0, block_q=128, block_k=128,
                              interpret=True)
    ref = attention_ref(fq, fk, fv, causal=True, window=128, softcap=50.0)
    pin("flash_interpret_max_abs_err", float(jnp.max(jnp.abs(out - ref))),
        2e-5)

    # --- fused dequant-matmul parity (ref and Pallas-interpret) -------------
    rows_n, d_in, d_out, blk = 512, 2048, 2048, 256
    w = jax.random.normal(jax.random.PRNGKey(2), (d_in, d_out),
                          jnp.float32) * 0.05
    xa = jax.random.normal(jax.random.PRNGKey(3), (rows_n, d_in), jnp.float32)
    qa, sa = quantize_int8(xa, blk)
    unfused_out = dequantize_int8(qa, sa, dtype=jnp.float32, block=blk) @ w
    pin("fused_vs_unfused_rel_err",
        _rel_err(dequant_matmul(qa, sa, w, dtype=jnp.float32, block=blk),
                 unfused_out), 1e-5)
    pin("fused_pallas_interpret_rel_err",
        _rel_err(dequant_matmul(qa, sa, w, dtype=jnp.float32, block=blk,
                                use_pallas=True, interpret=True),
                 unfused_out), 1e-5)

    # --- e2e: deployed demo_transformer, Pallas vs reference ----------------
    outs = _e2e_outputs()
    pin("e2e_pallas_vs_ref_rel_err", _rel_err(outs[True], outs[False]),
        INT8_MAX_REL_ERROR)

    # --- fused one-dispatch <= unfused two-dispatch service time ------------
    deq = jax.jit(lambda q, s: dequantize_int8(q, s, dtype=jnp.float32,
                                               block=blk))
    mm = jax.jit(lambda a, b_: a @ b_)
    unfused_s, fused_s = _best_interleaved(
        [lambda q, s, w_: mm(deq(q, s), w_),
         lambda q, s, w_: dequant_matmul(q, s, w_, dtype=jnp.float32,
                                         block=blk)],
        (qa, sa, w), reps=reps)
    pin("fused_over_unfused_time_ratio", fused_s / unfused_s, timing_slack)

    payload = {
        "rows": rows,
        "fused_ms": fused_s * 1e3,
        "unfused_ms": unfused_s * 1e3,
        "int8_max_rel_error": INT8_MAX_REL_ERROR,
        "timing_slack": timing_slack,
    }
    save(ARTIFACT, payload)
    print(table(rows, ["check", "value", "bound", "ok"], "Kernel fast path"))
    bad = [r["check"] for r in rows if not r["ok"]]
    if bad:
        raise RuntimeError(f"kernel fast-path pins violated: {bad}")
    return payload


if __name__ == "__main__":
    run()
