"""Codec throughput vs link bandwidth: the data plane's reason to exist.

SEIFER pipelines on edge networks are link-bound -- the inter-partition
activation transfer sets the bottleneck period -- and DEFER (the companion
paper) shows lossy activation compression restores throughput.  This sweep
MEASURES that: a demo_mlp pipeline is served through the discrete-event
engine over a cluster whose *inter-node* mesh bandwidth is swept across four
decades (the dispatcher's own links stay fast, so the constrained resource
is exactly the inter-stage activation path), once per registered codec plus
``codec="auto"``.

Asserted claims (the PR's acceptance criteria):

  * ``int8`` >= ``identity`` throughput on the most constrained link;
  * ``auto`` picks a compressing codec there and improves >= 1.5x over
    ``identity``;
  * engine-measured steady-state throughput is within 5% of
    ``Plan.predicted_throughput`` for EVERY codec at EVERY bandwidth (the
    engine and the planner share ``core.bottleneck.service_times``).

  PYTHONPATH=src python -m benchmarks.bandwidth_sweep [--requests N]
"""

from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

from repro.api import ClusterSpec, DeploymentSpec, deploy
from repro.core.model_zoo import demo_mlp
from repro.core.placement import CommGraph
from repro.dataplane import list_codecs

from benchmarks.common import save, table

ARTIFACT = "bandwidth_sweep"  # results/BENCH_bandwidth_sweep.json

WIDTH = 32  # demo_mlp width; boundaries carry 16 * 32 * 4 = 2048 B
HOSTING = 4  # one partition per hosting node (capacity = total / 3)
DISPATCHER_BW = 1e9  # node 0's links stay fast: the mesh is the bottleneck
BANDWIDTHS = (1e4, 1e5, 1e6, 1e7)  # bytes/s across the hosting mesh


def _cluster(mesh_bw: float) -> CommGraph:
    """Star-plus-mesh: fast dispatcher links, ``mesh_bw`` everywhere else."""
    n = HOSTING + 1
    bw = np.full((n, n), float(mesh_bw))
    bw[0, :] = bw[:, 0] = DISPATCHER_BW
    np.fill_diagonal(bw, 0.0)
    graph, _ = demo_mlp(d=WIDTH)
    cap = np.full(n, graph.total_param_bytes / 3.0)
    cap[0] = -1.0  # dispatcher hosts no partition
    return CommGraph(bw=bw, node_capacity=cap)


def _measure(codec: str, mesh_bw: float, requests: int, seed: int) -> dict:
    graph, executor_for_version = demo_mlp(d=WIDTH)
    dep = deploy(DeploymentSpec(
        model=graph,
        executor_for_version=executor_for_version,
        cluster=ClusterSpec(comm=_cluster(mesh_bw)),
        codec=codec,
        seed=seed,
        microbatch=1,  # measured requests/s == predicted microbatch rate
        serving="pipelined",
    ))
    for _ in range(requests):
        dep.submit(jnp.ones((WIDTH,)) * 0.1)
    dep.drain()
    assert len(dep.loop.failed) == 0
    assert len(dep.loop.completed) == requests
    measured = float(dep.loop.steady_state_throughput())
    predicted = float(dep.plan.predicted_throughput)
    links = [ln for ln in dep.loop.metrics()["links"]
             if ln["raw_bytes"] > 0 and 0 < ln["hop"] < len(dep.plan.path)]
    return {
        "bandwidth": mesh_bw,
        "codec": codec,
        "link_codecs": "|".join(dep.plan.codecs),
        "predicted": predicted,
        "measured": measured,
        "vs_predicted": measured / predicted if predicted > 0 else 0.0,
        "compression_x": (
            float(np.mean([ln["compression_x"] for ln in links]))
            if links else 1.0
        ),
    }


def run(requests: int = 48, seed: int = 0) -> dict:
    codecs = (*list_codecs(), "auto")
    rows = [
        _measure(codec, bw, requests, seed)
        for bw in BANDWIDTHS
        for codec in codecs
    ]
    by = {(r["bandwidth"], r["codec"]): r for r in rows}
    slow = min(BANDWIDTHS)
    ident, int8 = by[(slow, "identity")], by[(slow, "int8")]
    auto = by[(slow, "auto")]
    claims = {
        "int8_vs_identity_at_min_bw": int8["measured"] / ident["measured"],
        "auto_vs_identity_at_min_bw": auto["measured"] / ident["measured"],
        "auto_codecs_at_min_bw": auto["link_codecs"],
        "worst_vs_predicted": min(r["vs_predicted"] for r in rows),
        "best_vs_predicted": max(r["vs_predicted"] for r in rows),
    }
    payload = {
        "rows": rows,
        "claims": claims,
        "model": f"demo_mlp(d={WIDTH})",
        "requests": requests,
        "bandwidths": list(BANDWIDTHS),
        "serving": {"engine": "pipelined discrete-event",
                    "dispatcher_bw": DISPATCHER_BW},
    }
    save(ARTIFACT, payload)
    print(table(rows, ["bandwidth", "codec", "predicted", "measured",
                       "vs_predicted", "compression_x"],
                "Pipelined throughput per transfer codec vs mesh bandwidth"))
    print(f"claims: {claims}")
    assert claims["int8_vs_identity_at_min_bw"] >= 1.0, (
        f"int8 must not lose to identity on the constrained mesh, got "
        f"{claims['int8_vs_identity_at_min_bw']:.2f}x"
    )
    assert claims["auto_vs_identity_at_min_bw"] >= 1.5, (
        f"codec='auto' must beat identity >= 1.5x on the constrained mesh, "
        f"got {claims['auto_vs_identity_at_min_bw']:.2f}x"
    )
    assert any(c not in ("identity",) for c in
               auto["link_codecs"].split("|")[1:-1]), (
        "auto kept every inter-stage link uncompressed on a link-bound cluster"
    )
    assert 0.95 <= claims["worst_vs_predicted"], claims
    assert claims["best_vs_predicted"] <= 1.05, claims
    return payload


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(requests=args.requests, seed=args.seed)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
