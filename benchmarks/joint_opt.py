"""Paper Sec. 4 item 3: sequential (paper) vs joint partition+placement.

The joint search walks the partition-count frontier and re-places each
candidate; the benchmark quantifies the bottleneck-latency gap it closes.
Both optimizers are resolved by NAME through the strategy registry, so the
comparison is exactly what a ``DeploymentSpec(joint=...)`` would deploy."""

from __future__ import annotations

import numpy as np

from repro.api import get_strategy
from repro.core.model_zoo import PAPER_MODELS
from repro.core.simulate import random_cluster

from benchmarks.common import save, table

ARTIFACT = "joint_opt"  # results/BENCH_joint_opt.json


def run(trials: int = 16, n_nodes: int = 8, capacity_frac: float = 0.3, seed: int = 0) -> dict:
    sequential = get_strategy("joint", "sequential")
    joint = get_strategy("joint", "joint")
    rows = []
    for model, fn in PAPER_MODELS.items():
        graph = fn()
        biggest = max(l.param_bytes for l in graph.layers)
        capacity = max(capacity_frac * graph.total_param_bytes, 1.05 * biggest)
        gains, seq_lat, joint_lat = [], [], []
        for t in range(trials):
            comm = random_cluster(n_nodes, capacity, seed=seed + 613 * t)
            s = sequential(graph, comm, int(capacity), n_classes=4, seed=t)
            j = joint(graph, comm, int(capacity), n_classes=4, seed=t)
            if s.feasible and j.feasible and np.isfinite(s.bottleneck_latency):
                seq_lat.append(s.bottleneck_latency)
                joint_lat.append(j.bottleneck_latency)
                gains.append(s.bottleneck_latency / max(j.bottleneck_latency, 1e-12))
        if gains:
            rows.append({
                "model": model,
                "seq_mean_s": float(np.mean(seq_lat)),
                "joint_mean_s": float(np.mean(joint_lat)),
                "mean_speedup_x": float(np.mean(gains)),
                "max_speedup_x": float(np.max(gains)),
                "n": len(gains),
            })
    payload = {
        "rows": rows,
        "strategies": {"baseline": sequential.name, "candidate": joint.name},
        "n_nodes": n_nodes,
        "capacity_frac": capacity_frac,
    }
    save(ARTIFACT, payload)
    print(table(rows, ["model", "seq_mean_s", "joint_mean_s", "mean_speedup_x",
                       "max_speedup_x", "n"],
                "Sequential (paper) vs joint partition+placement"))
    return payload


if __name__ == "__main__":
    run()
