"""Aggregate serving throughput vs pipeline replica count (Fig. 5 style).

SEIFER's companion work frames the edge cluster as hosting *multiple*
parallel inference pipelines; this benchmark measures what replication buys
on a fixed 16-node symmetric cluster.  For each R the planner partitions the
hosting nodes into R disjoint sub-clusters, plans one pipeline per group,
and the cluster-wide router serves a request stream across them:

  * aggregate measured throughput should scale ~linearly in R while every
    group can still host the model (the depth-vs-width trade-off caps R);
  * the measurement must pin to the planner's SUMMED per-replica prediction
    (same ``service_times`` model) -- the run asserts within 5%;
  * ``replicas="auto"`` must find the best R on its own.

The run asserts the tentpole claim: at R=4 the aggregate is >= 3x the
single-pipeline measurement.

  PYTHONPATH=src python -m benchmarks.replica_scaling [--requests N]
"""

from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

from repro.api import ClusterSpec, DeploymentSpec, deploy
from repro.core.graph import Layer, LayerGraph
from repro.core.placement import CommGraph

from benchmarks.common import save, table

ARTIFACT = "replica_scaling"  # results/BENCH_replica_scaling.json

N_HOSTING = 16  # symmetric hosting nodes (+ node 0, the dispatcher)
N_LAYERS = 16
PARAM_BYTES = 1_000_000  # per layer
ACT_BYTES = 200_000  # per boundary activation
FLOPS = 20_000_000  # per layer: compute-bound stages, links cheap
LINK_BYTES_S = 20e6  # uniform link bandwidth
CAPACITY = 4.2e6  # 4 layers per node -> 4-stage pipelines
R_VALUES = (1, 2, 4)


def _graph() -> LayerGraph:
    layers = tuple(
        Layer(f"l{i}", param_bytes=PARAM_BYTES, out_bytes=ACT_BYTES, flops=FLOPS)
        for i in range(N_LAYERS)
    )
    return LayerGraph("synth16", layers, in_bytes=ACT_BYTES // 2)


def _comm(n_hosting: int = N_HOSTING) -> CommGraph:
    bw = np.full((n_hosting + 1, n_hosting + 1), LINK_BYTES_S)
    np.fill_diagonal(bw, 0.0)
    cap = np.full(n_hosting + 1, CAPACITY)
    cap[0] = -1.0  # dispatcher hosts no partition
    return CommGraph(bw=bw, node_capacity=cap)


def _measure(replicas, requests: int, seed: int) -> dict:
    spec = DeploymentSpec(
        model=_graph(),
        cluster=ClusterSpec(comm=_comm()),
        capacity=CAPACITY,
        seed=seed,
        microbatch=1,
        replicas=replicas,
    )
    dep = deploy(spec)
    n_rep = dep.replicaset.n_replicas if dep.replicated else 1
    for _ in range(requests * n_rep):
        dep.submit(jnp.ones((4,)))
    dep.drain()
    assert len(dep.loop.failed) == 0
    assert len(dep.loop.completed) == requests * n_rep
    measured = float(dep.loop.steady_state_throughput())
    predicted = float(dep.plan.predicted_throughput)
    return {
        "replicas": str(replicas),
        "pipelines": n_rep,
        "predicted_sum": predicted,
        "measured": measured,
        "vs_predicted": measured / predicted if predicted > 0 else 0.0,
    }


def run(requests: int = 60, seed: int = 0, r_values=R_VALUES) -> dict:
    rows = [_measure(r, requests, seed) for r in r_values]
    rows.append(_measure("auto", requests, seed))
    base = rows[0]["measured"] if rows[0]["pipelines"] == 1 else None
    for row in rows:
        row["speedup_vs_1"] = (
            row["measured"] / base if base else 0.0
        )
    auto = rows[-1]
    claims = {
        "auto_pipelines": auto["pipelines"],
        "auto_speedup_vs_1": auto["speedup_vs_1"],
        "max_speedup_vs_1": max(r["speedup_vs_1"] for r in rows),
        "worst_vs_predicted": min(r["vs_predicted"] for r in rows),
        "best_vs_predicted": max(r["vs_predicted"] for r in rows),
    }
    payload = {
        "rows": rows,
        "claims": claims,
        "cluster": {
            "hosting_nodes": N_HOSTING,
            "link_bytes_s": LINK_BYTES_S,
            "capacity_bytes": CAPACITY,
        },
        "requests_per_replica": requests,
        "serving": {"engine": "replicated router over pipelined engines"},
    }
    save(ARTIFACT, payload)
    print(table(rows, ["replicas", "pipelines", "predicted_sum", "measured",
                       "vs_predicted", "speedup_vs_1"],
                "Aggregate serving throughput vs replica count (16 nodes)"))
    print(f"claims: {claims}")
    # measurement pins to the planner's summed prediction on every row
    assert 0.95 <= claims["worst_vs_predicted"], claims
    assert claims["best_vs_predicted"] <= 1.05, claims
    four = [r for r in rows if r["pipelines"] == 4]
    if base and four:
        assert four[0]["speedup_vs_1"] >= 3.0, (
            f"replicas=4 must be >= 3x the single pipeline, got "
            f"{four[0]['speedup_vs_1']:.2f}x"
        )
    if base:
        # auto must not leave throughput on the table
        assert claims["auto_speedup_vs_1"] >= claims["max_speedup_vs_1"] - 1e-9
    return payload


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=60,
                    help="request stream size per replica")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(requests=args.requests, seed=args.seed)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
