"""Paper Sec. 4 item 2: algorithm vs optimal -- empirical approximation ratio.

On clusters small enough for the exact subset-DP on TRUE bandwidths, compare
the SEIFER pipeline (quantized bandwidth classes + color coding) against the
optimum, across class granularities -- and pin the hierarchical large-n
placer's quality against the same oracle.  The hierarchical rows force tiny
``group_size`` values so even an 8-node cluster splits into multiple groups
and the coarse-DP-over-representatives path is genuinely exercised (at the
default group size these clusters would be solved flat).

``claims`` bound the hierarchical degradation: mean ratio over every
(model, group_size) cell stays under ``HIER_MEAN_RATIO_MAX`` and no single
trial exceeds ``HIER_WORST_RATIO_MAX``.
"""

from __future__ import annotations

import numpy as np

from repro.core.model_zoo import PAPER_MODELS
from repro.core.partitioner import partition_min_bottleneck
from repro.core.placement import place_color_coding, place_hierarchical, place_optimal
from repro.core.simulate import random_cluster

from benchmarks.common import save, table

ARTIFACT = "approx_ratio"  # results/BENCH_approx_ratio.json

HIER_MEAN_RATIO_MAX = 2.0  # per-cell mean bottleneck vs exact subset-DP
HIER_WORST_RATIO_MAX = 4.0  # any single trial


def run(trials: int = 24, n_nodes: int = 8, capacity_frac: float = 0.3, seed: int = 0) -> dict:
    rows = []
    for model, fn in PAPER_MODELS.items():
        graph = fn()
        biggest = max(l.param_bytes for l in graph.layers)
        capacity = max(capacity_frac * graph.total_param_bytes, 1.05 * biggest)
        part = partition_min_bottleneck(graph, int(capacity), max_parts=n_nodes)
        if not part.feasible:
            continue
        weights = list(part.boundaries)
        sizes = [p.param_bytes for p in part.partitions]
        for classes in (1, 2, 4, 8, None):
            ratios = []
            for t in range(trials):
                comm = random_cluster(n_nodes, capacity, seed=seed + 97 * t)
                opt = place_optimal(weights, sizes, comm)
                alg = place_color_coding(weights, sizes, comm, n_classes=classes,
                                         seed=t, exact_limit=0, trials=80)
                if opt.feasible and alg.feasible and opt.bottleneck_latency > 0:
                    ratios.append(alg.bottleneck_latency / opt.bottleneck_latency)
            if ratios:
                rows.append({
                    "model": model,
                    "algo": "color_coding",
                    "classes": classes if classes else "inf",
                    "mean_ratio": float(np.mean(ratios)),
                    "p95_ratio": float(np.quantile(ratios, 0.95)),
                    "max_ratio": float(np.max(ratios)),
                    "n": len(ratios),
                })
        for group_size in (3, 4):
            ratios = []
            for t in range(trials):
                comm = random_cluster(n_nodes, capacity, seed=seed + 97 * t)
                opt = place_optimal(weights, sizes, comm)
                alg = place_hierarchical(weights, sizes, comm, n_classes=4,
                                         seed=t, group_size=group_size)
                if opt.feasible and alg.feasible and opt.bottleneck_latency > 0:
                    ratios.append(alg.bottleneck_latency / opt.bottleneck_latency)
            if ratios:
                rows.append({
                    "model": model,
                    "algo": f"hierarchical(g={group_size})",
                    "classes": 4,
                    "mean_ratio": float(np.mean(ratios)),
                    "p95_ratio": float(np.quantile(ratios, 0.95)),
                    "max_ratio": float(np.max(ratios)),
                    "n": len(ratios),
                })
    hier = [r for r in rows if r["algo"].startswith("hierarchical")]
    claims = {
        "hier_mean_ratio": max(r["mean_ratio"] for r in hier),
        "hier_worst_ratio": max(r["max_ratio"] for r in hier),
        "hier_mean_ratio_max": HIER_MEAN_RATIO_MAX,
        "hier_worst_ratio_max": HIER_WORST_RATIO_MAX,
    }
    payload = {"rows": rows, "n_nodes": n_nodes,
               "capacity_frac": capacity_frac, "claims": claims}
    save(ARTIFACT, payload)
    print(table(rows, ["model", "algo", "classes", "mean_ratio", "p95_ratio",
                       "max_ratio", "n"],
                "Placement vs optimal (approximation ratio)"))
    print(f"claims: {claims}")
    assert claims["hier_mean_ratio"] <= HIER_MEAN_RATIO_MAX, claims
    assert claims["hier_worst_ratio"] <= HIER_WORST_RATIO_MAX, claims
    return payload


if __name__ == "__main__":
    run()
