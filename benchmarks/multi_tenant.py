"""Multi-tenant co-residency: throughput retention + churn isolation.

Two heterogeneous tenants share one 16-hosting-node edge cluster under the
tenancy scheduler's partition carve (50/50 capacity fractions).  The two
claims that make tenancy worth its complexity:

  * **throughput retention** -- each co-located tenant completes >= 70% of
    the closed-loop throughput it achieves when deployed *alone* on the
    full cluster.  (The carve halves each tenant's node count, but a
    pipeline only needs as many nodes as it has stages, so a well-packed
    slice keeps the bottleneck unchanged.)
  * **churn isolation** -- killing a node that hosts only tenant A leaves
    tenant B's completion cadence (median inter-completion gap) within 5%
    of its pre-churn value: A re-plans inside its slice, B's engine never
    hears about it.

  PYTHONPATH=src python -m benchmarks.multi_tenant [--requests N]
"""

from __future__ import annotations

import argparse
import statistics

import numpy as np

from repro.api import ClusterSpec, DeploymentSpec, TenantSpec, deploy
from repro.cluster import NodeFailed
from repro.core.graph import Layer, LayerGraph
from repro.core.placement import CommGraph

from benchmarks.common import save, table

ARTIFACT = "multi_tenant"  # results/BENCH_multi_tenant.json

N_HOSTING = 16
LINK_BYTES_S = 20e6
CAPACITY = 4.2e6
RETENTION_FLOOR = 0.70
CADENCE_TOL = 0.05

# heterogeneous tenants: different depths, widths, and compute densities
TENANT_SHAPES = {
    "alpha": dict(n_layers=16, param_bytes=1_000_000, act_bytes=200_000,
                  flops=20_000_000),
    "beta": dict(n_layers=12, param_bytes=1_500_000, act_bytes=150_000,
                 flops=30_000_000),
}


def _graph(name: str, n_layers: int, param_bytes: int, act_bytes: int,
           flops: int) -> LayerGraph:
    layers = tuple(
        Layer(f"{name}{i}", param_bytes=param_bytes, out_bytes=act_bytes,
              flops=flops)
        for i in range(n_layers)
    )
    return LayerGraph(name, layers, in_bytes=act_bytes // 2)


def _comm() -> CommGraph:
    bw = np.full((N_HOSTING + 1, N_HOSTING + 1), LINK_BYTES_S)
    np.fill_diagonal(bw, 0.0)
    cap = np.full(N_HOSTING + 1, CAPACITY)
    cap[0] = -1.0  # dispatcher hosts no partition
    return CommGraph(bw=bw, node_capacity=cap)


def _spec(name: str, seed: int, comm: CommGraph | None = None) -> DeploymentSpec:
    return DeploymentSpec(
        model=_graph(name, **TENANT_SHAPES[name]),
        cluster=ClusterSpec(comm=comm if comm is not None else _comm()),
        capacity=CAPACITY,
        seed=seed,
        microbatch=1,  # one completion per request: clean cadence signal
    )


def solo_throughput(name: str, requests: int, seed: int) -> float:
    """Closed-loop throughput of the tenant alone on the full cluster."""
    dep = deploy(_spec(name, seed))
    for i in range(requests):
        dep.submit(i)
    dep.drain()
    assert len(dep.loop.completed) == requests
    return requests / dep.loop.clock_s


def _tenants(seed: int) -> list[TenantSpec]:
    comm = _comm()  # one shared cluster: tenants must agree on it
    return [
        TenantSpec(name, _spec(name, seed, comm), capacity_fraction=0.5)
        for name in TENANT_SHAPES
    ]


def colocated_throughput(requests: int, seed: int) -> dict[str, float]:
    """Per-tenant closed-loop throughput under the 50/50 partition carve."""
    d = deploy(_tenants(seed))
    for i in range(requests):
        for name in TENANT_SHAPES:
            d.submit(name, i)
    d.drain()
    out = {}
    for name in TENANT_SHAPES:
        loop = d.router.loop(name)
        assert len(loop.completed) == requests, (name, len(loop.completed))
        out[name] = requests / loop.clock_s
    return out


def _median_gap(times: list[float]) -> float:
    gaps = [b - a for a, b in zip(times, times[1:]) if b > a]
    return statistics.median(gaps)


def churn_isolation(requests: int, seed: int) -> dict:
    """Kill a node hosting only tenant alpha mid-stream; beta's completion
    cadence must not move."""
    d = deploy(_tenants(seed))
    for i in range(requests):
        for name in TENANT_SHAPES:
            d.submit(name, i)

    beta = d.router.loop("beta")
    # the victim must actually carry alpha's pipeline for the churn to bite
    victim = d.deployment("alpha").control.pipeline.pods[0].node_id
    assert victim in d.nodes_for("alpha")
    assert victim not in d.nodes_for("beta")

    kill_at = requests // 2
    killed_idx = None
    while d.router.backlog or d.pending:
        if killed_idx is None and len(beta.completed) >= kill_at:
            killed_idx = len(beta.completed)
            d.inject(NodeFailed(victim))
        if not d.step() and not d.pending and not d.router.backlog:
            break
    assert killed_idx is not None
    acts = {name: [a.kind for a in ctl.history]
            for name, ctl in (("alpha", d.deployment("alpha").control),
                              ("beta", d.deployment("beta").control))}
    assert len(beta.completed) == requests, len(beta.completed)

    times = sorted(r.completed_s for r in beta.completed)
    warmup = max(2, requests // 8)  # skip the pipeline-fill ramp
    pre = _median_gap(times[warmup:killed_idx])
    post = _median_gap(times[killed_idx:])
    drift = abs(post / pre - 1.0)
    return {
        "victim_node": victim,
        "killed_after_beta_completions": killed_idx,
        "alpha_actions": acts["alpha"],
        "beta_actions": acts["beta"],
        "beta_pre_gap_s": pre,
        "beta_post_gap_s": post,
        "beta_cadence_drift": drift,
    }


def run(requests: int = 48, seed: int = 0) -> dict:
    solo = {name: solo_throughput(name, requests, seed)
            for name in TENANT_SHAPES}
    colo = colocated_throughput(requests, seed)
    retention = {name: colo[name] / solo[name] for name in TENANT_SHAPES}
    iso = churn_isolation(requests, seed)

    rows = [
        {
            "tenant": name,
            "solo_req_s": solo[name],
            "colocated_req_s": colo[name],
            "retention": retention[name],
        }
        for name in TENANT_SHAPES
    ]
    claims = {
        "min_retention": min(retention.values()),
        "retention_floor": RETENTION_FLOOR,
        "beta_cadence_drift": iso["beta_cadence_drift"],
        "cadence_tolerance": CADENCE_TOL,
        "alpha_replanned": any(a != "noop" for a in iso["alpha_actions"]),
        "beta_untouched": iso["beta_actions"] == [],
    }
    payload = {
        "rows": rows,
        "isolation": iso,
        "claims": claims,
        "cluster": {
            "hosting_nodes": N_HOSTING,
            "link_bytes_s": LINK_BYTES_S,
            "capacity_bytes": CAPACITY,
            "policy": "partition",
            "fractions": {name: 0.5 for name in TENANT_SHAPES},
        },
        "workload": {"requests_per_tenant": requests, "seed": seed},
    }
    save(ARTIFACT, payload)
    print(table(rows, ["tenant", "solo_req_s", "colocated_req_s", "retention"],
                "Multi-tenant throughput retention (16 hosting nodes, 50/50)"))
    print(f"isolation: {iso}")
    print(f"claims: {claims}")

    # claim (a): each co-located tenant keeps >= 70% of its solo throughput
    assert claims["min_retention"] >= RETENTION_FLOOR, claims
    # claim (b): churn on alpha's slice leaves beta's cadence within 5%
    assert claims["beta_cadence_drift"] <= CADENCE_TOL, claims
    assert claims["alpha_replanned"], iso
    assert claims["beta_untouched"], iso
    return payload


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=48,
                    help="closed-loop requests per tenant per leg")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(requests=args.requests, seed=args.seed)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
