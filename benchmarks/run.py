"""Benchmark driver: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--fast]

Each benchmark module persists its payload as ``results/BENCH_<name>.json``
(single canonical casing, schema-validated by ``benchmarks.common.save``) so
the perf trajectory is diffable across PRs instead of living only in CI
logs.  The driver just sequences the modules and reports where the
artifacts landed.

``bench_registry()`` exposes the name -> (module, runner) table so tests can
assert every artifact-producing module under ``benchmarks/`` is wired in
(a benchmark that exists but never runs is a silent coverage hole).
"""

from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import ARTIFACT_PREFIX, RESULTS_DIR


def bench_registry(fast: bool = False) -> dict:
    """name -> (module, runner); each module's ARTIFACT names its payload."""
    from benchmarks import (
        algo_scaling,
        approx_ratio,
        bandwidth_sweep,
        churn_throughput,
        fig3_bottleneck,
        joint_opt,
        kernel_bench,
        kernel_path,
        latency_pareto,
        multi_tenant,
        observability,
        replica_scaling,
        throughput_scaling,
    )

    trials_fig3 = 4 if fast else 12
    trials = 6 if fast else 16
    return {
        "fig3": (fig3_bottleneck, lambda: fig3_bottleneck.run(trials=trials_fig3)),
        "throughput": (throughput_scaling,
                       lambda: throughput_scaling.run(
                           requests=32 if fast else 96)),
        "approx_ratio": (approx_ratio, lambda: approx_ratio.run(trials=max(trials, 8))),
        "joint_opt": (joint_opt, lambda: joint_opt.run(trials=trials)),
        "algo_scaling": (algo_scaling, algo_scaling.run),
        "kernels": (kernel_bench, kernel_bench.run),
        "kernel_path": (kernel_path, kernel_path.run),
        "churn": (churn_throughput,
                  lambda: churn_throughput.run(per_phase=8 if fast else 40)),
        "replicas": (replica_scaling,
                     lambda: replica_scaling.run(
                         requests=24 if fast else 60)),
        "bandwidth": (bandwidth_sweep,
                      lambda: bandwidth_sweep.run(
                          requests=24 if fast else 48)),
        "latency": (latency_pareto,
                    lambda: latency_pareto.run(
                        duration_s=1.0 if fast else 2.0)),
        "multi_tenant": (multi_tenant,
                         lambda: multi_tenant.run(
                             requests=24 if fast else 48)),
        "observability": (observability,
                          lambda: observability.run(
                              requests=64 if fast else 192,
                              reps=3 if fast else 6)),
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="fewer trials")
    ap.add_argument("--only", default=None, help="run a single benchmark")
    args = ap.parse_args()

    benches = bench_registry(fast=args.fast)
    failures = []
    for name, (module, fn) in benches.items():
        if args.only and name != args.only:
            continue
        print(f"\n### {name} ###", flush=True)
        t0 = time.time()
        try:
            fn()
            artifact = RESULTS_DIR / f"{ARTIFACT_PREFIX}{module.ARTIFACT}.json"
            # freshness, not mere existence: a stale file from an earlier
            # run must not mask a benchmark that stopped calling save()
            if not artifact.exists() or artifact.stat().st_mtime < t0:
                raise RuntimeError(f"{name} did not write {artifact}")
            print(f"[{name}] done in {time.time()-t0:.1f}s; artifact {artifact}",
                  flush=True)
        except Exception as e:  # pragma: no cover
            failures.append((name, repr(e)))
            print(f"[{name}] FAILED: {e!r}", flush=True)
    if failures:
        print("\nFAILURES:", failures)
        return 1
    print(f"\nall benchmarks complete; schema-validated artifacts under "
          f"{RESULTS_DIR}/ ({ARTIFACT_PREFIX}*.json)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
