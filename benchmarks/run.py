"""Benchmark driver: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--fast]
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="fewer trials")
    ap.add_argument("--only", default=None, help="run a single benchmark")
    args = ap.parse_args()

    from benchmarks import (
        algo_scaling,
        approx_ratio,
        churn_throughput,
        fig3_bottleneck,
        joint_opt,
        kernel_bench,
        throughput_scaling,
    )

    trials_fig3 = 4 if args.fast else 12
    trials = 6 if args.fast else 16
    benches = {
        "fig3": lambda: fig3_bottleneck.run(trials=trials_fig3),
        "throughput": lambda: throughput_scaling.run(trials=trials),
        "approx_ratio": lambda: approx_ratio.run(trials=max(trials, 8)),
        "joint_opt": lambda: joint_opt.run(trials=trials),
        "algo_scaling": algo_scaling.run,
        "kernels": kernel_bench.run,
        "churn": lambda: churn_throughput.run(per_phase=8 if args.fast else 40),
    }
    failures = []
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        print(f"\n### {name} ###", flush=True)
        t0 = time.time()
        try:
            fn()
            print(f"[{name}] done in {time.time()-t0:.1f}s", flush=True)
        except Exception as e:  # pragma: no cover
            failures.append((name, repr(e)))
            print(f"[{name}] FAILED: {e!r}", flush=True)
    if failures:
        print("\nFAILURES:", failures)
        return 1
    print("\nall benchmarks complete; results under results/bench_*.json")
    return 0


if __name__ == "__main__":
    sys.exit(main())
