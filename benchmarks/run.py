"""Benchmark driver: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--fast]

Besides each module's own stdout table, the driver persists every payload a
benchmark returns as ``results/BENCH_<module>.json`` (throughput windows,
bottleneck latencies, strategy names) so the perf trajectory is diffable
across PRs instead of living only in CI logs.
"""

from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

from benchmarks.common import save


def _save_bench_artifact(module_name: str, payload) -> Path | None:
    """Machine-readable per-PR artifact: results/BENCH_<module>.json."""
    if not isinstance(payload, dict):
        return None
    return save(module_name, payload, prefix="BENCH_")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="fewer trials")
    ap.add_argument("--only", default=None, help="run a single benchmark")
    args = ap.parse_args()

    from benchmarks import (
        algo_scaling,
        approx_ratio,
        churn_throughput,
        fig3_bottleneck,
        joint_opt,
        kernel_bench,
        throughput_scaling,
    )

    trials_fig3 = 4 if args.fast else 12
    trials = 6 if args.fast else 16
    benches = {
        # name -> (module basename for the BENCH_ artifact, runner)
        "fig3": ("fig3_bottleneck", lambda: fig3_bottleneck.run(trials=trials_fig3)),
        "throughput": ("throughput_scaling", lambda: throughput_scaling.run(trials=trials)),
        "approx_ratio": ("approx_ratio", lambda: approx_ratio.run(trials=max(trials, 8))),
        "joint_opt": ("joint_opt", lambda: joint_opt.run(trials=trials)),
        "algo_scaling": ("algo_scaling", algo_scaling.run),
        "kernels": ("kernel_bench", kernel_bench.run),
        "churn": ("churn_throughput",
                  lambda: churn_throughput.run(per_phase=8 if args.fast else 40)),
    }
    failures = []
    for name, (module_name, fn) in benches.items():
        if args.only and name != args.only:
            continue
        print(f"\n### {name} ###", flush=True)
        t0 = time.time()
        try:
            payload = fn()
            artifact = _save_bench_artifact(module_name, payload)
            suffix = f"; artifact {artifact}" if artifact else ""
            print(f"[{name}] done in {time.time()-t0:.1f}s{suffix}", flush=True)
        except Exception as e:  # pragma: no cover
            failures.append((name, repr(e)))
            print(f"[{name}] FAILED: {e!r}", flush=True)
    if failures:
        print("\nFAILURES:", failures)
        return 1
    print("\nall benchmarks complete; results under results/ "
          "(bench_*.json per module, BENCH_*.json per-PR artifacts)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
