"""Partitioning/placement wall-time scaling (production readiness: the
dispatcher re-runs these on every failure/redeploy, so they must be fast at
fleet-scale node counts)."""

from __future__ import annotations

import numpy as np

from repro.core.graph import chain
from repro.core.partitioner import partition_min_bottleneck
from repro.core.placement import place_color_coding
from repro.core.simulate import random_cluster

from benchmarks.common import save, table, timer

ARTIFACT = "algo_scaling"  # results/BENCH_algo_scaling.json


def run(seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    rows = []
    # partitioner: layers sweep
    for n_layers in (64, 256, 1024, 4096):
        sizes = [(int(rng.integers(1e5, 1e7)), int(rng.integers(1e4, 1e6)))
                 for _ in range(n_layers)]
        g = chain(f"synth{n_layers}", sizes)
        cap = g.total_param_bytes // 10
        with timer() as t:
            res = partition_min_bottleneck(g, cap)
        rows.append({"stage": "partition", "size": n_layers,
                     "time_ms": t.s * 1e3, "parts": res.n_parts,
                     "feasible": res.feasible})
    # placement: node sweep (color coding, beyond the exact-DP limit)
    g = chain("synth64", [(int(rng.integers(1e5, 1e7)), int(rng.integers(1e4, 1e6)))
                          for _ in range(64)])
    for n_nodes in (16, 32, 64, 128):
        comm = random_cluster(n_nodes, g.total_param_bytes // 6, seed=seed)
        part = partition_min_bottleneck(g, g.total_param_bytes // 6, max_parts=8)
        with timer() as t:
            res = place_color_coding(
                list(part.boundaries), [p.param_bytes for p in part.partitions],
                comm, n_classes=4, exact_limit=0, trials=40,
            )
        rows.append({"stage": "placement", "size": n_nodes,
                     "time_ms": t.s * 1e3, "parts": len(part.partitions),
                     "feasible": res.feasible})
    payload = {"rows": rows}
    save(ARTIFACT, payload)
    print(table(rows, ["stage", "size", "time_ms", "parts"],
                "Algorithm wall-time scaling"))
    return payload


if __name__ == "__main__":
    run()
