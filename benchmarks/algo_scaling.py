"""Partitioning/placement wall-time scaling (production readiness: the
dispatcher re-runs these on every failure/redeploy, so they must be fast at
fleet-scale node counts).

The placement sweep runs two placers side by side:

  * ``flat``          -- the full-graph color-coding binary search, capped at
                         ``flat_cap`` nodes where it already costs seconds;
  * ``hierarchical``  -- the >64-node default path (bandwidth-tiered groups,
                         coarse DP over group representatives, exact-DP
                         refinement inside the winners), swept to 1024 nodes.

The payload carries ``claims`` asserting the hierarchical path scales
near-linearly: time at the largest node count over time at the reference
count (128 in the default sweep, an 8x node growth) stays under
``SCALING_RATIO_MAX`` (~12x allows n log n slack).
"""

from __future__ import annotations

import numpy as np

from repro.core.graph import chain
from repro.core.partitioner import partition_min_bottleneck
from repro.core.placement import place_color_coding, place_hierarchical
from repro.core.simulate import random_cluster

from benchmarks.common import save, table, timer

ARTIFACT = "algo_scaling"  # results/BENCH_algo_scaling.json

FLAT_NODE_CAP = 128  # flat color coding is already ~2.5s here; don't sweep past
SCALING_RATIO_MAX = 12.0  # hierarchical: time(1024)/time(128) ceiling (8x nodes)
HIER_REF_NODES = 128  # near-linearity reference point


def run(
    seed: int = 0,
    partition_layers: tuple = (64, 256, 1024, 4096),
    placement_nodes: tuple = (16, 32, 64, 128, 256, 512, 1024),
    flat_cap: int = FLAT_NODE_CAP,
) -> dict:
    rng = np.random.default_rng(seed)
    rows = []
    # partitioner: layers sweep
    for n_layers in partition_layers:
        sizes = [(int(rng.integers(1e5, 1e7)), int(rng.integers(1e4, 1e6)))
                 for _ in range(n_layers)]
        g = chain(f"synth{n_layers}", sizes)
        cap = g.total_param_bytes // 10
        with timer() as t:
            res = partition_min_bottleneck(g, cap)
        rows.append({"stage": "partition", "algo": "min_bottleneck",
                     "size": n_layers, "time_ms": t.s * 1e3,
                     "parts": res.n_parts, "feasible": res.feasible})
    # placement: node sweep, flat color coding vs hierarchical large-n path
    g = chain("synth64", [(int(rng.integers(1e5, 1e7)), int(rng.integers(1e4, 1e6)))
                          for _ in range(64)])
    part = partition_min_bottleneck(g, g.total_param_bytes // 6, max_parts=8)
    boundaries = list(part.boundaries)
    part_bytes = [p.param_bytes for p in part.partitions]
    hier_ms: dict[int, float] = {}
    for n_nodes in placement_nodes:
        comm = random_cluster(n_nodes, g.total_param_bytes // 6, seed=seed)
        if n_nodes <= flat_cap:
            with timer() as t:
                res = place_color_coding(
                    boundaries, part_bytes, comm,
                    n_classes=4, exact_limit=0, trials=40,
                    hierarchical_limit=None,
                )
            rows.append({"stage": "placement", "algo": "flat",
                         "size": n_nodes, "time_ms": t.s * 1e3,
                         "parts": len(part_bytes), "feasible": res.feasible})
        if n_nodes >= 64:
            # warm numpy/lru caches out-of-band so the row measures the
            # algorithm, not first-call table construction
            place_hierarchical(boundaries, part_bytes, comm, seed=seed)
            with timer() as t:
                res = place_hierarchical(
                    boundaries, part_bytes, comm, n_classes=4, seed=seed,
                )
            hier_ms[n_nodes] = t.s * 1e3
            rows.append({"stage": "placement", "algo": "hierarchical",
                         "size": n_nodes, "time_ms": t.s * 1e3,
                         "parts": len(part_bytes), "feasible": res.feasible})
            assert res.feasible, f"hierarchical infeasible at n={n_nodes}"
    n_hi = max(hier_ms)
    n_ref = HIER_REF_NODES if HIER_REF_NODES in hier_ms else min(hier_ms)
    claims = {
        "hier_nodes_hi": n_hi,
        "hier_nodes_ref": n_ref,
        "hier_time_hi_ms": hier_ms[n_hi],
        "hier_time_ref_ms": hier_ms[n_ref],
        "hier_ratio": hier_ms[n_hi] / max(hier_ms[n_ref], 1e-9),
        "scaling_ratio_max": SCALING_RATIO_MAX,
    }
    payload = {"rows": rows, "claims": claims}
    save(ARTIFACT, payload)
    print(table(rows, ["stage", "algo", "size", "time_ms", "parts"],
                "Algorithm wall-time scaling"))
    print(f"claims: {claims}")
    assert claims["hier_ratio"] <= SCALING_RATIO_MAX, (
        f"hierarchical placement is not near-linear: "
        f"time({n_hi})/time({n_ref}) = {claims['hier_ratio']:.1f}x"
    )
    return payload


if __name__ == "__main__":
    run()
