"""Shared benchmark plumbing: result IO + table printing."""

from __future__ import annotations

import json
import os
import time
from pathlib import Path

RESULTS_DIR = Path(os.environ.get("BENCH_RESULTS", "results"))


def save(name: str, payload, prefix: str = "bench_") -> Path:
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    path = RESULTS_DIR / f"{prefix}{name}.json"
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    return path


def table(rows: list[dict], cols: list[str], title: str) -> str:
    out = [f"== {title} =="]
    widths = {c: max(len(c), *(len(_fmt(r.get(c))) for r in rows)) for c in cols}
    out.append("  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        out.append("  ".join(_fmt(r.get(c)).ljust(widths[c]) for c in cols))
    return "\n".join(out)


def _fmt(v) -> str:
    if isinstance(v, float):
        if v == 0 or (1e-3 < abs(v) < 1e4):
            return f"{v:.4g}"
        return f"{v:.3e}"
    return str(v)


class timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0
