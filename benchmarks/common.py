"""Shared benchmark plumbing: result IO, schema validation, table printing.

Every benchmark payload is persisted as ``results/BENCH_<name>.json`` -- one
canonical casing (the legacy lowercase ``bench_*.json`` twins are gone), and
every payload is schema-validated before it is written, so a benchmark that
emits NaN/Infinity or ragged rows fails loudly instead of producing an
artifact that silently breaks cross-PR diffing.
"""

from __future__ import annotations

import json
import math
import os
import time
from pathlib import Path

RESULTS_DIR = Path(os.environ.get("BENCH_RESULTS", "results"))

ARTIFACT_PREFIX = "BENCH_"  # the single canonical artifact casing

_SCALARS = (str, int, float, bool, type(None))


class PayloadSchemaError(ValueError):
    """A benchmark payload violates the artifact schema."""


def validate_payload(name: str, payload) -> None:
    """Check a payload against the BENCH_ artifact schema; raise on violation.

    Schema (shared by every benchmark):

      * the payload is a JSON object with string keys;
      * every leaf is a JSON scalar -- finite numbers only (NaN/Infinity are
        not JSON and break downstream tooling);
      * ``rows``, when present, is a non-empty list of flat objects that all
        share the same key set (a proper table).
    """
    if not isinstance(payload, dict):
        raise PayloadSchemaError(f"{name}: payload must be a dict, got {type(payload).__name__}")

    def walk(value, where):
        if isinstance(value, dict):
            for k, v in value.items():
                if not isinstance(k, str):
                    raise PayloadSchemaError(f"{name}: non-string key {k!r} at {where}")
                walk(v, f"{where}.{k}")
        elif isinstance(value, (list, tuple)):
            for i, v in enumerate(value):
                walk(v, f"{where}[{i}]")
        elif isinstance(value, float):
            if not math.isfinite(value):
                raise PayloadSchemaError(f"{name}: non-finite number at {where}")
        elif not isinstance(value, _SCALARS):
            raise PayloadSchemaError(
                f"{name}: non-JSON leaf {type(value).__name__} at {where}"
            )

    walk(payload, "$")
    rows = payload.get("rows")
    if rows is not None:
        if not isinstance(rows, (list, tuple)) or not rows:
            raise PayloadSchemaError(f"{name}: 'rows' must be a non-empty list")
        keys = None
        for i, row in enumerate(rows):
            if not isinstance(row, dict):
                raise PayloadSchemaError(f"{name}: rows[{i}] is not an object")
            if keys is None:
                keys = set(row)
            elif set(row) != keys:
                raise PayloadSchemaError(
                    f"{name}: rows[{i}] keys {sorted(set(row))} != rows[0] "
                    f"keys {sorted(keys)} (ragged table)"
                )
            for k, v in row.items():
                if not isinstance(v, _SCALARS):
                    raise PayloadSchemaError(
                        f"{name}: rows[{i}].{k} is not a scalar"
                    )


def _pythonize(value):
    """numpy scalars/arrays -> plain Python, so artifacts are pure JSON."""
    if isinstance(value, dict):
        return {k: _pythonize(v) for k, v in value.items()}
    if isinstance(value, (list, tuple)):
        return [_pythonize(v) for v in value]
    if hasattr(value, "item") and not isinstance(value, _SCALARS):
        try:
            return value.item()  # 0-d numpy scalar
        except (ValueError, TypeError):
            return [_pythonize(v) for v in value.tolist()]
    return value


def save(name: str, payload) -> Path:
    """Validate + persist a payload as ``results/BENCH_<name>.json``.

    The prefix is deliberately not a parameter: one canonical casing, no
    way to resurrect the legacy lowercase twins."""
    payload = _pythonize(payload)
    validate_payload(name, payload)
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    path = RESULTS_DIR / f"{ARTIFACT_PREFIX}{name}.json"
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, allow_nan=False)
    return path


def table(rows: list[dict], cols: list[str], title: str) -> str:
    out = [f"== {title} =="]
    widths = {c: max(len(c), *(len(_fmt(r.get(c))) for r in rows)) for c in cols}
    out.append("  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        out.append("  ".join(_fmt(r.get(c)).ljust(widths[c]) for c in cols))
    return "\n".join(out)


def _fmt(v) -> str:
    if isinstance(v, float):
        if v == 0 or (1e-3 < abs(v) < 1e4):
            return f"{v:.4g}"
        return f"{v:.3e}"
    return str(v)


class timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0
