"""The paper's headline claim: "improve the inference pipeline throughput by
200% by utilizing sufficient numbers of resource-constrained nodes."

Throughput (1/bottleneck) vs number of nodes, at fixed (small) node
capacity, relative to the minimum-viable cluster.  Also reports the random-
and greedy-placement baselines to isolate the algorithm's contribution.
"""

from __future__ import annotations

import numpy as np

from repro.core.model_zoo import PAPER_MODELS
from repro.core.simulate import run_trial
from repro.core.placement import place_greedy, place_random

from benchmarks.common import save, table


def run(trials: int = 16, capacity_frac: float = 0.25, seed: int = 0) -> dict:
    node_counts = [3, 4, 6, 8, 10, 12]
    rows = []
    for model, fn in PAPER_MODELS.items():
        graph = fn()
        biggest = max(l.param_bytes for l in graph.layers)
        capacity = max(capacity_frac * graph.total_param_bytes, 1.05 * biggest)
        base_tp = None
        for n in node_counts:
            tps, tps_greedy, tps_rand = [], [], []
            for t in range(trials):
                r = run_trial(graph, capacity, n, 8, seed + 31 * t)
                if r.feasible:
                    tps.append(r.throughput)
                rg = run_trial(graph, capacity, n, 4, seed + 31 * t, placer=place_greedy)
                if rg.feasible:
                    tps_greedy.append(rg.throughput)
                rr = run_trial(graph, capacity, n, 4, seed + 31 * t, placer=place_random)
                if rr.feasible:
                    tps_rand.append(rr.throughput)
            if not tps:
                continue
            tp = float(np.mean(tps))
            if base_tp is None:
                base_tp = tp
            rows.append({
                "model": model, "nodes": n,
                "throughput": tp,
                "gain_pct": 100.0 * (tp / base_tp - 1.0),
                "vs_greedy_x": tp / float(np.mean(tps_greedy)) if tps_greedy else float("nan"),
                "vs_random_x": tp / float(np.mean(tps_rand)) if tps_rand else float("nan"),
            })
    claims = {}
    for model in PAPER_MODELS:
        gains = [r["gain_pct"] for r in rows if r["model"] == model]
        if gains:
            claims[model] = {"max_gain_pct": max(gains)}
    payload = {"rows": rows, "claims": claims, "capacity_frac": capacity_frac, "trials": trials}
    save("throughput_scaling", payload)
    print(table(rows, ["model", "nodes", "throughput", "gain_pct", "vs_greedy_x", "vs_random_x"],
                "Throughput vs cluster size (paper: up to +200%)"))
    return payload


if __name__ == "__main__":
    run()
