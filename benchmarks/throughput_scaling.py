"""The paper's headline claim: "improve the inference pipeline throughput by
200% by utilizing sufficient numbers of resource-constrained nodes."

Throughput (1/bottleneck) vs number of nodes, at fixed (small) node
capacity, relative to the minimum-viable cluster.  Also reports the random-
and greedy-placement baselines to isolate the algorithm's contribution.
Every placer runs through the same ``Planner`` the deployment facade uses,
resolved by registry name, so the comparison covers exactly the strategies
a ``DeploymentSpec`` can name.
"""

from __future__ import annotations

import numpy as np

from repro.api import Planner
from repro.core.model_zoo import PAPER_MODELS
from repro.core.simulate import random_cluster

from benchmarks.common import save, table

PLACERS = ("color_coding", "greedy", "random")


def _trial_throughput(planner, graph, capacity, n, seed):
    comm = random_cluster(n, capacity, seed=seed)
    plan = planner.plan(
        graph, comm, capacity=capacity, max_parts=n, seed=seed, dispatcher=0,
    )
    return plan.placement.throughput if plan.feasible else None


def run(trials: int = 16, capacity_frac: float = 0.25, seed: int = 0) -> dict:
    node_counts = [3, 4, 6, 8, 10, 12]
    planners = {
        "color_coding": Planner(placer="color_coding", n_classes=8),
        "greedy": Planner(placer="greedy", n_classes=4),
        "random": Planner(placer="random", n_classes=4),
    }
    rows = []
    for model, fn in PAPER_MODELS.items():
        graph = fn()
        biggest = max(l.param_bytes for l in graph.layers)
        capacity = max(capacity_frac * graph.total_param_bytes, 1.05 * biggest)
        base_tp = None
        for n in node_counts:
            tps = {name: [] for name in PLACERS}
            for t in range(trials):
                for name in PLACERS:
                    tp = _trial_throughput(
                        planners[name], graph, capacity, n, seed + 31 * t
                    )
                    if tp is not None:
                        tps[name].append(tp)
            if not tps["color_coding"]:
                continue
            tp = float(np.mean(tps["color_coding"]))
            if base_tp is None:
                base_tp = tp
            rows.append({
                "model": model, "nodes": n,
                "throughput": tp,
                "gain_pct": 100.0 * (tp / base_tp - 1.0),
                "vs_greedy_x": tp / float(np.mean(tps["greedy"]))
                if tps["greedy"] else float("nan"),
                "vs_random_x": tp / float(np.mean(tps["random"]))
                if tps["random"] else float("nan"),
            })
    claims = {}
    for model in PAPER_MODELS:
        gains = [r["gain_pct"] for r in rows if r["model"] == model]
        if gains:
            claims[model] = {"max_gain_pct": max(gains)}
    payload = {
        "rows": rows,
        "claims": claims,
        "strategies": {"partitioner": "min_bottleneck", "placers": list(PLACERS)},
        "capacity_frac": capacity_frac,
        "trials": trials,
    }
    save("throughput_scaling", payload)
    print(table(rows, ["model", "nodes", "throughput", "gain_pct", "vs_greedy_x", "vs_random_x"],
                "Throughput vs cluster size (paper: up to +200%)"))
    return payload


if __name__ == "__main__":
    run()
