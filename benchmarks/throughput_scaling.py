"""The paper's headline claim, measured EMPIRICALLY: "improve the inference
pipeline throughput by 200% by utilizing sufficient numbers of
resource-constrained nodes."

Earlier revisions of this benchmark reported the *analytic* placement
throughput (1/bottleneck).  This one actually serves a request stream twice
per cluster size through the ``deploy(spec)`` facade:

  * ``serving="sync"``      -- the synchronous baseline: one microbatch
    traverses the whole chain per admission round, so throughput decays with
    pipeline depth (1 / end-to-end time);
  * ``serving="pipelined"`` -- the discrete-event engine: every partition
    works on a different microbatch, so throughput holds at the bottleneck
    stage's rate (the paper's Fig. 5 shape: ~flat in depth).

Reported per cluster size: partition count, the Planner's predicted
bottleneck throughput, both measured steady-state rates, and the speedup.
The run asserts the paper's claim: at >= 8 partitions the pipelined engine
delivers >= 2x the synchronous baseline.

  PYTHONPATH=src python -m benchmarks.throughput_scaling [--requests N]
"""

from __future__ import annotations

import argparse

import jax.numpy as jnp

from repro.api import ClusterSpec, DeploymentSpec, deploy
from repro.core.graph import Layer, LayerGraph

from benchmarks.common import save, table

ARTIFACT = "throughput_scaling"  # results/BENCH_throughput_scaling.json

N_LAYERS = 24
PARAM_BYTES = 1_000_000  # per layer (int8-quantized weights)
ACT_BYTES = 1_000_000  # per boundary activation
FLOPS = 2_000_000  # per layer
NODE_COUNTS = (3, 4, 6, 8, 10, 12)


def _graph() -> LayerGraph:
    layers = tuple(
        Layer(f"l{i}", param_bytes=PARAM_BYTES, out_bytes=ACT_BYTES, flops=FLOPS)
        for i in range(N_LAYERS)
    )
    return LayerGraph("synth24", layers, in_bytes=ACT_BYTES // 4)


def _measure(spec: DeploymentSpec, requests: int) -> tuple[float, dict]:
    dep = deploy(spec)
    for _ in range(requests):
        dep.submit(jnp.ones((4,)))
    dep.drain()
    assert len(dep.loop.failed) == 0
    assert len(dep.loop.completed) == requests
    if hasattr(dep.loop, "steady_state_throughput"):
        rate = dep.loop.steady_state_throughput()
    else:  # sync loop: constant per-round cost, the mean IS the steady state
        rate = dep.loop.metrics()["throughput"]
    return float(rate), dep.plan.summary()


def run(requests: int = 96, seed: int = 0) -> dict:
    graph = _graph()
    rows = []
    for n in NODE_COUNTS:
        # smallest per-node capacity that still packs the chain into <= n
        # contiguous parts (ceil division), so partition count tracks n
        layers_per_part = -(-N_LAYERS // n)
        capacity = layers_per_part * PARAM_BYTES * 1.05
        base = dict(
            model=graph,
            cluster=ClusterSpec(n_nodes=n, capacity_bytes=capacity, seed=seed + 3),
            capacity=capacity,
            seed=seed,
            microbatch=1,
        )
        pipe_rate, plan = _measure(
            DeploymentSpec(serving="pipelined", **base), requests)
        sync_rate, _ = _measure(DeploymentSpec(serving="sync", **base), requests)
        predicted = float(plan["predicted_throughput"])
        rows.append({
            "nodes": n,
            "parts": len(plan["path"]),
            "predicted": predicted,
            "pipelined": pipe_rate,
            "sync": sync_rate,
            "speedup_x": pipe_rate / sync_rate if sync_rate > 0 else 0.0,
            "vs_predicted": pipe_rate / predicted if predicted > 0 else 0.0,
        })
    deep = [r for r in rows if r["parts"] >= 8]
    base_tp = rows[0]["pipelined"]
    claims = {
        # the paper's 200% improvement: pipelined vs synchronous execution
        "max_speedup_x": max(r["speedup_x"] for r in rows),
        "speedup_at_8plus_parts_x": min(r["speedup_x"] for r in deep) if deep else 0.0,
        # Fig. 5 shape: pipelined throughput tracks the bottleneck rate, it
        # does not decay with partition count the way the sync baseline does
        "pipelined_depth_ratio": min(r["pipelined"] for r in rows) / base_tp,
        "sync_depth_ratio": min(r["sync"] for r in rows) / rows[0]["sync"],
    }
    payload = {
        "rows": rows,
        "claims": claims,
        "model": graph.name,
        "requests": requests,
        "serving": {"engine": "pipelined discrete-event", "baseline": "sync"},
    }
    save(ARTIFACT, payload)
    print(table(rows, ["nodes", "parts", "predicted", "pipelined", "sync",
                       "speedup_x", "vs_predicted"],
                "Measured serving throughput vs cluster size (paper: +200%)"))
    print(f"claims: {claims}")
    assert deep, "no configuration reached 8 partitions"
    assert claims["speedup_at_8plus_parts_x"] >= 2.0, (
        f"pipelined engine must be >= 2x the synchronous baseline at >= 8 "
        f"partitions, got {claims['speedup_at_8plus_parts_x']:.2f}x"
    )
    return payload


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=96)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(requests=args.requests, seed=args.seed)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
