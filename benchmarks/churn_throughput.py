"""Serving throughput under churn, driven through the ``deploy(spec)`` facade.

The scenario DEFER and the joint partition/placement literature use as the
benchmark: a continuous request stream over a re-plannable pipeline, with
disturbances injected **mid-stream**:

  phase 1  steady-state serving (baseline)
  phase 2  a node hosting a partition is killed mid-phase (``NodeFailed``)
  phase 3  steady-state after recovery
  phase 4  a new model version is published mid-phase (``VersionBumped``
           via the watch container's ``poll_events``)
  phase 5  steady-state on the new version

Reported per phase: completed requests, simulated window seconds, and
throughput (req/s).  Recovery is demonstrated by phase-3 and phase-5
throughput returning to within a small factor of phase 1.  All convergence
goes through ``Deployment.inject`` + the serving loop's reconcile -- no
manual ``Dispatcher.recover()``-style calls.  Serving runs through the
pipelined discrete-event engine by default (``--serving sync`` falls back
to the synchronous baseline), so recovery cost includes requeueing exactly
the microbatches resident on the affected stages.  The partition/placement
strategies are registry names, so the same scenario measures any pair:

  PYTHONPATH=src python -m benchmarks.churn_throughput [--smoke]
      [--partitioner NAME] [--placer NAME] [--serving pipelined|sync]
"""

from __future__ import annotations

import argparse

import jax.numpy as jnp

from repro.api import ClusterSpec, DeploymentSpec, deploy
from repro.cluster import NodeFailed
from repro.core.model_zoo import demo_mlp

from benchmarks.common import save, table

ARTIFACT = "churn_throughput"  # results/BENCH_churn_throughput.json

D = 32


def _serve_phase(dep, name, n_requests, inject=None):
    """Admit n requests, step to completion; fire ``inject`` mid-phase."""
    loop = dep.loop
    clock0, done0 = loop.clock_s, len(loop.completed)
    for _ in range(n_requests):
        dep.submit(jnp.ones((D,)) * 0.1)
    fired = inject is None
    while loop.backlog or dep.control.pending:
        if not fired and len(loop.completed) - done0 >= n_requests // 2:
            inject()
            fired = True
        dep.step()
    window_s = loop.clock_s - clock0
    done = len(loop.completed) - done0
    return {
        "phase": name,
        "requests": done,
        "window_s": window_s,
        "throughput": done / window_s if window_s > 0 else 0.0,
    }


def run(
    per_phase: int = 40,
    microbatch: int = 4,
    n_nodes: int = 8,
    seed: int = 0,
    partitioner: str | None = None,
    placer: str | None = None,
    serving: str = "pipelined",
) -> dict:
    graph, executor_for_version = demo_mlp(d=D)
    spec = DeploymentSpec(
        model=graph,
        executor_for_version=executor_for_version,
        cluster=ClusterSpec(
            n_nodes=n_nodes, capacity_bytes=graph.total_param_bytes / 3,
            seed=seed + 3,
        ),
        partitioner=partitioner,
        placer=placer,
        seed=seed,
        microbatch=microbatch,
        serving=serving,
    )
    dep = deploy(spec)
    strategies = dict(dep.plan.strategies)

    def kill_node():
        pods = dep.control.pipeline.pods
        victim = pods[1 if len(pods) > 1 else 0].node_id
        print(f"  [mid-stream] NodeFailed({victim})")
        dep.inject(NodeFailed(victim))

    def bump_version():
        print("  [mid-stream] store publishes v1 -> VersionBumped")
        dep.store.publish(1)
        dep.poll_model_updates()

    rows = [
        _serve_phase(dep, "steady-v0", per_phase),
        _serve_phase(dep, "node-kill", per_phase, inject=kill_node),
        _serve_phase(dep, "recovered", per_phase),
        _serve_phase(dep, "version-bump", per_phase, inject=bump_version),
        _serve_phase(dep, "steady-v1", per_phase),
    ]
    base = rows[0]["throughput"]
    for r in rows:
        r["vs_baseline"] = r["throughput"] / base

    m = dep.metrics()
    actions = [(a.kind, a.detail) for a in dep.control.history]
    payload = {
        "rows": rows,
        "strategies": strategies,
        "serving_mode": m["serving"].get("mode", "sync"),
        "stages": m["serving"].get("stages", []),
        "requeued_microbatches": m["serving"].get("requeued_microbatches", 0),
        "actions": actions,
        "bottleneck_latencies": {
            "predicted_s": m["predicted_bottleneck_s"],
            "observed_s": m["bottleneck_latency_s"],
        },
        "final_state": {
            "version": m["version"],
            "generation": m["generation"],
            "path": m["path"],
            "healthy": m["healthy"],
        },
        "lost_requests": m["serving"]["failed"],
        "per_phase": per_phase,
        "microbatch": microbatch,
    }
    save(ARTIFACT, payload)
    print(table(rows, ["phase", "requests", "window_s", "throughput", "vs_baseline"],
                f"Serving throughput under churn ({strategies})"))
    print(f"reconcile actions: {[k for k, _ in actions]}")
    print(f"final: v{m['version']}, generation {m['generation']}, "
          f"path {m['path']}, lost requests: {m['serving']['failed']}")
    assert m["serving"]["failed"] == 0, "requests were lost across recovery"
    assert rows[2]["throughput"] > 0.5 * base, "throughput did not recover after node kill"
    assert rows[4]["throughput"] > 0.5 * base, "throughput did not recover after version bump"
    return payload


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny run for CI")
    ap.add_argument("--per-phase", type=int, default=None)
    ap.add_argument("--microbatch", type=int, default=4)
    ap.add_argument("--partitioner", default=None)
    ap.add_argument("--placer", default=None)
    ap.add_argument("--serving", default="pipelined", choices=("pipelined", "sync"))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    per_phase = args.per_phase if args.per_phase is not None else (8 if args.smoke else 40)
    run(per_phase=per_phase, microbatch=args.microbatch, seed=args.seed,
        partitioner=args.partitioner, placer=args.placer, serving=args.serving)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
