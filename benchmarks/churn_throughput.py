"""Serving throughput under churn, driven through the ControlPlane event API.

The scenario DEFER and the joint partition/placement literature use as the
benchmark: a continuous request stream over a re-plannable pipeline, with
disturbances injected **mid-stream**:

  phase 1  steady-state serving (baseline)
  phase 2  a node hosting a partition is killed mid-phase (``NodeFailed``)
  phase 3  steady-state after recovery
  phase 4  a new model version is published mid-phase (``VersionBumped``
           via the watch container's ``poll_events``)
  phase 5  steady-state on the new version

Reported per phase: completed requests, simulated window seconds, and
throughput (req/s).  Recovery is demonstrated by phase-3 and phase-5
throughput returning to within a small factor of phase 1.  All convergence
goes through ``ControlPlane.submit`` + ``reconcile`` -- no manual
``Dispatcher.recover()``-style calls.

  PYTHONPATH=src python -m benchmarks.churn_throughput [--smoke]
"""

from __future__ import annotations

import argparse
import tempfile

import jax.numpy as jnp

from repro.cluster import (
    ArtifactStore,
    ControlPlane,
    EdgeCluster,
    ModelWatcher,
    NodeFailed,
    ServingLoop,
)
from repro.core.model_zoo import demo_mlp
from repro.core.simulate import random_cluster

from benchmarks.common import save, table

D = 32


def _serve_phase(loop, name, n_requests, inject=None):
    """Admit n requests, step to completion; fire ``inject`` mid-phase."""
    clock0, done0 = loop.clock_s, len(loop.completed)
    for _ in range(n_requests):
        loop.submit(jnp.ones((D,)) * 0.1)
    fired = inject is None
    while loop.backlog or loop.control.pending:
        if not fired and len(loop.completed) - done0 >= n_requests // 2:
            inject()
            fired = True
        loop.step()
    window_s = loop.clock_s - clock0
    done = len(loop.completed) - done0
    return {
        "phase": name,
        "requests": done,
        "window_s": window_s,
        "throughput": done / window_s if window_s > 0 else float("inf"),
    }


def run(per_phase: int = 40, microbatch: int = 4, n_nodes: int = 8, seed: int = 0) -> dict:
    graph, executor_for_version = demo_mlp(d=D)
    capacity = graph.total_param_bytes / 3
    cluster = EdgeCluster(
        random_cluster(n_nodes, capacity, seed=seed + 3), flops_per_s=1e9
    )
    store = ArtifactStore(tempfile.mkdtemp(prefix="seifer-churn-"))
    control = ControlPlane(
        cluster, store, lambda v: graph, executor_for_version,
        capacity=capacity, seed=seed,
    )
    control.bootstrap(0)
    watcher = ModelWatcher(store)
    loop = ServingLoop(control, microbatch=microbatch)

    def kill_node():
        victim = control.pipeline.pods[1].node_id
        print(f"  [mid-stream] NodeFailed({victim})")
        control.submit(NodeFailed(victim))

    def bump_version():
        print("  [mid-stream] store publishes v1 -> VersionBumped")
        store.publish(1)
        watcher.poll_events(control)

    rows = [
        _serve_phase(loop, "steady-v0", per_phase),
        _serve_phase(loop, "node-kill", per_phase, inject=kill_node),
        _serve_phase(loop, "recovered", per_phase),
        _serve_phase(loop, "version-bump", per_phase, inject=bump_version),
        _serve_phase(loop, "steady-v1", per_phase),
    ]
    base = rows[0]["throughput"]
    for r in rows:
        r["vs_baseline"] = r["throughput"] / base

    obs = control.observed()
    actions = [(a.kind, a.detail) for a in control.history]
    payload = {
        "rows": rows,
        "actions": actions,
        "final_state": {
            "version": obs.version,
            "generation": obs.generation,
            "path": list(obs.path),
            "healthy": obs.healthy,
        },
        "lost_requests": len(loop.failed),
        "per_phase": per_phase,
        "microbatch": microbatch,
    }
    save("churn_throughput", payload)
    print(table(rows, ["phase", "requests", "window_s", "throughput", "vs_baseline"],
                "Serving throughput under churn (ControlPlane events only)"))
    print(f"reconcile actions: {[k for k, _ in actions]}")
    print(f"final: v{obs.version}, generation {obs.generation}, "
          f"path {list(obs.path)}, lost requests: {len(loop.failed)}")
    assert len(loop.failed) == 0, "requests were lost across recovery"
    assert rows[2]["throughput"] > 0.5 * base, "throughput did not recover after node kill"
    assert rows[4]["throughput"] > 0.5 * base, "throughput did not recover after version bump"
    return payload


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny run for CI")
    ap.add_argument("--per-phase", type=int, default=None)
    ap.add_argument("--microbatch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    per_phase = args.per_phase if args.per_phase is not None else (8 if args.smoke else 40)
    run(per_phase=per_phase, microbatch=args.microbatch, seed=args.seed)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
