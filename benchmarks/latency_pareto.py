"""Latency-throughput pareto under open-loop load + autoscaling payoff.

Closed-loop benchmarks (``replica_scaling``, ``churn_throughput``) can never
overload the engine: offered load equals served load by construction.  This
benchmark drives the same synthetic 16-node cluster with *open-loop* seeded
traces (``repro.workload``) and measures the two claims that matter past
saturation:

  * **bounded tail** -- sweeping a Poisson trace from 0.4x to 2.0x the
    pipeline's capacity, p99 latency must stay bounded by the admission
    queue (load shedding rejects the overflow) instead of growing with the
    trace duration, and rejections must appear exactly in the overloaded
    legs;
  * **autoscaling pays** -- on a bursty (MMPP flash-crowd) trace that
    saturates a single pipeline, backlog-driven autoscaling over the
    planner's widest feasible split must complete >= 1.5x the requests of a
    fixed single replica at the same admission bound.

  PYTHONPATH=src python -m benchmarks.latency_pareto [--duration S]
"""

from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

from repro.api import (
    ArrivalSpec,
    AutoscaleSpec,
    ClusterSpec,
    DeploymentSpec,
    deploy,
)
from repro.core.graph import Layer, LayerGraph
from repro.core.placement import CommGraph

from benchmarks.common import save, table

ARTIFACT = "latency_pareto"  # results/BENCH_latency_pareto.json

N_HOSTING = 16  # symmetric hosting nodes (+ node 0, the dispatcher)
N_LAYERS = 16
PARAM_BYTES = 1_000_000  # per layer
ACT_BYTES = 200_000  # per boundary activation
FLOPS = 20_000_000  # per layer: compute-bound stages, links cheap
LINK_BYTES_S = 20e6  # uniform link bandwidth
CAPACITY = 4.2e6  # 4 layers per node -> 4-stage pipelines
MAX_BATCH = 8
ADMISSION_DEPTH = 32
LOAD_MULTS = (0.4, 0.7, 1.0, 1.5, 2.5)


def _graph() -> LayerGraph:
    layers = tuple(
        Layer(f"l{i}", param_bytes=PARAM_BYTES, out_bytes=ACT_BYTES, flops=FLOPS)
        for i in range(N_LAYERS)
    )
    return LayerGraph("synth16", layers, in_bytes=ACT_BYTES // 2)


def _comm(n_hosting: int = N_HOSTING) -> CommGraph:
    bw = np.full((n_hosting + 1, n_hosting + 1), LINK_BYTES_S)
    np.fill_diagonal(bw, 0.0)
    cap = np.full(n_hosting + 1, CAPACITY)
    cap[0] = -1.0  # dispatcher hosts no partition
    return CommGraph(bw=bw, node_capacity=cap)


def _spec(seed: int, *, arrival=None, autoscale=None) -> DeploymentSpec:
    return DeploymentSpec(
        model=_graph(),
        cluster=ClusterSpec(comm=_comm()),
        capacity=CAPACITY,
        seed=seed,
        microbatch=1,
        max_batch=MAX_BATCH,
        admission_depth=ADMISSION_DEPTH,
        arrival=arrival,
        autoscale=autoscale,
    )


def _drive(dep) -> None:
    """Serve an already-scheduled trace to completion."""
    while (dep.loop.backlog or dep.loop.pending_arrivals or dep.pending):
        if (not dep.step() and not dep.pending
                and not dep.loop.pending_arrivals and not dep.loop.backlog):
            break


def _open_loop(spec, trace_name: str, rate: float, duration_s: float,
               seed: int) -> dict:
    """Deploy, schedule the trace, drain, return the serving metrics."""
    dep = deploy(spec)
    n = len(dep.submit_trace(make_input=lambda i, a: jnp.ones((4,))))
    _drive(dep)
    m = dep.metrics()["serving"]
    assert m["completed"] + m["failed"] + m["rejected"] == n, (
        "request conservation violated",
        m["completed"], m["failed"], m["rejected"], n)
    m["offered"] = n
    return m


def measure_capacity(seed: int = 0, requests: int = 80) -> float:
    """Closed-loop saturation throughput (req/s) of the single pipeline with
    continuous batching: the load sweep's x-axis unit."""
    dep = deploy(_spec(seed))
    for _ in range(requests):
        dep.submit(jnp.ones((4,)))
    dep.drain()
    assert len(dep.loop.completed) == requests
    return requests / dep.loop.clock_s


def sweep_load(capacity: float, duration_s: float, seed: int) -> list[dict]:
    rows = []
    for mult in LOAD_MULTS:
        rate = mult * capacity
        arrival = ArrivalSpec(trace="poisson", rate=rate,
                              duration_s=duration_s, seed=seed)
        m = _open_loop(_spec(seed, arrival=arrival), "poisson", rate,
                       duration_s, seed)
        lat = m["latency"]["overall"]
        rows.append({
            "load_x": mult,
            "offered_rate": m["offered"] / duration_s,
            "completed_rate": m["completed"] / m["clock_s"],
            "rejected": m["rejected"],
            "reject_frac": m["rejected"] / m["offered"] if m["offered"] else 0.0,
            "p50_ms": lat["p50_s"] * 1e3,
            "p95_ms": lat["p95_s"] * 1e3,
            "p99_ms": lat["p99_s"] * 1e3,
            "mean_batch": m["batching"]["mean_batch"],
        })
    return rows


def autoscale_payoff(capacity: float, duration_s: float, seed: int) -> dict:
    """Bursty trace at 3x single-pipeline capacity: fixed replica sheds the
    bursts, the autoscaler absorbs them with standby groups."""
    rate = 3.5 * capacity
    arrival = ArrivalSpec(trace="bursty", rate=rate,
                          duration_s=1.5 * duration_s, seed=seed)
    fixed = _open_loop(_spec(seed, arrival=arrival), "bursty", rate,
                       duration_s, seed)
    auto_spec = _spec(seed, arrival=arrival, autoscale=AutoscaleSpec(
        min_replicas=1, backlog_high=4.0, backlog_low=0.5, cooldown_s=0.01))
    auto = _open_loop(auto_spec, "bursty", rate, duration_s, seed)
    fixed_rate = fixed["completed"] / fixed["clock_s"]
    auto_rate = auto["completed"] / auto["clock_s"]
    gain = auto_rate / fixed_rate if fixed_rate else float("inf")
    return {
        "trace": "bursty",
        "rate": rate,
        "offered": fixed["offered"],
        "fixed_completed": fixed["completed"],
        "fixed_rejected": fixed["rejected"],
        "fixed_rate": fixed_rate,
        "auto_completed": auto["completed"],
        "auto_rejected": auto["rejected"],
        "auto_rate": auto_rate,
        "auto_grows": auto["autoscaler"]["grows"],
        "auto_shrinks": auto["autoscaler"]["shrinks"],
        "completed_gain": gain,
    }


def run(duration_s: float = 2.0, seed: int = 0) -> dict:
    capacity = measure_capacity(seed)
    print(f"single-pipeline capacity (continuous batching, max_batch="
          f"{MAX_BATCH}): {capacity:.0f} req/s")
    rows = sweep_load(capacity, duration_s, seed)
    payoff = autoscale_payoff(capacity, duration_s, seed)

    # the admission bound is what keeps the tail finite past saturation:
    # an admitted request waits at most ~ADMISSION_DEPTH service slots
    p99_bound_ms = 3e3 * ADMISSION_DEPTH / capacity + rows[0]["p99_ms"]
    over = [r for r in rows if r["load_x"] >= 2.0]
    under = [r for r in rows if r["load_x"] <= 0.7]
    claims = {
        "capacity_req_s": capacity,
        "p99_bound_ms": p99_bound_ms,
        "worst_p99_ms": max(r["p99_ms"] for r in rows),
        "overload_rejects": min(r["rejected"] for r in over),
        "underload_rejects": max(r["rejected"] for r in under),
        "autoscale_gain": payoff["completed_gain"],
        "autoscale_grows": payoff["auto_grows"],
    }
    payload = {
        "rows": rows,
        "autoscale": payoff,
        "claims": claims,
        "cluster": {
            "hosting_nodes": N_HOSTING,
            "link_bytes_s": LINK_BYTES_S,
            "capacity_bytes": CAPACITY,
        },
        "serving": {
            "engine": "open-loop pipelined engine, trace-driven",
            "max_batch": MAX_BATCH,
            "admission_depth": ADMISSION_DEPTH,
            "duration_s": duration_s,
        },
    }
    save(ARTIFACT, payload)
    print(table(rows, ["load_x", "offered_rate", "completed_rate", "rejected",
                       "reject_frac", "p50_ms", "p95_ms", "p99_ms",
                       "mean_batch"],
                "Latency-throughput pareto, open-loop Poisson (16 nodes)"))
    print(f"autoscale payoff: {payoff}")
    print(f"claims: {claims}")

    # tail stays bounded by the admission queue even at 2x overload
    assert claims["worst_p99_ms"] <= p99_bound_ms, claims
    # overflow is rejected (shed), not queued without bound or lost
    assert claims["overload_rejects"] > 0, claims
    assert claims["underload_rejects"] == 0, claims
    # load shedding must not throttle the engine below capacity
    sat = max(r["completed_rate"] for r in rows)
    assert sat >= 0.9 * capacity, (sat, capacity)
    # the tentpole claim: autoscaling >= 1.5x the fixed single replica
    assert claims["autoscale_gain"] >= 1.5, (
        f"autoscaler must complete >= 1.5x the fixed single replica on the "
        f"bursty trace, got {claims['autoscale_gain']:.2f}x")
    return payload


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=2.0,
                    help="trace duration in virtual seconds")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(duration_s=args.duration, seed=args.seed)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
