"""Codec registry: named, discoverable inter-stage transfer codecs.

Mirrors ``repro.api.registry`` (the partitioner/placer registry): every codec
the data plane can put on a link self-registers by name via the
``@register_codec(name)`` decorator applied at its definition site
(``dataplane/codecs.py``).  The registry is the single source of truth for

  * which codecs exist (``list_codecs()``),
  * which one a ``DeploymentSpec.codec`` name means (``get_codec``), and
  * what rides a link when no codec is named (``default_codec`` --
    ``identity``, the uncompressed wire format every PR before this one
    implicitly used).

Unknown names raise ``UnknownCodecError`` carrying did-you-mean suggestions,
so a typo in a spec fails at validation time with a readable message instead
of deep inside the serving engine.

The table mechanics are the shared ``repro.core.registry`` helper; this
module keeps the codec-specific surface (instance storage, the ``auto``
sentinel, and the historical error type).
"""

from __future__ import annotations

from repro.core.registry import (
    Registry,
    UnknownNameError,
    suggest,
    unknown_message,
)

AUTO = "auto"  # spec sentinel: the planner picks the codec per link


class UnknownCodecError(UnknownNameError):
    """Raised for a codec name not in the registry; carries suggestions."""

    def __init__(self, name: str, known: tuple[str, ...]):
        suggestions = suggest(name, known)
        super().__init__(
            unknown_message("codec", name, known, suggestions),
            name=name, known=known, suggestions=suggestions,
        )


def _ensure_registered() -> None:
    """Import the codec module so its decorators have run."""
    import repro.dataplane.codecs  # noqa: F401


_REGISTRY = Registry(
    "codec",
    ensure=_ensure_registered,
    error=UnknownCodecError,
)


def register_codec(name: str, *, default: bool = False):
    """Class decorator: register one instance of ``cls`` as codec ``name``.

    Codecs are stateless singletons (their parameters -- block size, keep
    fraction -- are class-level configuration), so the registry stores an
    instance, not the class.
    """

    def deco(cls):
        inst = cls()
        inst.name = name
        _REGISTRY.register(name, inst, default=default)
        return cls

    return deco


def get_codec(name: str):
    """Look up a codec by name; unknown names raise with suggestions."""
    return _REGISTRY.get(name)


def list_codecs() -> tuple[str, ...]:
    """Registered codec names, sorted (default first)."""
    return _REGISTRY.names()


def default_codec() -> str:
    """The codec used when a spec leaves ``codec`` unset."""
    return _REGISTRY.default()


def codec_table() -> list[dict[str, str]]:
    """All registered codecs as rows (name/ratio/error/description)."""
    rows = []
    for name in list_codecs():
        c = _REGISTRY.get(name)
        rows.append({
            "name": name,
            "default": "yes" if default_codec() == name else "",
            "wire_ratio_f32": f"{c.wire_ratio():.3f}",
            "error_bound": f"{c.error_bound:.3g}",
            "description": type(c).__doc__.splitlines()[0] if type(c).__doc__ else "",
        })
    return rows
