"""Codec registry: named, discoverable inter-stage transfer codecs.

Mirrors ``repro.api.registry`` (the partitioner/placer registry): every codec
the data plane can put on a link self-registers by name via the
``@register_codec(name)`` decorator applied at its definition site
(``dataplane/codecs.py``).  The registry is the single source of truth for

  * which codecs exist (``list_codecs()``),
  * which one a ``DeploymentSpec.codec`` name means (``get_codec``), and
  * what rides a link when no codec is named (``default_codec`` --
    ``identity``, the uncompressed wire format every PR before this one
    implicitly used).

Unknown names raise ``UnknownCodecError`` carrying did-you-mean suggestions,
so a typo in a spec fails at validation time with a readable message instead
of deep inside the serving engine.

Like the strategy registry, this module deliberately imports nothing from
the codec implementations -- ``dataplane/codecs.py`` imports *it* to
self-register, and ``_ensure_registered`` imports that module lazily on
first lookup so ``list_codecs`` works no matter which side was imported
first.
"""

from __future__ import annotations

import difflib

AUTO = "auto"  # spec sentinel: the planner picks the codec per link


class UnknownCodecError(KeyError):
    """Raised for a codec name not in the registry; carries suggestions."""

    def __init__(self, name: str, known: tuple[str, ...]):
        self.name = name
        self.known = known
        self.suggestions = tuple(
            difflib.get_close_matches(name, known, n=3, cutoff=0.4)
        )
        msg = f"unknown codec {name!r}; registered: {', '.join(known)}"
        if self.suggestions:
            msg += f" (did you mean {' or '.join(map(repr, self.suggestions))}?)"
        super().__init__(msg)

    def __str__(self) -> str:  # KeyError.__str__ repr-quotes; keep it readable
        return self.args[0]


_REGISTRY: dict[str, "object"] = {}
_DEFAULT: list[str] = []


def register_codec(name: str, *, default: bool = False):
    """Class decorator: register one instance of ``cls`` as codec ``name``.

    Codecs are stateless singletons (their parameters -- block size, keep
    fraction -- are class-level configuration), so the registry stores an
    instance, not the class.
    """

    def deco(cls):
        if name in _REGISTRY:
            raise ValueError(f"duplicate codec {name!r}")
        inst = cls()
        inst.name = name
        _REGISTRY[name] = inst
        if default:
            if _DEFAULT and _DEFAULT[0] != name:
                raise ValueError(
                    f"two default codecs: {_DEFAULT[0]!r}, {name!r}")
            _DEFAULT[:] = [name]
        return cls

    return deco


def _ensure_registered() -> None:
    """Import the codec module so its decorators have run."""
    import repro.dataplane.codecs  # noqa: F401


def get_codec(name: str):
    """Look up a codec by name; unknown names raise with suggestions."""
    _ensure_registered()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise UnknownCodecError(name, list_codecs()) from None


def list_codecs() -> tuple[str, ...]:
    """Registered codec names, sorted (default first)."""
    _ensure_registered()
    names = sorted(_REGISTRY)
    if _DEFAULT and _DEFAULT[0] in names:
        names.remove(_DEFAULT[0])
        names.insert(0, _DEFAULT[0])
    return tuple(names)


def default_codec() -> str:
    """The codec used when a spec leaves ``codec`` unset."""
    _ensure_registered()
    return _DEFAULT[0]


def codec_table() -> list[dict[str, str]]:
    """All registered codecs as rows (name/ratio/error/description)."""
    _ensure_registered()
    rows = []
    for name in list_codecs():
        c = _REGISTRY[name]
        rows.append({
            "name": name,
            "default": "yes" if _DEFAULT and _DEFAULT[0] == name else "",
            "wire_ratio_f32": f"{c.wire_ratio():.3f}",
            "error_bound": f"{c.error_bound:.3g}",
            "description": type(c).__doc__.splitlines()[0] if type(c).__doc__ else "",
        })
    return rows
