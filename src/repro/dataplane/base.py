"""Codec interface: what the data plane needs to know about a wire format.

A codec is three things at once:

  1. a **transform** -- ``encode(x) -> payload`` / ``decode(payload) -> x'``
     on real activation arrays (jax or numpy), with ``transcode`` as the
     round-trip the serving engine applies when a microbatch crosses a link;
  2. a **byte model** -- ``compressed_bytes(shape, dtype)`` is the exact
     on-wire size of one array, and ``wire_bytes(nbytes)`` is the analytic
     projection of that ratio onto the simulator's byte-counted boundaries
     (activations are f32 on the wire unless a codec says otherwise), which
     is what ``core.bottleneck.service_times`` charges the link;
  3. a **cost model** -- encode/decode flops per input byte, turned into
     seconds by the hosting node's ``flops_per_s``, charged to the link's
     serial window (the transfer occupies the link for
     ``encode + wire/bw + decode``).

``error_bound`` is the codec's reported worst-case round-trip error,
relative to ``max|x|`` over the tensor (0 for lossless).  It is the single
number the planner's ``accuracy_tolerance`` check consumes -- for ``int8``
it is literally the bound the quantize-kernel tests assert
(``repro.kernels.quantize.INT8_MAX_REL_ERROR``).
"""

from __future__ import annotations

import copy
import dataclasses
import math
from typing import Any, Sequence

F32_BYTES = 4.0  # the simulator's byte model: f32 activations on the wire


@dataclasses.dataclass
class EncodedActivation:
    """A still-encoded boundary activation handed to a receiving stage.

    When the receiving stage's executor advertises a fused decode for the
    link's codec (``executor.fused_codecs``), the engine skips the eager
    ``decode`` half of ``transcode`` and passes the wire payload through --
    the stage's first op consumes it directly (e.g. int8 ->
    ``kernels.quantize.dequant_matmul``).  ``decode()`` is the always-correct
    fallback for any consumer that needs the plain array."""

    codec: "Codec"
    payload: Any

    def decode(self) -> Any:
        return self.codec.decode(self.payload)


class Codec:
    """One inter-stage transfer wire format.  Subclass and register with
    ``@register_codec(name)``; override the transform and the byte model."""

    name: str = "?"
    error_bound: float = 0.0  # max |roundtrip - x| / max|x| (0 = lossless)
    encode_flops_per_byte: float = 0.0
    decode_flops_per_byte: float = 0.0

    # -- transform -----------------------------------------------------------
    def encode(self, x: Any) -> Any:
        raise NotImplementedError

    def decode(self, payload: Any) -> Any:
        raise NotImplementedError

    def transcode(self, x: Any) -> Any:
        """decode(encode(x)): what a receiver sees.  The serving engine
        applies this when a transfer completes, so lossy codecs really do
        alter the activations flowing through the pipeline."""
        return self.decode(self.encode(x))

    def configured(self, **attrs: Any) -> "Codec":
        """A shallow copy with ``attrs`` overridden (e.g. the execution
        knob's ``use_pallas``/``interpret``).  The registry's singletons stay
        untouched; unknown attributes are rejected so a typo can't silently
        configure nothing."""
        for k in attrs:
            if not hasattr(self, k):
                raise AttributeError(f"codec {self.name!r} has no attribute {k!r}")
        dup = copy.copy(self)
        for k, v in attrs.items():
            setattr(dup, k, v)
        return dup

    # -- byte model ----------------------------------------------------------
    def wire_ratio(self, elem_bytes: float = F32_BYTES) -> float:
        """On-wire bytes per input byte for ``elem_bytes``-wide elements."""
        raise NotImplementedError

    def wire_bytes(self, nbytes: float, elem_bytes: float = F32_BYTES) -> float:
        """Analytic on-wire size of an ``nbytes`` boundary transfer."""
        return float(nbytes) * self.wire_ratio(elem_bytes)

    def compressed_bytes(self, shape: Sequence[int], dtype: Any = None) -> int:
        """Exact on-wire size of one array (measured layout, not the analytic
        ratio).  Default derives from ``wire_ratio``; codecs with per-block
        sidecars (scales, indices) override with the real layout math."""
        elem = _itemsize(dtype)
        n = math.prod(shape)
        return int(math.ceil(n * elem * self.wire_ratio(elem)))

    # -- cost model ----------------------------------------------------------
    def encode_cost_s(self, nbytes: float, flops_per_s: float) -> float:
        """Seconds the sender spends encoding an ``nbytes`` boundary."""
        if flops_per_s is None or flops_per_s <= 0:
            return 0.0
        return float(nbytes) * self.encode_flops_per_byte / float(flops_per_s)

    def decode_cost_s(self, nbytes: float, flops_per_s: float) -> float:
        """Seconds the receiver spends decoding back to ``nbytes``."""
        if flops_per_s is None or flops_per_s <= 0:
            return 0.0
        return float(nbytes) * self.decode_flops_per_byte / float(flops_per_s)

    def __repr__(self) -> str:
        return f"<codec {self.name}>"


def _itemsize(dtype: Any) -> float:
    """Bytes per element of ``dtype`` (default f32) without importing numpy
    at module scope."""
    if dtype is None:
        return F32_BYTES
    size = getattr(dtype, "itemsize", None)
    if size is None:  # a dtype *type* like jnp.bfloat16 / np.float32
        import numpy as np

        size = np.dtype(dtype).itemsize
    return float(size)
