"""Bandwidth-aware activation data plane: pluggable inter-stage codecs.

SEIFER pipelines on edge networks are link-bound -- the inter-partition
activation transfer, not compute, sets the bottleneck period -- and the
companion DEFER paper shows lossy activation compression is the lever that
restores throughput.  This package is that lever as a subsystem:

  * ``registry`` -- ``@register_codec`` named-codec registry with
    did-you-mean errors (mirrors ``repro.api.registry``);
  * ``base``     -- the ``Codec`` interface: real encode/decode transforms,
    an exact ``compressed_bytes(shape, dtype)`` layout model, the analytic
    ``wire_bytes`` ratio the byte-counted simulator charges, and an
    encode/decode compute-cost model;
  * ``codecs``   -- ``identity`` / ``fp16`` / ``int8`` (backed by the
    ``kernels/quantize`` Pallas stack, numpy fallback) / ``topk-sparse``;
  * ``auto``     -- per-link codec selection under a per-link
    ``accuracy_tolerance``, used by the planner's joint codec x placement
    search and provably never worse than ``identity``.

The codec names flow spec -> plan -> pipeline -> engine: the planner picks
(or is told) a codec per link, ``core.bottleneck.service_times`` charges
``encode + transfer(compressed) + decode`` to the link's serial window, and
the serving engine applies the real transform to every microbatch crossing
that link -- the first place the Pallas quantize kernel participates in the
serving path.
"""

from repro.dataplane.auto import (
    assign_link_codecs,
    link_charge_s,
    resolve_codecs,
    select_codec,
)
from repro.dataplane.base import Codec
from repro.dataplane.registry import (
    AUTO,
    UnknownCodecError,
    codec_table,
    default_codec,
    get_codec,
    list_codecs,
    register_codec,
)

__all__ = [
    "AUTO",
    "Codec",
    "UnknownCodecError",
    "assign_link_codecs",
    "codec_table",
    "default_codec",
    "get_codec",
    "link_charge_s",
    "list_codecs",
    "register_codec",
    "resolve_codecs",
    "select_codec",
]
