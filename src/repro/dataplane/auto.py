"""Joint codec-per-link selection (the data plane's planning half).

The timing model charges a link's serial window with

    encode(raw bytes on the sender) + wire_bytes / bandwidth + decode(raw
    bytes on the receiver)

so each hop's charge depends only on its *own* codec -- which makes the
per-link optimum exact and cheap: for every hop, pick the admissible codec
(``error_bound <= accuracy_tolerance``) minimizing the charged window, with
lossless-first tie-breaking.  Because ``identity`` (error 0) is always
admissible, ``codec="auto"`` can never predict worse than the uncompressed
plan, and because every candidate's window is non-increasing in bandwidth,
predicted throughput stays monotone in link bandwidth -- both properties are
pinned by ``tests/test_dataplane_properties.py``.

Hop indexing matches ``core.bottleneck.service_times``: hop 0 is the
dispatcher -> first-stage input, hop h (1 <= h <= k-1) the stage h-1 ->
stage h boundary, hop k the last-stage -> dispatcher output.  The dispatcher
round-trip hops always ride ``identity`` -- codecs compress *inter-stage*
activations; the request/response payload belongs to the client.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.bottleneck import node_flops
from repro.dataplane.base import Codec
from repro.dataplane.registry import AUTO, default_codec, get_codec, list_codecs


def resolve_codecs(codecs) -> list[Codec] | None:
    """Names-or-instances -> instances (None passes through)."""
    if codecs is None:
        return None
    return [c if isinstance(c, Codec) else get_codec(c) for c in codecs]


def link_charge_s(
    codec: Codec,
    nbytes: float,
    bw: float,
    *,
    src_flops: float = 0.0,
    dst_flops: float = 0.0,
) -> float:
    """The serial window one ``nbytes`` transfer occupies on the link."""
    if nbytes <= 0:
        return 0.0
    wire = codec.wire_bytes(nbytes)
    xfer = float("inf") if bw <= 0 else wire / bw
    return codec.encode_cost_s(nbytes, src_flops) + xfer + \
        codec.decode_cost_s(nbytes, dst_flops)


def select_codec(
    nbytes: float,
    bw: float,
    *,
    tolerance: float | None = None,
    src_flops: float = 0.0,
    dst_flops: float = 0.0,
    candidates: Sequence[str] | None = None,
) -> str:
    """The admissible codec with the smallest charged window on this link.

    Ties break toward the smaller error bound (then the name), so a link
    fast enough that compression buys nothing stays lossless.
    """
    names = list(candidates) if candidates is not None else list(list_codecs())
    best: tuple[float, float, str] | None = None
    for name in names:
        codec = get_codec(name)
        if tolerance is not None and codec.error_bound > tolerance:
            continue
        key = (
            link_charge_s(codec, nbytes, bw,
                          src_flops=src_flops, dst_flops=dst_flops),
            codec.error_bound,
            name,
        )
        if best is None or key < best:
            best = key
    if best is None:  # every candidate over tolerance: fall back to lossless
        return default_codec()
    return best[2]


def assign_link_codecs(
    hop_bytes: Sequence[float],
    path: Sequence[int],
    bw: np.ndarray,
    *,
    codec: str | None = None,
    tolerance: float | None = None,
    flops_per_node=None,
    dispatcher: int | None = None,
    compression_ratio: float = 1.0,
) -> tuple[str, ...]:
    """One codec name per hop (``len(path) + 1`` entries).

    ``codec`` is a registered name (every inter-stage hop uses it), ``"auto"``
    (per-hop optimum as above), or ``None`` (the registry default,
    ``identity``).  ``hop_bytes`` are the *raw* boundary bytes in hop order;
    the legacy ``compression_ratio`` is applied before the codec, matching
    ``service_times``.
    """
    k = len(path)
    if len(hop_bytes) != k + 1:
        raise ValueError(f"expected {k + 1} hop byte counts, got {len(hop_bytes)}")
    if codec is None:
        codec = default_codec()
    names: list[str] = []
    for h in range(k + 1):
        src = dispatcher if h == 0 else path[h - 1]
        dst = dispatcher if h == k else path[h]
        interior = 1 <= h <= k - 1
        if not interior:
            names.append(default_codec())  # dispatcher round-trip: raw
            continue
        if codec != AUTO:
            names.append(codec)
            continue
        raw = float(hop_bytes[h]) / compression_ratio
        if raw <= 0 or src is None or dst is None or src == dst:
            names.append(default_codec())  # nothing crosses a wire here
            continue
        names.append(select_codec(
            raw, float(bw[src, dst]),
            tolerance=tolerance,
            src_flops=node_flops(flops_per_node, src),
            dst_flops=node_flops(flops_per_node, dst),
        ))
    return tuple(names)
