"""The built-in inter-stage transfer codecs.

SEIFER/DEFER compress inter-partition activations on the wire (ZFP/LZ4 in
the papers); these are the TPU-native analogues, each registered by name so
``DeploymentSpec(codec=...)`` can put any of them on a link:

  =============  ========  ============  =======================================
  codec          ~ratio    error bound   mechanism
  =============  ========  ============  =======================================
  identity       1.000     0 (lossless)  raw f32 bytes (the historical wire)
  fp16           0.500     2^-11         float16 truncation
  int8           0.254     1/254         blockwise int8 (``kernels/quantize``:
                                         the Pallas kernel on TPU, its jnp ref
                                         under jit elsewhere, numpy fallback)
  topk-sparse    0.500     1 (unbounded) top-25% magnitudes as (index, value)
  =============  ========  ============  =======================================

Ratios are for f32 activations.  Transforms accept jax *or* numpy arrays and
return the same kind -- the engine feeds jax microbatches, unit tests and
the numpy fallback path feed numpy.
"""

from __future__ import annotations

import math
from typing import Any

import numpy as np

from repro.dataplane.base import Codec, _itemsize
from repro.dataplane.registry import register_codec

try:  # the int8 transform rides the quantize kernel stack when jax is up
    from repro.kernels.quantize import (
        INT8_MAX_REL_ERROR,
        dequantize_int8,
        quantize_int8,
    )

    _HAVE_JAX_QUANTIZE = True
except Exception:  # pragma: no cover - bare-numpy environments
    INT8_MAX_REL_ERROR = 0.5 / 127.0
    _HAVE_JAX_QUANTIZE = False


def _is_jax(x: Any) -> bool:
    return type(x).__module__.startswith(("jax", "jaxlib"))


@register_codec("identity", default=True)
class IdentityCodec(Codec):
    """Raw activations on the wire; the no-compression baseline."""

    def encode(self, x):
        return x

    def decode(self, payload):
        return payload

    def transcode(self, x):
        return x

    def wire_ratio(self, elem_bytes: float = 4.0) -> float:
        return 1.0


@register_codec("fp16")
class Fp16Codec(Codec):
    """float16 truncation: half the bytes at ~2^-11 relative error.

    The reported bound holds for activations within float16's finite range
    (|x| <= 65504, every normalized network in practice); larger values are
    clamped to the range edge on encode -- a graceful accuracy loss there,
    never an inf/NaN poisoning the downstream stages.
    """

    F16_MAX = 65504.0
    error_bound = 2.0 ** -11
    encode_flops_per_byte = 0.25  # one convert per f32 element
    decode_flops_per_byte = 0.25

    def encode(self, x):
        if _is_jax(x):
            import jax.numpy as jnp

            clamped = jnp.clip(x, -self.F16_MAX, self.F16_MAX)
            return clamped.astype(jnp.float16), x.dtype
        x = np.asarray(x)
        clamped = np.clip(x, -self.F16_MAX, self.F16_MAX)
        return clamped.astype(np.float16), x.dtype

    def decode(self, payload):
        y, dtype = payload
        return y.astype(dtype)

    def wire_ratio(self, elem_bytes: float = 4.0) -> float:
        return 2.0 / elem_bytes


@register_codec("int8")
class Int8Codec(Codec):
    """Blockwise symmetric int8 (``kernels/quantize``): 1 byte per element
    plus one f32 scale per ``block``; error <= scale/2 per element."""

    block = 256
    error_bound = INT8_MAX_REL_ERROR
    encode_flops_per_byte = 1.5  # abs/max-reduce/div/round/clip per element
    decode_flops_per_byte = 0.5  # mul + cast per element
    # execution knob (see repro.core.execution): deployments flip these via
    # ``configured()`` so the registry singleton keeps the ref defaults
    use_pallas = False
    interpret = False

    def encode(self, x):
        if _HAVE_JAX_QUANTIZE and _is_jax(x):
            q, s = quantize_int8(x, block=self.block, use_pallas=self.use_pallas,
                                 interpret=self.interpret)
            return "jax", q, s, x.dtype
        x = np.asarray(x)
        q, s = _np_quantize(x, self.block)
        return "np", q, s, x.dtype

    def decode(self, payload):
        kind, q, s, dtype = payload
        if kind == "jax":
            return dequantize_int8(q, s, dtype=dtype, block=self.block,
                                   use_pallas=self.use_pallas,
                                   interpret=self.interpret)
        return _np_dequantize(q, s, self.block).astype(dtype)

    def wire_ratio(self, elem_bytes: float = 4.0) -> float:
        return (1.0 + 4.0 / self.block) / elem_bytes

    def compressed_bytes(self, shape, dtype=None) -> int:
        *lead, d = shape
        n_blocks = math.prod(lead) * -(-d // self.block)
        return int(math.prod(shape)) + 4 * int(n_blocks)


@register_codec("topk-sparse")
class TopKSparseCodec(Codec):
    """Magnitude top-k sparsification: the largest ``keep_frac`` of the
    elements as (int32 index, value) pairs, zeros elsewhere.  The reported
    error bound is 1.0 -- a dropped element can be as large as the kept
    threshold -- so ``auto`` only picks it when the tolerance says the
    caller genuinely does not care."""

    keep_frac = 0.25
    error_bound = 1.0
    encode_flops_per_byte = 4.0  # selection dominates
    decode_flops_per_byte = 0.25  # scatter into zeros

    def _k(self, n: int) -> int:
        return max(1, int(math.ceil(self.keep_frac * n)))

    def encode(self, x):
        if _is_jax(x):
            import jax
            import jax.numpy as jnp

            flat = x.reshape(-1)
            _, idx = jax.lax.top_k(jnp.abs(flat), self._k(flat.shape[0]))
            return "jax", x.shape, x.dtype, idx, flat[idx]
        x = np.asarray(x)
        flat = x.reshape(-1)
        k = self._k(flat.size)
        idx = np.argpartition(np.abs(flat), -k)[-k:]
        return "np", x.shape, x.dtype, idx, flat[idx]

    def decode(self, payload):
        kind, shape, dtype, idx, vals = payload
        if kind == "jax":
            import jax.numpy as jnp

            n = math.prod(shape)
            flat = jnp.zeros((n,), dtype).at[idx].set(vals)
            return flat.reshape(shape)
        flat = np.zeros((math.prod(shape),), dtype)
        flat[idx] = vals
        return flat.reshape(shape)

    def wire_ratio(self, elem_bytes: float = 4.0) -> float:
        return self.keep_frac * (elem_bytes + 4.0) / elem_bytes

    def compressed_bytes(self, shape, dtype=None) -> int:
        k = self._k(int(math.prod(shape)))
        return int(k * (_itemsize(dtype) + 4.0))


# ---------------------------------------------------------------------------
# numpy fallback for the int8 transform.  Mirrors kernels/quantize/ref.py
# (which must stay jnp so it lowers under jit and cannot be imported without
# jax); tests/test_dataplane.py pins the two byte-for-byte so they cannot
# drift apart silently.
# ---------------------------------------------------------------------------

def _np_quantize(x: np.ndarray, block: int) -> tuple[np.ndarray, np.ndarray]:
    *lead, d = x.shape
    nb = -(-d // block)
    pad = nb * block - d
    xf = np.asarray(x, np.float32)
    if pad:
        xf = np.pad(xf, [(0, 0)] * len(lead) + [(0, pad)])
    xb = xf.reshape(*lead, nb, block)
    scale = np.max(np.abs(xb), axis=-1) / 127.0
    safe = np.maximum(scale, 1e-12)
    q = np.clip(np.round(xb / safe[..., None]), -127, 127).astype(np.int8)
    return q.reshape(*lead, nb * block)[..., :d], scale


def _np_dequantize(q: np.ndarray, scale: np.ndarray, block: int) -> np.ndarray:
    *lead, d = q.shape
    nb = scale.shape[-1]
    pad = nb * block - d
    qf = np.asarray(q, np.float32)
    if pad:
        qf = np.pad(qf, [(0, 0)] * len(lead) + [(0, pad)])
    xb = qf.reshape(*lead, nb, block) * scale[..., None]
    return xb.reshape(*lead, nb * block)[..., :d]
