"""Collective-byte accounting from compiled/lowered HLO text.

``cost_analysis()`` does not expose collective traffic, so we parse the HLO:
every ``all-gather`` / ``all-reduce`` / ``reduce-scatter`` / ``all-to-all`` /
``collective-permute`` instruction's *output* shape is summed (bytes a device
sends/receives is proportional to the op's result size; for reduce-scatter
the result is already the scattered shard).  This is the numerator of the
collective roofline term.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16,
}

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\s/]+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
    re.M,
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: dict[str, int]
    count_by_op: dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_op.values())

    def summary(self) -> str:
        parts = [
            f"{op}: {self.count_by_op[op]} ops, {self.bytes_by_op[op]/1e6:.1f} MB"
            for op in sorted(self.bytes_by_op)
        ]
        return "; ".join(parts) if parts else "none"


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum output bytes of every collective instruction in the HLO module.

    `-start`/`-done` async pairs are counted once (on `-start`; `-done`
    carries the same tuple so we skip it).
    """
    bytes_by_op: dict[str, int] = defaultdict(int)
    count_by_op: dict[str, int] = defaultdict(int)
    for m in _INSTR_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2)
        line = m.group(0)
        if f"{op}-done(" in line:
            continue
        bytes_by_op[op] += _shape_bytes(shape_str)
        count_by_op[op] += 1
    return CollectiveStats(dict(bytes_by_op), dict(count_by_op))
