import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry run: lower + compile every (arch x shape) on the production
meshes, record memory/cost/collective statistics.

The two lines above MUST run before any jax import: jax locks the device
count at first init.  Smoke tests and benches never import this module.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.json
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_config, shape_cells
from repro.launch import specs as specs_lib
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.mesh import (
    DCN_BW,
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS_BF16,
    make_production_mesh,
)
from repro.runtime import serve as serve_lib
from repro.runtime import train as train_lib
from repro.sharding import (
    ShardingPolicy,
    batch_pspec,
    cache_shardings,
    param_shardings,
    state_shardings,
)

BIG_PARAMS = 1e9  # models above this train with gradient accumulation


def _batch_shardings(mesh, batch_specs):
    bspec = batch_pspec(mesh, jax.tree.leaves(batch_specs)[0].shape[0])

    def leaf(x):
        extra = (None,) * (x.ndim - 1)
        return NamedSharding(mesh, P(bspec[0] if len(bspec) else None, *extra))

    return jax.tree.map(leaf, batch_specs)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool):
    """Build and lower the step function for one dry-run cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    policy = ShardingPolicy(cfg, mesh)

    if shape.kind == "train":
        micro = 0
        if cfg.param_count() >= BIG_PARAMS:
            micro = max(shape.global_batch // 8, 1)
        if os.environ.get("REPRO_MICROBATCH"):  # SPerf sweeps
            micro = int(os.environ["REPRO_MICROBATCH"])
        opt = train_lib.OptConfig(microbatch=micro, accum_dtype=cfg.opt_state_dtype)
        step = train_lib.make_train_step(cfg, opt, policy)
        state = specs_lib.state_specs(cfg, shape)
        batch = specs_lib.input_specs(cfg, shape)
        in_sh = (state_shardings(cfg, mesh, state), _batch_shardings(mesh, batch))
        out_sh = (state_shardings(cfg, mesh, state), None)
        fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(0,))
        with mesh:
            return fn.lower(state, batch), cfg, shape, mesh

    params = specs_lib.param_specs(cfg, shape)
    p_sh = param_shardings(cfg, mesh, params)
    if shape.kind == "prefill":
        step = serve_lib.make_prefill_step(cfg, policy)
        batch = specs_lib.input_specs(cfg, shape)
        fn = jax.jit(step, in_shardings=(p_sh, _batch_shardings(mesh, batch)))
        with mesh:
            return fn.lower(params, batch), cfg, shape, mesh

    # decode
    enc_len = shape.seq_len if cfg.family == "audio" else 0
    step = serve_lib.make_serve_step(cfg, policy, enc_len=enc_len)
    caches = specs_lib.cache_specs(cfg, shape)
    c_sh = cache_shardings(cfg, mesh, caches, shape.global_batch)
    tokens = specs_lib.input_specs(cfg, shape)["tokens"]
    t_sh = _batch_shardings(mesh, {"tokens": tokens})["tokens"]
    fn = jax.jit(step, in_shardings=(p_sh, c_sh, t_sh),
                 out_shardings=(t_sh, c_sh), donate_argnums=(1,))
    with mesh:
        return fn.lower(params, caches, tokens), cfg, shape, mesh


def model_flops(cfg, shape) -> float:
    """6·N_active·D (train) or 2·N_active·D (inference) useful FLOPs."""
    n = cfg.active_param_count()
    tokens = shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len)
    return (6.0 if shape.kind == "train" else 2.0) * n * tokens


def analyse(lowered, cfg, shape, mesh, *, compile: bool = True) -> dict:
    chips = mesh.devices.size
    rec: dict = {
        "arch": cfg.name, "shape": shape.name, "chips": chips,
        "mesh": "x".join(map(str, mesh.devices.shape)),
    }
    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)

    hlo_text = compiled.as_text()
    hc = analyze_hlo(hlo_text)  # trip-count-aware (XLA counts while bodies once)
    flops = hc.flops
    bytes_acc = hc.bytes
    rec["hlo_gflops_per_chip"] = flops / 1e9
    rec["hlo_gbytes_per_chip"] = bytes_acc / 1e9
    cost = compiled.cost_analysis() or {}
    rec["xla_flops_once"] = float(cost.get("flops", 0.0))  # reference only

    mem = compiled.memory_analysis()
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                rec[k] = int(v)
        args = rec.get("argument_size_in_bytes", 0)
        temp = rec.get("temp_size_in_bytes", 0)
        rec["hbm_per_chip_gb"] = round((args + temp) / 1e9, 3)

    rec["collective_bytes_per_chip"] = hc.total_collective_bytes
    rec["collective_ops"] = hc.collective_count
    rec["collective_bytes_by_op"] = hc.collective_bytes

    # --- roofline terms (seconds) ---
    rec["t_compute"] = flops / PEAK_FLOPS_BF16
    rec["t_memory"] = bytes_acc / HBM_BW
    link_bw = ICI_BW  # intra-pod; DCN-crossing collectives noted separately
    rec["t_collective"] = hc.total_collective_bytes / link_bw
    terms = {"compute": rec["t_compute"], "memory": rec["t_memory"],
             "collective": rec["t_collective"]}
    rec["bottleneck"] = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    rec["model_gflops_total"] = mf / 1e9
    rec["useful_flops_ratio"] = mf / (flops * chips) if flops else 0.0
    rec["roofline_frac"] = (
        rec["t_compute"] / max(terms.values()) if max(terms.values()) > 0 else 0.0
    )
    return rec


def run_cell(arch: str, shape_name: str, *, multi_pod: bool) -> dict:
    try:
        lowered, cfg, shape, mesh = lower_cell(arch, shape_name, multi_pod=multi_pod)
        rec = analyse(lowered, cfg, shape, mesh)
        rec["ok"] = True
    except Exception as e:  # record failures; the harness reports them
        rec = {
            "arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "ok": False, "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-2000:],
        }
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells: list[tuple[str, str, bool]] = []
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    if args.all:
        for name, cfg in ARCHS.items():
            for sh in shape_cells(cfg):
                for mp in meshes:
                    cells.append((name, sh, mp))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape, mp) for mp in meshes]

    results = []
    for arch, sh, mp in cells:
        rec = run_cell(arch, sh, multi_pod=mp)
        results.append(rec)
        status = "OK " if rec.get("ok") else "FAIL"
        extra = (
            f"compile={rec.get('compile_s')}s hbm/chip={rec.get('hbm_per_chip_gb')}GB "
            f"bottleneck={rec.get('bottleneck')} roofline={rec.get('roofline_frac', 0):.2f}"
            if rec.get("ok") else rec.get("error", "")
        )
        print(f"[{status}] {arch} x {sh} @ {rec.get('mesh')}  {extra}", flush=True)

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {len(results)} records to {args.out}")
    return 0 if all(r.get("ok") for r in results) else 1


if __name__ == "__main__":
    raise SystemExit(main())
