"""Production mesh definitions.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module does not touch jax device state.  The dry-run driver
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; tests and benches see the single real CPU device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod mesh: (16, 16) = 256 chips; 2 pods = (2, 16, 16) = 512."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh():
    """All locally visible devices on a ("data", "model") mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


# --- TPU v5e hardware constants (roofline denominators) --------------------
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (intra-pod)
DCN_BW = 6.25e9  # bytes/s per chip (inter-pod, 50 Gbit NIC-class)
