"""Aggregate dry-run records into the EXPERIMENTS.md roofline tables.

  PYTHONPATH=src python -m repro.launch.report results/*.json
"""

from __future__ import annotations

import glob
import json
import sys
from pathlib import Path


def load_records(patterns: list[str]) -> dict[tuple, dict]:
    """Latest record per (arch, shape, mesh): later files override earlier."""
    recs: dict[tuple, dict] = {}
    files: list[str] = []
    for p in patterns:
        files += sorted(glob.glob(p))
    for f in files:
        try:
            data = json.load(open(f))
        except Exception:
            continue
        if isinstance(data, dict):
            data = [data]
        for r in data:
            if "arch" in r and "shape" in r:
                key = (r["arch"], r["shape"], r.get("mesh", "?"))
                if r.get("ok") or key not in recs:
                    recs[key] = dict(r, _src=Path(f).name)
    return recs


def fmt_s(x) -> str:
    if x is None:
        return "-"
    if x == 0:
        return "0"
    for unit, div in (("s", 1.0), ("ms", 1e-3), ("us", 1e-6)):
        if x >= div:
            return f"{x/div:.2f}{unit}"
    return f"{x:.1e}s"


def roofline_table(recs: dict[tuple, dict], mesh: str = "16x16") -> str:
    rows = []
    hdr = ("| arch | shape | t_compute | t_memory | t_collective | bound | "
           "HBM/chip | useful/HLO | roofline |")
    sep = "|" + "---|" * 9
    rows.append(hdr)
    rows.append(sep)
    for (arch, shape, m), r in sorted(recs.items()):
        if m != mesh or not r.get("ok"):
            continue
        rows.append(
            f"| {arch} | {shape} | {fmt_s(r.get('t_compute'))} | "
            f"{fmt_s(r.get('t_memory'))} | {fmt_s(r.get('t_collective'))} | "
            f"{r.get('bottleneck','-')} | {r.get('hbm_per_chip_gb','-')}GB | "
            f"{r.get('useful_flops_ratio', 0):.2f} | "
            f"{r.get('roofline_frac', 0):.3f} |"
        )
    return "\n".join(rows)


def failures(recs: dict[tuple, dict]) -> list[str]:
    return [f"{k}: {r.get('error')}" for k, r in sorted(recs.items()) if not r.get("ok")]


def main() -> int:
    patterns = sys.argv[1:] or ["results/*.json"]
    recs = load_records(patterns)
    ok = sum(1 for r in recs.values() if r.get("ok"))
    print(f"{len(recs)} cells, {ok} ok\n")
    for mesh in ("16x16", "2x16x16"):
        print(f"### mesh {mesh}\n")
        print(roofline_table(recs, mesh))
        print()
    bad = failures(recs)
    if bad:
        print("FAILURES:")
        print("\n".join(bad))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
