"""Hierarchical HLO cost analysis with while-loop trip counts.

XLA's ``compiled.cost_analysis()`` visits each ``while`` body ONCE, so any
scan-over-layers program is undercounted by the trip count.  The optimized
HLO text carries ``backend_config={"known_trip_count":{"n":...}}`` on while
ops, so we walk the computation graph ourselves:

  * flops: MXU work only -- every ``dot`` op's 2 * |output| * |contracted|
    (convolutions are not emitted by this codebase), multiplied by the
    product of enclosing trip counts.  Elementwise flops are ignored (they
    are bandwidth-, not compute-, limited on TPU).
  * bytes: per top-level instruction, operands + outputs (fusions are
    opaque: interior intermediates stay in registers/VMEM), x multiplier.
  * collectives: output bytes per op kind, x multiplier.

Validated against XLA's own cost_analysis on scan-free programs in
``tests/test_hlo_cost.py``.
"""

from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16, "token": 0,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$"
)
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\](?:\{[\d,]*\})?")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED = re.compile(r"(?:body|condition|to_apply|calls)=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND = re.compile(r"%([\w.\-]+)")


def _shape_elems(dt: str, dims: str) -> tuple[int, int]:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n, _DTYPE_BYTES.get(dt, 0)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(shape_str):
        n, b = _shape_elems(dt, dims)
        total += n * b
    return total


def _shape_dims(shape_str: str) -> list[int]:
    m = _SHAPE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d.strip()]


@dataclasses.dataclass
class _Instr:
    name: str
    shape: str
    op: str
    rest: str  # everything after the opening paren


@dataclasses.dataclass
class _Comp:
    name: str
    instrs: list[_Instr]
    shapes: dict[str, str]  # instr name -> output shape string
    is_fusion: bool


def _parse(text: str) -> tuple[dict[str, _Comp], str]:
    comps: dict[str, _Comp] = {}
    entry = ""
    cur: _Comp | None = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m:
                name = m.group(1)
                cur = _Comp(name, [], {}, "fused_computation" in name)
                if line.strip().startswith("ENTRY"):
                    entry = name
        else:
            if line.strip() == "}":
                comps[cur.name] = cur
                cur = None
                continue
            m = _INSTR.match(line)
            if m:
                ins = _Instr(m.group(1), m.group(2), m.group(3), m.group(4))
                cur.instrs.append(ins)
                cur.shapes[ins.name] = ins.shape
    if cur is not None:
        comps[cur.name] = cur
    return comps, entry


_SLICE_OPS = {"dynamic-slice", "slice", "gather"}


def _fusion_profile(comp: _Comp) -> tuple[float, dict[int, float]]:
    """(output bytes written, {param index -> bytes read}) for a fused comp.

    A parameter consumed only by slice-type ops contributes its sliced
    windows, not its full size (XLA fusions read only what they touch);
    a parameter updated in place by a root dynamic-update-slice contributes
    the update region.  Everything else reads the full operand.
    """
    param_idx: dict[str, int] = {}
    for ins in comp.instrs:
        if ins.op == "parameter":
            m = re.match(r"(\d+)\)", ins.rest)
            if m:
                param_idx[ins.name] = int(m.group(1))
    reads: dict[int, float] = {}
    for pname, idx in param_idx.items():
        full = _shape_bytes(comp.shapes.get(pname, ""))
        b = 0.0
        sliced_only = True
        for ins in comp.instrs:
            if ins.op == "parameter":
                continue
            opnds = _OPERAND.findall(ins.rest.split("),")[0])
            if pname not in opnds:
                continue
            if ins.op in _SLICE_OPS:
                b += _shape_bytes(ins.shape)
            elif ins.op == "dynamic-update-slice" and opnds and opnds[0] == pname:
                pass  # untouched region is neither read nor written
            else:
                sliced_only = False
                break
        reads[idx] = b if sliced_only else full
    # output bytes: in-place DUS roots write only the update region
    root = comp.instrs[-1] if comp.instrs else None
    out_b = 0.0
    if root is not None:
        if root.op == "dynamic-update-slice":
            opnds = _OPERAND.findall(root.rest.split("),")[0])
            upd = comp.shapes.get(opnds[1]) if len(opnds) > 1 else None
            out_b = _shape_bytes(upd) if upd else _shape_bytes(root.shape)
        elif root.op == "tuple":
            for opnd in _OPERAND.findall(root.rest.split("),")[0]):
                oi = next((i for i in comp.instrs if i.name == opnd), None)
                if oi is not None and oi.op == "dynamic-update-slice":
                    o2 = _OPERAND.findall(oi.rest.split("),")[0])
                    upd = comp.shapes.get(o2[1]) if len(o2) > 1 else None
                    out_b += _shape_bytes(upd) if upd else _shape_bytes(oi.shape)
                else:
                    out_b += _shape_bytes(comp.shapes.get(opnd, ""))
        else:
            out_b = _shape_bytes(root.shape)
    return out_b, reads


@dataclasses.dataclass
class HloCost:
    flops: float
    bytes: float
    collective_bytes: dict[str, float]
    collective_count: dict[str, float]

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def _dot_flops(ins: _Instr, comp: _Comp) -> float:
    out_elems = 1
    for d in _shape_dims(ins.shape):
        out_elems *= d
    m = _CONTRACT.search(ins.rest)
    contracted = 1
    ops = _OPERAND.findall(ins.rest.split(")")[0])
    if m and ops:
        lhs_shape = comp.shapes.get(ops[0])
        if lhs_shape:
            dims = _shape_dims(lhs_shape)
            for i in m.group(1).split(","):
                if i.strip() and int(i) < len(dims):
                    contracted *= dims[int(i)]
    return 2.0 * out_elems * contracted


_NO_TRAFFIC = {
    "parameter", "tuple", "get-tuple-element", "bitcast", "constant",
    "after-all", "partition-id", "replica-id", "while", "conditional",
}


def analyze_hlo(text: str) -> HloCost:
    comps, entry = _parse(text)
    cost = HloCost(0.0, 0.0, defaultdict(float), defaultdict(float))
    seen_stack: list[str] = []
    profiles: dict[str, tuple[float, dict[int, float]]] = {}

    def profile(comp_name: str):
        if comp_name not in profiles:
            c = comps.get(comp_name)
            profiles[comp_name] = _fusion_profile(c) if c else (0.0, {})
        return profiles[comp_name]

    def visit(comp_name: str, mult: float, *, bytes_opaque: bool) -> None:
        comp = comps.get(comp_name)
        if comp is None or comp_name in seen_stack:
            return
        seen_stack.append(comp_name)
        for ins in comp.instrs:
            op = ins.op
            base = op[:-6] if op.endswith("-start") else op
            # --- collectives ---
            if base in COLLECTIVE_OPS and not op.endswith("-done"):
                cost.collective_bytes[base] += _shape_bytes(ins.shape) * mult
                cost.collective_count[base] += mult
            # --- flops ---
            if op == "dot":
                cost.flops += _dot_flops(ins, comp) * mult
            # --- bytes (skip when inside a fusion: opaque) ---
            if not bytes_opaque and op not in _NO_TRAFFIC:
                if op in _SLICE_OPS:
                    # only the sliced window moves (read + write)
                    b = 2 * _shape_bytes(ins.shape)
                elif op == "dynamic-update-slice":
                    # in-place: the update region is read + written
                    opnds = _OPERAND.findall(ins.rest.split("),")[0])
                    upd = comp.shapes.get(opnds[1]) if len(opnds) > 1 else None
                    b = 2 * _shape_bytes(upd) if upd else _shape_bytes(ins.shape)
                elif op == "fusion":
                    called0 = _CALLED.findall(ins.rest)
                    out_b, reads = profile(called0[0]) if called0 else (0.0, {})
                    b = out_b
                    opnds = _OPERAND.findall(ins.rest.split("),")[0])
                    for i, opnd in enumerate(opnds):
                        if i in reads:
                            b += reads[i]
                        else:
                            s = comp.shapes.get(opnd)
                            if s:
                                b += _shape_bytes(s)
                else:
                    b = _shape_bytes(ins.shape)
                    for opnd in _OPERAND.findall(ins.rest.split("),")[0]):
                        s = comp.shapes.get(opnd)
                        if s:
                            b += _shape_bytes(s)
                cost.bytes += b * mult
            # --- descend ---
            called = _CALLED.findall(ins.rest)
            branches = _BRANCHES.search(ins.rest)
            if branches:
                called += [c.strip().lstrip("%") for c in branches.group(1).split(",")]
            if op == "while":
                t = _TRIP.search(ins.rest)
                trip = float(t.group(1)) if t else 1.0
                for c in called:
                    visit(c, mult * trip, bytes_opaque=False)
            elif op == "fusion":
                for c in called:
                    visit(c, mult, bytes_opaque=True)
            elif called:
                for c in called:
                    visit(c, mult, bytes_opaque=bytes_opaque)
        seen_stack.pop()

    visit(entry, 1.0, bytes_opaque=False)
    cost.collective_bytes = dict(cost.collective_bytes)
    cost.collective_count = dict(cost.collective_count)
    return cost
