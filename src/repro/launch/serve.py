"""Serving CLI driver: prefill-style prompt consumption + decode loop.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --tokens 16

Edge mode serves a request stream through the simulated edge cluster's
control plane instead of the local accelerator, reporting the reconcile
actions taken under a scripted node failure.  The partition/placement
strategies are registry names (see ``repro.api.list_strategies``), so every
registered pair is one CLI flag away:

  PYTHONPATH=src python -m repro.launch.serve --edge --requests 32 \\
      --partitioner min_sum --placer greedy --capacity-frac 0.33 --width 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.api import (
    ArrivalSpec,
    AutoscaleSpec,
    ClusterSpec,
    DeploymentSpec,
    TraceConfig,
    deploy,
    list_strategies,
)
from repro.cluster import NodeFailed
from repro.dataplane import list_codecs
from repro.workload import list_traces
from repro.configs import ARCHS, get_config, reduced
from repro.core.model_zoo import demo_mlp, demo_ssm, demo_transformer
from repro.models import lm
from repro.runtime.serve import make_serve_step


def _zoo(model: str, width: int, *, use_pallas: bool = False,
         interpret: bool = False):
    """(graph, executor_for_version, demo input) for a zoo model name.

    The execution knob reaches the executors here (the spec's knob fields
    cover the codec side); demo_mlp has no kernel path, so it ignores it.
    """
    if model in ("demo_ssm", "ssm"):
        graph, ex = demo_ssm(use_pallas=use_pallas, interpret=interpret)
        return graph, ex, jnp.ones((8, 24)) * 0.1
    if model in ("demo_transformer", "transformer"):
        graph, ex = demo_transformer(use_pallas=use_pallas, interpret=interpret)
        return graph, ex, jnp.ones((256, 32)) * 0.1
    graph, ex = demo_mlp(d=width)
    return graph, ex, jnp.ones((width,)) * 0.1


def serve_edge(
    requests: int,
    nodes: int,
    seed: int,
    *,
    partitioner: str | None = None,
    placer: str | None = None,
    joint: str | None = None,
    capacity_frac: float = 1 / 3,
    width: int = 32,
    serving: str = "pipelined",
    queue_depth: int = 2,
    replicas: int | str = 1,
    codec: str | None = None,
    tolerance: float | None = None,
    trace: str | None = None,
    rate: float = 400.0,
    duration_s: float = 2.0,
    autoscale: bool = False,
    max_batch: int | None = None,
    admission_depth: int | None = None,
    model: str = "demo_mlp",
    use_pallas: bool = False,
    interpret: bool = False,
    trace_sample: float | None = None,
    trace_out: str | None = None,
) -> int:
    """Edge-cluster serving demo: deploy(spec) -> stream -> kill -> recover.

    With ``trace``, the stream is open-loop: a seeded arrival trace
    (``repro.workload``) admitted by timestamp on the virtual clock, with a
    latency percentile report at the end.  ``autoscale`` turns on
    backlog-driven replica scaling over the planner's widest feasible split.
    ``trace_sample`` enables per-request span tracing at that sampling rate
    and prints the critical-path attribution; ``trace_out`` additionally
    writes the Chrome trace-event export there (chrome://tracing /
    ui.perfetto.dev).
    """
    graph, executor_for_version, x0 = _zoo(
        model, width, use_pallas=use_pallas, interpret=interpret)
    capacity = graph.total_param_bytes * capacity_frac

    arrival = None
    if trace is not None:
        arrival = ArrivalSpec(trace=trace, rate=rate, duration_s=duration_s,
                              seed=seed)
    spec = DeploymentSpec(
        model=graph,
        executor_for_version=executor_for_version,
        cluster=ClusterSpec(n_nodes=nodes, capacity_bytes=capacity, seed=seed + 3),
        partitioner=partitioner,
        placer=placer,
        joint=joint,
        codec=codec,
        accuracy_tolerance=tolerance,
        seed=seed,
        microbatch=4,
        serving=serving,
        queue_depth=queue_depth,
        replicas=replicas,
        max_batch=max_batch,
        admission_depth=admission_depth,
        arrival=arrival,
        autoscale=AutoscaleSpec() if autoscale else None,
        trace=(TraceConfig(sample=trace_sample)
               if trace_sample is not None else None),
        use_pallas=use_pallas,
        interpret=interpret,
    )
    d = deploy(spec)
    names = dict(d.plan.strategies)
    if d.replicated:
        sets = d.replicaset
        print(f"edge serving [{names}, {serving}, x{sets.n_replicas} replicas]: "
              f"groups {[sorted(g) for g in sets.groups]}, summed predicted "
              f"{d.plan.predicted_throughput:.1f} microbatch/s")
    else:
        obs = d.observed()
        print(f"edge serving [{names}, {serving}]: {len(obs.path)} partitions on "
              f"nodes {list(obs.path)}, bottleneck {obs.bottleneck_latency*1e3:.3f} ms, "
              f"predicted {d.plan.predicted_throughput:.1f} microbatch/s, "
              f"link codecs {list(d.plan.codecs)}")
    if trace is not None:
        requests = len(d.submit_trace(make_input=lambda i, a: x0))
        print(f"open-loop trace '{trace}': {requests} arrivals over "
              f"{duration_s:g}s at nominal {rate:g} req/s"
              + (", autoscaling" if autoscale else ""))
    else:
        for _ in range(requests):
            d.submit(x0)
    half = requests // 2
    killed = half == 0  # nothing to kill mid-stream on a tiny run
    pending_arrivals = lambda: getattr(d.loop, "pending_arrivals", 0)  # noqa: E731
    while d.loop.backlog or d.pending or pending_arrivals():
        if not killed and len(d.loop.completed) >= half:
            pods = d.control.pipeline.pods
            victim = pods[1 if len(pods) > 1 else 0].node_id
            print(f"killing node {victim} mid-stream...")
            d.inject(NodeFailed(victim))
            killed = True
        if (not d.step() and not d.pending
                and not pending_arrivals() and not d.loop.backlog):
            break
    m = d.metrics()
    if d.replicated:
        s = m["serving"]
        print(f"served {s['completed']}/{requests} requests (lost {s['failed']}) "
              f"in {s['clock_s']:.3f} simulated s across "
              f"{m['live_replicas']}/{m['n_replicas']} live replicas; "
              f"router dispatched {s['router']['dispatched']}")
        for rep in m["replicas"]:
            print(f"  replica {rep['replica']}{' (retired)' if rep['retired'] else ''}: "
                  f"path {rep['path']}, actions {rep['reconcile_actions']}")
    else:
        print(f"served {m['serving']['completed']}/{requests} requests "
              f"(lost {m['serving']['failed']}) in {m['serving']['clock_s']:.3f} "
              f"simulated s; final path {m['path']}, actions: {m['reconcile_actions']}")
        for st in m["serving"].get("stages", ()):
            print(f"  stage {st['stage']} on node {st['node']}: "
                  f"occupancy {st['occupancy']:.2f}, mean queue {st['mean_queue']:.2f}, "
                  f"max queue {st['max_queue']}, {st['microbatches']} microbatches")
        for ln in m["serving"].get("links", ()):
            if ln["raw_bytes"] <= 0:
                continue  # colocated endpoints: nothing rides a wire
            print(f"  link {ln['hop']}: codec {ln['codec']}, "
                  f"{ln['raw_bytes']:.0f} -> {ln['wire_bytes']:.0f} B "
                  f"({ln['compression_x']:.2f}x), "
                  f"utilization {ln['utilization']:.2f}, "
                  f"{ln['transfers']} transfers")
    s = m["serving"]
    if trace is not None:
        lat = s["latency"]["overall"]
        print(f"latency (admit -> complete): p50 {lat['p50_s']*1e3:.2f} ms, "
              f"p95 {lat['p95_s']*1e3:.2f} ms, p99 {lat['p99_s']*1e3:.2f} ms, "
              f"max {lat['max_s']*1e3:.2f} ms; rejected {s['rejected']}")
        b = s.get("batching")
        if b:
            print(f"batching: cap {b['max_batch']}, peak batch "
                  f"{b['max_batch_seen']}, mean batch {b['mean_batch']:.2f}")
    if "autoscaler" in s:
        a = s["autoscaler"]
        print(f"autoscaler: {a['grows']} grows, {a['shrinks']} shrinks, "
              f"{a['standby_groups']} standby groups left")
        for e in a["events"]:
            print(f"  t={e['t_s']:.3f}s {e['action']} replica {e['replica']} "
                  f"({e['reason']}) -> {e['live_after']} live")
    if trace_sample is not None:
        att = d.attribution()
        f = att["fractions"]
        print(f"trace ({att['spans']} spans / {att['requests']} requests): "
              f"queue {f['queue']:.0%}, compute {f['compute']:.0%}, "
              f"wire {f['wire']:.0%}, transcode {f['transcode']:.0%}")
        bn = att["bottleneck"]
        if bn is not None:
            print(f"observed bottleneck: {bn['kind']} {bn['index']} "
                  f"({bn['service_s']*1e3:.3f} ms/visit)")
        if trace_out:
            import json

            with open(trace_out, "w") as fh:
                json.dump(d.chrome_trace(), fh)
            print(f"chrome trace written to {trace_out} "
                  f"(load in chrome://tracing or ui.perfetto.dev)")
    return 0


def _tenant_input(model: str):
    """A correctly-shaped demo payload for each zoo model name."""
    if model in ("demo_ssm", "ssm"):
        return jnp.ones((8, 24)) * 0.1
    if model in ("demo_transformer", "transformer"):
        return jnp.ones((256, 32)) * 0.1
    return jnp.ones((32,)) * 0.1


def serve_tenants(
    tenant_models: list[str],
    requests: int,
    nodes: int,
    seed: int,
    *,
    policy: str = "partition",
    fractions: list[float] | None = None,
    weights: list[float] | None = None,
    capacity_frac: float = 1 / 3,
) -> int:
    """Multi-tenant edge demo: carve one cluster, serve every tenant, kill a
    node in tenant 0's slice, and show the other tenants unperturbed."""
    from repro.api import TenantSpec

    cluster = ClusterSpec(
        n_nodes=nodes,
        capacity_bytes=demo_mlp()[0].total_param_bytes * capacity_frac,
        seed=seed + 3,
    )
    tenants = []
    for i, model in enumerate(tenant_models):
        tenants.append(TenantSpec(
            name=f"{model}-{i}",
            spec=DeploymentSpec(model=model, cluster=cluster, seed=seed),
            capacity_fraction=fractions[i] if fractions else None,
            weight=weights[i] if weights else 1.0,
        ))
    d = deploy(tenants, policy=policy)
    print(f"multi-tenant edge serving [{policy}]: {nodes} nodes, "
          f"{len(tenants)} tenants")
    for p in d.plan.placements:
        print(f"  tenant {p.name}: nodes {sorted(p.nodes)} "
              f"(fraction {p.fraction:.2f}, weight {p.weight:g})")
    if d.plan.spare:
        print(f"  spare nodes: {list(d.plan.spare)}")

    inputs = {t.name: _tenant_input(t.spec.model) for t in tenants}
    for t in tenants:
        for _ in range(requests):
            d.submit(t.name, inputs[t.name])

    victim_tenant = tenants[0].name
    victim = d.nodes_for(victim_tenant)[0]
    killed = False
    while d.router.backlog or d.pending:
        if not killed and len(d.completed()) >= requests * len(tenants) // 2:
            print(f"killing node {victim} (tenant {victim_tenant!r}'s slice) "
                  f"mid-stream...")
            d.inject(NodeFailed(victim))
            killed = True
        if not d.step() and not d.pending and not d.router.backlog:
            break
    m = d.metrics()
    fair = m["serving"]["fairness"]
    for name, dep in d.deployments.items():
        tm = m["tenants"][name]
        served = fair[name]["served"]
        acts = (tm.get("reconcile_actions")
                or [a for r in tm.get("replicas", ()) for a in r["reconcile_actions"]])
        print(f"  tenant {name}: served {served}/{requests}, "
              f"actions {acts}")
    routed = [f"{t or 'cluster'}:{k}" for t, k in d.controlplane.routed]
    print(f"event routing: {routed}")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--edge", action="store_true",
                    help="serve through the simulated edge control plane")
    ap.add_argument("--requests", type=int, default=32, help="edge mode stream size")
    ap.add_argument("--nodes", type=int, default=8, help="edge mode cluster size")
    ap.add_argument("--partitioner", default=None,
                    choices=list_strategies("partitioner"),
                    help="edge mode partition strategy (default: registry default)")
    ap.add_argument("--placer", default=None,
                    choices=list_strategies("placer"),
                    help="edge mode placement strategy (default: registry default)")
    ap.add_argument("--joint", default=None,
                    choices=list_strategies("joint"),
                    help="edge mode joint optimizer (replaces partitioner+placer)")
    ap.add_argument("--capacity-frac", type=float, default=1 / 3,
                    help="edge mode per-node capacity as a fraction of model bytes")
    ap.add_argument("--width", type=int, default=32,
                    help="edge mode demo-MLP width (d)")
    ap.add_argument("--model", default="demo_mlp",
                    choices=("demo_mlp", "demo_ssm", "demo_transformer"),
                    help="edge mode zoo model to serve (demo_transformer and "
                         "demo_ssm run kernel-backed executors)")
    ap.add_argument("--use-pallas", action="store_true",
                    help="route model executors and int8 link codecs through "
                         "the Pallas TPU kernels")
    ap.add_argument("--interpret", action="store_true",
                    help="run Pallas kernels in interpret mode (CPU CI)")
    ap.add_argument("--serving", default="pipelined",
                    choices=("pipelined", "sync"),
                    help="edge mode serving engine (discrete-event pipeline "
                         "vs synchronous baseline)")
    ap.add_argument("--queue-depth", type=int, default=2,
                    help="edge mode per-stage in-queue bound (pipelined only)")
    ap.add_argument("--replicas", default="1",
                    help="edge mode pipeline replica count: an int, or 'auto' "
                         "to maximize summed predicted throughput")
    ap.add_argument("--codec", default=None,
                    choices=(*list_codecs(), "auto"),
                    help="edge mode inter-stage transfer codec; 'auto' picks "
                         "the fastest codec per link within --tolerance "
                         "(default: identity, the raw wire)")
    ap.add_argument("--tolerance", type=float, default=None,
                    help="edge mode per-link accuracy tolerance (max codec "
                         "round-trip error relative to max|x|)")
    ap.add_argument("--trace", default=None, choices=list_traces(),
                    help="edge mode open-loop arrival trace (replaces the "
                         "closed-loop --requests stream)")
    ap.add_argument("--rate", type=float, default=400.0,
                    help="edge mode trace mean arrival rate (req/s)")
    ap.add_argument("--duration", type=float, default=2.0,
                    help="edge mode trace duration (virtual seconds)")
    ap.add_argument("--autoscale", action="store_true",
                    help="edge mode backlog-driven replica autoscaling "
                         "(scales over the widest feasible replica split)")
    ap.add_argument("--max-batch", type=int, default=None,
                    help="edge mode continuous-batching cap (coalesce up to "
                         "this many queued requests per admission)")
    ap.add_argument("--admission-depth", type=int, default=None,
                    help="edge mode admission queue bound; arrivals beyond "
                         "it are rejected (load shedding) instead of queued")
    ap.add_argument("--tenants", default=None,
                    help="edge mode multi-tenant serving: comma-separated "
                         "zoo model names (e.g. demo_mlp,demo_ssm), one "
                         "tenant each on a shared cluster")
    ap.add_argument("--tenant-policy", default="partition",
                    choices=("partition", "shared"),
                    help="tenancy placement policy (disjoint node slices "
                         "vs fractional co-residency)")
    ap.add_argument("--tenant-fractions", default=None,
                    help="comma-separated capacity fractions, one per tenant")
    ap.add_argument("--tenant-weights", default=None,
                    help="comma-separated fair-share weights, one per tenant")
    ap.add_argument("--trace-sample", type=float, default=None,
                    help="edge mode per-request span tracing: fraction of "
                         "requests traced (1.0 = all); prints the "
                         "critical-path attribution at the end")
    ap.add_argument("--trace-out", default=None,
                    help="edge mode: write the Chrome trace-event export "
                         "here (requires --trace-sample)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.edge and args.tenants:
        models = [m.strip() for m in args.tenants.split(",") if m.strip()]
        parse_floats = lambda s: (  # noqa: E731
            [float(x) for x in s.split(",")] if s else None)
        return serve_tenants(
            models, args.requests, args.nodes, args.seed,
            policy=args.tenant_policy,
            fractions=parse_floats(args.tenant_fractions),
            weights=parse_floats(args.tenant_weights),
            capacity_frac=args.capacity_frac,
        )
    if args.edge:
        replicas = args.replicas if args.replicas == "auto" else int(args.replicas)
        return serve_edge(
            args.requests, args.nodes, args.seed,
            partitioner=args.partitioner, placer=args.placer, joint=args.joint,
            capacity_frac=args.capacity_frac, width=args.width,
            serving=args.serving, queue_depth=args.queue_depth,
            replicas=replicas, codec=args.codec, tolerance=args.tolerance,
            trace=args.trace, rate=args.rate, duration_s=args.duration,
            autoscale=args.autoscale, max_batch=args.max_batch,
            admission_depth=args.admission_depth,
            model=args.model, use_pallas=args.use_pallas,
            interpret=args.interpret,
            trace_sample=args.trace_sample, trace_out=args.trace_out,
        )
    if not args.arch:
        ap.error("--arch is required unless --edge is given")

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced(cfg)
    print(f"serving {cfg.name} (reduced={not args.full})")
    params = lm.init_params(cfg, jax.random.PRNGKey(0), max_pos=args.max_len)
    caches = lm.init_caches(cfg, args.batch, args.max_len, enc_len=16)
    step = jax.jit(make_serve_step(cfg, enc_len=16))

    tok = jnp.zeros((args.batch, 1), jnp.int32)
    t0 = time.perf_counter()
    outs = []
    for _ in range(args.tokens):
        tok, caches = step(params, caches, tok)
        outs.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    print(f"{args.tokens} tokens x batch {args.batch} in {dt:.2f}s "
          f"({args.tokens*args.batch/dt:.1f} tok/s); sample:",
          jnp.concatenate(outs, 1)[0, :10].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
