"""Serving CLI driver: prefill-style prompt consumption + decode loop.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --tokens 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, reduced
from repro.models import lm
from repro.runtime.serve import make_serve_step


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced(cfg)
    print(f"serving {cfg.name} (reduced={not args.full})")
    params = lm.init_params(cfg, jax.random.PRNGKey(0), max_pos=args.max_len)
    caches = lm.init_caches(cfg, args.batch, args.max_len, enc_len=16)
    step = jax.jit(make_serve_step(cfg, enc_len=16))

    tok = jnp.zeros((args.batch, 1), jnp.int32)
    t0 = time.perf_counter()
    outs = []
    for _ in range(args.tokens):
        tok, caches = step(params, caches, tok)
        outs.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    print(f"{args.tokens} tokens x batch {args.batch} in {dt:.2f}s "
          f"({args.tokens*args.batch/dt:.1f} tok/s); sample:",
          jnp.concatenate(outs, 1)[0, :10].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
