"""Serving CLI driver: prefill-style prompt consumption + decode loop.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --tokens 16

Edge mode serves a request stream through the simulated edge cluster's
control plane instead of the local accelerator, reporting the reconcile
actions taken under a scripted node failure:

  PYTHONPATH=src python -m repro.launch.serve --edge --requests 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, reduced
from repro.models import lm
from repro.runtime.serve import make_serve_step


def serve_edge(requests: int, nodes: int, seed: int) -> int:
    """Edge-cluster serving demo: bootstrap -> stream -> kill -> recover."""
    import tempfile

    from repro.cluster import (
        ArtifactStore, ControlPlane, EdgeCluster, NodeFailed, ServingLoop,
    )
    from repro.core.model_zoo import demo_mlp
    from repro.core.simulate import random_cluster

    d = 32
    graph, executor_for_version = demo_mlp(d=d)
    capacity = graph.total_param_bytes / 3

    cluster = EdgeCluster(random_cluster(nodes, capacity, seed=seed + 3),
                          flops_per_s=1e9)
    control = ControlPlane(
        cluster, ArtifactStore(tempfile.mkdtemp(prefix="seifer-serve-")),
        lambda v: graph, executor_for_version, capacity=capacity, seed=seed,
    )
    control.bootstrap(0)
    obs = control.observed()
    print(f"edge serving: {len(obs.path)} partitions on nodes {list(obs.path)}, "
          f"bottleneck {obs.bottleneck_latency*1e3:.3f} ms")
    loop = ServingLoop(control, microbatch=4)
    for _ in range(requests):
        loop.submit(jnp.ones((d,)) * 0.1)
    half = requests // 2
    killed = half == 0  # nothing to kill mid-stream on a tiny run
    while loop.backlog or control.pending:
        if not killed and len(loop.completed) >= half:
            victim = control.pipeline.pods[1].node_id
            print(f"killing node {victim} mid-stream...")
            control.submit(NodeFailed(victim))
            killed = True
        loop.step()
    obs = control.observed()
    print(f"served {len(loop.completed)}/{requests} requests "
          f"(lost {len(loop.failed)}) in {loop.clock_s:.3f} simulated s; "
          f"final path {list(obs.path)}, "
          f"actions: {[a.kind for a in control.history]}")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--edge", action="store_true",
                    help="serve through the simulated edge control plane")
    ap.add_argument("--requests", type=int, default=32, help="edge mode stream size")
    ap.add_argument("--nodes", type=int, default=8, help="edge mode cluster size")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.edge:
        return serve_edge(args.requests, args.nodes, args.seed)
    if not args.arch:
        ap.error("--arch is required unless --edge is given")

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced(cfg)
    print(f"serving {cfg.name} (reduced={not args.full})")
    params = lm.init_params(cfg, jax.random.PRNGKey(0), max_pos=args.max_len)
    caches = lm.init_caches(cfg, args.batch, args.max_len, enc_len=16)
    step = jax.jit(make_serve_step(cfg, enc_len=16))

    tok = jnp.zeros((args.batch, 1), jnp.int32)
    t0 = time.perf_counter()
    outs = []
    for _ in range(args.tokens):
        tok, caches = step(params, caches, tok)
        outs.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    print(f"{args.tokens} tokens x batch {args.batch} in {dt:.2f}s "
          f"({args.tokens*args.batch/dt:.1f} tok/s); sample:",
          jnp.concatenate(outs, 1)[0, :10].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
