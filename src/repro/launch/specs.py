"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs`` returns the batch pytree for a (arch, shape) cell;
``state_specs`` / ``cache_specs`` derive train-state and decode-cache trees
with ``jax.eval_shape`` so they always match the real initializers.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import lm
from repro.runtime import train as train_lib


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Batch pytree of ShapeDtypeStructs for this cell."""
    b = shape.global_batch
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    s = shape.seq_len
    batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patches"] = jax.ShapeDtypeStruct(
            (b, lm.PATCH_TOKENS, lm.PATCH_DIM), jnp.bfloat16
        )
    return batch


def max_pos_for(cfg: ModelConfig, shape: ShapeConfig) -> int:
    return shape.seq_len if cfg.family == "audio" else 32768


def param_specs(cfg: ModelConfig, shape: ShapeConfig):
    init = partial(lm.init_params, cfg, jax.random.PRNGKey(0), max_pos=max_pos_for(cfg, shape))
    return jax.eval_shape(init)


def state_specs(cfg: ModelConfig, shape: ShapeConfig):
    params = param_specs(cfg, shape)
    return jax.eval_shape(partial(train_lib.init_state, cfg), params)


def cache_specs(cfg: ModelConfig, shape: ShapeConfig):
    b = shape.global_batch
    init = partial(lm.init_caches, cfg, b, shape.seq_len, enc_len=shape.seq_len)
    return jax.eval_shape(init)
