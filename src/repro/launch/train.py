"""Training CLI driver.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --steps 20 \
      [--reduced] [--batch 8] [--seq 128] [--ckpt-dir /tmp/ckpt]

Full configs are for real TPU fleets; on this host use --reduced (default).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, reduced
from repro.models import lm
from repro.runtime import train as train_lib
from repro.runtime.checkpoint import Checkpointer


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--full", action="store_true", help="full (fleet-scale) config")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced(cfg)
    print(f"arch={cfg.name} layers={cfg.n_layers} d={cfg.d_model} "
          f"family={cfg.family} sharding={cfg.sharding}")

    params = lm.init_params(cfg, jax.random.PRNGKey(0), max_pos=args.seq)
    state = train_lib.init_state(cfg, params)
    opt = train_lib.OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                              microbatch=args.microbatch,
                              accum_dtype=cfg.opt_state_dtype)
    step_fn = jax.jit(train_lib.make_train_step(cfg, opt))
    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if ckpt and ckpt.latest_step() >= 0:
        start, state = ckpt.restore(state)
        print(f"resumed from step {start}")

    key = jax.random.PRNGKey(1)
    t0 = time.perf_counter()
    for i in range(start, args.steps):
        key, k = jax.random.split(key)
        batch = {"tokens": jax.random.randint(k, (args.batch, args.seq), 0, cfg.vocab_size)}
        if cfg.family == "audio":
            batch["frames"] = jnp.ones((args.batch, args.seq, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            batch["patches"] = jnp.ones((args.batch, 8, lm.PATCH_DIM), jnp.bfloat16)
        state, m = step_fn(state, batch)
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss {float(m['loss']):.4f} gnorm {float(m['grad_norm']):.3f}")
        if ckpt and (i + 1) % args.ckpt_every == 0:
            ckpt.save(i + 1, state)
    print(f"{args.steps - start} steps in {time.perf_counter()-t0:.1f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
