"""``Planner``: compile a spec (or a raw graph + cluster) into a ``Plan``.

The planner is the *policy-free* middle of the API: it resolves strategy
names through the registry, runs partition -> placement (or a joint
optimizer), and scores the result with the simulator's pipeline metrics --
no cluster machinery, no pods.  ``Plan`` subsumes the old
``dispatcher.DeploymentPlan`` (same ``version``/``partition``/``placement``
fields, so ``Dispatcher.deploy`` consumes it unchanged) and adds the
predicted bottleneck/throughput plus the strategy names that produced it.

Strategy functions keep their natural signatures; the planner passes each
one only the keyword arguments it accepts (``inspect.signature``-filtered),
so e.g. ``place_greedy`` never sees ``n_classes`` and ``place_random``
still gets its ``seed``.
"""

from __future__ import annotations

import dataclasses
import inspect
from typing import TYPE_CHECKING, Sequence

from repro.api.registry import default_strategy, get_strategy
from repro.core.bottleneck import evaluate_pipeline
from repro.core.graph import LayerGraph
from repro.core.partitioner import PartitionResult
from repro.core.placement import CommGraph, PlacementResult

if TYPE_CHECKING:
    from repro.api.spec import DeploymentSpec, SpecIssue


@dataclasses.dataclass(frozen=True)
class Plan:
    """A compiled deployment: partition + placement + predicted metrics.

    Drop-in for the old ``dispatcher.DeploymentPlan`` (which is now an alias
    of this class): ``Dispatcher.deploy`` reads ``version``, ``partition``,
    ``placement``, ``feasible``.
    """

    version: int
    partition: PartitionResult
    placement: PlacementResult
    # the placement objective: max link latency on UNCOMPRESSED boundaries
    predicted_bottleneck_s: float = float("inf")
    # 1 / pipeline period, compression- and compute-aware (simulator metric)
    predicted_throughput: float = 0.0
    strategies: tuple[tuple[str, str], ...] = ()  # (kind, name) pairs

    @property
    def feasible(self) -> bool:
        return self.partition.feasible and self.placement.feasible

    @property
    def n_parts(self) -> int:
        return self.partition.n_parts

    @property
    def path(self) -> tuple[int, ...]:
        return self.placement.path

    def strategy(self, kind: str) -> str | None:
        return dict(self.strategies).get(kind)

    def slo_issues(self, spec: "DeploymentSpec") -> tuple["SpecIssue", ...]:
        """Check the plan's predictions against the spec's SLOs."""
        from repro.api.spec import SpecIssue

        issues = []
        if not self.feasible:
            issues.append(SpecIssue(
                "infeasible_plan",
                f"{self.partition.algorithm}/{self.placement.algorithm} found "
                f"no feasible partition+placement on this cluster",
            ))
            return tuple(issues)
        if (spec.max_bottleneck_s is not None
                and self.predicted_bottleneck_s > spec.max_bottleneck_s):
            issues.append(SpecIssue(
                "slo_bottleneck",
                f"predicted bottleneck {self.predicted_bottleneck_s:.3e} s "
                f"exceeds the max_bottleneck_s SLO {spec.max_bottleneck_s:.3e} s",
            ))
        if (spec.min_throughput is not None
                and self.predicted_throughput < spec.min_throughput):
            issues.append(SpecIssue(
                "slo_throughput",
                f"predicted throughput {self.predicted_throughput:.3e}/s is "
                f"below the min_throughput SLO {spec.min_throughput:.3e}/s",
            ))
        return tuple(issues)

    def summary(self) -> dict:
        """JSON-ready description (stored by the dispatcher, logged by benches)."""
        return {
            "version": self.version,
            "feasible": self.feasible,
            "cuts": list(self.partition.cuts),
            "path": list(self.placement.path),
            "bottleneck_latency": self.placement.bottleneck_latency,
            "predicted_bottleneck_s": self.predicted_bottleneck_s,
            "predicted_throughput": self.predicted_throughput,
            "algorithm": self.placement.algorithm,
            "strategies": {k: v for k, v in self.strategies},
        }


def _filter_kwargs(fn, kwargs: dict) -> dict:
    """Keep only the kwargs ``fn``'s signature accepts."""
    params = inspect.signature(fn).parameters
    if any(p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values()):
        return kwargs
    return {k: v for k, v in kwargs.items() if k in params}


class Planner:
    """Resolve strategy names once; compile graphs + clusters into ``Plan``s.

    One planner instance is shared by a ``Dispatcher``/``ControlPlane`` and
    reused across reconfigurations; per-call ``seed`` overrides keep the
    dispatcher's probe-noise RNG stream in charge of placement randomness
    (exactly the pre-API behavior, which the parity regression test pins).
    """

    def __init__(
        self,
        partitioner: str | None = None,
        placer: str | None = None,
        joint: str | None = None,
        *,
        n_classes: int | None = 4,
        seed: int = 0,
    ):
        self.partitioner = get_strategy(
            "partitioner", partitioner or default_strategy("partitioner"))
        self.placer = get_strategy("placer", placer or default_strategy("placer"))
        self.joint = get_strategy("joint", joint) if joint is not None else None
        self.n_classes = n_classes
        self.seed = seed

    @classmethod
    def from_spec(cls, spec: "DeploymentSpec") -> "Planner":
        return cls(
            partitioner=spec.partitioner,
            placer=spec.placer,
            joint=spec.joint,
            n_classes=spec.n_classes,
            seed=spec.seed,
        )

    def strategy_names(self) -> tuple[tuple[str, str], ...]:
        """The strategies that actually plan: a joint optimizer REPLACES the
        partitioner+placer pipeline, so only it is reported when set."""
        if self.joint is not None:
            return (("joint", self.joint.name),)
        return (("partitioner", self.partitioner.name),
                ("placer", self.placer.name))

    # -- core compilation ----------------------------------------------------
    def plan(
        self,
        graph: LayerGraph,
        comm: CommGraph,
        *,
        capacity: float | None = None,
        version: int = 0,
        max_parts: int | None = None,
        seed: int | None = None,
        include_dispatcher: bool = True,
        dispatcher: int | None = None,
        device_flops: float | Sequence[float] | None = None,
        compression_ratio: float = 1.0,
    ) -> Plan:
        """Partition + place ``graph`` on ``comm``; score the result.

        ``capacity`` defaults to the cluster's max node capacity.  ``seed``
        overrides the planner's own (the dispatcher threads its RNG stream
        through here).  With a joint strategy set, partitioning and placement
        are solved together and the partitioner/placer names are ignored.
        """
        if seed is None:
            seed = self.seed
        cap = capacity if capacity is not None else float(max(comm.node_capacity))
        in_bytes = graph.in_bytes if include_dispatcher else 0.0
        out_bytes = graph.layers[-1].out_bytes if include_dispatcher else 0.0

        if self.joint is not None:
            res = self.joint.fn(
                graph, comm, int(cap),
                **_filter_kwargs(self.joint.fn, dict(
                    n_classes=self.n_classes, seed=seed, max_parts=max_parts,
                    include_dispatcher=include_dispatcher, dispatcher=dispatcher,
                )),
            )
            part, place = res.partition, res.placement
        else:
            part = self.partitioner.fn(
                graph, int(cap),
                **_filter_kwargs(self.partitioner.fn, dict(max_parts=max_parts)),
            )
            if not part.feasible:
                return Plan(version, part,
                            PlacementResult(False, (), float("inf"), "n/a"),
                            strategies=self.strategy_names())
            place = self.place(
                part.boundaries, [p.param_bytes for p in part.partitions], comm,
                seed=seed, in_bytes=in_bytes, out_bytes=out_bytes,
                dispatcher=dispatcher,
            )

        if not (part.feasible and place.feasible):
            return Plan(version, part, place, strategies=self.strategy_names())
        metrics = evaluate_pipeline(
            part.partitions, place.path, comm,
            device_flops=device_flops, in_bytes=in_bytes, out_bytes=out_bytes,
            dispatcher=dispatcher, compression_ratio=compression_ratio,
        )
        return Plan(
            version, part, place,
            predicted_bottleneck_s=float(place.bottleneck_latency),
            predicted_throughput=float(metrics.effective_throughput),
            strategies=self.strategy_names(),
        )

    def place(
        self,
        boundaries,
        part_bytes,
        comm: CommGraph,
        *,
        seed: int | None = None,
        in_bytes: float = 0.0,
        out_bytes: float = 0.0,
        dispatcher: int | None = None,
    ) -> PlacementResult:
        """Placement only -- the dispatcher's re-placement (recovery) path."""
        if seed is None:
            seed = self.seed
        return self.placer.fn(
            boundaries, part_bytes, comm,
            **_filter_kwargs(self.placer.fn, dict(
                n_classes=self.n_classes, seed=seed,
                in_bytes=in_bytes, out_bytes=out_bytes, dispatcher=dispatcher,
            )),
        )

    # -- spec front door -----------------------------------------------------
    def compile(self, spec: "DeploymentSpec", *, version: int = 0) -> Plan:
        """Validate a spec, build its cluster, plan, and enforce SLOs.

        Raises ``InfeasibleSpecError`` (with structured reasons) on a bad
        spec, an infeasible plan, or a missed SLO.  This is the pure-planning
        entry point; ``api.deploy`` adds the serving stack on top.
        """
        from repro.api.spec import InfeasibleSpecError

        spec.check()
        graph = spec.graph()
        comm, _ = spec.cluster.build()
        # mirror Dispatcher.configure at bootstrap (all nodes healthy, leader
        # = lowest id = 0, dispatcher round-trip always scored) so the pure
        # planning answer agrees with what deploy() would deploy -- modulo
        # probe noise, which only deploy() sees
        plan = self.plan(
            graph, comm,
            capacity=spec.capacity, version=version, max_parts=comm.n,
            dispatcher=0,
            include_dispatcher=True,
            compression_ratio=spec.compression_ratio,
        )
        issues = plan.slo_issues(spec)
        if issues:
            raise InfeasibleSpecError(issues)
        return plan
