"""``Planner``: compile a spec (or a raw graph + cluster) into a ``Plan``.

The planner is the *policy-free* middle of the API: it resolves strategy
names through the registry, runs partition -> placement (or a joint
optimizer), and scores the result with the simulator's pipeline metrics --
no cluster machinery, no pods.  ``Plan`` subsumes the old
``dispatcher.DeploymentPlan`` (same ``version``/``partition``/``placement``
fields, so ``Dispatcher.deploy`` consumes it unchanged) and adds the
predicted bottleneck/throughput plus the strategy names that produced it.

Strategy functions keep their natural signatures; the planner passes each
one only the keyword arguments it accepts (``inspect.signature``-filtered),
so e.g. ``place_greedy`` never sees ``n_classes`` and ``place_random``
still gets its ``seed``.
"""

from __future__ import annotations

import dataclasses
import inspect
from typing import TYPE_CHECKING, Sequence

import numpy as np

from repro.api.registry import default_strategy, get_strategy
from repro.core.bottleneck import evaluate_pipeline
from repro.core.graph import LayerGraph
from repro.core.partitioner import PartitionResult
from repro.core.placement import CommGraph, PlacementResult

if TYPE_CHECKING:
    from repro.api.spec import DeploymentSpec, SpecIssue


@dataclasses.dataclass(frozen=True)
class Plan:
    """A compiled deployment: partition + placement + predicted metrics.

    Drop-in for the old ``dispatcher.DeploymentPlan`` (which is now an alias
    of this class): ``Dispatcher.deploy`` reads ``version``, ``partition``,
    ``placement``, ``feasible``.
    """

    version: int
    partition: PartitionResult
    placement: PlacementResult
    # the placement objective: max link latency on UNCOMPRESSED boundaries
    predicted_bottleneck_s: float = float("inf")
    # 1 / pipeline period, codec-, compression- and compute-aware
    predicted_throughput: float = 0.0
    strategies: tuple[tuple[str, str], ...] = ()  # (kind, name) pairs
    # transfer codec per hop (len n_parts + 1); () = all-identity legacy plan
    codecs: tuple[str, ...] = ()

    @property
    def feasible(self) -> bool:
        return self.partition.feasible and self.placement.feasible

    @property
    def n_parts(self) -> int:
        return self.partition.n_parts

    @property
    def path(self) -> tuple[int, ...]:
        return self.placement.path

    def strategy(self, kind: str) -> str | None:
        return dict(self.strategies).get(kind)

    def slo_issues(self, spec: "DeploymentSpec") -> tuple["SpecIssue", ...]:
        """Check the plan's predictions against the spec's SLOs."""
        from repro.api.spec import SpecIssue

        issues = []
        if not self.feasible:
            issues.append(SpecIssue(
                "infeasible_plan",
                f"{self.partition.algorithm}/{self.placement.algorithm} found "
                f"no feasible partition+placement on this cluster",
            ))
            return tuple(issues)
        if (spec.max_bottleneck_s is not None
                and self.predicted_bottleneck_s > spec.max_bottleneck_s):
            issues.append(SpecIssue(
                "slo_bottleneck",
                f"predicted bottleneck {self.predicted_bottleneck_s:.3e} s "
                f"exceeds the max_bottleneck_s SLO {spec.max_bottleneck_s:.3e} s",
            ))
        if (spec.min_throughput is not None
                and self.predicted_throughput < spec.min_throughput):
            issues.append(SpecIssue(
                "slo_throughput",
                f"predicted throughput {self.predicted_throughput:.3e}/s is "
                f"below the min_throughput SLO {spec.min_throughput:.3e}/s",
            ))
        return tuple(issues)

    def summary(self) -> dict:
        """JSON-ready description (stored by the dispatcher, logged by benches)."""
        return {
            "version": self.version,
            "feasible": self.feasible,
            "cuts": list(self.partition.cuts),
            "path": list(self.placement.path),
            "bottleneck_latency": self.placement.bottleneck_latency,
            "predicted_bottleneck_s": self.predicted_bottleneck_s,
            "predicted_throughput": self.predicted_throughput,
            "algorithm": self.placement.algorithm,
            "strategies": {k: v for k, v in self.strategies},
            "codecs": list(self.codecs),
        }


def _filter_kwargs(fn, kwargs: dict) -> dict:
    """Keep only the kwargs ``fn``'s signature accepts."""
    params = inspect.signature(fn).parameters
    if any(p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values()):
        return kwargs
    return {k: v for k, v in kwargs.items() if k in params}


class PlanCache:
    """Memo for the expensive per-plan sublattices, keyed by (cluster
    generation / comm digest, spec knobs).

    ``replicas="auto"`` re-plans R candidate splits and every recovery
    re-solves placement on every churn event; without the cache each of
    those recomputes the bandwidth quantization, the cluster split, and the
    probe-derived inputs from scratch.  Entries are keyed on explicit
    content keys (``CommGraph.key()`` digests, ``EdgeCluster.generation``
    counters), so a stale hit is impossible as long as the key captures
    every input -- the property the planner call sites maintain.
    """

    def __init__(self, max_entries: int = 512):
        self.max_entries = int(max_entries)
        self._store: dict = {}
        self.hits = 0
        self.misses = 0

    def lookup(self, key, build):
        """Return the cached value for ``key``, building (and storing) it on
        a miss.  FIFO-evicts when full; a raising ``build`` caches nothing."""
        if key in self._store:
            self.hits += 1
            return self._store[key]
        value = build()
        self.misses += 1
        if len(self._store) >= self.max_entries:
            self._store.pop(next(iter(self._store)))
        self._store[key] = value
        return value

    def invalidate(self) -> None:
        self._store.clear()

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "entries": len(self._store)}


# ---------------------------------------------------------------------------
# Replica sets: disjoint sub-clusters, one pipeline each
# ---------------------------------------------------------------------------

def split_cluster(
    comm: CommGraph,
    n_replicas: int,
    *,
    dispatcher: int | None = None,
    nodes: Sequence[int] | None = None,
    targets: Sequence[int] | None = None,
) -> list[tuple[int, ...]]:
    """Partition the hosting nodes into ``n_replicas`` disjoint groups.

    Greedy bandwidth-aware split: seed one group per replica with mutually
    far-apart (low-bandwidth) nodes -- so each group can grow around a
    distinct well-connected neighbourhood -- then repeatedly attach the
    (node, group) pair with the highest mean bandwidth from the node to the
    group's members, keeping group sizes balanced (within one node).  The
    dispatcher node never joins a group; it is shared by every replica.

    ``targets`` overrides the balanced sizing with one node count per group
    (the tenancy scheduler's quota carve): group ``r`` stops growing at
    ``targets[r]`` members, and when the targets sum to fewer than the
    hosting nodes the leftovers stay ungrouped (spare capacity).

    Deterministic; raises ``ValueError`` when fewer hosting nodes than
    replicas are available or the targets cannot be honored.
    """
    hosting = [
        i for i in range(comm.n)
        if comm.node_capacity[i] > 0 and i != dispatcher
        and (nodes is None or i in set(nodes))
    ]
    if n_replicas < 1:
        raise ValueError("n_replicas must be >= 1")
    if n_replicas > len(hosting):
        raise ValueError(
            f"cannot split {len(hosting)} hosting node(s) into "
            f"{n_replicas} replica group(s)"
        )
    if targets is not None:
        targets = [int(t) for t in targets]
        if len(targets) != n_replicas:
            raise ValueError(
                f"targets has {len(targets)} entries for "
                f"{n_replicas} group(s)")
        if any(t < 1 for t in targets):
            raise ValueError("every group target must be >= 1")
        if sum(targets) > len(hosting):
            raise ValueError(
                f"targets sum to {sum(targets)} but only "
                f"{len(hosting)} hosting node(s) are available")
    if n_replicas == 1 and targets is None:
        return [tuple(hosting)]

    bw = comm.bw
    # seeds: farthest-point traversal on bandwidth (low bw = far), starting
    # from the best-connected node, so replica neighbourhoods don't overlap
    totals = {i: float(sum(bw[i, j] for j in hosting if j != i)) for i in hosting}
    first = max(hosting, key=lambda i: (totals[i], -i))
    seeds = [first]
    while len(seeds) < n_replicas:
        # the node whose strongest link INTO the seed set is weakest
        cand = max(
            (i for i in hosting if i not in seeds),
            key=lambda i: (-max(float(bw[i, s]) for s in seeds), totals[i], -i),
        )
        seeds.append(cand)

    if targets is None:
        base, extra = divmod(len(hosting), n_replicas)
        targets = [base + (1 if r < extra else 0) for r in range(n_replicas)]
    groups: list[list[int]] = [[s] for s in seeds]
    remaining = [i for i in hosting if i not in seeds]
    while remaining:
        best = None  # (score, -node, r, node)
        for r, g in enumerate(groups):
            if len(g) >= targets[r]:
                continue
            for i in remaining:
                score = float(np.mean([bw[i, j] for j in g]))
                key = (score, -i, -r)
                if best is None or key > best[0]:
                    best = (key, r, i)
        if best is None:
            break  # every group is at target; leftovers stay spare
        _, r, i = best
        groups[r].append(i)
        remaining.remove(i)
    return [tuple(sorted(g)) for g in groups]


def subcluster(
    comm: CommGraph, group: Sequence[int], *, keep: Sequence[int] = ()
) -> CommGraph:
    """A replica's view of the cluster: the group's nodes plus the shared
    dispatcher (``keep``).  Nodes outside the view lose links and capacity;
    kept-but-not-hosting nodes (the dispatcher) keep links only -- so a
    plan compiled on the sub-cluster can never place outside the group."""
    allowed = set(group) | set(keep)
    bw = comm.bw.copy()
    cap = comm.node_capacity.copy()
    group_set = set(group)
    for i in range(comm.n):
        if i not in allowed:
            bw[i, :] = 0.0
            bw[:, i] = 0.0
            cap[i] = 0.0
        elif i not in group_set:
            cap[i] = min(cap[i], 0.0)
    return CommGraph(bw=bw, node_capacity=cap)


@dataclasses.dataclass(frozen=True)
class ReplicatedPlan:
    """R per-replica ``Plan``s over disjoint node groups.

    The replicas are data-parallel copies of the same model, so the
    cluster-wide prediction is the *sum* of the per-replica throughputs,
    while the worst per-replica bottleneck bounds latency.
    """

    version: int
    replicas: tuple[Plan, ...]
    groups: tuple[tuple[int, ...], ...]
    requested: int | str = 1  # the spec's replicas field: R or "auto"

    @property
    def n_replicas(self) -> int:
        return len(self.replicas)

    @property
    def feasible(self) -> bool:
        return bool(self.replicas) and all(p.feasible for p in self.replicas)

    @property
    def predicted_throughput(self) -> float:
        return float(sum(p.predicted_throughput for p in self.replicas))

    @property
    def predicted_bottleneck_s(self) -> float:
        return float(max(
            (p.predicted_bottleneck_s for p in self.replicas),
            default=float("inf"),
        ))

    @property
    def strategies(self) -> tuple[tuple[str, str], ...]:
        return self.replicas[0].strategies if self.replicas else ()

    def slo_issues(self, spec: "DeploymentSpec") -> tuple["SpecIssue", ...]:
        """Aggregate SLO check: summed throughput, worst bottleneck."""
        from repro.api.spec import SpecIssue

        if not self.feasible:
            return (SpecIssue(
                "infeasible_replicas",
                f"no feasible plan for {self.requested!r} replica pipeline(s)",
            ),)
        issues = []
        if (spec.max_bottleneck_s is not None
                and self.predicted_bottleneck_s > spec.max_bottleneck_s):
            issues.append(SpecIssue(
                "slo_bottleneck",
                f"worst replica bottleneck {self.predicted_bottleneck_s:.3e} s "
                f"exceeds max_bottleneck_s {spec.max_bottleneck_s:.3e} s",
            ))
        if (spec.min_throughput is not None
                and self.predicted_throughput < spec.min_throughput):
            issues.append(SpecIssue(
                "slo_throughput",
                f"summed replica throughput {self.predicted_throughput:.3e}/s "
                f"is below min_throughput {spec.min_throughput:.3e}/s",
            ))
        return tuple(issues)

    def summary(self) -> dict:
        return {
            "version": self.version,
            "feasible": self.feasible,
            "n_replicas": self.n_replicas,
            "requested": self.requested,
            "groups": [list(g) for g in self.groups],
            "predicted_throughput": self.predicted_throughput,
            "predicted_bottleneck_s": self.predicted_bottleneck_s,
            "replicas": [p.summary() for p in self.replicas],
        }


class Planner:
    """Resolve strategy names once; compile graphs + clusters into ``Plan``s.

    One planner instance is shared by a ``Dispatcher``/``ControlPlane`` and
    reused across reconfigurations; per-call ``seed`` overrides keep the
    dispatcher's probe-noise RNG stream in charge of placement randomness
    (exactly the pre-API behavior, which the parity regression test pins).
    """

    def __init__(
        self,
        partitioner: str | None = None,
        placer: str | None = None,
        joint: str | None = None,
        *,
        n_classes: int | None = 4,
        seed: int = 0,
        codec: str | None = None,
        accuracy_tolerance: float | None = None,
        cache: PlanCache | None = None,
    ):
        from repro.dataplane import AUTO, default_codec, get_codec

        self.partitioner = get_strategy(
            "partitioner", partitioner or default_strategy("partitioner"))
        self.placer = get_strategy("placer", placer or default_strategy("placer"))
        self.joint = get_strategy("joint", joint) if joint is not None else None
        self.n_classes = n_classes
        self.seed = seed
        self.cache = cache if cache is not None else PlanCache()
        self.codec = codec or default_codec()
        if self.codec != AUTO:
            get_codec(self.codec)  # typos raise here, with suggestions
        self.accuracy_tolerance = accuracy_tolerance

    @classmethod
    def from_spec(cls, spec: "DeploymentSpec") -> "Planner":
        return cls(
            partitioner=spec.partitioner,
            placer=spec.placer,
            joint=spec.joint,
            n_classes=spec.n_classes,
            seed=spec.seed,
            codec=spec.codec,
            accuracy_tolerance=spec.accuracy_tolerance,
        )

    def strategy_names(self) -> tuple[tuple[str, str], ...]:
        """The strategies that actually plan: a joint optimizer REPLACES the
        partitioner+placer pipeline, so only it is reported when set."""
        if self.joint is not None:
            return (("joint", self.joint.name),)
        return (("partitioner", self.partitioner.name),
                ("placer", self.placer.name))

    # -- core compilation ----------------------------------------------------
    def plan(
        self,
        graph: LayerGraph,
        comm: CommGraph,
        *,
        capacity: float | None = None,
        version: int = 0,
        max_parts: int | None = None,
        seed: int | None = None,
        include_dispatcher: bool = True,
        dispatcher: int | None = None,
        device_flops: float | Sequence[float] | None = None,
        compression_ratio: float = 1.0,
    ) -> Plan:
        """Partition + place ``graph`` on ``comm``; score the result.

        ``capacity`` defaults to the cluster's max node capacity.  ``seed``
        overrides the planner's own (the dispatcher threads its RNG stream
        through here).  With a joint strategy set, partitioning and placement
        are solved together and the partitioner/placer names are ignored.
        """
        if seed is None:
            seed = self.seed
        cap = capacity if capacity is not None else float(max(comm.node_capacity))
        in_bytes = graph.in_bytes if include_dispatcher else 0.0
        out_bytes = graph.layers[-1].out_bytes if include_dispatcher else 0.0

        if self.joint is not None:
            res = self.joint.fn(
                graph, comm, int(cap),
                **_filter_kwargs(self.joint.fn, dict(
                    n_classes=self.n_classes, seed=seed, max_parts=max_parts,
                    include_dispatcher=include_dispatcher, dispatcher=dispatcher,
                )),
            )
            part, place = res.partition, res.placement
        else:
            part = self.partitioner.fn(
                graph, int(cap),
                **_filter_kwargs(self.partitioner.fn, dict(max_parts=max_parts)),
            )
            if not part.feasible:
                return Plan(version, part,
                            PlacementResult(False, (), float("inf"), "n/a"),
                            strategies=self.strategy_names())
            place = self.place(
                part.boundaries, [p.param_bytes for p in part.partitions], comm,
                seed=seed, in_bytes=in_bytes, out_bytes=out_bytes,
                dispatcher=dispatcher,
            )

        if not (part.feasible and place.feasible):
            return Plan(version, part, place, strategies=self.strategy_names())
        codecs = self.assign_codecs(
            [in_bytes, *(p.out_bytes for p in part.partitions[:-1]), out_bytes],
            place.path, comm.bw,
            dispatcher=dispatcher, flops_per_node=device_flops,
            compression_ratio=compression_ratio,
        )
        metrics = evaluate_pipeline(
            part.partitions, place.path, comm,
            device_flops=device_flops, in_bytes=in_bytes, out_bytes=out_bytes,
            dispatcher=dispatcher, compression_ratio=compression_ratio,
            codecs=codecs,
        )
        return Plan(
            version, part, place,
            predicted_bottleneck_s=float(place.bottleneck_latency),
            predicted_throughput=float(metrics.effective_throughput),
            strategies=self.strategy_names(),
            codecs=codecs,
        )

    def assign_codecs(
        self,
        hop_bytes,
        path,
        bw,
        *,
        dispatcher: int | None = None,
        flops_per_node=None,
        compression_ratio: float = 1.0,
    ) -> tuple[str, ...]:
        """Codec-per-hop for a placed pipeline, under this planner's codec
        config (a fixed name on every inter-stage hop, or the ``"auto"``
        per-link optimum within ``accuracy_tolerance``).  Also the recovery
        path's entry point: a re-placement changes the links, so the
        dispatcher re-runs the assignment for the new path."""
        from repro.dataplane import assign_link_codecs

        return assign_link_codecs(
            hop_bytes, path, bw,
            codec=self.codec, tolerance=self.accuracy_tolerance,
            flops_per_node=flops_per_node, dispatcher=dispatcher,
            compression_ratio=compression_ratio,
        )

    def place(
        self,
        boundaries,
        part_bytes,
        comm: CommGraph,
        *,
        seed: int | None = None,
        in_bytes: float = 0.0,
        out_bytes: float = 0.0,
        dispatcher: int | None = None,
    ) -> PlacementResult:
        """Placement only -- the dispatcher's re-placement (recovery) path."""
        if seed is None:
            seed = self.seed
        kwargs = dict(
            n_classes=self.n_classes, seed=seed,
            in_bytes=in_bytes, out_bytes=out_bytes, dispatcher=dispatcher,
        )
        params = inspect.signature(self.placer.fn).parameters
        if "quantized" in params:
            # the quantized bandwidth-class sublattice is pure in (comm,
            # n_classes): share it across the auto-replica R search and
            # every recovery re-solve on an unchanged comm
            from repro.core.placement import quantize_bandwidths

            kwargs["quantized"] = self.cache.lookup(
                ("quantize", comm.key(), self.n_classes),
                lambda: quantize_bandwidths(comm.bw, self.n_classes),
            )
        return self.placer.fn(
            boundaries, part_bytes, comm, **_filter_kwargs(self.placer.fn, kwargs),
        )

    # -- replica sets --------------------------------------------------------
    def plan_replicated(
        self,
        graph: LayerGraph,
        comm: CommGraph,
        *,
        replicas: int | str = 1,
        capacity: float | None = None,
        version: int = 0,
        seed: int | None = None,
        include_dispatcher: bool = True,
        dispatcher: int | None = None,
        device_flops: float | Sequence[float] | None = None,
        compression_ratio: float = 1.0,
    ) -> ReplicatedPlan:
        """Split the cluster into R disjoint sub-clusters and plan one
        pipeline per sub-cluster with the registered strategies.

        ``replicas="auto"`` searches R = 1..#hosting-nodes and keeps the R
        maximizing the summed predicted throughput (the depth-vs-width
        trade-off: more replicas means shallower per-replica clusters, so
        past some R a group can no longer host the model and the sum stops
        growing).  ``replicas="max"`` keeps the *widest* feasible split
        instead -- the autoscaler's planning mode, where every group is a
        unit of standby capacity and headroom beats day-one throughput.
        An explicit R returns that plan even when infeasible, so callers
        can surface *why*; ``"auto"``/``"max"`` return the best feasible
        candidate (falling back to the R=1 attempt when none is).
        """
        hosting = [
            i for i in range(comm.n)
            if comm.node_capacity[i] > 0 and i != dispatcher
        ]
        if replicas == "auto":
            candidates = range(1, max(1, len(hosting)) + 1)
        elif replicas == "max":
            # widest first: the first feasible candidate wins outright
            candidates = range(max(1, len(hosting)), 0, -1)
        else:
            candidates = [int(replicas)]
        def group_capacity(group) -> float:
            total = 0.0
            for i in group:
                c = float(comm.node_capacity[i])
                if capacity is not None:
                    c = min(c, float(capacity))
                total += max(c, 0.0)
            return total

        best: ReplicatedPlan | None = None
        fallback: ReplicatedPlan | None = None
        for n_rep in candidates:
            try:
                # the greedy split is pure in (comm, n_rep, dispatcher):
                # cache it so the "auto"/"max" R searches and the
                # autoscaler's repeated widest-split planning stop paying it
                groups = self.cache.lookup(
                    ("split", comm.key(), n_rep, dispatcher),
                    lambda: split_cluster(comm, n_rep, dispatcher=dispatcher),
                )
            except ValueError:
                # more groups than hosting nodes: infeasible, not a crash --
                # deploy() surfaces it as a structured InfeasibleSpecError
                continue
            if replicas in ("auto", "max") and any(
                group_capacity(g) < graph.total_param_bytes for g in groups
            ):
                continue  # cheap prune: some group cannot hold the model
            keep = () if dispatcher is None else (dispatcher,)
            plans = []
            for g in groups:
                sub = self.cache.lookup(
                    ("subcluster", comm.key(), tuple(g), keep),
                    lambda: subcluster(comm, g, keep=keep),
                )
                cap = capacity
                if cap is None:
                    cap = float(max(sub.node_capacity[list(g)], default=0.0))
                plans.append(self.plan(
                    graph, sub,
                    capacity=cap, version=version, max_parts=len(g),
                    seed=seed, include_dispatcher=include_dispatcher,
                    dispatcher=dispatcher, device_flops=device_flops,
                    compression_ratio=compression_ratio,
                ))
            cand = ReplicatedPlan(
                version=version, replicas=tuple(plans),
                groups=tuple(groups), requested=replicas,
            )
            if fallback is None:
                fallback = cand
            if not cand.feasible:
                continue
            if replicas == "max":
                return cand  # widest feasible split, by candidate order
            if best is None or cand.predicted_throughput > best.predicted_throughput:
                best = cand
        if best is not None:
            return best
        if fallback is not None:
            return fallback
        return ReplicatedPlan(version=version, replicas=(), groups=(),
                              requested=replicas)

    # -- spec front door -----------------------------------------------------
    def compile(self, spec: "DeploymentSpec", *, version: int = 0) -> Plan:
        """Validate a spec, build its cluster, plan, and enforce SLOs.

        Raises ``InfeasibleSpecError`` (with structured reasons) on a bad
        spec, an infeasible plan, or a missed SLO.  This is the pure-planning
        entry point; ``api.deploy`` adds the serving stack on top.
        """
        from repro.api.spec import InfeasibleSpecError

        spec.check()
        graph = spec.graph()
        comm, _ = spec.cluster.build()
        # mirror Dispatcher.configure at bootstrap (all nodes healthy, leader
        # = lowest id = 0, dispatcher round-trip always scored) so the pure
        # planning answer agrees with what deploy() would deploy -- modulo
        # probe noise, which only deploy() sees
        plan = self.plan(
            graph, comm,
            capacity=spec.capacity, version=version, max_parts=comm.n,
            dispatcher=0,
            include_dispatcher=True,
            compression_ratio=spec.compression_ratio,
        )
        issues = plan.slo_issues(spec)
        if issues:
            raise InfeasibleSpecError(issues)
        return plan
