"""Strategy registry: named, discoverable partition/placement/joint algorithms.

Every algorithm that the declarative API can invoke self-registers here by
name via the ``@register_strategy(kind, name)`` decorator applied at its
definition site (``core/partitioner.py``, ``core/placement.py``,
``core/joint.py``).  The registry is the single source of truth for

  * which strategies exist (``list_strategies(kind)``),
  * which one a ``DeploymentSpec`` means by a name (``get_strategy``), and
  * what runs when no name is given (``default_strategy`` -- the paper's
    pipeline: ``min_bottleneck`` partitioning + ``color_coding`` placement).

Unknown names raise ``UnknownStrategyError`` carrying did-you-mean
suggestions, so a typo in a spec fails at validation time with a readable
message instead of deep inside placement.

This module deliberately imports nothing from ``repro.core`` -- the core
algorithm modules import *it* to self-register, and ``_ensure_registered``
imports them lazily on first lookup so ``list_strategies`` works no matter
which side was imported first.
"""

from __future__ import annotations

import dataclasses
import difflib
from typing import Callable

KINDS = ("partitioner", "placer", "joint")


@dataclasses.dataclass(frozen=True)
class Strategy:
    """One registered algorithm: callable + metadata for specs/docs/CLI."""

    kind: str
    name: str
    fn: Callable
    description: str = ""
    default: bool = False

    def __call__(self, *args, **kwargs):
        return self.fn(*args, **kwargs)


class UnknownStrategyError(KeyError):
    """Raised for a name not in the registry; carries suggestions."""

    def __init__(self, kind: str, name: str, known: tuple[str, ...]):
        self.kind = kind
        self.name = name
        self.known = known
        self.suggestions = tuple(
            difflib.get_close_matches(name, known, n=3, cutoff=0.4)
        )
        msg = f"unknown {kind} strategy {name!r}; registered: {', '.join(known)}"
        if self.suggestions:
            msg += f" (did you mean {' or '.join(map(repr, self.suggestions))}?)"
        super().__init__(msg)

    def __str__(self) -> str:  # KeyError.__str__ repr-quotes; keep it readable
        return self.args[0]


_REGISTRY: dict[str, dict[str, Strategy]] = {kind: {} for kind in KINDS}
_DEFAULTS: dict[str, str] = {}


def register_strategy(
    kind: str, name: str, *, default: bool = False, description: str = ""
) -> Callable[[Callable], Callable]:
    """Decorator: register ``fn`` as the ``kind`` strategy called ``name``."""
    if kind not in KINDS:
        raise ValueError(f"unknown strategy kind {kind!r}; one of {KINDS}")

    def deco(fn: Callable) -> Callable:
        if name in _REGISTRY[kind]:
            raise ValueError(f"duplicate {kind} strategy {name!r}")
        _REGISTRY[kind][name] = Strategy(kind, name, fn, description, default)
        if default:
            prior = _DEFAULTS.get(kind)
            if prior is not None and prior != name:
                raise ValueError(f"two defaults for {kind}: {prior!r}, {name!r}")
            _DEFAULTS[kind] = name
        return fn

    return deco


def _ensure_registered() -> None:
    """Import the algorithm modules so their decorators have run."""
    import repro.core.joint  # noqa: F401
    import repro.core.partitioner  # noqa: F401
    import repro.core.placement  # noqa: F401


def get_strategy(kind: str, name: str) -> Strategy:
    """Look up a strategy by name; unknown names raise with suggestions."""
    if kind not in KINDS:
        raise ValueError(f"unknown strategy kind {kind!r}; one of {KINDS}")
    _ensure_registered()
    try:
        return _REGISTRY[kind][name]
    except KeyError:
        raise UnknownStrategyError(kind, name, list_strategies(kind)) from None


def list_strategies(kind: str) -> tuple[str, ...]:
    """Registered names for one kind, sorted (default first)."""
    if kind not in KINDS:
        raise ValueError(f"unknown strategy kind {kind!r}; one of {KINDS}")
    _ensure_registered()
    names = sorted(_REGISTRY[kind])
    dflt = _DEFAULTS.get(kind)
    if dflt in names:
        names.remove(dflt)
        names.insert(0, dflt)
    return tuple(names)


def default_strategy(kind: str) -> str:
    """The name used when a spec leaves the strategy unset."""
    if kind not in KINDS:
        raise ValueError(f"unknown strategy kind {kind!r}; one of {KINDS}")
    _ensure_registered()
    return _DEFAULTS[kind]


def strategy_table() -> list[dict[str, str]]:
    """All registered strategies as rows (kind/name/default/description)."""
    _ensure_registered()
    rows = []
    for kind in KINDS:
        for name in list_strategies(kind):
            s = _REGISTRY[kind][name]
            rows.append(
                {
                    "kind": kind,
                    "name": name,
                    "default": "yes" if s.default else "",
                    "description": s.description,
                }
            )
    return rows
