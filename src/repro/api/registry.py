"""Strategy registry: named, discoverable partition/placement/joint algorithms.

Every algorithm that the declarative API can invoke self-registers here by
name via the ``@register_strategy(kind, name)`` decorator applied at its
definition site (``core/partitioner.py``, ``core/placement.py``,
``core/joint.py``).  The registry is the single source of truth for

  * which strategies exist (``list_strategies(kind)``),
  * which one a ``DeploymentSpec`` means by a name (``get_strategy``), and
  * what runs when no name is given (``default_strategy`` -- the paper's
    pipeline: ``min_bottleneck`` partitioning + ``color_coding`` placement).

Unknown names raise ``UnknownStrategyError`` carrying did-you-mean
suggestions, so a typo in a spec fails at validation time with a readable
message instead of deep inside placement.

The table mechanics (defaults-first listing, duplicate rejection, lazy
import of the registering modules, suggestion rendering) live in the shared
``repro.core.registry`` helper; this module keeps the strategy-specific
surface: the ``kind`` axis, the ``Strategy`` dataclass, and the historical
error type and message format.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core.registry import (
    Registry,
    UnknownNameError,
    suggest,
    unknown_message,
)

KINDS = ("partitioner", "placer", "joint")


@dataclasses.dataclass(frozen=True)
class Strategy:
    """One registered algorithm: callable + metadata for specs/docs/CLI."""

    kind: str
    name: str
    fn: Callable
    description: str = ""
    default: bool = False

    def __call__(self, *args, **kwargs):
        return self.fn(*args, **kwargs)


class UnknownStrategyError(UnknownNameError):
    """Raised for a name not in the registry; carries suggestions."""

    def __init__(self, kind: str, name: str, known: tuple[str, ...]):
        suggestions = suggest(name, known)
        super().__init__(
            unknown_message(f"{kind} strategy", name, known, suggestions),
            name=name, known=known, suggestions=suggestions,
        )
        self.kind = kind


def _ensure_registered() -> None:
    """Import the algorithm modules so their decorators have run."""
    import repro.core.joint  # noqa: F401
    import repro.core.partitioner  # noqa: F401
    import repro.core.placement  # noqa: F401


_REGISTRIES: dict[str, Registry] = {
    kind: Registry(
        f"{kind} strategy",
        ensure=_ensure_registered,
        error=lambda name, known, kind=kind: UnknownStrategyError(
            kind, name, known),
    )
    for kind in KINDS
}


def _registry(kind: str) -> Registry:
    if kind not in KINDS:
        raise ValueError(f"unknown strategy kind {kind!r}; one of {KINDS}")
    return _REGISTRIES[kind]


def register_strategy(
    kind: str, name: str, *, default: bool = False, description: str = ""
) -> Callable[[Callable], Callable]:
    """Decorator: register ``fn`` as the ``kind`` strategy called ``name``."""
    reg = _registry(kind)

    def deco(fn: Callable) -> Callable:
        reg.register(name, Strategy(kind, name, fn, description, default),
                     default=default)
        return fn

    return deco


def get_strategy(kind: str, name: str) -> Strategy:
    """Look up a strategy by name; unknown names raise with suggestions."""
    return _registry(kind).get(name)


def list_strategies(kind: str) -> tuple[str, ...]:
    """Registered names for one kind, sorted (default first)."""
    return _registry(kind).names()


def default_strategy(kind: str) -> str:
    """The name used when a spec leaves the strategy unset."""
    return _registry(kind).default()


def strategy_table() -> list[dict[str, str]]:
    """All registered strategies as rows (kind/name/default/description)."""
    rows = []
    for kind in KINDS:
        reg = _registry(kind)
        for name in reg.names():
            s = reg.get(name)
            rows.append(
                {
                    "kind": kind,
                    "name": name,
                    "default": "yes" if s.default else "",
                    "description": s.description,
                }
            )
    return rows
