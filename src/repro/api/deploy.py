"""``deploy(spec) -> Deployment``: the one-call serving facade.

Before the declarative API, standing up a SEIFER deployment meant hand-wiring
six objects (``LayerGraph`` -> ``EdgeCluster`` -> ``ArtifactStore`` ->
``ControlPlane`` -> bootstrap -> ``ServingLoop``), repeated in every example
and benchmark.  ``deploy()`` collapses that to one call: it validates the
spec, materializes the cluster, bootstraps the control plane through the
spec's strategies (Sec. 2.1-2.2: elect -> probe -> partition -> place ->
deploy), and wraps serving + churn + strategy-swap behind a ``Deployment``:

  * ``submit(x)`` / ``step()`` / ``drain()`` -- request-level serving,
  * ``inject(event)`` / ``reconcile()``     -- churn + convergence (Sec. 2.3),
  * ``replan(partitioner=..., placer=...)``  -- swap strategies on a LIVE
    deployment (probed bandwidths and generation reused),
  * ``metrics()``                            -- predicted vs. observed
    bottleneck, serving counters, reconcile history.
"""

from __future__ import annotations

import sys
import tempfile
from typing import Any

import numpy as np

from repro.api.planner import Plan, Planner
from repro.api.spec import DeploymentSpec
from repro.cluster.controlplane import ControlPlane, ObservedState, ReconcileAction
from repro.cluster.engine import PipelinedServingLoop
from repro.cluster.events import ClusterEvent, NodeJoined
from repro.cluster.lifecycle import EdgeCluster
from repro.cluster.serving import Request, ServingLoop
from repro.cluster.store import ArtifactStore
from repro.cluster.watch import ModelWatcher


def _passthrough_executor(start: int, stop: int, x):
    """Timing-only serving: latency still comes from bytes/bandwidth+flops."""
    return x


def deploy(
    spec: DeploymentSpec,
    *,
    store_root: str | None = None,
    version: int = 0,
    flops_per_s: float = 1e9,
) -> "Deployment":
    """Validate ``spec``, build the stack, bootstrap, return the facade.

    Raises ``InfeasibleSpecError`` with structured reasons when the spec
    cannot deploy (unknown strategy, layer over capacity, missed SLO, ...).
    """
    spec.check()
    graph, model_executor = spec.resolve_model()
    comm, positions = spec.cluster.build()
    executor_for_version = (
        spec.executor_for_version or model_executor or
        (lambda v: _passthrough_executor)
    )
    cluster = EdgeCluster(comm, flops_per_s=flops_per_s)
    store = ArtifactStore(
        store_root if store_root is not None
        else tempfile.mkdtemp(prefix="seifer-deploy-")
    )
    control = ControlPlane(
        cluster, store,
        lambda v: graph, executor_for_version,
        planner=Planner.from_spec(spec),
        capacity=spec.capacity, compression_ratio=spec.compression_ratio,
        seed=spec.seed,
    )
    control.bootstrap(version)
    dep = Deployment(spec, control, positions=positions)
    dep._check_slos()
    return dep


class Deployment:
    """A live deployment: serving loop + control plane + strategy registry.

    Constructed by ``deploy()``; everything the five old wiring copies did by
    hand is a method here.
    """

    def __init__(
        self,
        spec: DeploymentSpec,
        control: ControlPlane,
        *,
        positions: np.ndarray | None = None,
    ):
        self.spec = spec
        self.control = control
        if spec.serving == "sync":
            self.loop = ServingLoop(control, microbatch=spec.microbatch)
        else:
            self.loop = PipelinedServingLoop(
                control, microbatch=spec.microbatch,
                queue_depth=spec.queue_depth,
            )
        self.watcher = ModelWatcher(control.store)
        self.positions = positions  # node positions for random clusters (growth)

    # -- introspection -------------------------------------------------------
    @property
    def plan(self) -> Plan:
        """The most recent feasible plan the control plane deployed."""
        return self.control.last_plan

    @property
    def cluster(self) -> EdgeCluster:
        return self.control.cluster

    @property
    def store(self) -> ArtifactStore:
        return self.control.store

    def observed(self) -> ObservedState:
        return self.control.observed()

    # -- serving -------------------------------------------------------------
    def submit(self, x: Any) -> Request:
        """Admit one inference request."""
        return self.loop.submit(x)

    def step(self) -> list[Request]:
        """One admission round (reconciles pending events first)."""
        return self.loop.step()

    def drain(self, max_rounds: int = 10_000) -> list[Request]:
        """Serve until the queue empties; returns the completed requests."""
        return self.loop.drain(max_rounds=max_rounds)

    # -- churn + convergence -------------------------------------------------
    def inject(self, event: ClusterEvent) -> None:
        """Enqueue a cluster disturbance; ``reconcile()`` converges on it."""
        self.control.submit(event)

    def reconcile(self) -> list[ReconcileAction]:
        """Drain the event queue and converge observed -> desired state."""
        return self.control.reconcile()

    def poll_model_updates(self) -> bool:
        """Watch tick: emit ``VersionBumped`` if the store moved past us."""
        return self.watcher.poll_events(self.control)

    def grow_cluster(self, seed: int = 0) -> NodeJoined:
        """Convenience churn: add one random node (full-restart event).

        Only available for random clusters (the spec kept the positions);
        returns the injected ``NodeJoined`` event -- call ``reconcile()``
        (or keep serving) to converge.
        """
        if self.positions is None:
            raise RuntimeError(
                "grow_cluster() needs a position-seeded random cluster; "
                "inject NodeJoined(comm=...) yourself for explicit CommGraphs"
            )
        from repro.core.simulate import expand_cluster

        arena = self.spec.cluster.arena_m
        cap = self.spec.cluster.capacity_bytes
        grown, self.positions = expand_cluster(self.positions, cap, arena, seed)
        event = NodeJoined(comm=grown)
        self.inject(event)
        return event

    # -- strategy swap -------------------------------------------------------
    def replan(
        self,
        *,
        partitioner: str | None = None,
        placer: str | None = None,
        joint: str | None = None,
    ) -> Plan:
        """Swap strategies on the live deployment and redeploy in place.

        Unset kinds keep their current strategy, with one asymmetry: naming
        a ``partitioner`` or ``placer`` switches a joint-optimized deployment
        back to the two-step pipeline (a joint strategy *replaces* that
        pipeline, so keeping it would make the swap a silent no-op).  The
        running pipeline is only replaced if the new plan is feasible.
        """
        current = self.control.planner
        if joint is not None:
            new_joint = joint
        elif partitioner is not None or placer is not None:
            new_joint = None  # explicit pipeline strategies drop the joint
        else:
            new_joint = current.joint.name if current.joint else None
        planner = Planner(
            partitioner=partitioner or current.partitioner.name,
            placer=placer or current.placer.name,
            joint=new_joint,
            n_classes=current.n_classes,
            seed=current.seed,
        )
        return self.control.replan(planner)

    # -- metrics -------------------------------------------------------------
    def metrics(self) -> dict:
        """Predicted vs. observed placement quality + serving counters."""
        obs = self.observed()
        plan = self.plan
        out = {
            "version": obs.version,
            "generation": obs.generation,
            "leader": obs.leader,
            "path": list(obs.path),
            "n_nodes": obs.n_nodes,
            "healthy": obs.healthy,
            "bottleneck_latency_s": obs.bottleneck_latency,
            "strategies": dict(plan.strategies) if plan else {},
            "predicted_bottleneck_s": plan.predicted_bottleneck_s if plan else None,
            "predicted_throughput": plan.predicted_throughput if plan else None,
            "reconcile_actions": [a.kind for a in self.control.history],
            "serving": self.loop.metrics(),
        }
        return out

    def _check_slos(self) -> None:
        """SLOs re-checked on the as-deployed plan (probed bandwidths)."""
        from repro.api.spec import InfeasibleSpecError

        issues = self.plan.slo_issues(self.spec)
        if issues:
            raise InfeasibleSpecError(issues)


# The function and this module share the name "deploy", and a prior
# ``import repro.api.deploy`` binds the MODULE onto the package before the
# package's lazy __getattr__ can pin the function -- so make the module
# itself callable; either object a caller ends up with deploys the spec.
class _CallableDeployModule(sys.modules[__name__].__class__):
    def __call__(self, *args, **kwargs):
        return deploy(*args, **kwargs)


sys.modules[__name__].__class__ = _CallableDeployModule
