"""``deploy(spec) -> Deployment``: the one-call serving facade.

Before the declarative API, standing up a SEIFER deployment meant hand-wiring
six objects (``LayerGraph`` -> ``EdgeCluster`` -> ``ArtifactStore`` ->
``ControlPlane`` -> bootstrap -> ``ServingLoop``), repeated in every example
and benchmark.  ``deploy()`` collapses that to one call: it validates the
spec, materializes the cluster, bootstraps the control plane through the
spec's strategies (Sec. 2.1-2.2: elect -> probe -> partition -> place ->
deploy), and wraps serving + churn + strategy-swap behind a ``Deployment``:

  * ``submit(x)`` / ``step()`` / ``drain()`` -- request-level serving,
  * ``inject(event)`` / ``reconcile()``     -- churn + convergence (Sec. 2.3),
  * ``replan(partitioner=..., placer=...)``  -- swap strategies on a LIVE
    deployment (probed bandwidths and generation reused),
  * ``metrics()``                            -- predicted vs. observed
    bottleneck, serving counters, reconcile history.
"""

from __future__ import annotations

import sys
import tempfile
from typing import Any

import numpy as np

from repro.api.planner import Plan, Planner, ReplicatedPlan, subcluster
from repro.api.spec import DeploymentSpec, InfeasibleSpecError, SpecIssue
from repro.cluster.controlplane import (
    ControlPlane,
    ObservedState,
    ReconcileAction,
    ReplicaSet,
)
from repro.cluster.engine import PipelinedServingLoop, ReplicatedServingLoop
from repro.cluster.events import ClusterEvent, NodeJoined, VersionBumped
from repro.cluster.lifecycle import EdgeCluster
from repro.cluster.serving import Request, ServingLoop
from repro.cluster.store import ArtifactStore
from repro.cluster.watch import ModelWatcher
from repro.obs import Journal, MetricsRegistry, SpanTracer, analyze_spans


def _passthrough_executor(start: int, stop: int, x):
    """Timing-only serving: latency still comes from bytes/bandwidth+flops."""
    return x


def deploy(
    spec: DeploymentSpec,
    *,
    store_root: str | None = None,
    version: int = 0,
    flops_per_s: float = 1e9,
    **tenancy_kw,
) -> "Deployment":
    """Validate ``spec``, build the stack, bootstrap, return the facade.

    Raises ``InfeasibleSpecError`` with structured reasons when the spec
    cannot deploy (unknown strategy, layer over capacity, missed SLO, ...).

    A *list* of specs (``DeploymentSpec`` or ``TenantSpec``) deploys every
    tenant onto ONE shared cluster and returns a ``MultiTenantDeployment``
    (``repro.tenancy``): the tenancy scheduler carves the hosting nodes
    under per-tenant capacity fractions, and churn on one tenant's nodes
    never perturbs another's pipelines.
    """
    if isinstance(spec, (list, tuple)):
        from repro.tenancy import deploy_tenants

        return deploy_tenants(
            spec, store_root=store_root, version=version,
            flops_per_s=flops_per_s, **tenancy_kw,
        )
    if tenancy_kw:
        raise TypeError(
            f"unexpected keyword(s) {sorted(tenancy_kw)} -- tenancy options "
            f"apply only when deploying a list of specs")
    spec.check()
    graph, model_executor = spec.resolve_model()
    comm, positions = spec.cluster.build()
    executor_for_version = (
        spec.executor_for_version or model_executor or
        (lambda v: _passthrough_executor)
    )
    cluster = EdgeCluster(comm, flops_per_s=flops_per_s)
    store = ArtifactStore(
        store_root if store_root is not None
        else tempfile.mkdtemp(prefix="seifer-deploy-")
    )
    return _build_deployment(
        spec, graph, executor_for_version, cluster, store, positions,
        version=version, flops_per_s=flops_per_s,
    )


def _build_deployment(
    spec: DeploymentSpec,
    graph,
    executor_for_version,
    cluster: EdgeCluster,
    store: ArtifactStore,
    positions,
    *,
    version: int,
    flops_per_s: float,
    nodes=None,
    seed_offset: int = 0,
    journal: Journal | None = None,
    source_prefix: str = "",
) -> "Deployment":
    """Bootstrap one deployment's control + serving stack on ``cluster``.

    ``nodes`` restricts planning and placement to a hosting-node subset
    (the tenancy scheduler's carve): plans are compiled on the subset's
    ``subcluster`` view and every control plane is masked to it, so the
    deployment can never place -- or be perturbed -- outside its slice.
    ``seed_offset`` keeps per-tenant probe-noise streams distinct.
    ``journal``/``source_prefix`` let the tenancy layer share ONE
    control-plane journal across tenants (records keyed ``<tenant>/...``).
    """
    comm = cluster.comm
    if journal is None:
        journal = Journal()
    if spec.autoscale is not None:
        return _deploy_autoscaled(
            spec, graph, executor_for_version, cluster, store, positions,
            version=version, flops_per_s=flops_per_s,
            nodes=nodes, seed_offset=seed_offset,
            journal=journal, source_prefix=source_prefix,
        )
    view = comm if nodes is None else subcluster(comm, nodes, keep=(0,))
    rplan = None
    if spec.replicas != 1:
        # split the cluster BEFORE any probing: groups are decided on the
        # true bandwidths, each replica then bootstraps within its group
        rplan = Planner.from_spec(spec).plan_replicated(
            graph, view,
            replicas=spec.replicas, capacity=spec.capacity, version=version,
            dispatcher=0, device_flops=flops_per_s,
            compression_ratio=spec.compression_ratio,
        )
        if not rplan.feasible:
            raise InfeasibleSpecError((SpecIssue(
                "infeasible_replicas",
                f"could not plan {spec.replicas!r} replica pipeline(s) on "
                f"this cluster (hosting nodes per group too few, or a group "
                f"cannot host the model)",
            ),))
        if rplan.n_replicas == 1:
            rplan = None  # replicas="auto" chose a single pipeline
    if rplan is None:
        control = ControlPlane(
            cluster, store,
            lambda v: graph, executor_for_version,
            planner=Planner.from_spec(spec),
            capacity=spec.capacity, compression_ratio=spec.compression_ratio,
            seed=spec.seed + seed_offset,
            allowed_nodes=None if nodes is None else set(nodes) | {0},
            hosting_nodes=None if nodes is None else set(nodes),
            execution=spec.execution(),
            journal=journal, journal_source=source_prefix + "control",
        )
        control.bootstrap(version)
        dep = Deployment(spec, control, positions=positions, journal=journal)
    else:
        controls = []
        for r, group in enumerate(rplan.groups):
            control = ControlPlane(
                cluster, store,
                lambda v: graph, executor_for_version,
                planner=Planner.from_spec(spec),
                capacity=spec.capacity,
                compression_ratio=spec.compression_ratio,
                # distinct probe-noise streams per replica (and per tenant)
                seed=spec.seed + seed_offset + 7919 * r,
                allowed_nodes=set(group) | {0},
                hosting_nodes=set(group),
                execution=spec.execution(),
                journal=journal,
                journal_source=f"{source_prefix}replica:{r}",
            )
            control.bootstrap(version)
            controls.append(control)
        replicaset = ReplicaSet(
            cluster, controls, [set(g) for g in rplan.groups],
            dispatcher_node=0, journal=journal,
        )
        dep = Deployment(spec, replicaset=replicaset, positions=positions,
                         journal=journal)
    dep._check_slos()
    return dep


def _deploy_autoscaled(
    spec: DeploymentSpec,
    graph,
    executor_for_version,
    cluster: EdgeCluster,
    store: ArtifactStore,
    positions,
    *,
    version: int,
    flops_per_s: float,
    nodes=None,
    seed_offset: int = 0,
    journal: Journal | None = None,
    source_prefix: str = "",
) -> "Deployment":
    """Autoscaling path: plan the widest feasible replica split, activate
    ``min_replicas`` groups, park the rest as the autoscaler's standby pool."""
    from repro.cluster.autoscale import Autoscaler

    comm = cluster.comm
    view = comm if nodes is None else subcluster(comm, nodes, keep=(0,))
    auto = spec.autoscale
    plan_width = "max" if auto.max_replicas == "auto" else auto.max_replicas
    rplan = Planner.from_spec(spec).plan_replicated(
        graph, view,
        replicas=plan_width, capacity=spec.capacity, version=version,
        dispatcher=0, device_flops=flops_per_s,
        compression_ratio=spec.compression_ratio,
    )
    if not rplan.feasible or rplan.n_replicas < auto.min_replicas:
        raise InfeasibleSpecError((SpecIssue(
            "infeasible_replicas",
            f"autoscaling needs at least {auto.min_replicas} feasible replica "
            f"group(s) (max_replicas={auto.max_replicas!r}) but the planner "
            f"found {rplan.n_replicas if rplan.feasible else 0} on this cluster",
        ),))

    def make_control(group, r: int) -> ControlPlane:
        # one control plane per replica slot; r indexes the *router's*
        # append-only replica list so regrown slots get fresh noise streams
        control = ControlPlane(
            cluster, store,
            lambda v: graph, executor_for_version,
            planner=Planner.from_spec(spec),
            capacity=spec.capacity,
            compression_ratio=spec.compression_ratio,
            seed=spec.seed + seed_offset + 7919 * r,
            allowed_nodes=set(group) | {0},
            hosting_nodes=set(group),
            execution=spec.execution(),
            journal=journal,
            journal_source=f"{source_prefix}replica:{r}",
        )
        control.bootstrap(max(version, store.current_version()))
        return control

    active = [tuple(g) for g in rplan.groups[:auto.min_replicas]]
    standby = [tuple(g) for g in rplan.groups[auto.min_replicas:]]
    controls = [make_control(g, r) for r, g in enumerate(active)]
    replicaset = ReplicaSet(
        cluster, controls, [set(g) for g in active], dispatcher_node=0,
        journal=journal,
    )
    dep = Deployment(spec, replicaset=replicaset, positions=positions,
                     journal=journal)
    max_replicas = (
        None if auto.max_replicas == "auto" else int(auto.max_replicas))
    dep.autoscaler = Autoscaler(
        make_control, standby,
        min_replicas=auto.min_replicas, max_replicas=max_replicas,
        backlog_high=auto.backlog_high, backlog_low=auto.backlog_low,
        target_p99_s=auto.target_p99_s, cooldown_s=auto.cooldown_s,
        window=auto.window,
        name=source_prefix.rstrip("/") or None, journal=journal,
    )
    dep.loop.autoscaler = dep.autoscaler
    dep._check_slos()
    return dep


class Deployment:
    """A live deployment: serving loop + control plane + strategy registry.

    Constructed by ``deploy()``; everything the five old wiring copies did by
    hand is a method here.
    """

    def __init__(
        self,
        spec: DeploymentSpec,
        control: ControlPlane | None = None,
        *,
        replicaset: ReplicaSet | None = None,
        positions: np.ndarray | None = None,
        journal: Journal | None = None,
    ):
        if (control is None) == (replicaset is None):
            raise ValueError("give exactly one of control= or replicaset=")
        self.spec = spec
        self.replicaset = replicaset
        self.autoscaler = None  # set by deploy() when spec.autoscale is given
        self.journal = journal if journal is not None else Journal()
        self.tracer = (
            SpanTracer(spec.trace) if spec.trace is not None else None)
        self.registry = MetricsRegistry()
        if replicaset is not None:
            # replica 0 as the representative for shared resources
            # (cluster/store are one object across every replica)
            self.control = replicaset.controls[0]
            self.loop = ReplicatedServingLoop(
                replicaset, microbatch=spec.microbatch,
                queue_depth=spec.queue_depth,
                max_batch=spec.max_batch,
                admission_depth=spec.admission_depth,
                class_priority=spec.class_priority(),
                class_targets=spec.class_targets(),
                tracer=self.tracer, registry=self.registry,
            )
        else:
            self.control = control
            if spec.serving == "sync":
                self.loop = ServingLoop(
                    control, microbatch=spec.microbatch,
                    tracer=self.tracer, registry=self.registry,
                )
            else:
                self.loop = PipelinedServingLoop(
                    control, microbatch=spec.microbatch,
                    queue_depth=spec.queue_depth,
                    max_batch=spec.max_batch,
                    admission_depth=spec.admission_depth,
                    class_priority=spec.class_priority(),
                    class_targets=spec.class_targets(),
                    tracer=self.tracer, registry=self.registry,
                )
        # journal records are stamped off the serving clock from here on
        self.journal.bind_clock(lambda: self.loop.clock_s)
        self.watcher = ModelWatcher(self.control.store)
        self.positions = positions  # node positions for random clusters (growth)

    # -- introspection -------------------------------------------------------
    @property
    def replicated(self) -> bool:
        return self.replicaset is not None

    @property
    def plan(self) -> Plan | ReplicatedPlan:
        """What is deployed: the control plane's plan, or (replicated) the
        aggregate of the live replicas' plans (summed throughput)."""
        if self.replicaset is not None:
            return self.replicaset.deployed_plan()
        return self.control.last_plan

    @property
    def cluster(self) -> EdgeCluster:
        return self.control.cluster

    @property
    def store(self) -> ArtifactStore:
        return self.control.store

    @property
    def pending(self) -> int:
        """Cluster events not yet reconciled (rollouts included)."""
        if self.replicaset is not None:
            return self.replicaset.pending
        return self.control.pending

    def observed(self) -> ObservedState:
        """Single-pipeline observation; replicated deployments report per
        replica (``observed_replicas``), so this returns replica 0's view."""
        return self.control.observed()

    def observed_replicas(self) -> tuple[ObservedState, ...]:
        if self.replicaset is None:
            return (self.control.observed(),)
        return self.replicaset.observed()

    # -- serving -------------------------------------------------------------
    def submit(self, x: Any, *, slo_class: str | None = None) -> Request:
        """Admit one inference request."""
        if self.spec.serving == "sync":
            return self.loop.submit(x)
        return self.loop.submit(x, slo_class=slo_class)

    def schedule(
        self, x: Any, at_s: float, *, slo_class: str | None = None,
    ) -> Request:
        """Register one open-loop arrival at virtual time ``at_s``."""
        if self.spec.serving == "sync":
            raise RuntimeError("open-loop arrivals need pipelined serving")
        return self.loop.schedule(x, at_s, slo_class=slo_class)

    def submit_trace(self, trace=None, make_input=None) -> int:
        """Schedule every arrival of an open-loop trace onto the engine.

        With no ``trace`` argument, generates one from ``spec.arrival``
        (trace name, rate, duration, seed) and the spec's SLO class weights.
        ``make_input(i, arrival)`` builds each request payload; the default
        sends the arrival index.  Returns the number of arrivals scheduled.
        """
        if trace is None:
            arr = self.spec.arrival
            if arr is None:
                raise RuntimeError("spec has no arrival process; pass a trace")
            from repro.workload import make_trace

            trace = make_trace(
                arr.trace, rate=arr.rate, duration_s=arr.duration_s,
                seed=arr.seed, classes=self.spec.slo_classes,
            )
        if make_input is None:
            make_input = lambda i, a: i  # noqa: E731
        from repro.workload import schedule_trace

        return schedule_trace(self, trace, make_input)

    def step(self) -> list[Request]:
        """One admission round (reconciles pending events first)."""
        return self.loop.step()

    def drain(self, max_rounds: int = 10_000) -> list[Request]:
        """Serve until the queue empties; returns the completed requests."""
        return self.loop.drain(max_rounds=max_rounds)

    # -- churn + convergence -------------------------------------------------
    def inject(self, event: ClusterEvent) -> None:
        """Enqueue a cluster disturbance; ``reconcile()`` converges on it.

        Replicated deployments route the event to the replica(s) it touches
        (``ReplicaSet.submit``); the others never see it.
        """
        (self.replicaset or self.control).submit(event)

    def reconcile(self) -> list[ReconcileAction]:
        """Drain the event queue and converge observed -> desired state."""
        return (self.replicaset or self.control).reconcile()

    def poll_model_updates(self) -> bool:
        """Watch tick: emit ``VersionBumped`` if the store moved past us.

        Replicated deployments start a rolling bump (one replica at a time)
        when any live replica is behind the store pointer and that version
        is not already rolling.
        """
        if self.replicaset is None:
            return self.watcher.poll_events(self.control)
        rset = self.replicaset
        latest = self.store.current_version()
        behind = any(
            rset.controls[r].desired is not None
            and rset.controls[r].desired.version < latest
            for r in rset.live_indices()
        )
        if not behind or rset.rolling_version() >= latest:
            return False
        rset.submit(VersionBumped(latest))
        return True

    def grow_cluster(self, seed: int = 0) -> NodeJoined:
        """Convenience churn: add one random node (full-restart event).

        Only available for random clusters (the spec kept the positions);
        returns the injected ``NodeJoined`` event -- call ``reconcile()``
        (or keep serving) to converge.
        """
        if self.positions is None:
            raise RuntimeError(
                "grow_cluster() needs a position-seeded random cluster; "
                "inject NodeJoined(comm=...) yourself for explicit CommGraphs"
            )
        from repro.core.simulate import expand_cluster

        arena = self.spec.cluster.arena_m
        cap = self.spec.cluster.capacity_bytes
        grown, self.positions = expand_cluster(self.positions, cap, arena, seed)
        event = NodeJoined(comm=grown)
        self.inject(event)
        return event

    # -- strategy swap -------------------------------------------------------
    def replan(
        self,
        *,
        partitioner: str | None = None,
        placer: str | None = None,
        joint: str | None = None,
    ) -> Plan:
        """Swap strategies on the live deployment and redeploy in place.

        Unset kinds keep their current strategy, with one asymmetry: naming
        a ``partitioner`` or ``placer`` switches a joint-optimized deployment
        back to the two-step pipeline (a joint strategy *replaces* that
        pipeline, so keeping it would make the swap a silent no-op).  The
        running pipeline is only replaced if the new plan is feasible.

        On a replicated deployment the swap applies to every live replica
        (each keeps its own sub-cluster); the aggregate plan is returned.
        """
        current = self.control.planner
        if joint is not None:
            new_joint = joint
        elif partitioner is not None or placer is not None:
            new_joint = None  # explicit pipeline strategies drop the joint
        else:
            new_joint = current.joint.name if current.joint else None
        planner = Planner(
            partitioner=partitioner or current.partitioner.name,
            placer=placer or current.placer.name,
            joint=new_joint,
            n_classes=current.n_classes,
            seed=current.seed,
            codec=current.codec,
            accuracy_tolerance=current.accuracy_tolerance,
        )
        if self.replicaset is None:
            return self.control.replan(planner)
        for r in self.replicaset.live_indices():
            self.replicaset.controls[r].replan(planner)
        return self.replicaset.deployed_plan()

    # -- metrics -------------------------------------------------------------
    def metrics(self) -> dict:
        """Predicted vs. observed placement quality + serving counters.

        Replicated deployments report the aggregate (summed predicted
        throughput, live/retired counts) plus one entry per replica.
        """
        if self.replicaset is not None:
            return self._replicated_metrics()
        obs = self.observed()
        plan = self.plan
        out = {
            "version": obs.version,
            "generation": obs.generation,
            "leader": obs.leader,
            "path": list(obs.path),
            "n_nodes": obs.n_nodes,
            "healthy": obs.healthy,
            "bottleneck_latency_s": obs.bottleneck_latency,
            "strategies": dict(plan.strategies) if plan else {},
            "codecs": list(plan.codecs) if plan else [],
            "predicted_bottleneck_s": plan.predicted_bottleneck_s if plan else None,
            "predicted_throughput": plan.predicted_throughput if plan else None,
            "reconcile_actions": [a.kind for a in self.control.history],
            "serving": self.loop.metrics(),
            "recovery": {
                "last": self.control.dispatcher.last_recovery,
                "log": list(self.control.dispatcher.recovery_log),
            },
            "journal": self.journal.summary(),
        }
        return self._finalize_metrics(out)

    def _replicated_metrics(self) -> dict:
        rset = self.replicaset
        plan = rset.deployed_plan()
        replicas = []
        for r, control in enumerate(rset.controls):
            obs = control.observed()
            replicas.append({
                "replica": r,
                "retired": rset.retired[r],
                "group": sorted(rset.groups[r]),
                "version": obs.version,
                "generation": obs.generation,
                "leader": obs.leader,
                "path": list(obs.path),
                "healthy": obs.healthy,
                "bottleneck_latency_s": obs.bottleneck_latency,
                "predicted_throughput": (
                    control.last_plan.predicted_throughput
                    if control.last_plan else None
                ),
                "codecs": (
                    list(control.last_plan.codecs)
                    if control.last_plan else []
                ),
                "reconcile_actions": [a.kind for a in control.history],
                "recovery": {
                    "last": control.dispatcher.last_recovery,
                    "log": list(control.dispatcher.recovery_log),
                },
            })
        return self._finalize_metrics({
            "version": plan.version,
            "n_nodes": self.cluster.n,
            "n_replicas": rset.n_replicas,
            "live_replicas": len(rset.live_indices()),
            "strategies": dict(plan.strategies) if plan.replicas else {},
            "predicted_bottleneck_s": plan.predicted_bottleneck_s,
            "predicted_throughput": plan.predicted_throughput,
            "replicas": replicas,
            "serving": self.loop.metrics(),
            "journal": self.journal.summary(),
        })

    def _finalize_metrics(self, out: dict) -> dict:
        """Mirror the payload into the metrics registry, then attach the
        registry snapshot + trace digest (additive keys: everything the
        payload held before observability landed is untouched)."""
        self.registry.ingest("deployment", out)
        out["observability"] = {
            "metrics": self.registry.snapshot(),
            "trace": (self.tracer.summary()
                      if self.tracer is not None else None),
        }
        from repro.cluster.serving import normalize_metrics

        return normalize_metrics(out)

    # -- observability --------------------------------------------------------
    def trace_timeline(self) -> list[dict]:
        """The span timeline as flat JSON dicts ([] when tracing is off)."""
        return self.tracer.timeline() if self.tracer is not None else []

    def chrome_trace(self) -> dict | None:
        """Chrome trace-event export (None when tracing is off)."""
        return (self.tracer.chrome_trace()
                if self.tracer is not None else None)

    def attribution(self) -> dict | None:
        """Critical-path attribution over every recorded span (None when
        tracing is off); see ``repro.obs.analyze_spans``."""
        if self.tracer is None:
            return None
        return analyze_spans(self.tracer.spans)

    def _check_slos(self) -> None:
        """SLOs re-checked on the as-deployed plan (probed bandwidths)."""
        issues = self.plan.slo_issues(self.spec)
        if issues:
            raise InfeasibleSpecError(issues)


# The function and this module share the name "deploy", and a prior
# ``import repro.api.deploy`` binds the MODULE onto the package before the
# package's lazy __getattr__ can pin the function -- so make the module
# itself callable; either object a caller ends up with deploys the spec.
class _CallableDeployModule(sys.modules[__name__].__class__):
    def __call__(self, *args, **kwargs):
        return deploy(*args, **kwargs)


sys.modules[__name__].__class__ = _CallableDeployModule
