"""``DeploymentSpec``: a frozen, validated description of one deployment.

A spec names *what* to deploy (a ``LayerGraph`` or a model-zoo name), *where*
(a ``ClusterSpec``: explicit ``CommGraph`` or a seeded random wireless
cluster), *how* (strategy names from the registry, compression, bandwidth
classes), and *how well* (optional SLOs).  ``validate()`` returns structured
``SpecIssue``s explaining *why* a spec is unusable -- an unknown strategy
name, a single layer that exceeds node capacity, a model that cannot fit the
cluster -- instead of letting the failure surface as a cryptic infeasible
placement deep in the solver.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

from repro.api.registry import UnknownStrategyError, get_strategy
from repro.core.graph import LayerGraph
from repro.core.placement import CommGraph
from repro.obs.trace import TraceConfig


@dataclasses.dataclass(frozen=True)
class SpecIssue:
    """One structured reason a spec cannot be deployed."""

    code: str  # machine-readable: "unknown_strategy", "layer_exceeds_capacity", ...
    message: str  # human-readable explanation

    def __str__(self) -> str:
        return f"[{self.code}] {self.message}"


class InfeasibleSpecError(ValueError):
    """Spec validation failed; ``issues`` lists every reason found."""

    def __init__(self, issues: tuple[SpecIssue, ...]):
        self.issues = tuple(issues)
        super().__init__(
            "infeasible deployment spec:\n  " + "\n  ".join(str(i) for i in issues)
        )


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Where to deploy: an explicit ``CommGraph``, or a seeded random cluster.

    Exactly one description must be given:

      * ``comm`` -- a prebuilt communication graph (bandwidths + capacities);
      * ``n_nodes`` + ``capacity_bytes`` -- generate a wireless cluster with
        ``core.simulate.random_cluster`` (n compute nodes + dispatcher node 0,
        positions seeded by ``seed`` in an ``arena_m``-sized arena).
    """

    n_nodes: int | None = None
    capacity_bytes: float | None = None
    comm: CommGraph | None = None
    arena_m: float = 100.0
    seed: int = 0

    def validate(self) -> tuple[SpecIssue, ...]:
        issues = []
        any_random = self.n_nodes is not None or self.capacity_bytes is not None
        all_random = self.n_nodes is not None and self.capacity_bytes is not None
        if self.comm is not None and any_random:
            issues.append(SpecIssue(
                "ambiguous_cluster",
                "comm= and n_nodes=/capacity_bytes= both given; the random-"
                "cluster arguments would be silently ignored",
            ))
        elif self.comm is None and not all_random:
            issues.append(SpecIssue(
                "ambiguous_cluster",
                "give exactly one of comm= or (n_nodes= and capacity_bytes=)",
            ))
        if self.n_nodes is not None and self.n_nodes < 1:
            issues.append(SpecIssue("bad_cluster", "n_nodes must be >= 1"))
        if self.capacity_bytes is not None and self.capacity_bytes <= 0:
            issues.append(SpecIssue("bad_cluster", "capacity_bytes must be > 0"))
        return tuple(issues)

    def build(self):
        """Materialize ``(comm, positions)``; positions is None for explicit comm."""
        from repro.core.simulate import random_cluster

        if self.comm is not None:
            return self.comm, None
        return random_cluster(
            self.n_nodes, self.capacity_bytes, self.arena_m, self.seed,
            with_positions=True,
        )


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """One request latency class.

    ``priority`` orders continuous-batch admission (higher drains first);
    ``target_latency_s`` is the class's admit-to-complete target, reported
    as attainment in the latency metrics (``None`` = best-effort);
    ``weight`` is the class's share of generated trace traffic.
    """

    name: str
    priority: int = 0
    target_latency_s: float | None = None
    weight: float = 1.0

    def validate(self) -> tuple[SpecIssue, ...]:
        issues = []
        if not self.name or not isinstance(self.name, str):
            issues.append(SpecIssue(
                "bad_slo_class", f"SLO class name must be a non-empty "
                                 f"string, got {self.name!r}"))
        if self.target_latency_s is not None and self.target_latency_s <= 0:
            issues.append(SpecIssue(
                "bad_slo_class",
                f"SLO class {self.name!r}: target_latency_s must be > 0, "
                f"got {self.target_latency_s!r}"))
        if self.weight <= 0:
            issues.append(SpecIssue(
                "bad_slo_class",
                f"SLO class {self.name!r}: weight must be > 0, "
                f"got {self.weight!r}"))
        return tuple(issues)


@dataclasses.dataclass(frozen=True)
class ArrivalSpec:
    """Open-loop offered load: a seeded trace of request arrival times.

    ``trace`` is a registered generator name (``repro.workload``:
    ``poisson`` / ``diurnal`` / ``bursty`` / ``heavy-tailed``); ``rate`` is
    the mean arrivals/s on the virtual clock over ``duration_s``.  The
    trace seed is separate from the planning seed so load and placement
    randomness vary independently.
    """

    trace: str = "poisson"
    rate: float = 100.0
    duration_s: float = 10.0
    seed: int = 0

    def validate(self) -> tuple[SpecIssue, ...]:
        from repro.workload import UnknownTraceError, get_trace_generator

        issues = []
        try:
            get_trace_generator(self.trace)
        except UnknownTraceError as e:
            issues.append(SpecIssue("unknown_trace", str(e.args[0])))
        if self.rate <= 0:
            issues.append(SpecIssue(
                "bad_arrival", f"rate must be > 0 arrivals/s, got {self.rate!r}"))
        if self.duration_s <= 0:
            issues.append(SpecIssue(
                "bad_arrival", f"duration_s must be > 0, got {self.duration_s!r}"))
        return tuple(issues)


@dataclasses.dataclass(frozen=True)
class AutoscaleSpec:
    """Load-driven replica scaling policy (``cluster.autoscale.Autoscaler``).

    ``deploy()`` plans the widest feasible replica split, activates
    ``min_replicas`` groups, and parks the rest as standby capacity the
    autoscaler grows into when per-replica backlog crosses ``backlog_high``
    (or recent p99 drifts past ``target_p99_s``) and shrinks out of below
    ``backlog_low``.  ``max_replicas="auto"`` means every plannable group.
    """

    min_replicas: int = 1
    max_replicas: int | str = "auto"
    backlog_high: float = 16.0
    backlog_low: float = 2.0
    target_p99_s: float | None = None
    cooldown_s: float = 0.5
    window: int = 32

    def validate(self) -> tuple[SpecIssue, ...]:
        issues = []
        if not isinstance(self.min_replicas, int) or self.min_replicas < 1:
            issues.append(SpecIssue(
                "bad_autoscale",
                f"min_replicas must be an int >= 1, got {self.min_replicas!r}"))
        if self.max_replicas != "auto" and not (
            isinstance(self.max_replicas, int)
            and not isinstance(self.max_replicas, bool)
            and self.max_replicas >= 1
        ):
            issues.append(SpecIssue(
                "bad_autoscale",
                f"max_replicas must be an int >= 1 or 'auto', "
                f"got {self.max_replicas!r}"))
        elif (isinstance(self.max_replicas, int)
              and isinstance(self.min_replicas, int)
              and self.max_replicas < self.min_replicas):
            issues.append(SpecIssue(
                "bad_autoscale",
                f"max_replicas ({self.max_replicas}) < min_replicas "
                f"({self.min_replicas})"))
        if self.backlog_low >= self.backlog_high:
            issues.append(SpecIssue(
                "bad_autoscale",
                f"backlog_low ({self.backlog_low!r}) must be below "
                f"backlog_high ({self.backlog_high!r}) or scaling oscillates"))
        if self.target_p99_s is not None and self.target_p99_s <= 0:
            issues.append(SpecIssue(
                "bad_autoscale",
                f"target_p99_s must be > 0, got {self.target_p99_s!r}"))
        if self.cooldown_s < 0:
            issues.append(SpecIssue(
                "bad_autoscale",
                f"cooldown_s must be >= 0, got {self.cooldown_s!r}"))
        if not isinstance(self.window, int) or self.window < 1:
            issues.append(SpecIssue(
                "bad_autoscale",
                f"window must be an int >= 1, got {self.window!r}"))
        return tuple(issues)


def _resolve_model(
    model, *, use_pallas: bool = False, interpret: bool = False
) -> tuple[LayerGraph, Callable | None]:
    """model field -> (graph, executor_for_version | None).

    Accepts a ``LayerGraph``, a model-zoo name (``vgg16``, ``resnet50``,
    ``inceptionv3``, ``mobilenetv2``), or one of the executable demo models
    (``demo_mlp`` / ``demo_ssm`` / ``demo_transformer``, which also supply
    versioned executors).  ``use_pallas``/``interpret`` (the spec's
    execution knob) select the kernel path inside the executable models'
    stage executors.
    """
    if isinstance(model, LayerGraph):
        return model, None
    if not isinstance(model, str):
        raise TypeError(f"model must be a LayerGraph or name, got {type(model)}")
    from repro.core.model_zoo import (
        PAPER_MODELS,
        demo_mlp,
        demo_ssm,
        demo_transformer,
    )

    if model in PAPER_MODELS:
        return PAPER_MODELS[model](), None
    if model in ("demo_mlp", "mlp"):
        return demo_mlp()
    if model in ("demo_ssm", "ssm"):
        return demo_ssm(use_pallas=use_pallas, interpret=interpret)
    if model in ("demo_transformer", "transformer"):
        return demo_transformer(use_pallas=use_pallas, interpret=interpret)
    raise KeyError(model)


@dataclasses.dataclass(frozen=True)
class DeploymentSpec:
    """Everything ``deploy()`` needs, declared up front.

    Fields
    ------
    model:
        ``LayerGraph``, model-zoo name, or ``"demo_mlp"`` (executable demo).
    cluster:
        ``ClusterSpec`` (or a raw ``CommGraph``, wrapped automatically).
    capacity:
        per-node memory cap handed to the partitioner; ``None`` uses the
        cluster's max node capacity (the dispatcher's historical default).
    compression_ratio:
        boundary compression (paper: ZFP/LZ4; ours: int8 analogue).
    codec:
        inter-stage transfer codec, by registry name (``identity`` /
        ``fp16`` / ``int8`` / ``topk-sparse``; see
        ``repro.dataplane.list_codecs``).  ``"auto"`` lets the planner pick
        the throughput-maximizing codec *per link* among those whose
        reported error bound fits ``accuracy_tolerance``; ``None`` is the
        registry default (``identity``, the historical raw wire).
    accuracy_tolerance:
        per-link SLO: every inter-stage transfer's codec must report a
        round-trip error bound (relative to ``max|x|``) at most this value.
        ``None`` means unconstrained.  A named lossy ``codec`` that exceeds
        the tolerance is a validation error; ``"auto"`` simply drops the
        over-tolerance candidates (``identity`` is always admissible).
    partitioner / placer:
        registry names; ``None`` means the registered default.
    joint:
        optional joint-optimizer name (``sequential`` / ``joint``); when set
        the planner runs it *instead of* the partitioner+placer pipeline.
    n_classes / seed:
        bandwidth-class count for quantization, and the planning seed.
    max_bottleneck_s / min_throughput:
        optional SLOs checked against the plan's predicted metrics.
    executor_for_version:
        version -> ExecutorFn for real serving; ``None`` falls back to the
        model's own executor (``demo_mlp``) or a pass-through executor
        (timing-only simulation).
    microbatch:
        serving-loop admission batch size.
    serving:
        ``"pipelined"`` (default) serves through the discrete-event engine
        (``cluster.engine.PipelinedServingLoop``: every partition advances
        independently, throughput = bottleneck rate); ``"sync"`` uses the
        synchronous baseline loop (one microbatch through the whole chain
        per round, throughput = 1 / end-to-end time).
    queue_depth:
        pipelined mode only: bound on each stage's in-queue (backpressure).
    replicas:
        pipeline replica count.  ``1`` (default) plans one pipeline over the
        whole cluster; an int R partitions the hosting nodes into R disjoint
        sub-clusters and serves one data-parallel pipeline per sub-cluster
        behind a cluster-wide router; ``"auto"`` picks the R maximizing the
        summed predicted throughput.  Replicated serving always uses the
        pipelined engine.
    max_batch:
        continuous batching: coalesce up to this many queued requests into
        one microbatch per admission (pipelined engine only).  ``None``
        keeps the fixed ``microbatch`` admission target.
    admission_depth:
        open-loop admission bound: arrivals past this queue depth are
        rejected (load shedding) instead of queueing without bound.
        ``None`` = unbounded (the closed-loop default).
    slo_classes:
        request latency classes (``SLOClass``): batch-admission priority,
        per-class latency targets (reported as attainment), and trace
        traffic weights.
    arrival:
        open-loop offered load (``ArrivalSpec``): a seeded arrival-time
        trace served by timestamp on the virtual clock.  ``None`` keeps
        closed-loop ``submit()`` serving.
    autoscale:
        load-driven replica scaling (``AutoscaleSpec``): grow/retire
        replicas from observed backlog + p99 drift.  Mutually exclusive
        with an explicit ``replicas`` count (the autoscaler owns R).
    trace:
        per-request span tracing (``repro.obs.TraceConfig``): every sampled
        request carries a span timeline (queue / exec / encode / wire /
        decode) on the virtual clock, exposed via ``Deployment.tracer`` and
        the critical-path analyzer.  ``True`` is shorthand for the default
        config (sample=1.0); ``None`` (default) disables tracing with zero
        serving-path overhead.
    use_pallas / interpret:
        the execution knob (``repro.core.execution.ExecutionKnob``):
        ``use_pallas=True`` runs the Pallas kernels inside the executable
        models' stage executors (flash attention, SSD scan, fused
        dequant-matmul) AND the int8 link codec's quantize/dequantize;
        ``interpret=True`` runs those kernels under the Pallas interpreter
        so CI exercises the deployment artifacts on CPU.  Defaults keep
        the pure-jnp reference paths.
    """

    model: Any
    cluster: Any
    capacity: float | None = None
    compression_ratio: float = 1.0
    codec: str | None = None
    accuracy_tolerance: float | None = None
    partitioner: str | None = None
    placer: str | None = None
    joint: str | None = None
    n_classes: int | None = 4
    seed: int = 0
    max_bottleneck_s: float | None = None
    min_throughput: float | None = None
    executor_for_version: Callable | None = None
    microbatch: int = 4
    serving: str = "pipelined"
    queue_depth: int = 2
    replicas: int | str = 1
    max_batch: int | None = None
    admission_depth: int | None = None
    slo_classes: tuple[SLOClass, ...] | None = None
    arrival: ArrivalSpec | None = None
    autoscale: AutoscaleSpec | None = None
    trace: TraceConfig | None = None
    use_pallas: bool = False
    interpret: bool = False

    def __post_init__(self) -> None:
        if isinstance(self.cluster, CommGraph):
            object.__setattr__(self, "cluster", ClusterSpec(comm=self.cluster))
        if isinstance(self.slo_classes, (list, tuple)):
            object.__setattr__(self, "slo_classes", tuple(self.slo_classes))
        if self.autoscale is True:  # shorthand: default policy
            object.__setattr__(self, "autoscale", AutoscaleSpec())
        if self.trace is True:  # shorthand: trace everything
            object.__setattr__(self, "trace", TraceConfig())

    # -- SLO-class views ------------------------------------------------------
    def class_priority(self) -> dict[str, int]:
        return {c.name: c.priority for c in (self.slo_classes or ())}

    def class_targets(self) -> dict[str, float | None]:
        return {c.name: c.target_latency_s for c in (self.slo_classes or ())}

    # -- resolution ----------------------------------------------------------
    def resolve_model(self) -> tuple[LayerGraph, Callable | None]:
        return _resolve_model(self.model, use_pallas=self.use_pallas,
                              interpret=self.interpret)

    def execution(self):
        """The spec's execution knob as a ``core.execution.ExecutionKnob``."""
        from repro.core.execution import ExecutionKnob

        return ExecutionKnob(use_pallas=self.use_pallas,
                             interpret=self.interpret)

    def graph(self) -> LayerGraph:
        return self.resolve_model()[0]

    def strategy_names(self) -> dict[str, str | None]:
        from repro.api.registry import default_strategy

        return {
            "partitioner": self.partitioner or default_strategy("partitioner"),
            "placer": self.placer or default_strategy("placer"),
            "joint": self.joint,
        }

    # -- validation ----------------------------------------------------------
    def validate(self) -> tuple[SpecIssue, ...]:
        """Every reason this spec cannot deploy; empty tuple when clean.

        Static checks only -- SLOs need a plan and are checked by the
        planner (``Plan.slo_issues``) after prediction.
        """
        issues: list[SpecIssue] = []

        # strategy names exist in the registry
        for kind, name in (("partitioner", self.partitioner),
                           ("placer", self.placer),
                           ("joint", self.joint)):
            if name is None:
                continue
            try:
                get_strategy(kind, name)
            except UnknownStrategyError as e:
                issues.append(SpecIssue("unknown_strategy", str(e)))

        # model resolves
        try:
            graph, _ = self.resolve_model()
        except KeyError as e:
            from repro.core.model_zoo import PAPER_MODELS

            known = ", ".join(
                [*PAPER_MODELS, "demo_mlp", "demo_ssm", "demo_transformer"])
            issues.append(SpecIssue(
                "unknown_model", f"model {e.args[0]!r} not in the zoo ({known})"
            ))
            graph = None
        except TypeError as e:
            issues.append(SpecIssue("bad_model", str(e)))
            graph = None

        # cluster description is well-formed
        if not isinstance(self.cluster, ClusterSpec):
            issues.append(SpecIssue(
                "bad_cluster", f"cluster must be ClusterSpec/CommGraph, "
                               f"got {type(self.cluster).__name__}"
            ))
            cluster_ok = False
        else:
            cluster_issues = self.cluster.validate()
            issues.extend(cluster_issues)
            cluster_ok = not cluster_issues

        if self.compression_ratio <= 0:
            issues.append(SpecIssue("bad_compression",
                                    "compression_ratio must be > 0"))

        # transfer codec + per-link accuracy tolerance
        from repro.dataplane import AUTO, UnknownCodecError, get_codec

        named_codec = None
        if self.codec is not None and self.codec != AUTO:
            try:
                named_codec = get_codec(self.codec)
            except UnknownCodecError as e:
                issues.append(SpecIssue("unknown_codec", str(e)))
        if self.accuracy_tolerance is not None:
            if self.accuracy_tolerance < 0:
                issues.append(SpecIssue(
                    "bad_tolerance",
                    f"accuracy_tolerance must be >= 0, "
                    f"got {self.accuracy_tolerance!r}",
                ))
            elif (named_codec is not None
                  and named_codec.error_bound > self.accuracy_tolerance):
                issues.append(SpecIssue(
                    "codec_exceeds_tolerance",
                    f"codec {self.codec!r} reports a per-link error bound of "
                    f"{named_codec.error_bound:.3g} but accuracy_tolerance is "
                    f"{self.accuracy_tolerance:.3g}; raise the tolerance or "
                    f"use codec='auto' to let the planner pick within it",
                ))

        if self.serving not in ("pipelined", "sync"):
            issues.append(SpecIssue(
                "bad_serving",
                f"serving must be 'pipelined' or 'sync', got {self.serving!r}",
            ))
        if self.queue_depth < 1:
            issues.append(SpecIssue("bad_serving", "queue_depth must be >= 1"))

        # heavy-traffic serving knobs
        if self.max_batch is not None and (
            not isinstance(self.max_batch, int)
            or isinstance(self.max_batch, bool) or self.max_batch < 1
        ):
            issues.append(SpecIssue(
                "bad_batching",
                f"max_batch must be an int >= 1 or None, got {self.max_batch!r}",
            ))
        if self.admission_depth is not None and (
            not isinstance(self.admission_depth, int)
            or isinstance(self.admission_depth, bool)
            or self.admission_depth < 1
        ):
            issues.append(SpecIssue(
                "bad_batching",
                f"admission_depth must be an int >= 1 or None, "
                f"got {self.admission_depth!r}",
            ))
        if self.slo_classes is not None:
            seen = set()
            for c in self.slo_classes:
                if not isinstance(c, SLOClass):
                    issues.append(SpecIssue(
                        "bad_slo_class",
                        f"slo_classes entries must be SLOClass, "
                        f"got {type(c).__name__}",
                    ))
                    continue
                issues.extend(c.validate())
                if c.name in seen:
                    issues.append(SpecIssue(
                        "bad_slo_class", f"duplicate SLO class {c.name!r}"))
                seen.add(c.name)
        if self.arrival is not None:
            if not isinstance(self.arrival, ArrivalSpec):
                issues.append(SpecIssue(
                    "bad_arrival",
                    f"arrival must be an ArrivalSpec, "
                    f"got {type(self.arrival).__name__}",
                ))
            else:
                issues.extend(self.arrival.validate())
            if self.serving == "sync":
                issues.append(SpecIssue(
                    "bad_serving",
                    "open-loop arrivals serve through the pipelined engine "
                    "(timestamped admission); serving='sync' is closed-loop",
                ))
        if self.autoscale is not None:
            if not isinstance(self.autoscale, AutoscaleSpec):
                issues.append(SpecIssue(
                    "bad_autoscale",
                    f"autoscale must be an AutoscaleSpec (or True), "
                    f"got {type(self.autoscale).__name__}",
                ))
            else:
                issues.extend(self.autoscale.validate())
            if self.serving == "sync":
                issues.append(SpecIssue(
                    "bad_autoscale",
                    "autoscaling serves through the replicated pipelined "
                    "engine; serving='sync' supports only a fixed pipeline",
                ))
            if self.replicas != 1:
                issues.append(SpecIssue(
                    "bad_autoscale",
                    f"replicas={self.replicas!r} and autoscale= both given; "
                    f"the autoscaler owns the replica count (set "
                    f"min_replicas/max_replicas on the AutoscaleSpec)",
                ))

        if self.trace is not None:
            if not isinstance(self.trace, TraceConfig):
                issues.append(SpecIssue(
                    "bad_trace",
                    f"trace must be a TraceConfig (or True), "
                    f"got {type(self.trace).__name__}",
                ))
            else:
                issues.extend(SpecIssue("bad_trace", msg)
                              for msg in self.trace.issues())

        if not (
            self.replicas == "auto"
            or (isinstance(self.replicas, int)
                and not isinstance(self.replicas, bool)
                and self.replicas >= 1)
        ):
            issues.append(SpecIssue(
                "bad_replicas",
                f"replicas must be an int >= 1 or 'auto', got {self.replicas!r}",
            ))
        elif self.replicas != 1 and self.serving == "sync":
            issues.append(SpecIssue(
                "bad_replicas",
                "replica sets serve through the pipelined engine; "
                "serving='sync' supports only replicas=1",
            ))

        # capacity feasibility: report WHY, naming the offending layer
        if graph is not None and cluster_ok:
            comm, _ = self.cluster.build()
            cap = self.capacity
            if cap is None:
                cap = float(max(comm.node_capacity, default=0.0))
            worst = max(graph.layers, key=lambda l: l.param_bytes)
            if worst.param_bytes > cap:
                issues.append(SpecIssue(
                    "layer_exceeds_capacity",
                    f"layer {worst.name!r} needs {worst.param_bytes} B but the "
                    f"per-node capacity is {cap:.0f} B -- no contiguous "
                    f"partition can host it; raise capacity or split the layer",
                ))
            hostable = sum(c for c in comm.node_capacity if c > 0)
            if graph.total_param_bytes > hostable:
                issues.append(SpecIssue(
                    "model_exceeds_cluster",
                    f"model needs {graph.total_param_bytes} B but the cluster's "
                    f"hosting nodes hold {hostable:.0f} B total -- add nodes or "
                    f"raise per-node capacity",
                ))
            if isinstance(self.replicas, int) and self.replicas > 1:
                hosting = sum(
                    1 for i, c in enumerate(comm.node_capacity)
                    if c > 0 and i != 0
                )
                if self.replicas > hosting:
                    issues.append(SpecIssue(
                        "infeasible_replicas",
                        f"replicas={self.replicas} exceeds the {hosting} "
                        f"hosting node(s) (node 0 is the shared dispatcher) "
                        f"-- the cluster cannot be split that wide",
                    ))
            if (isinstance(self.autoscale, AutoscaleSpec)
                    and isinstance(self.autoscale.min_replicas, int)):
                hosting = sum(
                    1 for i, c in enumerate(comm.node_capacity)
                    if c > 0 and i != 0
                )
                if self.autoscale.min_replicas > hosting:
                    issues.append(SpecIssue(
                        "infeasible_replicas",
                        f"autoscale.min_replicas={self.autoscale.min_replicas} "
                        f"exceeds the {hosting} hosting node(s) -- the cluster "
                        f"cannot host that many replica groups",
                    ))

        return tuple(issues)

    def check(self) -> "DeploymentSpec":
        """Raise ``InfeasibleSpecError`` with every issue found; else self."""
        issues = self.validate()
        if issues:
            raise InfeasibleSpecError(issues)
        return self


# ---------------------------------------------------------------------------
# Multi-tenant serving: one shared cluster, many deployments
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant of a shared cluster: a ``DeploymentSpec`` plus its quota.

    ``capacity_fraction`` is the tenant's share of the cluster's hosting
    nodes (0 < f <= 1); ``None`` splits whatever the explicit fractions
    leave over equally among the unspecified tenants.  ``weight`` orders
    the router's weighted-fair service across tenants.  ``admission_depth``
    is the tenant's open-loop admission quota (overrides the wrapped
    spec's own ``admission_depth``; ``None`` falls back to it).
    """

    name: str
    spec: DeploymentSpec
    capacity_fraction: float | None = None
    weight: float = 1.0
    admission_depth: int | None = None

    def quota(self) -> int | None:
        """The effective admission bound: tenant override, else the spec's."""
        if self.admission_depth is not None:
            return self.admission_depth
        return self.spec.admission_depth

    def validate(self) -> tuple[SpecIssue, ...]:
        issues = []
        if not self.name or not isinstance(self.name, str):
            issues.append(SpecIssue(
                "bad_tenant",
                f"tenant name must be a non-empty string, got {self.name!r}"))
        if not isinstance(self.spec, DeploymentSpec):
            issues.append(SpecIssue(
                "bad_tenant",
                f"tenant {self.name!r}: spec must be a DeploymentSpec, "
                f"got {type(self.spec).__name__}"))
        if self.capacity_fraction is not None and not (
            0.0 < self.capacity_fraction <= 1.0
        ):
            issues.append(SpecIssue(
                "bad_quota",
                f"tenant {self.name!r}: capacity_fraction must be in (0, 1], "
                f"got {self.capacity_fraction!r}"))
        if self.weight <= 0:
            issues.append(SpecIssue(
                "bad_quota",
                f"tenant {self.name!r}: weight must be > 0, "
                f"got {self.weight!r}"))
        if self.admission_depth is not None and (
            not isinstance(self.admission_depth, int)
            or isinstance(self.admission_depth, bool)
            or self.admission_depth < 1
        ):
            issues.append(SpecIssue(
                "bad_quota",
                f"tenant {self.name!r}: admission_depth must be an int >= 1 "
                f"or None, got {self.admission_depth!r}"))
        return tuple(issues)


def as_tenants(specs) -> tuple[TenantSpec, ...]:
    """Normalize a tenant list: bare ``DeploymentSpec``s become equal-share
    tenants named ``tenant0``, ``tenant1``, ... in list order."""
    tenants = []
    for i, s in enumerate(specs):
        if isinstance(s, TenantSpec):
            tenants.append(s)
        elif isinstance(s, DeploymentSpec):
            tenants.append(TenantSpec(name=f"tenant{i}", spec=s))
        else:
            raise TypeError(
                f"tenant entries must be TenantSpec or DeploymentSpec, "
                f"got {type(s).__name__}")
    return tuple(tenants)


def _same_cluster(a, b) -> bool:
    """Two ClusterSpecs describe one physical cluster (ndarray-safe)."""
    if a is b:
        return True
    if not (isinstance(a, ClusterSpec) and isinstance(b, ClusterSpec)):
        return False
    if a.comm is not None or b.comm is not None:
        return a.comm is b.comm
    return (a.n_nodes, a.capacity_bytes, a.arena_m, a.seed) == (
        b.n_nodes, b.capacity_bytes, b.arena_m, b.seed)


def validate_tenants(tenants: tuple[TenantSpec, ...]) -> tuple[SpecIssue, ...]:
    """Cross-tenant checks for one shared cluster; per-tenant issues are
    prefixed with the tenant name so one report covers the whole fleet."""
    issues: list[SpecIssue] = []
    if not tenants:
        return (SpecIssue("bad_tenant", "tenant list is empty"),)
    seen: set[str] = set()
    for t in tenants:
        issues.extend(t.validate())
        if t.name in seen:
            issues.append(SpecIssue(
                "duplicate_tenant", f"duplicate tenant name {t.name!r}"))
        seen.add(t.name)
        if isinstance(t.spec, DeploymentSpec):
            issues.extend(SpecIssue(i.code, f"tenant {t.name!r}: {i.message}")
                          for i in t.spec.validate())
    given = [t.capacity_fraction for t in tenants
             if t.capacity_fraction is not None]
    if sum(given) > 1.0 + 1e-9:
        issues.append(SpecIssue(
            "quota_exceeded",
            f"tenant capacity fractions sum to {sum(given):.3f} > 1 -- the "
            f"cluster cannot honor every quota"))
    first = tenants[0].spec
    for t in tenants[1:]:
        if (isinstance(t.spec, DeploymentSpec)
                and isinstance(first, DeploymentSpec)
                and not _same_cluster(first.cluster, t.spec.cluster)):
            issues.append(SpecIssue(
                "tenant_cluster_mismatch",
                f"tenant {t.name!r} declares a different cluster than "
                f"{tenants[0].name!r}; multi-tenant deployments share one "
                f"EdgeCluster"))
    return tuple(issues)
