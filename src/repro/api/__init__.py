"""Declarative deployment API: ``DeploymentSpec`` -> ``Planner`` -> ``Deployment``.

The one-facade entry point to the SEIFER reproduction:

    from repro.api import ClusterSpec, DeploymentSpec, deploy

    spec = DeploymentSpec(model="demo_mlp",
                          cluster=ClusterSpec(n_nodes=8, capacity_bytes=11_000),
                          partitioner="min_bottleneck", placer="color_coding")
    d = deploy(spec)          # elect -> probe -> partition -> place -> deploy
    d.submit(x); d.step()     # serve
    d.inject(NodeFailed(3))   # churn
    d.reconcile()             # converge
    d.replan(placer="greedy") # swap a strategy on the live deployment

Layers: ``registry`` (named strategies, self-registered from ``repro.core``),
``spec`` (frozen validated description of model + cluster + strategies +
SLOs), ``planner`` (spec -> ``Plan``: partition + placement + predicted
metrics), ``deploy`` (``Deployment`` facade owning dispatcher + control
plane + serving loop).

Everything except the registry is imported lazily (PEP 562): the core
algorithm modules import ``repro.api.registry`` at definition time to
self-register, and an eager ``spec``/``planner`` import here would close
that cycle.
"""

from __future__ import annotations

from repro.api.registry import (
    KINDS,
    Strategy,
    UnknownStrategyError,
    default_strategy,
    get_strategy,
    list_strategies,
    register_strategy,
    strategy_table,
)

_LAZY = {
    "ArrivalSpec": "repro.api.spec",
    "AutoscaleSpec": "repro.api.spec",
    "ClusterSpec": "repro.api.spec",
    "DeploymentSpec": "repro.api.spec",
    "InfeasibleSpecError": "repro.api.spec",
    "SLOClass": "repro.api.spec",
    "SpecIssue": "repro.api.spec",
    "TenantSpec": "repro.api.spec",
    "TraceConfig": "repro.obs.trace",
    "as_tenants": "repro.api.spec",
    "validate_tenants": "repro.api.spec",
    "Plan": "repro.api.planner",
    "Planner": "repro.api.planner",
    "ReplicatedPlan": "repro.api.planner",
    "split_cluster": "repro.api.planner",
    "subcluster": "repro.api.planner",
    "Deployment": "repro.api.deploy",
    "deploy": "repro.api.deploy",
}

__all__ = [
    "KINDS",
    "Strategy",
    "UnknownStrategyError",
    "default_strategy",
    "get_strategy",
    "list_strategies",
    "register_strategy",
    "strategy_table",
    *sorted(_LAZY),
]


def __getattr__(name: str):
    if name in _LAZY:
        import importlib

        obj = getattr(importlib.import_module(_LAZY[name]), name)
        # cache it: the submodule import binds e.g. ``repro.api.deploy`` (the
        # MODULE) onto this package under the same name as the function it
        # exports; pinning the resolved object wins that collision
        globals()[name] = obj
        return obj
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
