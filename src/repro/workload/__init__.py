"""Trace-driven open-loop workload generation.

The paper's throughput claim is measured closed-loop: the benchmark submits a
fixed batch of requests and drains it, so offered load always equals served
load.  Serving millions of users (the ROADMAP north star) is the opposite
regime -- requests arrive on *their* schedule, not the engine's -- so this
package generates seeded arrival traces that the serving loops admit by
timestamp on the virtual clock:

  * ``poisson``      -- memoryless arrivals at a constant mean rate (M/·/·);
  * ``diurnal``      -- an inhomogeneous Poisson process whose intensity
    follows a day-shaped sinusoid (trough at the trace edges, peak in the
    middle), sampled by thinning;
  * ``bursty``       -- a two-state Markov-modulated Poisson process (on/off
    bursts): short high-rate bursts over a quiet baseline, the classic
    flash-crowd shape autoscalers must absorb;
  * ``heavy-tailed`` -- Pareto inter-arrival gaps (finite mean, infinite
    variance for ``alpha <= 2``): long silences punctuated by clumps.

Every generator draws from one ``numpy`` ``default_rng`` seeded from
``(seed, trace-name)``, so a ``(name, rate, duration_s, seed)`` tuple is a
complete, reproducible description of the offered load -- the determinism
regression tests and the chaos-seed matrix depend on that.

Traces are registered like strategies and codecs (``@register_trace``), so an
unknown name fails fast with suggestions and the CLI/spec can enumerate them.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Callable, Mapping, Sequence

import numpy as np

from repro.core.registry import (
    Registry,
    UnknownNameError,
    suggest,
    unknown_message,
)


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One request arrival: a virtual-clock timestamp + its SLO class."""

    t_s: float
    slo_class: str | None = None


@dataclasses.dataclass(frozen=True)
class Trace:
    """A generated arrival schedule (sorted by time, all within duration)."""

    name: str
    arrivals: tuple[Arrival, ...]
    duration_s: float
    rate: float  # requested mean rate (arrivals/s)
    seed: int

    @property
    def n(self) -> int:
        return len(self.arrivals)

    @property
    def offered_rate(self) -> float:
        """Realized arrivals/s (the requested ``rate`` up to sampling noise)."""
        return self.n / self.duration_s if self.duration_s > 0 else 0.0

    def summary(self) -> dict:
        return {
            "trace": self.name,
            "n": self.n,
            "duration_s": self.duration_s,
            "rate": self.rate,
            "offered_rate": self.offered_rate,
            "seed": self.seed,
        }


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

class UnknownTraceError(UnknownNameError):
    """Trace name not registered; message lists near-misses + all names."""

    def __init__(self, name: str):
        known = list_traces()
        suggestions = suggest(name, known)
        super().__init__(
            unknown_message("trace", name, known, suggestions, style="inline"),
            name=name, known=known, suggestions=suggestions,
        )


# Generators live in this module, so no lazy-import hook is needed; the
# registry historically allows re-registration (overwrite) for traces.
_TRACES = Registry(
    "trace",
    error=lambda name, known: UnknownTraceError(name),
    allow_overwrite=True,
)


def register_trace(name: str):
    """Register ``fn(rate, duration_s, rng) -> iterable of arrival times``."""

    def deco(fn):
        _TRACES.register(name, fn)
        return fn

    return deco


def list_traces() -> tuple[str, ...]:
    return _TRACES.names()


def get_trace_generator(name: str) -> Callable:
    return _TRACES.get(name)


# ---------------------------------------------------------------------------
# Generators
# ---------------------------------------------------------------------------

@register_trace("poisson")
def _poisson(rate: float, duration_s: float, rng: np.random.Generator):
    """Constant-rate Poisson process: exponential inter-arrival gaps."""
    times = []
    t = float(rng.exponential(1.0 / rate))
    while t < duration_s:
        times.append(t)
        t += float(rng.exponential(1.0 / rate))
    return times


@register_trace("diurnal")
def _diurnal(rate: float, duration_s: float, rng: np.random.Generator,
             amplitude: float = 0.75):
    """Day-shaped inhomogeneous Poisson process, sampled by thinning.

    Intensity ``lambda(t) = rate * (1 + amplitude * sin(2*pi*t/T - pi/2))``:
    trough at the trace edges, peak (``(1+amplitude) * rate``) at mid-trace,
    mean exactly ``rate``.  Thinning: draw candidates at the peak intensity
    and keep each with probability ``lambda(t) / lambda_max``.
    """
    lam_max = rate * (1.0 + amplitude)
    times = []
    t = float(rng.exponential(1.0 / lam_max))
    while t < duration_s:
        lam = rate * (1.0 + amplitude * np.sin(
            2.0 * np.pi * t / duration_s - np.pi / 2.0))
        if rng.random() < lam / lam_max:
            times.append(t)
        t += float(rng.exponential(1.0 / lam_max))
    return times


@register_trace("bursty")
def _bursty(rate: float, duration_s: float, rng: np.random.Generator,
            burst_factor: float = 6.0, burst_frac: float = 0.15,
            cycles: float = 6.0):
    """Two-state MMPP: quiet baseline punctuated by high-rate bursts.

    A fraction ``burst_frac`` of the time is spent in the burst state at
    ``burst_factor * rate``; the off-state rate is solved so the long-run
    mean stays ``rate`` (requires ``burst_frac * burst_factor < 1``).  State
    holding times are exponential with means sized for ``cycles`` on/off
    cycles per trace.
    """
    if burst_frac * burst_factor >= 1.0:
        raise ValueError("burst_frac * burst_factor must be < 1 "
                         "(mean rate could not equal the requested rate)")
    lam_on = burst_factor * rate
    lam_off = rate * (1.0 - burst_frac * burst_factor) / (1.0 - burst_frac)
    cycle_s = duration_s / cycles
    mean_on, mean_off = burst_frac * cycle_s, (1.0 - burst_frac) * cycle_s
    times = []
    t, burst = 0.0, False  # start quiet: bursts arrive mid-trace
    phase_end = float(rng.exponential(mean_off))
    while t < duration_s:
        lam = lam_on if burst else lam_off
        t += float(rng.exponential(1.0 / lam))
        while t >= phase_end:  # phase flips carry no arrival of their own
            burst = not burst
            t = phase_end + float(rng.exponential(
                1.0 / (lam_on if burst else lam_off)))
            phase_end += float(rng.exponential(mean_on if burst else mean_off))
        if t < duration_s:
            times.append(t)
    return times


@register_trace("heavy-tailed")
def _heavy_tailed(rate: float, duration_s: float, rng: np.random.Generator,
                  alpha: float = 1.8):
    """Pareto inter-arrival gaps: long silences, then clumps.

    Gap = ``x_m * (1 + Pareto(alpha))`` with scale ``x_m`` chosen so the
    mean gap is ``1/rate``; ``alpha <= 2`` gives infinite gap variance --
    the tail the latency percentiles must survive.
    """
    if alpha <= 1.0:
        raise ValueError("alpha must be > 1 (gaps need a finite mean)")
    x_m = (alpha - 1.0) / (alpha * rate)
    times = []
    t = x_m * (1.0 + float(rng.pareto(alpha)))
    while t < duration_s:
        times.append(t)
        t += x_m * (1.0 + float(rng.pareto(alpha)))
    return times


# ---------------------------------------------------------------------------
# Front door
# ---------------------------------------------------------------------------

def _normalize_classes(classes) -> list[tuple[str, float]]:
    """Accept ``{name: weight}``, ``[(name, weight)]``, or objects with
    ``.name``/``.weight`` (e.g. ``api.spec.SLOClass``)."""
    if classes is None:
        return []
    if isinstance(classes, Mapping):
        pairs = [(str(k), float(v)) for k, v in classes.items()]
    else:
        pairs = []
        for c in classes:
            if isinstance(c, (tuple, list)):
                pairs.append((str(c[0]), float(c[1])))
            else:
                pairs.append((str(c.name), float(getattr(c, "weight", 1.0))))
    if not pairs:
        return []
    if any(w <= 0 for _, w in pairs):
        raise ValueError("SLO-class weights must be > 0")
    return pairs


def make_trace(
    name: str,
    *,
    rate: float,
    duration_s: float,
    seed: int = 0,
    classes=None,
    **kwargs,
) -> Trace:
    """Generate a seeded arrival trace.

    ``classes`` optionally assigns each arrival an SLO class, drawn i.i.d.
    with probability proportional to the class weights (same RNG stream, so
    the class labels are as reproducible as the timestamps).
    """
    if rate <= 0:
        raise ValueError("rate must be > 0 arrivals/s")
    if duration_s <= 0:
        raise ValueError("duration_s must be > 0")
    fn = get_trace_generator(name)
    # per-(seed, name) stream: two traces from one seed don't share draws
    rng = np.random.default_rng([int(seed), zlib.crc32(name.encode())])
    times = sorted(float(t) for t in fn(rate, duration_s, rng, **kwargs)
                   if 0.0 <= t < duration_s)
    pairs = _normalize_classes(classes)
    if pairs:
        names = [n for n, _ in pairs]
        total = sum(w for _, w in pairs)
        p = [w / total for _, w in pairs]
        labels = rng.choice(len(names), size=len(times), p=p)
        arrivals = tuple(Arrival(t, names[int(c)]) for t, c in zip(times, labels))
    else:
        arrivals = tuple(Arrival(t) for t in times)
    return Trace(name=name, arrivals=arrivals, duration_s=float(duration_s),
                 rate=float(rate), seed=int(seed))


def schedule_trace(target, trace: Trace, make_input: Callable[[int, Arrival], object]):
    """Feed every arrival into ``target.schedule`` (a serving loop or a
    ``Deployment``); returns the created requests in arrival order."""
    return [
        target.schedule(make_input(i, a), a.t_s, slo_class=a.slo_class)
        for i, a in enumerate(trace.arrivals)
    ]


__all__ = [
    "Arrival",
    "Trace",
    "UnknownTraceError",
    "get_trace_generator",
    "list_traces",
    "make_trace",
    "register_trace",
    "schedule_trace",
]
