"""Request-level serving over the control plane: admission + microbatching.

The paper's inference step (Sec. 2.3) is a continuous stream of requests
through the pod chain; this module makes that stream first-class.  A
``ServingLoop`` owns an admission queue of single-sample ``Request``s,
stacks up to ``microbatch`` of them per admission round, and runs the
stacked batch through the control plane's current ``InferencePipeline``.

Failure semantics: when the pipeline is degraded mid-stream (dead pod,
failed node), the in-flight microbatch is **re-queued at the front**, the
control plane reconciles (which is where the event-class-aware recovery
happens), and the requests are retried on the repaired pipeline -- so
every admitted request either completes or is retried across a recovery,
never silently lost (up to ``max_attempts``).

Time is simulated: each successful round advances the clock by the
**end-to-end time** (sum of stage compute and link times, dispatcher
input/output hops included, on the probed bandwidths -- the same
``service_times`` model the pipelined engine uses) -- the honest cost of
synchronous execution, where the next microbatch is only admitted once
the previous one has left the last stage.  Each non-trivial reconcile adds
``recovery_penalty_s`` (pod restart + re-placement cost).  Completion
timestamps let benchmarks window throughput before/during/after churn.

This loop is the *baseline*.  ``cluster.engine.PipelinedServingLoop`` keeps
every partition busy on a different microbatch and reaches the bottleneck
rate ``1 / max(stage, link time)`` instead of ``1 / sum`` -- the paper's
pipeline-parallel throughput model (and the source of its 200% claim).
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Any

import jax.numpy as jnp

from repro.cluster.controlplane import ControlPlane, ReconcileAction
from repro.obs.stats import latency_report, latency_stats, percentile  # noqa: F401 -- re-exported; the single nearest-rank implementation lives in obs.stats
from repro.obs.trace import split_hop, split_window


@dataclasses.dataclass
class Request:
    """One admitted inference request (a single sample).

    ``replica`` is stamped by the cluster-wide router when the request is
    dispatched to a pipeline replica (re-stamped if it is re-routed after a
    replica retires); ``None`` under single-pipeline serving.

    ``submitted_s`` is the *arrival* time on the virtual clock: the loop's
    clock at ``submit()``, or the trace timestamp under open-loop
    ``schedule()`` -- so ``completed_s - submitted_s`` is the request's full
    admit-to-complete latency, queueing included.  ``slo_class`` names the
    request's latency class (``None`` = unclassified); ``priority`` orders
    continuous-batch admission (higher first, FIFO within a class).
    ``tenant`` is stamped by the tenancy router under multi-tenant serving
    (``None`` for single-tenant deployments).
    """

    req_id: int
    x: Any
    submitted_s: float
    attempts: int = 0
    completed_s: float | None = None
    result: Any = None
    replica: int | None = None
    slo_class: str | None = None
    priority: int = 0
    tenant: str | None = None

    @property
    def done(self) -> bool:
        return self.completed_s is not None

    @property
    def latency_s(self) -> float | None:
        """Admit-to-complete time on the virtual clock; None while pending."""
        if self.completed_s is None:
            return None
        return self.completed_s - self.submitted_s


def normalize_metrics(payload):
    """Canonical metrics payload: the JSON round-trip identity.

    Every mapping key is coerced to ``str`` (some sub-dicts -- per-replica,
    per-link, per-tenant -- were historically keyed by whatever the
    producer used, so ints and stringified ints could coexist in one
    payload), tuples become lists, and numpy scalars become native Python
    numbers.  Applied once at the metrics facades (``Deployment.metrics``,
    the engines, the tenancy router), so ``json.loads(json.dumps(m)) == m``
    holds for every metrics dict the benchmarks persist.
    """
    if isinstance(payload, dict):
        return {str(k): normalize_metrics(v) for k, v in payload.items()}
    if isinstance(payload, (list, tuple)):
        return [normalize_metrics(v) for v in payload]
    if isinstance(payload, bool) or payload is None:
        return payload
    if isinstance(payload, (int, float, str)):
        return payload
    import numpy as _np

    if isinstance(payload, _np.integer):
        return int(payload)
    if isinstance(payload, _np.floating):
        return float(payload)
    return payload


class ServingLoop:
    def __init__(
        self,
        control: ControlPlane,
        *,
        microbatch: int = 4,
        max_attempts: int = 5,
        recovery_penalty_s: float = 0.25,
        tracer=None,
        registry=None,
    ):
        self.control = control
        self.microbatch = int(microbatch)
        self.max_attempts = int(max_attempts)
        self.recovery_penalty_s = float(recovery_penalty_s)
        self.tracer = tracer
        self._registry = registry
        self.queue: deque[Request] = deque()
        self.completed: list[Request] = []
        self.failed: list[Request] = []
        self.clock_s = 0.0
        self._next_id = 0

    # -- admission -----------------------------------------------------------
    def submit(self, x: Any) -> Request:
        req = Request(self._next_id, x, submitted_s=self.clock_s)
        self._next_id += 1
        self.queue.append(req)
        return req

    def admit(self, req: Request) -> Request:
        """Admit an already-created request (ids minted by the caller)."""
        self.queue.append(req)
        return req

    @property
    def backlog(self) -> int:
        return len(self.queue)

    # -- one admission round ---------------------------------------------------
    def step(self) -> list[Request]:
        """Run one microbatch; returns the requests completed this round.

        Pending control-plane events are reconciled *before* admission (the
        watch/failure detectors enqueue between rounds), and a degraded run
        triggers reconcile + retry instead of losing the batch.
        """
        if self.control.pending:
            self._reconcile()
        if not self.queue:
            return []
        take = min(self.microbatch, len(self.queue))
        batch = [self.queue.popleft() for _ in range(take)]
        xs = jnp.stack([r.x for r in batch])
        try:
            ys, trace = self.control.pipeline.run(xs)
        except RuntimeError:
            self._requeue(batch)
            self._reconcile()
            return []
        t0_round = self.clock_s
        self.clock_s += self._round_e2e_s(trace)
        if self.tracer is not None:
            self._trace_round(batch, t0_round, self.clock_s)
        for i, req in enumerate(batch):
            req.result = ys[i]
            req.completed_s = self.clock_s
            self.completed.append(req)
            if self._registry is not None:
                self._registry.counter(
                    "requests_completed", engine="sync").inc()
                self._registry.histogram(
                    "request_latency_s", engine="sync",
                ).observe(req.latency_s)
        return batch

    def metrics(self) -> dict:
        """Serving-side counters for ``Deployment.metrics()`` / benchmarks."""
        done = len(self.completed)
        return {
            "mode": "sync",
            "completed": done,
            "failed": len(self.failed),
            "rejected": 0,  # the sync baseline has no admission bound
            "backlog": len(self.queue),
            "clock_s": self.clock_s,
            "throughput": done / self.clock_s if self.clock_s > 0 else 0.0,
            "retries": sum(r.attempts for r in self.completed),
            "latency": latency_report(self.completed),
        }

    def drain(self, max_rounds: int = 10_000) -> list[Request]:
        """Step until the queue empties (or max_rounds); returns completions."""
        done: list[Request] = []
        for _ in range(max_rounds):
            if not self.queue and not self.control.pending:
                break
            done.extend(self.step())
        return done

    def _round_times(self):
        """Per-stage/per-hop service times for one synchronous round, on
        the SAME timing model as the pipelined engine
        (``core.bottleneck.service_times``: probed bandwidths, dispatcher
        input/output hops included).  ``None`` when the dispatcher has no
        probed view (direct lifecycle use)."""
        control = self.control
        disp = control.dispatcher
        pipe = control.pipeline
        if disp.probed is None or control.desired is None:
            return None
        from repro.core.bottleneck import service_times

        graph = control.desired.graph
        return service_times(
            [p.partition for p in pipe.pods],
            [p.node_id for p in pipe.pods],
            disp.probed.bw,
            flops_per_node=[n.flops_per_s for n in control.cluster.nodes],
            in_bytes=graph.in_bytes,
            out_bytes=graph.layers[-1].out_bytes,
            dispatcher=disp.leader,
            compression_ratio=pipe.compression_ratio,
            codecs=pipe.link_codecs,
        )

    def _round_e2e_s(self, trace) -> float:
        """End-to-end cost of one synchronous round -- the honest sum of
        stage and link times (so the pipelined-vs-sync comparison isolates
        execution discipline, not a timing-model delta).  Falls back to the
        pipeline's own trace when no probed view exists."""
        times = self._round_times()
        if times is None:
            return trace.e2e_s
        compute_s, link_s = times
        finite = [s for s in compute_s + link_s if s != float("inf")]
        return sum(finite)

    def _trace_round(self, batch: list[Request], t0: float, t1: float) -> None:
        """Emit one synchronous round's spans for the sampled requests of
        ``batch``: the admission-queue wait up to the round start, then the
        sequential hop/stage walk the round actually paid for (link windows
        tiled into encode/wire/decode via the codec cost model).  The walk
        replays the same per-resource times ``_round_e2e_s`` summed, so the
        spans tile ``[queue-entry, t1)``."""
        tr = self.tracer
        traced = [r for r in batch if tr.sampled(r.req_id)]
        if not traced:
            return
        control = self.control
        pipe = control.pipeline
        gen = control.generation

        def emit(req, phase, a, b, stage=None, hop=None, codec=None):
            tr.record(req.req_id, phase, a, b, stage, hop,
                      req.replica, req.tenant, codec, gen, req.attempts)

        for req in traced:
            emit(req, "queue", tr.queue_take(req), t0)
        times = self._round_times()
        if times is None or t1 <= t0:
            for req in traced:  # no probed decomposition: one opaque window
                emit(req, "exec", t0, t1)
            return
        compute_s, link_s = times
        path = [p.node_id for p in pipe.pods]
        k = len(path)
        graph = control.desired.graph
        hop_bytes = [graph.in_bytes, *pipe.boundary_bytes,
                     graph.layers[-1].out_bytes]
        ends = [(control.dispatcher.leader, path[0] if path else None)]
        ends += [(path[i], path[i + 1]) for i in range(k - 1)]
        ends += [(path[-1] if path else None, control.dispatcher.leader)]
        flops = [n.flops_per_s for n in control.cluster.nodes]
        cursor = t0
        for h in range(k + 1):
            if math.isfinite(link_s[h]) and link_s[h] > 0:
                raw = float(hop_bytes[h]) / pipe.compression_ratio
                a, b = ends[h]
                active = raw > 0 and a is not None and b is not None and a != b
                codec = pipe.hop_codec(h) if active else None
                parts = split_hop(
                    link_s[h], codec, raw,
                    src_flops=flops[a] if a is not None else 0.0,
                    dst_flops=flops[b] if b is not None else 0.0)
                for phase, pa, pb in split_window(
                        cursor, cursor + link_s[h], parts):
                    for req in traced:
                        emit(req, phase, pa, pb, hop=h,
                             codec=codec.name if codec is not None else None)
                cursor += link_s[h]
            if h < k and math.isfinite(compute_s[h]):
                for req in traced:
                    emit(req, "exec", cursor, cursor + compute_s[h], stage=h)
                cursor += compute_s[h]

    # -- recovery internals ----------------------------------------------------
    def _requeue(self, batch: list[Request]) -> None:
        for req in reversed(batch):
            req.attempts += 1
            if req.attempts >= self.max_attempts:
                self.failed.append(req)
            else:
                self.queue.appendleft(req)

    def _reconcile(self) -> list[ReconcileAction]:
        actions = self.control.reconcile()
        if any(a.kind != "noop" for a in actions):
            self.clock_s += self.recovery_penalty_s
        return actions
