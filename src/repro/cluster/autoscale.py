"""Load-driven replica autoscaling over the replicated serving router.

PR 4's ``ReplicaSet`` changes replica count only on churn (a group that can
no longer host the model retires).  Heavy traffic needs the other direction
too: capacity that tracks *load*.  The ``Autoscaler`` watches the router's
observed backlog and recent p99 latency each serving round and

  * **grows** -- bootstraps a standby node group into a brand-new replica
    (control plane + engine appended to the router) when the per-replica
    backlog crosses ``backlog_high`` or the recent p99 drifts past
    ``target_p99_s``;
  * **shrinks** -- retires the weakest live replica through the exact
    split/retire machinery churn uses (``ReplicaSet.mark_retired`` + router
    reclaim, so in-flight requests are re-routed, never dropped) when the
    per-replica backlog falls below ``backlog_low``, returning its group to
    the standby pool;
  * **restores** -- when churn retires the *last* live replica, the router
    asks the autoscaler to grow from standby before failing the queue, so a
    cluster with spare groups self-heals.

Groups come from the planner's widest feasible split
(``plan_replicated(replicas="max")``): ``deploy()`` activates
``min_replicas`` of them and parks the rest here as standby capacity.  A
``cooldown_s`` of virtual time between actions damps oscillation, and every
decision is logged as a ``ScaleEvent`` so tests and benchmarks can assert on
*why* capacity moved, not just how much.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

from repro.obs.stats import percentile


@dataclasses.dataclass(frozen=True)
class ScaleEvent:
    """One autoscaling decision, on the virtual clock.

    ``measurement`` is the observed value that crossed the threshold named
    in ``reason`` (backlog-per-replica, or the recent-window p99 in
    seconds), so dashboards can plot the trigger alongside the decision
    without parsing the reason string.  ``None`` for restore events, where
    the trigger is total replica loss, not a measurement.
    """

    t_s: float
    action: str  # "grow" | "retire" | "restore"
    replica: int
    reason: str
    live_after: int
    measurement: float | None = None

    def summary(self) -> dict:
        return dataclasses.asdict(self)


class Autoscaler:
    """Backlog- and tail-latency-driven replica scaling policy.

    Parameters
    ----------
    make_control:
        ``(group, replica_index) -> bootstrapped ControlPlane`` -- built by
        ``deploy()`` so the autoscaler stays free of planner/store wiring.
        May raise ``RuntimeError`` when the group can no longer host the
        model (e.g. its nodes died while on standby); the group is discarded
        and the next standby group is tried.
    standby_groups:
        disjoint node groups not yet serving; ``grow`` consumes from the
        front, ``shrink`` returns groups to the back (LRU rotation).
    backlog_high / backlog_low:
        per-live-replica backlog thresholds for growing / shrinking.
    target_p99_s:
        optional tail-latency target: p99 over the last ``window``
        completions above this triggers a grow even with modest backlog,
        and shrinking is suppressed until the tail is comfortably (2x)
        inside the target.
    cooldown_s:
        minimum virtual time between scale actions.
    name:
        optional label (the owning tenant under multi-tenant serving) --
        each tenant's autoscaler scales only that tenant's standby budget,
        and the label keys its events in cluster-wide metrics.
    journal:
        optional ``repro.obs.Journal``: every ``ScaleEvent`` is also
        appended there as a ``kind="scale"`` record, so scaling decisions
        interleave with reconciles/recoveries/rollouts on one timeline.
    """

    def __init__(
        self,
        make_control: Callable,
        standby_groups: Sequence[Sequence[int]],
        *,
        min_replicas: int = 1,
        max_replicas: int | None = None,
        backlog_high: float = 16.0,
        backlog_low: float = 2.0,
        target_p99_s: float | None = None,
        cooldown_s: float = 0.5,
        window: int = 32,
        name: str | None = None,
        journal=None,
    ):
        self.make_control = make_control
        self.name = name
        self.journal = journal
        self.standby: list[tuple[int, ...]] = [
            tuple(sorted(g)) for g in standby_groups]
        self.min_replicas = int(min_replicas)
        self.max_replicas = max_replicas
        self.backlog_high = float(backlog_high)
        self.backlog_low = float(backlog_low)
        self.target_p99_s = target_p99_s
        self.cooldown_s = float(cooldown_s)
        self.window = int(window)
        self.events: list[ScaleEvent] = []
        self.discarded: list[tuple[int, ...]] = []  # standby groups gone bad
        self._last_action_s = -math.inf

    # -- observation ---------------------------------------------------------
    def recent_p99(self, router) -> float | None:
        """p99 latency over the last ``window`` completions (None when too
        few completions to call a tail)."""
        done = router.completed
        if len(done) < 8:
            return None
        lats = sorted(r.latency_s for r in done[-self.window:])
        return float(percentile(lats, 0.99))

    def observe(self, router) -> None:
        """One policy tick: called by the router between serving events."""
        now = router.clock_s
        if now - self._last_action_s < self.cooldown_s:
            return
        live = router.replicaset.live_indices()
        if not live:
            return  # the router's restore path handles total loss
        per_replica = router.backlog / len(live)
        p99 = self.recent_p99(router)
        reason = None
        measurement = None
        if per_replica > self.backlog_high:
            reason = (f"backlog/replica {per_replica:.1f} > "
                      f"{self.backlog_high:g}")
            measurement = per_replica
        elif (self.target_p99_s is not None and p99 is not None
              and p99 > self.target_p99_s):
            reason = f"recent p99 {p99:.3g}s > target {self.target_p99_s:g}s"
            measurement = p99
        if reason is not None:
            cap = self.max_replicas
            if cap is None or len(live) < cap:
                self._grow(router, reason, measurement=measurement)
            return
        if (
            per_replica < self.backlog_low
            and len(live) > self.min_replicas
            and not router.pending_arrivals
            and (self.target_p99_s is None or p99 is None
                 or p99 <= 0.5 * self.target_p99_s)
        ):
            self._shrink(
                router,
                f"backlog/replica {per_replica:.1f} < {self.backlog_low:g}",
                measurement=per_replica)

    def restore(self, router) -> bool:
        """Last-live-replica-retired path: grow unconditionally (no
        cooldown -- an outage outranks oscillation damping)."""
        self._last_action_s = -math.inf
        return self._grow(router, "no live replicas", action="restore")

    # -- actions -------------------------------------------------------------
    def _record(self, event: ScaleEvent) -> None:
        self.events.append(event)
        if self.journal is not None:
            source = "autoscaler" if self.name is None \
                else f"{self.name}/autoscaler"
            self.journal.append("scale", source, event.summary(),
                                t_s=event.t_s)

    def _grow(self, router, reason: str, action: str = "grow",
              measurement: float | None = None) -> bool:
        while self.standby:
            group = self.standby.pop(0)
            try:
                control = self.make_control(group, len(router.loops))
            except RuntimeError:
                # the group lost nodes while parked; it cannot host anymore
                self.discarded.append(group)
                continue
            r = router.add_replica(control, group)
            self._last_action_s = router.clock_s
            self._record(ScaleEvent(
                router.clock_s, action, r, reason,
                len(router.replicaset.live_indices()),
                measurement=measurement,
            ))
            return True
        return False

    def _shrink(self, router, reason: str,
                measurement: float | None = None) -> None:
        rset = router.replicaset
        live = rset.live_indices()
        r = rset._weakest(live)
        rset.mark_retired(r, f"autoscale: {reason}")
        router._reclaim(r)  # resident requests re-route to the survivors
        self.standby.append(tuple(sorted(rset.groups[r])))
        self._last_action_s = router.clock_s
        self._record(ScaleEvent(
            router.clock_s, "retire", r, reason,
            len(rset.live_indices()),
            measurement=measurement,
        ))

    # -- reporting -----------------------------------------------------------
    def metrics(self) -> dict:
        return {
            "name": self.name,
            "min_replicas": self.min_replicas,
            "max_replicas": self.max_replicas,
            "backlog_high": self.backlog_high,
            "backlog_low": self.backlog_low,
            "target_p99_s": self.target_p99_s,
            "cooldown_s": self.cooldown_s,
            "standby_groups": len(self.standby),
            "discarded_groups": len(self.discarded),
            "grows": sum(1 for e in self.events if e.action in ("grow", "restore")),
            "shrinks": sum(1 for e in self.events if e.action == "retire"),
            "events": [e.summary() for e in self.events],
        }
