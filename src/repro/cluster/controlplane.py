"""Continuous-reconciliation control plane for the SEIFER edge cluster.

The one-shot ``configure -> deploy`` calls in ``dispatcher.py`` are the
*mechanism*; this module is the *policy* loop that keeps a cluster converged
under churn.  A ``ControlPlane`` owns

  * a **desired state** (``DesiredState``): model version + layer graph,
    per-node capacity, boundary compression,
  * an **observed state** (``ObservedState``): deployed version, restart
    generation, pod path, node health, leader, measured bottleneck,

and drives observed -> desired through typed events (``cluster/events.py``).
Convergence is *event-class-aware*, exactly the paper's Sec. 2.3 rules:

  ===============  ========================================================
  event            convergence action
  ===============  ========================================================
  VersionBumped    in-place redeploy: stop pods, re-partition/place the new
                   graph on the already-probed bandwidths; no restart
  NodeFailed       re-place existing partitions onto healthy nodes (store
                   restart path); full reconfigure only as fallback
  NodeJoined       FULL cluster restart: re-elect, re-probe, re-partition,
                   re-place, re-deploy (generation += 1)
  LinkDegraded     re-place only if an active boundary rides the link and
                   the bottleneck worsens past ``link_tolerance``
  ===============  ========================================================

``reconcile()`` drains the event queue, applies the actions, then runs a
drift check (unhealthy pipeline with no explaining event -> re-place), and
returns the ``ReconcileAction`` log so callers can assert on *what* the
control plane did, not just the end state.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Sequence

from repro.api.planner import Plan, Planner, ReplicatedPlan
from repro.cluster.dispatcher import UNSET, Dispatcher
from repro.cluster.events import (
    ClusterEvent,
    LinkDegraded,
    NodeFailed,
    NodeJoined,
    VersionBumped,
)
from repro.cluster.lifecycle import EdgeCluster, ExecutorFn, InferencePipeline
from repro.cluster.store import ArtifactStore
from repro.core.graph import LayerGraph


@dataclasses.dataclass
class DesiredState:
    """What should be running: the spec the reconciler converges toward."""

    version: int
    graph: LayerGraph
    capacity: float | None = None
    compression_ratio: float = 1.0


@dataclasses.dataclass(frozen=True)
class ObservedState:
    """Snapshot of what *is* running."""

    version: int
    generation: int  # full-restart counter (bumps only on node join)
    leader: int | None
    path: tuple[int, ...]
    n_nodes: int
    healthy: bool
    bottleneck_latency: float


@dataclasses.dataclass(frozen=True)
class ReconcileAction:
    """One convergence step taken by ``reconcile()``."""

    event: ClusterEvent | None  # None for drift-check repairs
    kind: str  # "redeploy" | "replace" | "restart" | "noop"
    detail: str = ""


class ControlPlane:
    """Event-driven reconciler over the dispatcher/watch/lifecycle mechanism.

    Parameters
    ----------
    graph_for_version:
        version -> LayerGraph, the external model repository's view.
    executor_for_version:
        version -> ExecutorFn running partition [start, stop) on an input.
        Versions may change weights, so the executor is versioned too.
    planner:
        strategy resolution (``repro.api.Planner``); ``None`` builds the
        default (``min_bottleneck`` + ``color_coding``, the paper pipeline).
    """

    def __init__(
        self,
        cluster: EdgeCluster,
        store: ArtifactStore,
        graph_for_version: Callable[[int], LayerGraph],
        executor_for_version: Callable[[int], ExecutorFn],
        *,
        planner: Planner | None = None,
        capacity: float | None = None,
        compression_ratio: float = 1.0,
        n_classes: int | None = UNSET,
        link_tolerance: float = 1.25,
        seed: int = 0,
        allowed_nodes: set[int] | None = None,
        hosting_nodes: set[int] | None = None,
        scoped_recovery: bool = True,
        recovery_width: int | None = None,
        execution=None,
        journal=None,
        journal_source: str = "control",
    ):
        self.cluster = cluster
        self.store = store
        self.graph_for_version = graph_for_version
        self.executor_for_version = executor_for_version
        self.dispatcher = Dispatcher(
            cluster, store, planner=planner, n_classes=n_classes, seed=seed,
            allowed_nodes=allowed_nodes, hosting_nodes=hosting_nodes,
            execution=execution,
        )
        self.link_tolerance = link_tolerance
        # NodeFailed recovery scope: re-solve only the failure neighborhood
        # (surviving path + recovery_width best-connected spares), falling
        # back to a full re-solve when the scoped one is infeasible
        self.scoped_recovery = scoped_recovery
        self.recovery_width = recovery_width
        self._default_capacity = capacity
        self._default_compression = compression_ratio
        self.desired: DesiredState | None = None
        self.pipeline: InferencePipeline | None = None
        self.generation = 0
        self._events: deque[ClusterEvent] = deque()
        self.history: list[ReconcileAction] = []
        # shared control-plane journal (obs.journal.Journal); every non-noop
        # decision ALSO lands there, tagged with this plane's source name
        self.journal = journal
        self.journal_source = str(journal_source)

    # -- bootstrap -----------------------------------------------------------
    def bootstrap(
        self,
        version: int,
        *,
        capacity: float | None = None,
        compression_ratio: float | None = None,
    ) -> InferencePipeline:
        """Initial convergence: elect, probe, configure, deploy (Sec. 2.1-2.2).

        ``capacity`` / ``compression_ratio`` default to the constructor's
        values; pass them here only to override per-bootstrap.
        """
        if capacity is None:
            capacity = self._default_capacity
        if compression_ratio is None:
            compression_ratio = self._default_compression
        graph = self.graph_for_version(version)
        self.desired = DesiredState(version, graph, capacity, compression_ratio)
        self.dispatcher.elect_leader()
        self.dispatcher.probe_bandwidths()
        plan = self._configure(graph, version)
        self.pipeline = self.dispatcher.deploy(
            plan, self.executor_for_version(version),
            compression_ratio=compression_ratio,
        )
        self.store.publish(version)
        return self.pipeline

    def _configure(self, graph: LayerGraph, version: int) -> Plan:
        plan = self.dispatcher.configure(
            graph, version, capacity=self.desired.capacity,
            compression_ratio=self.desired.compression_ratio,
        )
        if not plan.feasible:
            raise RuntimeError(f"version {version} does not fit the cluster")
        return plan

    @property
    def last_plan(self) -> Plan | None:
        """The plan matching what is deployed: the dispatcher keeps it
        current across configure AND the re-placement recovery path."""
        return self.dispatcher.last_plan

    # -- strategy swap -------------------------------------------------------
    @property
    def planner(self) -> Planner:
        return self.dispatcher.planner

    def replan(self, planner: Planner | None = None) -> Plan:
        """Re-plan the desired state (optionally under a new ``Planner``) and
        redeploy in place -- probed bandwidths, leader, and generation are
        reused, exactly like a version bump without the version.

        This is how a live deployment swaps strategies
        (``Deployment.replan(partitioner=..., placer=...)`` builds the
        planner and calls here).  Raises if the new plan is infeasible,
        leaving the running pipeline untouched.
        """
        if self.desired is None or self.pipeline is None:
            raise RuntimeError("bootstrap() before replan()")
        old_planner = self.dispatcher.planner
        if planner is not None:
            self.dispatcher.planner = planner
        try:
            plan = self._configure(self.desired.graph, self.desired.version)
        except RuntimeError:
            self.dispatcher.planner = old_planner  # keep a working strategy
            raise
        for pod in self.pipeline.pods:  # stop the old inference pods
            pod.alive = False
        self.pipeline = self.dispatcher.deploy(
            plan, self.executor_for_version(self.desired.version),
            compression_ratio=self.desired.compression_ratio,
        )
        self.history.append(ReconcileAction(
            None, "redeploy",
            f"replan with {dict(plan.strategies)}",
        ))
        self._journal_action(self.history[-1])
        return plan

    # -- event intake --------------------------------------------------------
    def submit(self, event: ClusterEvent) -> None:
        """Enqueue an observation; convergence happens at ``reconcile()``."""
        self._events.append(event)

    def owned_nodes(self) -> set[int] | None:
        """Nodes within this control plane's view (``None`` = unmasked,
        the whole cluster).  Tenant- and replica-scoped event routing
        delivers a node's churn only to the planes that own it."""
        allowed = self.dispatcher.allowed_nodes
        return None if allowed is None else set(allowed)

    def adopt_node(self, node_id: int) -> None:
        """Extend a masked view by one node (tenancy/replica-set growth);
        a no-op for unmasked planes, which already see everything."""
        disp = self.dispatcher
        if disp.allowed_nodes is not None:
            disp.allowed_nodes.add(node_id)
        if disp.hosting_nodes is not None:
            disp.hosting_nodes.add(node_id)

    @property
    def pending(self) -> int:
        return len(self._events)

    def pending_events(self) -> tuple[ClusterEvent, ...]:
        """Snapshot of the queued (not yet reconciled) events.

        The pipelined serving engine reads this *before* calling
        ``reconcile()`` to compute which stages a pending ``NodeFailed``
        is about to kill -- the pods are only marked dead during
        reconciliation, but the in-flight microbatches resident on them
        must be requeued, not carried."""
        return tuple(self._events)

    # -- reconciliation ------------------------------------------------------
    def reconcile(self) -> list[ReconcileAction]:
        """Drain the queue, converge observed -> desired, log the actions."""
        if self.desired is None or self.pipeline is None:
            raise RuntimeError("bootstrap() before reconcile()")
        actions: list[ReconcileAction] = []
        while self._events:
            event = self._events.popleft()
            actions.append(self._handle(event))
        # drift check: anything unhealthy that no event explained
        if not self.pipeline.healthy():
            actions.append(
                ReconcileAction(None, "replace", "drift: unhealthy pipeline")
            )
            self._replace()
        self.history.extend(actions)
        for a in actions:
            self._journal_action(a)
        return actions

    def _journal_action(self, action: ReconcileAction) -> None:
        """Record a non-noop reconcile decision on the shared journal."""
        if self.journal is None or action.kind == "noop":
            return
        self.journal.append("reconcile", self.journal_source, {
            "event": (type(action.event).__name__
                      if action.event is not None else None),
            "action": action.kind,
            "detail": action.detail,
        })

    def _handle(self, event: ClusterEvent) -> ReconcileAction:
        if isinstance(event, VersionBumped):
            return self._on_version_bumped(event)
        if isinstance(event, NodeFailed):
            return self._on_node_failed(event)
        if isinstance(event, NodeJoined):
            return self._on_node_joined(event)
        if isinstance(event, LinkDegraded):
            return self._on_link_degraded(event)
        return ReconcileAction(event, "noop", "unknown event class")

    # VersionBumped: in-place redeploy, NO cluster restart (Sec. 2.3).
    def _on_version_bumped(self, event: VersionBumped) -> ReconcileAction:
        if event.version <= self.desired.version:
            return ReconcileAction(event, "noop", "not newer than deployed")
        graph = self.graph_for_version(event.version)
        # plan BEFORE touching the running pods: an infeasible version must
        # not take down a healthy deployment (the watcher will re-emit the
        # event while the store pointer stays ahead of the deployed version)
        try:
            plan = self._configure(graph, event.version)
        except RuntimeError as e:
            return ReconcileAction(
                event, "noop",
                f"rejected: {e}; keeping v{self.desired.version}",
            )
        self.desired = dataclasses.replace(
            self.desired, version=event.version, graph=graph
        )
        for pod in self.pipeline.pods:  # stop the old inference pods
            pod.alive = False
        # reuse the probed bandwidths: no re-election, no re-probe
        self.pipeline = self.dispatcher.deploy(
            plan, self.executor_for_version(event.version),
            compression_ratio=self.desired.compression_ratio,
        )
        if self.store.current_version() < event.version:
            self.store.publish(event.version)
        return ReconcileAction(
            event, "redeploy", f"in-place redeploy at v{event.version}"
        )

    # NodeFailed: re-place surviving partitions; store restart path.
    def _on_node_failed(self, event: NodeFailed) -> ReconcileAction:
        self.cluster.fail(event.node_id)
        dead = self.pipeline.mark_node_failed(event.node_id)
        leader_died = event.node_id == self.dispatcher.leader
        if not dead and not leader_died:
            # no pod to move, but the probed view must not keep showing the
            # dead node as usable for later configures
            self.dispatcher.probe_bandwidths()
            return ReconcileAction(event, "noop", "node hosted no pod")
        scope = (
            self._failure_neighborhood(event.node_id)
            if self.scoped_recovery else None
        )
        self._replace(scope=scope)
        detail = f"re-placed {len(dead)} pod(s) off node {event.node_id}"
        rec = self.dispatcher.last_recovery
        if rec is not None and rec.get("scoped"):
            detail += f"; scoped to {rec['scope_size']} node(s)"
        elif scope is not None:
            detail += "; scoped solve infeasible, full re-solve"
        if leader_died:
            detail += f"; re-elected leader {self.dispatcher.leader}"
        return ReconcileAction(event, "replace", detail)

    # NodeJoined: the paper's full-cluster-restart rule.
    def _on_node_joined(self, event: NodeJoined) -> ReconcileAction:
        if event.node_id is not None:
            self.cluster.heal(event.node_id)
            joined = event.node_id
        else:
            joined = self.cluster.add_node(event.comm)
        self.dispatcher.reset()  # forget leader + probes: full restart
        self.dispatcher.elect_leader()
        self.dispatcher.probe_bandwidths()
        # plan BEFORE stopping the running pods: if the post-join cluster
        # cannot host the model, the old pipeline must keep serving
        try:
            plan = self._configure(self.desired.graph, self.desired.version)
        except RuntimeError as e:
            return ReconcileAction(
                event, "noop", f"rejected: {e}; keeping current deployment"
            )
        for pod in self.pipeline.pods:
            pod.alive = False
        self.generation += 1
        self.pipeline = self.dispatcher.deploy(
            plan, self.executor_for_version(self.desired.version),
            compression_ratio=self.desired.compression_ratio,
        )
        return ReconcileAction(
            event, "restart", f"full restart (gen {self.generation}) after node {joined} joined"
        )

    # LinkDegraded: re-place only when the slow link hurts an active boundary.
    def _on_link_degraded(self, event: LinkDegraded) -> ReconcileAction:
        before = self._current_bottleneck()
        self.cluster.degrade_link(event.a, event.b, event.factor)
        after = self._current_bottleneck()
        if after <= before * self.link_tolerance:
            self.dispatcher.probe_bandwidths()  # keep the probed view current
            return ReconcileAction(
                event, "noop", "bottleneck within tolerance on current path"
            )
        self._replace()
        return ReconcileAction(
            event, "replace",
            f"bottleneck {before:.2e}s -> {after:.2e}s, re-placed",
        )

    def _failure_neighborhood(self, failed: int) -> list[int]:
        """The node slice a ``NodeFailed`` re-solve is scoped to: surviving
        path nodes plus the ``recovery_width`` healthy visible spares with
        the fattest link into the old path (incl. the failed node's
        neighborhood, since the replacement inherits its role)."""
        pipe = self.pipeline
        surviving = [
            p.node_id for p in pipe.pods
            if p.node_id != failed and self.cluster.nodes[p.node_id].healthy
        ]
        allowed = self.dispatcher.allowed_nodes
        anchors = set(surviving) | {failed}
        spares = []
        for node in self.cluster.nodes:
            i = node.node_id
            if (not node.healthy or i in anchors
                    or (allowed is not None and i not in allowed)):
                continue
            bw = max((self.cluster.true_bandwidth(i, a) for a in anchors),
                     default=0.0)
            spares.append((bw, i))
        width = self.recovery_width
        if width is None:
            width = max(4, len(pipe.pods))
        spares.sort(key=lambda t: (-t[0], t[1]))
        return surviving + [i for _, i in spares[:width]]

    def _replace(self, scope: Sequence[int] | None = None) -> None:
        self.pipeline = self.dispatcher.replace_placement(
            self.pipeline, self.desired.graph, self.desired.version,
            capacity=self.desired.capacity, scope_nodes=scope,
        )
        if self.journal is not None and self.dispatcher.last_recovery:
            # the scoped-recovery record (affected stages included) lands on
            # the journal next to the reconcile action that triggered it
            self.journal.append(
                "recovery", self.journal_source,
                dict(self.dispatcher.last_recovery))

    def _current_bottleneck(self) -> float:
        """Max link time of the deployed path on the TRUE bandwidths,
        including the dispatcher round-trip (input to the first partition,
        output from the last) when the leader is not colocated.

        Note the deliberate asymmetry with ``InferencePipeline.run``: the
        serving trace charges only pod-to-pod links (the dispatcher feeds
        requests out-of-band), so measured serving throughput can exceed
        ``1 / bottleneck_latency`` when a leader link is the slowest edge.
        This metric matches the *placement objective* (which also scores
        in_bytes/out_bytes/dispatcher), not the serving clock."""
        pipe = self.pipeline
        lat = 0.0
        for i in range(len(pipe.pods) - 1):
            bw = self.cluster.true_bandwidth(
                pipe.pods[i].node_id, pipe.pods[i + 1].node_id
            )
            bytes_ = pipe.wire_bytes(i)  # compression_ratio + hop codec
            lat = max(lat, float("inf") if bw <= 0 else bytes_ / bw)
        graph = self.desired.graph if self.desired else None
        lead = self.dispatcher.leader
        if graph is not None and lead is not None:
            for bytes_, node in (
                (graph.in_bytes, pipe.pods[0].node_id),
                (graph.layers[-1].out_bytes, pipe.pods[-1].node_id),
            ):
                if bytes_ > 0 and node != lead:
                    bw = self.cluster.true_bandwidth(lead, node)
                    lat = max(lat, float("inf") if bw <= 0 else bytes_ / bw)
        return lat

    # -- observation ---------------------------------------------------------
    def observed(self) -> ObservedState:
        pipe = self.pipeline
        return ObservedState(
            version=self.desired.version if self.desired else -1,
            generation=self.generation,
            leader=self.dispatcher.leader,
            path=tuple(pipe.path()) if pipe else (),
            n_nodes=self.cluster.n,
            healthy=bool(pipe and pipe.healthy()),
            bottleneck_latency=self._current_bottleneck() if pipe else float("inf"),
        )


class ReplicaSet:
    """R ``ControlPlane``s over one shared ``EdgeCluster``, one per disjoint
    node group -- the control side of pipeline replica sets.

    Each replica reconciles independently within its own sub-cluster (its
    dispatcher is masked to the group + the shared dispatcher node), so a
    ``NodeFailed`` re-places -- or, when the group can no longer host the
    model, *retires* -- only the touched replica while the others keep
    serving.  Event routing:

      ===============  ======================================================
      event            routed to
      ===============  ======================================================
      NodeFailed       every live replica whose view contains the node (its
                       owner; all replicas when the shared dispatcher dies)
      NodeJoined       heal: the group that owns the node (or, if its
                       replica retired, adopted by the weakest live one);
                       grow: the node is added to the cluster at intake and
                       adopted by the weakest live replica (full restart of
                       that replica only -- the paper's rule, scoped)
      LinkDegraded     the one live replica hosting an endpoint (replica
                       paths never ride cross-group links, so one tolerance
                       check suffices); no owner -> applied to the cluster
      VersionBumped    ROLLED one replica at a time: the next replica only
                       receives the event after the previous one converged,
                       so aggregate throughput never drops to zero
      ===============  ======================================================
    """

    def __init__(
        self,
        cluster: EdgeCluster,
        controls: Sequence[ControlPlane],
        groups: Sequence[Sequence[int]],
        *,
        dispatcher_node: int = 0,
        journal=None,
    ):
        if len(controls) != len(groups):
            raise ValueError("one node group per control plane")
        self.cluster = cluster
        self.controls = list(controls)
        self.groups = [set(g) for g in groups]
        self.dispatcher_node = dispatcher_node
        self.journal = journal  # rollout/retire transitions land here
        self.retired = [False] * len(self.controls)
        self._rollout_queue: deque[VersionBumped] = deque()
        self._rollout_targets: deque[int] | None = None
        self._rollout_current: int | None = None
        self._rollout_event: VersionBumped | None = None

    # -- introspection -------------------------------------------------------
    @property
    def n_replicas(self) -> int:
        return len(self.controls)

    def live_indices(self) -> list[int]:
        return [r for r in range(len(self.controls)) if not self.retired[r]]

    @property
    def pending(self) -> int:
        """Queued events across live replicas + rollout still in flight."""
        n = sum(self.controls[r].pending for r in self.live_indices())
        if self._rollout_event is not None or self._rollout_queue:
            n += 1
        return n

    def observed(self) -> tuple[ObservedState, ...]:
        return tuple(c.observed() for c in self.controls)

    def owned_nodes(self) -> set[int] | None:
        """Union of the live replicas' views (+ the shared dispatcher);
        ``None`` when any live replica is unmasked."""
        out = {self.dispatcher_node}
        for r in self.live_indices():
            allowed = self.controls[r].dispatcher.allowed_nodes
            if allowed is None:
                return None
            out |= set(allowed)
        return out

    def recovery_log(self) -> list[dict | None]:
        """Per-replica ``Dispatcher.last_recovery`` records (``None`` =
        that replica never ran a recovery re-solve).  Chaos tests assert
        scoped recoveries stayed inside the failed replica's neighborhood."""
        return [c.dispatcher.last_recovery for c in self.controls]

    def deployed_plan(self) -> ReplicatedPlan:
        """The as-deployed aggregate: live replicas' current plans."""
        live = self.live_indices()
        return ReplicatedPlan(
            version=max(
                (self.controls[r].desired.version for r in live
                 if self.controls[r].desired), default=-1,
            ),
            replicas=tuple(self.controls[r].last_plan for r in live),
            groups=tuple(tuple(sorted(self.groups[r])) for r in live),
            requested=len(self.controls),
        )

    def rolling_version(self) -> int:
        """Highest version in the rollout machinery; -1 when idle."""
        versions = [e.version for e in self._rollout_queue]
        if self._rollout_event is not None:
            versions.append(self._rollout_event.version)
        return max(versions, default=-1)

    # -- event intake --------------------------------------------------------
    def submit(self, event: ClusterEvent) -> None:
        """Route one cluster disturbance to the replica(s) it touches."""
        if isinstance(event, VersionBumped):
            self._rollout_queue.append(event)
            self.advance_rollout()
            return
        if isinstance(event, NodeFailed):
            owners = [
                r for r in self.live_indices()
                if (allowed := self.controls[r].dispatcher.allowed_nodes) is None
                or event.node_id in allowed
            ]
            if not owners:
                # a retired replica's node (or an unknown one): keep the
                # shared cluster state honest, no pipeline is affected
                self.cluster.fail(event.node_id)
                return
            for r in owners:
                self.controls[r].submit(event)
            return
        if isinstance(event, NodeJoined):
            self._route_node_joined(event)
            return
        if isinstance(event, LinkDegraded):
            owners = [
                r for r in self.live_indices()
                if event.a in self.groups[r] or event.b in self.groups[r]
            ]
            if not owners:
                self.cluster.degrade_link(event.a, event.b, event.factor)
                return
            # replica paths stay inside their group (+ the shared
            # dispatcher), so at most one live pipeline can ride this link;
            # route to its owner, which applies the cluster mutation once
            self.controls[owners[0]].submit(event)
            return
        # unknown event class: let every live replica log a noop
        for r in self.live_indices():
            self.controls[r].submit(event)

    def _route_node_joined(self, event: NodeJoined) -> None:
        live = self.live_indices()
        if event.comm is not None:
            # grow: adopt the node at intake (serializes concurrent grows)
            # and hand the weakest live replica a heal-style event
            new_id = self.cluster.add_node(event.comm)
            if not live:
                return
            target = self._weakest(live)
            self._adopt(target, new_id)
            self.controls[target].submit(NodeJoined(node_id=new_id))
            return
        owners = [r for r in live if event.node_id in self.groups[r]]
        if owners:
            self.controls[owners[0]].submit(event)
            return
        self.cluster.heal(event.node_id)
        if not live:
            return
        # a retired replica's node coming back: the weakest live replica
        # absorbs it (and pays that group's full restart)
        target = self._weakest(live)
        self._adopt(target, event.node_id)
        self.controls[target].submit(NodeJoined(node_id=event.node_id))

    def _weakest(self, live: list[int]) -> int:
        def throughput(r: int) -> float:
            plan = self.controls[r].last_plan
            return plan.predicted_throughput if plan is not None else 0.0

        return min(live, key=lambda r: (throughput(r), r))

    def _adopt(self, r: int, node_id: int) -> None:
        self.groups[r].add(node_id)
        self.controls[r].adopt_node(node_id)

    # -- rolling version bumps ----------------------------------------------
    def advance_rollout(self) -> None:
        """Move the one-replica-at-a-time version rollout forward.

        Called by the router between serving steps (and by ``reconcile``):
        the next replica receives the ``VersionBumped`` event only once the
        current one has drained its event queue -- by then it either
        redeployed at the new version or rejected it, and in both cases it
        is serving again, so at most one replica is ever mid-redeploy.
        """
        if self._rollout_event is None:
            if not self._rollout_queue:
                return
            self._rollout_event = self._rollout_queue.popleft()
            self._rollout_targets = deque(self.live_indices())
            self._rollout_current = None
        cur = self._rollout_current
        if cur is not None and not self.retired[cur] and self.controls[cur].pending:
            return  # still digesting; the others keep serving
        while self._rollout_targets:
            nxt = self._rollout_targets.popleft()
            if self.retired[nxt]:
                continue
            self.controls[nxt].submit(self._rollout_event)
            if self.journal is not None:
                self.journal.append("rollout", "replicaset", {
                    "version": self._rollout_event.version,
                    "replica": nxt, "phase": "submit",
                })
            self._rollout_current = nxt
            return
        if self.journal is not None and self._rollout_event is not None:
            self.journal.append("rollout", "replicaset", {
                "version": self._rollout_event.version,
                "replica": None, "phase": "complete",
            })
        self._rollout_event = None
        self._rollout_current = None
        self._rollout_targets = None
        if self._rollout_queue:
            self.advance_rollout()

    # -- convergence ---------------------------------------------------------
    def reconcile(self) -> list[ReconcileAction]:
        """Reconcile every live replica; a replica whose group can no longer
        host the model is retired instead of taking the set down."""
        actions: list[ReconcileAction] = []
        for r in self.live_indices():
            try:
                actions.extend(self.controls[r].reconcile())
            except RuntimeError as e:
                self.mark_retired(r, str(e))
                actions.append(self.controls[r].history[-1])
        self.advance_rollout()
        return actions

    def add_replica(self, control: ControlPlane, group: Sequence[int]) -> int:
        """Append a freshly-bootstrapped replica over ``group`` (the
        autoscaler's grow path).  Replica indices are append-only -- retired
        slots keep their history -- so routers indexing by replica id stay
        consistent across grow/retire cycles."""
        self.controls.append(control)
        self.groups.append(set(group))
        self.retired.append(False)
        return len(self.controls) - 1

    def mark_retired(self, r: int, reason: str = "") -> None:
        if self.retired[r]:
            return
        self.retired[r] = True
        control = self.controls[r]
        if control.pipeline is not None:
            for pod in control.pipeline.pods:
                pod.alive = False
        control.history.append(ReconcileAction(
            None, "retire",
            reason or f"replica {r}'s group can no longer host the model",
        ))
        if self.journal is not None:
            self.journal.append("retire", "replicaset", {
                "replica": r, "reason": control.history[-1].detail,
            })
