"""NFS-Ganesha analogue: a versioned artifact store with atomic writes.

SEIFER provisions a cluster-wide NFS server whose lifecycle is independent of
every pod, so crashed pods can restart their inference runtime from stored
partition files.  Here: a directory of ``<version>/<name>.npz`` artifacts
written atomically (tmp + rename), plus a ``VERSION`` pointer file.
"""

from __future__ import annotations

import json
import os
import tempfile
from pathlib import Path
from typing import Any

import numpy as np


class ArtifactStore:
    def __init__(self, root: str | os.PathLike):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    # -- version pointer ----------------------------------------------------
    def current_version(self) -> int:
        vf = self.root / "VERSION"
        return int(vf.read_text()) if vf.exists() else -1

    def _set_version(self, v: int) -> None:
        self._atomic_write(self.root / "VERSION", str(v).encode())

    @staticmethod
    def _atomic_write(path: Path, data: bytes) -> None:
        fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=".tmp-")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(data)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    # -- artifacts ------------------------------------------------------------
    def _vdir(self, version: int) -> Path:
        d = self.root / f"v{version:06d}"
        d.mkdir(parents=True, exist_ok=True)
        return d

    def put_arrays(self, version: int, name: str, arrays: dict[str, np.ndarray]) -> None:
        import io

        buf = io.BytesIO()
        np.savez(buf, **arrays)
        self._atomic_write(self._vdir(version) / f"{name}.npz", buf.getvalue())

    def get_arrays(self, version: int, name: str) -> dict[str, np.ndarray]:
        with np.load(self._vdir(version) / f"{name}.npz") as z:
            return {k: z[k] for k in z.files}

    def put_json(self, version: int, name: str, obj: Any) -> None:
        self._atomic_write(
            self._vdir(version) / f"{name}.json", json.dumps(obj, indent=1).encode()
        )

    def get_json(self, version: int, name: str) -> Any:
        return json.loads((self._vdir(version) / f"{name}.json").read_text())

    def publish(self, version: int) -> None:
        """Flip the version pointer after all artifacts are written."""
        self._set_version(version)

    def exists(self, version: int, name: str, ext: str = "npz") -> bool:
        return (self.root / f"v{version:06d}" / f"{name}.{ext}").exists()
