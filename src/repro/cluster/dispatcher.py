"""Dispatcher: leader election, bandwidth probing, configure + deploy.

The SEIFER system-initialization and configuration steps (Sec. 2.1-2.2):

  1. leader election -- lowest-id healthy node wins (bully-style),
  2. IPerf jobs -- pairwise bandwidth probes, leader-directed; measurements
     are the true link bandwidth with multiplicative log-normal noise,
  3. partitioning + placement containers -- compiled by the ``Planner``
     (strategy names resolved through ``repro.api.registry``) on the PROBED
     bandwidths; partition artifacts + the plan go to the store,
  4. deploy -- one pod per partition, wired in a chain,
  5. node-failure recovery -- re-place on the degraded graph (the planner's
     ``place``) and restart crashed pods from the store.

The dispatcher is pure *mechanism*: which algorithms run is the planner's
business, so swapping ``min_bottleneck``/``color_coding`` for any registered
strategy pair is a constructor argument, not a code edit.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from repro.api.planner import Plan, Planner
from repro.cluster.lifecycle import EdgeCluster, InferencePipeline, Pod
from repro.cluster.store import ArtifactStore
from repro.core.graph import LayerGraph
from repro.core.placement import CommGraph

# ``DeploymentPlan`` was the dispatcher's own plan type before the
# declarative API subsumed it; the alias keeps old imports working.
DeploymentPlan = Plan

# sentinel: n_classes=None legitimately means "unquantized", so "not given"
# needs its own marker to detect a planner/n_classes conflict
UNSET = object()


class Dispatcher:
    def __init__(
        self,
        cluster: EdgeCluster,
        store: ArtifactStore,
        *,
        planner: Planner | None = None,
        n_classes: int | None = UNSET,
        probe_noise: float = 0.05,
        seed: int = 0,
        allowed_nodes: set[int] | None = None,
        hosting_nodes: set[int] | None = None,
        execution=None,
    ):
        self.cluster = cluster
        self.store = store
        # execution knob (repro.core.execution.ExecutionKnob | None): which
        # kernel path the deployed pipelines' codecs run; threaded into every
        # InferencePipeline this dispatcher deploys
        self.execution = execution
        # replica-set masking: ``allowed_nodes`` bounds what this dispatcher
        # can see at all (its group + the shared dispatcher node); within
        # that, only ``hosting_nodes`` may host partitions.  ``None`` (the
        # default, single-pipeline mode) sees the whole cluster.
        self.allowed_nodes = allowed_nodes
        self.hosting_nodes = hosting_nodes
        if planner is not None:
            if n_classes is not UNSET:
                raise ValueError(
                    "pass n_classes via the Planner when supplying one "
                    "(planner.n_classes would silently win otherwise)"
                )
            self.planner = planner
        else:
            self.planner = Planner(
                n_classes=4 if n_classes is UNSET else n_classes
            )
        self.probe_noise = probe_noise
        self.rng = np.random.default_rng(seed)
        self.leader: int | None = None
        self.probed: CommGraph | None = None
        self.last_plan: Plan | None = None  # most recent feasible plan
        # cache keys: the cluster generation (+ mask fingerprint) the cached
        # probe / flops sublattices were computed at
        self._probe_key: tuple | None = None
        self._flops_key: int | None = None
        self._flops: list[float] | None = None
        # recovery bookkeeping: how the last replace_placement was solved
        # ({"scoped": bool, "scope_size": int, "fallback": str,
        # "affected_stages": [int, ...]}); None until the first recovery.
        # recovery_log accumulates every such record in order, so the full
        # recovery history is auditable (metrics + journal surface it).
        self.last_recovery: dict | None = None
        self.recovery_log: list[dict] = []

    def node_flops(self) -> list[float]:
        """Per-node compute rates, indexed by node id (0 = unmodelled).

        Cached by cluster generation: one of ``service_times``'s inputs the
        planner re-reads on every (re-)plan."""
        gen = self.cluster.generation
        if self._flops is None or self._flops_key != gen:
            self._flops = [n.flops_per_s for n in self.cluster.nodes]
            self._flops_key = gen
        return self._flops

    # -- Sec 2.1: system initialization --------------------------------------
    def reset(self) -> None:
        """Forget leader + probed bandwidths (the paper's full cluster
        restart, required when a node is *added*)."""
        self.leader = None
        self.probed = None
        self._probe_key = None

    def visible_healthy_ids(self) -> list[int]:
        """Healthy nodes this dispatcher may see (its replica group, or the
        whole cluster in single-pipeline mode)."""
        healthy = self.cluster.healthy_ids()
        if self.allowed_nodes is None:
            return healthy
        return [i for i in healthy if i in self.allowed_nodes]

    def elect_leader(self) -> int:
        healthy = self.visible_healthy_ids()
        if not healthy:
            raise RuntimeError("no healthy nodes")
        self.leader = min(healthy)
        return self.leader

    def _mask_fingerprint(self) -> tuple:
        return (
            None if self.allowed_nodes is None else frozenset(self.allowed_nodes),
            None if self.hosting_nodes is None else frozenset(self.hosting_nodes),
        )

    def probe_bandwidths(self) -> CommGraph:
        """IPerf-analogue: noisy symmetric measurements of live links.

        Cached by (cluster generation, view mask): re-probing an unchanged
        cluster returns the stored measurement instead of re-drawing an
        O(n^2) noise matrix -- the recovery path re-probes on every
        re-solve, and at fleet scale the redraw dominated small re-plans.
        A topology or health mutation bumps ``EdgeCluster.generation`` and
        invalidates the entry."""
        key = (self.cluster.generation, self._mask_fingerprint())
        if self.probed is not None and self._probe_key == key:
            return self.probed
        true = self.cluster.degraded_comm()
        n = true.n
        noise = self.rng.lognormal(0.0, self.probe_noise, size=(n, n))
        noise = np.tril(noise) + np.tril(noise, -1).T  # symmetric
        bw = true.bw * noise
        cap = true.node_capacity
        if self.allowed_nodes is not None:
            bw = bw.copy()
            cap = cap.copy()
            for i in range(n):
                if i not in self.allowed_nodes:
                    bw[i, :] = 0.0
                    bw[:, i] = 0.0
                    cap[i] = 0.0
                elif self.hosting_nodes is not None and i not in self.hosting_nodes:
                    cap[i] = min(cap[i], 0.0)
        self.probed = CommGraph(bw=bw, node_capacity=cap)
        self._probe_key = key
        return self.probed

    # -- Sec 2.2: configuration step -----------------------------------------
    def configure(
        self,
        graph: LayerGraph,
        version: int,
        *,
        capacity: float | None = None,
        include_dispatcher: bool = True,
        compression_ratio: float = 1.0,
    ) -> Plan:
        if self.leader is None:
            self.elect_leader()
        comm = self.probed if self.probed is not None else self.probe_bandwidths()
        cap = capacity if capacity is not None else float(np.max(comm.node_capacity))
        plan = self.planner.plan(
            graph, comm,
            capacity=cap,
            version=version,
            max_parts=len(self.visible_healthy_ids()),
            seed=int(self.rng.integers(1 << 31)),
            include_dispatcher=include_dispatcher,
            dispatcher=self.leader if include_dispatcher else None,
            device_flops=self.node_flops(),
            compression_ratio=compression_ratio,
        )
        if plan.feasible:
            self.last_plan = plan
            self.store.put_json(version, "plan", plan.summary())
        return plan

    def deploy(
        self,
        plan: Plan,
        executor: Callable,
        *,
        compression_ratio: float = 1.0,
    ) -> InferencePipeline:
        if not plan.feasible:
            raise RuntimeError("cannot deploy infeasible plan")
        pods = [
            Pod(f"inf-{plan.version}-{i}", node, part, plan.version)
            for i, (node, part) in enumerate(zip(plan.placement.path, plan.partition.partitions))
        ]
        return InferencePipeline(
            self.cluster,
            pods,
            executor,
            boundary_bytes=list(plan.partition.boundaries),
            compression_ratio=compression_ratio,
            link_codecs=list(plan.codecs) if plan.codecs else None,
            execution=self.execution,
        )

    # -- fault tolerance -------------------------------------------------------
    def recover(
        self,
        pipeline: InferencePipeline,
        graph: LayerGraph,
        version: int,
        *,
        capacity: float | None = None,
    ) -> InferencePipeline:
        """Manual recovery entry point.

        Kept for direct use; the control plane drives the same mechanism via
        ``replace_placement`` in response to ``NodeFailed`` events.
        """
        return self.replace_placement(pipeline, graph, version, capacity=capacity)

    def scoped_comm(self, comm: CommGraph, scope_nodes) -> CommGraph:
        """``comm`` restricted to ``scope_nodes`` + the leader (links only):
        nodes outside the scope lose links and capacity, so a scoped
        recovery solve can only place within the neighborhood."""
        allowed = set(int(i) for i in scope_nodes)
        if self.leader is not None:
            allowed.add(self.leader)
        mask = np.zeros(comm.n, dtype=bool)
        mask[list(allowed)] = True
        bw = np.where(mask[:, None] & mask[None, :], comm.bw, 0.0)
        cap = np.where(mask, comm.node_capacity, 0.0)
        return CommGraph(bw=bw, node_capacity=cap)

    def replace_placement(
        self,
        pipeline: InferencePipeline,
        graph: LayerGraph,
        version: int,
        *,
        capacity: float | None = None,
        scope_nodes=None,
    ) -> InferencePipeline:
        """Re-place on the degraded cluster; restart dead pods from the store.

        The paper reschedules pods onto healthy nodes; partitions are reused
        (their files live on NFS), only the placement is re-solved through
        the planner's placer strategy.  With ``scope_nodes`` (the control
        plane's failure neighborhood) the solve is first attempted on the
        comm graph restricted to that neighborhood -- churn re-plans then
        touch only the affected slice -- and falls back to the full graph
        when the scoped solve is infeasible.  Falls back further to a full
        reconfigure when even the full graph cannot host the existing
        partitions.
        """
        if self.leader is not None and not self.cluster.nodes[self.leader].healthy:
            self.elect_leader()  # leader itself died -> re-elect
        self.probe_bandwidths()
        comm = self.probed
        part = pipeline_partition(pipeline)
        part_bytes = [p.param_bytes for p in part]
        place_kwargs = dict(
            # score the dispatcher round-trip like configure() does, so a
            # recovery placement doesn't strand the first/last partition
            # behind a dead-slow link to the leader
            in_bytes=graph.in_bytes,
            out_bytes=graph.layers[-1].out_bytes,
            dispatcher=self.leader,
        )
        # stages whose pod is dead or stranded on an unhealthy node -- the
        # serving engines requeue exactly these; recorded so recovery
        # records are comparable with the engine's requeue decisions
        affected = sorted(
            s for s, pod in enumerate(pipeline.pods)
            if not pod.alive or not self.cluster.nodes[pod.node_id].healthy
        )
        place = None
        self.last_recovery = {"scoped": False, "scope_size": 0,
                              "fallback": "none", "affected_stages": affected}
        if scope_nodes is not None:
            place = self.planner.place(
                pipeline.boundary_bytes, part_bytes,
                self.scoped_comm(comm, scope_nodes),
                seed=int(self.rng.integers(1 << 31)), **place_kwargs,
            )
            if place.feasible:
                self.last_recovery = {
                    "scoped": True, "scope_size": len(set(scope_nodes)),
                    "fallback": "none", "affected_stages": affected,
                }
            else:
                place = None
                self.last_recovery["fallback"] = "full"
        if place is None:
            place = self.planner.place(
                pipeline.boundary_bytes, part_bytes, comm,
                seed=int(self.rng.integers(1 << 31)), **place_kwargs,
            )
        if not place.feasible:
            self.last_recovery["fallback"] = "reconfigure"
            self.recovery_log.append(dict(self.last_recovery))
            # partitions no longer fit the surviving nodes: full reconfigure
            plan = self.configure(graph, version, capacity=capacity,
                                  compression_ratio=pipeline.compression_ratio)
            if not plan.feasible:
                raise RuntimeError("cluster too degraded to host the model")
            return self.deploy(plan, pipeline.executor,
                               compression_ratio=pipeline.compression_ratio)
        for pod, node in zip(pipeline.pods, place.path):
            if not pod.alive or not self.cluster.nodes[pod.node_id].healthy:
                pod.restart_on(node)
            else:
                pod.node_id = node
        # joint codec x placement: the links changed, so the codec-per-link
        # assignment is re-solved for the new path and follows the pipeline
        codecs = self.planner.assign_codecs(
            [graph.in_bytes, *pipeline.boundary_bytes,
             graph.layers[-1].out_bytes],
            place.path, comm.bw,
            dispatcher=self.leader, flops_per_node=self.node_flops(),
            compression_ratio=pipeline.compression_ratio,
        )
        pipeline.link_codecs = list(codecs)
        # the plan record must track what is actually deployed: same
        # partitions, new placement, metrics re-scored on the re-probed comm
        if self.last_plan is not None:
            from repro.core.bottleneck import evaluate_pipeline

            metrics = evaluate_pipeline(
                part, place.path, comm,
                device_flops=self.node_flops(),
                in_bytes=graph.in_bytes,
                out_bytes=graph.layers[-1].out_bytes,
                dispatcher=self.leader,
                compression_ratio=pipeline.compression_ratio,
                codecs=codecs,
            )
            self.last_plan = dataclasses.replace(
                self.last_plan,
                placement=place,
                predicted_bottleneck_s=float(place.bottleneck_latency),
                predicted_throughput=float(metrics.effective_throughput),
                codecs=codecs,
            )
        self.recovery_log.append(dict(self.last_recovery))
        return pipeline


def pipeline_partition(pipeline: InferencePipeline) -> Sequence:
    return [p.partition for p in pipeline.pods]
