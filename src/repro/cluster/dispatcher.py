"""Dispatcher: leader election, bandwidth probing, configure + deploy.

The SEIFER system-initialization and configuration steps (Sec. 2.1-2.2):

  1. leader election -- lowest-id healthy node wins (bully-style),
  2. IPerf jobs -- pairwise bandwidth probes, leader-directed; measurements
     are the true link bandwidth with multiplicative log-normal noise,
  3. partitioning + placement containers -- run the core algorithms on the
     PROBED bandwidths, store partition artifacts + the plan,
  4. deploy -- one pod per partition, wired in a chain,
  5. node-failure recovery -- re-place on the degraded graph and restart
     crashed pods from the store.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import numpy as np

from repro.cluster.lifecycle import EdgeCluster, InferencePipeline, Pod
from repro.cluster.store import ArtifactStore
from repro.core.graph import LayerGraph
from repro.core.partitioner import PartitionResult, partition_min_bottleneck
from repro.core.placement import CommGraph, PlacementResult, place_color_coding


@dataclasses.dataclass
class DeploymentPlan:
    version: int
    partition: PartitionResult
    placement: PlacementResult

    @property
    def feasible(self) -> bool:
        return self.partition.feasible and self.placement.feasible


class Dispatcher:
    def __init__(
        self,
        cluster: EdgeCluster,
        store: ArtifactStore,
        *,
        n_classes: int | None = 4,
        probe_noise: float = 0.05,
        seed: int = 0,
    ):
        self.cluster = cluster
        self.store = store
        self.n_classes = n_classes
        self.probe_noise = probe_noise
        self.rng = np.random.default_rng(seed)
        self.leader: int | None = None
        self.probed: CommGraph | None = None

    # -- Sec 2.1: system initialization --------------------------------------
    def reset(self) -> None:
        """Forget leader + probed bandwidths (the paper's full cluster
        restart, required when a node is *added*)."""
        self.leader = None
        self.probed = None

    def elect_leader(self) -> int:
        healthy = self.cluster.healthy_ids()
        if not healthy:
            raise RuntimeError("no healthy nodes")
        self.leader = min(healthy)
        return self.leader

    def probe_bandwidths(self) -> CommGraph:
        """IPerf-analogue: noisy symmetric measurements of live links."""
        true = self.cluster.degraded_comm()
        n = true.n
        noise = self.rng.lognormal(0.0, self.probe_noise, size=(n, n))
        noise = np.tril(noise) + np.tril(noise, -1).T  # symmetric
        bw = true.bw * noise
        self.probed = CommGraph(bw=bw, node_capacity=true.node_capacity)
        return self.probed

    # -- Sec 2.2: configuration step -----------------------------------------
    def configure(
        self,
        graph: LayerGraph,
        version: int,
        *,
        capacity: float | None = None,
        include_dispatcher: bool = True,
    ) -> DeploymentPlan:
        if self.leader is None:
            self.elect_leader()
        comm = self.probed if self.probed is not None else self.probe_bandwidths()
        cap = capacity if capacity is not None else float(np.max(comm.node_capacity))
        part = partition_min_bottleneck(graph, int(cap), max_parts=len(self.cluster.healthy_ids()))
        if not part.feasible:
            return DeploymentPlan(version, part, PlacementResult(False, (), float("inf"), "n/a"))
        place = place_color_coding(
            part.boundaries,
            [p.param_bytes for p in part.partitions],
            comm,
            n_classes=self.n_classes,
            seed=int(self.rng.integers(1 << 31)),
            in_bytes=graph.in_bytes if include_dispatcher else 0.0,
            out_bytes=graph.layers[-1].out_bytes if include_dispatcher else 0.0,
            dispatcher=self.leader if include_dispatcher else None,
        )
        plan = DeploymentPlan(version, part, place)
        if plan.feasible:
            self.store.put_json(
                version,
                "plan",
                {
                    "cuts": list(part.cuts),
                    "path": list(place.path),
                    "bottleneck_latency": place.bottleneck_latency,
                    "algorithm": place.algorithm,
                },
            )
        return plan

    def deploy(
        self,
        plan: DeploymentPlan,
        executor: Callable,
        *,
        compression_ratio: float = 1.0,
    ) -> InferencePipeline:
        if not plan.feasible:
            raise RuntimeError("cannot deploy infeasible plan")
        pods = [
            Pod(f"inf-{plan.version}-{i}", node, part, plan.version)
            for i, (node, part) in enumerate(zip(plan.placement.path, plan.partition.partitions))
        ]
        return InferencePipeline(
            self.cluster,
            pods,
            executor,
            boundary_bytes=list(plan.partition.boundaries),
            compression_ratio=compression_ratio,
        )

    # -- fault tolerance -------------------------------------------------------
    def recover(
        self,
        pipeline: InferencePipeline,
        graph: LayerGraph,
        version: int,
        *,
        capacity: float | None = None,
    ) -> InferencePipeline:
        """Manual recovery entry point.

        Kept for direct use; the control plane drives the same mechanism via
        ``replace_placement`` in response to ``NodeFailed`` events.
        """
        return self.replace_placement(pipeline, graph, version, capacity=capacity)

    def replace_placement(
        self,
        pipeline: InferencePipeline,
        graph: LayerGraph,
        version: int,
        *,
        capacity: float | None = None,
    ) -> InferencePipeline:
        """Re-place on the degraded cluster; restart dead pods from the store.

        The paper reschedules pods onto healthy nodes; partitions are reused
        (their files live on NFS), only the placement is re-solved.  Falls
        back to a full reconfigure when the surviving nodes cannot host the
        existing partitions.
        """
        if self.leader is not None and not self.cluster.nodes[self.leader].healthy:
            self.elect_leader()  # leader itself died -> re-elect
        self.probe_bandwidths()
        comm = self.probed
        part = pipeline_partition(pipeline)
        place = place_color_coding(
            pipeline.boundary_bytes,
            [p.param_bytes for p in part],
            comm,
            n_classes=self.n_classes,
            seed=int(self.rng.integers(1 << 31)),
            # score the dispatcher round-trip like configure() does, so a
            # recovery placement doesn't strand the first/last partition
            # behind a dead-slow link to the leader
            in_bytes=graph.in_bytes,
            out_bytes=graph.layers[-1].out_bytes,
            dispatcher=self.leader,
        )
        if not place.feasible:
            # partitions no longer fit the surviving nodes: full reconfigure
            plan = self.configure(graph, version, capacity=capacity)
            if not plan.feasible:
                raise RuntimeError("cluster too degraded to host the model")
            return self.deploy(plan, pipeline.executor,
                               compression_ratio=pipeline.compression_ratio)
        for pod, node in zip(pipeline.pods, place.path):
            if not pod.alive or not self.cluster.nodes[pod.node_id].healthy:
                pod.restart_on(node)
            else:
                pod.node_id = node
        return pipeline


def pipeline_partition(pipeline: InferencePipeline) -> Sequence:
    return [p.partition for p in pipeline.pods]
