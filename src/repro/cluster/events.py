"""Typed cluster events consumed by the control plane's reconciler.

SEIFER Sec. 2.3 prescribes a different convergence action per disturbance
class, and the event types encode exactly that taxonomy:

  * ``NodeFailed``    -- pods on the node die; *re-place* the existing
    partitions onto the surviving nodes (partitions live on the store, only
    the placement is re-solved; full reconfigure only as fallback).
  * ``NodeJoined``    -- the paper requires a **full cluster restart** when a
    node is added: re-elect, re-probe, re-partition, re-place, re-deploy.
  * ``VersionBumped`` -- a new model version in the artifact store triggers
    an **in-place redeploy**: stop the inference pods and reconfigure on the
    already-probed bandwidths, no cluster restart.
  * ``LinkDegraded``  -- bandwidth loss on one link; re-place only if the
    link carries an active boundary and the bottleneck worsens past a
    tolerance (otherwise the current placement still maximizes throughput).

Events are plain frozen dataclasses so they can be queued, logged, and
asserted on in tests.  ``ControlPlane.submit`` enqueues; ``reconcile``
drains and converges.
"""

from __future__ import annotations

import dataclasses

from repro.core.placement import CommGraph


@dataclasses.dataclass(frozen=True)
class ClusterEvent:
    """Base class; carries nothing, exists for isinstance dispatch."""


@dataclasses.dataclass(frozen=True)
class NodeFailed(ClusterEvent):
    node_id: int


@dataclasses.dataclass(frozen=True)
class NodeJoined(ClusterEvent):
    """A node joins the cluster.

    Either an existing failed node coming back (``node_id``) or a brand-new
    node with its link bandwidths (``comm``: the expanded (n+1)-node graph,
    e.g. from ``core.simulate.expand_cluster``).  Exactly one must be set.
    """

    node_id: int | None = None
    comm: CommGraph | None = None

    def __post_init__(self) -> None:
        if (self.node_id is None) == (self.comm is None):
            raise ValueError("NodeJoined needs exactly one of node_id / comm")


@dataclasses.dataclass(frozen=True)
class VersionBumped(ClusterEvent):
    version: int


@dataclasses.dataclass(frozen=True)
class LinkDegraded(ClusterEvent):
    a: int
    b: int
    factor: float  # multiplies the link bandwidth; 0 < factor <= 1 degrades

    def __post_init__(self) -> None:
        if self.factor < 0:
            raise ValueError("factor must be nonnegative")
