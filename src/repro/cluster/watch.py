"""Model-watch container: redeploy on model-version updates (Sec. 2.3-3).

Watches the artifact store's version pointer; when the external repository
publishes a new model version, the watcher stops the inference pods and
reruns partitioning/placement + deployment.  A full cluster restart is only
needed when a NODE is added (per the paper) -- version bumps are handled
in-place.

Two modes:

  * ``poll``        -- legacy one-shot: detect + redeploy in one call.
  * ``poll_events`` -- control-plane mode: the watcher only *detects* and
    emits a ``VersionBumped`` event; the reconciler owns convergence.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Callable

from repro.cluster.dispatcher import Dispatcher
from repro.cluster.events import VersionBumped
from repro.cluster.lifecycle import InferencePipeline
from repro.cluster.store import ArtifactStore
from repro.core.graph import LayerGraph

if TYPE_CHECKING:  # avoid a cycle: controlplane imports nothing from watch
    from repro.cluster.controlplane import ControlPlane


class ModelWatcher:
    def __init__(
        self,
        store: ArtifactStore,
        dispatcher: Dispatcher | None = None,
        graph_for_version: Callable[[int], LayerGraph] | None = None,
    ):
        # dispatcher/graph_for_version are only needed for legacy ``poll``;
        # in control-plane mode the reconciler owns both.
        self.store = store
        self.dispatcher = dispatcher
        self.graph_for_version = graph_for_version
        self.deployed_version = store.current_version()

    def poll(
        self, pipeline: InferencePipeline, executor: Callable, **deploy_kw
    ) -> InferencePipeline:
        """One watch tick: redeploy if the store moved past us."""
        if self.dispatcher is None or self.graph_for_version is None:
            raise RuntimeError(
                "legacy poll() requires dispatcher and graph_for_version; "
                "use poll_events(control) in control-plane mode"
            )
        latest = self.store.current_version()
        if latest <= self.deployed_version:
            return pipeline
        for pod in pipeline.pods:  # stop the old inference pods
            pod.alive = False
        graph = self.graph_for_version(latest)
        plan = self.dispatcher.configure(graph, latest)
        if not plan.feasible:
            raise RuntimeError(f"version {latest} does not fit the cluster")
        new_pipe = self.dispatcher.deploy(plan, executor, **deploy_kw)
        self.deployed_version = latest
        return new_pipe

    def poll_events(self, control: "ControlPlane") -> bool:
        """One watch tick in control-plane mode: emit, don't act.

        Compares the store pointer against the control plane's *deployed*
        version (the observed state), so the detector itself is stateless
        and watchers can be created at any time.  Returns True when a
        ``VersionBumped`` event was submitted; the caller (or the serving
        loop) triggers ``control.reconcile()``.
        """
        latest = self.store.current_version()
        deployed = (
            control.desired.version
            if control.desired is not None
            else self.deployed_version
        )
        if latest <= deployed:
            return False
        control.submit(VersionBumped(latest))
        # deployed_version deliberately NOT advanced: the reconciler may
        # reject the bump (infeasible), and control-plane mode compares
        # against control.desired.version anyway -- mutating here would
        # desync a watcher that also serves legacy poll() callers
        return True
