"""Model-watch container: redeploy on model-version updates (Sec. 2.3-3).

Watches the artifact store's version pointer; when the external repository
publishes a new model version, the watcher stops the inference pods and
reruns partitioning/placement + deployment.  A full cluster restart is only
needed when a NODE is added (per the paper) -- version bumps are handled
in-place.
"""

from __future__ import annotations

from typing import Callable

from repro.cluster.dispatcher import Dispatcher
from repro.cluster.lifecycle import InferencePipeline
from repro.cluster.store import ArtifactStore
from repro.core.graph import LayerGraph


class ModelWatcher:
    def __init__(
        self,
        store: ArtifactStore,
        dispatcher: Dispatcher,
        graph_for_version: Callable[[int], LayerGraph],
    ):
        self.store = store
        self.dispatcher = dispatcher
        self.graph_for_version = graph_for_version
        self.deployed_version = store.current_version()

    def poll(
        self, pipeline: InferencePipeline, executor: Callable, **deploy_kw
    ) -> InferencePipeline:
        """One watch tick: redeploy if the store moved past us."""
        latest = self.store.current_version()
        if latest <= self.deployed_version:
            return pipeline
        for pod in pipeline.pods:  # stop the old inference pods
            pod.alive = False
        graph = self.graph_for_version(latest)
        plan = self.dispatcher.configure(graph, latest)
        if not plan.feasible:
            raise RuntimeError(f"version {latest} does not fit the cluster")
        new_pipe = self.dispatcher.deploy(plan, executor, **deploy_kw)
        self.deployed_version = latest
        return new_pipe
