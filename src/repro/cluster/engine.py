"""Pipelined discrete-event serving engine (the paper's actual throughput model).

SEIFER's headline claim -- ~200% more inference throughput from partitioning
across resource-constrained nodes -- rests on *pipeline parallelism*: each
partition works on a different microbatch concurrently, so steady-state
throughput equals the bottleneck stage's rate, independent of pipeline depth
(same model as DEFER and the companion placement paper).  The synchronous
``ServingLoop`` pushes one microbatch through the whole chain per round and
therefore pays the *sum* of stage times; this module replaces it with a
virtual-clock scheduler in which every placed partition advances
independently:

  * **virtual clock** -- ``clock_s`` advances to the earliest pending event
    (a compute or a transfer finishing); nothing is wall-clock timed.
  * **bounded in-queues** -- each stage owns a ``queue_depth``-bounded input
    queue; a transfer may only start once it can reserve a slot downstream,
    so a slow stage stalls its upstream neighbours and ultimately admission
    (backpressure), bounding memory everywhere.
  * **serial resources** -- each stage computes one microbatch at a time
    (service time = ``partition.flops / node.flops_per_s``) and each link
    carries one transfer at a time (``boundary_bytes / probed_bandwidth``,
    compression-adjusted), including the dispatcher's input/output hops.
    Steady-state throughput is therefore ``1 / max(stage, link times)`` --
    exactly what ``Planner`` predicts via the shared
    ``core.bottleneck.service_times`` model.
  * **in-flight tracking** -- every admitted request lives in exactly one
    place: the admission queue, one in-flight microbatch, ``completed``, or
    ``failed``.  When reconciliation disturbs the pipeline, microbatches
    resident on *affected* stages (the dead node's pods, or every stage on a
    version bump / full restart) are requeued to admission with an attempt
    count; batches elsewhere keep their partial progress, because the
    re-placement recovery path preserves partitions.

The engine exposes the same surface as ``ServingLoop`` (``submit`` /
``step`` / ``drain`` / ``metrics`` / ``backlog``), so ``Deployment`` and the
benchmarks can switch between the honest synchronous baseline and the
pipelined engine with one spec field.
"""

from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from typing import Any

import jax.numpy as jnp

from repro.cluster.controlplane import ControlPlane, ReconcileAction, ReplicaSet
from repro.cluster.events import NodeFailed
from repro.cluster.lifecycle import Pod
from repro.cluster.serving import Request, latency_report, normalize_metrics
from repro.core.bottleneck import service_times
from repro.obs.trace import split_hop, split_window

_ALL = "all"  # sentinel: every stage is affected (version bump, restart)


@dataclasses.dataclass
class Microbatch:
    """A stacked group of requests moving through the stage chain.

    ``location`` is the single source of truth for where the batch is:

      ``("queue", s)``    waiting in stage s's bounded in-queue
      ``("compute", s)``  being computed by stage s (``ready_at`` = finish)
      ``("out", s)``      computed by stage s, waiting for the next hop
      ``("link", h)``     riding hop h (0 = dispatcher->0, k = last->out)
    """

    mb_id: int
    requests: list[Request]
    x: Any  # current activation (input stack before stage ``stage``)
    stage: int  # next stage whose compute this batch still needs
    location: tuple
    ready_at: float = 0.0
    # span tracing (populated only for sampled requests; empty = untraced)
    traced: list = dataclasses.field(default_factory=list)
    phase: tuple | None = None  # open span phase, e.g. ("exec", s)
    phase_t0: float = 0.0


@dataclasses.dataclass
class StageState:
    """One placed partition: bounded in-queue + serial compute + out buffer."""

    index: int
    pod: Pod
    compute_s: float
    queue: deque
    out: deque  # computed batches awaiting their outgoing hop (normally <= 1)
    reserved: int = 0  # in-queue slots reserved by in-flight transfers
    current: Microbatch | None = None
    busy_s: float = 0.0  # total time spent computing
    queue_area: float = 0.0  # integral of queue length over virtual time
    max_queue: int = 0  # peak of len(queue) + reserved
    completed: int = 0  # microbatches computed by this stage


class PipelinedServingLoop:
    """Discrete-event pipelined serving over a ``ControlPlane``.

    Drop-in for ``ServingLoop``: same constructor shape, same
    ``submit``/``step``/``drain``/``metrics`` surface, same recovery
    semantics (reconcile pending events before advancing; a non-trivial
    reconcile costs ``recovery_penalty_s`` of virtual time).
    """

    def __init__(
        self,
        control: ControlPlane,
        *,
        microbatch: int = 4,
        queue_depth: int = 2,
        max_attempts: int = 5,
        recovery_penalty_s: float = 0.25,
        max_batch: int | None = None,
        admission_depth: int | None = None,
        class_priority: dict[str, int] | None = None,
        class_targets: dict[str, float | None] | None = None,
        tracer=None,
        registry=None,
    ):
        if queue_depth < 1:
            raise ValueError("queue_depth must be >= 1")
        if max_batch is not None and max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if admission_depth is not None and admission_depth < 1:
            raise ValueError("admission_depth must be >= 1")
        self.control = control
        self.microbatch = int(microbatch)
        self.queue_depth = int(queue_depth)
        self.max_attempts = int(max_attempts)
        self.recovery_penalty_s = float(recovery_penalty_s)
        # continuous batching: coalesce up to max_batch queued requests per
        # admission (None keeps the fixed microbatch target of closed loops)
        self.max_batch = None if max_batch is None else int(max_batch)
        # open-loop admission bound: arrivals beyond this queue depth are
        # rejected (load shedding), never silently dropped
        self.admission_depth = (
            None if admission_depth is None else int(admission_depth))
        self.class_priority = dict(class_priority or {})
        self.class_targets = dict(class_targets or {})
        # observability plane: both default None (zero overhead -- every
        # tracing/counting site is behind an ``is not None`` guard)
        self.tracer = tracer
        self._registry = registry
        self.queue: deque[Request] = deque()  # admission queue
        self.completed: list[Request] = []
        self.failed: list[Request] = []
        self.rejected: list[Request] = []
        self._arrivals: list[tuple[float, int, Request]] = []  # future arrivals
        self._arrival_seq = 0  # heap tiebreak for externally-minted ids
        self._max_batch_seen = 0
        self.clock_s = 0.0
        self._next_id = 0
        self._next_mb = 0
        self._inflight: list[Microbatch] = []
        self._stages: list[StageState] = []
        self._link_s: list[float] = []  # per-hop transfer time, len k+1
        self._links_busy: list[Microbatch | None] = []
        self._link_codecs: list = []  # Codec per hop (None = raw / no wire)
        self._link_parts: list = []  # (encode_s, wire_s, decode_s) per hop
        self._link_raw: list[float] = []  # raw boundary bytes per hop
        self._link_wire: list[float] = []  # on-wire bytes per hop
        self._link_busy_s: list[float] = []  # time each link spent occupied
        self._link_xfers: list[int] = []  # completed transfers per hop
        self._mb_completed = 0
        self._requeues = 0  # microbatches pulled off affected stages
        self._bound_pipeline = None  # identity of the pipeline we're bound to
        self._pod_sig: list[tuple[int, int, int]] = []
        if control.pipeline is not None:
            self._rebind(affected=frozenset())

    # -- admission -----------------------------------------------------------
    def submit(self, x: Any, *, slo_class: str | None = None) -> Request:
        req = Request(
            self._next_id, x, submitted_s=self.clock_s, slo_class=slo_class,
            priority=self.class_priority.get(slo_class, 0),
        )
        self._next_id += 1
        self.queue.append(req)
        return req

    def schedule(self, x: Any, at_s: float, *,
                 slo_class: str | None = None) -> Request:
        """Open-loop admission: the request arrives at virtual time ``at_s``
        (a trace timestamp), not when the caller happened to invoke us.
        Future arrivals wait in a heap and are admitted -- or rejected, when
        the admission queue is at ``admission_depth`` -- as the clock passes
        them."""
        req = Request(
            self._next_id, x, submitted_s=float(at_s), slo_class=slo_class,
            priority=self.class_priority.get(slo_class, 0),
        )
        self._next_id += 1
        return self.schedule_request(req)

    def schedule_request(self, req: Request) -> Request:
        """Timestamped admission of an already-created request (the router's
        dispatch path: per-replica clocks must never complete a request
        before its cluster-wide arrival time)."""
        if req.submitted_s <= self.clock_s:
            self._admit_bounded(req)
        else:
            self._arrival_seq += 1
            heapq.heappush(
                self._arrivals, (req.submitted_s, self._arrival_seq, req))
        return req

    def admit(self, req: Request) -> Request:
        """Admit an already-created request (the replica router's path: ids
        are minted cluster-wide, so the per-replica loop must not renumber).
        Unbounded: the router already applied its own admission policy."""
        self.queue.append(req)
        return req

    def _admit_bounded(self, req: Request) -> None:
        if (self.admission_depth is not None
                and len(self.queue) >= self.admission_depth):
            self.rejected.append(req)
            if self._registry is not None:
                self._registry.counter(
                    "requests_rejected", engine="pipelined").inc()
        else:
            self.queue.append(req)

    def _admit_due(self) -> None:
        """Move every arrival whose timestamp has passed into the queue."""
        while self._arrivals and self._arrivals[0][0] <= self.clock_s:
            _, _, req = heapq.heappop(self._arrivals)
            self._admit_bounded(req)

    @property
    def arrivals(self) -> list[Request]:
        """Scheduled requests whose arrival time is still in the future."""
        return [req for _, _, req in self._arrivals]

    @property
    def pending_arrivals(self) -> int:
        return len(self._arrivals)

    @property
    def next_arrival_s(self) -> float | None:
        return self._arrivals[0][0] if self._arrivals else None

    @property
    def backlog(self) -> int:
        """Requests not yet delivered: admission queue + in-flight batches.
        (Future arrivals are offered load, not backlog -- they have not
        entered the system yet.)"""
        return len(self.queue) + sum(len(m.requests) for m in self._inflight)

    # -- one serving round -----------------------------------------------------
    def step(self) -> list[Request]:
        """Advance the virtual clock until the next completion (or idle).

        Pending control-plane events (and unannounced failures discovered by
        the health check) are reconciled first, requeueing exactly the
        in-flight microbatches resident on affected stages.
        """
        done0 = len(self.completed)
        pipe = self.control.pipeline
        if pipe is None:
            raise RuntimeError("bootstrap the control plane before serving")
        if pipe is not self._bound_pipeline:
            # out-of-band swap (e.g. Deployment.replan): nothing carries over
            self._rebind(affected=_ALL)
        elif self._pod_signature() != self._pod_sig:
            # out-of-band in-place recovery (reconcile() called directly, not
            # through step): restarted pods lost their resident batches, moved
            # pods migrated with theirs; timings re-derive either way
            restarted = {
                s for s, (pod, (_, _, restarts0)) in
                enumerate(zip(pipe.pods, self._pod_sig))
                if pod.restarts != restarts0
            }
            self._rebind(affected=frozenset(restarted))
        if self.control.pending or not pipe.healthy():
            self._reconcile()
        self._admit_due()
        self._schedule()
        while len(self.completed) == done0:
            if not self._advance():
                break
        return self.completed[done0:]

    def drain(self, max_rounds: int = 100_000) -> list[Request]:
        """Step until every admitted request completes (or max_rounds).
        Open-loop schedules keep draining through future arrivals: the clock
        jumps across idle gaps in the trace."""
        done: list[Request] = []
        for _ in range(max_rounds):
            if (not self.backlog and not self._arrivals
                    and not self.control.pending):
                break
            done.extend(self.step())
        return done

    # -- metrics ---------------------------------------------------------------
    def metrics(self) -> dict:
        """Serving counters + per-stage occupancy/queue statistics.

        The payload is normalized (``serving.normalize_metrics``): string
        keys everywhere, native Python numbers, JSON round-trip stable.
        """
        done = len(self.completed)
        t = self.clock_s
        return normalize_metrics({
            "mode": "pipelined",
            "completed": done,
            "failed": len(self.failed),
            "rejected": len(self.rejected),
            "backlog": self.backlog,
            "pending_arrivals": self.pending_arrivals,
            "clock_s": t,
            "throughput": done / t if t > 0 else 0.0,
            "retries": sum(r.attempts for r in self.completed),
            "latency": latency_report(self.completed, self.class_targets),
            "microbatches": self._mb_completed,
            "in_flight": len(self._inflight),
            "requeued_microbatches": self._requeues,
            "queue_depth": self.queue_depth,
            "batching": {
                "max_batch": self.max_batch,
                "admission_depth": self.admission_depth,
                "max_batch_seen": self._max_batch_seen,
                "mean_batch": (
                    done / self._mb_completed if self._mb_completed else 0.0),
            },
            "link_s": list(self._link_s),
            "links": [
                {
                    "hop": h,
                    "codec": codec.name if codec is not None else "identity",
                    "raw_bytes": self._link_raw[h],
                    "wire_bytes": self._link_wire[h],
                    "compression_x": (
                        self._link_raw[h] / self._link_wire[h]
                        if self._link_wire[h] > 0 else 1.0
                    ),
                    "link_s": self._link_s[h],
                    "utilization": self._link_busy_s[h] / t if t > 0 else 0.0,
                    "transfers": self._link_xfers[h],
                }
                for h, codec in enumerate(self._link_codecs)
            ],
            "stages": [
                {
                    "stage": st.index,
                    "node": st.pod.node_id,
                    "compute_s": st.compute_s,
                    "occupancy": st.busy_s / t if t > 0 else 0.0,
                    "mean_queue": st.queue_area / t if t > 0 else 0.0,
                    "max_queue": st.max_queue,
                    "microbatches": st.completed,
                }
                for st in self._stages
            ],
        })

    def steady_state_throughput(self, skip_frac: float = 0.5) -> float:
        """Requests/s over the tail of the completions (fill/drain excluded).

        Falls back to the overall mean when the tail window is degenerate
        (too few completions, or the whole window shares one timestamp --
        e.g. a short run whose tail is a single microbatch)."""
        reqs = self.completed
        mean = len(reqs) / self.clock_s if self.clock_s > 0 else 0.0
        if len(reqs) < 4:
            return mean
        i0 = int(len(reqs) * skip_frac)
        t0, t1 = reqs[i0].completed_s, reqs[-1].completed_s
        if t1 <= t0:
            return mean
        return (len(reqs) - 1 - i0) / (t1 - t0)

    # -- reconciliation bridge -------------------------------------------------
    def _pod_signature(self) -> list[tuple[int, int, int]]:
        return [
            (id(pod), pod.node_id, pod.restarts)
            for pod in self.control.pipeline.pods
        ]

    def _reconcile(self) -> list[ReconcileAction]:
        pipe_before = self.control.pipeline
        # stages a pending NodeFailed is about to kill, plus any pod already
        # dead/unhealthy (unannounced failure -> drift repair)
        doomed_nodes = {
            e.node_id
            for e in self.control.pending_events()
            if isinstance(e, NodeFailed)
        }
        affected = {
            s
            for s, pod in enumerate(pipe_before.pods)
            if not pod.alive
            or not self.control.cluster.nodes[pod.node_id].healthy
            or pod.node_id in doomed_nodes
        }
        actions = self.control.reconcile()
        if any(a.kind != "noop" for a in actions):
            self.clock_s += self.recovery_penalty_s
            if self._registry is not None:
                self._registry.counter("reconciles", engine="pipelined").inc()
        if self.control.pipeline is not pipe_before:
            # new pipeline object: version bump, full restart, or reconfigure
            # fallback -- partitions/weights may differ, nothing carries over
            self._rebind(affected=_ALL)
        else:
            # in-place re-placement: partitions preserved, so batches on
            # unaffected stages keep their progress; timings are re-derived
            # (nodes moved, bandwidths re-probed)
            self._rebind(affected=frozenset(affected))
        return actions

    def _rebind(self, affected) -> None:
        """Rebuild stage/link state from the current pipeline.

        ``affected`` is the set of stage indices whose resident microbatches
        must be requeued (or ``"all"``).  Batches elsewhere are re-seated at
        their current position and rescheduled from the current clock.
        """
        control = self.control
        pipe = control.pipeline
        disp = control.dispatcher
        graph = control.desired.graph
        if self.tracer is not None:
            # close every traced batch's open span on the OLD hop/stage
            # geometry (the decomposition tables are about to be rebuilt);
            # re-seated batches reopen below, requeued ones restart from
            # admission
            for mb in self._inflight:
                if mb.traced:
                    self._trace_close(mb, self.clock_s)
        comm = disp.probed if disp.probed is not None else control.cluster.comm
        path = [p.node_id for p in pipe.pods]
        parts = [p.partition for p in pipe.pods]
        codecs = [pipe.hop_codec(h) for h in range(len(path) + 1)]
        compute_s, link_s = service_times(
            parts, path, comm.bw,
            flops_per_node=[n.flops_per_s for n in control.cluster.nodes],
            in_bytes=graph.in_bytes,
            out_bytes=graph.layers[-1].out_bytes,
            dispatcher=disp.leader,
            compression_ratio=pipe.compression_ratio,
            codecs=None if pipe.link_codecs is None else pipe.link_codecs,
        )
        k = len(path)
        # per-hop byte model for the link report: raw boundary bytes (after
        # the legacy compression knob) vs what the codec puts on the wire;
        # a hop with colocated endpoints or zero bytes carries no codec
        hop_bytes = [graph.in_bytes, *pipe.boundary_bytes,
                     graph.layers[-1].out_bytes]
        ends = [(disp.leader, path[0] if path else None)]
        ends += [(path[i], path[i + 1]) for i in range(k - 1)]
        ends += [(path[-1] if path else None, disp.leader)]
        self._link_codecs, self._link_raw, self._link_wire = [], [], []
        for h in range(k + 1):
            raw = float(hop_bytes[h]) / pipe.compression_ratio
            a, b = ends[h]
            active = raw > 0 and a is not None and b is not None and a != b
            codec = codecs[h] if active else None
            self._link_codecs.append(codec)
            self._link_raw.append(raw if active else 0.0)
            self._link_wire.append(
                codec.wire_bytes(raw) if codec is not None
                else (raw if active else 0.0))
        # analytic encode/wire/decode decomposition of each hop window, on
        # the same codec cost model link_s itself was built from -- the
        # tracer tiles observed link windows with these proportions
        flops = [n.flops_per_s for n in control.cluster.nodes]
        self._link_parts = [
            split_hop(
                link_s[h], self._link_codecs[h], self._link_raw[h],
                src_flops=flops[ends[h][0]] if ends[h][0] is not None else 0.0,
                dst_flops=flops[ends[h][1]] if ends[h][1] is not None else 0.0,
            )
            for h in range(k + 1)
        ]
        old_stages = self._stages
        carry_stats = len(old_stages) == k and affected is not _ALL
        self._stages = []
        for i, pod in enumerate(pipe.pods):
            st = StageState(i, pod, compute_s[i], deque(), deque())
            if carry_stats:  # keep occupancy accounting across a re-placement
                prev = old_stages[i]
                st.busy_s, st.queue_area = prev.busy_s, prev.queue_area
                st.max_queue, st.completed = prev.max_queue, prev.completed
            self._stages.append(st)
        self._link_s = link_s
        self._links_busy = [None] * (k + 1)
        if not (carry_stats and len(self._link_busy_s) == k + 1):
            self._link_busy_s = [0.0] * (k + 1)
            self._link_xfers = [0] * (k + 1)
        self._bound_pipeline = pipe
        self._pod_sig = self._pod_signature()

        old = sorted(self._inflight, key=lambda m: m.mb_id)
        self._inflight = []
        requeue: list[Microbatch] = []  # resident on an affected stage: retry
        readmit: list[Microbatch] = []  # on the input hop: free retransmission
        for mb in old:
            kind, idx = mb.location
            if kind == "link" and idx == 0:
                # the dispatcher still holds the input: re-admit without an
                # attempt (no stage ever hosted this batch, nothing was
                # lost) -- true even across a version bump or full restart
                readmit.append(mb)
                continue
            if affected is _ALL:
                requeue.append(mb)
                continue
            if kind in ("queue", "compute", "out"):
                bad = idx in affected
            else:  # riding hop idx: data is between stages idx-1 and idx
                bad = (idx - 1) in affected or (idx < k and idx in affected)
            if bad:
                requeue.append(mb)
                continue
            self._inflight.append(mb)
            if kind in ("queue", "compute"):
                # a compute in progress restarts: mb.x is still the stage input
                mb.location = ("queue", idx)
                self._stages[idx].queue.append(mb)
                if mb.traced:
                    self._trace_open(mb, ("squeue", idx), self.clock_s)
            elif kind == "out":
                self._stages[idx].out.append(mb)
                if mb.traced:
                    self._trace_open(mb, ("out", idx), self.clock_s)
            else:  # hop idx >= 1: retransmit from the source stage's out buffer
                mb.location = ("out", idx - 1)
                self._stages[idx - 1].out.append(mb)
                if mb.traced:
                    self._trace_open(mb, ("out", idx - 1), self.clock_s)
        # back to admission newest-first so it re-admits in original order
        self._requeues += len(requeue)
        if requeue and self._registry is not None:
            self._registry.counter(
                "requeued_microbatches", engine="pipelined").inc(len(requeue))
        retried = {id(mb) for mb in requeue}
        for mb in sorted(requeue + readmit, key=lambda m: -m.mb_id):
            self._readmit(mb.requests, retry=id(mb) in retried)

    def evacuate(self) -> list[tuple[Request, bool]]:
        """Strip every undelivered request out of the engine (the router's
        replica-retirement path) and reset the stage/link state.

        Returns ``(request, charged)`` pairs in admission order, applying
        the same classification ``_rebind`` uses on recovery: a request
        resident on a stage or a non-input link is charged (its work was
        lost), an input-hop rider or a still-queued request is free (the
        dispatcher still holds the input)."""
        out: list[tuple[Request, bool]] = []
        for mb in sorted(self._inflight, key=lambda m: m.mb_id):
            charged = mb.location != ("link", 0)
            if charged:
                self._requeues += 1
            out.extend((req, charged) for req in mb.requests)
        out.extend((req, False) for req in self.queue)
        # future arrivals ride along uncharged: they never entered the system
        out.extend(
            (req, False)
            for _, _, req in sorted(self._arrivals)
        )
        if self.tracer is not None:
            # evacuated requests restart on another engine whose clock is
            # unrelated to ours: drop their partial timelines here so the
            # receiving engine re-attributes their whole life (lost work
            # shows up as queueing there, never as overlapping spans)
            self.tracer.restart_many(
                {req.req_id for req, _ in out
                 if self.tracer.sampled(req.req_id)})
        self._inflight.clear()
        self.queue.clear()
        self._arrivals.clear()
        self._links_busy = [None] * len(self._links_busy)
        for st in self._stages:
            st.queue.clear()
            st.out.clear()
            st.current = None
            st.reserved = 0
        return out

    # -- discrete-event core ---------------------------------------------------
    def _elapse(self, t: float) -> None:
        """Advance the clock to ``t``, integrating queue occupancy."""
        dt = max(0.0, t - self.clock_s)
        for st in self._stages:
            st.queue_area += len(st.queue) * dt
        self.clock_s = max(self.clock_s, t)

    def _advance(self) -> bool:
        """Pop the earliest event batch off the virtual clock; False if idle.

        A scheduled arrival is an event like any other: when it precedes
        every pending compute/transfer (or the pipeline is idle), the clock
        jumps to it and admission re-runs."""
        pend = [m for m in self._inflight if m.location[0] in ("compute", "link")]
        times = [m.ready_at for m in pend]
        arrival = self.next_arrival_s
        if not times:
            if arrival is None:
                return False  # idle
            self._elapse(arrival)  # idle gap in the trace: jump to the arrival
            self._admit_due()
            self._schedule()
            return True
        t = min(times)
        if t == float("inf"):
            # every pending event is a transfer on a dead link: it can never
            # finish, so retry the riders instead of hanging callers that
            # loop on backlog.  attempts bound the retries (-> failed), the
            # sync loop's liveness guarantee.
            self._requeue_stalled([m for m in pend if m.ready_at == float("inf")])
            self._schedule()
            return True
        if arrival is not None and arrival < t:
            self._elapse(arrival)
            self._admit_due()
            self._schedule()
            return True
        self._elapse(t)
        self._admit_due()
        k = len(self._stages)
        for mb in sorted(pend, key=lambda m: m.mb_id):
            if mb.ready_at > t:
                continue
            kind, idx = mb.location
            if kind == "compute":
                st = self._stages[idx]
                part = st.pod.partition
                mb.x = self.control.pipeline.executor(part.start, part.stop, mb.x)
                st.busy_s += st.compute_s
                st.completed += 1
                st.current = None
                mb.stage = idx + 1
                mb.location = ("out", idx)
                st.out.append(mb)
                if mb.traced:
                    self._trace_close(mb, self.clock_s)  # exec span
                    self._trace_open(mb, ("out", idx), self.clock_s)
            else:  # transfer on hop idx finished
                self._links_busy[idx] = None
                self._link_busy_s[idx] += self._link_s[idx]
                self._link_xfers[idx] += 1
                if mb.traced:
                    self._trace_close(mb, self.clock_s)  # encode/wire/decode
                codec = self._link_codecs[idx] if idx < len(self._link_codecs) else None
                if codec is not None:
                    executor = self.control.pipeline.executor
                    if (idx != k and codec.name
                            in getattr(executor, "fused_codecs", ())):
                        # fused decode: the receiving stage's first op
                        # consumes the wire payload directly (e.g. int8 ->
                        # dequant-matmul), so hand over the still-encoded
                        # activation instead of eagerly decoding it
                        from repro.dataplane.base import EncodedActivation

                        mb.x = EncodedActivation(codec, codec.encode(mb.x))
                    else:
                        # the receiver sees decode(encode(x)): the codec's
                        # real transform (Pallas int8 stack, fp16, top-k)
                        # runs on the activations riding the wire
                        mb.x = codec.transcode(mb.x)
                if idx == k:
                    self._complete(mb)
                else:
                    st = self._stages[idx]
                    st.reserved -= 1
                    st.queue.append(mb)
                    mb.location = ("queue", idx)
                    if mb.traced:
                        self._trace_open(mb, ("squeue", idx), self.clock_s)
        self._schedule()
        return True

    def _schedule(self) -> None:
        """Start every action the current state allows (fixpoint)."""
        k = len(self._stages)
        progress = True
        while progress:
            progress = False
            # sends, downstream-first, so freed slots propagate upstream
            for s in range(k - 1, -1, -1):
                st = self._stages[s]
                if not st.out:
                    continue
                h = s + 1  # outgoing hop index
                if self._links_busy[h] is not None:
                    continue
                if h < k:
                    dst = self._stages[h]
                    if len(dst.queue) + dst.reserved >= self.queue_depth:
                        continue  # backpressure: no slot downstream
                    dst.reserved += 1
                    dst.max_queue = max(dst.max_queue, len(dst.queue) + dst.reserved)
                mb = st.out.popleft()
                if mb.traced:
                    self._trace_close(mb, self.clock_s)  # out-buffer wait
                    self._trace_open(mb, ("xfer", h), self.clock_s)
                mb.location = ("link", h)
                mb.ready_at = self.clock_s + self._link_s[h]
                self._links_busy[h] = mb
                progress = True
            # compute starts: serial stage, blocked while its out buffer holds
            for s in range(k):
                st = self._stages[s]
                if st.current is None and not st.out and st.queue:
                    mb = st.queue.popleft()
                    if mb.traced:
                        self._trace_close(mb, self.clock_s)  # stage-queue wait
                        self._trace_open(mb, ("exec", s), self.clock_s)
                    st.current = mb
                    mb.location = ("compute", s)
                    mb.ready_at = self.clock_s + st.compute_s
                    progress = True
            # admission: one microbatch per free input hop + free slot.
            # Continuous batching: with max_batch set, coalesce everything
            # queued (up to the cap) into one batch instead of the fixed
            # microbatch target -- queue pressure dynamically widens batches.
            st0 = self._stages[0]
            if (
                self.queue
                and self._links_busy[0] is None
                and len(st0.queue) + st0.reserved < self.queue_depth
            ):
                cap = self.max_batch if self.max_batch is not None else self.microbatch
                take = min(cap, len(self.queue))
                batch = self._take_batch(take)
                self._max_batch_seen = max(self._max_batch_seen, len(batch))
                mb = Microbatch(
                    self._next_mb, batch,
                    jnp.stack([r.x for r in batch]),
                    stage=0, location=("link", 0),
                    ready_at=self.clock_s + self._link_s[0],
                )
                tr = self.tracer
                if tr is not None:
                    traced = [r for r in batch if tr.sampled(r.req_id)]
                    if traced:
                        mb.traced = traced
                        for r in traced:
                            # the admission-queue span runs from the last
                            # (re-)entry into admission to now
                            self._emit_span(
                                r, "queue", tr.queue_take(r), self.clock_s)
                        self._trace_open(mb, ("xfer", 0), self.clock_s)
                self._next_mb += 1
                self._links_busy[0] = mb
                st0.reserved += 1
                st0.max_queue = max(st0.max_queue, len(st0.queue) + st0.reserved)
                self._inflight.append(mb)
                progress = True

    def _take_batch(self, take: int) -> list[Request]:
        """Pop ``take`` requests off admission, highest priority class first,
        FIFO within a class (the common all-one-priority case stays a pure
        popleft loop)."""
        if take >= len(self.queue) or all(
            r.priority == self.queue[0].priority for r in self.queue
        ):
            return [self.queue.popleft() for _ in range(take)]
        order = sorted(range(len(self.queue)),
                       key=lambda i: (-self.queue[i].priority, i))
        chosen = sorted(order[:take])  # admission order within the batch
        batch = [self.queue[i] for i in chosen]
        left = set(chosen)
        self.queue = deque(
            r for i, r in enumerate(self.queue) if i not in left)
        return batch

    def _readmit(self, requests: list[Request], *, retry: bool) -> None:
        """Send a microbatch's requests back to the front of admission.

        ``retry=True`` charges an attempt (the batch was resident on a
        failed resource) and moves exhausted requests to ``failed``;
        ``retry=False`` is a free retransmission (input hop)."""
        tr = self.tracer
        for req in reversed(requests):
            if retry:
                req.attempts += 1
                if req.attempts >= self.max_attempts:
                    self.failed.append(req)
                    if tr is not None:
                        tr.forget(req.req_id)
                    if self._registry is not None:
                        self._registry.counter(
                            "requests_failed", engine="pipelined").inc()
                    continue
            self.queue.appendleft(req)
            if tr is not None and tr.sampled(req.req_id):
                tr.queue_open(req.req_id, self.clock_s)

    def _requeue_stalled(self, stalled: list[Microbatch]) -> None:
        """Pull transfers off dead links and send their requests back to
        admission with an attempt (only link rides can be infinite -- a
        stage compute is finite whenever its node models flops at all)."""
        self._requeues += len(stalled)
        for mb in sorted(stalled, key=lambda m: -m.mb_id):
            if mb.traced:
                self._trace_close(mb, self.clock_s)  # truncated dead-link ride
            h = mb.location[1]
            self._links_busy[h] = None
            if h < len(self._stages):  # hop h had reserved stage h's in-slot
                self._stages[h].reserved -= 1
            self._inflight.remove(mb)
            self._readmit(mb.requests, retry=True)

    def _complete(self, mb: Microbatch) -> None:
        self._inflight.remove(mb)
        self._mb_completed += 1
        reg = self._registry
        if reg is not None:
            reg.counter("requests_completed", engine="pipelined").inc(
                len(mb.requests))
            reg.counter("microbatches_completed", engine="pipelined").inc()
        for i, req in enumerate(mb.requests):
            req.result = mb.x[i]
            req.completed_s = self.clock_s
            self.completed.append(req)
            if reg is not None:
                reg.histogram(
                    "request_latency_s", engine="pipelined",
                ).observe(req.latency_s)

    # -- span tracing ----------------------------------------------------------
    # A microbatch carries at most one OPEN phase (``mb.phase``): the
    # engine-internal state it is currently occupying, tagged by location
    # kind -- ("squeue", s) stage-input wait, ("exec", s) compute,
    # ("out", s) out-buffer wait, ("xfer", h) riding hop h.  Every state
    # transition closes the open phase (emitting one span per traced
    # request -- link windows are tiled into encode/wire/decode via the
    # per-hop analytic parts) and opens the next at the same clock tick,
    # so a completed request's spans tile [submitted_s, completed_s)
    # exactly.

    def _trace_open(self, mb: Microbatch, phase: tuple, t: float) -> None:
        mb.phase = phase
        mb.phase_t0 = t

    def _trace_close(self, mb: Microbatch, t1: float) -> None:
        if mb.phase is None:
            return
        name, idx = mb.phase
        t0 = mb.phase_t0
        mb.phase = None
        if t1 <= t0:
            return
        emit = self.tracer.record_many
        gen = self.control.generation
        if name == "xfer":
            parts = (self._link_parts[idx] if idx < len(self._link_parts)
                     else (0.0, t1 - t0, 0.0))
            codec = (self._link_codecs[idx]
                     if idx < len(self._link_codecs) else None)
            cname = codec.name if codec is not None else None
            for phase, a, b in split_window(t0, t1, parts):
                emit(mb.traced, phase, a, b, hop=idx, codec=cname,
                     generation=gen)
        elif name == "exec":
            emit(mb.traced, "exec", t0, t1, stage=idx, generation=gen)
        else:  # "squeue" / "out": stage-attributed queueing
            emit(mb.traced, "queue", t0, t1, stage=idx, generation=gen)

    def _emit_span(self, req: Request, phase: str, t0: float, t1: float, *,
                   stage: int | None = None, hop: int | None = None,
                   codec: str | None = None) -> None:
        self.tracer.record(
            req.req_id, phase, t0, t1, stage, hop,
            req.replica, req.tenant, codec,
            self.control.generation, req.attempts)


class ReplicatedServingLoop:
    """Cluster-wide request router over R per-replica pipelined engines.

    Each replica runs its own ``PipelinedServingLoop`` (its own stages,
    links, and virtual clock); the router co-simulates them on one shared
    timeline by always advancing the *lagging* replica (the discrete-event
    rule: process the earliest pending event first).  Admission policy:

      * **shortest expected wait** -- a request goes to the replica whose
        ``clock + backlog x predicted microbatch period`` is smallest (the
        period comes from the replica's as-deployed plan, so routing adapts
        when a replica is re-placed onto slower links);
      * **bounded per-replica backlog** -- a replica holds at most
        ``replica_backlog`` undelivered requests; when every live replica is
        full, requests wait in the cluster-wide queue (backpressure composes
        with the per-stage ``queue_depth`` bounds inside each engine);
      * **retirement** -- when a replica's group can no longer host the
        model (its control plane's recovery raises), the replica is retired:
        its resident requests are reclaimed into the cluster-wide queue
        (stage residents charged an attempt, input-hop riders and
        still-queued requests free) and re-routed to the survivors.

    Same surface as ``PipelinedServingLoop`` (``submit`` / ``step`` /
    ``drain`` / ``metrics`` / ``backlog`` / ``steady_state_throughput``), so
    ``Deployment`` and the benchmarks treat R pipelines as one.
    """

    def __init__(
        self,
        replicaset: ReplicaSet,
        *,
        microbatch: int = 4,
        queue_depth: int = 2,
        max_attempts: int = 5,
        recovery_penalty_s: float = 0.25,
        replica_backlog: int = 32,
        max_batch: int | None = None,
        admission_depth: int | None = None,
        class_priority: dict[str, int] | None = None,
        class_targets: dict[str, float | None] | None = None,
        tracer=None,
        registry=None,
    ):
        if replica_backlog < 1:
            raise ValueError("replica_backlog must be >= 1")
        if admission_depth is not None and admission_depth < 1:
            raise ValueError("admission_depth must be >= 1")
        self.replicaset = replicaset
        self.tracer = tracer
        self._registry = registry
        # the admission bound lives at the router (cluster-wide queue); the
        # per-replica engines are bound by replica_backlog, never rejecting.
        # tracer/registry ride along so autoscaler-grown replicas
        # (add_replica) record into the same deployment-wide plane
        self._engine_kw = dict(
            microbatch=microbatch, queue_depth=queue_depth,
            max_attempts=max_attempts, recovery_penalty_s=recovery_penalty_s,
            max_batch=max_batch, class_priority=class_priority,
            class_targets=class_targets, tracer=tracer, registry=registry,
        )
        self.loops = [
            PipelinedServingLoop(control, **self._engine_kw)
            for control in replicaset.controls
        ]
        self.microbatch = int(microbatch)
        self.max_attempts = int(max_attempts)
        self.replica_backlog = int(replica_backlog)
        self.admission_depth = (
            None if admission_depth is None else int(admission_depth))
        self.class_priority = dict(class_priority or {})
        self.class_targets = dict(class_targets or {})
        self.autoscaler = None  # attached by deploy() when the spec asks
        self.queue: deque[Request] = deque()  # cluster-wide admission
        self.completed: list[Request] = []
        self.rejected: list[Request] = []
        self._arrivals: list[tuple[float, int, Request]] = []
        self._arrival_seq = 0
        self._router_failed: list[Request] = []
        self._next_id = 0
        self.dispatched = [0] * len(self.loops)
        self._reclaimed = [False] * len(self.loops)

    # -- aggregate views -------------------------------------------------------
    @property
    def clock_s(self) -> float:
        return max((loop.clock_s for loop in self.loops), default=0.0)

    @property
    def failed(self) -> list[Request]:
        return self._router_failed + [
            req for loop in self.loops for req in loop.failed
        ]

    @property
    def backlog(self) -> int:
        """Undelivered requests anywhere: router queue + every replica
        (dispatched-but-not-yet-arrived requests included -- they are
        committed to a replica even though its clock lags their timestamp)."""
        return len(self.queue) + sum(
            loop.backlog + loop.pending_arrivals for loop in self.loops)

    @property
    def pending(self) -> int:
        return self.replicaset.pending

    @property
    def arrivals(self) -> list[Request]:
        """Scheduled requests the router has not admitted yet."""
        return [req for _, _, req in self._arrivals]

    @property
    def pending_arrivals(self) -> int:
        return len(self._arrivals)

    # -- admission -------------------------------------------------------------
    def submit(self, x: Any, *, slo_class: str | None = None) -> Request:
        req = Request(
            self._next_id, x, submitted_s=self.clock_s, slo_class=slo_class,
            priority=self.class_priority.get(slo_class, 0),
        )
        self._next_id += 1
        self.queue.append(req)
        return req

    def schedule(self, x: Any, at_s: float, *,
                 slo_class: str | None = None) -> Request:
        """Open-loop admission by trace timestamp (see the engine's
        ``schedule``); the router admits arrivals as its clock passes them
        and sheds load past ``admission_depth``."""
        req = Request(
            self._next_id, x, submitted_s=float(at_s), slo_class=slo_class,
            priority=self.class_priority.get(slo_class, 0),
        )
        self._next_id += 1
        if req.submitted_s <= self.clock_s:
            self.queue.append(req)
            self._shed()
        else:
            self._arrival_seq += 1
            heapq.heappush(
                self._arrivals, (req.submitted_s, self._arrival_seq, req))
        return req

    def _admit_due(self) -> None:
        """Admit every arrival the router clock has passed, dispatch, then
        shed whatever exceeds the cluster-wide admission bound (newest
        first, so earlier arrivals keep their place in line)."""
        due = False
        while self._arrivals and self._arrivals[0][0] <= self.clock_s:
            _, _, req = heapq.heappop(self._arrivals)
            self.queue.append(req)
            due = True
        if due:
            self._dispatch()
            self._shed()

    def _shed(self) -> None:
        if self.admission_depth is None:
            return
        while len(self.queue) > self.admission_depth:
            self.rejected.append(self.queue.pop())
            if self._registry is not None:
                self._registry.counter(
                    "requests_rejected", engine="router").inc()

    # -- one serving round -----------------------------------------------------
    def step(self) -> list[Request]:
        """Advance the lagging replica until some replica completes a
        request (or the whole set is idle)."""
        done0 = len(self.completed)
        rset = self.replicaset
        for r in range(len(self.loops)):
            if rset.retired[r] and not self._reclaimed[r]:
                self._reclaim(r)  # retired out of band (direct reconcile())
        rset.advance_rollout()
        if self.autoscaler is not None:
            self.autoscaler.observe(self)
        self._admit_due()
        self._dispatch()
        guard = 0
        while len(self.completed) == done0:
            guard += 1
            if guard > 1_000_000:
                raise RuntimeError("replica router made no progress")
            live = rset.live_indices()
            if not live:
                # every replica retired: grow from the standby pool if an
                # autoscaler can, else nothing left can ever serve
                if (self.autoscaler is not None
                        and self.autoscaler.restore(self)):
                    continue
                while self.queue:
                    self._router_failed.append(self.queue.popleft())
                while self._arrivals:
                    _, _, req = heapq.heappop(self._arrivals)
                    self._router_failed.append(req)
                break
            active = [
                r for r in live
                if self.loops[r].backlog or self.loops[r].pending_arrivals
                or self.loops[r].control.pending
            ]
            if not active:
                if self._arrivals:
                    # idle gap in the trace: jump every live clock to the
                    # next arrival (the replicas share one timeline)
                    t = self._arrivals[0][0]
                    for i in live:
                        self.loops[i]._elapse(t)
                    self._admit_due()
                    self._dispatch()
                    continue
                break  # idle (the dispatch above drained the router queue)
            r = min(active, key=lambda i: (self.loops[i].clock_s, i))
            try:
                self.completed.extend(self.loops[r].step())
            except RuntimeError as e:
                rset.mark_retired(r, str(e))
                self._reclaim(r)
            rset.advance_rollout()
            if self.autoscaler is not None:
                self.autoscaler.observe(self)
            self._admit_due()
            self._dispatch()
        return self.completed[done0:]

    def drain(self, max_rounds: int = 100_000) -> list[Request]:
        done: list[Request] = []
        for _ in range(max_rounds):
            if (not self.backlog and not self._arrivals
                    and not self.pending):
                break
            done.extend(self.step())
        return done

    # -- routing ---------------------------------------------------------------
    def _expected_ready_s(self, r: int) -> float:
        """Shortest-expected-wait estimate: the replica's clock plus its
        backlog served at the planner-predicted microbatch period."""
        loop = self.loops[r]
        plan = self.replicaset.controls[r].last_plan
        rate = plan.predicted_throughput if plan is not None else 0.0
        period = 1.0 / rate if rate > 0 and rate != float("inf") else 0.0
        batches = loop.backlog // max(1, loop.microbatch) + 1
        return loop.clock_s + batches * period

    def _dispatch(self) -> None:
        """Route router-queue requests to replicas; stop at backpressure."""
        while self.queue:
            best = None
            for r in self.replicaset.live_indices():
                held = self.loops[r].backlog + self.loops[r].pending_arrivals
                if held >= self.replica_backlog:
                    continue
                key = (self._expected_ready_s(r), held, r)
                if best is None or key < best[0]:
                    best = (key, r)
            if best is None:
                return  # every live replica is full (or none is live)
            r = best[1]
            req = self.queue.popleft()
            req.replica = r
            # timestamped handoff: a lagging replica must not serve the
            # request before its cluster-wide arrival time
            self.loops[r].schedule_request(req)
            self.dispatched[r] += 1

    def add_replica(self, control: ControlPlane, group) -> int:
        """Attach a freshly-bootstrapped replica (the autoscaler's grow
        path).  The new engine's clock starts at the router's current time,
        so its completions never predate its birth."""
        r = self.replicaset.add_replica(control, group)
        loop = PipelinedServingLoop(control, **self._engine_kw)
        loop.clock_s = self.clock_s
        self.loops.append(loop)
        self.dispatched.append(0)
        self._reclaimed.append(False)
        self._dispatch()
        return r

    def _reclaim(self, r: int) -> None:
        """Pull every request out of a retired replica and re-route it.

        The engine owns the requeue semantics (``evacuate``): requests
        resident on the replica's stages/links come back charged an attempt
        (their work was lost), input-hop riders and still-queued requests
        free."""
        self._reclaimed[r] = True
        # front of the router queue, original relative order preserved
        for req, charged in reversed(self.loops[r].evacuate()):
            if charged:
                req.attempts += 1
                if req.attempts >= self.max_attempts:
                    self._router_failed.append(req)
                    continue
            self.queue.appendleft(req)

    # -- metrics ---------------------------------------------------------------
    def metrics(self) -> dict:
        done = len(self.completed)
        t = self.clock_s
        live = set(self.replicaset.live_indices())
        out = {
            "mode": "replicated",
            "completed": done,
            "failed": len(self.failed),
            "rejected": len(self.rejected),
            "backlog": self.backlog,
            "pending_arrivals": self.pending_arrivals,
            "clock_s": t,
            "throughput": done / t if t > 0 else 0.0,
            "retries": sum(r.attempts for r in self.completed),
            "latency": latency_report(self.completed, self.class_targets),
            "n_replicas": len(self.loops),
            "live_replicas": len(live),
            "router": {
                "policy": "shortest_expected_wait",
                "replica_backlog": self.replica_backlog,
                "admission_depth": self.admission_depth,
                "queued": len(self.queue),
                "dispatched": list(self.dispatched),
            },
            "replicas": [
                {"replica": r, "retired": r not in live, **loop.metrics()}
                for r, loop in enumerate(self.loops)
            ],
        }
        if self.autoscaler is not None:
            out["autoscaler"] = self.autoscaler.metrics()
        return normalize_metrics(out)

    def steady_state_throughput(self, skip_frac: float = 0.5) -> float:
        """Aggregate requests/s: the sum of the live replicas' steady-state
        rates (each measured on its own completion tail)."""
        return float(sum(
            self.loops[r].steady_state_throughput(skip_frac)
            for r in self.replicaset.live_indices()
        ))
