"""Cluster + pod lifecycle simulation (the microK8s layer, in-process).

``EdgeCluster`` holds nodes and the true link bandwidths; ``Pod``s host one
partition each and forward intermediate activations to the next pod --
latency is simulated from bytes / bandwidth (the paper's FIFO+TCP transport)
with optional boundary int8 compression (the ZFP/LZ4 analogue).  Node
failures mark pods dead; the dispatcher reschedules onto healthy nodes and
pods re-instantiate their partition from the artifact store, exactly the
SEIFER recovery path.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import numpy as np

from repro.core.graph import Partition
from repro.core.placement import CommGraph


@dataclasses.dataclass
class Node:
    node_id: int
    capacity_bytes: float
    flops_per_s: float = 0.0
    healthy: bool = True


class EdgeCluster:
    """Nodes + symmetric link bandwidths; node 0 is the dispatcher host."""

    def __init__(self, comm: CommGraph, flops_per_s: float = 0.0):
        self.comm = comm
        self.nodes = [
            Node(i, comm.node_capacity[i], flops_per_s) for i in range(comm.n)
        ]
        # topology/health generation: bumped on every mutation, so planner
        # and dispatcher caches can key their sublattices on it
        self.generation = 0

    @property
    def n(self) -> int:
        return len(self.nodes)

    def healthy_ids(self) -> list[int]:
        return [n.node_id for n in self.nodes if n.healthy]

    def fail(self, node_id: int) -> None:
        self.nodes[node_id].healthy = False
        self.generation += 1

    def heal(self, node_id: int) -> None:
        self.nodes[node_id].healthy = True
        self.generation += 1

    def add_node(self, comm: CommGraph, flops_per_s: float | None = None) -> int:
        """Grow the cluster by one node; ``comm`` is the expanded graph.

        Existing nodes keep their ids and health state.  Returns the new
        node's id.  Per the paper, a node *addition* forces a full cluster
        restart -- that policy lives in the control plane, not here.
        """
        if comm.n != self.n + 1:
            raise ValueError(f"expected a {self.n + 1}-node comm graph, got {comm.n}")
        new_id = self.n
        # keep the existing block (incl. any degraded links); adopt only the
        # joining node's row/column and capacity from the expanded graph
        bw = comm.bw.copy()
        bw[:new_id, :new_id] = self.comm.bw
        cap = np.append(self.comm.node_capacity, comm.node_capacity[new_id])
        self.comm = CommGraph(bw=bw, node_capacity=cap)
        if flops_per_s is None:
            flops_per_s = self.nodes[-1].flops_per_s if self.nodes else 0.0
        self.nodes.append(Node(new_id, cap[new_id], flops_per_s))
        self.generation += 1
        return new_id

    def degrade_link(self, a: int, b: int, factor: float) -> None:
        """Scale the true bandwidth of link (a, b) by ``factor`` (symmetric)."""
        bw = self.comm.bw.copy()
        bw[a, b] *= factor
        bw[b, a] *= factor
        self.comm = CommGraph(bw=bw, node_capacity=self.comm.node_capacity.copy())
        self.generation += 1

    def degraded_comm(self) -> CommGraph:
        """CommGraph with failed nodes' capacity zeroed and links cut."""
        bw = self.comm.bw.copy()
        cap = self.comm.node_capacity.copy()
        for node in self.nodes:
            if not node.healthy:
                bw[node.node_id, :] = 0.0
                bw[:, node.node_id] = 0.0
                cap[node.node_id] = 0.0
        return CommGraph(bw=bw, node_capacity=cap)

    def true_bandwidth(self, a: int, b: int) -> float:
        return float(self.comm.bw[a, b])


@dataclasses.dataclass
class Pod:
    """One inference pod: runtime container + IO container, simulated."""

    pod_id: str
    node_id: int
    partition: Partition
    version: int
    restarts: int = 0
    alive: bool = True

    def restart_on(self, node_id: int) -> None:
        self.node_id = node_id
        self.restarts += 1
        self.alive = True


ExecutorFn = Callable[[int, int, Any], Any]  # (start_layer, stop_layer, x) -> y


@dataclasses.dataclass
class StepTrace:
    compute_s: list[float]
    link_s: list[float]

    @property
    def bottleneck_s(self) -> float:
        return max(self.link_s, default=0.0)

    @property
    def period_s(self) -> float:
        return max(self.compute_s + self.link_s, default=0.0)

    @property
    def e2e_s(self) -> float:
        return sum(self.compute_s) + sum(self.link_s)


class InferencePipeline:
    """Chain of pods executing a partitioned model over simulated links."""

    def __init__(
        self,
        cluster: EdgeCluster,
        pods: Sequence[Pod],
        executor: ExecutorFn,
        boundary_bytes: Sequence[float],
        compression_ratio: float = 1.0,
        link_codecs: Sequence[str] | None = None,
        execution=None,
    ):
        self.cluster = cluster
        self.pods = list(pods)
        self.executor = executor
        self.boundary_bytes = list(boundary_bytes)
        self.compression_ratio = compression_ratio
        # transfer codec per hop (len k+1, service_times indexing); None =
        # all-identity (direct lifecycle construction, pre-dataplane tests)
        self.link_codecs = list(link_codecs) if link_codecs is not None else None
        # execution knob (repro.core.execution.ExecutionKnob | None):
        # hop_codec() configures knob-aware codecs with it, so e.g. int8
        # links quantize through the Pallas kernel when the spec says so
        self.execution = execution

    def hop_codec(self, h: int):
        """The ``repro.dataplane.Codec`` riding hop ``h`` (None = raw).

        Knob-aware codecs (those with a ``use_pallas`` attribute) are
        returned as ``configured()`` copies carrying the pipeline's
        execution knob; the registry singletons stay untouched."""
        if self.link_codecs is None or not 0 <= h < len(self.link_codecs):
            return None
        from repro.dataplane import get_codec

        codec = get_codec(self.link_codecs[h])
        if (codec is not None and self.execution is not None
                and getattr(self.execution, "use_pallas", False)
                and hasattr(codec, "use_pallas")):
            codec = codec.configured(
                use_pallas=self.execution.use_pallas,
                interpret=self.execution.interpret,
            )
        return codec

    def wire_bytes(self, boundary_idx: int) -> float:
        """On-wire bytes of partition boundary ``boundary_idx`` (hop
        ``boundary_idx + 1``) after compression_ratio and the hop codec."""
        raw = self.boundary_bytes[boundary_idx] / self.compression_ratio
        codec = self.hop_codec(boundary_idx + 1)
        return codec.wire_bytes(raw) if codec is not None else raw

    def path(self) -> list[int]:
        return [p.node_id for p in self.pods]

    def healthy(self) -> bool:
        return all(
            p.alive and self.cluster.nodes[p.node_id].healthy for p in self.pods
        )

    def run(self, x: Any) -> tuple[Any, StepTrace]:
        """One inference through the chain; raises if a pod is dead."""
        if not self.healthy():
            raise RuntimeError("pipeline degraded: dead pod or failed node")
        compute_s, link_s = [], []
        for idx, pod in enumerate(self.pods):
            x = self.executor(pod.partition.start, pod.partition.stop, x)
            node = self.cluster.nodes[pod.node_id]
            compute_s.append(
                pod.partition.flops / node.flops_per_s if node.flops_per_s else 0.0
            )
            if idx < len(self.pods) - 1:
                bw = self.cluster.true_bandwidth(
                    pod.node_id, self.pods[idx + 1].node_id
                )
                bytes_ = self.wire_bytes(idx)
                link_s.append(float("inf") if bw <= 0 else bytes_ / bw)
                codec = self.hop_codec(idx + 1)
                if codec is not None and pod.node_id != self.pods[idx + 1].node_id:
                    if codec.name in getattr(self.executor, "fused_codecs", ()):
                        # the receiving stage decodes inside its first op
                        # (fused dequant-matmul): hand over the wire payload
                        from repro.dataplane.base import EncodedActivation

                        x = EncodedActivation(codec, codec.encode(x))
                    else:
                        # the receiver sees the decoded payload: lossy codecs
                        # really alter the activations crossing the wire
                        x = codec.transcode(x)
        return x, StepTrace(compute_s, link_s)

    def mark_node_failed(self, node_id: int) -> list[Pod]:
        """k8s node-down event: pods on the node become dead."""
        dead = []
        for p in self.pods:
            if p.node_id == node_id:
                p.alive = False
                dead.append(p)
        return dead
