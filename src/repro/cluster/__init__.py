from repro.cluster.dispatcher import Dispatcher
from repro.cluster.lifecycle import EdgeCluster, InferencePipeline, Node, Pod
from repro.cluster.store import ArtifactStore
from repro.cluster.watch import ModelWatcher

__all__ = [
    "ArtifactStore",
    "Dispatcher",
    "EdgeCluster",
    "InferencePipeline",
    "ModelWatcher",
    "Node",
    "Pod",
]
