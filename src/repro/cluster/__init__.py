from repro.cluster.controlplane import (
    ControlPlane,
    DesiredState,
    ObservedState,
    ReconcileAction,
)
from repro.cluster.dispatcher import DeploymentPlan, Dispatcher
from repro.cluster.events import (
    ClusterEvent,
    LinkDegraded,
    NodeFailed,
    NodeJoined,
    VersionBumped,
)
from repro.cluster.lifecycle import EdgeCluster, InferencePipeline, Node, Pod
from repro.cluster.serving import Request, ServingLoop
from repro.cluster.store import ArtifactStore
from repro.cluster.watch import ModelWatcher

__all__ = [
    "ArtifactStore",
    "ClusterEvent",
    "ControlPlane",
    "DeploymentPlan",
    "DesiredState",
    "Dispatcher",
    "EdgeCluster",
    "InferencePipeline",
    "LinkDegraded",
    "ModelWatcher",
    "Node",
    "NodeFailed",
    "NodeJoined",
    "ObservedState",
    "Pod",
    "ReconcileAction",
    "Request",
    "ServingLoop",
    "VersionBumped",
]
