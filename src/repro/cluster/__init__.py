from repro.cluster.autoscale import Autoscaler, ScaleEvent
from repro.cluster.controlplane import (
    ControlPlane,
    DesiredState,
    ObservedState,
    ReconcileAction,
    ReplicaSet,
)
from repro.cluster.dispatcher import DeploymentPlan, Dispatcher
from repro.cluster.events import (
    ClusterEvent,
    LinkDegraded,
    NodeFailed,
    NodeJoined,
    VersionBumped,
)
from repro.cluster.engine import (
    Microbatch,
    PipelinedServingLoop,
    ReplicatedServingLoop,
    StageState,
)
from repro.cluster.lifecycle import EdgeCluster, InferencePipeline, Node, Pod
from repro.cluster.serving import (
    Request,
    ServingLoop,
    latency_report,
    latency_stats,
)
from repro.cluster.store import ArtifactStore
from repro.cluster.watch import ModelWatcher

__all__ = [
    "ArtifactStore",
    "Autoscaler",
    "ClusterEvent",
    "ControlPlane",
    "DeploymentPlan",
    "DesiredState",
    "Dispatcher",
    "EdgeCluster",
    "InferencePipeline",
    "LinkDegraded",
    "Microbatch",
    "ModelWatcher",
    "Node",
    "NodeFailed",
    "NodeJoined",
    "ObservedState",
    "PipelinedServingLoop",
    "Pod",
    "ReconcileAction",
    "ReplicaSet",
    "ReplicatedServingLoop",
    "Request",
    "ScaleEvent",
    "ServingLoop",
    "StageState",
    "VersionBumped",
    "latency_report",
    "latency_stats",
]
