"""Jit'd wrapper for the chunked SSD scan (Pallas on TPU, jnp ref on host)."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.ssm_scan.kernel import ssd_chunked_tpu
from repro.kernels.ssm_scan.ref import ssd_ref


@partial(jax.jit, static_argnames=("chunk", "use_pallas", "interpret"))
def ssd_chunked(xs, bm, cm, dt, a, *, chunk: int = 128, use_pallas: bool = False,
                interpret: bool = False):
    """Chunked selective-state scan.  Returns y (B,S,H,dh) f32."""
    if use_pallas:
        return ssd_chunked_tpu(xs, bm, cm, dt, a, chunk=chunk, interpret=interpret)
    y, _ = ssd_ref(xs, bm, cm, dt, a, chunk=chunk)
    return y
