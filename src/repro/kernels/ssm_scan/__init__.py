from repro.kernels.ssm_scan.ops import ssd_chunked

__all__ = ["ssd_chunked"]
