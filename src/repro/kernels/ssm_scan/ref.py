"""Pure-jnp oracle for the chunked SSD (Mamba2) scan.

Inputs are the post-projection, post-conv tensors of one mamba layer:
  xs  (B, S, H, dh)  state inputs (bf16/f32)
  bm  (B, S, N)      input projections B_t (f32)
  cm  (B, S, N)      output projections C_t (f32)
  dt  (B, S, H)      softplus'd step sizes (f32)
  a   (H,)           negative decay rates (f32)

Output: y (B, S, H, dh) f32 with y_t = sum_{s<=t} C_t^T (prod exp(dt A)) dt_s B_s x_s.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(xs, bm, cm, dt, a, *, chunk: int = 64):
    b, s, h, dh = xs.shape
    n = bm.shape[-1]
    q = min(chunk, s)
    assert s % q == 0
    nc = s // q
    da = dt * a  # (B,S,H)
    xs_c = xs.reshape(b, nc, q, h, dh).astype(jnp.float32)
    bm_c = bm.reshape(b, nc, q, n)
    cm_c = cm.reshape(b, nc, q, n)
    dt_c = dt.reshape(b, nc, q, h)
    cum = jnp.cumsum(da.reshape(b, nc, q, h), axis=2)

    def step(hstate, inp):
        xs_k, bm_k, cm_k, dt_k, cum_k = inp
        ldiff = cum_k[:, :, None, :] - cum_k[:, None, :, :]
        mask = jnp.tril(jnp.ones((q, q), bool))
        lmat = jnp.where(mask[None, :, :, None], jnp.exp(ldiff), 0.0)
        gbc = jnp.einsum("btn,bsn->bts", cm_k, bm_k)
        scores = gbc[:, :, :, None] * lmat * dt_k[:, None, :, :]
        y_intra = jnp.einsum("btsh,bshd->bthd", scores, xs_k)
        y_inter = jnp.einsum("btn,bhdn->bthd", cm_k, hstate) * jnp.exp(cum_k)[..., None]
        decay_out = jnp.exp(cum_k[:, -1:, :] - cum_k)
        contrib = jnp.einsum("bsh,bsn,bshd->bhdn", decay_out * dt_k, bm_k, xs_k)
        h_new = hstate * jnp.exp(cum_k[:, -1])[:, :, None, None] + contrib
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((b, h, dh, n), jnp.float32)
    inputs = tuple(jnp.moveaxis(t, 1, 0) for t in (xs_c, bm_c, cm_c, dt_c, cum))
    hT, y = jax.lax.scan(step, h0, inputs)
    return jnp.moveaxis(y, 0, 1).reshape(b, s, h, dh), hT
