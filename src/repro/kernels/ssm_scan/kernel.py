"""Pallas TPU kernel: chunked SSD (Mamba2) selective-state scan.

Grid (B, H, nc): the chunk dim is LAST, so TPU executes it sequentially and
the (dh, N) recurrent state lives in VMEM scratch across a head's chunks
(the same scratch-carry idiom as the flash-attention kernel).  Per step the
MXU sees three small matmuls: C@B^T (Q,N)x(N,Q), scores@x (Q,Q)x(Q,dh) and
x^T@(B*decay) (dh,Q)x(Q,N).  VMEM at Q=128, N=64, dh=64: inputs ~100 KiB,
L-matrix 64 KiB f32, state 16 KiB -- trivially resident.

The per-chunk cumulative decays are precomputed outside (one cumsum); the
kernel consumes cum (B,S,H) so there is no sequential math inside a chunk.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(xs_ref, bm_ref, cm_ref, dt_ref, cum_ref, y_ref, state_scr,
                *, q: int, nc: int):
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    xs = xs_ref[0, :, 0, :].astype(jnp.float32)  # (Q, dh)
    bm = bm_ref[0].astype(jnp.float32)  # (Q, N)
    cm = cm_ref[0].astype(jnp.float32)  # (Q, N)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (Q,)
    cum = cum_ref[0, :, 0].astype(jnp.float32)  # (Q,)

    # intra-chunk: masked decay-weighted attention over the chunk
    ldiff = cum[:, None] - cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= jax.lax.broadcasted_iota(
        jnp.int32, (q, q), 1
    )
    lmat = jnp.where(tri, jnp.exp(ldiff), 0.0)
    gbc = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (Q, Q)
    scores = gbc * lmat * dt[None, :]
    y = jax.lax.dot_general(scores, xs, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, dh)

    # inter-chunk: readout of the carried state
    state = state_scr[...]  # (dh, N)
    y += jax.lax.dot_general(cm, state, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32) * jnp.exp(cum)[:, None]

    # state update
    decay_out = jnp.exp(cum[-1] - cum) * dt  # (Q,)
    contrib = jax.lax.dot_general(
        xs, bm * decay_out[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (dh, N)
    state_scr[...] = state * jnp.exp(cum[-1]) + contrib

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)


def ssd_chunked_tpu(xs, bm, cm, dt, a, *, chunk: int = 128, interpret: bool = False):
    """xs (B,S,H,dh), bm/cm (B,S,N), dt (B,S,H), a (H,) -> y (B,S,H,dh) f32."""
    b, s, h, dh = xs.shape
    n = bm.shape[-1]
    q = min(chunk, s)
    if s % q:
        raise ValueError(f"seq {s} must divide chunk {q}")
    nc = s // q
    cum = jnp.cumsum((dt * a).reshape(b, nc, q, h), axis=2).reshape(b, s, h)

    kernel = functools.partial(_ssd_kernel, q=q, nc=nc)
    y = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, q, 1, dh), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, q, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, q, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, q, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1, q, 1), lambda bi, hi, ci: (bi, ci, hi)),
        ],
        out_specs=pl.BlockSpec((1, q, 1, dh), lambda bi, hi, ci: (bi, ci, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, h, dh), jnp.float32),
        scratch_shapes=[pltpu.VMEM((dh, n), jnp.float32)],
        interpret=interpret,
    )(xs, bm, cm, dt, cum)
    return y
