"""Pure-jnp oracle: naive softmax attention with GQA/causal/window/softcap.

Materializes the full (Sq, Skv) logits -- use only at test shapes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Skv, KH, hd)
    v: jax.Array,  # (B, Skv, KH, hd)
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    q_offset: int = 0,
) -> jax.Array:
    b, sq, h, hd = q.shape
    skv, kh = k.shape[1], k.shape[2]
    g = h // kh
    qg = q.reshape(b, sq, kh, g, hd).astype(jnp.float32) * hd**-0.5
    logits = jnp.einsum("bqhgk,bshk->bhgqs", qg, k.astype(jnp.float32))
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = q_offset + jnp.arange(sq)
    kpos = jnp.arange(skv)
    ok = jnp.ones((sq, skv), bool)
    if causal:
        ok &= qpos[:, None] >= kpos[None, :]
    if window > 0:
        ok &= qpos[:, None] - kpos[None, :] < window
    logits = jnp.where(ok[None, None, None], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgqs,bshk->bqhgk", w, v.astype(jnp.float32))
    return o.reshape(b, sq, h, hd).astype(q.dtype)
