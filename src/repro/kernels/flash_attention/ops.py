"""Blockwise flash attention (jnp) with a custom VJP.

Why this exists (vs. differentiating an online-softmax scan): the backward
pass of a scanned online softmax saves its (m, l, acc) carry at EVERY step --
O(S * S/c) f32 -- which is what blows HBM on 32k prefill.  A custom VJP keeps
residuals at O(S) (output + logsumexp) and recomputes probabilities blockwise,
exactly like the FlashAttention kernel the Pallas version implements on TPU.

FLOP exactness: causal grids use *wraparound pairing* -- super-row r
processes q-rows (r, nq-1-r), touching exactly nq+1 kv-blocks -- so no
block above the diagonal is ever computed and the HLO flop count equals the
true masked-attention work.  Sliding-window grids visit a constant
ceil(window/c)+1 offsets per row.  All loop trip counts are static (the
roofline analyzer multiplies while bodies by trip count).

Layouts: "blocked" (default) slices (c, H, hd) windows directly from the
native (B, S, H, hd) tensors and transposes per block; "grouped" pre-
transposes the whole tensor to (B, KH, G, S, hd) -- simpler HLO but costs
three full HBM round-trips of q/k/v per call, which dominated the memory
roofline at 32k (EXPERIMENTS.md SPerf iteration 1 measures the difference).

Supports GQA (H = G * KH), logit softcap (gemma2), causal / bidirectional /
sliding-window masks.  Math: logits f32, probabilities bf16 into the MXU,
f32 accumulators.
"""

from __future__ import annotations

from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.kernel import flash_attention_tpu
from repro.kernels.flash_attention.ref import attention_ref

DEFAULT_BLOCK = 1024
_NEG_INF = -1e30


def _blk(x: jax.Array, i, c: int, axis: int) -> jax.Array:
    return jax.lax.dynamic_slice_in_dim(x, i * c, c, axis=axis)


def _mask(qpos, kpos, *, causal: bool, window: int):
    ok = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        ok &= qpos[:, None] >= kpos[None, :]
    if window > 0:
        ok &= qpos[:, None] - kpos[None, :] < window
    ok &= (kpos >= 0)[None, :]  # clamped out-of-range blocks
    return ok


def _fwd_update(carry, qb, kb, vb, qpos, kpos, cfg):
    """Online-softmax update of one (q-block, kv-block) pair.

    qb (B,KH,G,c,hd) pre-scaled; kb/vb (B,KH,ck,hd).
    """
    m, l, acc = carry
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kb, preferred_element_type=jnp.float32)
    if cfg["softcap"] > 0:
        s = cfg["softcap"] * jnp.tanh(s / cfg["softcap"])
    ok = _mask(qpos, kpos, causal=cfg["causal"], window=cfg["window"])
    s = jnp.where(ok[None, None, None], s, _NEG_INF)
    m_new = jnp.maximum(m, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + p.sum(axis=-1)
    acc_new = acc * corr[..., None] + jnp.einsum(
        "bhgqk,bhkd->bhgqd", p.astype(vb.dtype), vb,
        preferred_element_type=jnp.float32,
    )
    return m_new, l_new, acc_new


def _sel(lane_sel, pair):
    """Select lane 0/1 of a stacked (2, ...) pytree by a traced bool."""
    return jax.tree.map(lambda t: jnp.where(lane_sel, t[0], t[1]), pair)


def _put(lane_sel, pair, new):
    return jax.tree.map(
        lambda t, n: jnp.stack(
            [jnp.where(lane_sel, n, t[0]), jnp.where(lane_sel, t[1], n)]
        ),
        pair, new,
    )


def _finalize(m, l, acc):
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    return o, lse


def _row_plan(nq: int, nk: int, cfg) -> tuple[str, int]:
    if cfg["window"] > 0:
        wb = -(-cfg["window"] // cfg["block"])
        return "window", min(wb + 1, nk)
    if cfg["causal"]:
        return "wrap", nq + 1
    return "full", nk


# ---------------------------------------------------------------------------
# Block loaders (layout abstraction)
# ---------------------------------------------------------------------------

def _loaders(q, k, v, cfg):
    """Returns (load_q, load_kv, dims).  load_q pre-scales by hd^-0.5."""
    c = cfg["block"]
    if cfg["layout"] == "grouped":
        b, kh, g, sq, hd = q.shape
        scale = jnp.asarray(hd**-0.5, q.dtype)

        def load_q(i):
            return _blk(q, i, c, 3) * scale

        def load_kv(j):
            return _blk(k, j, c, 2), _blk(v, j, c, 2)

        return load_q, load_kv, (b, kh, g, sq, hd)
    # blocked: native (B, S, H, hd) / (B, S, KH, hd)
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    g = h // kh
    scale = jnp.asarray(hd**-0.5, q.dtype)

    def load_q(i):
        qb = _blk(q, i, c, 1)  # (B, c, H, hd)
        qb = qb.reshape(b, c, kh, g, hd).transpose(0, 2, 3, 1, 4)
        return qb * scale

    def load_kv(j):
        kb = _blk(k, j, c, 1).transpose(0, 2, 1, 3)  # (B, KH, c, hd)
        vb = _blk(v, j, c, 1).transpose(0, 2, 1, 3)
        return kb, vb

    return load_q, load_kv, (b, kh, g, sq, hd)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _forward(q, k, v, cfg):
    """Returns o_rows (nq, B,KH,G,c,hd) f32 (row-permuted), lse likewise, and
    the static row permutation applied."""
    load_q, load_kv, (b, kh, g, sq, hd) = _loaders(q, k, v, cfg)
    c = cfg["block"]
    skv = k.shape[2] if cfg["layout"] == "grouped" else k.shape[1]
    nq, nk = sq // c, skv // c
    plan, steps = _row_plan(nq, nk, cfg)
    ar = jnp.arange(c)

    def lane_init(n_lane):
        return (
            jnp.full((n_lane, b, kh, g, c), _NEG_INF, jnp.float32),
            jnp.zeros((n_lane, b, kh, g, c), jnp.float32),
            jnp.zeros((n_lane, b, kh, g, c, hd), jnp.float32),
        )

    if plan == "wrap":
        half = nq // 2

        def super_row(_, r):
            lo, hi = r, nq - 1 - r
            q_lo, q_hi = load_q(lo), load_q(hi)

            def inner(carry, j):
                use_lo = j <= r
                qi = jnp.where(use_lo, lo, hi)
                kj = jnp.where(use_lo, j, j - (r + 1))
                qb = jnp.where(use_lo, q_lo, q_hi)
                kb, vb = load_kv(kj)
                lane = _sel(use_lo, carry)
                new = _fwd_update(lane, qb, kb, vb, qi * c + ar, kj * c + ar, cfg)
                return _put(use_lo, carry, new), None

            carry, _ = jax.lax.scan(inner, lane_init(2), jnp.arange(steps))
            return None, _finalize(*carry)

        _, (o_pairs, lse_pairs) = jax.lax.scan(super_row, None, jnp.arange(half))
        order = np.array([[r, nq - 1 - r] for r in range(half)]).reshape(-1)
        perm = np.argsort(order)
        o_rows = o_pairs.reshape((nq, b, kh, g, c, hd))[perm]
        lse_rows = lse_pairs.reshape((nq, b, kh, g, c))[perm]
    else:
        def row(_, i):
            qb = load_q(i)

            def inner(carry, t):
                kj = i - (steps - 1) + t if plan == "window" else t
                kjc = jnp.clip(kj, 0, nk - 1)
                kb, vb = load_kv(kjc)
                kpos = jnp.where(kj >= 0, kjc * c, -c) + ar
                new = _fwd_update(carry, qb, kb, vb, i * c + ar, kpos, cfg)
                return new, None

            m0 = (jnp.full((b, kh, g, c), _NEG_INF, jnp.float32),
                  jnp.zeros((b, kh, g, c), jnp.float32),
                  jnp.zeros((b, kh, g, c, hd), jnp.float32))
            carry, _ = jax.lax.scan(inner, m0, jnp.arange(steps))
            return None, _finalize(*carry)

        _, (o_rows, lse_rows) = jax.lax.scan(row, None, jnp.arange(nq))

    return o_rows, lse_rows, (b, kh, g, sq, hd)


def _rows_to_native(o_rows, dims, dtype):
    """(nq, B, KH, G, c, hd) -> (B, S, H, hd)."""
    nq, b, kh, g, c, hd = o_rows.shape
    o = o_rows.transpose(1, 0, 4, 2, 3, 5)  # (B, nq, c, KH, G, hd)
    return o.reshape(b, nq * c, kh * g, hd).astype(dtype)


def _rows_to_grouped(o_rows, dims, dtype):
    nq, b, kh, g, c, hd = o_rows.shape
    o = jnp.moveaxis(o_rows, 0, 3)  # (B, KH, G, nq, c, hd)
    return o.reshape(b, kh, g, nq * c, hd).astype(dtype)


# ---------------------------------------------------------------------------
# Backward (fused single pass over kv columns; dq scattered in-place)
# ---------------------------------------------------------------------------

def _bwd_block(qb, kb, vb, dob, lseb, db, qpos, kpos, cfg):
    """One (q-block, kv-block) tile: returns (dq_b, dk_b, dv_b) grouped."""
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kb, preferred_element_type=jnp.float32)
    s = s * cfg["scale"]
    if cfg["softcap"] > 0:
        capped = cfg["softcap"] * jnp.tanh(s / cfg["softcap"])
        dcap = 1.0 - (capped / cfg["softcap"]) ** 2
    else:
        capped, dcap = s, None
    ok = _mask(qpos, kpos, causal=cfg["causal"], window=cfg["window"])
    capped = jnp.where(ok[None, None, None], capped, _NEG_INF)
    p = jnp.exp(capped - lseb[..., None])
    dp = jnp.einsum("bhgqd,bhkd->bhgqk", dob, vb, preferred_element_type=jnp.float32)
    ds = p * (dp - db[..., None])
    if dcap is not None:
        ds = ds * dcap
    pb = p.astype(vb.dtype)
    dsb = ds.astype(qb.dtype)
    dv_b = jnp.einsum("bhgqk,bhgqd->bhkd", pb, dob, preferred_element_type=jnp.float32)
    dk_b = jnp.einsum("bhgqk,bhgqd->bhkd", dsb, qb, preferred_element_type=jnp.float32) * cfg["scale"]
    dq_b = jnp.einsum("bhgqk,bhkd->bhgqd", dsb, kb, preferred_element_type=jnp.float32) * cfg["scale"]
    return dq_b, dk_b, dv_b


def _backward(q, k, v, o_native, lse_g, do_native, cfg):
    """All tensors in the configured layout; lse_g (B,KH,G,S) f32.

    Returns gradients in the SAME layout as the inputs.
    """
    c = cfg["block"]
    blocked = cfg["layout"] == "blocked"
    if blocked:
        b, sq, h, hd = q.shape
        kh = k.shape[2]
        g = h // kh
        skv = k.shape[1]
    else:
        b, kh, g, sq, hd = q.shape
        skv = k.shape[2]
    nq, nk = sq // c, skv // c
    ar = jnp.arange(c)

    d_full = (o_native.astype(jnp.float32) * do_native.astype(jnp.float32)).sum(-1)
    if blocked:
        d_g = d_full.reshape(b, sq, kh, g).transpose(0, 2, 3, 1)  # (B,KH,G,S)
    else:
        d_g = d_full

    dob = do_native.astype(q.dtype)

    def load_q(i):
        if blocked:
            qb = _blk(q, i, c, 1).reshape(b, c, kh, g, hd).transpose(0, 2, 3, 1, 4)
            do_b = _blk(dob, i, c, 1).reshape(b, c, kh, g, hd).transpose(0, 2, 3, 1, 4)
        else:
            qb = _blk(q, i, c, 3)
            do_b = _blk(dob, i, c, 3)
        return qb, do_b, _blk(lse_g, i, c, 3), _blk(d_g, i, c, 3)

    def load_kv(j):
        if blocked:
            return (_blk(k, j, c, 1).transpose(0, 2, 1, 3),
                    _blk(v, j, c, 1).transpose(0, 2, 1, 3))
        return _blk(k, j, c, 2), _blk(v, j, c, 2)

    def add_dq(dq_full, i, dq_b):
        # dq_full kept NATIVE (B, S, H, hd) f32 so no global transpose at the end
        dq_n = dq_b.transpose(0, 3, 1, 2, 4).reshape(b, c, kh * g, hd)
        old = jax.lax.dynamic_slice_in_dim(dq_full, i * c, c, axis=1)
        return jax.lax.dynamic_update_slice_in_dim(dq_full, old + dq_n, i * c, axis=1)

    dq0 = jnp.zeros((b, sq, kh * g, hd), jnp.float32)
    bcfg = cfg

    if cfg["causal"] and cfg["window"] == 0:  # wraparound over columns
        half = nq // 2
        steps = nq + 1

        def super_col(dq_full, r):
            lo, hi = r, nq - 1 - r
            k_lo, v_lo = load_kv(lo)
            k_hi, v_hi = load_kv(hi)

            def inner(carry, t):
                dq_full, dkv = carry
                n_lo = nq - r
                use_lo = t < n_lo
                col = jnp.where(use_lo, lo, hi)
                row = jnp.where(use_lo, lo + t, hi + (t - n_lo))
                kb = jnp.where(use_lo, k_lo, k_hi)
                vb = jnp.where(use_lo, v_lo, v_hi)
                qb, dob_b, lseb, db = load_q(row)
                dq_b, dk_b, dv_b = _bwd_block(
                    qb, kb, vb, dob_b, lseb, db, row * c + ar, col * c + ar, bcfg
                )
                dq_full = add_dq(dq_full, row, dq_b)
                lane = _sel(use_lo, dkv)
                new = (lane[0] + dk_b, lane[1] + dv_b)
                return (dq_full, _put(use_lo, dkv, new)), None

            z = jnp.zeros((2, b, kh, c, hd), jnp.float32)
            (dq_full, dkv), _ = jax.lax.scan(inner, (dq_full, (z, z)), jnp.arange(steps))
            return dq_full, dkv

        dq_full, dkv_pairs = jax.lax.scan(super_col, dq0, jnp.arange(half))
        order = np.array([[r, nq - 1 - r] for r in range(half)]).reshape(-1)
        perm = np.argsort(order)
        dk_cols = dkv_pairs[0].reshape((nq, b, kh, c, hd))[perm]
        dv_cols = dkv_pairs[1].reshape((nq, b, kh, c, hd))[perm]
    else:
        if cfg["window"] > 0:
            wb = -(-cfg["window"] // c)
            steps = min(wb + 1, nq)
        else:
            steps = nq

        def col(dq_full, j):
            kb, vb = load_kv(j)

            def inner(carry, t):
                dq_full, dk_acc, dv_acc = carry
                row = j + t if cfg["window"] > 0 else t
                rowc = jnp.clip(row, 0, nq - 1)
                qb, dob_b, lseb, db = load_q(rowc)
                qpos = jnp.where(row < nq, rowc * c, -c) + ar
                dq_b, dk_b, dv_b = _bwd_block(
                    qb, kb, vb, dob_b, lseb, db, qpos, j * c + ar, bcfg
                )
                dq_full = add_dq(dq_full, rowc, dq_b)
                return (dq_full, dk_acc + dk_b, dv_acc + dv_b), None

            z = jnp.zeros((b, kh, c, hd), jnp.float32)
            (dq_full, dk_j, dv_j), _ = jax.lax.scan(inner, (dq_full, z, z), jnp.arange(steps))
            return dq_full, (dk_j, dv_j)

        dq_full, (dk_cols, dv_cols) = jax.lax.scan(col, dq0, jnp.arange(nk))

    if blocked:
        dk = dk_cols.transpose(1, 0, 3, 2, 4).reshape(b, nk * c, kh, hd)
        dv = dv_cols.transpose(1, 0, 3, 2, 4).reshape(b, nk * c, kh, hd)
        return dq_full, dk, dv
    dk = jnp.moveaxis(dk_cols, 0, 2).reshape(b, kh, nk * c, hd)
    dv = jnp.moveaxis(dv_cols, 0, 2).reshape(b, kh, nk * c, hd)
    dqg = dq_full.reshape(b, sq, kh, g, hd).transpose(0, 2, 3, 1, 4)
    return dqg, dk, dv


# ---------------------------------------------------------------------------
# custom_vjp plumbing + public API
# ---------------------------------------------------------------------------

@lru_cache(maxsize=64)
def _make_flash(causal: bool, window: int, softcap: float, block: int,
                layout: str = "blocked"):
    cfg = dict(causal=causal, window=window, softcap=softcap, block=block,
               layout=layout)

    def _run_fwd(q, k, v):
        o_rows, lse_rows, dims = _forward(q, k, v, cfg)
        if layout == "blocked":
            o = _rows_to_native(o_rows, dims, q.dtype)
        else:
            o = _rows_to_grouped(o_rows, dims, q.dtype)
        b, kh, g, sq, hd = dims
        lse = jnp.moveaxis(lse_rows, 0, 3).reshape(b, kh, g, sq)
        return o, lse

    @jax.custom_vjp
    def fn(q, k, v):
        return _run_fwd(q, k, v)[0]

    def fwd(q, k, v):
        o, lse = _run_fwd(q, k, v)
        return o, (q, k, v, o, lse)

    def bwd(res, do):
        # o/do arrive in the configured layout (fwd saved them as returned),
        # and _backward both consumes and emits that layout -- its D
        # computation and grad reshapes branch on cfg["layout"] internally,
        # so no per-layout staging is needed here.  Grouped-layout gradient
        # parity vs attention_ref is pinned in tests/test_kernels.py.
        q, k, v, o, lse = res
        hd = q.shape[-1]
        bcfg = dict(cfg, scale=hd**-0.5)
        dq, dk, dv = _backward(q, k, v, o, lse, do, bcfg)
        return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)

    fn.defvjp(fwd, bwd)
    return fn


def _block_for(sq: int, skv: int, block: int, causal: bool) -> int | None:
    c = min(block, sq, skv)
    while c >= 128:
        if sq % c == 0 and skv % c == 0 and (not causal or (sq // c) % 2 == 0):
            return c
        c //= 2
    return None


def flash_attention(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Skv, KH, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    block: int = DEFAULT_BLOCK,
    layout: str = "blocked",
    use_pallas: bool = False,
    interpret: bool = False,
) -> jax.Array:
    """Blockwise attention; falls back to the naive ref at tiny shapes.

    ``use_pallas=True`` dispatches the forward pass to the Pallas TPU kernel
    (``interpret=True`` runs it on CPU for CI) when the sequence lengths
    divide the block size; it is forward-only, which is what the serving
    executors need.  Shapes the kernel can't tile -- or any gradient use --
    take the jnp blockwise path below, which has a custom VJP."""
    b, sq, h, hd = q.shape
    skv, kh = k.shape[1], k.shape[2]
    if use_pallas:
        bq, bk = min(block, sq), min(block, skv)
        # self-attention only: the TPU kernel's grid pairs q/kv blocks by
        # index, so cross-length (sq != skv) shapes take the jnp path
        if sq == skv and sq % bq == 0 and skv % bk == 0:
            return flash_attention_tpu(
                q, k, v, causal=causal, window=window, softcap=softcap,
                block_q=bq, block_k=bk, interpret=interpret,
            )
    c = _block_for(sq, skv, block, causal and window == 0)
    if c is None or sq < 2 * 128:
        return attention_ref(q, k, v, causal=causal, window=window, softcap=softcap)
    g = h // kh
    fn = _make_flash(causal, window, float(softcap), c, layout)
    if layout == "grouped":
        qg = jnp.moveaxis(q.reshape(b, sq, kh, g, hd), 1, 3)
        kg = jnp.moveaxis(k, 1, 2)
        vg = jnp.moveaxis(v, 1, 2)
        o = fn(qg, kg, vg)
        return jnp.moveaxis(o, 3, 1).reshape(b, sq, h, hd)
    return fn(q, k, v)
