"""Pallas TPU flash-attention forward kernel.

Grid: (B * KH * G, nq, nk) -- TPU executes the last grid dim sequentially,
so the (m, l, acc) online-softmax state lives in VMEM scratch across the nk
steps of one q-row and the output block is written on the row's final step.
BlockSpecs tile q/o to (block_q, hd) and k/v to (block_k, hd) in VMEM; the
MXU sees (block_q x hd) @ (hd x block_k) and (block_q x block_k) @
(block_k x hd) matmuls -- block sizes default to 512/1024 and hd is 64-256
(MXU lanes are 128-wide; hd=64 pads one lane tile).

VMEM budget per core at the defaults (hd=128, bf16 in / f32 scratch):
  q 512x128x2 = 128 KiB, k/v 1024x128x2 = 256 KiB each (x2 for double
  buffering), acc 512x128x4 = 256 KiB, m/l 4 KiB  ->  ~1.4 MiB of ~16 MiB.

Causal/window masking is positional inside the kernel; fully-dead blocks are
skipped with ``pl.when`` (predication -- no MXU work issued).  The dry-run
lowers the jnp blockwise twin in ``ops.py`` (identical math); this kernel is
the TPU deployment artifact, validated in interpret mode against ``ref.py``
over shape/dtype sweeps in ``tests/test_kernels.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(
    q_ref, k_ref, v_ref,  # VMEM tiles: (1, bq, hd), (1, bk, hd), (1, bk, hd)
    o_ref,  # (1, bq, hd)
    m_scr, l_scr, acc_scr,  # VMEM scratch, persistent across the nk grid dim
    *, block_q: int, block_k: int, nk: int, causal: bool, window: int,
    softcap: float, scale: float,
):
    i, j = pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # block-level liveness: skip fully-masked tiles entirely
    q_lo = i * block_q
    k_lo = j * block_k
    live = jnp.bool_(True)
    if causal:
        live = jnp.logical_and(live, k_lo <= q_lo + block_q - 1)
    if window > 0:
        live = jnp.logical_and(live, q_lo - (k_lo + block_k - 1) < window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale  # (bq, hd)
        k = k_ref[0].astype(jnp.float32)  # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        ok = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            ok = jnp.logical_and(ok, qpos >= kpos)
        if window > 0:
            ok = jnp.logical_and(ok, qpos - kpos < window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + p.sum(axis=1)
        m_scr[...] = m_new
        pv = jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_scr[...] = acc_scr[...] * corr[:, None] + pv

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[0] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


def flash_attention_tpu(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Skv, KH, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    block_q: int = 512,
    block_k: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    """TPU flash attention forward (GQA folded into the batch grid dim)."""
    b, sq, h, hd = q.shape
    skv, kh = k.shape[1], k.shape[2]
    g = h // kh
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    if sq % block_q or skv % block_k:
        raise ValueError(f"seq ({sq},{skv}) must divide blocks ({block_q},{block_k})")
    nq, nk = sq // block_q, skv // block_k

    qg = jnp.moveaxis(q.reshape(b, sq, kh, g, hd), 1, 3).reshape(b * kh * g, sq, hd)
    kg = jnp.moveaxis(k, 1, 2).reshape(b * kh, skv, hd)
    vg = jnp.moveaxis(v, 1, 2).reshape(b * kh, skv, hd)

    kernel = functools.partial(
        _fa_kernel, block_q=block_q, block_k=block_k, nk=nk, causal=causal,
        window=window, softcap=softcap, scale=hd**-0.5,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * kh * g, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, i, j: (bh // g, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, i, j: (bh // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kh * g, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kg, vg)
    out = out.reshape(b, kh, g, sq, hd)
    return jnp.moveaxis(out, 3, 1).reshape(b, sq, h, hd)
