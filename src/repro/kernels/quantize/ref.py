"""Pure-jnp oracle for blockwise int8 quantization (boundary compression).

SEIFER compresses inter-partition activations with ZFP/LZ4 on the wire; the
TPU-native analogue is blockwise symmetric int8: each ``block``-wide slice of
the trailing dim gets an f32 scale = max|x| / 127.  ~2x wire compression for
bf16 activations at <0.5% relative error, with an MXU/VPU-friendly layout.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_ref(x: jax.Array, block: int = 256) -> tuple[jax.Array, jax.Array]:
    """x (..., d) -> (q int8 (..., d), scales f32 (..., d/block))."""
    *lead, d = x.shape
    if d % block:
        raise ValueError(f"trailing dim {d} must divide block {block}")
    xb = x.astype(jnp.float32).reshape(*lead, d // block, block)
    scale = jnp.max(jnp.abs(xb), axis=-1) / 127.0
    safe = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / safe[..., None]), -127, 127).astype(jnp.int8)
    return q.reshape(*lead, d), scale


def dequantize_ref(q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    *lead, d = q.shape
    block = d // scale.shape[-1]
    xb = q.reshape(*lead, d // block, block).astype(jnp.float32)
    return (xb * scale[..., None]).reshape(*lead, d).astype(dtype)
