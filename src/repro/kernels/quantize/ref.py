"""Pure-jnp oracle for blockwise int8 quantization (boundary compression).

SEIFER compresses inter-partition activations with ZFP/LZ4 on the wire; the
TPU-native analogue is blockwise symmetric int8: each ``block``-wide slice of
the trailing dim gets an f32 scale = max|x| / 127.  ~2x wire compression for
bf16 activations at <0.5% relative error, with an MXU/VPU-friendly layout.

A trailing dim that does not divide ``block`` is zero-padded to the next
block boundary internally (a ragged last block); padding zeros never raise a
block's max-abs, so scales -- and therefore the error bound -- are identical
to an exact ragged computation.

``INT8_MAX_REL_ERROR`` is the codec's contract: the round-trip error of any
element is at most ``scale / 2 = max|x_block| / 254``, i.e. at most
``INT8_MAX_REL_ERROR`` relative to the block's max magnitude.  The kernel
tests assert this bound and the data plane's ``accuracy_tolerance`` check
consumes the same constant (``repro.dataplane.codecs.Int8Codec``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# max |dequant(quant(x)) - x| / max|x_block|: round-off is half a step of
# size scale = max/127, so 0.5/127 (plus f32 rounding slack in the tests).
INT8_MAX_REL_ERROR = 0.5 / 127.0


def _pad_to_block(x: jax.Array, block: int) -> tuple[jax.Array, int]:
    """Zero-pad the trailing dim up to a block multiple; returns (x, nb)."""
    *lead, d = x.shape
    nb = -(-d // block)
    pad = nb * block - d
    if pad:
        x = jnp.pad(x, [(0, 0)] * len(lead) + [(0, pad)])
    return x, nb


def quantize_ref(x: jax.Array, block: int = 256) -> tuple[jax.Array, jax.Array]:
    """x (..., d) -> (q int8 (..., d), scales f32 (..., ceil(d/block)))."""
    *lead, d = x.shape
    xp, nb = _pad_to_block(x.astype(jnp.float32), block)
    xb = xp.reshape(*lead, nb, block)
    scale = jnp.max(jnp.abs(xb), axis=-1) / 127.0
    safe = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / safe[..., None]), -127, 127).astype(jnp.int8)
    return q.reshape(*lead, nb * block)[..., :d], scale


def dequantize_ref(
    q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16, block: int | None = None
) -> jax.Array:
    """Inverse of ``quantize_ref``.  ``block`` may be omitted only when the
    trailing dim divides the scale count exactly (no ragged last block)."""
    *lead, d = q.shape
    nb = scale.shape[-1]
    if block is None:
        if d % nb:
            raise ValueError(
                f"trailing dim {d} is ragged over {nb} scale blocks; "
                f"pass the block= used to quantize"
            )
        block = d // nb
    qp, _ = _pad_to_block(q, block)
    xb = qp.reshape(*lead, nb, block).astype(jnp.float32) * scale[..., None]
    return xb.reshape(*lead, nb * block)[..., :d].astype(dtype)


def dequant_matmul_ref(
    q: jax.Array,
    scale: jax.Array,
    w: jax.Array,
    dtype=None,
    block: int | None = None,
) -> jax.Array:
    """Fused-op oracle: ``dequantize_ref(q, scale) @ w`` in one f32 pass.

    ``q`` is (..., d) int8 with blockwise ``scale`` (..., ceil(d/block));
    ``w`` is (d, dout).  The product is accumulated in f32 and cast to
    ``dtype`` (default: ``w.dtype``), so the result carries exactly the
    int8 round-trip error of the activations -- the matmul adds only f32
    rounding on top of the ``INT8_MAX_REL_ERROR`` contract."""
    x = dequantize_ref(q, scale, dtype=jnp.float32, block=block)
    out = x @ w.astype(jnp.float32)
    return out.astype(w.dtype if dtype is None else dtype)
