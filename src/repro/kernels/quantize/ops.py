"""Jit'd wrappers for boundary int8 compression.

``use_pallas`` selects the TPU kernel (tests exercise it in interpret mode);
the default jnp path is what the dry-run lowers -- XLA fuses it into two
cheap VPU passes either way.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.quantize.kernel import dequantize_int8_tpu, quantize_int8_tpu
from repro.kernels.quantize.ref import dequantize_ref, quantize_ref


@partial(jax.jit, static_argnames=("block", "use_pallas", "interpret"))
def quantize_int8(
    x: jax.Array, block: int = 256, *, use_pallas: bool = False,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    if use_pallas:
        return quantize_int8_tpu(x, block=block, interpret=interpret)
    return quantize_ref(x, block=block)


@partial(jax.jit, static_argnames=("dtype", "block", "use_pallas", "interpret"))
def dequantize_int8(
    q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16, *,
    block: int | None = None, use_pallas: bool = False, interpret: bool = False,
) -> jax.Array:
    if use_pallas:
        return dequantize_int8_tpu(q, scale, dtype=dtype, block=block,
                                   interpret=interpret)
    return dequantize_ref(q, scale, dtype=dtype, block=block)
