"""Jit'd wrappers for boundary int8 compression.

``use_pallas`` selects the TPU kernel (tests exercise it in interpret mode);
the default jnp path is what the dry-run lowers -- XLA fuses it into two
cheap VPU passes either way.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.quantize.kernel import (
    dequant_matmul_tpu,
    dequantize_int8_tpu,
    quantize_int8_tpu,
)
from repro.kernels.quantize.ref import dequant_matmul_ref, dequantize_ref, quantize_ref


@partial(jax.jit, static_argnames=("block", "use_pallas", "interpret"))
def quantize_int8(
    x: jax.Array, block: int = 256, *, use_pallas: bool = False,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    if use_pallas:
        return quantize_int8_tpu(x, block=block, interpret=interpret)
    return quantize_ref(x, block=block)


@partial(jax.jit, static_argnames=("dtype", "block", "use_pallas", "interpret"))
def dequantize_int8(
    q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16, *,
    block: int | None = None, use_pallas: bool = False, interpret: bool = False,
) -> jax.Array:
    if use_pallas:
        return dequantize_int8_tpu(q, scale, dtype=dtype, block=block,
                                   interpret=interpret)
    return dequantize_ref(q, scale, dtype=dtype, block=block)


@partial(jax.jit, static_argnames=("dtype", "block", "use_pallas", "interpret"))
def dequant_matmul(
    q: jax.Array, scale: jax.Array, w: jax.Array, dtype=None, *,
    block: int | None = None, use_pallas: bool = False, interpret: bool = False,
) -> jax.Array:
    """``dequantize_int8(q, scale) @ w`` as one fused dispatch.

    The receiving stage of an int8-coded link feeds its first matmul straight
    from the wire payload -- no separate decode pass materializing the f32
    activation.  The ref path is a single jit region (XLA fuses the scale
    multiply into the matmul operand); the Pallas path dequantizes in VMEM
    feeding the MXU directly."""
    if use_pallas:
        return dequant_matmul_tpu(q, scale, w, dtype=dtype, block=block,
                                  interpret=interpret)
    return dequant_matmul_ref(q, scale, w, dtype=dtype, block=block)
