from repro.kernels.quantize.ops import dequant_matmul, dequantize_int8, quantize_int8
from repro.kernels.quantize.ref import INT8_MAX_REL_ERROR

__all__ = ["quantize_int8", "dequantize_int8", "dequant_matmul", "INT8_MAX_REL_ERROR"]
