from repro.kernels.quantize.ops import dequantize_int8, quantize_int8

__all__ = ["quantize_int8", "dequantize_int8"]
