"""Pallas TPU kernels: blockwise int8 quantize / dequantize.

Tiling: rows of the flattened (N, d) input are processed ``row_tile`` at a
time; the trailing dim is reshaped to (d/block, block) inside the kernel so
the VPU reduces |x| over the lane dimension.  VMEM per step at defaults
(row_tile=256, d=8192, bf16): in 4 MiB + out 2 MiB + scales 128 KiB -- fits
comfortably with double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref, *, block: int):
    x = x_ref[...].astype(jnp.float32)  # (rows, d)
    rows, d = x.shape
    xb = x.reshape(rows, d // block, block)
    scale = jnp.max(jnp.abs(xb), axis=-1) / 127.0
    safe = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / safe[..., None]), -127, 127)
    q_ref[...] = q.reshape(rows, d).astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, x_ref, *, block: int):
    rows, d = q_ref.shape
    qb = q_ref[...].reshape(rows, d // block, block).astype(jnp.float32)
    x = qb * s_ref[...][..., None]
    x_ref[...] = x.reshape(rows, d).astype(x_ref.dtype)


def quantize_int8_tpu(
    x: jax.Array, block: int = 256, row_tile: int = 256, interpret: bool = False
) -> tuple[jax.Array, jax.Array]:
    """x (..., d) -> (int8 (..., d), f32 scales (..., d/block))."""
    *lead, d = x.shape
    n = 1
    for s in lead:
        n *= s
    x2 = x.reshape(n, d)
    rt = min(row_tile, n)
    if n % rt:
        rt = n
    q, s = pl.pallas_call(
        functools.partial(_quant_kernel, block=block),
        grid=(n // rt,),
        in_specs=[pl.BlockSpec((rt, d), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((rt, d), lambda i: (i, 0)),
            pl.BlockSpec((rt, d // block), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), jnp.int8),
            jax.ShapeDtypeStruct((n, d // block), jnp.float32),
        ],
        interpret=interpret,
    )(x2)
    return q.reshape(*lead, d), s.reshape(*lead, d // block)


def dequantize_int8_tpu(
    q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16,
    row_tile: int = 256, interpret: bool = False,
) -> jax.Array:
    *lead, d = q.shape
    block = d // scale.shape[-1]
    n = 1
    for s in lead:
        n *= s
    q2 = q.reshape(n, d)
    s2 = scale.reshape(n, d // block)
    rt = min(row_tile, n)
    if n % rt:
        rt = n
    x = pl.pallas_call(
        functools.partial(_dequant_kernel, block=block),
        grid=(n // rt,),
        in_specs=[
            pl.BlockSpec((rt, d), lambda i: (i, 0)),
            pl.BlockSpec((rt, d // block), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), dtype),
        interpret=interpret,
    )(q2, s2)
    return x.reshape(*lead, d)
