"""Pallas TPU kernels: blockwise int8 quantize / dequantize.

Tiling: rows of the flattened (N, d) input are processed ``row_tile`` at a
time; the trailing dim is reshaped to (d/block, block) inside the kernel so
the VPU reduces |x| over the lane dimension.  VMEM per step at defaults
(row_tile=256, d=8192, bf16): in 4 MiB + out 2 MiB + scales 128 KiB -- fits
comfortably with double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref, *, block: int):
    x = x_ref[...].astype(jnp.float32)  # (rows, d)
    rows, d = x.shape
    xb = x.reshape(rows, d // block, block)
    scale = jnp.max(jnp.abs(xb), axis=-1) / 127.0
    safe = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / safe[..., None]), -127, 127)
    q_ref[...] = q.reshape(rows, d).astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, x_ref, *, block: int):
    rows, d = q_ref.shape
    qb = q_ref[...].reshape(rows, d // block, block).astype(jnp.float32)
    x = qb * s_ref[...][..., None]
    x_ref[...] = x.reshape(rows, d).astype(x_ref.dtype)


def _dqmm_kernel(q_ref, s_ref, w_ref, o_ref, *, block: int):
    rows, d = q_ref.shape
    qb = q_ref[...].reshape(rows, d // block, block).astype(jnp.float32)
    x = (qb * s_ref[...][..., None]).reshape(rows, d)
    o_ref[...] = jax.lax.dot_general(
        x, w_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


def quantize_int8_tpu(
    x: jax.Array, block: int = 256, row_tile: int = 256, interpret: bool = False
) -> tuple[jax.Array, jax.Array]:
    """x (..., d) -> (int8 (..., d), f32 scales (..., ceil(d/block))).

    A ragged trailing dim is zero-padded to the next block boundary before
    the kernel (padding never raises a block's max-abs, so the scales match
    the ref's exactly) and sliced back after."""
    *lead, d = x.shape
    nb = -(-d // block)
    dp = nb * block
    n = 1
    for s in lead:
        n *= s
    x2 = x.reshape(n, d)
    if dp != d:
        x2 = jnp.pad(x2, ((0, 0), (0, dp - d)))
    rt = min(row_tile, n)
    if n % rt:
        rt = n
    q, s = pl.pallas_call(
        functools.partial(_quant_kernel, block=block),
        grid=(n // rt,),
        in_specs=[pl.BlockSpec((rt, dp), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((rt, dp), lambda i: (i, 0)),
            pl.BlockSpec((rt, nb), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, dp), jnp.int8),
            jax.ShapeDtypeStruct((n, nb), jnp.float32),
        ],
        interpret=interpret,
    )(x2)
    return q[:, :d].reshape(*lead, d), s.reshape(*lead, nb)


def dequantize_int8_tpu(
    q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16,
    row_tile: int = 256, interpret: bool = False, block: int | None = None,
) -> jax.Array:
    *lead, d = q.shape
    nb = scale.shape[-1]
    if block is None:
        if d % nb:
            raise ValueError(
                f"trailing dim {d} is ragged over {nb} scale blocks; "
                f"pass the block= used to quantize"
            )
        block = d // nb
    dp = nb * block
    n = 1
    for s in lead:
        n *= s
    q2 = q.reshape(n, d)
    if dp != d:
        q2 = jnp.pad(q2, ((0, 0), (0, dp - d)))
    s2 = scale.reshape(n, nb)
    rt = min(row_tile, n)
    if n % rt:
        rt = n
    x = pl.pallas_call(
        functools.partial(_dequant_kernel, block=block),
        grid=(n // rt,),
        in_specs=[
            pl.BlockSpec((rt, dp), lambda i: (i, 0)),
            pl.BlockSpec((rt, nb), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rt, dp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, dp), dtype),
        interpret=interpret,
    )(q2, s2)
    return x[:, :d].reshape(*lead, d)


def dequant_matmul_tpu(
    q: jax.Array, scale: jax.Array, w: jax.Array, dtype=None,
    row_tile: int = 256, interpret: bool = False, block: int | None = None,
) -> jax.Array:
    """Fused dequantize-into-matmul: ``dequant(q, scale) @ w`` per row tile.

    The int8 tile is widened and scaled in VMEM and fed straight to the MXU
    -- the dequantized activation never round-trips through HBM, which is
    the whole point of receiving a quantized boundary activation.  ``w``
    (d, dout) rides whole in VMEM; its rows are zero-padded alongside a
    ragged ``q`` trailing dim (padded q is zero, so the extra rows are
    inert either way)."""
    *lead, d = q.shape
    nb = scale.shape[-1]
    if block is None:
        if d % nb:
            raise ValueError(
                f"trailing dim {d} is ragged over {nb} scale blocks; "
                f"pass the block= used to quantize"
            )
        block = d // nb
    dp = nb * block
    dout = w.shape[-1]
    n = 1
    for s in lead:
        n *= s
    q2 = q.reshape(n, d)
    w2 = w
    if dp != d:
        q2 = jnp.pad(q2, ((0, 0), (0, dp - d)))
        w2 = jnp.pad(w, ((0, dp - d), (0, 0)))
    s2 = scale.reshape(n, nb)
    rt = min(row_tile, n)
    if n % rt:
        rt = n
    o = pl.pallas_call(
        functools.partial(_dqmm_kernel, block=block),
        grid=(n // rt,),
        in_specs=[
            pl.BlockSpec((rt, dp), lambda i: (i, 0)),
            pl.BlockSpec((rt, nb), lambda i: (i, 0)),
            pl.BlockSpec((dp, dout), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rt, dout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, dout), w.dtype if dtype is None else dtype),
        interpret=interpret,
    )(q2, s2, w2)
    return o.reshape(*lead, dout)
