"""Multi-tenant serving: many models, one shared edge cluster.

Everything below PR 6 serves ONE model per cluster.  This package adds the
cluster-level tenancy layer:

  * ``TenantScheduler`` -- carve the hosting nodes into per-tenant slices
    under ``capacity_fraction`` quotas (or fractional co-residency under
    the ``"shared"`` policy),
  * ``TenancyRouter`` -- quota-scoped admission + weighted-fair service
    across tenants on one virtual timeline,
  * ``MultiTenantControlPlane`` -- churn routed only to the tenant(s)
    whose slice it touches, so one tenant's re-plan never perturbs
    another's live pipelines,
  * ``deploy_tenants`` -- the one-call entry (also reached by handing
    ``repro.api.deploy()`` a *list* of specs).
"""

from repro.tenancy.controlplane import MultiTenantControlPlane
from repro.tenancy.deploy import MultiTenantDeployment, deploy_tenants
from repro.tenancy.router import TenancyRouter
from repro.tenancy.scheduler import (
    POLICIES,
    TenancyPlan,
    TenantPlacement,
    TenantScheduler,
    resolve_fractions,
)

__all__ = [
    "MultiTenantControlPlane",
    "MultiTenantDeployment",
    "POLICIES",
    "TenancyPlan",
    "TenancyRouter",
    "TenantPlacement",
    "TenantScheduler",
    "deploy_tenants",
    "resolve_fractions",
]
