"""``TenantScheduler``: co-plan tenant placements on one shared cluster.

The scheduler answers the cluster-level packing question multi-tenant
serving opens: *which hosting nodes does each tenant get?*  Two policies:

  * ``"partition"`` (default) -- carve the hosting nodes into disjoint,
    bandwidth-coherent slices, one per tenant, sized by the tenants'
    ``capacity_fraction`` quotas (largest-remainder apportionment; every
    tenant gets at least one node).  The carve reuses the replica-set
    split machinery (``api.planner.split_cluster`` with per-group
    ``targets``), so each slice grows around a well-connected
    neighbourhood exactly like a replica group does.  Disjoint slices are
    what make churn isolation *structural*: a tenant's control planes are
    masked to its slice, so another tenant's node failures are events it
    never owns.
  * ``"shared"`` -- every tenant sees every hosting node, with its
    ``capacity_fraction`` applied to per-node capacity instead (fractional
    co-residency).  Tenants' pipelines may then pack onto the same nodes;
    contention is approximated by the router's weighted-fair service and
    churn on a shared node reaches every tenant hosting it.

Unspecified fractions split whatever the explicit ones leave over equally.
When the fractions sum below 1 under ``"partition"``, the unclaimed nodes
stay *spare* -- unowned capacity later growth can adopt.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.api.planner import split_cluster
from repro.api.spec import TenantSpec

POLICIES = ("partition", "shared")


@dataclasses.dataclass(frozen=True)
class TenantPlacement:
    """One tenant's share of the cluster: its hosting-node slice + quota."""

    name: str
    nodes: tuple[int, ...]
    fraction: float  # resolved capacity fraction (explicit or equal-share)
    weight: float

    def summary(self) -> dict:
        return {
            "name": self.name,
            "nodes": list(self.nodes),
            "fraction": self.fraction,
            "weight": self.weight,
        }


@dataclasses.dataclass(frozen=True)
class TenancyPlan:
    """The scheduler's carve: per-tenant placements + unclaimed spares."""

    policy: str
    placements: tuple[TenantPlacement, ...]
    spare: tuple[int, ...]

    def nodes_for(self, name: str) -> tuple[int, ...]:
        for p in self.placements:
            if p.name == name:
                return p.nodes
        raise KeyError(name)

    def summary(self) -> dict:
        return {
            "policy": self.policy,
            "tenants": [p.summary() for p in self.placements],
            "spare": list(self.spare),
        }


def resolve_fractions(tenants: Sequence[TenantSpec]) -> list[float]:
    """Explicit ``capacity_fraction``s pass through; ``None`` entries split
    the remainder equally (0 when the explicit ones already claim it all)."""
    explicit = sum(t.capacity_fraction for t in tenants
                   if t.capacity_fraction is not None)
    auto_n = sum(1 for t in tenants if t.capacity_fraction is None)
    share = max(0.0, 1.0 - explicit) / auto_n if auto_n else 0.0
    return [t.capacity_fraction if t.capacity_fraction is not None else share
            for t in tenants]


class TenantScheduler:
    """Carve a cluster's hosting nodes into per-tenant slices."""

    def __init__(self, *, policy: str = "partition", dispatcher: int = 0):
        if policy not in POLICIES:
            raise ValueError(
                f"policy must be one of {POLICIES}, got {policy!r}")
        self.policy = policy
        self.dispatcher = dispatcher

    def carve(self, comm, tenants: Sequence[TenantSpec]) -> TenancyPlan:
        """Place every tenant; raises ``ValueError`` when the cluster has
        fewer hosting nodes than tenants (no slice can be empty)."""
        tenants = list(tenants)
        hosting = [
            i for i in range(comm.n)
            if comm.node_capacity[i] > 0 and i != self.dispatcher
        ]
        fracs = resolve_fractions(tenants)
        if self.policy == "shared":
            placements = tuple(
                TenantPlacement(t.name, tuple(hosting), f, t.weight)
                for t, f in zip(tenants, fracs)
            )
            return TenancyPlan("shared", placements, spare=())

        if len(tenants) > len(hosting):
            raise ValueError(
                f"{len(tenants)} tenant(s) need at least one hosting node "
                f"each but the cluster has {len(hosting)}")
        counts = self._apportion(fracs, len(hosting))
        groups = split_cluster(
            comm, len(tenants), dispatcher=self.dispatcher, targets=counts)
        taken = {i for g in groups for i in g}
        placements = tuple(
            TenantPlacement(t.name, g, f, t.weight)
            for t, g, f in zip(tenants, groups, fracs)
        )
        spare = tuple(i for i in hosting if i not in taken)
        return TenancyPlan("partition", placements, spare=spare)

    @staticmethod
    def _apportion(fracs: Sequence[float], n_hosting: int) -> list[int]:
        """Largest-remainder node counts: every tenant >= 1 node, total =
        what the fractions entitle (spares stay unclaimed)."""
        raw = [f * n_hosting for f in fracs]
        budget = int(math.floor(sum(raw) + 1e-9))
        budget = min(n_hosting, max(len(fracs), budget))
        counts = [1] * len(fracs)
        for _ in range(budget - len(fracs)):
            i = max(range(len(fracs)),
                    key=lambda j: (raw[j] - counts[j], -j))
            counts[i] += 1
        return counts
