"""``deploy_tenants(specs) -> MultiTenantDeployment``: one shared cluster.

``api.deploy.deploy()`` dispatches here when handed a *list* of specs.  The
flow generalizes the single-tenant bootstrap:

  1. validate the tenant set (quota sums, duplicate names, one cluster),
  2. build the shared ``EdgeCluster`` from the first tenant's cluster spec,
  3. ``TenantScheduler.carve`` the hosting nodes into per-tenant slices
     (or fractional co-residency under the ``"shared"`` policy),
  4. bootstrap each tenant through the ordinary ``_build_deployment`` path
     restricted to its slice (masked control planes, subcluster planning,
     per-tenant artifact store + probe-noise stream),
  5. wire the cluster-level pair that makes it multi-tenant: a
     ``MultiTenantControlPlane`` (tenant-scoped churn) and a
     ``TenancyRouter`` (quota admission + weighted-fair serving).

Each tenant gets its own ``ArtifactStore`` subdirectory -- tenants serve
*different models*, so sharing one version pointer would alias their
rollouts (which is also why ``VersionBumped`` requires ``tenant=``).
"""

from __future__ import annotations

import dataclasses
import os
import tempfile
from typing import Any, Sequence

from repro.api.spec import (
    InfeasibleSpecError,
    SpecIssue,
    TenantSpec,
    as_tenants,
    validate_tenants,
)
from repro.cluster.events import ClusterEvent
from repro.cluster.lifecycle import EdgeCluster
from repro.cluster.serving import Request
from repro.cluster.store import ArtifactStore
from repro.obs import Journal
from repro.tenancy.controlplane import MultiTenantControlPlane
from repro.tenancy.router import TenancyRouter
from repro.tenancy.scheduler import TenancyPlan, TenantScheduler

# per-tenant probe-noise stream separation (prime-strided, like the
# 7919 * replica stride inside one deployment)
_TENANT_SEED_STRIDE = 104_729


def deploy_tenants(
    specs: Sequence,
    *,
    store_root: str | None = None,
    version: int = 0,
    flops_per_s: float = 1e9,
    policy: str = "partition",
) -> "MultiTenantDeployment":
    """Deploy every tenant onto ONE shared edge cluster.

    ``specs`` mixes ``TenantSpec`` and bare ``DeploymentSpec`` entries
    (bare specs become ``tenant<i>`` with default quota/weight).  Raises
    ``InfeasibleSpecError`` with structured, tenant-prefixed issues when
    the set cannot deploy.
    """
    from repro.api.deploy import _build_deployment, _passthrough_executor

    tenants = as_tenants(specs)
    issues = validate_tenants(tenants)
    if issues:
        raise InfeasibleSpecError(tuple(issues))

    comm, positions = tenants[0].spec.cluster.build()
    cluster = EdgeCluster(comm, flops_per_s=flops_per_s)
    scheduler = TenantScheduler(policy=policy)
    try:
        plan = scheduler.carve(comm, tenants)
    except ValueError as e:
        raise InfeasibleSpecError((SpecIssue("infeasible_tenancy", str(e)),))

    root = (store_root if store_root is not None
            else tempfile.mkdtemp(prefix="seifer-tenants-"))
    journal = Journal()  # ONE control-plane journal shared by every tenant
    deployments: dict[str, Any] = {}
    for idx, (tenant, placement) in enumerate(zip(tenants, plan.placements)):
        spec = _effective_spec(tenant, plan, comm)
        graph, model_executor = spec.resolve_model()
        executor_for_version = (
            spec.executor_for_version or model_executor or
            (lambda v: _passthrough_executor)
        )
        store = ArtifactStore(os.path.join(root, tenant.name))
        try:
            dep = _build_deployment(
                spec, graph, executor_for_version, cluster, store, positions,
                version=version, flops_per_s=flops_per_s,
                nodes=placement.nodes,
                seed_offset=_TENANT_SEED_STRIDE * idx,
                journal=journal, source_prefix=f"{tenant.name}/",
            )
        except (InfeasibleSpecError, RuntimeError) as e:
            detail = ("; ".join(i.message for i in e.issues)
                      if isinstance(e, InfeasibleSpecError) else str(e))
            raise InfeasibleSpecError((SpecIssue(
                "infeasible_tenancy",
                f"tenant {tenant.name!r} cannot deploy on its "
                f"{len(placement.nodes)}-node slice: {detail}",
            ),))
        if dep.autoscaler is not None:
            dep.autoscaler.name = tenant.name
        deployments[tenant.name] = dep

    entries = {
        name: (dep.replicaset or dep.control)
        for name, dep in deployments.items()
    }
    weights = {t.name: t.weight for t in tenants}
    mtcp = MultiTenantControlPlane(
        cluster, entries, weights=weights, journal=journal)
    router = TenancyRouter(
        {name: dep.loop for name, dep in deployments.items()},
        weights=weights,
        quotas={t.name: t.quota() for t in tenants},
    )
    return MultiTenantDeployment(
        tuple(tenants), plan, deployments, mtcp, router,
        cluster=cluster, positions=positions, journal=journal,
    )


def _effective_spec(tenant: TenantSpec, plan: TenancyPlan, comm):
    """The tenant's spec with its quota applied.

    The tenant-level ``admission_depth`` override lands on the spec (so the
    tenant's own engine enforces it), and under the ``"shared"`` policy the
    ``capacity_fraction`` scales the per-node capacity the planner sees --
    fractional co-residency instead of node carving.
    """
    spec = tenant.spec
    quota = tenant.quota()
    if quota != spec.admission_depth:
        spec = dataclasses.replace(spec, admission_depth=quota)
    if plan.policy == "shared" and tenant.capacity_fraction is not None:
        base = spec.capacity
        if base is None:
            base = spec.cluster.capacity_bytes
        if base is None:
            hosting = plan.nodes_for(tenant.name)
            base = float(min(comm.node_capacity[i] for i in hosting))
        spec = dataclasses.replace(
            spec, capacity=tenant.capacity_fraction * float(base))
    return spec


class MultiTenantDeployment:
    """Live multi-tenant serving: per-tenant deployments + shared control.

    The per-tenant ``Deployment`` facades stay fully usable (strategy
    swaps, model-watch polling, per-tenant metrics); this object adds the
    cluster-level views -- tenant-keyed serving through the weighted-fair
    router, and churn injection that routes each disturbance only to the
    tenant(s) whose slice it touches.
    """

    def __init__(
        self,
        tenants: tuple[TenantSpec, ...],
        plan: TenancyPlan,
        deployments: dict,
        mtcp: MultiTenantControlPlane,
        router: TenancyRouter,
        *,
        cluster: EdgeCluster,
        positions=None,
        journal: Journal | None = None,
    ):
        self.tenants = tenants
        self.plan = plan
        self.deployments = deployments
        self.controlplane = mtcp
        self.router = router
        self.cluster = cluster
        self.positions = positions
        self.journal = journal if journal is not None else Journal()

    # -- introspection -------------------------------------------------------
    def names(self) -> tuple[str, ...]:
        return tuple(self.deployments)

    def deployment(self, tenant: str):
        """The tenant's own ``Deployment`` facade."""
        return self.deployments[tenant]

    def nodes_for(self, tenant: str) -> tuple[int, ...]:
        return self.plan.nodes_for(tenant)

    @property
    def pending(self) -> int:
        return self.controlplane.pending

    # -- serving -------------------------------------------------------------
    def submit(self, tenant: str, x: Any, *,
               slo_class: str | None = None) -> Request:
        return self.router.submit(tenant, x, slo_class=slo_class)

    def schedule(self, tenant: str, x: Any, at_s: float, *,
                 slo_class: str | None = None) -> Request:
        return self.router.schedule(tenant, x, at_s, slo_class=slo_class)

    def submit_trace(self, tenant: str | None = None, trace=None,
                     make_input=None) -> int:
        """Schedule open-loop arrivals.  With ``tenant=None`` every tenant
        whose spec declares an arrival process schedules its own trace (per
        tenant seeds, merged by the router on the shared timeline)."""
        if tenant is None:
            if trace is not None:
                raise ValueError("an explicit trace needs a tenant=")
            return sum(
                self.submit_trace(t.name)
                for t in self.tenants if t.spec.arrival is not None
            )
        dep = self.deployments[tenant]
        if trace is None:
            arr = dep.spec.arrival
            if arr is None:
                raise RuntimeError(
                    f"tenant {tenant!r} has no arrival process; pass a trace")
            from repro.workload import make_trace

            trace = make_trace(
                arr.trace, rate=arr.rate, duration_s=arr.duration_s,
                seed=arr.seed, classes=dep.spec.slo_classes,
            )
        if make_input is None:
            make_input = lambda i, a: i  # noqa: E731
        for i, a in enumerate(trace.arrivals):
            self.schedule(tenant, make_input(i, a), a.t_s,
                          slo_class=a.slo_class)
        return len(trace.arrivals)

    def step(self) -> list[Request]:
        return self.router.step()

    def drain(self, max_rounds: int = 100_000) -> list[Request]:
        return self.router.drain(max_rounds=max_rounds)

    def completed(self, tenant: str | None = None) -> list[Request]:
        return self.router.completed(tenant)

    # -- churn + convergence -------------------------------------------------
    def inject(self, event: ClusterEvent, *, tenant: str | None = None) -> None:
        """Route one disturbance (tenant-scoped when ``tenant=`` is given;
        otherwise ownership routing decides who sees it)."""
        self.controlplane.submit(event, tenant=tenant)

    def reconcile(self, *, tenant: str | None = None) -> dict:
        return self.controlplane.reconcile(tenant=tenant)

    # -- reporting -----------------------------------------------------------
    def latency_report(self) -> dict:
        return self.router.latency_report({
            t.name: t.spec.class_targets() for t in self.tenants
        })

    def metrics(self) -> dict:
        """Cluster-level view: the carve, fairness counters, and every
        tenant's own ``Deployment.metrics()`` under its name."""
        from repro.cluster.serving import normalize_metrics

        return normalize_metrics({
            "mode": "multi-tenant",
            "policy": self.plan.policy,
            "n_nodes": self.cluster.n,
            "placements": self.plan.summary(),
            "routing": [
                {"tenant": t, "event": kind}
                for t, kind in self.controlplane.routed
            ],
            "serving": self.router.metrics(),
            "tenants": {
                name: dep.metrics()
                for name, dep in self.deployments.items()
            },
            "journal": self.journal.summary(),
        })

    # -- observability --------------------------------------------------------
    def trace_timeline(self) -> list[dict]:
        """Every tenant's span timeline merged (spans carry ``tenant``)."""
        out = [s for dep in self.deployments.values()
               for s in dep.trace_timeline()]
        out.sort(key=lambda s: (s["tenant"] or "", s["req_id"], s["t0_s"]))
        return out

    def chrome_trace(self) -> dict | None:
        """One Chrome trace across tenants: per-tenant pid blocks (each
        tenant's replica pids offset past the previous tenant's), process
        names prefixed with the tenant.  None when no tenant traces."""
        events: list[dict] = []
        offset = 0
        any_traced = False
        for name, dep in self.deployments.items():
            ct = dep.chrome_trace()
            if ct is None:
                continue
            any_traced = True
            max_pid = 0
            for ev in ct["traceEvents"]:
                ev = dict(ev)
                max_pid = max(max_pid, int(ev["pid"]))
                ev["pid"] = int(ev["pid"]) + offset
                if ev.get("ph") == "M":
                    ev["args"] = {"name": f"{name}: {ev['args']['name']}"}
                events.append(ev)
            offset += max_pid + 1
        if not any_traced:
            return None
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def attribution(self) -> dict:
        """Per-tenant critical-path attributions (None entries: no tracer)."""
        return {name: dep.attribution()
                for name, dep in self.deployments.items()}
