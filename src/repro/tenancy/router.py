"""``TenancyRouter``: multiplex per-tenant serving loops on one timeline.

Each tenant serves through its own engine (``PipelinedServingLoop``, or
``ReplicatedServingLoop`` for a replicated/autoscaled tenant) over its own
node slice; the router co-simulates them on one shared virtual timeline
with the same discrete-event rule the replica router uses -- always
advance the *lagging* tenant -- so the merged completion stream is in
time order across tenants.

Admission is quota-scoped: each tenant's ``admission_depth`` (its
``TenantSpec`` quota) is enforced inside that tenant's own loop, so one
tenant's overload sheds *its* arrivals without starving another's queue.
Ties on the shared timeline break by **weighted-fair deficit**: every
completion charges ``1 / weight`` to its tenant, and the tenant with the
smallest accumulated charge is served first among equally-lagging loops --
on shared nodes (the scheduler's ``"shared"`` policy) this is what
apportions service ``weight``-proportionally.

Completions are stamped with their tenant (``Request.tenant``), and
metrics/latency reports come back keyed per tenant.
"""

from __future__ import annotations

from typing import Any

from repro.cluster.serving import Request, latency_report, normalize_metrics


class TenancyRouter:
    """Weighted-fair multiplexer over per-tenant serving loops."""

    def __init__(
        self,
        loops: dict[str, Any],
        *,
        weights: dict[str, float] | None = None,
        quotas: dict[str, int | None] | None = None,
    ):
        if not loops:
            raise ValueError("at least one tenant loop is required")
        self.loops = dict(loops)
        self.weights = {
            name: float((weights or {}).get(name, 1.0)) for name in loops}
        if any(w <= 0 for w in self.weights.values()):
            raise ValueError("tenant weights must be > 0")
        self.quotas = {
            name: (quotas or {}).get(name) for name in loops}
        self.served = {name: 0 for name in loops}
        self._deficit = {name: 0.0 for name in loops}

    # -- aggregate views -----------------------------------------------------
    @property
    def clock_s(self) -> float:
        return max((loop.clock_s for loop in self.loops.values()), default=0.0)

    def loop(self, tenant: str):
        return self.loops[tenant]

    def completed(self, tenant: str | None = None) -> list[Request]:
        if tenant is not None:
            return list(self.loops[tenant].completed)
        out = [r for loop in self.loops.values() for r in loop.completed]
        out.sort(key=lambda r: (r.completed_s, r.tenant or "", r.req_id))
        return out

    @property
    def backlog(self) -> int:
        return sum(loop.backlog for loop in self.loops.values())

    @property
    def pending_arrivals(self) -> int:
        return sum(loop.pending_arrivals for loop in self.loops.values())

    # -- admission -----------------------------------------------------------
    def submit(self, tenant: str, x: Any, *,
               slo_class: str | None = None) -> Request:
        req = self.loops[tenant].submit(x, slo_class=slo_class)
        req.tenant = tenant
        return req

    def schedule(self, tenant: str, x: Any, at_s: float, *,
                 slo_class: str | None = None) -> Request:
        req = self.loops[tenant].schedule(x, at_s, slo_class=slo_class)
        req.tenant = tenant
        return req

    # -- serving -------------------------------------------------------------
    def _has_work(self, loop) -> bool:
        return loop.backlog > 0 or loop.pending_arrivals > 0

    def _pick(self) -> str | None:
        """The lagging tenant among those with work; weighted-fair deficit
        breaks clock ties (served/weight lowest first), then name."""
        ready = [n for n, loop in self.loops.items() if self._has_work(loop)]
        if not ready:
            return None
        return min(
            ready,
            key=lambda n: (self.loops[n].clock_s, self._deficit[n], n),
        )

    def step(self) -> list[Request]:
        """Advance the picked tenant's engine by one completion burst."""
        name = self._pick()
        if name is None:
            return []
        out = self.loops[name].step()
        for req in out:
            req.tenant = name
        self.served[name] += len(out)
        self._deficit[name] += len(out) / self.weights[name]
        return out

    def drain(self, max_rounds: int = 100_000) -> list[Request]:
        """Serve until every tenant's queue empties (stall-guarded: a pass
        where no loop advances -- e.g. a tenant with a dead slice -- stops
        instead of spinning)."""
        done: list[Request] = []
        stalled = 0
        for _ in range(max_rounds):
            if not any(self._has_work(loop) for loop in self.loops.values()):
                return done
            before = self._fingerprint()
            done.extend(self.step())
            if self._fingerprint() == before:
                stalled += 1
                if stalled > len(self.loops):
                    return done
            else:
                stalled = 0
        raise RuntimeError(f"drain did not converge in {max_rounds} rounds")

    def _fingerprint(self) -> tuple:
        return tuple(
            (loop.clock_s, loop.backlog, loop.pending_arrivals,
             len(loop.completed))
            for loop in self.loops.values()
        )

    # -- reporting -----------------------------------------------------------
    def steady_state_throughput(self, skip_frac: float = 0.5) -> dict:
        return {
            name: loop.steady_state_throughput(skip_frac)
            for name, loop in self.loops.items()
        }

    def latency_report(
        self, class_targets: dict[str, dict] | None = None,
    ) -> dict:
        """Per-tenant latency percentiles (``class_targets`` maps tenant ->
        that tenant's SLO-class targets)."""
        return {
            name: latency_report(
                loop.completed, (class_targets or {}).get(name))
            for name, loop in self.loops.items()
        }

    def metrics(self) -> dict:
        return normalize_metrics({
            "mode": "multi-tenant",
            "clock_s": self.clock_s,
            "backlog": self.backlog,
            "pending_arrivals": self.pending_arrivals,
            "fairness": {
                name: {
                    "weight": self.weights[name],
                    "quota": self.quotas[name],
                    "served": self.served[name],
                    "deficit": self._deficit[name],
                }
                for name in self.loops
            },
            "tenants": {
                name: loop.metrics() for name, loop in self.loops.items()
            },
        })
