"""``MultiTenantControlPlane``: tenant-scoped event routing (churn isolation).

The tenant-level generalization of ``ReplicaSet.submit``'s ownership
routing: every tenant's control entry (a ``ControlPlane`` or, for a
replicated tenant, a ``ReplicaSet``) is masked to the tenant's node slice,
and a cluster disturbance is delivered only to the tenant(s) whose view
contains it.  One tenant's ``NodeFailed`` re-plan therefore never perturbs
another tenant's live pipelines -- the isolation the chaos suite and the
multi-tenant benchmark assert.

Routing rules (``submit``):

  ===============  ======================================================
  event            routed to
  ===============  ======================================================
  NodeFailed       every tenant whose view owns the node (all tenants
                   when the shared dispatcher dies); no owner -> the
                   shared cluster state is updated and no pipeline moves
  NodeJoined       heal: the owning tenant; grow (or an orphaned heal):
                   the node joins the cluster at intake and the weakest
                   tenant -- lowest live throughput per unit weight --
                   adopts it into its slice
  LinkDegraded     the one tenant whose view contains BOTH endpoints
                   (under the partition policy tenant paths never ride
                   cross-slice links, so one tolerance check suffices;
                   under the shared policy the first owner checks, an
                   approximation);  no owner -> cluster-only mutation
  VersionBumped    tenant-scoped by nature (each tenant rolls its own
                   model): requires an explicit ``tenant=`` -- replicated
                   tenants then roll one replica at a time as before
  ===============  ======================================================

``reconcile()`` converges tenants independently and reports per tenant,
so one tenant's recovery actions are attributable -- and billable -- to
that tenant alone.
"""

from __future__ import annotations

from repro.cluster.controlplane import ControlPlane, ReconcileAction, ReplicaSet
from repro.cluster.events import (
    ClusterEvent,
    LinkDegraded,
    NodeFailed,
    NodeJoined,
    VersionBumped,
)


def _entry_throughput(entry) -> float:
    """Live predicted throughput of a tenant's control entry."""
    if isinstance(entry, ReplicaSet):
        return float(entry.deployed_plan().predicted_throughput)
    plan = entry.last_plan
    return float(plan.predicted_throughput) if plan is not None else 0.0


class MultiTenantControlPlane:
    """Per-tenant control entries over one shared ``EdgeCluster``."""

    def __init__(
        self,
        cluster,
        entries: "dict[str, ControlPlane | ReplicaSet]",
        *,
        weights: dict[str, float] | None = None,
        dispatcher_node: int = 0,
        journal=None,
    ):
        if not entries:
            raise ValueError("at least one tenant entry is required")
        self.cluster = cluster
        self.entries = dict(entries)
        self.weights = {
            name: float((weights or {}).get(name, 1.0)) for name in entries}
        self.dispatcher_node = dispatcher_node
        # routing log: (tenant | None, event class name) per delivery;
        # mirrored into the shared control-plane journal when one is given
        self.routed: list[tuple[str | None, str]] = []
        self.journal = journal

    def _route(self, tenant: str | None, kind: str) -> None:
        self.routed.append((tenant, kind))
        if self.journal is not None:
            self.journal.append(
                "route", "tenancy", {"tenant": tenant, "event": kind})

    # -- introspection -------------------------------------------------------
    def names(self) -> tuple[str, ...]:
        return tuple(self.entries)

    @property
    def pending(self) -> int:
        return sum(e.pending for e in self.entries.values())

    def observed(self) -> dict:
        return {name: e.observed() for name, e in self.entries.items()}

    def recovery_log(self) -> dict:
        """Per-tenant recovery records: a tenant's ``Dispatcher.last_recovery``
        (single plane) or its per-replica list (``ReplicaSet``).  ``None``
        entries mean no recovery re-solve has run there yet."""
        out = {}
        for name, e in self.entries.items():
            if hasattr(e, "recovery_log"):
                out[name] = e.recovery_log()
            else:
                out[name] = e.dispatcher.last_recovery
        return out

    def owners_of_node(self, node_id: int) -> list[str]:
        return [
            name for name, e in self.entries.items()
            if (owned := e.owned_nodes()) is None or node_id in owned
        ]

    def owners_of_link(self, a: int, b: int) -> list[str]:
        return [
            name for name, e in self.entries.items()
            if (owned := e.owned_nodes()) is None
            or (a in owned and b in owned)
        ]

    def _weakest(self) -> str:
        """The tenant furthest below its fair share: lowest live predicted
        throughput per unit weight (ties break by name for determinism)."""
        return min(
            self.entries,
            key=lambda n: (_entry_throughput(self.entries[n])
                           / self.weights[n], n),
        )

    # -- event intake --------------------------------------------------------
    def submit(self, event: ClusterEvent, *, tenant: str | None = None) -> None:
        """Route one disturbance to the tenant(s) it touches."""
        kind = type(event).__name__
        if tenant is not None:
            entry = self.entries[tenant]  # KeyError on unknown tenant
            entry.submit(event)
            self._route(tenant, kind)
            return
        if isinstance(event, VersionBumped):
            raise ValueError(
                "VersionBumped is tenant-scoped under multi-tenant serving; "
                "pass tenant=<name> to roll that tenant's model")
        if isinstance(event, NodeFailed):
            owners = self.owners_of_node(event.node_id)
            if not owners:
                # a spare node (or a retired slice's): keep the shared
                # cluster honest; no tenant pipeline is affected
                self.cluster.fail(event.node_id)
                self._route(None, kind)
                return
            for name in owners:
                self.entries[name].submit(event)
                self._route(name, kind)
            return
        if isinstance(event, NodeJoined):
            self._route_node_joined(event)
            return
        if isinstance(event, LinkDegraded):
            owners = self.owners_of_link(event.a, event.b)
            if not owners:
                self.cluster.degrade_link(event.a, event.b, event.factor)
                self._route(None, kind)
                return
            self.entries[owners[0]].submit(event)
            self._route(owners[0], kind)
            return
        # unknown event class: every tenant logs its own noop
        for name, entry in self.entries.items():
            entry.submit(event)
            self._route(name, kind)

    def _route_node_joined(self, event: NodeJoined) -> None:
        if event.comm is not None:
            # grow: the node joins the shared cluster exactly once at
            # intake, then the weakest tenant adopts it into its slice
            new_id = self.cluster.add_node(event.comm)
            self._adopt(self._weakest(), new_id)
            return
        owners = [
            name for name, e in self.entries.items()
            if (owned := e.owned_nodes()) is None or event.node_id in owned
        ]
        if owners:
            self.entries[owners[0]].submit(event)
            self._route(owners[0], "NodeJoined")
            return
        # a spare node coming back: the weakest tenant absorbs it
        self.cluster.heal(event.node_id)
        self._adopt(self._weakest(), event.node_id)

    def _adopt(self, name: str, node_id: int) -> None:
        entry = self.entries[name]
        if isinstance(entry, ControlPlane):
            # extend the masked view first, or the heal-style event would
            # be invisible to the tenant's dispatcher
            entry.adopt_node(node_id)
        # ReplicaSet entries adopt internally (weakest live replica)
        entry.submit(NodeJoined(node_id=node_id))
        self._route(name, "NodeJoined")

    # -- convergence ---------------------------------------------------------
    def reconcile(
        self, *, tenant: str | None = None,
    ) -> dict[str, list[ReconcileAction]]:
        """Converge tenants independently; per-tenant action lists."""
        names = [tenant] if tenant is not None else list(self.entries)
        return {name: self.entries[name].reconcile() for name in names}
