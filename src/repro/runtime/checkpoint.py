"""Fault-tolerant checkpointing: atomic, versioned, resumable.

Train state is flattened to numpy arrays and written ``tmp -> fsync ->
rename`` so a crash mid-save never corrupts the latest checkpoint; a STEP
pointer names the newest complete version.  Restore rebuilds the exact
pytree (structure comes from a treedef JSON).  This is the TPU analogue of
SEIFER's NFS store: state survives any worker's death.
"""

from __future__ import annotations

import json
import os
import re
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.store import ArtifactStore


def _to_np(x) -> np.ndarray:
    """npz-safe array: bf16 (and friends) stored as a uint16/uint8 view."""
    a = np.asarray(x)
    if a.dtype.kind == "V" or a.dtype.name.startswith(("bfloat16", "float8")):
        return a.view(np.uint16 if a.dtype.itemsize == 2 else np.uint8)
    return a


def _from_np(a: np.ndarray, dtype) -> "jnp.ndarray":
    dt = jnp.dtype(dtype)
    if a.dtype != dt and a.dtype.kind == "u" and a.dtype.itemsize == dt.itemsize:
        a = a.view(dt)  # stored as a raw view (bf16/f8)
    return jnp.asarray(a, dtype=dt)


def _flatten(tree: Any) -> tuple[dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return {f"leaf_{i:05d}": _to_np(x) for i, x in enumerate(leaves)}, treedef


class Checkpointer:
    def __init__(self, directory: str | os.PathLike, keep: int = 3):
        self.store = ArtifactStore(directory)
        self.keep = keep

    def save(self, step: int, state: Any) -> None:
        arrays, treedef = _flatten(state)
        self.store.put_arrays(step, "state", arrays)
        self.store.put_json(step, "meta", {
            "step": step,
            "treedef": str(treedef),
            "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        })
        self.store.publish(step)
        self._gc()

    def latest_step(self) -> int:
        return self.store.current_version()

    def restore(self, like: Any, step: int | None = None) -> tuple[int, Any]:
        """Restore into the structure of ``like`` (shape/dtype template)."""
        step = self.latest_step() if step is None else step
        if step < 0:
            raise FileNotFoundError("no checkpoint found")
        arrays = self.store.get_arrays(step, "state")
        leaves, treedef = jax.tree.flatten(like)
        if len(arrays) != len(leaves):
            raise ValueError(
                f"checkpoint has {len(arrays)} leaves, template has {len(leaves)}"
            )
        restored = [
            _from_np(arrays[f"leaf_{i:05d}"], l.dtype) for i, l in enumerate(leaves)
        ]
        return step, jax.tree.unflatten(treedef, restored)

    def _gc(self) -> None:
        vdirs = sorted(
            (d for d in self.store.root.iterdir() if re.match(r"v\d{6}", d.name)),
            key=lambda d: d.name,
        )
        for d in vdirs[: -self.keep]:
            for f in d.iterdir():
                f.unlink()
            d.rmdir()
