"""Training loop substrate: AdamW, grad clipping, LR schedule, microbatching.

Optimizer moments are stored in ``cfg.opt_state_dtype`` (bf16 for the 1T MoE
-- fp32 m/v for 1T params cannot fit 512 x 16 GB); all update math is fp32.
``make_train_step`` builds the jit-able step the dry-run lowers; the update
is fully shardable (moments follow the parameter shardings).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.common import NO_SHARDING


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    microbatch: int = 0  # 0 = no gradient accumulation
    accum_dtype: str = "float32"  # bf16 for the 1T MoE (HBM: grads = params)


def init_state(cfg, params: Any) -> dict:
    dt = jnp.dtype(cfg.opt_state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "params": params,
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def _lr_at(opt: OptConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step.astype(jnp.float32) / max(opt.warmup_steps, 1), 1.0)
    return opt.lr * warm


def adamw_update(cfg, opt: OptConfig, state: dict, grads: Any) -> dict:
    step = state["step"] + 1
    lr = _lr_at(opt, step)
    b1, b2 = opt.b1, opt.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    dt = jnp.dtype(cfg.opt_state_dtype)

    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, opt.grad_clip / jnp.maximum(gnorm, 1e-9))

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m32 / c1
        vhat = v32 / c2
        step_ = mhat / (jnp.sqrt(vhat) + opt.eps) + opt.weight_decay * p.astype(jnp.float32)
        return (
            (p.astype(jnp.float32) - lr * step_).astype(p.dtype),
            m32.astype(dt),
            v32.astype(dt),
        )

    out = jax.tree.map(upd, state["params"], grads, state["m"], state["v"])
    params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return {"params": params, "m": m, "v": v, "step": step}


def make_train_step(cfg, opt: OptConfig | None = None, policy=NO_SHARDING) -> Callable:
    """(state, batch) -> (state', metrics).  Microbatched when configured."""
    opt = opt or OptConfig()

    def loss_of(params, batch):
        return lm.loss_fn(cfg, params, batch, policy=policy)

    def train_step(state, batch):
        if opt.microbatch and opt.microbatch < _batch_dim(batch):
            grads, (loss, parts) = _accumulated_grads(
                loss_of, state["params"], batch, opt.microbatch,
                jnp.dtype(opt.accum_dtype),
            )
        else:
            (loss, parts), grads = jax.value_and_grad(loss_of, has_aux=True)(
                state["params"], batch
            )
        new_state = adamw_update(cfg, opt, state, grads)
        metrics = {
            "loss": loss,
            "xent": parts["xent"],
            "aux": parts["aux"],
            "grad_norm": _global_norm(grads),
        }
        return new_state, metrics

    return train_step


def _batch_dim(batch) -> int:
    return jax.tree.leaves(batch)[0].shape[0]


def _accumulated_grads(loss_of, params, batch, micro: int, accum_dtype=jnp.float32):
    """Gradient accumulation over batch slices (sequential, scan-based)."""
    b = _batch_dim(batch)
    n = b // micro
    sliced = jax.tree.map(lambda x: x.reshape((n, micro) + x.shape[1:]), batch)

    def step(carry, mb):
        g_acc, l_acc, x_acc, a_acc = carry
        (loss, parts), g = jax.value_and_grad(loss_of, has_aux=True)(params, mb)
        g_acc = jax.tree.map(
            lambda a, b_: a + (b_.astype(jnp.float32) / n).astype(accum_dtype), g_acc, g
        )
        return (g_acc, l_acc + loss / n, x_acc + parts["xent"] / n, a_acc + parts["aux"] / n), None

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)
    (grads, loss, xent, aux), _ = jax.lax.scan(
        step, (g0, jnp.float32(0), jnp.float32(0), jnp.float32(0)), sliced
    )
    return grads, (loss, {"xent": xent, "aux": aux})
