"""Serving steps: prefill (full-sequence logits) and decode (one token).

``make_prefill_step`` / ``make_serve_step`` build the jit-able functions the
dry-run lowers and the serving loop (`runtime/engine.py`) drives.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.common import NO_SHARDING


def make_prefill_step(cfg, policy=NO_SHARDING):
    """(params, batch) -> last-position logits (B, V)."""

    def prefill_step(params, batch):
        hidden, _ = lm.forward_hidden(cfg, params, batch, policy=policy, remat=False)
        last = hidden[:, -1]
        logits = jnp.einsum(
            "bd,dv->bv", last, lm.lm_head_matrix(cfg, params)
        ).astype(jnp.float32)
        if cfg.final_softcap > 0:
            logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
        return logits

    return prefill_step


def make_serve_step(cfg, policy=NO_SHARDING, *, enc_len: int = 0):
    """(params, caches, tokens (B,1)) -> (next_token (B,1), caches')."""

    def serve_step(params, caches, tokens):
        logits, caches = lm.decode_step(cfg, params, caches, tokens, enc_len=enc_len)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return next_tok, caches

    return serve_step
