"""GPipe pipeline over a mesh axis with SEIFER cuts + compressed boundaries.

This is the paper's technique as a first-class TPU feature:

  * **cuts** come from ``core.partitioner`` on the arch's exported
    LayerGraph (min-bottleneck contiguous cuts under per-stage memory),
  * **placement** of stages onto pods comes from ``core.placement`` on the
    ICI/DCN bandwidth table -- the heaviest boundary rides the fastest link,
  * **boundary transport** is ``jax.lax.ppermute`` inside ``shard_map``
    (the FIFO+TCP analogue), optionally int8-compressed
    (``kernels/quantize`` -- the ZFP/LZ4 analogue), halving DCN bytes.

GPipe schedule: ``n_micro + n_stages - 1`` ticks; stage s computes microbatch
``t - s`` at tick t.  Steady-state period = max(stage compute, link time) --
literally the paper's bottleneck-latency objective.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.bottleneck import evaluate_pipeline
from repro.core.execution import ExecutionKnob
from repro.core.graph import LayerGraph
from repro.core.partitioner import partition_exact_k
from repro.core.placement import CommGraph, place_optimal
from repro.dataplane.base import EncodedActivation
from repro.kernels.quantize import dequantize_int8, quantize_int8


# ---------------------------------------------------------------------------
# Planning: SEIFER cuts + stage->pod placement
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PipelinePlan:
    n_stages: int
    cuts: tuple[int, ...]  # layer-graph edges cut
    stage_order: tuple[int, ...]  # stage i runs on pod stage_order[i]
    bottleneck_bytes: float
    est_bottleneck_s: float
    # steady-state GPipe period under the serving engine's timing model
    # (max over stage compute and link times); 1/est_period_s is the
    # pipeline's predicted per-microbatch throughput once full
    est_period_s: float = 0.0


def plan_pipeline(
    graph: LayerGraph,
    n_stages: int,
    *,
    stage_capacity: float,
    pod_bw: np.ndarray | None = None,
    device_flops: float | Sequence[float] | None = None,
) -> PipelinePlan:
    """Cut the layer graph and place stages on the pod graph.

    ``pod_bw``: (n_stages, n_stages) inter-pod bandwidth (bytes/s).  Defaults
    to a DCN ring.  Placement maximizes throughput by matching the heaviest
    boundaries to the fastest links (exact min-bottleneck path).

    ``device_flops`` (per-pod compute rate) feeds the same
    ``core.bottleneck.service_times`` model the edge serving engine uses, so
    ``est_period_s`` is comparable across the TPU and edge backends.
    """
    part = partition_exact_k(graph, int(stage_capacity), n_stages)
    if not part.feasible:
        raise ValueError(
            f"model does not fit {n_stages} stages of {stage_capacity/1e9:.1f} GB"
        )
    if pod_bw is None:
        pod_bw = np.full((n_stages, n_stages), 6.25e9)
        np.fill_diagonal(pod_bw, 0.0)
    comm = CommGraph(bw=pod_bw, node_capacity=np.full(n_stages, stage_capacity))
    place = place_optimal(
        list(part.boundaries), [p.param_bytes for p in part.partitions], comm
    )
    if not place.feasible:
        raise ValueError("no feasible stage placement on the pod graph")
    # ONE steady-state definition: est_period_s IS
    # core.bottleneck.PipelineMetrics.pipeline_period on the same inputs --
    # max over every serial resource (stage compute times and link
    # latencies), the cadence of a full pipe.  tests/test_pipeline_multidev.py
    # pins the two against each other so they cannot drift apart again.
    metrics = evaluate_pipeline(
        part.partitions, place.path, comm, device_flops=device_flops
    )
    return PipelinePlan(
        n_stages=n_stages,
        cuts=part.cuts,
        stage_order=place.path,
        bottleneck_bytes=float(max(part.boundaries, default=0)),
        est_bottleneck_s=float(place.bottleneck_latency),
        est_period_s=float(metrics.pipeline_period),
    )


# ---------------------------------------------------------------------------
# GPipe execution inside shard_map
# ---------------------------------------------------------------------------

def make_gpipe(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    mesh: Mesh,
    *,
    axis: str = "stage",
    n_micro: int,
    compress: bool = False,
    quant_block: int = 256,
    stage_order: tuple[int, ...] | None = None,
    execution: ExecutionKnob | None = None,
):
    """Build a pipelined forward: (stage_params, x (n_micro, mb, ...)) -> y.

    ``stage_params`` leaves have a leading ``n_stages`` dim in MESH order
    (sharded over ``axis``; use ``reorder_stage_params`` to realize a SEIFER
    placement); ``x`` is replicated; output is (n_stages, n_micro, ...) --
    the last LOGICAL stage's rows are the pipeline output.

    ``stage_order[j]`` = mesh position hosting logical stage j; the
    ppermute route follows it, so the heaviest boundary rides the link the
    placement chose.

    ``execution`` (``repro.core.execution.ExecutionKnob``) selects the
    quantize path for the compressed send -- the same knob a
    ``DeploymentSpec`` threads to the edge engines' codecs.
    """
    n_stages = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    order = list(stage_order) if stage_order is not None else list(range(n_stages))
    perm = [(order[j], order[j + 1]) for j in range(n_stages - 1)]
    # logical stage index of each mesh position
    logical = np.argsort(np.asarray(order))
    ex_kw = execution.kwargs() if execution is not None else {}

    def _send(x):
        if not compress:
            return jax.lax.ppermute(x, axis, perm)
        q, s = quantize_int8(x, quant_block, **ex_kw)
        q = jax.lax.ppermute(q, axis, perm)
        s = jax.lax.ppermute(s, axis, perm)
        return dequantize_int8(q, s, dtype=x.dtype, **ex_kw)

    def pipe(stage_params, x):
        local = jax.tree.map(lambda t: t[0], stage_params)  # strip stage dim
        stage = jnp.asarray(logical)[jax.lax.axis_index(axis)]
        mb_shape = x.shape[1:]
        buf = jnp.zeros(mb_shape, x.dtype)  # incoming activation
        outs = jnp.zeros((n_micro,) + mb_shape, x.dtype)

        def tick(carry, t):
            buf, outs = carry
            feed = jax.lax.dynamic_index_in_dim(
                x, jnp.clip(t, 0, n_micro - 1), keepdims=False
            )
            inp = jnp.where(stage == 0, feed, buf)
            active = (t - stage >= 0) & (t - stage < n_micro)
            y = stage_fn(local, inp)
            y = jnp.where(active, y, jnp.zeros_like(y))
            out_t = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            is_out = (stage == n_stages - 1) & (t >= n_stages - 1)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs,
                jnp.where(is_out, y, outs[out_t]),
                out_t, 0,
            )
            buf = _send(y)
            return (buf, outs), None

        (buf, outs), _ = jax.lax.scan(
            tick, (buf, outs), jnp.arange(n_micro + n_stages - 1)
        )
        return outs

    in_specs = (P(axis), P())
    out_specs = P(axis)  # concatenates stage rows along dim 0
    sm = shard_map(pipe, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)

    def run(stage_params, x):
        out = sm(stage_params, x)
        out = out.reshape((n_stages,) + x.shape)  # (stage, n_micro, mb...)
        return out[order[-1]]  # rows of the last LOGICAL stage

    return run


# ---------------------------------------------------------------------------
# Edge-cluster bridge: run the same stage execution through simulated pods
# ---------------------------------------------------------------------------

def make_layer_executor(layer_fns: list[Callable[[jax.Array], jax.Array]]):
    """Adapt per-layer callables into the cluster ``ExecutorFn`` signature.

    The edge control plane's ``InferencePipeline`` drives pods with
    ``executor(start, stop, x)`` over the partition's layer range -- this is
    the bridge that lets the TPU-side stage functions (or any per-layer jnp
    closures) serve through the simulated pod chain, so the serving loop's
    microbatches exercise identical math on both backends.

    **Fused decode protocol.**  A layer fn may carry a ``fused`` attribute --
    a ``{codec_name: handler}`` dict whose handler consumes a still-encoded
    boundary activation (``dataplane.base.EncodedActivation``) directly,
    e.g. int8 wire payloads feeding ``kernels.quantize.dequant_matmul``
    instead of a separate dequantize pass.  The executor advertises
    ``executor.fused_codecs`` -- codec names EVERY layer can consume, so the
    engine's gating stays correct for any partition cut point -- and
    transparently falls back to ``EncodedActivation.decode()`` when the
    entry layer has no handler.
    """
    fused_codecs: frozenset[str] | None = None
    for fn in layer_fns:
        keys = frozenset(getattr(fn, "fused", {}) or {})
        fused_codecs = keys if fused_codecs is None else fused_codecs & keys

    def executor(start: int, stop: int, x):
        if isinstance(x, EncodedActivation):
            handler = None
            if start < stop:
                handler = getattr(layer_fns[start], "fused", {}).get(x.codec.name)
            if handler is not None:
                x = handler(x)
                start += 1
            else:
                x = x.decode()
        for i in range(start, stop):
            x = layer_fns[i](x)
        return x

    executor.fused_codecs = fused_codecs or frozenset()
    return executor


def reorder_stage_params(stage_params: Any, plan: PipelinePlan) -> Any:
    """Permute logically-ordered stage params into mesh order.

    Input leaves are stacked in LOGICAL stage order; mesh position p must
    hold logical stage argsort(stage_order)[p] so that, combined with the
    route in ``make_gpipe``, logical stage j physically runs on pod
    ``plan.stage_order[j]``.
    """
    inv = np.argsort(np.asarray(plan.stage_order))
    return jax.tree.map(lambda t: t[inv], stage_params)
