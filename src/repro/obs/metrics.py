"""Metrics registry: counter/gauge/histogram primitives with label sets.

Each deployment owns one :class:`MetricsRegistry`.  Components update
instruments directly on the hot path (engines count
completions/failures/requeues and observe latencies), and
``Deployment.metrics()`` additionally mirrors its assembled JSON payload
into gauges via :meth:`MetricsRegistry.ingest` -- so
:meth:`MetricsRegistry.snapshot` is the one schema-validated superset
view while the legacy payload shape stays byte-identical on top of it.

Everything recorded here must be a finite native number derived from the
virtual clock / request counts -- :meth:`snapshot` validates this, so a
wall-clock read or a NaN sneaking into the registry fails loudly instead
of silently breaking same-seed determinism.
"""

from __future__ import annotations

import math

from repro.obs.stats import percentile


class SnapshotSchemaError(ValueError):
    """A registry snapshot violates the metrics schema."""


def _labelkey(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Counter:
    """Monotonically non-decreasing count."""

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = {str(k): str(v) for k, v in labels.items()}
        self.value = 0

    def inc(self, by: int | float = 1) -> None:
        if by < 0:
            raise ValueError(f"counter {self.name} cannot decrease (by={by})")
        self.value += by


class Gauge:
    """Last-write-wins instantaneous value."""

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = {str(k): str(v) for k, v in labels.items()}
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = value


class Histogram:
    """Streaming distribution: count/sum/min/max + retained observations
    for nearest-rank percentiles (bounded; oldest dropped past the cap)."""

    def __init__(self, name: str, labels: dict, *, keep: int = 4096):
        self.name = name
        self.labels = {str(k): str(v) for k, v in labels.items()}
        self.count = 0
        self.sum = 0.0
        self.min = None
        self.max = None
        self._keep = int(keep)
        self._obs: list[float] = []

    def observe(self, value: float) -> None:
        value = float(value)
        self.count += 1
        self.sum += value
        self.min = value if self.min is None else min(self.min, value)
        self.max = value if self.max is None else max(self.max, value)
        self._obs.append(value)
        if len(self._obs) > self._keep:
            del self._obs[: len(self._obs) - self._keep]

    def quantile(self, q: float) -> float:
        return percentile(sorted(self._obs), q)


class MetricsRegistry:
    """One deployment-wide home for every instrument."""

    def __init__(self):
        self._counters: dict[tuple, Counter] = {}
        self._gauges: dict[tuple, Gauge] = {}
        self._histograms: dict[tuple, Histogram] = {}

    # -- instrument accessors (create on first use) ------------------------
    def counter(self, name: str, **labels) -> Counter:
        key = (name, _labelkey(labels))
        if key not in self._counters:
            self._counters[key] = Counter(name, labels)
        return self._counters[key]

    def gauge(self, name: str, **labels) -> Gauge:
        key = (name, _labelkey(labels))
        if key not in self._gauges:
            self._gauges[key] = Gauge(name, labels)
        return self._gauges[key]

    def histogram(self, name: str, **labels) -> Histogram:
        key = (name, _labelkey(labels))
        if key not in self._histograms:
            self._histograms[key] = Histogram(name, labels)
        return self._histograms[key]

    # -- payload mirroring -------------------------------------------------
    def ingest(self, prefix: str, payload) -> None:
        """Mirror every numeric leaf of a metrics payload into gauges.

        The gauge name is the dotted path (list indices become an ``i``
        label component), so the registry snapshot subsumes the legacy
        ``metrics()`` dict without changing its shape.
        """
        def walk(value, path):
            if isinstance(value, dict):
                for k, v in value.items():
                    walk(v, f"{path}.{k}")
            elif isinstance(value, (list, tuple)):
                for i, v in enumerate(value):
                    walk(v, f"{path}[{i}]")
            elif isinstance(value, bool) or value is None or isinstance(value, str):
                return
            elif isinstance(value, (int, float)):
                if isinstance(value, float) and not math.isfinite(value):
                    return
                self.gauge(path).set(value)

        walk(payload, prefix)

    # -- export ------------------------------------------------------------
    def snapshot(self) -> dict:
        """One schema-validated export of every instrument."""
        snap = {
            "counters": [
                {"name": c.name, "labels": c.labels, "value": c.value}
                for _, c in sorted(self._counters.items())
            ],
            "gauges": [
                {"name": g.name, "labels": g.labels, "value": g.value}
                for _, g in sorted(self._gauges.items())
            ],
            "histograms": [
                {"name": h.name, "labels": h.labels, "count": h.count,
                 "sum": h.sum, "min": h.min if h.min is not None else 0.0,
                 "max": h.max if h.max is not None else 0.0,
                 "p50": h.quantile(0.50), "p95": h.quantile(0.95),
                 "p99": h.quantile(0.99)}
                for _, h in sorted(self._histograms.items())
            ],
        }
        validate_snapshot(snap)
        return snap

    def summary(self) -> dict:
        """Tiny digest for embedding in metrics payloads."""
        return {"counters": len(self._counters), "gauges": len(self._gauges),
                "histograms": len(self._histograms)}


def validate_snapshot(snap: dict) -> None:
    """Schema check: str names, str->str labels, finite native numbers."""
    for family in ("counters", "gauges", "histograms"):
        entries = snap.get(family)
        if not isinstance(entries, list):
            raise SnapshotSchemaError(f"{family} must be a list")
        for e in entries:
            if not isinstance(e.get("name"), str) or not e["name"]:
                raise SnapshotSchemaError(f"{family} entry without a name: {e!r}")
            labels = e.get("labels")
            if not isinstance(labels, dict) or any(
                    not isinstance(k, str) or not isinstance(v, str)
                    for k, v in labels.items()):
                raise SnapshotSchemaError(
                    f"{family} entry {e['name']}: labels must be str->str")
            for k, v in e.items():
                if k in ("name", "labels"):
                    continue
                if isinstance(v, bool) or not isinstance(v, (int, float)):
                    raise SnapshotSchemaError(
                        f"{family} entry {e['name']}.{k}: non-numeric {v!r}")
                if isinstance(v, float) and not math.isfinite(v):
                    raise SnapshotSchemaError(
                        f"{family} entry {e['name']}.{k}: non-finite value")
