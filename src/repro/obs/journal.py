"""The control-plane journal: one append-only, monotonic event log.

Before this existed, "what did the control plane do?" was smeared across
four incompatible private logs: ``ControlPlane.history`` (reconcile
actions), ``Dispatcher.last_recovery`` (a single overwritten dict),
``Autoscaler.events`` (``ScaleEvent``s), and
``MultiTenantControlPlane.routed`` (tenancy routing pairs).  Those
structures still exist for their owners' internal use, but every decision
now *also* lands here as a :class:`JournalRecord`, so a single ordered
read reconstructs the full control-plane story of a run.

Timestamps come from registered virtual-clock providers (the serving
loops / router), clamped monotone non-decreasing: a record is stamped
``max(last_t, max(clocks))``, so the journal is totally ordered by
``(t_s, seq)`` even when multiple engines with skewed clocks share it
(multi-tenant deployments share one journal across tenants).

Only JSON-scalar detail values are accepted -- the journal is part of the
metrics surface and must survive ``normalize_metrics`` byte-identically
across same-seed runs.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class JournalRecord:
    """One control-plane decision.

    ``kind`` is the decision class (``reconcile``, ``recovery``,
    ``rollout``, ``retire``, ``scale``, ``route``, ...); ``source`` names
    the emitting component (``control``, ``replica:2``,
    ``tenant:alpha/control``, ``autoscaler``...); ``detail`` is a flat
    JSON-scalar dict specific to the kind.
    """

    seq: int
    t_s: float
    kind: str
    source: str
    detail: dict

    def as_dict(self) -> dict:
        return {"seq": self.seq, "t_s": self.t_s, "kind": self.kind,
                "source": self.source, "detail": dict(self.detail)}


class Journal:
    """Append-only, monotonically-timestamped control-plane event log."""

    def __init__(self):
        self.records: list[JournalRecord] = []
        self._clocks: list = []  # callables -> current virtual time
        self._last_t = 0.0

    def bind_clock(self, clock) -> None:
        """Register a virtual-clock provider (callable -> seconds).

        Several providers may be registered (one per serving loop sharing
        the journal); records are stamped with the max across providers,
        clamped non-decreasing.
        """
        self._clocks.append(clock)

    def now(self) -> float:
        ts = [float(c()) for c in self._clocks]
        t = max(ts) if ts else self._last_t
        return max(t, self._last_t)

    def append(self, kind: str, source: str, detail: dict | None = None,
               *, t_s: float | None = None) -> JournalRecord:
        """Record a decision; returns the appended record.

        ``t_s`` overrides the clock when the caller knows the decision
        time precisely (e.g. autoscaler events carry their own stamp); it
        is still clamped monotone so the log stays ordered.
        """
        t = self.now() if t_s is None else max(float(t_s), self._last_t)
        self._last_t = t
        rec = JournalRecord(len(self.records), t, str(kind), str(source),
                            dict(detail or {}))
        self.records.append(rec)
        return rec

    # -- views -------------------------------------------------------------
    def __len__(self) -> int:
        return len(self.records)

    def select(self, kind: str | None = None,
               source: str | None = None) -> list[JournalRecord]:
        return [r for r in self.records
                if (kind is None or r.kind == kind)
                and (source is None or r.source == source)]

    def as_dicts(self) -> list[dict]:
        return [r.as_dict() for r in self.records]

    def summary(self) -> dict:
        """Metrics-payload digest: counts per kind + last stamp."""
        kinds: dict[str, int] = {}
        for r in self.records:
            kinds[r.kind] = kinds.get(r.kind, 0) + 1
        return {
            "records": len(self.records),
            "kinds": kinds,
            "last_t_s": self.records[-1].t_s if self.records else 0.0,
        }
