"""Unified observability plane: spans, journal, metrics registry, attribution.

One package owns every "what happened and where did the time go" question:

* ``obs.trace`` -- per-request span timelines on the virtual clock
  (``TraceConfig``/``SpanTracer``), with JSON-timeline and Chrome
  trace-event (Perfetto-loadable) exporters.
* ``obs.journal`` -- the append-only, monotonically-timestamped
  control-plane journal unifying reconcile decisions, scoped-recovery
  records, rollout transitions, autoscaler scale events, and tenancy
  event routing.
* ``obs.metrics`` -- counter/gauge/histogram primitives with label sets,
  exported as one schema-validated snapshot.
* ``obs.stats`` -- the single nearest-rank percentile + latency report
  implementation (serving, tenancy, and the autoscaler all route here).
* ``obs.critical_path`` -- folds span timelines into per-request and
  aggregate latency attributions (queue/compute/wire/transcode) and pins
  observed per-stage service times against the plan's
  ``core.bottleneck.service_times`` predictions.

Nothing in this package imports from ``repro.api``/``repro.cluster`` --
it sits below them so every layer can depend on it without cycles.
"""

from repro.obs.critical_path import analyze_spans, request_attribution
from repro.obs.journal import Journal, JournalRecord
from repro.obs.metrics import MetricsRegistry
from repro.obs.stats import latency_report, latency_stats, percentile
from repro.obs.trace import Span, SpanTracer, TraceConfig

__all__ = [
    "Journal",
    "JournalRecord",
    "MetricsRegistry",
    "Span",
    "SpanTracer",
    "TraceConfig",
    "analyze_spans",
    "latency_report",
    "latency_stats",
    "percentile",
    "request_attribution",
]
