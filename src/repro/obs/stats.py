"""Shared latency statistics: ONE nearest-rank percentile implementation.

``cluster/serving.latency_report``, ``tenancy/router.latency_report`` and
the autoscaler's recent-window p99 each used to carry their own copy of
the nearest-rank computation; they all route here now, so a percentile
quoted anywhere in a metrics payload means exactly the same thing.
"""

from __future__ import annotations

import math


def percentile(sorted_vals, q: float) -> float:
    """Nearest-rank percentile (q in [0, 1]) over pre-sorted values."""
    if not sorted_vals:
        return 0.0
    rank = max(1, math.ceil(q * len(sorted_vals)))
    return float(sorted_vals[rank - 1])


def latency_stats(requests) -> dict:
    """p50/p95/p99 + mean/max admit-to-complete latency of completed requests."""
    lats = sorted(r.latency_s for r in requests if r.done)
    n = len(lats)
    return {
        "count": n,
        "mean_s": sum(lats) / n if n else 0.0,
        "p50_s": percentile(lats, 0.50),
        "p95_s": percentile(lats, 0.95),
        "p99_s": percentile(lats, 0.99),
        "max_s": lats[-1] if n else 0.0,
    }


def latency_report(requests, class_targets: dict | None = None) -> dict:
    """Latency percentiles overall and per SLO class.

    ``class_targets`` maps class name -> target latency (seconds) or None;
    classed entries gain ``target_s`` and ``attainment`` (fraction of the
    class's completions within target).  Requests without a class report
    under ``"default"``.
    """
    by_class: dict[str, list] = {}
    for r in requests:
        if r.done:
            by_class.setdefault(r.slo_class or "default", []).append(r)
    classes = {}
    for name in sorted(by_class):
        reqs = by_class[name]
        entry = latency_stats(reqs)
        target = (class_targets or {}).get(name)
        entry["target_s"] = target
        entry["attainment"] = (
            sum(1 for r in reqs if r.latency_s <= target) / len(reqs)
            if target is not None and reqs else None
        )
        classes[name] = entry
    return {"overall": latency_stats(r for r in requests if r.done),
            "classes": classes}
