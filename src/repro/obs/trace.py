"""Per-request span timelines on the virtual clock.

A **span** is one contiguous interval ``[t0_s, t1_s)`` of a request's life,
labelled with a phase -- ``queue`` (admission or stage-input wait,
out-buffer backpressure included), ``exec`` (stage compute), and the link
window decomposed into ``encode``/``wire``/``decode`` via the codec cost
model.  Spans are emitted by the serving engines at every microbatch state
transition, so a completed request's spans tile ``[submitted_s,
completed_s)`` exactly: monotone, contiguous, no gaps or overlaps.

Everything is driven by the engines' virtual clocks -- no wall-clock
reads -- so same-seed runs produce byte-identical trace output.  Sampling
is a deterministic hash of the request id (``crc32``), not an RNG draw, so
enabling tracing at any rate never perturbs the simulation itself.

``SpanTracer`` is deliberately dumb storage plus a couple of bookkeeping
maps; all interpretation lives in :mod:`repro.obs.critical_path`, and the
exporters (:meth:`SpanTracer.timeline`, :meth:`SpanTracer.chrome_trace`)
are pure views.  The Chrome export loads directly in ``chrome://tracing``
or https://ui.perfetto.dev: one process per replica, one track per
request.
"""

from __future__ import annotations

import dataclasses
import math
import zlib

_U32 = float(1 << 32)


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Spec-level tracing knob (zero overhead when absent from the spec).

    ``sample`` is the fraction of requests traced, decided per ``req_id``
    by a deterministic hash seeded with ``seed`` -- 1.0 traces everything,
    0.01 traces ~1%.  ``max_spans`` bounds retained spans; past it new
    spans are counted in ``SpanTracer.dropped`` instead of stored.
    """

    sample: float = 1.0
    max_spans: int = 200_000
    seed: int = 0

    def issues(self) -> list[str]:
        """Validation problems, empty when the config is well-formed."""
        out = []
        if not isinstance(self.sample, (int, float)) or isinstance(self.sample, bool) \
                or not (0.0 <= float(self.sample) <= 1.0):
            out.append(f"trace.sample must be in [0, 1], got {self.sample!r}")
        if not isinstance(self.max_spans, int) or isinstance(self.max_spans, bool) \
                or self.max_spans < 1:
            out.append(f"trace.max_spans must be a positive int, got {self.max_spans!r}")
        return out


@dataclasses.dataclass(frozen=True)
class Span:
    """One attributed interval of one request's timeline."""

    req_id: int
    phase: str  # queue | exec | encode | wire | decode
    t0_s: float
    t1_s: float
    stage: int | None = None  # pipeline stage index (exec / stage-input queue)
    hop: int | None = None    # link hop index (encode / wire / decode)
    replica: int | None = None
    tenant: str | None = None
    codec: str | None = None
    generation: int = 0
    attempt: int = 0

    @property
    def duration_s(self) -> float:
        return self.t1_s - self.t0_s

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["duration_s"] = self.duration_s
        return d


PHASES = ("queue", "exec", "encode", "wire", "decode")


class SpanTracer:
    """Append-only span store shared by every engine of one deployment.

    The engines own the *when* (they call :meth:`record` at microbatch
    state transitions); the tracer owns sampling, retention, and the
    admission bookkeeping map ``queue_since`` (req_id -> time the request
    last entered an admission queue, so the queue span survives
    engine-internal requeues without the engine holding per-request state).

    Storage is a flat list of field tuples (``Span``'s fields, in order):
    the serving hot path pays one tuple append per span, and the ``Span``
    objects the views hand out are materialized lazily (cached until the
    store mutates).
    """

    def __init__(self, config: TraceConfig | None = None):
        self.config = config or TraceConfig()
        self._raw: list[tuple] = []
        self._cache: list[Span] | None = None
        self._max_spans = int(self.config.max_spans)
        self.dropped = 0
        self.queue_since: dict[int, float] = {}
        self._sample = float(self.config.sample)
        self._seed = int(self.config.seed)
        # hash threshold precomputed once: sampled iff crc32 < _threshold
        self._threshold = int(self._sample * _U32)

    @property
    def spans(self) -> list[Span]:
        """Materialized ``Span`` views of the raw store (cached)."""
        if self._cache is None:
            self._cache = [Span(*t) for t in self._raw]
        return self._cache

    # -- sampling ----------------------------------------------------------
    def sampled(self, req_id: int) -> bool:
        """Deterministic per-request sampling decision (no RNG state)."""
        if self._sample >= 1.0:
            return True
        if self._sample <= 0.0:
            return False
        h = zlib.crc32(f"{self._seed}:{req_id}".encode())
        return h < self._threshold

    # -- recording ---------------------------------------------------------
    def record(self, req_id: int, phase: str, t0_s: float, t1_s: float,
               stage=None, hop=None, replica=None, tenant=None, codec=None,
               generation: int = 0, attempt: int = 0) -> None:
        """Record one span from its fields (the serving hot path: one tuple
        append, no ``Span`` construction).  Zero-length spans are skipped
        (phase boundaries at the same clock tick carry no time), over-cap
        spans are counted in ``dropped`` instead of stored."""
        if t1_s <= t0_s:
            return
        if len(self._raw) >= self._max_spans:
            self.dropped += 1
            return
        self._raw.append((req_id, phase, t0_s, t1_s, stage, hop,
                          replica, tenant, codec, generation, attempt))
        self._cache = None

    def record_many(self, reqs, phase: str, t0_s: float, t1_s: float,
                    stage=None, hop=None, codec=None,
                    generation: int = 0) -> None:
        """Record one identical window for every request riding a
        microbatch -- the engine fan-out path, one call per transition."""
        if t1_s <= t0_s:
            return
        raw = self._raw
        cap = self._max_spans
        for req in reqs:
            if len(raw) >= cap:
                self.dropped += 1
                continue
            raw.append((req.req_id, phase, t0_s, t1_s, stage, hop,
                        req.replica, req.tenant, codec, generation,
                        req.attempts))
        self._cache = None

    def emit(self, span: Span) -> None:
        """Record an already-built ``Span`` (views/tests convenience)."""
        self.record(*dataclasses.astuple(span))

    def queue_open(self, req_id: int, t_s: float) -> None:
        """Mark a request (re-)entering an admission queue at ``t_s``."""
        self.queue_since[req_id] = t_s

    def queue_take(self, req) -> float:
        """Pop the request's queue-entry time (default: its arrival)."""
        return self.queue_since.pop(req.req_id, req.submitted_s)

    def restart(self, req_id: int) -> None:
        """Drop one request's timeline (it is restarting on another engine
        whose clock is unrelated; its life will be re-attributed there)."""
        self.restart_many({req_id})

    def restart_many(self, req_ids) -> None:
        ids = set(req_ids)
        if not ids:
            return
        self._raw = [t for t in self._raw if t[0] not in ids]
        self._cache = None
        for rid in ids:
            self.queue_since.pop(rid, None)

    def forget(self, req_id: int) -> None:
        """Drop bookkeeping for a request leaving the system (failed)."""
        self.queue_since.pop(req_id, None)

    # -- views -------------------------------------------------------------
    def spans_for(self, req_id: int) -> list[Span]:
        return [s for s in self.spans if s.req_id == req_id]

    def timeline(self) -> list[dict]:
        """JSON timeline: one flat dict per span, time-ordered per request."""
        return [s.as_dict()
                for s in sorted(self.spans, key=lambda s: (s.req_id, s.t0_s))]

    def chrome_trace(self, *, process_prefix: str = "replica") -> dict:
        """Chrome trace-event export (``chrome://tracing`` / Perfetto).

        Complete ("X") events, microsecond timestamps; ``pid`` is the
        replica index (0 when single-pipeline), ``tid`` the request id, so
        every request renders as its own track and spans on one track
        never overlap (they tile the request's life by construction).
        """
        events = []
        pids = {}
        for s in sorted(self.spans, key=lambda s: (s.t0_s, s.req_id)):
            pid = s.replica if s.replica is not None else 0
            pids.setdefault(pid, s.tenant)
            where = ""
            if s.stage is not None:
                where = f"[s{s.stage}]"
            elif s.hop is not None:
                where = f"[h{s.hop}]"
            events.append({
                "ph": "X",
                "name": f"{s.phase}{where}",
                "cat": s.phase,
                "ts": s.t0_s * 1e6,
                "dur": (s.t1_s - s.t0_s) * 1e6,
                "pid": pid,
                "tid": s.req_id,
                "args": {
                    "stage": s.stage, "hop": s.hop, "codec": s.codec,
                    "tenant": s.tenant, "generation": s.generation,
                    "attempt": s.attempt,
                },
            })
        meta = []
        for pid in sorted(pids):
            tenant = pids[pid]
            name = f"{process_prefix} {pid}" + (f" ({tenant})" if tenant else "")
            meta.append({"ph": "M", "name": "process_name", "pid": pid,
                         "tid": 0, "args": {"name": name}})
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}

    def summary(self) -> dict:
        """Small metrics-payload-safe digest (counts only)."""
        by_phase: dict[str, int] = {}
        for t in self._raw:
            by_phase[t[1]] = by_phase.get(t[1], 0) + 1
        return {
            "sample": self._sample,
            "spans": len(self._raw),
            "dropped": self.dropped,
            "requests": len({t[0] for t in self._raw}),
            "by_phase": by_phase,
        }


# -- link-window decomposition --------------------------------------------

def split_hop(link_s: float, codec, raw_bytes: int,
              src_flops: float = 0.0, dst_flops: float = 0.0):
    """Analytic ``(encode_s, wire_s, decode_s)`` decomposition of one hop.

    Uses the codec cost model (the same one ``dataplane.link_charge_s``
    charges), so the three parts sum to the hop's total service time.
    Codec-free hops are pure wire; dead links (inf) stay pure wire so the
    infinity never leaks into encode/decode.
    """
    link_s = float(link_s)
    if codec is None or not math.isfinite(link_s):
        return (0.0, link_s, 0.0)
    enc = float(codec.encode_cost_s(raw_bytes, src_flops))
    dec = float(codec.decode_cost_s(raw_bytes, dst_flops))
    wire = max(0.0, link_s - enc - dec)
    return (enc, wire, dec)


def split_window(t0: float, t1: float, parts) -> list[tuple[str, float, float]]:
    """Tile the observed window ``[t0, t1)`` into encode/wire/decode spans
    proportionally to the analytic ``parts`` -- exact when the ride ran to
    completion (window == sum(parts)), proportional when churn truncated
    it, pure wire when the analytic total is zero or infinite.  Segments
    share boundaries, so their durations telescope to ``t1 - t0``."""
    dur = t1 - t0
    if dur <= 0:
        return []
    enc, wire, dec = (float(p) for p in parts)
    total = enc + wire + dec
    if total <= 0 or not math.isfinite(total):
        return [("wire", t0, t1)]
    b1 = t0 + dur * (enc / total)
    b2 = t1 - dur * (dec / total)
    segs = [("encode", t0, b1), ("wire", b1, b2), ("decode", b2, t1)]
    return [(phase, a, b) for phase, a, b in segs if b > a]
