"""Critical-path analyzer: fold span timelines into latency attributions.

Per request: what fraction of admit-to-complete latency went to queueing,
stage compute, link wire, and codec transcode (encode+decode).  Aggregate:
per-stage and per-hop observed service times, the observed bottleneck
resource, and a pin of observed per-stage service against the plan's
``core.bottleneck.service_times`` prediction -- PR 3 pinned *throughput*
against the plan once, in one benchmark; this makes the same
prediction-vs-measurement check an always-available diagnostic at
per-stage granularity.

Spans are already exact on the virtual clock, so in a churn-free run the
observed medians equal the plan's numbers to float precision; the 5%
tolerance absorbs truncated spans under churn.
"""

from __future__ import annotations

import math

# span phase -> attribution group
GROUPS = {
    "queue": "queue",
    "exec": "compute",
    "wire": "wire",
    "encode": "transcode",
    "decode": "transcode",
}
GROUP_NAMES = ("queue", "compute", "wire", "transcode")


def _median(vals: list[float]) -> float:
    if not vals:
        return 0.0
    s = sorted(vals)
    n = len(s)
    mid = n // 2
    return s[mid] if n % 2 else 0.5 * (s[mid - 1] + s[mid])


def request_attribution(spans) -> dict:
    """Fractions of one request's total span time per attribution group.

    Fractions sum to 1 (± float addition error) because the spans tile the
    request's life contiguously and every phase maps to exactly one group.
    """
    totals = {g: 0.0 for g in GROUP_NAMES}
    for s in spans:
        totals[GROUPS[s.phase]] += s.duration_s
    total = sum(totals.values())
    if total <= 0:
        return {"total_s": 0.0, **{g: 0.0 for g in GROUP_NAMES}}
    return {"total_s": total, **{g: totals[g] / total for g in GROUP_NAMES}}


def analyze_spans(spans) -> dict:
    """Aggregate attribution over a span set (typically a whole run).

    Returns time-weighted overall fractions, per-request mean fractions,
    per-stage exec service times, per-hop link windows, and the observed
    bottleneck resource ``{"kind": "stage"|"link", "index", "service_s"}``
    (the resource whose per-visit service time is largest -- the engine's
    steady-state period is set by exactly this resource).
    """
    by_req: dict[int, list] = {}
    group_totals = {g: 0.0 for g in GROUP_NAMES}
    stage_exec: dict[int, list[float]] = {}
    hop_time: dict[int, dict[str, float]] = {}
    hop_crossings: dict[int, int] = {}
    for s in spans:
        by_req.setdefault(s.req_id, []).append(s)
        group_totals[GROUPS[s.phase]] += s.duration_s
        if s.phase == "exec" and s.stage is not None:
            stage_exec.setdefault(s.stage, []).append(s.duration_s)
        elif s.phase in ("encode", "wire", "decode") and s.hop is not None:
            agg = hop_time.setdefault(s.hop, {"wire": 0.0, "transcode": 0.0})
            agg["wire" if s.phase == "wire" else "transcode"] += s.duration_s
            if s.phase == "wire":
                hop_crossings[s.hop] = hop_crossings.get(s.hop, 0) + 1

    total = sum(group_totals.values())
    fractions = {g: (group_totals[g] / total if total > 0 else 0.0)
                 for g in GROUP_NAMES}

    per_req = [request_attribution(ss) for ss in by_req.values()]
    n_req = len(per_req)
    per_request_mean = {
        g: (sum(a[g] for a in per_req) / n_req if n_req else 0.0)
        for g in GROUP_NAMES
    }

    stages = [{
        "stage": s,
        "count": len(durs),
        "mean_s": sum(durs) / len(durs),
        "median_s": _median(durs),
    } for s, durs in sorted(stage_exec.items())]

    hops = []
    for h in sorted(hop_time):
        crossings = max(1, hop_crossings.get(h, 0))
        tot = hop_time[h]["wire"] + hop_time[h]["transcode"]
        hops.append({
            "hop": h,
            "crossings": hop_crossings.get(h, 0),
            "mean_s": tot / crossings,
            "wire_s": hop_time[h]["wire"] / crossings,
            "transcode_s": hop_time[h]["transcode"] / crossings,
        })

    bottleneck = None
    candidates = [("stage", row["stage"], row["median_s"]) for row in stages]
    candidates += [("link", row["hop"], row["mean_s"]) for row in hops]
    if candidates:
        kind, index, service = max(candidates, key=lambda c: c[2])
        bottleneck = {"kind": kind, "index": index, "service_s": service}

    return {
        "requests": n_req,
        "spans": sum(len(ss) for ss in by_req.values()),
        "fractions": fractions,
        "per_request_fractions_mean": per_request_mean,
        "stages": stages,
        "hops": hops,
        "bottleneck": bottleneck,
    }


def predicted_times(control):
    """The plan's per-stage/per-hop service times for a control plane's
    current pipeline -- the same ``core.bottleneck.service_times`` call the
    engines bind their timing to.  Returns ``(compute_s, link_s)`` or
    ``None`` when the dispatcher has no probed view yet."""
    disp = control.dispatcher
    pipe = control.pipeline
    if disp.probed is None or control.desired is None or pipe is None:
        return None
    from repro.core.bottleneck import service_times

    graph = control.desired.graph
    return service_times(
        [p.partition for p in pipe.pods],
        [p.node_id for p in pipe.pods],
        disp.probed.bw,
        flops_per_node=[n.flops_per_s for n in control.cluster.nodes],
        in_bytes=graph.in_bytes,
        out_bytes=graph.layers[-1].out_bytes,
        dispatcher=disp.leader,
        compression_ratio=pipe.compression_ratio,
        codecs=pipe.link_codecs,
    )


def predicted_bottleneck(compute_s, link_s) -> dict:
    """The plan-side bottleneck resource, comparable to the observed one."""
    candidates = [("stage", i, t) for i, t in enumerate(compute_s)]
    candidates += [("link", h, t) for h, t in enumerate(link_s)
                   if math.isfinite(t)]
    kind, index, service = max(candidates, key=lambda c: c[2])
    return {"kind": kind, "index": index, "service_s": service}


def pin_service_times(analysis: dict, compute_s, link_s,
                      rel_tol: float = 0.05) -> dict:
    """Pin observed per-stage exec medians against the plan's compute
    times, and the observed bottleneck against the plan's.

    Returns a flat report with per-stage rows, the worst relative error,
    and ``within_tol`` / ``bottleneck_agrees`` verdicts.
    """
    rows = []
    worst = 0.0
    predicted = list(compute_s)
    for row in analysis["stages"]:
        s = row["stage"]
        if s >= len(predicted):
            continue
        pred = predicted[s]
        obs = row["median_s"]
        rel = abs(obs - pred) / pred if pred > 0 else abs(obs - pred)
        worst = max(worst, rel)
        rows.append({"stage": s, "observed_s": obs, "predicted_s": pred,
                     "rel_err": rel})
    plan_bn = predicted_bottleneck(compute_s, link_s)
    obs_bn = analysis["bottleneck"]
    agrees = (obs_bn is not None
              and obs_bn["kind"] == plan_bn["kind"]
              and obs_bn["index"] == plan_bn["index"])
    return {
        "stages": rows,
        "max_rel_err": worst,
        "rel_tol": rel_tol,
        "within_tol": bool(rows) and worst <= rel_tol,
        "observed_bottleneck": obs_bn,
        "predicted_bottleneck": plan_bn,
        "bottleneck_agrees": agrees,
    }
