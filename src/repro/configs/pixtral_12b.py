"""--arch config module (see archs.py for the definition)."""
from repro.configs.archs import PIXTRAL_12B as CONFIG

__all__ = ["CONFIG"]
