"""--arch config module (see archs.py for the definition)."""
from repro.configs.archs import WHISPER_SMALL as CONFIG

__all__ = ["CONFIG"]
