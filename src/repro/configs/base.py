"""Config system: architecture + shape + sharding descriptors.

Every assigned architecture is a ``ModelConfig`` in its own module under
``repro/configs``; ``repro.configs.registry`` maps ``--arch`` ids to them.
``reduced()`` derives the small smoke-test variant of any config.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "vlm", "audio", "ssm", "hybrid"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25

    # --- attention variants ---
    sliding_window: int = 0  # 0 = full attention
    local_global: bool = False  # gemma2: alternate local(sliding)/global
    attn_softcap: float = 0.0  # gemma2 logit soft-capping
    final_softcap: float = 0.0
    qkv_bias: bool = False  # qwen2
    rope_theta: float = 10_000.0
    pos_emb: Literal["rope", "learned"] = "rope"  # whisper: learned

    # --- TP ergonomics ---
    # pad Q heads up to this count (0 = off) so heads shard over the model
    # axis; padded heads have zero-initialized output projections (exact at
    # init).  SPerf iteration: qwen2's 28 heads on a 16-wide axis otherwise
    # replicate attention 16x and all-gather q every layer.
    pad_heads_to: int = 0

    # --- block internals ---
    mlp_kind: Literal["swiglu", "geglu", "gelu"] = "swiglu"
    norm_kind: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    gemma_norm: bool = False  # (1 + w) RMSNorm scaling + embed * sqrt(d)
    post_norm: bool = False  # gemma2 post-attn/post-ffn extra norms
    tie_embeddings: bool = True

    # --- ssm / hybrid / recurrent ---
    ssm_state: int = 0  # mamba2 state size per head
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    attn_every: int = 0  # zamba2: shared attn block every N mamba blocks
    slstm_every: int = 0  # xlstm: sLSTM block every N blocks (rest mLSTM)

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0  # 0 = decoder-only

    # --- modality frontend stub ---
    frontend: Literal["none", "patch", "frames"] = "none"

    # --- distribution defaults ---
    sharding: Literal["tp", "fsdp", "ep", "ep_fsdp", "fsdp_full"] = "tp"
    # optimizer-state dtype: fp32 default; bf16 for the 1T model (documented)
    opt_state_dtype: Literal["float32", "bfloat16"] = "float32"

    # sub-quadratic attention available? (long_500k eligibility)
    subquadratic: bool = False

    def __post_init__(self) -> None:
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_heads and self.n_kv_heads and self.n_heads % self.n_kv_heads:
            raise ValueError("n_heads must be a multiple of n_kv_heads")
        if self.n_experts and not self.experts_per_token:
            raise ValueError("MoE config needs experts_per_token")

    # ------------------------------------------------------------------
    @property
    def padded_heads(self) -> int:
        return max(self.n_heads, self.pad_heads_to)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks + head)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        per_layer = 0
        # attention (when present)
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        gated = self.mlp_kind in ("swiglu", "geglu")
        ffn_dense = d * f * (3 if gated else 2)
        if self.family == "ssm":
            d_in = d * self.ssm_expand
            per_layer = d * 2 * d_in + d_in * d + d_in * (2 * self.ssm_state)
        elif self.family == "hybrid":
            d_in = d * self.ssm_expand
            per_layer = d * 2 * d_in + d_in * d + d_in * (2 * self.ssm_state)
        elif self.is_moe:
            per_layer = attn + self.n_experts * d * f * 3 + d * self.n_experts
        else:
            per_layer = attn + ffn_dense
        total = self.n_layers * per_layer
        if self.family == "hybrid" and self.attn_every:
            total += attn + ffn_dense  # one shared attention+MLP block
        if self.encoder_layers:
            total += self.encoder_layers * (attn + ffn_dense) + self.n_layers * attn
        total += v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if not self.is_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense = self.param_count() - self.n_layers * self.n_experts * d * f * 3
        return dense + self.n_layers * self.experts_per_token * d * f * 3


def reduced(cfg: ModelConfig, *, layers: int = 2, d_model: int = 64,
            vocab: int = 256) -> ModelConfig:
    """Smoke-test variant: same family/block structure, tiny dims."""
    n_heads = max(2, min(cfg.n_heads, 4))
    ratio = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1))
    n_kv = max(1, n_heads // min(ratio, n_heads))
    changes = dict(
        n_layers=max(layers, 2 * cfg.attn_every or layers, 2 * cfg.slstm_every or layers),
        d_model=d_model,
        pad_heads_to=0,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=d_model // n_heads,
        d_ff=0 if cfg.d_ff == 0 else d_model * 4,
        vocab_size=vocab,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        experts_per_token=min(cfg.experts_per_token, 2) if cfg.n_experts else 0,
        sliding_window=min(cfg.sliding_window, 8) if cfg.sliding_window else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        encoder_layers=min(cfg.encoder_layers, 2) if cfg.encoder_layers else 0,
        attn_every=min(cfg.attn_every, 2) if cfg.attn_every else 0,
        slstm_every=min(cfg.slstm_every, 2) if cfg.slstm_every else 0,
    )
    return dataclasses.replace(cfg, **changes)


# ---------------------------------------------------------------------------
# Input shapes (assigned): every (arch x shape) cell is well-defined
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_cells(cfg: ModelConfig) -> list[str]:
    """Which shape cells run for this arch (long_500k: sub-quadratic only)."""
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        cells.append("long_500k")
    return cells
