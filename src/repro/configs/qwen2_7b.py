"""--arch config module (see archs.py for the definition)."""
from repro.configs.archs import QWEN2_7B as CONFIG

__all__ = ["CONFIG"]
