"""--arch config module (see archs.py for the definition)."""
from repro.configs.archs import XLSTM_125M as CONFIG

__all__ = ["CONFIG"]
