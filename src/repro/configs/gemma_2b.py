"""--arch config module (see archs.py for the definition)."""
from repro.configs.archs import GEMMA_2B as CONFIG

__all__ = ["CONFIG"]
