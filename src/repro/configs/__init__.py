from repro.configs.archs import ALIASES, ARCHS, get_config
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, reduced, shape_cells

__all__ = [
    "ALIASES",
    "ARCHS",
    "get_config",
    "SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "reduced",
    "shape_cells",
]
