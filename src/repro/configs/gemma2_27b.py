"""--arch config module (see archs.py for the definition)."""
from repro.configs.archs import GEMMA2_27B as CONFIG

__all__ = ["CONFIG"]
