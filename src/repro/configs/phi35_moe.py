"""--arch config module (see archs.py for the definition)."""
from repro.configs.archs import PHI35_MOE as CONFIG

__all__ = ["CONFIG"]
