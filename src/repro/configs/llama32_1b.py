"""--arch config module (see archs.py for the definition)."""
from repro.configs.archs import LLAMA32_1B as CONFIG

__all__ = ["CONFIG"]
