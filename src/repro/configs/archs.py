"""The 10 assigned architectures, exactly as specified in the assignment.

Each config is selectable via ``--arch <id>``; ``registry()`` returns the id
-> ModelConfig map.  Sources are noted per config ([hf]/[arXiv] per the
assignment brackets).
"""

from __future__ import annotations

from repro.configs.base import ModelConfig

# --- [vlm] pixtral-ViT + mistral-nemo backbone -----------------------------
# hf:mistralai/Pixtral-12B-2409 (backbone only; patch frontend is a stub)
PIXTRAL_12B = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1_000_000.0,
    frontend="patch",
    sharding="fsdp",
)

# --- [moe] microsoft/Phi-3.5-MoE-instruct: 16 experts, top-2 ---------------
PHI35_MOE = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    n_experts=16,
    experts_per_token=2,
    rope_theta=10_000.0,
    sharding="ep_fsdp",
)

# --- [moe] Kimi K2: trillion-param MoE, 384 experts top-8 (paper-table) ----
KIMI_K2 = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=163840,
    n_experts=384,
    experts_per_token=8,
    rope_theta=50_000.0,
    sharding="fsdp_full",
    opt_state_dtype="bfloat16",  # 1T params: fp32 m,v would not fit 512x16GB
)

# --- [dense] gemma-2b: GeGLU, head_dim=256, MQA (kv=1) [arXiv:2403.08295] --
GEMMA_2B = ModelConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16384,
    vocab_size=256000,
    head_dim=256,
    mlp_kind="geglu",
    gemma_norm=True,
    sharding="tp",
)

# --- [dense] llama3.2-1b [hf:meta-llama/Llama-3.2-1B] ----------------------
LLAMA32_1B = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    head_dim=64,
    rope_theta=500_000.0,
    sharding="tp",
)

# --- [dense] qwen2-7b: GQA + QKV bias [arXiv:2407.10671] -------------------
QWEN2_7B = ModelConfig(
    name="qwen2-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    sharding="tp",
    pad_heads_to=32,  # 28 Q heads don't divide the 16-wide model axis
)

# --- [dense] gemma2-27b: local+global alternating, softcaps [2408.00118] ---
GEMMA2_27B = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_ff=36864,
    vocab_size=256000,
    head_dim=128,
    mlp_kind="geglu",
    gemma_norm=True,
    post_norm=True,
    local_global=True,
    sliding_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    sharding="fsdp",
)

# --- [audio] whisper-small: enc-dec, conv frontend stubbed [2212.04356] ----
WHISPER_SMALL = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,  # decoder layers
    encoder_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    mlp_kind="gelu",
    norm_kind="layernorm",
    pos_emb="learned",
    frontend="frames",
    sharding="tp",
)

# --- [ssm] xLSTM-125m: sLSTM + mLSTM blocks [arXiv:2405.04517] -------------
XLSTM_125M = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,  # mLSTM/sLSTM blocks have internal up/down projections
    vocab_size=50304,
    slstm_every=4,  # blocks 0,4,8 are sLSTM; rest mLSTM (7:1-ish mix)
    ssm_expand=2,
    sharding="tp",
    subquadratic=True,  # recurrent state, O(1) per decoded token
)

# --- [hybrid] zamba2-2.7b: Mamba2 + shared attn [arXiv:2411.15242] ---------
ZAMBA2_27B = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,  # shared attention block's MLP
    vocab_size=32000,
    head_dim=80,
    ssm_state=64,
    attn_every=6,  # shared attention block applied every 6 mamba blocks
    sharding="tp",
    subquadratic=True,
)

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in (
        PIXTRAL_12B,
        PHI35_MOE,
        KIMI_K2,
        GEMMA_2B,
        LLAMA32_1B,
        QWEN2_7B,
        GEMMA2_27B,
        WHISPER_SMALL,
        XLSTM_125M,
        ZAMBA2_27B,
    )
}
# short aliases for --arch
ALIASES = {
    "pixtral-12b": "pixtral-12b",
    "phi3.5-moe": "phi3.5-moe-42b-a6.6b",
    "kimi-k2": "kimi-k2-1t-a32b",
    "gemma-2b": "gemma-2b",
    "llama3.2-1b": "llama3.2-1b",
    "qwen2-7b": "qwen2-7b",
    "gemma2-27b": "gemma2-27b",
    "whisper-small": "whisper-small",
    "xlstm-125m": "xlstm-125m",
    "zamba2-2.7b": "zamba2-2.7b",
}


def get_config(name: str) -> ModelConfig:
    key = ALIASES.get(name, name)
    if key not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[key]
