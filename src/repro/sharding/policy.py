"""PartitionSpec policies: TP / FSDP / EP over the production mesh.

Rules are path+shape based so they apply uniformly to the scan-stacked
parameter pytrees (leading group axes are padded with ``None``).  Every rule
checks divisibility against the actual mesh axis size and falls back to
replication, so the same policy lowers on the (16, 16) pod mesh, the
(2, 16, 16) multi-pod mesh, and the 1-device test mesh.

Axis convention:
  * "data"  -- batch / fsdp axis,
  * "model" -- tensor/expert-parallel axis,
  * "pod"   -- data-parallel across pods (DCN); also a storage axis for the
    1T MoE (fsdp_full shards expert d_ff over it).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    """Axes the global batch is sharded over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def batch_pspec(mesh: Mesh, batch: int) -> P:
    """Shard the batch dim over as many batch axes as divide it."""
    axes = []
    div = 1
    for a in batch_axes(mesh):
        n = _axis(mesh, a)
        if batch % (div * n) == 0:
            axes.append(a)
            div *= n
    return P(tuple(axes) if axes else None)


# ---------------------------------------------------------------------------
# Parameter shardings
# ---------------------------------------------------------------------------

def _div(n: int, mesh: Mesh, axis: str | None):
    """axis if it divides n, else None (replicate)."""
    if axis is None or n % _axis(mesh, axis) != 0:
        return None
    return axis


@dataclasses.dataclass(frozen=True)
class _Rules:
    mesh: Mesh
    fsdp: str | None  # "data" or None
    pod: str | None  # "pod" for fsdp_full on the multi-pod mesh

    def spec(self, cfg, path: str, shape: tuple[int, ...]) -> P:
        """Trailing-dims PartitionSpec, padded for leading stack dims."""
        name = path.split("/")[-1]
        m = self.mesh
        base = self._base(cfg, path, name, shape)
        pad = len(shape) - len(base)
        if pad < 0:  # scalar-ish leaf matched a bigger rule; replicate
            return P()
        return P(*([None] * pad + list(base)))

    # -- the rule table ----------------------------------------------------
    def _base(self, cfg, path: str, name: str, shape) -> list:
        m, f, pod = self.mesh, self.fsdp, self.pod
        moe = cfg.is_moe and "mlp" in path
        if name == "embed":
            return [_div(shape[-2], m, "model"), _div(shape[-1], m, f)]
        if name == "lm_head":
            return [_div(shape[-2], m, f), _div(shape[-1], m, "model")]
        if name == "patch_proj":
            return [None, _div(shape[-1], m, "model")]
        if name in ("pos", "dec_pos"):
            return [None, None]
        attn_proj = "attn/" in path or "cross/" in path or "shared_attn/" in path
        if name in ("wq", "wk", "wv") and attn_proj:
            d, h, hd = shape[-3], shape[-2], shape[-1]
            if _div(h, m, "model"):
                return [_div(d, m, f), "model", None]
            return [_div(d, m, f), None, _div(hd, m, "model")]
        if name == "wo":
            h, hd, d = shape[-3], shape[-2], shape[-1]
            if _div(h, m, "model"):
                return ["model", None, _div(d, m, f)]
            return [None, _div(hd, m, "model"), _div(d, m, f)]
        if name in ("bq", "bk", "bv"):
            h, hd = shape[-2], shape[-1]
            if _div(h, m, "model"):
                return ["model", None]
            return [None, _div(hd, m, "model")]
        if name == "router":
            return [None, None]
        if moe and name in ("w_gate", "w_up"):
            e, d, ff = shape[-3], shape[-2], shape[-1]
            return [_div(e, m, "model"), _div(d, m, f), _div(ff, m, pod)]
        if moe and name == "w_down":
            e, ff, d = shape[-3], shape[-2], shape[-1]
            return [_div(e, m, "model"), _div(ff, m, pod), _div(d, m, f)]
        if name in ("w_gate", "w_up"):  # dense gated MLP (d, ff)
            return [_div(shape[-2], m, f), _div(shape[-1], m, "model")]
        if name == "w_down":
            return [_div(shape[-2], m, "model"), _div(shape[-1], m, f)]
        if name == "in_proj":  # mamba (d, 2*d_in + 2N + H)
            return [_div(shape[-2], m, f), _div(shape[-1], m, "model")]
        if name == "conv_w":
            return [None, _div(shape[-1], m, "model")]
        if name == "conv_b":
            return [_div(shape[-1], m, "model")]
        if name == "norm_w":
            return [_div(shape[-1], m, "model")]
        if name == "out_proj":  # (d_in, d)
            return [_div(shape[-2], m, "model"), _div(shape[-1], m, f)]
        if name in ("wq", "wk", "wv", "w_ogate", "w_in"):  # mlstm/slstm (d, X)
            return [_div(shape[-2], m, f), _div(shape[-1], m, "model")]
        return [None] * min(len(shape), 1)  # norms, biases, gates: replicate


def _path_str(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _mk_rules(cfg, mesh: Mesh) -> _Rules:
    fsdp = "data" if cfg.sharding in ("fsdp", "ep_fsdp", "fsdp_full") else None
    pod = "pod" if (cfg.sharding == "fsdp_full" and "pod" in mesh.axis_names) else None
    return _Rules(mesh=mesh, fsdp=fsdp, pod=pod)


def param_shardings(cfg, mesh: Mesh, params: Any) -> Any:
    """NamedSharding tree matching ``params`` (works on ShapeDtypeStructs)."""
    rules = _mk_rules(cfg, mesh)

    def leaf(path, x):
        return NamedSharding(mesh, rules.spec(cfg, _path_str(path), x.shape))

    return jax.tree_util.tree_map_with_path(leaf, params)


def state_shardings(cfg, mesh: Mesh, state: Any) -> Any:
    """Train-state shardings: m/v follow params; step replicated."""
    p = param_shardings(cfg, mesh, state["params"])
    return {
        "params": p,
        "m": p,
        "v": p,
        "step": NamedSharding(mesh, P()),
    }


# ---------------------------------------------------------------------------
# Cache shardings (decode)
# ---------------------------------------------------------------------------

def cache_shardings(cfg, mesh: Mesh, caches: Any, batch: int | None = None) -> Any:
    """KV caches: batch over data axes, heads (or head_dim) over model."""
    baxes = batch_pspec(mesh, batch if batch is not None else _first_batch_dim(caches))

    def leaf(path, x):
        p = _path_str(path)
        name = p.split("/")[-1]
        if name == "pos" or x.ndim == 0:
            return NamedSharding(mesh, P())
        spec = _cache_spec(cfg, mesh, p, x.shape, baxes)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(leaf, caches)


def _first_batch_dim(caches) -> int:
    # blocks/*/kv/k has shape (G, B, S, KH, hd); pos is scalar
    for path, leaf in jax.tree_util.tree_leaves_with_path(caches):
        if leaf.ndim >= 2:
            return leaf.shape[1]
    return 1


def _cache_spec(cfg, mesh: Mesh, path: str, shape, bspec: P) -> P:
    name = path.split("/")[-1]
    b = bspec[0] if len(bspec) else None
    if name in ("k", "v"):  # (G, B, S, KH, hd)
        pad = len(shape) - 4
        s, kh, hd = shape[-3], shape[-2], shape[-1]
        # B=1 (long-context decode) leaves the data axis idle: split-KV over it
        seq_ax = None if b is not None else _div(s, mesh, "data")
        if _div(kh, mesh, "model"):
            return P(*([None] * pad), b, seq_ax, "model", None)
        if seq_ax and s % (_axis(mesh, "data") * _axis(mesh, "model")) == 0:
            return P(*([None] * pad), b, ("data", "model"), None, None)
        if _div(s, mesh, "model"):
            # KV heads don't divide the model axis: shard the SEQUENCE dim
            # instead (FlashDecoding-style split-KV).  Head-dim sharding is
            # strictly worse: it partial-sums f32 logits every layer (SPerf
            # llama decode iteration).
            return P(*([None] * pad), b, "model", None, None)
        return P(*([None] * pad), b, None, None, _div(hd, mesh, "model"))
    if name == "ssm":  # (G, per, B, H, dh, N)
        pad = len(shape) - 4
        return P(*([None] * pad), b, _div(shape[-3], mesh, "model"), None, None)
    if name == "conv":  # (G, per, B, K-1, conv_dim)
        pad = len(shape) - 3
        return P(*([None] * pad), b, None, _div(shape[-1], mesh, "model"))
    if name == "c":  # mlstm (G, per, B, H, dh+1, dh)
        pad = len(shape) - 4
        return P(*([None] * pad), b, None, None, _div(shape[-1], mesh, "model"))
    if "slstm" in path:  # tuple state leaves (G, B, H, dh)
        pad = len(shape) - 3
        return P(*([None] * pad), b, None, _div(shape[-1], mesh, "model"))
    return P()


# ---------------------------------------------------------------------------
# Activation policy hook
# ---------------------------------------------------------------------------

class ShardingPolicy:
    """Injected into the model; constrains key activations on the mesh."""

    def __init__(self, cfg, mesh: Mesh):
        self.cfg = cfg
        self.mesh = mesh
        self.baxes = batch_axes(mesh)

    def _b(self, n: int):
        axes, div = [], 1
        for a in self.baxes:
            sz = _axis(self.mesh, a)
            if n % (div * sz) == 0:
                axes.append(a)
                div *= sz
        return tuple(axes) if axes else None

    def act(self, x: jax.Array, kind: str) -> jax.Array:
        m = self.mesh
        if kind in ("attn_q", "attn_kv"):  # (B, S, H, hd)
            h = x.shape[2]
            spec = (
                P(self._b(x.shape[0]), None, "model", None)
                if h % _axis(m, "model") == 0
                else P(self._b(x.shape[0]), None, None, None)
            )
        elif kind in ("mlp_out", "final_hidden"):  # (B, S, d)
            spec = P(self._b(x.shape[0]), None, None)
        elif kind == "logits":  # (B, C, V)
            spec = P(self._b(x.shape[0]), None, "model")
        else:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(m, spec))
