from repro.sharding.policy import (
    ShardingPolicy,
    batch_axes,
    batch_pspec,
    cache_shardings,
    param_shardings,
    state_shardings,
)

__all__ = [
    "ShardingPolicy",
    "batch_axes",
    "batch_pspec",
    "cache_shardings",
    "param_shardings",
    "state_shardings",
]
