"""xLSTM blocks: chunked-parallel mLSTM and sequential sLSTM.

mLSTM (matrix memory, exponential gating) is a gated linear recurrence; we
run it with the same chunked state-passing scheme as the Mamba2 SSD kernel
(quadratic within a chunk, (dh_v+1, dh_k) state across chunks -- the +1 row
carries the normalizer).  sLSTM (scalar memory, per-head recurrent weights)
is inherently sequential and scans over time.  All gate math fp32 with the
max-stabilizer from the paper.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init

MLSTM_CHUNK = 256
GATE_CLIP = 15.0  # clip exp-gate preactivations


def mlstm_dims(cfg) -> tuple[int, int]:
    d_in = cfg.ssm_expand * cfg.d_model
    return d_in, d_in // cfg.n_heads  # (d_inner, head_dim)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(cfg, key: jax.Array) -> dict:
    d = cfg.d_model
    d_in, _ = mlstm_dims(cfg)
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], (d, d_in)),
        "wk": dense_init(ks[1], (d, d_in)),
        "wv": dense_init(ks[2], (d, d_in)),
        "w_gates": dense_init(ks[3], (d, 2 * cfg.n_heads), dtype=jnp.float32),
        "b_gates": jnp.concatenate(
            [jnp.zeros((cfg.n_heads,)), jnp.full((cfg.n_heads,), 3.0)]
        ),  # forget-gate bias ~ sigmoid(3) = 0.95
        "w_ogate": dense_init(ks[4], (d, d_in)),
        "out_proj": dense_init(ks[5], (d_in, d), scale=d_in**-0.5),
    }


def _mlstm_qkvg(cfg, p: dict, x: jax.Array):
    b, s, _ = x.shape
    h = cfg.n_heads
    d_in, dh = mlstm_dims(cfg)
    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(b, s, h, dh)
    k = jnp.einsum("bsd,de->bse", x, p["wk"]).reshape(b, s, h, dh)
    v = jnp.einsum("bsd,de->bse", x, p["wv"]).reshape(b, s, h, dh)
    gates = jnp.einsum("bsd,dg->bsg", x.astype(jnp.float32), p["w_gates"]) + p["b_gates"]
    log_i = jnp.minimum(gates[..., :h], GATE_CLIP)  # exp input gate, clipped
    log_f = jax.nn.log_sigmoid(gates[..., h:])  # (B,S,H)
    ogate = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["w_ogate"].astype(jnp.float32)))
    return q, k, v, log_i, log_f, ogate


def _mlstm_out(cfg, p: dict, y: jax.Array, ogate: jax.Array, shape) -> jax.Array:
    b, s = shape
    d_in, dh = mlstm_dims(cfg)
    num, den = y[..., :dh], y[..., dh]
    hout = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    hout = hout.reshape(b, s, d_in) * ogate
    return jnp.einsum("bse,ed->bsd", hout.astype(jnp.bfloat16), p["out_proj"])


def mlstm_forward(cfg, p: dict, x: jax.Array, *, chunk: int = MLSTM_CHUNK) -> jax.Array:
    """Full-sequence mLSTM.  x: (B, S, d) -> (B, S, d)."""
    b, s, _ = x.shape
    hh = cfg.n_heads
    d_in, dh = mlstm_dims(cfg)
    q_sz = min(chunk, s)
    if s % q_sz:
        raise ValueError(f"seq {s} must divide chunk {q_sz}")
    nc = s // q_sz
    q, k, v, log_i, log_f, ogate = _mlstm_qkvg(cfg, p, x)
    scale = dh**-0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = jnp.concatenate(  # augment with normalizer row
        [v.astype(jnp.float32), jnp.ones((b, s, hh, 1), jnp.float32)], axis=-1
    )

    def to_chunks(t):
        return jnp.moveaxis(t.reshape((b, nc, q_sz) + t.shape[2:]), 1, 0)

    qc, kc, vc, lic, lfc = map(to_chunks, (qf, kf, vf, log_i, log_f))
    cumf = jnp.cumsum(lfc, axis=2)  # (nc,B,Q,H)

    def chunk_step(cstate, inp):
        qk, kk, vk, lik, cumk = inp
        ldiff = cumk[:, :, None, :] - cumk[:, None, :, :] + lik[:, None, :, :]
        mask = jnp.tril(jnp.ones((q_sz, q_sz), bool))
        lmat = jnp.where(mask[None, :, :, None], jnp.exp(ldiff), 0.0)  # (B,Q,S,H)
        gqk = jnp.einsum("bthn,bshn->btsh", qk, kk)  # (B,Q,S,H)
        y_intra = jnp.einsum("btsh,bshd->bthd", gqk * lmat, vk)
        decay_in = jnp.exp(cumk)  # (B,Q,H)
        y_inter = jnp.einsum("bthn,bhdn->bthd", qk, cstate) * decay_in[..., None]
        decay_out = jnp.exp(cumk[:, -1:, :] - cumk + lik)  # (B,Q,H)
        contrib = jnp.einsum("bsh,bshn,bshd->bhdn", decay_out, kk, vk)
        c_new = cstate * jnp.exp(cumk[:, -1])[:, :, None, None] + contrib
        return c_new, y_intra + y_inter

    c0 = jnp.zeros((b, hh, dh + 1, dh), jnp.float32)
    _, y = jax.lax.scan(chunk_step, c0, (qc, kc, vc, lic, cumf))
    y = jnp.moveaxis(y, 0, 1).reshape(b, s, hh, dh + 1)
    return _mlstm_out(cfg, p, y, ogate, (b, s))


def mlstm_init_cache(cfg, batch: int) -> dict:
    hh = cfg.n_heads
    _, dh = mlstm_dims(cfg)
    return {"c": jnp.zeros((batch, hh, dh + 1, dh), jnp.float32)}


def mlstm_step(cfg, p: dict, cache: dict, x: jax.Array) -> tuple[dict, jax.Array]:
    """Single decode step.  x: (B, 1, d)."""
    b = x.shape[0]
    hh = cfg.n_heads
    _, dh = mlstm_dims(cfg)
    q, k, v, log_i, log_f, ogate = _mlstm_qkvg(cfg, p, x)
    qf = q[:, 0].astype(jnp.float32) * dh**-0.5  # (B,H,dh)
    kf = k[:, 0].astype(jnp.float32)
    vf = jnp.concatenate(
        [v[:, 0].astype(jnp.float32), jnp.ones((b, hh, 1), jnp.float32)], axis=-1
    )
    f1 = jnp.exp(log_f[:, 0])  # (B,H)
    i1 = jnp.exp(log_i[:, 0])
    c_new = cache["c"] * f1[:, :, None, None] + i1[:, :, None, None] * (
        vf[:, :, :, None] * kf[:, :, None, :]
    )
    y = jnp.einsum("bhn,bhdn->bhd", qf, c_new)[:, None]  # (B,1,H,dh+1)
    return {"c": c_new}, _mlstm_out(cfg, p, y, ogate, (b, 1))


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(cfg, key: jax.Array) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 4)
    return {
        "w_in": dense_init(ks[0], (d, 4 * d), dtype=jnp.float32),
        "r": dense_init(ks[1], (h, dh, 4 * dh), dtype=jnp.float32, scale=dh**-0.5),
        "b": jnp.concatenate(
            [jnp.zeros((2 * d,)), jnp.full((d,), 3.0), jnp.zeros((d,))]
        ),  # z, i, f(+3), o
        "w_up": dense_init(ks[2], (d, 2 * d)),
        "w_down": dense_init(ks[3], (d, d), scale=d**-0.5),
    }


def _slstm_cell(cfg, p: dict, state, x_t: jax.Array):
    """One sLSTM step.  x_t: (B, d) fp32-projected gates; state: c,n,h,m (B,H,dh)."""
    b = x_t.shape[0]
    h, d = cfg.n_heads, cfg.d_model
    dh = d // h
    c, n, hid, m = state
    rec = jnp.einsum("bhd,hde->bhe", hid, p["r"])  # (B,H,4dh)
    gates = (
        jnp.einsum("bd,dg->bg", x_t, p["w_in"]).reshape(b, h, 4 * dh)
        + rec
        + p["b"].reshape(1, 4, h, dh).transpose(0, 2, 1, 3).reshape(1, h, 4 * dh)
    )
    z_r, i_r, f_r, o_r = jnp.split(gates, 4, axis=-1)  # (B,H,dh) each
    log_f = jax.nn.log_sigmoid(f_r)
    i_r = jnp.minimum(i_r, GATE_CLIP)
    m_new = jnp.maximum(log_f + m, i_r)
    i_g = jnp.exp(i_r - m_new)
    f_g = jnp.exp(log_f + m - m_new)
    c_new = f_g * c + i_g * jnp.tanh(z_r)
    n_new = f_g * n + i_g
    h_new = jax.nn.sigmoid(o_r) * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_init_state(cfg, batch: int):
    h, dh = cfg.n_heads, cfg.d_model // cfg.n_heads
    zeros = jnp.zeros((batch, h, dh), jnp.float32)
    return (zeros, zeros, zeros, zeros)


def slstm_forward(cfg, p: dict, x: jax.Array) -> jax.Array:
    """Sequential sLSTM + gated MLP.  x: (B, S, d) -> (B, S, d)."""
    b, s, d = x.shape
    xf = x.astype(jnp.float32)

    def step(state, x_t):
        return _slstm_cell(cfg, p, state, x_t)

    _, hs = jax.lax.scan(step, slstm_init_state(cfg, b), jnp.moveaxis(xf, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
    up = jnp.einsum("bsd,de->bse", y, p["w_up"])
    g, u = jnp.split(up, 2, axis=-1)
    return jnp.einsum("bse,ed->bsd", jax.nn.gelu(g, approximate=True) * u, p["w_down"])


def slstm_step(cfg, p: dict, state, x: jax.Array):
    """Single decode step.  x: (B, 1, d)."""
    state, h_new = _slstm_cell(cfg, p, state, x[:, 0].astype(jnp.float32))
    b = x.shape[0]
    y = h_new.reshape(b, 1, cfg.d_model).astype(x.dtype)
    up = jnp.einsum("bsd,de->bse", y, p["w_up"])
    g, u = jnp.split(up, 2, axis=-1)
    out = jnp.einsum("bse,ed->bsd", jax.nn.gelu(g, approximate=True) * u, p["w_down"])
    return state, out
