"""Mixture-of-Experts FFN: top-k routing, capacity-based GShard dispatch.

The dispatch/combine einsum formulation lowers cleanly under GSPMD: expert
weights are sharded over the "model" axis (expert parallelism), tokens over
"data"; the combine contraction over the expert axis produces the EP
all-reduce.  Dispatch-tensor memory is bounded by the ``group_size`` knob
(tokens are routed within groups): dispatch is (G, Sg, E, C) with
C = ceil(Sg * top_k * capacity_factor / E), so bytes scale with Sg, not S.

Tokens beyond expert capacity are dropped (classic Switch/GShard semantics);
the auxiliary load-balancing loss keeps drop rates low in training.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init

DEFAULT_GROUP = 512


def init_moe(cfg, key: jax.Array) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, e), dtype=jnp.float32, scale=d**-0.5),
        "w_gate": dense_init(ks[1], (e, d, f)),
        "w_up": dense_init(ks[2], (e, d, f)),
        "w_down": dense_init(ks[3], (e, f, d), scale=f**-0.5),
    }


def _capacity(group: int, top_k: int, n_experts: int, cf: float) -> int:
    c = int(-(-group * top_k * cf // n_experts))  # ceil
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


def route(cfg, p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Router probabilities and top-k selection.  x: (..., d) bf16.

    Returns (probs (..., E) f32, top_p (..., k) f32, top_e (..., k) i32).
    Top-k probabilities are renormalized (Mixtral-style).
    """
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, cfg.experts_per_token)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    return probs, top_p, top_e


def moe_mlp(
    cfg, p: dict, x: jax.Array, *, group_size: int = DEFAULT_GROUP
) -> tuple[jax.Array, jax.Array]:
    """Top-k MoE FFN.  x: (B, S, d).  Returns (y, aux_loss)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    t = b * s
    g_sz = min(group_size, t)
    if t % g_sz:
        g_sz = t  # fall back to one group (smoke-test sizes)
    g = t // g_sz
    xg = x.reshape(g, g_sz, d)

    probs, top_p, top_e = route(cfg, p, xg)  # (G,Sg,E) (G,Sg,k) (G,Sg,k)
    cap = _capacity(g_sz, k, e, cfg.moe_capacity_factor)

    # --- position of each (token, slot) within its expert's capacity ------
    onehot_e = jax.nn.one_hot(top_e, e, dtype=jnp.float32)  # (G,Sg,k,E)
    flat = onehot_e.reshape(g, g_sz * k, e)
    pos_flat = jnp.cumsum(flat, axis=1) - flat  # (G,Sg*k,E)
    pos = (pos_flat.reshape(g, g_sz, k, e) * onehot_e).sum(-1)  # (G,Sg,k)
    keep = (pos < cap).astype(jnp.float32)

    onehot_c = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)
    # dispatch (G,Sg,E,C): 1 where token s goes to slot c of expert e
    dispatch = jnp.einsum("gske,gskc,gsk->gsec", onehot_e, onehot_c, keep)
    combine = jnp.einsum("gske,gskc,gsk->gsec", onehot_e, onehot_c, keep * top_p)

    # --- expert compute -----------------------------------------------------
    xin = jnp.einsum("gsec,gsd->gecd", dispatch.astype(x.dtype), x.reshape(g, g_sz, d))
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xin, p["w_gate"])) * jnp.einsum(
        "gecd,edf->gecf", xin, p["w_up"]
    )
    out = jnp.einsum("gecf,efd->gecd", h.astype(x.dtype), p["w_down"])
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(jnp.float32), out.astype(jnp.float32))

    # --- load-balancing auxiliary loss (Switch Eq. 4) ------------------------
    frac_tokens = onehot_e.mean(axis=(1, 2))  # (G,E) fraction routed
    frac_probs = probs.mean(axis=1)  # (G,E)
    aux = e * jnp.mean(jnp.sum(frac_tokens * frac_probs, axis=-1))
    return y.reshape(b, s, d).astype(x.dtype), aux
