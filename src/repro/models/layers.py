"""Transformer building blocks: norms, rotary, MLPs, GQA attention.

Attention comes in three execution strategies:
  * ``attention_full``     -- materializes (.., Sq, Skv) logits; used for
    short sequences and smoke tests.
  * ``attention_chunked``  -- flash-style pair-block streaming (exact FLOPs
    for causal/windowed masks: only valid (q-chunk, kv-chunk) pairs are
    computed); used for long prefill/train.  The Pallas kernel in
    ``kernels/flash_attention`` is the TPU-optimized version of this.
  * ``attention_decode``   -- one-token query against a KV cache.

All softmax math is fp32; params/activations are bf16.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense_init, matmul

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array, *, gemma: bool = False, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    scale = (1.0 + w.astype(jnp.float32)) if gemma else w.astype(jnp.float32)
    return (y * scale).astype(x.dtype)


def layernorm(x: jax.Array, w: jax.Array, b: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def apply_norm(cfg, x: jax.Array, p: dict) -> jax.Array:
    if cfg.norm_kind == "layernorm":
        return layernorm(x, p["w"], p["b"])
    return rmsnorm(x, p["w"], gemma=cfg.gemma_norm)


def init_norm(cfg, d: int) -> dict:
    if cfg.norm_kind == "layernorm":
        return {"w": jnp.ones((d,), jnp.bfloat16), "b": jnp.zeros((d,), jnp.bfloat16)}
    return {"w": jnp.zeros((d,), jnp.bfloat16) if cfg.gemma_norm else jnp.ones((d,), jnp.bfloat16)}


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    sin = jnp.sin(angles)[..., None, :]  # broadcast over heads
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(cfg, key: jax.Array) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_kind in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(ks[0], (d, f)),
            "w_up": dense_init(ks[1], (d, f)),
            "w_down": dense_init(ks[2], (f, d)),
        }
    return {"w_up": dense_init(ks[0], (d, f)), "w_down": dense_init(ks[1], (f, d))}


def mlp(cfg, p: dict, x: jax.Array) -> jax.Array:
    if cfg.mlp_kind == "swiglu":
        h = jax.nn.silu(matmul(x, p["w_gate"])) * matmul(x, p["w_up"])
    elif cfg.mlp_kind == "geglu":
        h = jax.nn.gelu(matmul(x, p["w_gate"]), approximate=True) * matmul(x, p["w_up"])
    else:
        h = jax.nn.gelu(matmul(x, p["w_up"]), approximate=True)
    from repro.models.common import matmul_reduced

    return matmul_reduced(h, p["w_down"])  # d_ff is TP-contracted


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnSpec:
    causal: bool = True
    window: int = 0  # 0 = unlimited
    softcap: float = 0.0
    chunk_q: int = 1024
    chunk_kv: int = 1024


def init_attention(cfg, key: jax.Array) -> dict:
    d = cfg.d_model
    hq = cfg.padded_heads  # padded heads: zero wo slice -> exact at init
    ks = jax.random.split(key, 4)
    wo = dense_init(ks[3], (hq, cfg.head_dim, d), scale=(cfg.n_heads * cfg.head_dim) ** -0.5)
    if hq > cfg.n_heads:
        wo = wo.at[cfg.n_heads :].set(0)
    p = {
        "wq": dense_init(ks[0], (d, hq, cfg.head_dim)),
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads, cfg.head_dim)),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads, cfg.head_dim)),
        "wo": wo,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq, cfg.head_dim), jnp.bfloat16)
        p["bk"] = jnp.zeros((cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16)
        p["bv"] = jnp.zeros((cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16)
    return p


def qkv_proj(cfg, p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    q = jnp.einsum("...d,dhk->...hk", x, p["wq"]).astype(x.dtype)
    k = jnp.einsum("...d,dhk->...hk", x, p["wk"]).astype(x.dtype)
    v = jnp.einsum("...d,dhk->...hk", x, p["wv"]).astype(x.dtype)
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return q, k, v


def out_proj(p: dict, o: jax.Array) -> jax.Array:
    # bf16 dot output: the heads dim is TP-contracted, so the partial-sum
    # all-reduce this feeds moves bf16, not f32 (see common.matmul_reduced)
    return jax.lax.dot_general(
        o, p["wo"], (((o.ndim - 2, o.ndim - 1), (0, 1)), ((), ())),
    ).astype(o.dtype)


def _softcap(logits: jax.Array, cap: float) -> jax.Array:
    return cap * jnp.tanh(logits / cap) if cap > 0 else logits


def _mask_bias(qpos: jax.Array, kpos: jax.Array, spec: AttnSpec) -> jax.Array:
    """(Sq, Skv) additive bias in f32: 0 allowed / -inf masked."""
    ok = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if spec.causal:
        ok &= qpos[:, None] >= kpos[None, :]
    if spec.window > 0:
        ok &= qpos[:, None] - kpos[None, :] < spec.window
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


def _gqa_split(q: jax.Array, n_kv: int) -> jax.Array:
    """(B, S, H, hd) -> (B, S, KH, G, hd)."""
    b, s, h, hd = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, hd)


def attention_full(
    q: jax.Array, k: jax.Array, v: jax.Array, spec: AttnSpec,
    q_offset: jax.Array | int = 0,
) -> jax.Array:
    """q: (B, Sq, H, hd); k, v: (B, Skv, KH, hd).  Returns (B, Sq, H, hd)."""
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    qg = _gqa_split(q, kh)
    scale = hd**-0.5
    logits = jnp.einsum("bqhgk,bshk->bhgqs", qg.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    logits = _softcap(logits, spec.softcap)
    qpos = q_offset + jnp.arange(sq)
    kpos = jnp.arange(k.shape[1])
    logits = logits + _mask_bias(qpos, kpos, spec)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgqs,bshk->bqhgk", w, v.astype(jnp.float32))
    return o.reshape(b, sq, h, hd).astype(q.dtype)


def _pair_blocks(nq: int, nkv: int, spec: AttnSpec, chunk: int) -> tuple[np.ndarray, np.ndarray]:
    """Valid (q-chunk, kv-chunk) pairs for the mask — exact FLOPs, no dead blocks."""
    pairs = []
    for i in range(nq):
        q_lo, q_hi = i * chunk, (i + 1) * chunk - 1
        for j in range(nkv):
            k_lo = j * chunk
            if spec.causal and k_lo > q_hi:
                continue  # entirely above the diagonal
            if spec.window > 0 and (q_lo - ((j + 1) * chunk - 1)) >= spec.window:
                continue  # entirely outside the sliding window
            pairs.append((i, j))
    idx = np.asarray(pairs, dtype=np.int32)
    return idx[:, 0], idx[:, 1]


def attention_chunked(
    q: jax.Array, k: jax.Array, v: jax.Array, spec: AttnSpec,
) -> jax.Array:
    """Flash-style streaming attention (exact): scan over valid pair-blocks.

    Online-softmax carry (m, l, acc) is kept per q-chunk; pair-blocks are
    visited grouped by q-chunk so each chunk's carry is finalized in order.
    FLOPs match the true masked attention (no wasted blocks), which keeps
    the roofline accounting honest.
    """
    b, sq, h, hd = q.shape
    skv, kh = k.shape[1], k.shape[2]
    c = min(spec.chunk_q, sq, skv)
    if sq % c or skv % c:
        raise ValueError(f"seq lens ({sq},{skv}) must divide chunk {c}")
    nq, nkv = sq // c, skv // c
    qi, kj = _pair_blocks(nq, nkv, spec, c)
    g = h // kh
    scale = hd**-0.5
    qg = _gqa_split(q, kh)  # (B, S, KH, G, hd)

    m0 = jnp.full((b, kh, g, nq, c), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kh, g, nq, c), jnp.float32)
    a0 = jnp.zeros((b, kh, g, nq, c, hd), jnp.float32)

    def step(carry, ij):
        m, l, acc, = carry
        i, j = ij
        qb = jax.lax.dynamic_slice_in_dim(qg, i * c, c, axis=1)  # (B,c,KH,G,hd)
        kb = jax.lax.dynamic_slice_in_dim(k, j * c, c, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, j * c, c, axis=1)
        logits = jnp.einsum(
            "bqhgk,bshk->bhgqs", qb.astype(jnp.float32) * scale, kb.astype(jnp.float32)
        )
        logits = _softcap(logits, spec.softcap)
        qpos = i * c + jnp.arange(c)
        kpos = j * c + jnp.arange(c)
        logits = logits + _mask_bias(qpos, kpos, spec)
        mi = jax.lax.dynamic_slice_in_dim(m, i, 1, axis=3)[:, :, :, 0]
        li = jax.lax.dynamic_slice_in_dim(l, i, 1, axis=3)[:, :, :, 0]
        ai = jax.lax.dynamic_slice_in_dim(acc, i, 1, axis=3)[:, :, :, 0]
        m_new = jnp.maximum(mi, logits.max(axis=-1))
        # guard fully-masked rows (m_new == -inf)
        safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(logits - safe_m[..., None])
        p = jnp.where(jnp.isfinite(logits), p, 0.0)
        corr = jnp.where(jnp.isfinite(mi), jnp.exp(mi - safe_m), 0.0)
        l_new = li * corr + p.sum(axis=-1)
        a_new = ai * corr[..., None] + jnp.einsum("bhgqs,bshk->bhgqk", p, vb.astype(jnp.float32))
        m = jax.lax.dynamic_update_slice_in_dim(m, m_new[:, :, :, None], i, axis=3)
        l = jax.lax.dynamic_update_slice_in_dim(l, l_new[:, :, :, None], i, axis=3)
        acc = jax.lax.dynamic_update_slice_in_dim(acc, a_new[:, :, :, None], i, axis=3)
        return (m, l, acc), None

    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (jnp.asarray(qi), jnp.asarray(kj)))
    o = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,KH,G,nq,c,hd)
    o = jnp.moveaxis(o.reshape(b, kh, g, sq, hd), 3, 1)  # (B,S,KH,G,hd)
    return o.reshape(b, sq, h, hd).astype(q.dtype)


def attention_decode(
    q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, cache_len: jax.Array,
    spec: AttnSpec,
) -> jax.Array:
    """q: (B, 1, H, hd); caches: (B, Smax, KH, hd); cache_len: () int32.

    The new token's K/V are assumed already written at cache_len - 1.
    """
    b, _, h, hd = q.shape
    kh = k_cache.shape[2]
    qg = _gqa_split(q, kh)
    scale = hd**-0.5
    # mixed-precision dot: bf16 cache never materializes in f32 (full-cache
    # converts were the decode memory whale -- SPerf llama decode iter. 2)
    logits = jnp.einsum(
        "bqhgk,bshk->bhgqs", (qg.astype(jnp.float32) * scale).astype(qg.dtype),
        k_cache, preferred_element_type=jnp.float32,
    )
    logits = _softcap(logits, spec.softcap)
    kpos = jnp.arange(k_cache.shape[1])
    qpos = cache_len - 1
    ok = kpos < cache_len
    if spec.window > 0:
        ok &= (qpos - kpos) < spec.window
    logits = jnp.where(ok[None, None, None, None, :], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgqs,bshk->bqhgk", w.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, 1, h, hd).astype(q.dtype)


def attend(q, k, v, spec: AttnSpec, *, chunk_threshold: int = 2048) -> jax.Array:
    """Dispatch: full attention for short seqs, blockwise flash for long.

    The flash path (kernels/flash_attention) has a custom VJP with O(S)
    residuals -- required for 4k-32k training memory -- and exact causal
    FLOPs via wraparound pairing.
    """
    if q.shape[1] >= chunk_threshold:
        import os

        if os.environ.get("REPRO_ATTN_STUB"):
            # shape-correct, traffic-free stand-in: lowering a cell with and
            # without it isolates the attention loop's HBM bytes (used to
            # derive the Pallas-kernelized memory term in EXPERIMENTS SPerf)
            b, s, h, hd = q.shape
            kh = k.shape[2]
            vm = jnp.mean(v, axis=1, keepdims=True)  # (B,1,KH,hd)
            qg = q.reshape(b, s, kh, h // kh, hd)
            return (qg * vm[:, :, :, None]).reshape(b, s, h, hd)
        from repro.kernels.flash_attention import flash_attention

        return flash_attention(
            q, k, v, causal=spec.causal, window=spec.window,
            softcap=spec.softcap, block=spec.chunk_q,
        )
    return attention_full(q, k, v, spec)
