"""Export assigned-arch models as SEIFER ``LayerGraph``s.

The partitioner cuts between residual blocks; each block node carries
  * param_bytes -- bf16 weight bytes resident on a device hosting the block,
  * out_bytes   -- the activation tensor crossing the cut (B, S, d) bf16 for
    full-sequence work, (B, 1, d) per token for decode, plus any recurrent
    state that must migrate with a decode-stage boundary,
  * flops       -- forward FLOPs of the block at the given shape.

This is what makes the SEIFER technique architecture-agnostic: partitioning
and placement consume only this graph.
"""

from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.graph import Layer, LayerGraph
from repro.models.lm import PATCH_DIM, PATCH_TOKENS
from repro.models.ssm import HEAD_DIM as SSM_HEAD_DIM
from repro.models.ssm import ssm_dims

BF16 = 2


def _attn_params(cfg: ModelConfig) -> int:
    p = cfg.d_model * cfg.q_dim + 2 * cfg.d_model * cfg.kv_dim + cfg.q_dim * cfg.d_model
    if cfg.qkv_bias:
        p += cfg.q_dim + 2 * cfg.kv_dim
    return p


def _mlp_params(cfg: ModelConfig) -> int:
    gated = cfg.mlp_kind in ("swiglu", "geglu")
    return cfg.d_model * cfg.d_ff * (3 if gated else 2)


def _moe_params(cfg: ModelConfig) -> int:
    return cfg.n_experts * cfg.d_model * cfg.d_ff * 3 + cfg.d_model * cfg.n_experts


def _mamba_params(cfg: ModelConfig) -> int:
    d_in, h, n = ssm_dims(cfg)
    return (
        cfg.d_model * (2 * d_in + 2 * n + h)  # in_proj
        + cfg.ssm_conv_width * (d_in + 2 * n)  # conv
        + d_in * cfg.d_model  # out_proj
        + 3 * h + d_in
    )


def _mlstm_params(cfg: ModelConfig) -> int:
    d, d_in = cfg.d_model, cfg.ssm_expand * cfg.d_model
    return 4 * d * d_in + d * 2 * cfg.n_heads + d_in * d


def _slstm_params(cfg: ModelConfig) -> int:
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    return d * 4 * d + h * dh * 4 * dh + 4 * d + d * 2 * d + 2 * d * d


def _attn_flops(cfg: ModelConfig, b: int, sq: int, skv: int, *, causal: bool, window: int = 0) -> int:
    """QK^T + PV flops (projections counted via 2*params*tokens)."""
    eff = min(skv, window) if window else skv
    pair = sq * eff if not causal else sq * eff // 2
    return 4 * b * pair * cfg.n_heads * cfg.head_dim


def _block_layers(cfg: ModelConfig, shape: ShapeConfig) -> list[Layer]:
    b = shape.global_batch
    decode = shape.kind == "decode"
    sq = 1 if decode else shape.seq_len
    skv = shape.seq_len
    tokens = b * sq
    act = b * sq * cfg.d_model * BF16  # boundary tensor

    layers: list[Layer] = []

    def attn_layer(i: int, *, window: int = 0, extra: str = "") -> Layer:
        p = _attn_params(cfg)
        f = 2 * p * tokens + _attn_flops(cfg, b, sq, skv, causal=not decode, window=window)
        # a decode-stage boundary carries the hidden + nothing else (KV stays put)
        return Layer(f"attn{extra}.{i}", p * BF16, act, f)

    def mlp_layer(i: int) -> Layer:
        if cfg.is_moe:
            p_tot, p_act = _moe_params(cfg), 3 * cfg.experts_per_token * cfg.d_model * cfg.d_ff
            return Layer(f"moe.{i}", p_tot * BF16, act, 2 * p_act * tokens)
        p = _mlp_params(cfg)
        return Layer(f"mlp.{i}", p * BF16, act, 2 * p * tokens)

    def mamba_layer(i: int) -> Layer:
        p = _mamba_params(cfg)
        d_in, h, n = ssm_dims(cfg)
        f = 2 * p * tokens + 6 * tokens * h * SSM_HEAD_DIM * n  # state update+readout
        # decode boundary also carries the recurrent state of the *cut* layer
        state = b * h * SSM_HEAD_DIM * n * 4 if decode else 0
        return Layer(f"mamba.{i}", p * BF16, act + state, f)

    def xlstm_layer(i: int, kind: str) -> Layer:
        if kind == "slstm":
            p = _slstm_params(cfg)
            f = 2 * p * tokens
            state = b * cfg.d_model * 4 * 4 if decode else 0
        else:
            p = _mlstm_params(cfg)
            d_in = cfg.ssm_expand * cfg.d_model
            dh = d_in // cfg.n_heads
            f = 2 * p * tokens + 4 * tokens * cfg.n_heads * dh * dh
            state = b * cfg.n_heads * (dh + 1) * dh * 4 if decode else 0
        return Layer(f"{kind}.{i}", p * BF16, act + state, f)

    if cfg.family in ("dense", "vlm", "moe"):
        for i in range(cfg.n_layers):
            local = cfg.local_global and i % 2 == 0
            layers.append(attn_layer(i, window=cfg.sliding_window if local else 0))
            layers.append(mlp_layer(i))
    elif cfg.family == "hybrid":
        per = max(cfg.attn_every, 1)
        shared_p = (_attn_params(cfg) + _mlp_params(cfg)) * BF16
        for i in range(cfg.n_layers):
            layers.append(mamba_layer(i))
            if (i + 1) % per == 0:
                # shared block: params live once; model it on its first use
                first = i + 1 == per
                f = 2 * (_attn_params(cfg) + _mlp_params(cfg)) * tokens
                f += _attn_flops(cfg, b, sq, skv, causal=not decode)
                layers.append(Layer(f"shared.{i}", shared_p if first else 0, act, f))
    elif cfg.family == "ssm":
        per = max(cfg.slstm_every, 1)
        for i in range(cfg.n_layers):
            layers.append(xlstm_layer(i, "slstm" if i % per == 0 else "mlstm"))
    elif cfg.family == "audio":
        enc_tokens = b * shape.seq_len  # encoder always sees the full input
        enc_act = b * shape.seq_len * cfg.d_model * BF16
        for i in range(cfg.encoder_layers):
            p = _attn_params(cfg) + _mlp_params(cfg)
            f = 2 * p * enc_tokens + _attn_flops(cfg, b, shape.seq_len, shape.seq_len, causal=False)
            layers.append(Layer(f"enc.{i}", p * BF16, enc_act, f))
        for i in range(cfg.n_layers):
            p = 2 * _attn_params(cfg) + _mlp_params(cfg)  # self + cross + mlp
            f = 2 * p * tokens
            f += _attn_flops(cfg, b, sq, skv, causal=not decode)  # self
            f += _attn_flops(cfg, b, sq, shape.seq_len, causal=False)  # cross
            layers.append(Layer(f"dec.{i}", p * BF16, act, f))
    else:  # pragma: no cover
        raise ValueError(f"unknown family {cfg.family}")

    return layers


def export_graph(cfg: ModelConfig, shape: ShapeConfig) -> LayerGraph:
    """LayerGraph of ``cfg`` at ``shape`` (embedding/head folded into ends)."""
    b = shape.global_batch
    decode = shape.kind == "decode"
    sq = 1 if decode else shape.seq_len
    layers = _block_layers(cfg, shape)
    embed_bytes = cfg.vocab_size * cfg.d_model * BF16
    act = b * sq * cfg.d_model * BF16

    head = Layer(
        "head",
        embed_bytes if not cfg.tie_embeddings else 0,
        b * sq * cfg.vocab_size * (4 if decode else BF16),
        2 * cfg.vocab_size * cfg.d_model * b * sq,
    )
    first = Layer("embed", embed_bytes, act, 0)
    if cfg.family == "vlm":
        first = Layer("embed", embed_bytes + PATCH_DIM * cfg.d_model * BF16, act, 0)
    in_bytes = b * sq * 4  # token ids
    if cfg.family == "audio":
        in_bytes += b * shape.seq_len * cfg.d_model * BF16  # frame embeddings
    if cfg.family == "vlm":
        in_bytes += b * PATCH_TOKENS * PATCH_DIM * BF16
    return LayerGraph(cfg.name, tuple([first] + layers + [head]), in_bytes=in_bytes)
