"""Shared model plumbing: dtype policy, init helpers, sharding hook."""

from __future__ import annotations

from typing import Any, Callable, Protocol

import jax
import jax.numpy as jnp

Params = Any  # nested dict pytree of jnp arrays


class ActivationPolicy(Protocol):
    """Hook the sharding layer injects; models call it on key activations."""

    def act(self, x: jax.Array, kind: str) -> jax.Array: ...


class NoSharding:
    def act(self, x: jax.Array, kind: str) -> jax.Array:
        return x


NO_SHARDING = NoSharding()


def dense_init(key: jax.Array, shape: tuple[int, ...], dtype=jnp.bfloat16,
               scale: float | None = None) -> jax.Array:
    """Truncated-normal fan-in init (LM standard)."""
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale if scale is not None else fan_in**-0.5
    return (jax.random.truncated_normal(key, -3, 3, shape, jnp.float32) * std).astype(dtype)


def embed_init(key: jax.Array, vocab: int, d: int, dtype=jnp.bfloat16) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def split_keys(key: jax.Array, names: list[str]) -> dict[str, jax.Array]:
    keys = jax.random.split(key, len(names))
    return dict(zip(names, keys))


def fp32(x: jax.Array) -> jax.Array:
    return x.astype(jnp.float32)


def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """bf16 x bf16 matmul with f32 accumulation, result cast back."""
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)


def matmul_reduced(x: jax.Array, w: jax.Array) -> jax.Array:
    """Matmul whose output feeds a cross-shard partial-sum (TP-contracted).

    Emits a bf16 dot output (per-shard accumulation is still f32 inside the
    MXU) so GSPMD's all-reduce moves HALF the bytes of the f32 variant --
    SPerf iteration: TP activation all-reduces dominated the collective term.
    """
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
    ).astype(x.dtype)


def stack_layer_params(init_one: Callable[[jax.Array], Params], key: jax.Array,
                       n_layers: int) -> Params:
    """Initialize n_layers sets of params stacked on a leading axis (for scan)."""
    keys = jax.random.split(key, n_layers)
    return jax.vmap(init_one)(keys)
