"""Generic LM assembly for every assigned architecture family.

One functional model covering:
  * dense / vlm / moe decoder-only transformers (GQA, RoPE, local+global
    alternation, logit softcaps, QKV bias, GeGLU/SwiGLU, tied embeddings),
  * audio enc-dec (whisper: learned positions, cross-attention, stubbed
    conv frontend -- precomputed frame embeddings),
  * ssm (xLSTM: sLSTM + mLSTM groups),
  * hybrid (zamba2: Mamba2 towers + one shared attention block applied
    every ``attn_every`` layers).

Layers are grouped and scanned (``jax.lax.scan`` over stacked group params)
so the HLO stays compact at 61-layer scale; training groups are rematerialized
with ``jax.checkpoint``.  The vocab-dim loss is computed by a chunked
cross-entropy (never materializes (B, S, V) logits).

Decode steps carry an explicit cache pytree (KV ring buffers for sliding-
window layers, recurrent states for ssm/hybrid) and are O(1) in sequence
length for the sub-quadratic families.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models import xlstm as xlstm_lib
from repro.models.common import NO_SHARDING, dense_init, embed_init
from repro.models.layers import (
    AttnSpec,
    apply_norm,
    attention_decode,
    attention_full,
    attend,
    init_attention,
    init_norm,
    out_proj,
    qkv_proj,
    rope,
)

PATCH_TOKENS = 256  # vlm: patch embeddings occupy the first positions
PATCH_DIM = 1024  # vlm: precomputed patch-embedding width
XENT_CHUNK = 512  # tokens per chunk in the chunked cross-entropy


# ---------------------------------------------------------------------------
# Group structure
# ---------------------------------------------------------------------------

def group_layout(cfg) -> tuple[int, int]:
    """(n_groups, layers_per_group) for the scanned stack."""
    if cfg.family == "ssm":
        per = max(cfg.slstm_every, 1)
        return cfg.n_layers // per, per
    if cfg.family == "hybrid":
        per = max(cfg.attn_every, 1)
        return cfg.n_layers // per, per
    if cfg.local_global:
        return cfg.n_layers // 2, 2
    return cfg.n_layers, 1


def _attn_spec(cfg, *, local: bool, causal: bool = True) -> AttnSpec:
    window = cfg.sliding_window if local else 0
    return AttnSpec(causal=causal, window=window, softcap=cfg.attn_softcap)


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def _init_block(cfg, key: jax.Array, *, cross: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "ln1": init_norm(cfg, cfg.d_model),
        "attn": init_attention(cfg, ks[0]),
        "ln2": init_norm(cfg, cfg.d_model),
    }
    if cfg.is_moe:
        p["mlp"] = moe_lib.init_moe(cfg, ks[1])
    else:
        from repro.models.layers import init_mlp

        p["mlp"] = init_mlp(cfg, ks[1])
    if cfg.post_norm:
        p["post1"] = init_norm(cfg, cfg.d_model)
        p["post2"] = init_norm(cfg, cfg.d_model)
    if cross:
        p["ln_cross"] = init_norm(cfg, cfg.d_model)
        p["cross"] = init_attention(cfg, ks[2])
    return p


def _init_group(cfg, key: jax.Array) -> dict:
    if cfg.family == "ssm":
        k1, k2 = jax.random.split(key)
        per = max(cfg.slstm_every, 1)
        mk = jax.random.split(k2, max(per - 1, 1))
        return {
            "slstm_ln": init_norm(cfg, cfg.d_model),
            "slstm": xlstm_lib.init_slstm(cfg, k1),
            "mlstm_ln": jax.vmap(lambda _: init_norm(cfg, cfg.d_model))(mk),
            "mlstm": jax.vmap(partial(xlstm_lib.init_mlstm, cfg))(mk),
        }
    if cfg.family == "hybrid":
        per = max(cfg.attn_every, 1)
        mk = jax.random.split(key, per)
        return {
            "mamba_ln": jax.vmap(lambda _: init_norm(cfg, cfg.d_model))(mk),
            "mamba": jax.vmap(partial(ssm_lib.init_mamba, cfg))(mk),
        }
    if cfg.local_global:
        k1, k2 = jax.random.split(key)
        return {"local": _init_block(cfg, k1), "global": _init_block(cfg, k2)}
    return _init_block(cfg, key)


def init_params(cfg, key: jax.Array, *, max_pos: int = 32768) -> dict:
    keys = jax.random.split(key, 8)
    n_groups, _ = group_layout(cfg)
    params: dict[str, Any] = {
        "embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model),
        "final_norm": init_norm(cfg, cfg.d_model),
        "blocks": jax.vmap(partial(_init_group, cfg))(jax.random.split(keys[1], n_groups)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[2], (cfg.d_model, cfg.vocab_size))
    if cfg.family == "vlm":
        params["patch_proj"] = dense_init(keys[3], (PATCH_DIM, cfg.d_model))
    if cfg.family == "hybrid":
        params["shared_attn"] = _init_block(cfg, keys[4])
    if cfg.family == "audio":
        enc_keys = jax.random.split(keys[5], cfg.encoder_layers)
        params["encoder"] = {
            "blocks": jax.vmap(partial(_init_block, cfg))(enc_keys),
            "final_norm": init_norm(cfg, cfg.d_model),
            "pos": dense_init(keys[6], (max_pos, cfg.d_model), scale=0.02),
        }
        params["dec_pos"] = dense_init(keys[7], (max_pos, cfg.d_model), scale=0.02)
        # decoder blocks get cross-attention
        dec_keys = jax.random.split(keys[1], n_groups)
        params["blocks"] = jax.vmap(partial(_init_block, cfg, cross=True))(dec_keys)
    return params


# ---------------------------------------------------------------------------
# Forward blocks (full-sequence: train / prefill)
# ---------------------------------------------------------------------------

def _attn_sublayer(cfg, p, x, spec: AttnSpec, positions, *, kv_x=None, policy=NO_SHARDING):
    h = apply_norm(cfg, x, p["ln1" if kv_x is None else "ln_cross"])
    src = kv_x if kv_x is not None else h  # cross-attn keys from raw encoder output
    ap = p["attn"] if kv_x is None else p["cross"]
    q, _, _ = qkv_proj(cfg, ap, h)
    _, k, v = qkv_proj(cfg, ap, src)
    if cfg.pos_emb == "rope" and kv_x is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q, k, v = policy.act(q, "attn_q"), policy.act(k, "attn_kv"), policy.act(v, "attn_kv")
    o = attend(q, k, v, spec)
    o = out_proj(ap, o)
    if cfg.post_norm and kv_x is None:
        o = apply_norm(cfg, o, p["post1"])
    return x + o


def _mlp_sublayer(cfg, p, x, *, policy=NO_SHARDING):
    h = apply_norm(cfg, x, p["ln2"])
    if cfg.is_moe:
        o, aux = moe_lib.moe_mlp(cfg, p["mlp"], h)
    else:
        from repro.models.layers import mlp

        o, aux = mlp(cfg, p["mlp"], h), 0.0
    o = policy.act(o, "mlp_out")
    if cfg.post_norm:
        o = apply_norm(cfg, o, p["post2"])
    return x + o, aux


def _transformer_block(cfg, p, x, spec, positions, policy, *, enc_out=None):
    x = _attn_sublayer(cfg, p, x, spec, positions, policy=policy)
    if enc_out is not None:
        x = _attn_sublayer(
            cfg, p, x, AttnSpec(causal=False), positions, kv_x=enc_out, policy=policy
        )
    x, aux = _mlp_sublayer(cfg, p, x, policy=policy)
    return x, aux


def _group_forward(cfg, gp, x, positions, policy, *, enc_out=None):
    """Run one layer-group (full sequence).  Returns (x, aux_loss)."""
    if cfg.family == "ssm":
        x = x + xlstm_lib.slstm_forward(
            cfg, gp["slstm"], apply_norm(cfg, x, gp["slstm_ln"])
        )

        def mstep(h, inner):
            ln, mp = inner
            return h + xlstm_lib.mlstm_forward(cfg, mp, apply_norm(cfg, h, ln)), None

        x, _ = jax.lax.scan(mstep, x, (gp["mlstm_ln"], gp["mlstm"]))
        return x, 0.0
    if cfg.family == "hybrid":
        def mstep(h, inner):
            ln, mp = inner
            return h + ssm_lib.mamba_forward(cfg, mp, apply_norm(cfg, h, ln)), None

        x, _ = jax.lax.scan(mstep, x, (gp["mamba_ln"], gp["mamba"]))
        return x, 0.0  # shared attention applied by the caller
    if cfg.local_global:
        x, a1 = _transformer_block(
            cfg, gp["local"], x, _attn_spec(cfg, local=True), positions, policy
        )
        x, a2 = _transformer_block(
            cfg, gp["global"], x, _attn_spec(cfg, local=False), positions, policy
        )
        return x, a1 + a2
    return _transformer_block(cfg, gp, x, spec=_attn_spec(cfg, local=False),
                              positions=positions, policy=policy, enc_out=enc_out)


def embed_inputs(cfg, params, batch) -> jax.Array:
    """Token (+patch / frame) embedding.  Returns (B, S, d)."""
    if cfg.family == "audio":
        raise ValueError("audio uses encode()/decoder paths")
    x = params["embed"][batch["tokens"]]  # (B,S,d)
    if cfg.gemma_norm:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    if cfg.family == "vlm" and "patches" in batch:
        p_tok = batch["patches"].shape[1]  # patches occupy the first positions
        pe = jnp.einsum("bpc,cd->bpd", batch["patches"], params["patch_proj"])
        x = jnp.concatenate([pe.astype(x.dtype), x[:, p_tok:]], axis=1)
    return x


def forward_hidden(cfg, params, batch, *, policy=NO_SHARDING, remat: bool = False):
    """Full-sequence forward to final hidden states.  Returns (h, aux)."""
    if cfg.family == "audio":
        return _audio_forward(cfg, params, batch, policy=policy, remat=remat)
    x = embed_inputs(cfg, params, batch)
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]

    shared = params.get("shared_attn")

    def group_fn(x, gp):
        x, aux = _group_forward(cfg, gp, x, positions, policy)
        if shared is not None:
            x, aux2 = _transformer_block(
                cfg, shared, x, _attn_spec(cfg, local=False), positions, policy
            )
            aux = aux + aux2
        return x, aux

    if remat:
        group_fn = jax.checkpoint(group_fn)

    def scan_fn(carry, gp):
        x, aux = carry
        x, a = group_fn(x, gp)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(scan_fn, (x, 0.0), params["blocks"])
    x = apply_norm(cfg, x, params["final_norm"])
    return policy.act(x, "final_hidden"), aux


def encode(cfg, params, frames: jax.Array, *, policy=NO_SHARDING, remat: bool = False):
    """Whisper encoder: frames (B, S, d) -> (B, S, d)."""
    enc = params["encoder"]
    s = frames.shape[1]
    x = frames + enc["pos"][:s][None]
    spec = AttnSpec(causal=False)
    positions = jnp.arange(s)[None, :]

    def block_fn(bp, x):
        return _transformer_block(cfg, bp, x, spec, positions, policy)[0]

    if remat:
        block_fn = jax.checkpoint(block_fn)

    def scan_fn(x, bp):
        return block_fn(bp, x), None

    x, _ = jax.lax.scan(scan_fn, x, enc["blocks"])
    return apply_norm(cfg, x, enc["final_norm"])


def _audio_forward(cfg, params, batch, *, policy=NO_SHARDING, remat: bool = False):
    enc_out = encode(cfg, params, batch["frames"], policy=policy, remat=remat)
    tokens = batch["tokens"]
    s = tokens.shape[1]
    x = params["embed"][tokens] + params["dec_pos"][:s][None]
    positions = jnp.arange(s)[None, :]

    def block_fn(bp, x, enc_out):
        return _group_forward(cfg, bp, x, positions, policy, enc_out=enc_out)[0]

    if remat:
        block_fn = jax.checkpoint(block_fn)

    def scan_fn(x, bp):
        return block_fn(bp, x, enc_out), None

    x, _ = jax.lax.scan(scan_fn, x, params["blocks"])
    x = apply_norm(cfg, x, params["final_norm"])
    return policy.act(x, "final_hidden"), 0.0


# ---------------------------------------------------------------------------
# Chunked cross-entropy (never materializes (B, S, V))
# ---------------------------------------------------------------------------

def lm_head_matrix(cfg, params) -> jax.Array:
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def chunked_xent(
    cfg, params, hidden: jax.Array, labels: jax.Array, mask: jax.Array,
    *, chunk: int = XENT_CHUNK, policy=NO_SHARDING,
) -> jax.Array:
    """Mean next-token cross entropy.  hidden (B,S,d); labels/mask (B,S)."""
    w = lm_head_matrix(cfg, params)  # (d, V)
    b, s, d = hidden.shape
    c = min(chunk, s)
    if s % c:
        c = s
    nchunk = s // c
    h_c = jnp.moveaxis(hidden.reshape(b, nchunk, c, d), 1, 0)
    y_c = jnp.moveaxis(labels.reshape(b, nchunk, c), 1, 0)
    m_c = jnp.moveaxis(mask.reshape(b, nchunk, c), 1, 0)

    def step(acc, inp):
        h, y, m = inp
        logits = jnp.einsum("bcd,dv->bcv", h, w).astype(jnp.float32)
        logits = policy.act(logits, "logits")
        if cfg.final_softcap > 0:
            logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * m
        return (acc[0] + nll.sum(), acc[1] + m.sum()), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.float32(0), jnp.float32(0)), (h_c, y_c, m_c))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(cfg, params, batch, *, policy=NO_SHARDING, aux_weight: float = 0.01):
    """Next-token LM loss over the batch; adds the MoE aux loss."""
    hidden, aux = forward_hidden(cfg, params, batch, policy=policy, remat=True)
    tokens = batch["tokens"]
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.ones(tokens.shape, jnp.float32).at[:, -1].set(0.0)
    if cfg.family == "vlm" and "patches" in batch:
        mask = mask.at[:, : batch["patches"].shape[1] - 1].set(0.0)
    loss = chunked_xent(cfg, params, hidden, labels, mask, policy=policy)
    return loss + aux_weight * aux, {"xent": loss, "aux": aux}


# ---------------------------------------------------------------------------
# Decode caches
# ---------------------------------------------------------------------------

def _kv_cache(cfg, batch: int, length: int) -> dict:
    shape = (batch, length, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, jnp.bfloat16), "v": jnp.zeros(shape, jnp.bfloat16)}


def _group_cache(cfg, batch: int, max_len: int) -> dict:
    if cfg.family == "ssm":
        per = max(cfg.slstm_every, 1)
        n_m = max(per - 1, 1)
        return {
            "slstm": xlstm_lib.slstm_init_state(cfg, batch),
            "mlstm": jax.tree.map(
                lambda x: jnp.broadcast_to(x, (n_m,) + x.shape),
                xlstm_lib.mlstm_init_cache(cfg, batch),
            ),
        }
    if cfg.family == "hybrid":
        per = max(cfg.attn_every, 1)
        mc = ssm_lib.mamba_init_cache(cfg, batch)
        return {
            "mamba": jax.tree.map(lambda x: jnp.broadcast_to(x, (per,) + x.shape), mc),
            "shared_kv": _kv_cache(cfg, batch, max_len),
        }
    if cfg.local_global:
        return {
            "local": _kv_cache(cfg, batch, min(cfg.sliding_window, max_len)),
            "global": _kv_cache(cfg, batch, max_len),
        }
    return {"kv": _kv_cache(cfg, batch, max_len)}


def init_caches(cfg, batch: int, max_len: int, *, enc_len: int = 0) -> dict:
    n_groups, _ = group_layout(cfg)
    gc = _group_cache(cfg, batch, max_len)
    caches: dict[str, Any] = {
        "pos": jnp.zeros((), jnp.int32),
        "blocks": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape), gc
        ),
    }
    if cfg.family == "audio":
        cross = _kv_cache(cfg, batch, enc_len or max_len)
        caches["cross"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape), cross
        )
    return caches


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------

def _attn_decode_sub(cfg, p, cache, x, pos, *, local: bool):
    """One-token attention vs a (ring or linear) KV cache."""
    h = apply_norm(cfg, x, p["ln1"])
    q, k, v = qkv_proj(cfg, p["attn"], h)  # (B,1,H,hd)/(B,1,KH,hd)
    if cfg.pos_emb == "rope":
        q = rope(q, pos[None, None], cfg.rope_theta)
        k = rope(k, pos[None, None], cfg.rope_theta)
    length = cache["k"].shape[1]
    slot = pos % length if local else jnp.minimum(pos, length - 1)
    kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
    vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
    if local:
        # ring buffer: every written slot is within the window by construction
        valid = jnp.arange(length) <= jnp.minimum(pos, length - 1)
        spec = AttnSpec(causal=False, softcap=cfg.attn_softcap)
        o = _masked_decode(cfg, q, kc, vc, valid, spec)
    else:
        spec = AttnSpec(causal=True, softcap=cfg.attn_softcap)
        o = attention_decode(q, kc, vc, pos + 1, spec)
    o = out_proj(p["attn"], o)
    if cfg.post_norm:
        o = apply_norm(cfg, o, p["post1"])
    return {"k": kc, "v": vc}, x + o


def _masked_decode(cfg, q, kc, vc, valid, spec):
    b, _, h, hd = q.shape
    kh = kc.shape[2]
    qg = q.reshape(b, 1, kh, h // kh, hd)
    # bf16 caches stay bf16 (mixed-precision dot with f32 accumulation)
    logits = jnp.einsum(
        "bqhgk,bshk->bhgqs", (qg.astype(jnp.float32) * hd**-0.5).astype(q.dtype),
        kc, preferred_element_type=jnp.float32,
    )
    if spec.softcap > 0:
        logits = spec.softcap * jnp.tanh(logits / spec.softcap)
    logits = jnp.where(valid[None, None, None, None, :], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgqs,bshk->bqhgk", w.astype(vc.dtype), vc,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, 1, h, hd).astype(q.dtype)


def _cross_decode_sub(cfg, p, cross_cache, x, enc_len):
    h = apply_norm(cfg, x, p["ln_cross"])
    q, _, _ = qkv_proj(cfg, p["cross"], h)
    valid = jnp.arange(cross_cache["k"].shape[1]) < enc_len
    o = _masked_decode(cfg, q, cross_cache["k"], cross_cache["v"], valid, AttnSpec(causal=False))
    return x + out_proj(p["cross"], o)


def _block_decode(cfg, p, cache, x, pos, *, local: bool, cross_cache=None, enc_len=0):
    kv, x = _attn_decode_sub(cfg, p, cache, x, pos, local=local)
    if cross_cache is not None:
        x = _cross_decode_sub(cfg, p, cross_cache, x, enc_len)
    x, _ = _mlp_sublayer(cfg, p, x)
    return kv, x


def _group_decode(cfg, params, gp, gc, x, pos, *, cross=None, enc_len=0):
    """Decode one group.  Returns (new group cache, x)."""
    if cfg.family == "ssm":
        st, y = xlstm_lib.slstm_step(
            cfg, gp["slstm"], gc["slstm"], apply_norm(cfg, x, gp["slstm_ln"])
        )
        x = x + y

        def mstep(h, inner):
            ln, mp, mc = inner
            mc, y = xlstm_lib.mlstm_step(cfg, mp, mc, apply_norm(cfg, h, ln))
            return h + y, mc

        x, mcs = jax.lax.scan(mstep, x, (gp["mlstm_ln"], gp["mlstm"], gc["mlstm"]))
        return {"slstm": st, "mlstm": mcs}, x
    if cfg.family == "hybrid":
        def mstep(h, inner):
            ln, mp, mc = inner
            mc, y = ssm_lib.mamba_step(cfg, mp, mc, apply_norm(cfg, h, ln))
            return h + y, mc

        x, mcs = jax.lax.scan(mstep, x, (gp["mamba_ln"], gp["mamba"], gc["mamba"]))
        kv, x = _block_decode(
            cfg, params["shared_attn"], gc["shared_kv"], x, pos, local=False
        )
        return {"mamba": mcs, "shared_kv": kv}, x
    if cfg.local_global:
        kv_l, x = _block_decode(cfg, gp["local"], gc["local"], x, pos, local=True)
        kv_g, x = _block_decode(cfg, gp["global"], gc["global"], x, pos, local=False)
        return {"local": kv_l, "global": kv_g}, x
    if cfg.family == "audio":
        kv, x = _block_decode(
            cfg, gp, gc["kv"], x, pos, local=False, cross_cache=cross, enc_len=enc_len
        )
        return {"kv": kv}, x
    kv, x = _block_decode(cfg, gp, gc["kv"], x, pos, local=False)
    return {"kv": kv}, x


def decode_step(cfg, params, caches, tokens: jax.Array, *, enc_len: int = 0):
    """One decode step.  tokens (B, 1) -> (logits (B, 1, V), caches')."""
    pos = caches["pos"]
    x = params["embed"][tokens]
    if cfg.gemma_norm:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    if cfg.family == "audio":
        x = x + jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos, 1, axis=0)[None]

    def scan_fn(x, inner):
        gp, gc, cross = inner
        gc, x = _group_decode(cfg, params, gp, gc, x, pos, cross=cross, enc_len=enc_len)
        return x, gc

    cross = caches.get("cross")
    if cross is None:
        n_groups, _ = group_layout(cfg)
        cross = jnp.zeros((n_groups, 0))  # dummy scanned leaf
    x, new_blocks = jax.lax.scan(scan_fn, x, (params["blocks"], caches["blocks"], cross))
    x = apply_norm(cfg, x, params["final_norm"])
    logits = jnp.einsum("btd,dv->btv", x, lm_head_matrix(cfg, params)).astype(jnp.float32)
    if cfg.final_softcap > 0:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    new_caches = dict(caches, blocks=new_blocks, pos=pos + 1)
    return logits, new_caches
