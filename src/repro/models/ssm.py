"""Mamba2 (SSD) blocks: chunked parallel scan for train/prefill, O(1) decode.

The chunked SSD algorithm (Mamba2 paper Sec. 6) splits the sequence into
chunks of ``chunk`` steps; within a chunk the recurrence is materialized as a
(Q, Q) masked "attention" (quadratic in the chunk only), and a (dh, N) state
is carried between chunks by ``jax.lax.scan``.  All gate math is fp32.

Layout: d_inner = ssm_expand * d_model, heads of size HEAD_DIM, single B/C
group (n_groups=1), scalar-per-head A (the Mamba2 restriction).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init

HEAD_DIM = 64
DEFAULT_CHUNK = 256


def ssm_dims(cfg) -> tuple[int, int, int]:
    """(d_inner, n_heads, state N) for the mamba tower of this config."""
    d_in = cfg.ssm_expand * cfg.d_model
    return d_in, d_in // HEAD_DIM, max(cfg.ssm_state, 16)


def init_mamba(cfg, key: jax.Array) -> dict:
    d = cfg.d_model
    d_in, h, n = ssm_dims(cfg)
    conv_dim = d_in + 2 * n
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_in + 2 * n + h)),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv_width, conv_dim), scale=0.3),
        "conv_b": jnp.zeros((conv_dim,), jnp.bfloat16),
        "A_log": jnp.zeros((h,), jnp.float32),  # A = -exp(A_log) = -1 at init
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm_w": jnp.ones((d_in,), jnp.bfloat16),
        "out_proj": dense_init(ks[3], (d_in, d), scale=d_in**-0.5),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv.  x: (B, S, C); w: (K, C).  Sum of shifts."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        jax.lax.dynamic_slice_in_dim(xp, i, x.shape[1], axis=1)
        * w[i].astype(x.dtype)
        for i in range(k)
    )
    return out + b.astype(x.dtype)


def _split_proj(cfg, p: dict, x: jax.Array):
    """x (B,S,d) -> z (B,S,d_in), xBC (B,S,d_in+2N), dt (B,S,H) fp32."""
    d_in, h, n = ssm_dims(cfg)
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"]).astype(x.dtype)
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in : 2 * d_in + 2 * n]
    dt = zxbcdt[..., 2 * d_in + 2 * n :].astype(jnp.float32)
    dt = jax.nn.softplus(dt + p["dt_bias"])
    return z, xbc, dt


def _gate_out(cfg, p: dict, y: jax.Array, z: jax.Array) -> jax.Array:
    """Gated RMSNorm then down-projection.  y, z: (B, S, d_in)."""
    g = (y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32)))
    var = jnp.mean(g * g, axis=-1, keepdims=True)
    g = g * jax.lax.rsqrt(var + 1e-6) * p["norm_w"].astype(jnp.float32)
    return jnp.einsum("bse,ed->bsd", g.astype(z.dtype), p["out_proj"]).astype(z.dtype)


def mamba_forward(
    cfg, p: dict, x: jax.Array, *, chunk: int = DEFAULT_CHUNK
) -> jax.Array:
    """Full-sequence forward (train / prefill).  x: (B, S, d) -> (B, S, d)."""
    b, s, _ = x.shape
    d_in, h, n = ssm_dims(cfg)
    q = min(chunk, s)
    if s % q:
        raise ValueError(f"seq {s} must divide chunk {q}")
    nc = s // q

    z, xbc, dt = _split_proj(cfg, p, x)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    xs = xbc[..., :d_in].reshape(b, s, h, HEAD_DIM)
    bm = xbc[..., d_in : d_in + n].astype(jnp.float32)  # (B,S,N)
    cm = xbc[..., d_in + n :].astype(jnp.float32)

    a = -jnp.exp(p["A_log"])  # (H,)
    da = dt * a  # (B,S,H) negative

    # chunked tensors: (B, nc, Q, ...)
    xs_c = xs.reshape(b, nc, q, h, HEAD_DIM).astype(jnp.float32)
    bm_c = bm.reshape(b, nc, q, n)
    cm_c = cm.reshape(b, nc, q, n)
    dt_c = dt.reshape(b, nc, q, h)
    da_c = da.reshape(b, nc, q, h)
    cum = jnp.cumsum(da_c, axis=2)  # (B,nc,Q,H)

    def chunk_step(hstate, inp):
        xs_k, bm_k, cm_k, dt_k, da_k, cum_k = inp  # leading axis = B
        # ---- intra-chunk (quadratic within chunk) ----
        # L[t,s] = exp(cum[t] - cum[s]) for s <= t
        ldiff = cum_k[:, :, None, :] - cum_k[:, None, :, :]  # (B,Q,S,H)
        mask = jnp.tril(jnp.ones((q, q), bool))
        lmat = jnp.where(mask[None, :, :, None], jnp.exp(ldiff), 0.0)
        gbc = jnp.einsum("btn,bsn->bts", cm_k, bm_k)  # (B,Q,S)
        scores = gbc[:, :, :, None] * lmat * dt_k[:, None, :, :]  # (B,Q,S,H)
        y_intra = jnp.einsum("btsh,bshd->bthd", scores, xs_k)
        # ---- inter-chunk (carry state) ----
        decay_in = jnp.exp(cum_k)  # (B,Q,H): decay from chunk start to t
        y_inter = jnp.einsum("btn,bhdn->bthd", cm_k, hstate) * decay_in[..., None]
        # ---- state update ----
        decay_out = jnp.exp(cum_k[:, -1:, :] - cum_k)  # (B,Q,H)
        contrib = jnp.einsum(
            "bsh,bsn,bshd->bhdn", decay_out * dt_k, bm_k, xs_k
        )
        h_new = hstate * jnp.exp(cum_k[:, -1])[:, :, None, None] + contrib
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((b, h, HEAD_DIM, n), jnp.float32)
    inputs = tuple(
        jnp.moveaxis(t, 1, 0) for t in (xs_c, bm_c, cm_c, dt_c, da_c, cum)
    )
    _, y = jax.lax.scan(chunk_step, h0, inputs)
    y = jnp.moveaxis(y, 0, 1).reshape(b, s, h, HEAD_DIM)
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    return _gate_out(cfg, p, y.reshape(b, s, d_in), z)


def mamba_init_cache(cfg, batch: int) -> dict:
    d_in, h, n = ssm_dims(cfg)
    conv_dim = d_in + 2 * n
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), jnp.bfloat16),
        "ssm": jnp.zeros((batch, h, HEAD_DIM, n), jnp.float32),
    }


def mamba_step(cfg, p: dict, cache: dict, x: jax.Array) -> tuple[dict, jax.Array]:
    """Single decode step.  x: (B, 1, d).  Returns (cache', y (B, 1, d))."""
    b = x.shape[0]
    d_in, h, n = ssm_dims(cfg)
    z, xbc, dt = _split_proj(cfg, p, x)  # (B,1,*)
    window = jnp.concatenate([cache["conv"], xbc.astype(jnp.bfloat16)], axis=1)
    conv_out = (
        jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), p["conv_w"].astype(jnp.float32))
        + p["conv_b"].astype(jnp.float32)
    )
    xbc1 = jax.nn.silu(conv_out)  # (B, conv_dim)
    xs = xbc1[:, :d_in].reshape(b, h, HEAD_DIM).astype(jnp.float32)
    bm = xbc1[:, d_in : d_in + n].astype(jnp.float32)
    cm = xbc1[:, d_in + n :].astype(jnp.float32)
    a = -jnp.exp(p["A_log"])
    dt1 = dt[:, 0]  # (B,H)
    decay = jnp.exp(dt1 * a)  # (B,H)
    hstate = cache["ssm"] * decay[:, :, None, None] + jnp.einsum(
        "bh,bn,bhd->bhdn", dt1, bm, xs
    )
    y = jnp.einsum("bn,bhdn->bhd", cm, hstate) + xs * p["D"][None, :, None]
    out = _gate_out(cfg, p, y.reshape(b, 1, d_in), z)
    return {"conv": window[:, 1:], "ssm": hstate}, out
