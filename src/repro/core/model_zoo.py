"""Layer graphs of the CNNs used in the paper-style evaluation.

SEIFER's preliminary evaluation (Fig. 3) sweeps several Keras-style vision
models (the DEFER predecessor used VGG16/ResNet-family models).  We
reconstruct their chain layer graphs from the published architectures:
per-layer parameter counts and output activation shapes.  Parameters default
to 1 byte each (the paper quantizes models with TFLite before deployment);
activations default to 4 bytes (float), with an optional compression ratio
applied by the caller (paper: ZFP/LZ4).

These graphs feed ``core.simulate`` and the Fig. 3 / throughput benchmarks.
The assigned LM architectures export their own graphs via
``models/graph_export.py``.
"""

from __future__ import annotations

from repro.core.graph import Layer, LayerGraph

PARAM_BYTES = 1  # int8-quantized weights (TFLite), per the paper
ACT_BYTES = 4  # float32 activations on the wire


def _conv(name: str, k: int, cin: int, cout: int, oh: int, ow: int) -> Layer:
    return Layer(
        name=name,
        param_bytes=(k * k * cin * cout + cout) * PARAM_BYTES,
        out_bytes=oh * ow * cout * ACT_BYTES,
        flops=2 * k * k * cin * cout * oh * ow,
    )


def _fc(name: str, cin: int, cout: int) -> Layer:
    return Layer(
        name=name,
        param_bytes=(cin * cout + cout) * PARAM_BYTES,
        out_bytes=cout * ACT_BYTES,
        flops=2 * cin * cout,
    )


def vgg16() -> LayerGraph:
    """VGG16 (224x224x3).  Pooling folded into the preceding conv's output."""
    cfg = [
        # (cin, cout, out_h/w after optional pool)
        (3, 64, 224),
        (64, 64, 112),  # pool
        (64, 128, 112),
        (128, 128, 56),  # pool
        (128, 256, 56),
        (256, 256, 56),
        (256, 256, 28),  # pool
        (256, 512, 28),
        (512, 512, 28),
        (512, 512, 14),  # pool
        (512, 512, 14),
        (512, 512, 14),
        (512, 512, 7),  # pool
    ]
    layers = [
        _conv(f"conv{i}", 3, cin, cout, hw, hw) for i, (cin, cout, hw) in enumerate(cfg)
    ]
    layers += [_fc("fc1", 7 * 7 * 512, 4096), _fc("fc2", 4096, 4096), _fc("fc3", 4096, 1000)]
    return LayerGraph("vgg16", tuple(layers), in_bytes=224 * 224 * 3 * ACT_BYTES)


def _bottleneck(name: str, cin: int, cmid: int, cout: int, hw: int, downsample: bool) -> Layer:
    params = cin * cmid + 9 * cmid * cmid + cmid * cout + (cin * cout if downsample else 0)
    flops = 2 * hw * hw * (cin * cmid + 9 * cmid * cmid + cmid * cout)
    return Layer(
        name=name,
        param_bytes=params * PARAM_BYTES,
        out_bytes=hw * hw * cout * ACT_BYTES,
        flops=flops,
    )


def resnet50() -> LayerGraph:
    layers = [_conv("stem", 7, 3, 64, 112, 112)]
    stages = [  # (blocks, cin, cmid, cout, hw)
        (3, 64, 64, 256, 56),
        (4, 256, 128, 512, 28),
        (6, 512, 256, 1024, 14),
        (3, 1024, 512, 2048, 7),
    ]
    for s, (nblk, cin, cmid, cout, hw) in enumerate(stages):
        for b in range(nblk):
            layers.append(
                _bottleneck(f"s{s}b{b}", cin if b == 0 else cout, cmid, cout, hw, b == 0)
            )
    layers.append(_fc("fc", 2048, 1000))
    return LayerGraph("resnet50", tuple(layers), in_bytes=224 * 224 * 3 * ACT_BYTES)


def inceptionv3() -> LayerGraph:
    """Stage-level InceptionV3 chain (299x299x3): published block output
    shapes; per-block params distributed to match the ~23.8M total."""
    blocks = [  # (name, params, out_h/w, out_c)
        ("stem1", 0.03e6, 147, 32),
        ("stem2", 0.1e6, 147, 64),
        ("stem3", 0.3e6, 71, 192),
        ("mixed0", 0.26e6, 35, 256),
        ("mixed1", 0.28e6, 35, 288),
        ("mixed2", 0.29e6, 35, 288),
        ("mixed3", 1.2e6, 17, 768),
        ("mixed4", 1.3e6, 17, 768),
        ("mixed5", 1.4e6, 17, 768),
        ("mixed6", 1.4e6, 17, 768),
        ("mixed7", 1.6e6, 17, 768),
        ("mixed8", 1.7e6, 8, 1280),
        ("mixed9", 5.0e6, 8, 2048),
        ("mixed10", 6.1e6, 8, 2048),
    ]
    layers = [
        Layer(
            name=n,
            param_bytes=int(p) * PARAM_BYTES,
            out_bytes=hw * hw * c * ACT_BYTES,
            flops=int(p) * 2 * hw * hw,
        )
        for (n, p, hw, c) in blocks
    ]
    layers.append(_fc("fc", 2048, 1000))
    return LayerGraph("inceptionv3", tuple(layers), in_bytes=299 * 299 * 3 * ACT_BYTES)


def _inverted_residual(name: str, cin: int, cout: int, hw: int, expand: int = 6) -> Layer:
    cexp = cin * expand
    params = cin * cexp + 9 * cexp + cexp * cout
    return Layer(
        name=name,
        param_bytes=params * PARAM_BYTES,
        out_bytes=hw * hw * cout * ACT_BYTES,
        flops=2 * hw * hw * params,
    )


def mobilenetv2() -> LayerGraph:
    layers = [_conv("stem", 3, 3, 32, 112, 112)]
    cfg = [  # (cin, cout, hw, repeats)
        (32, 16, 112, 1),
        (16, 24, 56, 2),
        (24, 32, 28, 3),
        (32, 64, 14, 4),
        (64, 96, 14, 3),
        (96, 160, 7, 3),
        (160, 320, 7, 1),
    ]
    for i, (cin, cout, hw, rep) in enumerate(cfg):
        for r in range(rep):
            layers.append(_inverted_residual(f"ir{i}_{r}", cin if r == 0 else cout, cout, hw))
    layers.append(_conv("head", 1, 320, 1280, 7, 7))
    layers.append(_fc("fc", 1280, 1000))
    return LayerGraph("mobilenetv2", tuple(layers), in_bytes=224 * 224 * 3 * ACT_BYTES)


PAPER_MODELS = {
    "vgg16": vgg16,
    "resnet50": resnet50,
    "inceptionv3": inceptionv3,
    "mobilenetv2": mobilenetv2,
}


def demo_mlp(d: int = 32, n_layers: int = 8):
    """An *executable* demo model for the edge serving examples/benchmarks.

    Returns ``(graph, executor_for_version)``: a tanh-MLP layer graph plus a
    version -> ``ExecutorFn`` factory whose weights are keyed by the model
    version (``PRNGKey(version)``), so a ``VersionBumped`` redeploy visibly
    changes the served function.  jax is imported lazily to keep the CNN
    zoo importable without it.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.graph import chain
    from repro.runtime.pipeline import make_layer_executor

    graph = chain(
        f"mlp{n_layers}", [(d * d * 4, 16 * d * 4)] * n_layers, in_bytes=16 * d * 4
    )

    def executor_for_version(version: int):
        ws = np.asarray(
            jax.random.normal(jax.random.PRNGKey(version), (n_layers, d, d)) * 0.3
        )
        return make_layer_executor(
            [lambda x, w=ws[i]: jnp.tanh(x @ w) for i in range(n_layers)]
        )

    return graph, executor_for_version


def demo_ssm(d: int = 24, n_layers: int = 6, seq: int = 8, heads: int = 2,
             state: int = 4):
    """An executable state-space demo model (Mamba2-style mixing layers).

    The multi-tenant tests/benchmarks need a second small model whose layer
    shapes genuinely differ from ``demo_mlp`` -- same ``(graph,
    executor_for_version)`` contract, but each layer is a selective-state
    scan riding the ``kernels/ssm_scan`` reference path (``ssd_chunked``
    with ``use_pallas=False``): input/output projections plus the chunked
    SSD recurrence, with a residual + tanh around it.  Activations flow
    between layers as ``(seq, d)`` float32, so ``out_bytes = seq * d * 4``
    and per-layer params are the B/C/dt projections -- both distinct from
    the MLP's ``d x d`` blocks.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.graph import chain
    from repro.kernels.ssm_scan.ops import ssd_chunked
    from repro.runtime.pipeline import make_layer_executor

    if d % heads != 0:
        raise ValueError(f"d={d} must be divisible by heads={heads}")
    dh = d // heads
    act_bytes = seq * d * ACT_BYTES
    # per-layer params: Wb/Wc (d x state each) + Wdt (d x heads) + a (heads)
    param_bytes = (2 * d * state + d * heads + heads) * 4
    graph = chain(
        f"ssm{n_layers}", [(param_bytes, act_bytes)] * n_layers,
        in_bytes=act_bytes,
    )

    def executor_for_version(version: int):
        key = jax.random.fold_in(jax.random.PRNGKey(version), 0x55D)
        kb, kc, kd = jax.random.split(key, 3)
        wb = np.asarray(jax.random.normal(kb, (n_layers, d, state)) * 0.3)
        wc = np.asarray(jax.random.normal(kc, (n_layers, d, state)) * 0.3)
        wd = np.asarray(jax.random.normal(kd, (n_layers, d, heads)) * 0.3)
        a = np.full((heads,), -0.5, np.float32)

        def layer(x, i):
            # batch-polymorphic like demo_mlp: the serving engine stacks a
            # microbatch onto a leading axis, so fold any leading dims into
            # ssd_chunked's batch dim and restore the caller's shape after
            x = jnp.asarray(x, jnp.float32)
            xb = x.reshape(-1, seq, d)
            n = xb.shape[0]
            xs = xb.reshape(n, seq, heads, dh)
            bm = xb @ wb[i]
            cm = xb @ wc[i]
            dt = jax.nn.softplus(xb @ wd[i])
            y = ssd_chunked(xs, bm, cm, dt, jnp.asarray(a), chunk=seq)
            return jnp.tanh(xb + y.reshape(n, seq, d)).reshape(x.shape)

        return make_layer_executor(
            [lambda x, i=i: layer(x, i) for i in range(n_layers)]
        )

    return graph, executor_for_version
