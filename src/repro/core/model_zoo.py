"""Layer graphs of the CNNs used in the paper-style evaluation.

SEIFER's preliminary evaluation (Fig. 3) sweeps several Keras-style vision
models (the DEFER predecessor used VGG16/ResNet-family models).  We
reconstruct their chain layer graphs from the published architectures:
per-layer parameter counts and output activation shapes.  Parameters default
to 1 byte each (the paper quantizes models with TFLite before deployment);
activations default to 4 bytes (float), with an optional compression ratio
applied by the caller (paper: ZFP/LZ4).

These graphs feed ``core.simulate`` and the Fig. 3 / throughput benchmarks.
The assigned LM architectures export their own graphs via
``models/graph_export.py``.
"""

from __future__ import annotations

from repro.core.graph import Layer, LayerGraph

PARAM_BYTES = 1  # int8-quantized weights (TFLite), per the paper
ACT_BYTES = 4  # float32 activations on the wire


def _conv(name: str, k: int, cin: int, cout: int, oh: int, ow: int) -> Layer:
    return Layer(
        name=name,
        param_bytes=(k * k * cin * cout + cout) * PARAM_BYTES,
        out_bytes=oh * ow * cout * ACT_BYTES,
        flops=2 * k * k * cin * cout * oh * ow,
    )


def _fc(name: str, cin: int, cout: int) -> Layer:
    return Layer(
        name=name,
        param_bytes=(cin * cout + cout) * PARAM_BYTES,
        out_bytes=cout * ACT_BYTES,
        flops=2 * cin * cout,
    )


def vgg16() -> LayerGraph:
    """VGG16 (224x224x3).  Pooling folded into the preceding conv's output."""
    cfg = [
        # (cin, cout, out_h/w after optional pool)
        (3, 64, 224),
        (64, 64, 112),  # pool
        (64, 128, 112),
        (128, 128, 56),  # pool
        (128, 256, 56),
        (256, 256, 56),
        (256, 256, 28),  # pool
        (256, 512, 28),
        (512, 512, 28),
        (512, 512, 14),  # pool
        (512, 512, 14),
        (512, 512, 14),
        (512, 512, 7),  # pool
    ]
    layers = [
        _conv(f"conv{i}", 3, cin, cout, hw, hw) for i, (cin, cout, hw) in enumerate(cfg)
    ]
    layers += [_fc("fc1", 7 * 7 * 512, 4096), _fc("fc2", 4096, 4096), _fc("fc3", 4096, 1000)]
    return LayerGraph("vgg16", tuple(layers), in_bytes=224 * 224 * 3 * ACT_BYTES)


def _bottleneck(name: str, cin: int, cmid: int, cout: int, hw: int, downsample: bool) -> Layer:
    params = cin * cmid + 9 * cmid * cmid + cmid * cout + (cin * cout if downsample else 0)
    flops = 2 * hw * hw * (cin * cmid + 9 * cmid * cmid + cmid * cout)
    return Layer(
        name=name,
        param_bytes=params * PARAM_BYTES,
        out_bytes=hw * hw * cout * ACT_BYTES,
        flops=flops,
    )


def resnet50() -> LayerGraph:
    layers = [_conv("stem", 7, 3, 64, 112, 112)]
    stages = [  # (blocks, cin, cmid, cout, hw)
        (3, 64, 64, 256, 56),
        (4, 256, 128, 512, 28),
        (6, 512, 256, 1024, 14),
        (3, 1024, 512, 2048, 7),
    ]
    for s, (nblk, cin, cmid, cout, hw) in enumerate(stages):
        for b in range(nblk):
            layers.append(
                _bottleneck(f"s{s}b{b}", cin if b == 0 else cout, cmid, cout, hw, b == 0)
            )
    layers.append(_fc("fc", 2048, 1000))
    return LayerGraph("resnet50", tuple(layers), in_bytes=224 * 224 * 3 * ACT_BYTES)


def inceptionv3() -> LayerGraph:
    """Stage-level InceptionV3 chain (299x299x3): published block output
    shapes; per-block params distributed to match the ~23.8M total."""
    blocks = [  # (name, params, out_h/w, out_c)
        ("stem1", 0.03e6, 147, 32),
        ("stem2", 0.1e6, 147, 64),
        ("stem3", 0.3e6, 71, 192),
        ("mixed0", 0.26e6, 35, 256),
        ("mixed1", 0.28e6, 35, 288),
        ("mixed2", 0.29e6, 35, 288),
        ("mixed3", 1.2e6, 17, 768),
        ("mixed4", 1.3e6, 17, 768),
        ("mixed5", 1.4e6, 17, 768),
        ("mixed6", 1.4e6, 17, 768),
        ("mixed7", 1.6e6, 17, 768),
        ("mixed8", 1.7e6, 8, 1280),
        ("mixed9", 5.0e6, 8, 2048),
        ("mixed10", 6.1e6, 8, 2048),
    ]
    layers = [
        Layer(
            name=n,
            param_bytes=int(p) * PARAM_BYTES,
            out_bytes=hw * hw * c * ACT_BYTES,
            flops=int(p) * 2 * hw * hw,
        )
        for (n, p, hw, c) in blocks
    ]
    layers.append(_fc("fc", 2048, 1000))
    return LayerGraph("inceptionv3", tuple(layers), in_bytes=299 * 299 * 3 * ACT_BYTES)


def _inverted_residual(name: str, cin: int, cout: int, hw: int, expand: int = 6) -> Layer:
    cexp = cin * expand
    params = cin * cexp + 9 * cexp + cexp * cout
    return Layer(
        name=name,
        param_bytes=params * PARAM_BYTES,
        out_bytes=hw * hw * cout * ACT_BYTES,
        flops=2 * hw * hw * params,
    )


def mobilenetv2() -> LayerGraph:
    layers = [_conv("stem", 3, 3, 32, 112, 112)]
    cfg = [  # (cin, cout, hw, repeats)
        (32, 16, 112, 1),
        (16, 24, 56, 2),
        (24, 32, 28, 3),
        (32, 64, 14, 4),
        (64, 96, 14, 3),
        (96, 160, 7, 3),
        (160, 320, 7, 1),
    ]
    for i, (cin, cout, hw, rep) in enumerate(cfg):
        for r in range(rep):
            layers.append(_inverted_residual(f"ir{i}_{r}", cin if r == 0 else cout, cout, hw))
    layers.append(_conv("head", 1, 320, 1280, 7, 7))
    layers.append(_fc("fc", 1280, 1000))
    return LayerGraph("mobilenetv2", tuple(layers), in_bytes=224 * 224 * 3 * ACT_BYTES)


PAPER_MODELS = {
    "vgg16": vgg16,
    "resnet50": resnet50,
    "inceptionv3": inceptionv3,
    "mobilenetv2": mobilenetv2,
}


def demo_mlp(d: int = 32, n_layers: int = 8):
    """An *executable* demo model for the edge serving examples/benchmarks.

    Returns ``(graph, executor_for_version)``: a tanh-MLP layer graph plus a
    version -> ``ExecutorFn`` factory whose weights are keyed by the model
    version (``PRNGKey(version)``), so a ``VersionBumped`` redeploy visibly
    changes the served function.  jax is imported lazily to keep the CNN
    zoo importable without it.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.graph import chain
    from repro.runtime.pipeline import make_layer_executor

    graph = chain(
        f"mlp{n_layers}", [(d * d * 4, 16 * d * 4)] * n_layers, in_bytes=16 * d * 4
    )

    def executor_for_version(version: int):
        ws = np.asarray(
            jax.random.normal(jax.random.PRNGKey(version), (n_layers, d, d)) * 0.3
        )
        return make_layer_executor(
            [lambda x, w=ws[i]: jnp.tanh(x @ w) for i in range(n_layers)]
        )

    return graph, executor_for_version


def demo_ssm(d: int = 24, n_layers: int = 6, seq: int = 8, heads: int = 2,
             state: int = 4, *, use_pallas: bool = False,
             interpret: bool = False):
    """An executable state-space demo model (Mamba2-style mixing layers).

    The multi-tenant tests/benchmarks need a second small model whose layer
    shapes genuinely differ from ``demo_mlp`` -- same ``(graph,
    executor_for_version)`` contract, but each layer is a selective-state
    scan riding ``kernels/ssm_scan``'s ``ssd_chunked``: input/output
    projections plus the chunked SSD recurrence, with a residual + tanh
    around it.  ``use_pallas``/``interpret`` (the deployment execution
    knob, ``repro.core.execution``) select the Pallas SSD kernel vs its
    jnp ref.  Activations flow between layers as ``(seq, d)`` float32, so
    ``out_bytes = seq * d * 4`` and per-layer params are the B/C/dt
    projections -- both distinct from the MLP's ``d x d`` blocks.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.graph import chain
    from repro.kernels.ssm_scan.ops import ssd_chunked
    from repro.runtime.pipeline import make_layer_executor

    if d % heads != 0:
        raise ValueError(f"d={d} must be divisible by heads={heads}")
    dh = d // heads
    act_bytes = seq * d * ACT_BYTES
    # per-layer params: Wb/Wc (d x state each) + Wdt (d x heads) + a (heads)
    param_bytes = (2 * d * state + d * heads + heads) * 4
    graph = chain(
        f"ssm{n_layers}", [(param_bytes, act_bytes)] * n_layers,
        in_bytes=act_bytes,
    )

    def executor_for_version(version: int):
        key = jax.random.fold_in(jax.random.PRNGKey(version), 0x55D)
        kb, kc, kd = jax.random.split(key, 3)
        wb = np.asarray(jax.random.normal(kb, (n_layers, d, state)) * 0.3)
        wc = np.asarray(jax.random.normal(kc, (n_layers, d, state)) * 0.3)
        wd = np.asarray(jax.random.normal(kd, (n_layers, d, heads)) * 0.3)
        a = np.full((heads,), -0.5, np.float32)

        def layer(x, i):
            # batch-polymorphic like demo_mlp: the serving engine stacks a
            # microbatch onto a leading axis, so fold any leading dims into
            # ssd_chunked's batch dim and restore the caller's shape after
            x = jnp.asarray(x, jnp.float32)
            xb = x.reshape(-1, seq, d)
            n = xb.shape[0]
            xs = xb.reshape(n, seq, heads, dh)
            bm = xb @ wb[i]
            cm = xb @ wc[i]
            dt = jax.nn.softplus(xb @ wd[i])
            y = ssd_chunked(xs, bm, cm, dt, jnp.asarray(a), chunk=seq,
                            use_pallas=use_pallas, interpret=interpret)
            return jnp.tanh(xb + y.reshape(n, seq, d)).reshape(x.shape)

        return make_layer_executor(
            [lambda x, i=i: layer(x, i) for i in range(n_layers)]
        )

    return graph, executor_for_version


def demo_transformer(d: int = 32, n_layers: int = 4, seq: int = 256,
                     heads: int = 4, kv_heads: int = 2, mlp_mult: int = 2,
                     window: int = 128, softcap: float = 50.0,
                     attn_block: int = 128, *, use_pallas: bool = False,
                     interpret: bool = False):
    """An executable transformer demo model on the flash-attention kernels.

    Architecture knobs are scaled-down ``configs.archs.GEMMA2_27B``: GQA at
    ratio 2 (``heads=4, kv_heads=2`` mirroring 32/16), logit softcap 50.0,
    and gemma2's local/global alternation -- odd layers attend through a
    sliding window, even layers globally.  Every layer's attention runs
    ``kernels.flash_attention`` (blocked layout; the Pallas TPU kernel when
    the execution knob says ``use_pallas``), so this is the model that puts
    real attention compute on the serving hot path.

    Each layer's FIRST op is ``x @ Wqkv`` and nothing else reads ``x``, so
    when the inbound link codec is int8 the layer's fused handler (the
    ``fused`` attribute consumed by ``make_layer_executor``) feeds the wire
    payload straight into ``kernels.quantize.dequant_matmul`` -- the
    dequantized activation is never materialized.  Activations are
    ``(seq, d)`` float32 between layers.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.graph import chain
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.quantize import dequant_matmul
    from repro.runtime.pipeline import make_layer_executor

    if d % heads or heads % kv_heads:
        raise ValueError(f"need d % heads == 0 and heads % kv_heads == 0, "
                         f"got d={d}, heads={heads}, kv_heads={kv_heads}")
    hd = d // heads
    proj = (heads + 2 * kv_heads) * hd  # fused q|k|v projection width
    f = mlp_mult * d
    act_bytes = seq * d * ACT_BYTES
    param_bytes = (d * proj + d * d + 2 * d * f) * 4
    graph = chain(
        f"transformer{n_layers}", [(param_bytes, act_bytes)] * n_layers,
        in_bytes=act_bytes,
    )

    def executor_for_version(version: int):
        key = jax.random.fold_in(jax.random.PRNGKey(version), 0xA77)
        kq, ko, k1, k2 = jax.random.split(key, 4)
        wqkv = np.asarray(jax.random.normal(kq, (n_layers, d, proj)) * 0.3)
        wo = np.asarray(jax.random.normal(ko, (n_layers, d, d)) * 0.3)
        w1 = np.asarray(jax.random.normal(k1, (n_layers, d, f)) * 0.3)
        w2 = np.asarray(jax.random.normal(k2, (n_layers, f, d)) * 0.3)

        def tail(qkv, out_shape, i, win):
            # everything after the qkv projection: attention + out-proj +
            # gelu MLP, residual around the MLP, tanh to keep depth stable
            qkvb = jnp.asarray(qkv, jnp.float32).reshape(-1, seq, proj)
            n = qkvb.shape[0]
            qh = qkvb[..., : heads * hd].reshape(n, seq, heads, hd)
            kk = qkvb[..., heads * hd : (heads + kv_heads) * hd]
            vv = qkvb[..., (heads + kv_heads) * hd :]
            o = flash_attention(
                qh,
                kk.reshape(n, seq, kv_heads, hd),
                vv.reshape(n, seq, kv_heads, hd),
                causal=True, window=win, softcap=softcap, block=attn_block,
                use_pallas=use_pallas, interpret=interpret,
            )
            y = o.reshape(n, seq, d) @ wo[i]
            z = y + jax.nn.gelu(y @ w1[i]) @ w2[i]
            return jnp.tanh(z).reshape(out_shape)

        def make_layer(i):
            # gemma2-style alternation: odd layers local (sliding window)
            win = window if (window > 0 and i % 2 == 1) else 0

            def layer_fn(x):
                x = jnp.asarray(x, jnp.float32)
                qkv = x.reshape(-1, seq, d) @ wqkv[i]
                return tail(qkv, x.shape, i, win)

            def fused_int8(enc):
                # enc: dataplane EncodedActivation with an Int8Codec payload
                if enc.payload[0] != "jax":
                    return layer_fn(enc.decode())
                _, q, s, _dtype = enc.payload
                qkv = dequant_matmul(
                    q, s, jnp.asarray(wqkv[i]), dtype=jnp.float32,
                    block=enc.codec.block, use_pallas=use_pallas,
                    interpret=interpret,
                )
                return tail(qkv, q.shape, i, win)

            layer_fn.fused = {"int8": fused_int8}
            return layer_fn

        return make_layer_executor([make_layer(i) for i in range(n_layers)])

    return graph, executor_for_version
