"""Layer-graph abstraction consumed by the SEIFER partitioner.

The paper treats a DNN as a chain of layers; each inter-layer edge carries
the activation bytes produced by the earlier layer.  ``LayerGraph`` captures
exactly the three quantities the partitioning/placement algorithms need:

  * ``param_bytes``  -- memory the layer occupies on a device (weights),
  * ``out_bytes``    -- activation bytes sent to the *next* layer (edge weight),
  * ``flops``        -- compute cost (used by the beyond-paper joint objective).

All SEIFER algorithms are architecture-agnostic: any model that can export a
``LayerGraph`` (CNNs for the paper's own evaluation, every assigned LM arch
via ``models/graph_export.py``) is partitionable.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence


@dataclasses.dataclass(frozen=True)
class Layer:
    """One node in the chain."""

    name: str
    param_bytes: int
    out_bytes: int  # activation bytes handed to the next layer
    flops: int = 0

    def __post_init__(self) -> None:
        if self.param_bytes < 0 or self.out_bytes < 0 or self.flops < 0:
            raise ValueError(f"layer {self.name!r}: negative size")


@dataclasses.dataclass(frozen=True)
class LayerGraph:
    """A chain-structured DNN graph.

    ``layers[i].out_bytes`` is the weight of the edge (i, i+1).  The final
    layer's ``out_bytes`` is the model *output* size (used only when the
    dispatcher round-trip is included in the bottleneck metric).
    """

    name: str
    layers: tuple[Layer, ...]
    in_bytes: int = 0  # model input size (dispatcher -> first partition)

    def __post_init__(self) -> None:
        if not self.layers:
            raise ValueError("LayerGraph needs at least one layer")

    # -- basic accessors -------------------------------------------------
    def __len__(self) -> int:
        return len(self.layers)

    @property
    def total_param_bytes(self) -> int:
        return sum(l.param_bytes for l in self.layers)

    @property
    def total_flops(self) -> int:
        return sum(l.flops for l in self.layers)

    def edge_bytes(self, i: int) -> int:
        """Activation bytes crossing the cut between layer i and i+1."""
        if not 0 <= i < len(self.layers) - 1:
            raise IndexError(f"edge {i} out of range for {len(self.layers)} layers")
        return self.layers[i].out_bytes

    @property
    def edges(self) -> tuple[int, ...]:
        """All inter-layer edge weights (len == n_layers - 1)."""
        return tuple(l.out_bytes for l in self.layers[:-1])

    # -- partition helpers ------------------------------------------------
    def segment_param_bytes(self, start: int, stop: int) -> int:
        """Parameter bytes of the contiguous segment layers[start:stop]."""
        return sum(l.param_bytes for l in self.layers[start:stop])

    def segment_flops(self, start: int, stop: int) -> int:
        return sum(l.flops for l in self.layers[start:stop])

    def prefix_param_bytes(self) -> list[int]:
        """prefix[i] = sum of param_bytes of layers[:i]; len == n+1."""
        acc, out = 0, [0]
        for l in self.layers:
            acc += l.param_bytes
            out.append(acc)
        return out

    def prefix_flops(self) -> list[int]:
        acc, out = 0, [0]
        for l in self.layers:
            acc += l.flops
            out.append(acc)
        return out


@dataclasses.dataclass(frozen=True)
class Partition:
    """A contiguous slice [start, stop) of the layer chain."""

    start: int
    stop: int
    param_bytes: int
    flops: int
    out_bytes: int  # bytes sent to the next partition (0 for the last)

    @property
    def n_layers(self) -> int:
        return self.stop - self.start


def make_partitions(graph: LayerGraph, cuts: Sequence[int]) -> tuple[Partition, ...]:
    """Materialize partitions from sorted cut points.

    ``cuts`` are layer indices i meaning "cut the edge between layer i and
    layer i+1"; e.g. cuts=[2, 5] over 8 layers yields [0:3), [3:6), [6:8).
    """
    n = len(graph)
    cuts = sorted(cuts)
    if any(not 0 <= c < n - 1 for c in cuts):
        raise ValueError(f"cut out of range: {cuts} for {n} layers")
    if len(set(cuts)) != len(cuts):
        raise ValueError(f"duplicate cuts: {cuts}")
    bounds = [0] + [c + 1 for c in cuts] + [n]
    parts = []
    for s, e in zip(bounds[:-1], bounds[1:]):
        parts.append(
            Partition(
                start=s,
                stop=e,
                param_bytes=graph.segment_param_bytes(s, e),
                flops=graph.segment_flops(s, e),
                out_bytes=graph.layers[e - 1].out_bytes if e < n else 0,
            )
        )
    return tuple(parts)


def boundary_bytes(parts: Sequence[Partition]) -> tuple[int, ...]:
    """Bytes crossing each of the k-1 partition boundaries."""
    return tuple(p.out_bytes for p in parts[:-1])


def chain(name: str, sizes: Iterable[tuple[int, int]], in_bytes: int = 0) -> LayerGraph:
    """Convenience constructor from (param_bytes, out_bytes) pairs."""
    layers = tuple(
        Layer(name=f"{name}.{i}", param_bytes=p, out_bytes=o)
        for i, (p, o) in enumerate(sizes)
    )
    return LayerGraph(name=name, layers=layers, in_bytes=in_bytes)
