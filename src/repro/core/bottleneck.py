"""Bottleneck-latency / throughput metrics (SEIFER Sec. 2.2-1a).

Latency of a link = bytes transferred / bandwidth.  The *bottleneck latency*
of an inference pipeline is the maximum link latency; pipeline throughput is
its reciprocal.  The extended metric additionally accounts for per-stage
compute time (used when mapping placements onto TPU pods, where stage compute
can dominate the link): steady-state pipeline period = max over all stage
compute times and link latencies.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.graph import Partition
from repro.core.placement import CommGraph


@dataclasses.dataclass(frozen=True)
class PipelineMetrics:
    bottleneck_latency: float  # s, max link latency (paper metric)
    pipeline_period: float  # s, max(link latency, stage compute) (extended)
    end_to_end_latency: float  # s, sum of stage compute + link latencies
    throughput: float  # 1 / bottleneck_latency (paper)
    effective_throughput: float  # 1 / pipeline_period (extended)


def link_latencies(
    boundaries: Sequence[float], path: Sequence[int], comm: CommGraph
) -> list[float]:
    out = []
    for i, w in enumerate(boundaries):
        b = comm.bw[path[i], path[i + 1]]
        out.append(float("inf") if b <= 0 else w / b)
    return out


def evaluate_pipeline(
    partitions: Sequence[Partition],
    path: Sequence[int],
    comm: CommGraph,
    device_flops: float | Sequence[float] | None = None,
    in_bytes: float = 0.0,
    dispatcher: int | None = None,
    compression_ratio: float = 1.0,
) -> PipelineMetrics:
    """Score a (partition, placement) pair.

    ``compression_ratio`` models boundary compression (paper: ZFP/LZ4; ours:
    blockwise int8): transferred bytes are divided by it.
    """
    if len(path) != len(partitions):
        raise ValueError("path length != number of partitions")
    boundaries = [p.out_bytes / compression_ratio for p in partitions[:-1]]
    lats = link_latencies(boundaries, path, comm)
    if dispatcher is not None and in_bytes > 0 and len(path) > 0:
        b = comm.bw[dispatcher, path[0]]
        lats = [float("inf") if b <= 0 else (in_bytes / compression_ratio) / b] + lats
    bottleneck = max(lats, default=0.0)
    if device_flops is None:
        compute = [0.0] * len(partitions)
    else:
        flops = (
            [float(device_flops)] * len(partitions)
            if np.isscalar(device_flops)
            else [float(device_flops[node]) for node in path]
        )
        compute = [p.flops / f if f > 0 else float("inf") for p, f in zip(partitions, flops)]
    period = max([bottleneck] + compute)
    e2e = sum(compute) + sum(l for l in lats if np.isfinite(l))
    return PipelineMetrics(
        bottleneck_latency=float(bottleneck),
        pipeline_period=float(period),
        end_to_end_latency=float(e2e),
        throughput=0.0 if bottleneck == float("inf") else (float("inf") if bottleneck == 0 else 1.0 / bottleneck),
        effective_throughput=0.0 if period == float("inf") else (float("inf") if period == 0 else 1.0 / period),
    )
