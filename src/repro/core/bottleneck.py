"""Bottleneck-latency / throughput metrics (SEIFER Sec. 2.2-1a).

Latency of a link = bytes transferred / bandwidth.  The *bottleneck latency*
of an inference pipeline is the maximum link latency; pipeline throughput is
its reciprocal.  The extended metric additionally accounts for per-stage
compute time (used when mapping placements onto TPU pods, where stage compute
can dominate the link): steady-state pipeline period = max over all stage
compute times and link latencies.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.graph import Partition
from repro.core.placement import CommGraph


@dataclasses.dataclass(frozen=True)
class PipelineMetrics:
    bottleneck_latency: float  # s, max link latency (paper metric)
    pipeline_period: float  # s, max(link latency, stage compute) (extended)
    end_to_end_latency: float  # s, sum of stage compute + link latencies
    throughput: float  # 1 / bottleneck_latency (paper)
    effective_throughput: float  # 1 / pipeline_period (extended)


def link_latencies(
    boundaries: Sequence[float], path: Sequence[int], comm: CommGraph
) -> list[float]:
    out = []
    for i, w in enumerate(boundaries):
        b = comm.bw[path[i], path[i + 1]]
        out.append(float("inf") if b <= 0 else w / b)
    return out


def node_flops(flops_per_node, node: int | None) -> float:
    """Resolve a scalar-or-per-node flops model for one node (0 = unmodelled).

    The single dispatch point for ``flops_per_node``: ``service_times`` and
    the data plane's codec cost model both price compute through it."""
    if flops_per_node is None or node is None:
        return 0.0
    if np.isscalar(flops_per_node):
        return float(flops_per_node)
    return float(flops_per_node[node])


def service_times(
    partitions: Sequence[Partition],
    path: Sequence[int],
    bw: np.ndarray,
    *,
    flops_per_node: float | Sequence[float] | None = None,
    in_bytes: float = 0.0,
    out_bytes: float = 0.0,
    dispatcher: int | None = None,
    compression_ratio: float = 1.0,
    codecs: Sequence | None = None,
) -> tuple[list[float], list[float]]:
    """The single timing model shared by the discrete-event serving engine,
    the planner's prediction, and the TPU pipeline planner.

    Returns ``(compute_s, link_s)``:

      * ``compute_s[i]`` -- stage i's service time, ``partition.flops /
        flops_per_node[path[i]]`` (0 when flops are unmodelled),
      * ``link_s`` -- one entry per *hop*, ``len(path) + 1`` long:
        ``link_s[0]`` is the dispatcher -> first-stage input transfer,
        ``link_s[h]`` (1 <= h <= k-1) is the stage h-1 -> stage h boundary,
        ``link_s[k]`` is the last-stage -> dispatcher output transfer.
        Colocated endpoints (or zero bytes, or no dispatcher) cost 0.

    ``codecs`` (one ``repro.dataplane.Codec`` or registered name per hop)
    puts a transfer codec on each link: the hop's serial window is then
    charged ``codec_encode (sender flops) + wire_bytes / bandwidth +
    codec_decode (receiver flops)`` -- compressed bytes ride the wire, and
    the codec's compute rides the link window it serializes.  ``None``
    keeps every hop raw.  The legacy ``compression_ratio`` divides bytes
    *before* the codec sees them (the knobs compose; both default off).

    The pipeline's steady-state period is ``max(compute_s + link_s)`` --
    every stage and every link is a serial resource, so the bottleneck one
    sets the cadence once the pipe is full.
    """
    if codecs is not None:
        from repro.dataplane import resolve_codecs

        codecs = resolve_codecs(codecs)
        if len(codecs) != len(path) + 1:
            raise ValueError(
                f"expected {len(path) + 1} hop codecs, got {len(codecs)}")

    def flops_at(node: int | None) -> float:
        return node_flops(flops_per_node, node)

    def hop(a: int | None, b: int | None, bytes_: float, h: int) -> float:
        if bytes_ <= 0 or a is None or b is None or a == b:
            return 0.0
        rate = float(bw[a, b])
        raw = bytes_ / compression_ratio
        if codecs is None:
            return float("inf") if rate <= 0 else raw / rate
        from repro.dataplane import link_charge_s

        return link_charge_s(
            codecs[h], raw, rate,
            src_flops=flops_at(a), dst_flops=flops_at(b),
        )

    compute = []
    for part, node in zip(partitions, path):
        f = flops_at(node)
        compute.append(part.flops / f if f > 0 else 0.0)
    links = [hop(dispatcher, path[0] if path else None, in_bytes, 0)]
    for i in range(len(path) - 1):
        links.append(
            hop(path[i], path[i + 1], float(partitions[i].out_bytes), i + 1))
    links.append(hop(path[-1] if path else None, dispatcher, out_bytes, len(path)))
    return compute, links


def evaluate_pipeline(
    partitions: Sequence[Partition],
    path: Sequence[int],
    comm: CommGraph,
    device_flops: float | Sequence[float] | None = None,
    in_bytes: float = 0.0,
    out_bytes: float = 0.0,
    dispatcher: int | None = None,
    compression_ratio: float = 1.0,
    codecs: Sequence | None = None,
) -> PipelineMetrics:
    """Score a (partition, placement) pair.

    ``compression_ratio`` models boundary compression (paper: ZFP/LZ4; ours:
    blockwise int8): transferred bytes are divided by it.  ``codecs`` (one
    per hop, see ``service_times``) charges each link with its transfer
    codec's ``encode + compressed transfer + decode`` window.  ``in_bytes``
    / ``out_bytes`` charge the dispatcher round-trip hops when
    ``dispatcher`` is given (colocation costs nothing).
    """
    if len(path) != len(partitions):
        raise ValueError("path length != number of partitions")
    compute, hops = service_times(
        partitions, path, comm.bw,
        flops_per_node=device_flops,
        in_bytes=in_bytes if dispatcher is not None else 0.0,
        out_bytes=out_bytes if dispatcher is not None else 0.0,
        dispatcher=dispatcher,
        compression_ratio=compression_ratio,
        codecs=codecs,
    )
    lats = [h for h in hops if h > 0]
    bottleneck = max(lats, default=0.0)
    period = max([bottleneck] + compute)
    e2e = sum(compute) + sum(l for l in lats if np.isfinite(l))
    return PipelineMetrics(
        bottleneck_latency=float(bottleneck),
        pipeline_period=float(period),
        end_to_end_latency=float(e2e),
        throughput=0.0 if bottleneck == float("inf") else (float("inf") if bottleneck == 0 else 1.0 / bottleneck),
        effective_throughput=0.0 if period == float("inf") else (float("inf") if period == 0 else 1.0 / period),
    )
